"""Corruption faults, wire integrity, and Byzantine-robust aggregation.

Covers the PR-9 robustness tier: the seeded CORRUPT fault class
(bit-flips, NaN poison, persistent Byzantine workers), CRC32 wire
framing with the post-decode finite guard, the extended exact-ledger
contract (ok + lost + dup + corrupted == comm), the robust-aggregator
registry at the sync-PS quorum step, checkpoint-donor checksum
re-fetch, and the ACCEPTANCE criterion — f=2 sign-flip Byzantine
workers of N=8, trimmed-mean sync-PS within 2x of the healthy loss at
equal simulated wall-clock on the quadratic AND the reduced LM, naive
mean worse than the robust rule by an asserted margin.
"""
import dataclasses
import math

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
import jax.numpy as jnp

from repro import cluster
from repro.cluster import aggregators, faults
from repro.core import compression

N = 8


def _spec(**kw):
    base = dict(n_workers=N, t_compute=1.0,
                multipliers=cluster.straggler_multipliers(N, factor=4.0),
                t_lat=1e-2, t_tr=2e-3, size_mb=1.0)
    base.update(kw)
    return cluster.ClusterSpec(**base)


# ---------------------------------------------------------------------------
# FaultPlan: the corruption class is seeded and pure
# ---------------------------------------------------------------------------


def test_corruption_decisions_are_pure_functions():
    p = faults.FaultPlan(N, seed=7, p_corrupt=0.3, p_poison=0.2,
                         p_ckpt_corrupt=0.5)
    for _ in range(3):
        assert p.corrupts_msg(0, 8, "agg3", 0) == \
            p.corrupts_msg(0, 8, "agg3", 0)
        assert p.poisons_msg(2, 8, "agg3", 1) == \
            p.poisons_msg(2, 8, "agg3", 1)
        assert p.corrupt_bit(0, 8, "agg3", 0, 4096) == \
            p.corrupt_bit(0, 8, "agg3", 0, 4096)
        assert p.bad_checkpoint(3, 7, 2) == p.bad_checkpoint(3, 7, 2)
    # distinct identities draw independently
    assert {p.corrupts_msg(s, 8, f"agg{r}", 0)
            for s in range(N) for r in range(20)} == {True, False}
    bits = {p.corrupt_bit(0, 8, f"agg{r}", 0, 4096) for r in range(50)}
    assert len(bits) > 10 and all(0 <= b < 4096 for b in bits)


def test_byzantine_roster_validation():
    p = faults.byzantine_workers(N, f=2, mode="sign_flip", scale=4.0)
    assert p.byzantine == ((0, "sign_flip"), (1, "sign_flip"))
    assert p.is_byzantine(0) and not p.is_byzantine(2)
    assert p.byzantine_mode(1) == "sign_flip"
    assert p.byzantine_mode(5) is None
    with pytest.raises(ValueError, match="mode"):
        faults.FaultPlan(N, byzantine=((0, "evil"),))
    with pytest.raises(ValueError, match="names worker"):
        faults.FaultPlan(N, byzantine=((9, "sign_flip"),))


# ---------------------------------------------------------------------------
# Ledger exactness with the corrupted status
# ---------------------------------------------------------------------------


def test_corruption_ledger_exactness_sync_and_async():
    plan = faults.FaultPlan(N, seed=4, p_drop=0.1, p_dup=0.05,
                            p_corrupt=0.15, p_poison=0.05)
    for name, kw in (("sync_ps", {"quorum": 6}), ("async_ps", {})):
        proto = cluster.make_protocol(name, **kw)
        tr = (proto.schedule(_spec(), rounds=3, plan=plan)
              if name == "sync_ps"
              else proto.schedule(_spec(), horizon=20.0, plan=plan))
        tally = faults.validate(tr)   # exact accounting, or it throws
        corr = sum(1 for d in tr.comm if d.status == "corrupted")
        assert tally["corrupted"] == corr > 0, name
        lost = sum(1 for d in tr.comm if d.status == "lost")
        dup = sum(1 for d in tr.comm if d.status == "dup")
        ok = sum(1 for d in tr.comm if d.status == "ok")
        assert ok + lost + dup + corr == len(tr.comm), name
        # both corruption kinds fire under p_corrupt + p_poison
        kinds = {r.kind for r in tr.faults.corrupt}
        assert "bitflip" in kinds, name


def test_corrupt_traces_are_deterministic():
    plan = faults.FaultPlan(N, seed=4, p_corrupt=0.2, p_poison=0.1,
                            p_drop=0.1)
    t1 = cluster.make_protocol("sync_ps", quorum=6).schedule(
        _spec(jitter=0.3, seed=9), rounds=3, plan=plan)
    t2 = cluster.make_protocol("sync_ps", quorum=6).schedule(
        _spec(jitter=0.3, seed=9), rounds=3, plan=plan)
    assert t1 == t2 and t1.faults == t2.faults


def test_validate_catches_a_forged_corrupt_ledger():
    plan = faults.corrupt_wire(N, p_corrupt=0.3, seed=0)
    tr = cluster.make_protocol("sync_ps", quorum=6).schedule(
        _spec(), rounds=3, plan=plan)
    assert tr.faults.n_corrupted > 0
    forged = dataclasses.replace(
        tr, faults=dataclasses.replace(tr.faults, corrupt=()))
    with pytest.raises(AssertionError):
        faults.validate(forged)


def test_all_corrupted_round_terminates_as_quorum_shortfall():
    """p_corrupt = 1: every uplink fails its CRC every round — the round
    must close as a recorded QuorumShortfall (carrying the previous
    params), and the reliable broadcast retry chain must terminate."""
    plan = faults.FaultPlan(N, seed=0, p_corrupt=1.0, max_retries=2)
    tr = cluster.make_protocol("sync_ps").schedule(_spec(), rounds=3,
                                                   plan=plan)
    tally = faults.validate(tr)
    assert tally["shortfalls"] == 3
    assert tally["corrupted"] > 0 and math.isfinite(tr.makespan)
    # the replay carries params0 through every shortfall round
    wl = cluster.quadratic_workload(n_workers=N)
    res = cluster.replay(tr, wl, lr=0.1, eval_every=1)
    f0 = float(wl.eval_loss(wl.params0))
    assert np.allclose(res.losses, f0)


# ---------------------------------------------------------------------------
# Wire integrity: CRC32 framing + the finite guard
# ---------------------------------------------------------------------------


def _tree(seed=0):
    key = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(key, (96,)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (17,))}


def test_crc_frame_roundtrip_and_checked_decode():
    cdc = compression.QuantCodec(4, backend="jnp")
    packed = cdc.tree_encode_flat(_tree(), jax.random.PRNGKey(2))
    framed, crc = compression.frame(packed)
    compression.verify_wire(framed, crc)            # clean frame passes
    out = compression.checked_decode(cdc, framed, crc)
    ref = cdc.flat_decode(packed)
    assert all(np.array_equal(a, b) for a, b in zip(
        jax.tree_util.tree_leaves(out), jax.tree_util.tree_leaves(ref)))
    with pytest.raises(compression.WireCorruptionError, match="CRC32"):
        compression.verify_wire(framed, crc ^ 1)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_any_single_bitflip_is_caught_by_crc(raw):
    """PROPERTY: flipping any one bit of the framed payload or params —
    any bucket, any quantization width, Pallas and jnp backends — fails
    the CRC check on receive. The drawn integer indexes a different
    frame bit per (bits, backend) combination, and the boundary draws
    cover bit 0 and the last params bit."""
    for backend in ("jnp", "pallas"):
        for bits in (2, 4, 8):
            cdc = compression.QuantCodec(bits, backend=backend)
            packed = cdc.tree_encode_flat(_tree(1), jax.random.PRNGKey(3))
            n_bits = compression.wire_bits(packed)
            bit = raw % n_bits
            _, crc = compression.frame(packed)
            flipped = compression.flip_bit(packed, bit)
            ctx = (backend, bits, bit)
            with pytest.raises(compression.WireCorruptionError):
                compression.verify_wire(flipped, crc)
                pytest.fail(f"undetected flip: {ctx}")
            with pytest.raises(compression.WireCorruptionError):
                compression.checked_decode(cdc, flipped, crc)
                pytest.fail(f"undetected flip through decode: {ctx}")


def test_plan_corrupt_bit_indexes_the_frame():
    plan = faults.FaultPlan(N, seed=3, p_corrupt=1.0)
    cdc = compression.QuantCodec(4, backend="jnp")
    packed = cdc.tree_encode_flat(_tree(2), jax.random.PRNGKey(4))
    n_bits = compression.wire_bits(packed)
    _, crc = compression.frame(packed)
    bit = plan.corrupt_bit(0, N, "agg0", 0, n_bits)
    assert 0 <= bit < n_bits
    with pytest.raises(compression.WireCorruptionError):
        compression.verify_wire(compression.flip_bit(packed, bit), crc)


def test_finite_guard_catches_poison_that_frames_correctly():
    """A NaN-poisoned message re-framed by the sender has a CONSISTENT
    checksum — only the post-decode guard can catch it."""
    cdc = compression.QuantCodec(4, backend="jnp")
    packed = cdc.tree_encode_flat(_tree(3), jax.random.PRNGKey(5))
    poisoned = dataclasses.replace(
        packed, params=jnp.full_like(packed.params, jnp.nan))
    framed, crc = compression.frame(poisoned)
    compression.verify_wire(framed, crc)            # CRC cannot see it
    with pytest.raises(compression.WireCorruptionError, match="NaN|Inf"):
        compression.checked_decode(cdc, framed, crc)
    assert compression.tree_finite(cdc.flat_decode(packed))
    assert not compression.tree_finite({"x": jnp.array([1.0, jnp.inf])})


# ---------------------------------------------------------------------------
# Robust aggregators: masked numpy references
# ---------------------------------------------------------------------------


def _np_refs(g, mask):
    rows = g[mask.astype(bool)]
    n = g.shape[0]
    f = max(1, n // 4)
    refs = {"mean": rows.mean(0) if rows.size else np.zeros(g.shape[1:])}
    s = np.sort(rows, axis=0)
    if rows.shape[0] > 2 * f:
        refs["trimmed_mean"] = s[f:rows.shape[0] - f].mean(0)
    else:
        refs["trimmed_mean"] = refs["mean"]
    refs["coordinate_median"] = (np.median(rows, axis=0) if rows.size
                                 else np.zeros(g.shape[1:]))
    return refs


@pytest.mark.parametrize("live", [list(range(N)), [0, 2, 3, 5, 6, 7],
                                  [1, 4], [3], []])
def test_aggregators_match_numpy_references(live):
    rng = np.random.default_rng(0)
    g = rng.normal(size=(N, 7)).astype(np.float32)
    mask = np.zeros(N, dtype=np.float32)
    mask[live] = 1.0
    refs = _np_refs(g, mask)
    for name in ("mean", "trimmed_mean", "coordinate_median"):
        out = np.asarray(aggregators.AGGREGATORS[name](
            jnp.asarray(g), jnp.asarray(mask)))
        assert np.allclose(out, refs[name], atol=1e-5), (name, live)
    # every rule returns zeros on an empty mask (shortfall semantics)
    if not live:
        for name, fn in aggregators.AGGREGATORS.items():
            out = np.asarray(fn(jnp.asarray(g), jnp.asarray(mask)))
            assert np.allclose(out, 0.0), name


def test_norm_clip_bounds_row_norms_to_masked_median():
    rng = np.random.default_rng(1)
    g = rng.normal(size=(N, 5)).astype(np.float32)
    g[0] *= 100.0                                   # the large-norm attack
    mask = jnp.ones(N)
    out = np.asarray(aggregators.norm_clip(jnp.asarray(g), mask))
    naive = g.mean(0)
    honest = g[1:].mean(0)
    # clipping pulls the aggregate far closer to the honest mean
    assert np.linalg.norm(out - honest) < 0.2 * np.linalg.norm(
        naive - honest)


def test_aggregator_registry_rejects_unknown_rules():
    with pytest.raises(KeyError, match="unknown aggregator"):
        aggregators.aggregator("krum")
    with pytest.raises(KeyError, match="unknown aggregator"):
        cluster.make_protocol("sync_ps", aggregator="krum").schedule(
            _spec(), rounds=1, plan=faults.FaultPlan(N))


def test_mean_aggregator_is_bit_identical_to_legacy_quorum_path():
    """The registry's default must not move a single bit of the existing
    quorum replay (its arithmetic is the compatibility contract)."""
    plan = faults.lossy_network(N, p_drop=0.2, seed=1)
    wl = cluster.quadratic_workload(n_workers=N)
    tr = cluster.make_protocol("sync_ps", quorum=5).schedule(
        _spec(), rounds=4, plan=plan)
    r1 = cluster.replay(tr, wl, lr=0.1, eval_every=1)
    tr2 = cluster.make_protocol("sync_ps", quorum=5,
                                aggregator="mean").schedule(
        _spec(), rounds=4, plan=plan)
    r2 = cluster.replay(tr2, wl, lr=0.1, eval_every=1)
    assert np.array_equal(r1.losses, r2.losses)


# ---------------------------------------------------------------------------
# Checkpoint-donor integrity: the second-donor re-fetch
# ---------------------------------------------------------------------------


def test_rejoiner_refetches_from_next_donor_on_checksum_failure():
    """p_ckpt_corrupt = 1: every donor checkpoint fails verification
    until the last candidate — the rejoin lands on a LATER donor than
    the healthy run's first pick, each rejected fetch is ledgered as a
    kind='checksum' CorruptRecord, and the accounting stays exact."""
    base = faults.churn(N, departures=((5, 3.0),), joins=((7, 4.0),))
    plan = dataclasses.replace(base, p_ckpt_corrupt=1.0)
    tr = cluster.make_protocol("dsgd").schedule(_spec(), rounds=6,
                                                plan=plan)
    tally = faults.validate(tr)
    healthy = cluster.make_protocol("dsgd").schedule(_spec(), rounds=6,
                                                     plan=base)
    (rejoin,) = [r for r in tr.faults.rejoins if r.worker == 7]
    (ref_rejoin,) = [r for r in healthy.faults.rejoins if r.worker == 7]
    assert rejoin.donor != ref_rejoin.donor        # walked past donor 0
    ck = [r for r in tr.faults.corrupt if r.dst == 7]
    assert ck and all(r.kind == "checksum" for r in ck)
    assert tally["corrupted"] == len(ck)
    # rejected fetches cost retry waits: the rejoin happens LATER
    assert tr.makespan > healthy.makespan
    # the re-fetch chain is deterministic
    tr2 = cluster.make_protocol("dsgd").schedule(_spec(), rounds=6,
                                                 plan=plan)
    assert tr == tr2


# ---------------------------------------------------------------------------
# ACCEPTANCE: f=2 Byzantine of N=8, robust rule vs naive mean
# ---------------------------------------------------------------------------


def _byz_run(spec, wl, *, rounds, lr, plan, agg):
    tr = cluster.make_protocol("sync_ps", aggregator=agg).schedule(
        spec, rounds=rounds, plan=plan)
    faults.validate(tr)
    return cluster.replay(tr, wl, lr=lr, eval_every=1)


def test_acceptance_byzantine_quadratic():
    """ACCEPTANCE (quadratic): trimmed-mean within 2x of the healthy
    loss at equal simulated wall-clock under f=2 sign-flip workers;
    naive mean recovers at most 75% of the robust rule's progress."""
    spec = _spec()
    wl = cluster.quadratic_workload(n_workers=N, batch=256)
    rounds, lr = 10, 0.1
    healthy = cluster.make_protocol("sync_ps").schedule(spec,
                                                        rounds=rounds)
    t_eq = healthy.makespan
    ref = cluster.replay(healthy, wl, lr=lr, eval_every=1)
    f0 = float(wl.eval_loss(wl.params0))

    plan = faults.byzantine_workers(N, f=2, mode="sign_flip")
    robust = _byz_run(spec, wl, rounds=rounds, lr=lr, plan=plan,
                      agg="trimmed_mean")
    naive = _byz_run(spec, wl, rounds=rounds, lr=lr, plan=plan,
                     agg="mean")
    # same wire, same simulated wall-clock: Byzantine rows cost nothing
    assert robust.makespan == pytest.approx(healthy.makespan)

    ref_loss = ref.loss_at(t_eq)
    assert robust.loss_at(t_eq) <= 2.0 * ref_loss
    prog_ref = f0 - ref_loss
    prog_robust = f0 - robust.loss_at(t_eq)
    prog_naive = f0 - naive.loss_at(t_eq)
    assert prog_robust >= 0.6 * prog_ref            # near-full recovery
    assert prog_naive <= 0.75 * prog_robust         # the asserted margin


def test_acceptance_byzantine_lm_smoke():
    """ACCEPTANCE (reduced LM): trimmed-mean within 2x of healthy at
    equal simulated wall-clock under sign-flip; under the scaled attack
    (where divergence is measurable above the reduced model's gradient
    noise) naive mean climbs above the initial loss while trimmed-mean
    stays an asserted margin below it."""
    spec = _spec()
    wl = cluster.lm_workload(smoke=True)
    rounds, lr = 3, 0.05
    healthy = cluster.make_protocol("sync_ps").schedule(spec,
                                                        rounds=rounds)
    t_eq = healthy.makespan
    ref = cluster.replay(healthy, wl, lr=lr, eval_every=1)
    f0 = float(wl.eval_loss(wl.params0))

    sign = faults.byzantine_workers(N, f=2, mode="sign_flip")
    robust_sf = _byz_run(spec, wl, rounds=rounds, lr=lr, plan=sign,
                         agg="trimmed_mean")
    assert robust_sf.loss_at(t_eq) <= 2.0 * ref.loss_at(t_eq)

    scaled = faults.byzantine_workers(N, f=2, mode="scale", scale=-8.0)
    naive = _byz_run(spec, wl, rounds=rounds, lr=lr, plan=scaled,
                     agg="mean")
    robust = _byz_run(spec, wl, rounds=rounds, lr=lr, plan=scaled,
                      agg="trimmed_mean")
    assert naive.loss_at(t_eq) >= f0 + 0.005        # measurable divergence
    assert robust.loss_at(t_eq) <= naive.loss_at(t_eq) - 0.005
    assert robust.loss_at(t_eq) <= 2.0 * ref.loss_at(t_eq)


def test_byzantine_replay_is_deterministic_and_honest_without_roster():
    """An empty roster leaves the replay graph untouched (bit-identical
    losses to a plain faulted run); a roster makes the run seeded-
    reproducible."""
    wl = cluster.quadratic_workload(n_workers=N)
    plan = faults.byzantine_workers(N, f=2, mode="random", scale=4.0)
    r1 = _byz_run(_spec(), wl, rounds=3, lr=0.1, plan=plan,
                  agg="coordinate_median")
    r2 = _byz_run(_spec(), wl, rounds=3, lr=0.1, plan=plan,
                  agg="coordinate_median")
    assert np.array_equal(r1.losses, r2.losses)
    assert np.isfinite(r1.losses).all()


# ---------------------------------------------------------------------------
# Obs: corruption instants under the verified-counts contract
# ---------------------------------------------------------------------------


def test_timeline_renders_corruption_instants_with_verified_counts():
    from repro.obs import export as obs_export
    from repro.obs import trace as obs_trace

    plan = faults.FaultPlan(N, seed=4, p_drop=0.1, p_corrupt=0.2,
                            p_poison=0.05)
    tr = cluster.make_protocol("sync_ps", quorum=6).schedule(
        _spec(), rounds=3, plan=plan)
    faults.validate(tr)
    assert tr.faults.n_corrupted > 0
    tl = obs_trace.timeline_from_trace(tr)
    obs_export.verify_timeline(tr, tl)              # exact, or it throws
    events = tl.events()
    instants = [e for e in events
                if e.get("ph") == "i" and e.get("cat") == "fault,corrupt"]
    assert len(instants) == len(tr.faults.corrupt)
    wire_corrupt = [e for e in events if e.get("ph") == "X"
                    and e.get("cat", "").endswith(",corrupted")]
    assert len(wire_corrupt) == sum(1 for d in tr.comm
                                    if d.status == "corrupted")
    kinds = {e["args"]["kind"] for e in instants}
    assert kinds <= {"bitflip", "nan", "checksum"}
