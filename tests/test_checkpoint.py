"""Checkpoint robustness: atomic npz writes + corrupt-file validation.

A crash mid-checkpoint (the failure mode ``cluster.FaultPlan`` injects
into the simulated tier) must never leave a half-written ``step-*.npz``:
``save_state`` publishes with write-temp-then-``os.replace``, and
``load_state`` raises ``ValueError`` on truncated/corrupt archives
instead of deserializing garbage.
"""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_state, save_state


def _state():
    return {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": jnp.ones((4,), jnp.bfloat16)}


def test_save_publishes_atomically_no_temp_residue(tmp_path):
    f = save_state(_state(), str(tmp_path), step=3)
    assert os.path.basename(f) == "step-00000003.npz"
    # the temp file was renamed away, never left behind
    assert sorted(os.listdir(tmp_path)) == ["step-00000003.npz"]
    restored = load_state(_state(), f)
    np.testing.assert_array_equal(
        np.asarray(restored["w"]),
        np.arange(6, dtype=np.float32).reshape(2, 3))
    assert restored["b"].dtype == jnp.bfloat16


def test_truncated_checkpoint_raises_cleanly(tmp_path):
    f = save_state(_state(), str(tmp_path), step=0)
    raw = open(f, "rb").read()
    for cut in (10, len(raw) // 2, len(raw) - 4):
        bad = tmp_path / "bad.npz"
        bad.write_bytes(raw[:cut])
        with pytest.raises(ValueError, match="corrupt or truncated"):
            load_state(_state(), str(bad))


def test_garbage_file_raises_cleanly(tmp_path):
    bad = tmp_path / "garbage.npz"
    bad.write_bytes(b"this is not a zip archive at all")
    with pytest.raises(ValueError, match="corrupt or truncated"):
        load_state(_state(), str(bad))


def test_missing_file_stays_file_not_found(tmp_path):
    # absence is not corruption — the caller distinguishes the two
    with pytest.raises(FileNotFoundError):
        load_state(_state(), str(tmp_path / "step-00000042.npz"))


def test_failed_write_leaves_previous_checkpoint_intact(tmp_path):
    f = save_state(_state(), str(tmp_path), step=7)
    before = open(f, "rb").read()

    class Boom:
        # a leaf whose device_get explodes mid-serialization
        shape, dtype = (2,), np.float32

        def __array__(self, *a, **k):
            raise RuntimeError("simulated crash mid-checkpoint")

    with pytest.raises(RuntimeError, match="simulated crash"):
        save_state({"w": Boom()}, str(tmp_path), step=7)
    # the failed write neither clobbered step-7 nor left temp files
    assert sorted(os.listdir(tmp_path)) == ["step-00000007.npz"]
    assert open(f, "rb").read() == before


# ---------------------------------------------------------------------------
# Per-array CRC32 integrity (PR-9)
# ---------------------------------------------------------------------------


def test_checkpoint_carries_per_array_checksums(tmp_path):
    f = save_state(_state(), str(tmp_path), step=1)
    keys = set(np.load(f).files)
    arrays = {k for k in keys if not k.startswith("__crc__")}
    assert {"__crc__" + k for k in arrays} <= keys
    back = load_state(_state(), f)          # clean verify on load
    assert np.array_equal(np.asarray(back["w"]),
                          np.asarray(_state()["w"]))
    assert back["b"].dtype == jnp.bfloat16


def test_bitflipped_array_fails_checksum_naming_the_leaf(tmp_path):
    from repro.checkpoint import CheckpointCorruptionError

    f = save_state(_state(), str(tmp_path), step=2)
    data = dict(np.load(f))
    arr = data["w"].copy()
    raw = bytearray(arr.tobytes())
    raw[3] ^= 0x40                           # one silent bit-flip
    data["w"] = np.frombuffer(bytes(raw),
                              dtype=arr.dtype).reshape(arr.shape)
    np.savez(f, **data)                      # valid zip, bad bytes
    with pytest.raises(CheckpointCorruptionError, match="'w'"):
        load_state(_state(), f)
    assert issubclass(CheckpointCorruptionError, ValueError)


def test_bf16_checksum_covers_raw_stored_bytes(tmp_path):
    f = save_state(_state(), str(tmp_path), step=3)
    data = dict(np.load(f))
    arr = data["__bf16__b"].copy()           # stored as a uint16 view
    arr[0] ^= 1
    data["__bf16__b"] = arr
    np.savez(f, **data)
    with pytest.raises(ValueError, match="__bf16__b"):
        load_state(_state(), f)


def test_checksumless_archive_still_loads(tmp_path):
    # pre-integrity checkpoints (no __crc__ entries) stay restorable
    f = save_state(_state(), str(tmp_path), step=4)
    data = {k: v for k, v in dict(np.load(f)).items()
            if not k.startswith("__crc__")}
    np.savez(f, **data)
    back = load_state(_state(), f)
    assert np.array_equal(np.asarray(back["w"]),
                          np.asarray(_state()["w"]))
