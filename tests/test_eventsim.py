"""The Section 1.3 communication model must reproduce the paper's closed
forms and the qualitative claims of Figures 1.3-1.7 / 3.4-3.5 / 5.2-5.3."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import eventsim, theory


LAT, TR = 1.5, 5.0


def test_single_ps_closed_form():
    """§1.3.2: 2 N (t_lat + t_tr)."""
    for n in (2, 3, 4, 8):
        got = eventsim.single_ps_makespan(n, 1.0, t_lat=LAT, t_tr=TR)
        assert got == pytest.approx(2 * n * (LAT + TR))


def test_ring_allreduce_closed_form():
    """§1.3.3: 2(N-1)(t_lat + t_tr/N) ~= 2 N t_lat + 2 t_tr."""
    n = 8
    got = eventsim.ring_allreduce_makespan(n, 1.0, t_lat=LAT, t_tr=TR)
    assert got == pytest.approx(2 * (n - 1) * (LAT + TR / n))
    # paper's asymptotic form
    assert got == pytest.approx(2 * n * LAT + 2 * TR, rel=0.35)


def test_unpartitioned_ring_loses_bandwidth():
    """'Why do we partition': unpartitioned = 2N(t_lat+t_tr) >> partitioned."""
    n = 8
    part = eventsim.ring_allreduce_makespan(n, 1.0, t_lat=LAT, t_tr=TR)
    nopart = eventsim.ring_allreduce_makespan(n, 1.0, t_lat=LAT, t_tr=TR,
                                              partitioned=False)
    assert nopart == pytest.approx(2 * (n - 1) * (LAT + TR))
    assert nopart > part * 3


def test_multi_ps_equals_ring_allreduce():
    """§1.3.4: same cost as ring AllReduce under the model."""
    n = 8
    assert eventsim.multi_ps_makespan(n, 1.0, t_lat=LAT, t_tr=TR) == \
        pytest.approx(eventsim.ring_allreduce_makespan(n, 1.0, t_lat=LAT,
                                                       t_tr=TR))


def test_csgd_ring_makespan_partitioned_vs_monolithic():
    """CSGDRingExchange's cost forms: partitioned = 2(N-1) rounds of
    size/N chunks (== the generic partitioned ring AllReduce), monolithic
    = N-1 full-size hops; per-worker wire bytes 2M(N-1)/N vs (N-1)M."""
    n = 8
    part = eventsim.csgd_ring_makespan(n, 1.0, t_lat=LAT, t_tr=TR)
    mono = eventsim.csgd_ring_makespan(n, 1.0, t_lat=LAT, t_tr=TR,
                                       partitioned=False)
    assert part == pytest.approx(2 * (n - 1) * (LAT + TR / n))
    assert part == pytest.approx(
        eventsim.ring_allreduce_makespan(n, 1.0, t_lat=LAT, t_tr=TR))
    assert mono == pytest.approx((n - 1) * (LAT + TR))
    assert eventsim.ring_wire_mb_per_worker(n, 1.0) == \
        pytest.approx(2 * (n - 1) / n)
    assert eventsim.ring_wire_mb_per_worker(n, 1.0, partitioned=False) == \
        pytest.approx(n - 1)


def test_partitioned_ring_ledger_2n_minus_1_messages_per_worker():
    """Acceptance: simulating one partitioned ring iteration records
    exactly 2(N-1) wire messages SENT per worker in the per-wire ledger,
    moving 2M(N-1)/N bytes per worker."""
    n, size = 6, 12.0
    res = eventsim.simulate(
        eventsim.ring_allreduce_msgs(n, size), t_lat=LAT, t_tr=TR)
    sent = {w: [m for m in res.messages if m.src == w] for w in range(n)}
    for w in range(n):
        assert len(sent[w]) == 2 * (n - 1)
        assert sum(m.size for m in sent[w]) == \
            pytest.approx(2 * size * (n - 1) / n)


def test_decentralized_o1_latency():
    """§5.1: 2 t_lat + 2 t_tr independent of N."""
    for n in (4, 16, 256):
        got = eventsim.decentralized_makespan(n, 1.0, t_lat=LAT, t_tr=TR)
        assert got == pytest.approx(2 * (LAT + TR))


@given(st.floats(1.1, 32.0))
@settings(max_examples=20, deadline=None)
def test_compression_scales_transfer_only(k):
    """Figures 3.4/3.5: K-times compression divides transfer time by K and
    leaves latency untouched."""
    n = 8
    base = eventsim.ring_allreduce_makespan(n, 1.0, t_lat=LAT, t_tr=TR)
    comp = eventsim.ring_allreduce_makespan(n, 1.0, t_lat=LAT, t_tr=TR,
                                            compression=k)
    lat_part = 2 * (n - 1) * LAT
    tr_part = base - lat_part
    assert comp == pytest.approx(lat_part + tr_part / k)


def test_example_1_3_2_saving_is_transfer_only():
    """Example 1.3.1/1.3.2: with 2x compression the three-event span shrinks
    by exactly the transfer saving (paper: 14 -> 9; our port semantics give
    13 -> 8 — same delta, see eventsim docstring)."""
    msgs = [eventsim.Msg(5.0, 0, 1, 1.0), eventsim.Msg(6.0, 1, 0, 1.0),
            eventsim.Msg(6.0, 2, 1, 1.0)]
    full = eventsim.simulate(msgs, t_lat=1.5, t_tr=5.0)
    half = eventsim.simulate([eventsim.Msg(m.t_req, m.src, m.dst, 0.5)
                              for m in msgs], t_lat=1.5, t_tr=5.0)
    assert full.span == pytest.approx(13.0)
    assert half.span == pytest.approx(8.0)
    assert full.span - half.span == pytest.approx(5.0)  # pure transfer delta


def test_worker_port_serialization():
    """A worker receives one message at a time (Example 1.3.1)."""
    msgs = [eventsim.Msg(0.0, 0, 2, 1.0), eventsim.Msg(0.0, 1, 2, 1.0)]
    res = eventsim.simulate(msgs, t_lat=LAT, t_tr=TR)
    d = sorted(res.deliveries, key=lambda x: x.t_start)
    assert d[1].t_start >= d[0].t_end


def test_concurrent_send_recv_allowed():
    msgs = [eventsim.Msg(0.0, 0, 1, 1.0), eventsim.Msg(0.0, 1, 0, 1.0)]
    res = eventsim.simulate(msgs, t_lat=LAT, t_tr=TR)
    assert res.makespan == pytest.approx(LAT + TR)


def test_async_no_global_barrier():
    """Figure 4.2: with one slow worker, fast workers keep pushing updates;
    staleness stays bounded and positive for somebody."""
    updates = eventsim.async_ps_timeline(
        3, t_compute=[1.0, 1.0, 10.0], t_lat=0.1, t_tr=0.2, size=1.0,
        horizon=60.0)
    by_worker = {}
    for w, t, s in updates:
        by_worker.setdefault(w, []).append((t, s))
    assert len(by_worker[0]) > 2 * len(by_worker[2])   # fast >> slow
    assert max(s for _, _, s in updates) >= 1          # staleness occurs


def test_async_staleness_bounded_linear_in_n():
    """Figure 4.2 invariant: at equal worker speeds the async-PS staleness
    is exactly n-1 (every other worker lands one update per cycle); a
    k-times straggler stretches it to at most k*n."""
    for n in (2, 4, 8, 16):
        ups = eventsim.async_ps_timeline(
            n, t_compute=[1.0] * n, t_lat=0.01, t_tr=0.002, size=1.0,
            horizon=100.0)
        assert max(s for *_, s in ups) == n - 1
    for n in (4, 8):
        ups = eventsim.async_ps_timeline(
            n, t_compute=[1.0] * (n - 1) + [4.0], t_lat=0.01, t_tr=0.002,
            size=1.0, horizon=100.0)
        assert n - 1 < max(s for *_, s in ups) <= 4 * n


def test_async_throughput_beats_sync_under_straggler():
    """Figure 4.1 invariant: a barrier makes every round pay the
    straggler; async keeps the fast workers pushing."""
    n, horizon = 8, 200.0
    t_compute = [1.0] * (n - 1) + [4.0]
    sync = eventsim.sync_ps_throughput(n, t_compute_max=max(t_compute),
                                       t_lat=0.01, t_tr=0.002, size=1.0)
    ups = eventsim.async_ps_timeline(n, t_compute=t_compute, t_lat=0.01,
                                     t_tr=0.002, size=1.0, horizon=horizon)
    assert len(ups) / horizon >= sync
    # without the straggler the gap narrows but async still >= sync
    sync_u = eventsim.sync_ps_throughput(n, t_compute_max=1.0, t_lat=0.01,
                                         t_tr=0.002, size=1.0)
    ups_u = eventsim.async_ps_timeline(n, t_compute=[1.0] * n, t_lat=0.01,
                                       t_tr=0.002, size=1.0, horizon=horizon)
    assert len(ups_u) / horizon >= sync_u


def test_async_timeline_sorted_by_apply_time():
    ups = eventsim.async_ps_timeline(
        6, t_compute=[1.0, 1.5, 1.0, 3.0, 1.0, 2.0], t_lat=0.02,
        t_tr=0.005, size=1.0, horizon=80.0)
    times = [t for _, t, _ in ups]
    assert times == sorted(times)
    assert all(s >= 0 for *_, s in ups)


def test_per_message_records_partition_deliveries():
    """SimResult.messages: an n_messages=k transfer is k back-to-back wire
    messages, each paying t_lat + its share of the transfer time."""
    res = eventsim.simulate([eventsim.Msg(0.0, 0, 1, 1.0, "x", 4),
                             eventsim.Msg(0.0, 2, 3, 1.0, "y", 1)],
                            t_lat=LAT, t_tr=TR)
    assert res.n_wire_messages == 5
    xs = sorted((r for r in res.messages if r.tag == "x"),
                key=lambda r: r.index)
    d = next(d for d in res.deliveries if d.tag == "x")
    assert xs[0].t_start == pytest.approx(d.t_start)
    assert xs[-1].t_end == pytest.approx(d.t_end)
    for a, b in zip(xs, xs[1:]):
        assert b.t_start == pytest.approx(a.t_end)
    for r in xs:
        assert r.t_end - r.t_start == pytest.approx(LAT + TR / 4)
        assert r.n_messages == 4


def test_decentralized_degree_from_mixing_matrix():
    """Satellite: the decentralized cost takes deg(W) from any mixing.py
    matrix instead of hardcoding the ring's 2."""
    from repro.core import mixing

    ring_t = eventsim.decentralized_makespan(16, 1.0, t_lat=LAT, t_tr=TR)
    torus_t = eventsim.decentralized_makespan(16, 1.0, t_lat=LAT, t_tr=TR,
                                              w=mixing.torus_2d(4, 4))
    full_t = eventsim.decentralized_makespan(
        16, 1.0, t_lat=LAT, t_tr=TR, w=mixing.fully_connected(16))
    assert ring_t == pytest.approx(2 * (LAT + TR))
    assert torus_t == pytest.approx(4 * (LAT + TR))
    assert full_t == pytest.approx(15 * (LAT + TR))
    assert eventsim.decentralized_makespan(
        16, 1.0, t_lat=LAT, t_tr=TR, degree=4) == pytest.approx(torus_t)


def test_table_1_1_comm_costs_match_eventsim():
    """Table 1.1 comm-cost column == simulator outputs."""
    n, a, b = 8, LAT, TR
    assert theory.comm_cost_ps(n, a, b) == pytest.approx(
        eventsim.single_ps_makespan(n, 1.0, t_lat=a, t_tr=b))
    assert theory.comm_cost_allreduce(n, a, b) == pytest.approx(
        eventsim.ring_allreduce_makespan(n, 1.0, t_lat=a, t_tr=b), rel=0.35)
    assert theory.comm_cost_decentralized(2, a, b) == pytest.approx(
        eventsim.decentralized_makespan(n, 1.0, t_lat=a, t_tr=b))
