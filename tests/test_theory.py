"""Consistency properties of the Table 1.1/1.2 closed forms."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import theory

W = theory.Workload()


@given(st.floats(1e-4, 1e-1), st.integers(2, 256))
@settings(max_examples=30, deadline=None)
def test_relaxations_never_beat_baseline_iterations(eps, n):
    """The paper's §1.3 point: relaxations do NOT improve iteration counts
    (they improve seconds/iteration)."""
    base = theory.dist_sgd_iterations(W, eps, n)
    assert theory.csgd_iterations(W, eps, n) >= base
    assert theory.ecsgd_iterations(W, eps, n) >= base
    assert theory.asgd_iterations(W, eps, n) >= base
    assert theory.dsgd_iterations(W, eps, n, rho=0.9) >= base


@given(st.floats(1e-4, 1e-1), st.integers(2, 64))
@settings(max_examples=30, deadline=None)
def test_ecsgd_asymptotically_beats_csgd(eps, n):
    """Thm 3.4.2 vs Eq. 3.6: EC's sigma'/eps^1.5 term < CSGD's
    sigma'^2/eps^2 term for small eps."""
    if eps < (W.sigma_c) ** 2:   # regime where the comparison is meaningful
        assert theory.ecsgd_iterations(W, eps, n) <= \
            theory.csgd_iterations(W, eps, n)


@given(st.integers(2, 512))
@settings(max_examples=30, deadline=None)
def test_comm_costs_structure(n):
    a, b = 1e-3, 1e-2
    ps = theory.comm_cost_ps(n, a, b)
    ar = theory.comm_cost_allreduce(n, a, b)
    dec = theory.comm_cost_decentralized(2, a, b)
    assert ps >= ar                      # partitioning helps
    assert dec == pytest.approx(2 * (a + b))   # O(1) in n
    # compression scales only the bandwidth term
    c = theory.comm_cost_compressed(n, a, b, eta=0.25)
    assert c == pytest.approx(2 * n * a + 2 * b * 0.25)


def test_more_workers_fewer_iterations():
    it8 = theory.dist_sgd_iterations(W, 1e-3, 8)
    it64 = theory.dist_sgd_iterations(W, 1e-3, 64)
    assert it64 < it8


def test_learning_rates_positive_and_shrink_with_T():
    for fn, args in [(theory.lr_sgd, (W, 100)), (theory.lr_csgd, (W, 100)),
                     (theory.lr_ecsgd, (W, 100, 8)),
                     (theory.lr_asgd, (W, 100, 8.0)),
                     (theory.lr_dsgd, (W, 100, 8, 0.9))]:
        small = fn(*args)
        big_args = (args[0], 10_000) + args[2:]
        big = fn(*big_args)
        assert 0 < big < small
