"""Compressed decentralized tier: DCD/ECD-PSGD over arbitrary gossip
matrices — replica/delta semantics, degree-correct wire accounting, the
cluster protocol + replay, and the convergence-at-quarter-bytes
acceptance claim."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import cluster
from repro.core import communicators as C
from repro.core import compression, eventsim, mixing, parallel

AXIS = "workers"


def _tree(key, shapes):
    keys = jax.random.split(key, len(shapes))
    return {f"p{i}": jax.random.normal(k, s)
            for i, (k, s) in enumerate(zip(keys, shapes))}


def _stack_tree(key, n, shapes):
    """Per-worker DISTINCT params (the replica invariant must hold even
    when workers start from different models)."""
    return _tree(key, [(n,) + s for s in shapes])


# ---------------------------------------------------------------------------
# exchange semantics
# ---------------------------------------------------------------------------


def test_dcd_identity_codec_tracks_dsgd():
    """With the identity codec the delta broadcast is lossless, so DCD is
    plain D-PSGD (same Birkhoff lowering; fp accumulation order differs,
    hence rtol instead of bit equality)."""
    w = mixing.ring(8)
    dsgd = parallel.run_quadratic("dsgd", n_workers=8, steps=60, lr=0.05,
                                  gossip_w=w)
    dcd = parallel.run_quadratic("dcd", n_workers=8, steps=60, lr=0.05,
                                 gossip_w=w,
                                 exchange_kw={"compressor": "none"})
    np.testing.assert_allclose(np.asarray(dcd.losses),
                               np.asarray(dsgd.losses), rtol=1e-3)


def test_dcd_replica_invariant_bit_exact():
    """The DCD replica-drift lemma: after every mix (i) each worker's
    model IS its public copy, and (ii) the term-k replica every receiver
    holds equals the sender's public copy BIT-EXACTLY — the decoded wire
    delta advances all holders identically."""
    n = 8
    ex = C.DCDGossipExchange(compressor="rq4")
    shapes = [(7,), (3, 5)]
    params_w = _stack_tree(jax.random.PRNGKey(0), n, shapes)
    state_w = ex.init_stacked(params_w)
    layout = compression.FlatLayout.from_tree(
        jax.tree_util.tree_map(lambda p: p[0], params_w))
    _, terms = ex.birkhoff_terms(n)
    assert terms, "ring W must have non-identity Birkhoff terms"

    step = jax.vmap(
        lambda p, s, k: ex(p, s, k, axis_name=AXIS),
        axis_name=AXIS, in_axes=(0, 0, None))
    for t in range(4):
        params_w, state_w = step(params_w, state_w,
                                 jax.random.PRNGKey(100 + t))
        flat_w = jax.vmap(layout.flatten)(params_w)
        # (i) model == public copy
        np.testing.assert_array_equal(np.asarray(flat_w),
                                      np.asarray(state_w["xhat"]))
        # (ii) receiver's replica == sender's public copy, per term
        for k, (_, perm) in enumerate(terms):
            src_of = np.zeros(n, dtype=int)
            for src, dst in perm:
                src_of[dst] = src
            np.testing.assert_array_equal(
                np.asarray(state_w["nbr"][:, k]),
                np.asarray(state_w["xhat"])[src_of])


def test_ecd_residual_feedback_with_biased_codec():
    """ECD carries a single flat fp32 residual (like ECSGD) so the biased
    1-bit sign codec still trains; the residual state really is one flat
    buffer per worker."""
    ecd = parallel.run_quadratic("ecd", n_workers=8, steps=300, lr=0.1)
    assert float(ecd.losses[-1]) < 0.25 * float(ecd.losses[0])
    ex = C.ECDGossipExchange()
    params_w = _stack_tree(jax.random.PRNGKey(1), 4, [(6,), (2, 3)])
    state = ex.init_stacked(params_w)
    assert state["err"].shape == (4, 6 + 2 * 3)


def test_dcd_registry_entries():
    assert isinstance(C.make_exchange("dcd"), C.DCDGossipExchange)
    ecd = C.make_exchange("ecd", topology="torus")
    assert isinstance(ecd, C.ECDGossipExchange)
    assert ecd.error_compensated and ecd.compressor == "sign1"


# ---------------------------------------------------------------------------
# wire accounting
# ---------------------------------------------------------------------------


def test_gossip_and_dcd_message_bytes_scale_with_degree():
    """Per-mix sends scale with mixing.degree(W) for ring vs torus vs an
    explicit dense W — fp32 models for GossipMix, measured fused-flat
    compressed deltas for DCD."""
    tree = {"a": jnp.zeros((4096,)), "b": jnp.zeros((33, 65))}
    fp32 = compression.codec("none").tree_wire_bytes(tree)
    flat4 = compression.codec("rq4").tree_wire_bytes_flat(tree)
    dense = mixing.fully_connected(8)
    cases = [({"topology": "ring"}, 16, 2),
             ({"topology": "torus"}, 16, 4),
             ({"w": dense}, 8, 7)]
    for kw, n, deg in cases:
        assert mixing.degree(C.DCDGossipExchange(**kw)._matrix(n)) == deg
        assert C.GossipMix(**kw).message_bytes(tree, n_workers=n) \
            == deg * fp32
        dcd = C.DCDGossipExchange(**kw)
        assert dcd.message_bytes(tree, n_workers=n) == deg * flat4
        assert dcd.n_wire_messages(n) == deg
    # compressed deltas are far below fp32 per neighbor
    assert flat4 < fp32 / 4


def test_eventsim_decentralized_costs_compressed_bytes():
    """decentralized_makespan / gossip_wire_mb_per_worker with a codec:
    message count (t_lat term) unchanged, transfer term at the measured
    wire size."""
    kw = dict(t_lat=1.0, t_tr=1.0)
    full = eventsim.decentralized_makespan(8, 1.0, **kw)
    comp = eventsim.decentralized_makespan(8, 1.0, codec="rq4", **kw)
    wire = eventsim.wire_size_mb("rq4", int(1e6 / 4))
    assert full == pytest.approx(2 * (1.0 + 1.0))
    assert comp == pytest.approx(2 * (1.0 + wire))
    # per-worker wire MB: degree many messages, codec-measured
    w = mixing.torus_2d(4, 4)
    assert eventsim.gossip_wire_mb_per_worker(1.0, w=w) \
        == pytest.approx(4 * 1.0)
    ratio = eventsim.gossip_wire_mb_per_worker(1.0, codec="rq4") \
        / eventsim.gossip_wire_mb_per_worker(1.0)
    assert ratio <= 0.25


# ---------------------------------------------------------------------------
# cluster protocol + replay
# ---------------------------------------------------------------------------


def _spec(**kw):
    base = dict(n_workers=8, t_compute=1.0,
                multipliers=cluster.straggler_multipliers(8, factor=4.0),
                t_lat=1e-2, t_tr=2e-3, size_mb=1.0)
    base.update(kw)
    return cluster.ClusterSpec(**base)


def test_dcd_protocol_ledger_compressed_and_degree_many():
    """The scheduler ledger accounts compressed bytes AND degree-many
    messages per iteration: dcd rounds ship deg(W) sends per worker (same
    count as dsgd) at the codec's measured wire size (~8x fewer MB for
    rq4)."""
    rounds = 3
    dsgd = cluster.make_protocol("dsgd").schedule(_spec(), rounds=rounds)
    dcd = cluster.make_protocol("dcd").schedule(_spec(), rounds=rounds)
    assert dcd.protocol == "dcd" and dcd.extra("codec") == "rq4"
    deg = dcd.extra("degree")
    assert deg == 2
    for tr in (dsgd, dcd):
        assert len(tr.comm) == deg * 8 * rounds
    wire = eventsim.wire_size_mb("rq4", int(1e6 / 4))
    assert all(d.size == pytest.approx(wire) for d in dcd.comm)
    assert all(d.size == pytest.approx(1.0) for d in dsgd.comm)
    total = lambda tr: sum(d.size for d in tr.comm)
    assert total(dcd) <= total(dsgd) / 4
    # the compressed rounds finish no later (same latency, fewer bytes)
    assert dcd.makespan <= dsgd.makespan + 1e-9


def test_ecd_protocol_uses_its_own_codec():
    ecd = cluster.make_protocol("ecd").schedule(_spec(), rounds=2)
    assert ecd.protocol == "ecd" and ecd.extra("codec") == "sign1"
    wire = eventsim.wire_size_mb("sign1", int(1e6 / 4))
    assert all(d.size == pytest.approx(wire) for d in ecd.comm)


def test_dcd_replay_trains_quadratic_under_straggler():
    """Trace-replayed DCD trains the quadratic: the replay mixes with the
    trace's W, compresses only the broadcast delta with the trace's
    codec, and lands in the same neighborhood as full-precision DSGD."""
    wl = cluster.quadratic_workload(n_workers=8)
    rounds = 40
    dsgd_tr = cluster.make_protocol("dsgd").schedule(_spec(), rounds=rounds)
    dcd_tr = cluster.make_protocol("dcd").schedule(_spec(), rounds=rounds)
    ecd_tr = cluster.make_protocol("ecd").schedule(_spec(), rounds=rounds)
    dsgd = cluster.replay(dsgd_tr, wl, lr=0.1, eval_every=5)
    dcd = cluster.replay(dcd_tr, wl, lr=0.1, eval_every=5)
    ecd = cluster.replay(ecd_tr, wl, lr=0.1, eval_every=5)
    assert dcd.final_loss < dcd.losses[0]          # still descending
    assert dcd.final_loss <= 1.1 * dsgd.final_loss
    assert ecd.final_loss <= 1.25 * dsgd.final_loss
    # simulated time axes exist and are monotone (loss-vs-wall-clock)
    assert np.all(np.diff(dcd.t_wall) > 0)


# ---------------------------------------------------------------------------
# roofline + benchmark plumbing
# ---------------------------------------------------------------------------


def test_roofline_dcd_gossip_entry():
    """The what-if DCD gossip term: deg(W)=2 compressed-delta sends, each
    ONE fused message -> 2 ICI_LAT total, wire measured."""
    from benchmarks.roofline import (ICI_BW, ICI_LAT,
                                     compressed_collective_s, derive)
    rec = {"arch": "repro-100m", "shape": "train_4k", "n_devices": 256,
           "dot_flops": 1e12, "flops_body_once": 1e12,
           "bytes_accessed_body_once": 1e9,
           "argument_size_in_bytes": 2**30, "temp_size_in_bytes": 2**30,
           "collectives": {"total": 4e9,
                           "collective_breakdown": {"all-reduce": 3e9}}}
    out = derive(rec, grad_codec="rq4")
    per_nbr = compressed_collective_s(3e9, "rq4", elem_bytes=2.0,
                                      n_messages=1)
    assert out["gossip_degree"] == 2
    # deg(W)=2 sends, ONE fused message each -> 2 ICI_LAT total in the
    # term (vs the ring what-if's 2(n-1)); the transfer is wire-measured
    assert out["t_gossip_dcd_s"] == pytest.approx(1e9 / ICI_BW + 2 * per_nbr)
    assert per_nbr == pytest.approx(
        compression.codec("rq4").wire_bytes_for(int(3e9 / 2)) / ICI_BW
        + ICI_LAT)


def test_bench_delta_generalizes_to_all_families():
    """bench_delta keys rows of every benchmark family and flags both
    slowdown-style and throughput-drop regressions."""
    from benchmarks.bench_delta import compare, row_key
    assert row_key({"op": "quant_qdq_16K", "us": 1.0}) == "quant_qdq_16K"
    assert row_key({"n": 4, "regime": "bw-bound", "ps": 1.0}) == "4/bw-bound"
    assert row_key({"workload": "quadratic", "protocol": "dcd"}) \
        == "quadratic/dcd"
    base = {"q/dcd": {"workload": "q", "protocol": "dcd",
                      "makespan_s": 10.0, "async_updates_per_s": 6.0,
                      "first_call_us": 1.0}}
    fresh = {"q/dcd": {"workload": "q", "protocol": "dcd",
                       "makespan_s": 25.0, "async_updates_per_s": 2.0,
                       "first_call_us": 100.0}}
    regs = {(k, m): r for k, m, _, _, r in compare(base, fresh, 2.0)}
    assert regs[("q/dcd", "makespan_s")] == pytest.approx(2.5)
    # throughput metrics regress downward
    assert regs[("q/dcd", "async_updates_per_s")] == pytest.approx(3.0)
    # compile-time column is excluded by design
    assert ("q/dcd", "first_call_us") not in regs


# ---------------------------------------------------------------------------
# acceptance
# ---------------------------------------------------------------------------


def test_acceptance_dcd_matches_sync_loss_at_quarter_bytes():
    """ACCEPTANCE: DCD-PSGD (rq4 deltas, ring W) on the quadratic reaches
    the synchronous full-precision loss within 5% at equal iteration
    count, while its measured per-iteration gossip wire is <= 1/4 of
    full-precision DSGD's fp32 bytes (d=1024 so the packed format's lane
    granule amortizes — the same number BENCH_comm.json's 5.dcd row
    reports)."""
    steps, lr, d = 400, 0.2, 1024
    dcd = parallel.run_quadratic("dcd", n_workers=8, steps=steps, lr=lr,
                                 d=d)
    sync = parallel.run_quadratic("mbsgd", n_workers=8, steps=steps, lr=lr,
                                  d=d)
    dsgd = parallel.run_quadratic("dsgd", n_workers=8, steps=steps, lr=lr,
                                  d=d)
    assert float(dcd.losses[-1]) <= 1.05 * float(sync.losses[-1])
    assert float(dcd.losses[-1]) < 0.9 * float(dcd.losses[0])
    # measured wire: deg(W)=2 compressed deltas vs deg(W)=2 fp32 models
    assert dcd.comm_bytes_per_step <= dsgd.comm_bytes_per_step / 4
