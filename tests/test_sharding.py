"""Sharding-rule unit tests + a real (1x1 mesh) lower/compile integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.data.pipeline import make_batch_shapes
from repro.dist import sharding
from repro.models.common import InputShape
from repro.optim import make_optimizer
from repro.train import steps


class FakeKey:
    def __init__(self, key):
        self.key = key


def _mesh(shape=(1, 1)):
    # single real device: a 1x1 mesh still exercises the full spec logic
    return jax.make_mesh(shape, ("data", "model")[:len(shape)])


def _spec(pathnames, shape, mesh):
    path = tuple(FakeKey(n) for n in pathnames)
    return sharding.param_spec(path, shape, mesh)


def test_column_parallel_rule():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    spec = _spec(("layers", "0", "mixer", "q", "w"), (1024, 2048), mesh)
    assert spec == P("data", "model")


def test_row_parallel_rule():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    spec = _spec(("layers", "0", "mixer", "o", "w"), (2048, 1024), mesh)
    assert spec == P("model", "data")


def test_rwkv_channel_mix_v_is_row_parallel():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    spec = _spec(("layers", "0", "ffn", "v", "w"), (2048, 1024), mesh)
    assert spec == P("model", "data")
    # attention 'v' stays column-parallel
    spec2 = _spec(("layers", "0", "mixer", "v", "w"), (1024, 128), mesh)
    assert spec2 == P("data", "model")


def test_maybe_divisibility():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    assert sharding._maybe("model", 7, mesh) == "model"  # 7 % 1 == 0
    # simulate 16-wide axis via a fake mesh object
    class M:
        axis_names = ("data", "model")
        devices = np.empty((4, 4), dtype=object)
    assert sharding._maybe("model", 7, M) is None
    assert sharding._maybe("model", 8, M) == "model"
    assert sharding._maybe(("data",), 8, M) == ("data",)


def test_scan_stacked_param_replicates_layer_dim():
    class M:
        axis_names = ("data", "model")
        devices = np.empty((4, 4), dtype=object)
    spec = _spec(("scan_blocks", "0", "mixer", "q", "w"), (24, 1024, 2048), M)
    assert spec == P(None, ("data",), "model")


def test_moe_bank_rules():
    class M:
        axis_names = ("data", "model")
        devices = np.empty((4, 4), dtype=object)
    assert _spec(("layers", "0", "ffn", "w_gate"), (8, 4096, 32768), M) == \
        P(None, ("data",), "model")
    assert _spec(("layers", "0", "ffn", "w_down"), (8, 32768, 4096), M) == \
        P(None, "model", ("data",))


def test_cache_spec_gqa_head_dim_fallback():
    class M:
        axis_names = ("data", "model")
        devices = np.empty((16, 16), dtype=object)
    path = tuple(FakeKey(n) for n in ("layers", "0", "k"))
    # kv_heads=8 not divisible by 16 -> shard head_dim 128 instead
    spec = sharding.cache_spec(path, (128, 32768, 8, 128), M)
    assert spec == P("data", None, None, "model")
    # kv_heads=16 divisible -> shard heads
    spec2 = sharding.cache_spec(path, (128, 32768, 16, 64), M)
    assert spec2 == P("data", None, "model", None)


def test_batch_spec():
    class M:
        axis_names = ("data", "model")
        devices = np.empty((16, 16), dtype=object)
    assert sharding.batch_spec((256, 4096), M) == P(("data",), None)
    assert sharding.batch_spec((1, 524288), M) == P(None, None)


def test_lower_compile_reduced_arch_on_host_mesh():
    """Integration: the dryrun wiring lowers + compiles on the real device
    (1x1 mesh), for a train step and a decode step."""
    from repro.models import transformer_scan
    cfg = configs.get_config("qwen1.5-0.5b").reduced()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    opt = make_optimizer("adamw", 1e-3)
    scfg = steps.TrainStepConfig(remat=True, scan_layers=True)
    state = steps.abstract_train_state(cfg, opt, step_cfg=scfg)
    batch = make_batch_shapes(cfg, InputShape("t", 64, 4, "train"),
                              dtype=jnp.float32)
    from repro.launch.dryrun import _state_shardings
    with mesh:
        fn = steps.make_train_step(cfg, opt, scfg)
        j = jax.jit(fn, in_shardings=(_state_shardings(state, mesh),
                                      sharding.batch_shardings(batch, mesh)))
        compiled = j.lower(state, batch).compile()
    assert compiled.cost_analysis() is not None

    params = jax.eval_shape(
        lambda k: transformer_scan.init(cfg, k, dtype=jnp.float32),
        jax.random.PRNGKey(0))
    dstate = jax.eval_shape(
        lambda p: transformer_scan.init_decode_state(p, cfg, 4, 64),
        params)
    dbatch = {"tokens": jax.ShapeDtypeStruct((4, 1), jnp.int32)}
    with mesh:
        sfn = steps.make_serve_step(cfg, scan_layers=True)
        j2 = jax.jit(sfn, in_shardings=(
            sharding.params_shardings(params, mesh),
            sharding.cache_shardings(dstate, mesh),
            sharding.batch_shardings(dbatch, mesh)))
        compiled2 = j2.lower(params, dstate, dbatch).compile()
    assert compiled2.cost_analysis() is not None
