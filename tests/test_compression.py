"""Property tests for the Section 3 compression operators (Assumptions 3/4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import compression


def _rand(key, n, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), (n,)) * scale


@pytest.mark.parametrize("name,bound", [("rq8", 0.3), ("rq4", 0.3),
                                        ("rq2", 0.3),
                                        ("rand_sparse_10", 1.0)])
def test_unbiasedness_statistical(name, bound):
    """E[Q(x)] = x for the unbiased operators (Assumption 3).

    Bound ~ 5 * sigma'(op) / sqrt(n_draws); sparsification has per-coord
    std |x| * sqrt((1-p)/p) = 3|x|, hence the looser bound.
    """
    fn, spec = compression.get(name)
    assert spec.unbiased
    x = _rand(0, 256)
    keys = jax.random.split(jax.random.PRNGKey(1), 600)
    qs = jax.vmap(lambda k: fn(x, k))(keys)
    bias = jnp.abs(qs.mean(0) - x).max()
    assert float(bias) < bound, f"{name} bias {bias}"


@pytest.mark.parametrize("name", ["rq8", "rq4"])
def test_quantization_bounded_by_range(name):
    fn, _ = compression.get(name)
    x = _rand(2, 512, scale=3.0)
    q = fn(x, jax.random.PRNGKey(3))
    assert float(q.min()) >= float(x.min()) - 1e-5
    assert float(q.max()) <= float(x.max()) + 1e-5


def test_rq8_error_much_smaller_than_rq2():
    x = _rand(4, 1024)
    e8 = jnp.abs(compression.get("rq8")[0](x, jax.random.PRNGKey(0)) - x).mean()
    e2 = jnp.abs(compression.get("rq2")[0](x, jax.random.PRNGKey(0)) - x).mean()
    assert float(e8) * 10 < float(e2)


def test_sign_is_biased_but_norm_preserving_direction():
    fn, spec = compression.get("sign1")
    assert not spec.unbiased
    x = _rand(5, 128)
    q = fn(x, None)
    assert jnp.all(jnp.sign(q) == jnp.sign(x))
    np.testing.assert_allclose(jnp.abs(q), jnp.mean(jnp.abs(x)), rtol=1e-5)


def test_clip16_is_mantissa_truncation():
    """Deterministic low-bit clipping (Section 3.2's 'Clipping'): keeps the
    top 16 bits — truncation toward zero in the mantissa, i.e. |q| <= |x|
    and the error is below one bf16 ULP. (bf16 *cast* rounds-to-nearest,
    so it is intentionally NOT the comparison.)"""
    x = _rand(6, 128)
    q = compression.get("clip16")[0](x, None)
    assert jnp.all(jnp.abs(q) <= jnp.abs(x))
    ulp = 2.0 ** (jnp.floor(jnp.log2(jnp.abs(x))) - 7)
    assert jnp.all(jnp.abs(q - x) < ulp + 1e-12)


def test_topk_keeps_largest():
    fn, _ = compression.get("topk_1")
    x = jnp.arange(1000.0) - 500.0
    q = fn(x)
    nz = int((q != 0).sum())
    assert 10 <= nz <= 11
    assert q[0] != 0 and q[-1] != 0 and q[500] == 0


@given(st.integers(min_value=1, max_value=10**7))
@settings(max_examples=25, deadline=None)
def test_wire_cost_model(n):
    """Compression ratio eta < 1 for every operator vs fp32 (Table 1.1)."""
    for name in ("rq8", "rq4", "rq2", "sign1", "clip16"):
        _, spec = compression.get(name)
        if n > 100:
            assert spec.ratio(n) < 1.0
        assert spec.compressed_bytes(n) > 0


def test_tree_compress_independent_keys():
    tree = {"a": _rand(7, 64), "b": _rand(8, 64)}
    fn, _ = compression.get("rq8")
    out = compression.tree_compress(tree, jax.random.PRNGKey(0), fn)
    assert out["a"].shape == (64,) and out["b"].shape == (64,)
    # same values -> different keys -> different quantization noise
    tree2 = {"a": tree["a"], "b": tree["a"]}
    out2 = compression.tree_compress(tree2, jax.random.PRNGKey(0), fn)
    assert not jnp.allclose(out2["a"], out2["b"])
