"""Recurrent-layer consistency: parallel scans == stepwise recurrences, and
the Pallas WKV6 kernel wired through the model layer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import rglru, rwkv

KEY = jax.random.PRNGKey(0)


def test_rglru_scan_equals_stepwise():
    """associative_scan path (prefill) == single-token recurrence (decode)."""
    cfg = configs.get_config("recurrentgemma-9b").reduced()
    p = rglru.rglru_block_init(KEY, cfg)
    b, s = 2, 17
    x = jax.random.normal(jax.random.fold_in(KEY, 1),
                          (b, s, cfg.d_model)) * 0.5
    full, _ = rglru.rglru_block(p, cfg, x)
    st = rglru.init_state(cfg, b, dtype=jnp.float32)
    steps = []
    for i in range(s):
        out, st = rglru.rglru_block_decode(p, cfg, x[:, i:i + 1], st)
        steps.append(out[:, 0])
    np.testing.assert_allclose(jnp.stack(steps, 1), full, rtol=2e-4,
                               atol=2e-4)


def test_rglru_state_carries_across_segments():
    """Processing [a|b] in two segments == one segment (streaming prefill)."""
    cfg = configs.get_config("recurrentgemma-9b").reduced()
    p = rglru.rglru_block_init(KEY, cfg)
    x = jax.random.normal(KEY, (1, 24, cfg.d_model)) * 0.5
    full, _ = rglru.rglru_block(p, cfg, x)
    seg1, st = rglru.rglru_block(p, cfg, x[:, :10])
    seg2, _ = rglru.rglru_block(p, cfg, x[:, 10:], state=st)
    np.testing.assert_allclose(jnp.concatenate([seg1, seg2], 1), full,
                               rtol=2e-4, atol=2e-4)


def test_rwkv_time_mix_chunk_vs_decode():
    cfg = configs.get_config("rwkv6-3b").reduced()
    p = rwkv.time_mix_init(KEY, cfg)
    b, s = 1, 13
    x = jax.random.normal(jax.random.fold_in(KEY, 2),
                          (b, s, cfg.d_model)) * 0.5
    full, _ = rwkv.time_mix(p, cfg, x)
    st = rwkv.init_state(cfg, b)
    outs = []
    for i in range(s):
        out, st = rwkv.time_mix_decode(p, cfg, x[:, i:i + 1], st)
        outs.append(out[:, 0])
    np.testing.assert_allclose(jnp.stack(outs, 1), full, rtol=2e-3,
                               atol=2e-3)


def test_rwkv_time_mix_pallas_kernel_path():
    """use_kernel=True routes through the Pallas WKV6 kernel (interpret on
    CPU) and must match the jnp chunked path."""
    cfg = configs.get_config("rwkv6-3b").reduced()
    p = rwkv.time_mix_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 96, cfg.d_model)) * 0.5
    ref, st_ref = rwkv.time_mix(p, cfg, x, use_kernel=False)
    ker, st_ker = rwkv.time_mix(p, cfg, x, use_kernel=True)
    np.testing.assert_allclose(ker, ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(st_ker["wkv"], st_ref["wkv"], rtol=2e-4,
                               atol=2e-4)


def test_rwkv_segment_streaming():
    cfg = configs.get_config("rwkv6-3b").reduced()
    p = rwkv.time_mix_init(KEY, cfg)
    x = jax.random.normal(KEY, (1, 40, cfg.d_model)) * 0.5
    full, _ = rwkv.time_mix(p, cfg, x)
    seg1, st = rwkv.time_mix(p, cfg, x[:, :16])
    seg2, _ = rwkv.time_mix(p, cfg, x[:, 16:], state=st)
    np.testing.assert_allclose(jnp.concatenate([seg1, seg2], 1), full,
                               rtol=2e-3, atol=2e-3)
