"""Unified telemetry tier (PR-8): tracer, metrics, flight recorder.

Covers the observability contracts that CI leans on:

  * Perfetto export is schema-valid Chrome trace JSON — only X/M/i/C
    phases, complete spans carry ts+dur, every (pid, tid) that appears
    in an event has process_name/thread_name metadata, and the pid
    scheme (host=1, PS=10, worker w=100+w) gives one track per worker;
  * the exported timeline reconstructs the wire ledger EXACTLY —
    ok + lost + dup wire spans == ``trace.comm``, fault instants match
    the fault ledger record for record (the export-side twin of
    ``faults.validate``);
  * exports are deterministic at a fixed seed (byte-identical event
    streams), and telemetry is semantics-free: the scheduler emits the
    same Trace with the whole tier on as with it off;
  * metrics are a shared no-op when disabled and real instruments when
    enabled (pow2 histogram buckets, label scoping, jax-tracer skip);
  * the flight recorder is a bounded ring and dumps on a forged
    fault ledger (``faults.validate``) and on scheduler exceptions;
  * every BENCH row gets a ``run_id``/``schema_version`` stamp, and
    ``bench_delta`` tolerates (but announces) rows gaining columns.
"""
import dataclasses
import importlib.util
import json
import os

import numpy as np
import pytest

from repro import cluster
from repro.cluster import faults
from repro.obs import export as obs_export
from repro.obs import flight as obs_flight
from repro.obs import metrics as obs_metrics
from repro.obs import runinfo, state
from repro.obs import trace as obs_trace

N = 8


@pytest.fixture(autouse=True)
def _clean_obs():
    """Every test starts and ends with the tier fully off and empty."""
    state.disable()
    obs_trace.reset()
    obs_metrics.reset()
    obs_flight.reset()
    yield
    state.disable()
    obs_trace.reset()
    obs_metrics.reset()
    obs_flight.reset()


def _demo_trace(seed=0, rounds=4):
    return obs_export.build_trace(protocol="sync_ps", n=N, rounds=rounds,
                                  p_drop=0.1, crash=True, quorum=6,
                                  seed=seed)


# ---------------------------------------------------------------------------
# Perfetto schema validity + track-per-worker invariants
# ---------------------------------------------------------------------------


def test_export_is_schema_valid_chrome_trace(tmp_path):
    tr = _demo_trace()
    out = tmp_path / "timeline.json"
    obs_export.export_trace(tr, str(out))
    doc = json.loads(out.read_text())

    assert set(doc) >= {"traceEvents", "displayTimeUnit", "metadata"}
    events = doc["traceEvents"]
    assert events, "empty timeline"
    assert {e["ph"] for e in events} <= {"X", "M", "i", "C"}
    for e in events:
        if e["ph"] == "X":       # complete spans: ts + non-negative dur
            assert e["ts"] >= 0 and e["dur"] >= 0
        if e["ph"] == "i":       # instants carry an explicit scope
            assert e["s"] == "t"
    # file-level identity stamp for artifact cross-referencing
    assert doc["metadata"]["schema_version"] == runinfo.SCHEMA_VERSION
    assert doc["metadata"]["counts"]["wire_spans"] == len(tr.comm)


def test_every_track_is_named_and_pids_follow_the_scheme(tmp_path):
    tr = _demo_trace()
    out = tmp_path / "timeline.json"
    obs_export.export_trace(tr, str(out))
    events = json.loads(out.read_text())["traceEvents"]

    named_pids = {e["pid"] for e in events
                  if e["ph"] == "M" and e["name"] == "process_name"}
    named_tracks = {(e["pid"], e["tid"]) for e in events
                    if e["ph"] == "M" and e["name"] == "thread_name"}
    for e in events:
        if e["ph"] in ("X", "i"):
            assert e["pid"] in named_pids
            assert (e["pid"], e["tid"]) in named_tracks

    # pid scheme: server = 10, worker w = 100 + w — one track per worker
    data_pids = {e["pid"] for e in events if e["ph"] in ("X", "i")}
    worker_pids = {p for p in data_pids if p >= 100}
    assert worker_pids == {100 + w for w in range(N)}
    assert 10 in data_pids     # the PS track (barriers, shortfalls)


def test_worker_uplink_spans_live_on_the_sender_track(tmp_path):
    tr = _demo_trace()
    tracer = obs_trace.timeline_from_trace(tr)
    ps = tr.n_workers
    uplinks = [d for d in tr.comm if d.dst == ps]
    up_spans = [e for e in tracer.events()
                if e["ph"] == "X" and e["cat"].startswith("wire,uplink")]
    assert len(up_spans) == len(uplinks)
    for e in up_spans:
        assert e["pid"] == 100 + e["args"]["src"]


# ---------------------------------------------------------------------------
# Ledger reconstruction: ok + lost + dup == comm, fault instants exact
# ---------------------------------------------------------------------------


def test_timeline_counts_match_ledgers_exactly():
    tr = _demo_trace()
    tally = faults.validate(tr)
    tracer = obs_trace.timeline_from_trace(tr)
    counts = obs_export.verify_timeline(tr, tracer)   # asserts internally

    by = counts["wire_by_status"]
    assert by["ok"] + by["lost"] + by["dup"] == len(tr.comm)
    assert by["ok"] == tally["delivered"]
    assert by["lost"] == tally["dropped"]
    assert by["dup"] == tally["duplicated"]
    assert counts["quorum_spans"] == tally["timed_out"]
    # the demo scenario actually exercises the faulty paths
    assert by["lost"] > 0 and counts["quorum_spans"] > 0
    assert tally["rejoins"] >= 1


def test_verify_timeline_catches_a_missing_span():
    tr = _demo_trace()
    tracer = obs_trace.timeline_from_trace(tr)
    dropped = tracer._events.pop()    # forge: lose one rendered event
    with pytest.raises(AssertionError, match="timeline/ledger mismatch"):
        obs_export.verify_timeline(tr, tracer)
    tracer._events.append(dropped)
    obs_export.verify_timeline(tr, tracer)


def test_live_compute_spans_do_not_disturb_the_accounting():
    # live scheduler tracing adds cat="sim,compute" rows to the SAME
    # tracer; verify_timeline must still balance (it tallies only the
    # wire,/event,/fault, categories)
    state.enable(trace=True, metrics=False, flight=False)
    live = obs_trace.tracer()
    tr = _demo_trace()
    assert any(e["cat"] == "sim,compute" for e in live.events())
    obs_trace.timeline_from_trace(tr, into=live)
    obs_export.verify_timeline(tr, live)


# ---------------------------------------------------------------------------
# Determinism + zero-semantics-impact
# ---------------------------------------------------------------------------


def test_export_is_deterministic_at_fixed_seed(tmp_path):
    docs = []
    for i in range(2):
        obs_trace.reset()
        out = tmp_path / f"t{i}.json"
        obs_export.export_trace(_demo_trace(seed=3), str(out))
        docs.append(json.loads(out.read_text()))
    assert docs[0]["traceEvents"] == docs[1]["traceEvents"]


def test_telemetry_never_changes_the_schedule():
    off = _demo_trace()
    state.enable()
    on = _demo_trace()
    assert on.makespan == off.makespan
    assert len(on.comm) == len(off.comm)
    assert on.events == off.events
    assert on.faults.summary() == off.faults.summary()


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------


def test_metrics_are_a_shared_noop_when_disabled():
    c = obs_metrics.counter("x.count")
    g = obs_metrics.gauge("x.gauge")
    assert c is g                       # the single shared null object
    c.inc(5)
    g.set(1.0)
    assert obs_metrics.registry().snapshot() == {}


def test_metrics_record_when_enabled_and_labels_scope_names():
    state.enable(trace=False, metrics=True, flight=False)
    obs_metrics.counter("wire.msgs", protocol="sync_ps").inc()
    obs_metrics.counter("wire.msgs", protocol="sync_ps").inc(2)
    obs_metrics.counter("wire.msgs", protocol="dsgd").inc()
    snap = obs_metrics.registry().snapshot()
    assert snap["wire.msgs[protocol=sync_ps]"]["value"] == 3
    assert snap["wire.msgs[protocol=dsgd]"]["value"] == 1


def test_histogram_pow2_buckets():
    state.enable(trace=False, metrics=True, flight=False)
    h = obs_metrics.histogram("lag")
    for v in (0.5, 1.0, 3.0, 7.9, 8.0, 100.0, 0.0, -2.0):
        h.observe(v)
    s = h.snapshot()
    assert s["count"] == 8 and s["zero"] == 1 and s["neg"] == 1
    # (0,1] -> bucket 0; (2,4] -> 2; (4,8] -> 3; (64,128] -> 7
    assert s["pow2_buckets"] == {"0": 2, "2": 1, "3": 2, "7": 1}
    assert s["min"] == -2.0 and s["max"] == 100.0


def test_observe_array_skips_jax_tracers_and_flattens_numpy():
    state.enable(trace=False, metrics=True, flight=False)

    class Tracer:                       # duck-typed jax.core.Tracer
        def ravel(self):                # pragma: no cover - must not run
            raise AssertionError("tracer was observed")

    obs_metrics.observe_array("q.range", Tracer())
    assert "q.range" not in obs_metrics.registry().snapshot()
    obs_metrics.observe_array("q.range", np.arange(6.0).reshape(2, 3))
    assert obs_metrics.registry().snapshot()["q.range"]["count"] == 6


def test_scheduler_fills_the_registry():
    state.enable(trace=False, metrics=True, flight=False)
    _demo_trace()
    snap = obs_metrics.registry().snapshot()
    assert snap["cluster.traces[protocol=sync_ps]"]["value"] >= 1
    assert snap["faults.quorum_cuts"]["value"] > 0
    assert any(k.startswith("cluster.wire_msgs[") for k in snap)


# ---------------------------------------------------------------------------
# Flight recorder
# ---------------------------------------------------------------------------


def test_flight_recorder_is_a_bounded_ring():
    state.enable(trace=False, metrics=False, flight=True)
    rec = obs_flight.recorder()
    rec.set_capacity(8)
    try:
        for i in range(20):
            obs_flight.record("tick", i=i)
        evs = rec.snapshot()
        assert len(evs) == 8
        assert [e["i"] for e in evs] == list(range(12, 20))
    finally:
        rec.set_capacity(obs_flight.DEFAULT_CAPACITY)


def test_forged_ledger_dumps_the_flight_buffer(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_OBS_DIR", str(tmp_path))
    state.enable(trace=False, metrics=False, flight=True)
    tr = _demo_trace()
    # forge: the ledger loses a drop record, so it no longer matches wire
    led = dataclasses.replace(tr.faults, drops=tr.faults.drops[:-1])
    tr = dataclasses.replace(tr, faults=led)
    with pytest.raises(AssertionError):
        faults.validate(tr)
    dump = tmp_path / "flight_faults_validate.json"
    assert dump.exists()
    payload = json.loads(dump.read_text())
    assert "AssertionError" in payload["reason"]
    assert payload["run_id"] == runinfo.run_id()
    # the buffer holds the events leading up to the failure, in order
    seqs = [e["seq"] for e in payload["events"]]
    assert seqs == sorted(seqs)
    assert payload["events"][-1]["kind"] == "faults.validate_failed"


def test_guarded_dumps_on_uncaught_exception(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_OBS_DIR", str(tmp_path))
    state.enable(trace=False, metrics=False, flight=True)

    @obs_flight.guarded("unit.boom")
    def boom():
        obs_flight.record("about.to.fail")
        raise ValueError("kaboom")

    with pytest.raises(ValueError, match="kaboom"):
        boom()
    payload = json.loads((tmp_path / "flight_unit_boom.json").read_text())
    assert payload["reason"] == "ValueError: kaboom"
    assert payload["events"][-1]["kind"] == "about.to.fail"


def test_flight_disabled_writes_nothing(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_OBS_DIR", str(tmp_path))
    obs_flight.record("never")
    assert obs_flight.dump_on_failure("scope", "reason") is None
    assert list(tmp_path.iterdir()) == []


def test_kernel_annotation_is_transparent():
    @obs_flight.kernel_annotation("unit.kernel")
    def f(x, y=1):
        return x + y

    assert f(2) == 3                    # tier off: plain passthrough
    state.enable(trace=True, metrics=False, flight=False)
    assert f(2, y=3) == 5               # tier on: named_scope wraps it
    assert f.__name__ == "f"            # wraps() keeps jit-able identity


# ---------------------------------------------------------------------------
# run_id stamping + bench_delta schema tolerance
# ---------------------------------------------------------------------------


def test_stamp_rows_adds_run_identity():
    rows = [{"op": "a", "us": 1.0}, {"op": "b", "us": 2.0}]
    out = runinfo.stamp_rows(rows, seed=7)
    assert out is rows                  # in-place, like the benches use it
    for r in rows:
        assert r["run_id"] == runinfo.run_id(7)
        assert r["run_id"].endswith("-s7")
        assert r["schema_version"] == runinfo.SCHEMA_VERSION


def _load_bench_delta():
    here = os.path.dirname(__file__)
    path = os.path.join(here, os.pardir, "benchmarks", "bench_delta.py")
    spec = importlib.util.spec_from_file_location("bench_delta", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_bench_delta_tolerates_rows_gaining_stamped_columns():
    bd = _load_bench_delta()
    base = {"q/sync_ps": {"workload": "q", "protocol": "sync_ps",
                          "makespan_s": 10.0}}
    fresh = {"q/sync_ps": {"workload": "q", "protocol": "sync_ps",
                           "makespan_s": 10.0, "run_id": "abc-s0",
                           "schema_version": 2, "stale_p99": 4.0}}
    # the new columns never gate...
    assert bd.compare(base, fresh, threshold=1.0001) == []
    # ...but their appearance is announced, and schema_version/run_id
    # are identity stamps, not metrics
    assert bd.schema_drift(base, fresh) == (["stale_p99"], [])
    # a real regression in a shared metric still trips
    fresh["q/sync_ps"]["makespan_s"] = 30.0
    assert len(bd.compare(base, fresh, threshold=2.0)) == 1
