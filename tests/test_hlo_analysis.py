"""The trip-count-aware HLO analyzer: unit fixtures + scan==unroll parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as H

SYNTH = """
HloModule test

%body.1 (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %w = f32[16,16]{1,0} constant(0)
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %dot.1 = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %all-reduce.1 = f32[8,16]{1,0} all-reduce(%dot.1), to_apply=%add.1
}

%cond.1 (p2: (s32[], f32[8,16])) -> pred[] {
  %p2 = (s32[], f32[8,16]) parameter(0)
  %c = s32[] constant(12)
  %i = s32[] get-tuple-element(%p2), index=0
  %cmp = pred[] compare(%i, %c), direction=LT
}

%add.1 (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  %s = f32[] add(%a, %b)
}

ENTRY %main (q: f32[8,16]) -> f32[8,16] {
  %q = f32[8,16]{1,0} parameter(0)
  %t = (s32[], f32[8,16]) tuple(s32[] constant(0), %q)
  %while.1 = (s32[], f32[8,16]) while(%t), condition=%cond.1, body=%body.1
  %w2 = f32[16,32]{1,0} constant(0)
  %dot.2 = f32[8,32]{1,0} dot(%q, %w2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %all-gather.7 = f32[64,32]{1,0} all-gather(%dot.2), dimensions={0}
}
"""


def test_synthetic_module_trips_and_costs():
    costs = H.analyze_hlo(SYNTH)
    # loop dot: 2*8*16*16 = 4096 flops x 12 trips; outer dot 2*8*32*16=8192
    assert costs.dot_flops == pytest.approx(4096 * 12 + 8192)
    # all-reduce 8*16*4 bytes x 12 + all-gather 64*32*4
    assert costs.collective_bytes == pytest.approx(8 * 16 * 4 * 12
                                                   + 64 * 32 * 4)
    assert costs.loops[0]["trips"] == 12


def test_scan_vs_unroll_parity_on_device():
    """The analyzer's core guarantee: scanned and unrolled versions of the
    same model report the same totals."""
    from repro import configs
    from repro.data.pipeline import make_batch_shapes
    from repro.dist import sharding
    from repro.models.common import InputShape
    from repro.optim import make_optimizer
    from repro.train import steps
    from repro.launch.dryrun import _state_shardings

    cfg = configs.get_config("qwen1.5-0.5b").reduced(n_layers=3)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    opt = make_optimizer("adamw", 1e-3)
    batch = make_batch_shapes(cfg, InputShape("t", 64, 4, "train"),
                              dtype=jnp.float32)

    def compile_one(scan):
        scfg = steps.TrainStepConfig(remat=False, scan_layers=scan)
        state = steps.abstract_train_state(cfg, opt, step_cfg=scfg)
        fn = steps.make_train_step(cfg, opt, scfg)
        with mesh:
            j = jax.jit(fn, in_shardings=(
                _state_shardings(state, mesh),
                sharding.batch_shardings(batch, mesh)))
            return j.lower(state, batch).compile()

    cs = H.analyze_hlo(compile_one(True).as_text())
    cu = H.analyze_hlo(compile_one(False).as_text())
    assert cs.dot_flops == pytest.approx(cu.dot_flops, rel=0.02)
    assert any(l["trips"] == 3 for l in cs.loops)


def test_dot_flops_parser_handles_batch_dims():
    line = ("%dot.3 = f32[4,128,64]{2,1,0} dot(%a, %b), "
            "lhs_batch_dims={0}, rhs_batch_dims={0}, "
            "lhs_contracting_dims={2}, rhs_contracting_dims={1}")
    symbols = {"a": "f32[4,128,256]", "b": "f32[4,256,64]"}
    f = H._dot_flops_line(line, symbols)
    assert f == 2 * 4 * 128 * 64 * 256
