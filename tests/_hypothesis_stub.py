"""Minimal stand-in for `hypothesis` when the real package is absent.

The container that runs tier-1 has no network access, so `pip install
hypothesis` is not an option there; CI installs the real thing from
requirements.txt. This stub covers exactly the API surface the test suite
uses — `given`, `settings`, `strategies.integers/floats` — with
deterministic sampling (seeded per-test) that always includes the
boundary values, so the property tests stay meaningful.

Imported by tests/conftest.py, which registers it (and its `strategies`
attribute) in sys.modules under the real names only when the genuine
package is missing.
"""
from __future__ import annotations

import functools
import random


class _Strategy:
    """A value source: deterministic boundary cases + seeded random draws."""

    def __init__(self, draw, boundaries):
        self._draw = draw
        self._boundaries = list(boundaries)

    def examples(self, rng: random.Random, n: int):
        out = list(self._boundaries[:n])
        while len(out) < n:
            out.append(self._draw(rng))
        return out


def integers(min_value=None, max_value=None):
    lo = -(2**63) if min_value is None else min_value
    hi = 2**63 - 1 if max_value is None else max_value
    mid = (lo + hi) // 2
    return _Strategy(lambda r: r.randint(lo, hi), (lo, hi, mid))


def floats(min_value=None, max_value=None, **_kw):
    lo = -1e9 if min_value is None else min_value
    hi = 1e9 if max_value is None else max_value
    return _Strategy(lambda r: r.uniform(lo, hi),
                     (lo, hi, 0.5 * (lo + hi)))


def settings(max_examples: int = 20, deadline=None, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(*strategies):
    def deco(fn):
        n = getattr(fn, "_stub_max_examples", 20)
        # per-test deterministic seed: reruns are reproducible
        rng = random.Random(hash(fn.__qualname__) & 0xFFFFFFFF)
        columns = [s.examples(rng, n) for s in strategies]

        @functools.wraps(fn)
        def wrapper():
            for args in zip(*columns):
                fn(*args)

        # hide the strategy params from pytest's fixture resolution
        del wrapper.__wrapped__
        return wrapper

    return deco
