"""Codec engine tests: packed wire format, backend equality, and the
measured-byte plumbing into eventsim / roofline / benchmarks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import communicators as C
from repro.core import compression, eventsim

KEY = jax.random.PRNGKey(0)
AXIS = "w"


# ------------------------------------------------------------- round trip ----

@pytest.mark.parametrize("name", ["rq8", "rq4", "rq2"])
def test_packed_backends_identical_same_key(name):
    """Pallas (interpret mode off-TPU) and the jnp reference are the SAME
    codec: identical payloads, identical decodes, for the same key."""
    cdc = compression.codec(name)
    pallas = compression.QuantCodec(cdc.bits, backend="pallas")
    jnp_ref = compression.QuantCodec(cdc.bits, backend="jnp")
    x = jax.random.normal(KEY, (777,))
    pp = pallas.encode(x, KEY)
    pj = jnp_ref.encode(x, KEY)
    np.testing.assert_array_equal(pp.payload, pj.payload)
    np.testing.assert_array_equal(pp.params, pj.params)
    np.testing.assert_array_equal(pallas.decode(pp), jnp_ref.decode(pj))


@pytest.mark.parametrize("name", ["rq8", "rq4", "rq2"])
def test_decode_encode_equals_qdq(name):
    """The fused path and the wire path are bit-identical, so falling back
    to qdq where a collective needs fp32 changes nothing numerically."""
    cdc = compression.codec(name)
    for n in (5, 512, 1000, 4097):
        x = jax.random.normal(jax.random.fold_in(KEY, n), (n,))
        np.testing.assert_array_equal(cdc.decode(cdc.encode(x, KEY)),
                                      cdc.qdq(x, KEY))


@pytest.mark.parametrize("name,bound", [("rq8", 0.3), ("rq4", 0.6),
                                        ("rq2", 1.5)])
def test_packed_codec_unbiased(name, bound):
    """E[decode(encode(x))] = x (Assumption 3) through the packed path."""
    cdc = compression.codec(name)
    x = jax.random.normal(KEY, (256,))
    keys = jax.random.split(jax.random.PRNGKey(1), 600)
    qs = jax.vmap(lambda k: cdc.decode(cdc.encode(x, k)))(keys)
    assert float(jnp.abs(qs.mean(0) - x).max()) < bound


# ------------------------------------------------------------- wire bytes ----

@pytest.mark.parametrize("name,bits", [("rq8", 8), ("rq4", 4), ("rq2", 2)])
def test_wire_bytes_matches_packed_arrays(name, bits):
    """Codec.wire_bytes == actual packed array bytes == spec arithmetic
    within the documented header + lane-padding overhead."""
    cdc = compression.codec(name)
    for n in (1000, 4096, 10**5):
        x = jnp.zeros((n,), jnp.float32)
        packed = cdc.encode(jax.random.normal(KEY, (n,)), KEY)
        # measured == the arrays that would hit the wire
        assert cdc.wire_bytes(x) == packed.wire_bytes
        # sub-byte packing really happened: bits/8 bytes per element...
        payload_bytes = packed.payload.size
        assert payload_bytes >= n * bits / 8
        # ...up to one pad granule (pack * 512 elements) + 8B header
        granule_bytes = 512  # one padded row of packed codes
        spec_bytes = cdc.spec.compressed_bytes(n)
        assert packed.wire_bytes <= spec_bytes + granule_bytes
        # and far below fp32
        if n >= 4096:
            assert packed.wire_bytes < 4 * n * (bits / 32 + 0.01)


def test_wire_bytes_nonpackable_uses_spec():
    cdc = compression.codec("sign1")
    assert not cdc.packable
    x = jnp.zeros((1000,), jnp.float32)
    assert cdc.wire_bytes(x) == cdc.spec.compressed_bytes(1000)


def test_tree_wire_bytes_sums_leaves():
    cdc = compression.codec("rq4")
    tree = {"a": jnp.zeros((1000,)), "b": jnp.zeros((64, 64))}
    total = cdc.tree_wire_bytes(tree)
    assert total == cdc.wire_bytes(tree["a"]) + cdc.wire_bytes(tree["b"])


# ----------------------------------------------------------- packed wire -----

def test_packed_moves_through_ppermute():
    """The wire object crosses ppermute intact (the ring's hop handoff)."""
    n = 4
    cdc = compression.codec("rq4")
    x = jax.random.normal(KEY, (n, 100))

    def shift(xi):
        packed = cdc.encode(xi, KEY)
        perm = [(i, (i + 1) % n) for i in range(n)]
        moved = jax.tree_util.tree_map(
            lambda a: jax.lax.ppermute(a, AXIS, perm), packed)
        return cdc.decode(moved)

    out = jax.vmap(shift, axis_name=AXIS)(x)
    expect = jax.vmap(lambda xi: cdc.qdq(xi, KEY))(x)
    # worker i ends with worker (i-1)'s decoded payload
    np.testing.assert_array_equal(out, jnp.roll(expect, 1, axis=0))


def test_csgd_ring_packed_equals_qdq_formulation():
    """The per-leaf packed ring (flat=False reference tier: uint8 payloads
    through ppermute) is numerically identical to the per-leaf qdq
    formulation, because decode(encode(.)) == qdq."""
    n = 4
    g = jax.random.normal(KEY, (n, 32))
    key = jax.random.PRNGKey(1)
    out, _ = jax.vmap(
        lambda gg: C.CSGDRingExchange(compressor="rq4", flat=False)(
            gg, (), key, axis_name=AXIS),
        axis_name=AXIS)(g)

    cdc = compression.codec("rq4")
    accs = [cdc.tree_qdq(g[i], jax.random.fold_in(key, i)) for i in range(n)]
    for h in range(1, n):
        prev = list(accs)
        accs = [cdc.tree_qdq(prev[(i - 1) % n] + g[i],
                             jax.random.fold_in(jax.random.fold_in(key, i), h))
                for i in range(n)]
    expect = np.stack([np.asarray(a) / n for a in accs])
    np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-6, atol=1e-6)


def test_exchanges_report_measured_bytes():
    """message_bytes = bytes one worker sends per ITERATION (2(n-1)
    partition messages for the partitioned ring, n-1 full hops for the
    monolithic chain, 2 neighbor sends for ring gossip)."""
    tree = jnp.zeros((10**4,), jnp.float32)
    rq4 = compression.codec("rq4")
    assert C.CSGDRingExchange(compressor="rq4").message_bytes(
        tree, n_workers=8) == \
        2 * 7 * rq4.tree_wire_bytes_partitioned(tree, 8)
    assert C.CSGDRingExchange(compressor="rq4",
                              partitioned=False).message_bytes(
        tree, n_workers=8) == 7 * rq4.tree_wire_bytes(tree)
    assert C.CSGDPSExchange(compressor="rq4").message_bytes(tree) == \
        2 * rq4.tree_wire_bytes(tree)
    # mb-SGD uses the same uplink+broadcast convention (2x) as CSGD PS
    assert C.MbSGDExchange().message_bytes(tree) == 2 * 4 * 10**4
    assert C.DelayedExchange(inner=C.CSGDPSExchange("rq8")).message_bytes(
        tree) == 2 * compression.codec("rq8").tree_wire_bytes(tree)
    assert C.GossipMix("ring").message_bytes(tree, n_workers=8) == \
        2 * 4 * 10**4
    assert C.GossipMix("full").message_bytes(tree, n_workers=5) == \
        4 * 4 * 10**4


# ------------------------------------------------------- cost-model users ----

def test_eventsim_consumes_measured_wire_bytes():
    """K-times compression divides transfer only; with the measured codec
    sizes the ring makespan lands between the ideal bits ratio and ideal
    plus header/padding overhead."""
    n, lat, tr = 8, 1e-4, 1e-2
    size = 100.0
    base = eventsim.ring_allreduce_makespan(n, size, t_lat=lat, t_tr=tr)
    rq4 = eventsim.ring_allreduce_makespan(n, size, t_lat=lat, t_tr=tr,
                                           codec="rq4")
    # measured chunk MB must equal wire_size_mb of a chunk's elements
    chunk_mb = eventsim.wire_size_mb("rq4", int(size * 1e6 / 4 / n))
    assert rq4 == pytest.approx(2 * (n - 1) * (lat + chunk_mb * tr))
    # ~8x fewer bytes than fp32 (4 bits vs 32), overheads included
    lat_part = 2 * (n - 1) * lat
    assert (base - lat_part) / (rq4 - lat_part) == pytest.approx(8.0,
                                                                 rel=0.01)


def test_eventsim_wire_size_matches_codec():
    for name in ("rq8", "rq4", "rq2", "sign1"):
        got = eventsim.wire_size_mb(name, 10**6)
        want = compression.codec(name).wire_bytes_for(10**6) / 1e6
        assert got == pytest.approx(want)


def test_roofline_compressed_collective_uses_measured_codec():
    from benchmarks.roofline import ICI_BW, ICI_LAT, compressed_collective_s
    coll_bytes = 4e9
    t = compressed_collective_s(coll_bytes, "rq4")
    wire_term = compression.codec("rq4").wire_bytes_for(int(coll_bytes / 4)) \
        / ICI_BW
    # one fused message -> one ICI_LAT on top of the transfer term
    assert t == pytest.approx(wire_term + ICI_LAT)
    # per-message accounting: per-leaf messaging (n_messages=L) pays the
    # latency L times, transfer unchanged
    t_leaf = compressed_collective_s(coll_bytes, "rq4", n_messages=110)
    assert t_leaf - t == pytest.approx(109 * ICI_LAT)
    # ~8x cheaper than the fp32 collective term (transfer part)
    assert (coll_bytes / ICI_BW) / wire_term == pytest.approx(8.0, rel=0.01)


def test_train_step_reports_wire_bytes():
    """Production tier: metrics carry the measured size of the ONE fused
    gradient message (flat-buffer tier). (Tiny config to keep the test
    fast.)"""
    from repro import configs
    from repro.data.pipeline import SyntheticLM
    from repro.optim import make_optimizer
    from repro.train import steps

    cfg = configs.get_config("qwen1.5-0.5b").reduced()
    data = SyntheticLM(vocab=cfg.vocab, seq_len=17, batch=2, seed=0)
    opt = make_optimizer("sgd", 1e-3)
    scfg = steps.TrainStepConfig(grad_compression="rq4")
    state = steps.init_train_state(cfg, opt, KEY, step_cfg=scfg)
    ts = jax.jit(steps.make_train_step(cfg, opt, scfg))
    state, m = ts(state, data.batch_at(0))
    want = compression.codec("rq4").tree_wire_bytes_flat(state["params"])
    assert float(m["comm_bytes"]) == pytest.approx(want)
    # and the fused message is strictly smaller than per-leaf messaging
    assert want < compression.codec("rq4").tree_wire_bytes(state["params"])
