"""Per-architecture smoke tests (assigned requirement): every arch
instantiates a REDUCED variant (2 layers, d_model<=512, <=4 experts), runs
one forward + one train step on CPU, asserts output shapes + no NaNs.
Plus cross-implementation consistency: scan==unrolled, decode==prefill."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data import synthetic_batch
from repro.models import transformer, transformer_scan
from repro.models.common import InputShape
from repro.optim import make_optimizer
from repro.train import steps

KEY = jax.random.PRNGKey(0)
SHAPE = InputShape("smoke", 32, 2, "train")

ALL_ARCHS = list(configs.ASSIGNED)


def _batch(cfg, seq=32, b=2):
    batch = synthetic_batch(cfg, InputShape("t", seq, b, "train"), KEY,
                            dtype=jnp.float32)
    if "labels" not in batch:
        batch["labels"] = jax.random.randint(KEY, (b, seq), 0, cfg.vocab)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = configs.get_config(arch).reduced()
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    batch = _batch(cfg)
    params = transformer.init(cfg, KEY)
    logits, aux = transformer.apply(params, cfg, batch)
    assert logits.shape == (2, 32, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())

    opt = make_optimizer("adamw", 1e-3)
    scfg = steps.TrainStepConfig()
    state = steps.init_train_state(cfg, opt, KEY, step_cfg=scfg)
    ts = jax.jit(steps.make_train_step(cfg, opt, scfg))
    state, metrics = ts(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()),
        state["params"], transformer.init(cfg, KEY))
    assert max(jax.tree_util.tree_leaves(moved)) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_decode_step(arch):
    cfg = configs.get_config(arch).reduced()
    params = transformer.init(cfg, KEY)
    mem = None
    if cfg.is_encdec:
        src = jax.random.normal(KEY, (2, 16, cfg.d_model)) * 0.02
        mem = transformer.encode(params, cfg, src)
    state = transformer.init_decode_state(params, cfg, 2, 64, memory=mem)
    ins = ({"tokens": jnp.zeros((2, 1), jnp.int32)}
           if cfg.frontend == "token"
           else {"embeddings": jnp.zeros((2, 1, cfg.d_model))})
    logits, state2 = transformer.decode_step(params, cfg, ins, state)
    assert logits.shape == (2, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "recurrentgemma-9b",
                                  "deepseek-v2-lite-16b", "rwkv6-3b",
                                  "grok-1-314b"])
def test_scan_equals_unrolled(arch):
    cfg = configs.get_config(arch).reduced(n_layers=4)
    batch = _batch(cfg)
    ps = transformer_scan.init(cfg, KEY)
    prefix, unit, n_rep, suffix = transformer_scan.pattern_segments(cfg)
    layers_list = list(ps["prefix_layers"])
    for r in range(n_rep):
        for j in range(len(unit)):
            layers_list.append(jax.tree_util.tree_map(
                lambda a: a[r], ps["scan_blocks"][j]))
    layers_list += list(ps["suffix_layers"])
    pu = {k: v for k, v in ps.items()
          if k not in ("prefix_layers", "scan_blocks", "suffix_layers",
                       "encoder")}
    pu["layers"] = layers_list
    if cfg.is_encdec:
        pu["encoder"] = {
            "layers": [jax.tree_util.tree_map(
                lambda a: a[i], ps["encoder"]["scan_blocks"])
                for i in range(cfg.n_encoder_layers)],
            "final_norm": ps["encoder"]["final_norm"]}
    np.testing.assert_allclose(transformer_scan.loss_fn(ps, cfg, batch),
                               transformer.loss_fn(pu, cfg, batch),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "rwkv6-3b",
                                  "recurrentgemma-9b",
                                  "deepseek-v2-lite-16b"])
def test_decode_matches_full_forward(arch):
    """Serving correctness: token-by-token cached decode must reproduce the
    full-sequence forward logits position by position.

    MoE note: capacity routing drops over-capacity tokens in the batched
    forward but never in single-token decode, so the comparison needs a
    no-drop capacity factor (the divergence itself is asserted in
    test_moe_capacity_drops_diverge_from_decode).
    """
    import dataclasses
    cfg = configs.get_config(arch).reduced()
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    b, s = 1, 12
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    params = transformer.init(cfg, KEY)
    full_logits, _ = transformer.apply(params, cfg, {"tokens": tokens})
    state = transformer.init_decode_state(params, cfg, b, s + 1,
                                          dtype=jnp.float32)
    got = []
    for i in range(s):
        lg, state = transformer.decode_step(
            params, cfg, {"tokens": tokens[:, i:i + 1]}, state)
        got.append(lg[:, 0])
    got = jnp.stack(got, axis=1)
    np.testing.assert_allclose(got, full_logits, rtol=2e-3, atol=2e-3)


def test_sliding_window_cache_matches_full_within_window():
    """Windowed ring cache == full cache while cursor < window (long_500k
    serving correctness at the boundary)."""
    cfg = configs.get_config("qwen1.5-0.5b").reduced()
    b, s = 1, 10
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    params = transformer.init(cfg, KEY)
    full = transformer.init_decode_state(params, cfg, b, 64,
                                          dtype=jnp.float32)
    wind = transformer.init_decode_state(params, cfg, b, 64, window=16,
                                         dtype=jnp.float32)
    for i in range(s):
        lf, full = transformer.decode_step(
            params, cfg, {"tokens": tokens[:, i:i + 1]}, full)
        lw, wind = transformer.decode_step(
            params, cfg, {"tokens": tokens[:, i:i + 1]}, wind)
        np.testing.assert_allclose(lf, lw, rtol=2e-4, atol=2e-4)


def test_sliding_window_evicts_old_tokens():
    """After cursor passes the window, logits must differ from full cache
    (old context dropped) but stay finite."""
    cfg = configs.get_config("qwen1.5-0.5b").reduced()
    b, s, w = 1, 24, 8
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    params = transformer.init(cfg, KEY)
    full = transformer.init_decode_state(params, cfg, b, 64,
                                          dtype=jnp.float32)
    wind = transformer.init_decode_state(params, cfg, b, 64, window=w,
                                         dtype=jnp.float32)
    for i in range(s):
        lf, full = transformer.decode_step(
            params, cfg, {"tokens": tokens[:, i:i + 1]}, full)
        lw, wind = transformer.decode_step(
            params, cfg, {"tokens": tokens[:, i:i + 1]}, wind)
    assert bool(jnp.isfinite(lw).all())
    assert float(jnp.abs(lf - lw).max()) > 1e-4


def test_moe_capacity_drops_diverge_from_decode():
    """Documents the capacity-routing semantics: with a tight capacity
    factor, the batched forward drops over-capacity tokens and diverges
    from exact single-token decode at later positions."""
    cfg = configs.get_config("deepseek-v2-lite-16b").reduced()
    b, s = 1, 12
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    params = transformer.init(cfg, KEY)
    full_logits, _ = transformer.apply(params, cfg, {"tokens": tokens})
    state = transformer.init_decode_state(params, cfg, b, s + 1,
                                          dtype=jnp.float32)
    got = []
    for i in range(s):
        lg, state = transformer.decode_step(
            params, cfg, {"tokens": tokens[:, i:i + 1]}, state)
        got.append(lg[:, 0])
    err = jnp.abs(jnp.stack(got, 1) - full_logits).max(axis=(0, 2))
    assert float(err[0]) < 1e-4          # early positions exact
    assert float(err[-1]) > 1e-2         # late positions hit the cap


def test_chunked_attention_matches_reference():
    """The production long-seq attention path (q-chunked) == full SDPA."""
    from repro.models import attention as A
    q = jax.random.normal(KEY, (2, 256, 4, 32))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 256, 4, 32))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (2, 256, 4, 32))
    for causal, window in [(True, 0), (True, 64), (False, 0)]:
        ref = A.sdpa_reference(
            q, k, v, A.make_mask(256, 256, causal=causal, window=window)[None])
        got = A.chunked_sdpa(q, k, v, causal=causal, window=window,
                             softcap=0.0, q_chunk=64)
        np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)


def test_int8_kv_cache_close_to_full_precision():
    """Quantized KV cache (Section 3.1.1 quantization applied to serving):
    int8 K/V + per-(slot,head) scale tracks full-precision decode to ~1-2%
    relative logit error."""
    cfg = configs.get_config("qwen1.5-0.5b").reduced()
    b, s = 1, 12
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    params = transformer.init(cfg, KEY)
    full = transformer.init_decode_state(params, cfg, b, 32,
                                         dtype=jnp.float32)
    q8 = transformer.init_decode_state(params, cfg, b, 32,
                                       dtype=jnp.float32, quantize_kv=True)
    assert q8["layers"][0]["k"].dtype == jnp.int8
    for i in range(s):
        lf, full = transformer.decode_step(
            params, cfg, {"tokens": tokens[:, i:i + 1]}, full)
        lq, q8 = transformer.decode_step(
            params, cfg, {"tokens": tokens[:, i:i + 1]}, q8)
    rel = float(jnp.abs(lf - lq).max() / jnp.abs(lf).max())
    assert rel < 0.05
    # and it is NOT bit-identical (the quantization is real)
    assert float(jnp.abs(lf - lq).max()) > 1e-5


def test_mrope_text_equals_rope():
    """M-RoPE with identical (t,h,w) position ids == plain RoPE (the
    Qwen2-VL text-stream property)."""
    from repro.models import layers
    x = jax.random.normal(KEY, (2, 16, 4, 64))
    pos = jnp.broadcast_to(jnp.arange(16)[None], (2, 16))
    r1 = layers.apply_rope(x, pos, theta=10_000.0)
    r2 = layers.apply_mrope(x, layers.text_mrope_positions(pos),
                            theta=10_000.0, sections=(8, 12, 12))
    np.testing.assert_allclose(r1, r2, rtol=1e-5, atol=1e-6)


def test_moe_grouped_dispatch_bounded():
    """The dispatch tensor must be grouped (not O(T^2)); aux loss ~1 for a
    balanced router at init."""
    from repro.models import moe as moe_mod
    cfg = configs.get_config("grok-1-314b").reduced()
    x = jax.random.normal(KEY, (2, 64, cfg.d_model)) * 0.1
    params = moe_mod.moe_init(KEY, cfg)
    out, aux = moe_mod.moe_apply(params, cfg, x)
    assert out.shape == x.shape
    assert 0.0 < float(aux) < 1.0
    n_groups, g = moe_mod._group_shape(17 * 4096)
    assert g <= moe_mod.MAX_GROUP and n_groups * g == 17 * 4096


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_matches_assignment(arch):
    """The full (non-reduced) configs carry the exact assigned dimensions."""
    spec = {
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
    }[arch]
    cfg = configs.get_config(arch)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab)
    assert got == spec
