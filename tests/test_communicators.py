"""Exact algebraic tests for the exchange operators (Sections 3-5)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import communicators as C
from repro.core import compression, mixing

AXIS = "w"


def _vrun(exchange, grads, state, key):
    return jax.vmap(lambda g, s: exchange(g, s, key, axis_name=AXIS),
                    axis_name=AXIS)(grads, state)


def test_mbsgd_is_exact_mean():
    g = jax.random.normal(jax.random.PRNGKey(0), (4, 16))
    ex = C.MbSGDExchange()
    out, _ = _vrun(ex, g, jax.vmap(ex.init)(g), jax.random.PRNGKey(1))
    np.testing.assert_allclose(out, jnp.broadcast_to(g.mean(0), (4, 16)),
                               rtol=1e-6)


def test_csgd_ps_form_eq_3_2():
    """out = Q(mean_n Q(g_n)) with per-worker inner keys, shared outer key
    (fused flat-buffer tier: Q is the bucketed flat qdq)."""
    n = 4
    g = jax.random.normal(jax.random.PRNGKey(0), (n, 32))
    ex = C.CSGDPSExchange(compressor="rq8")
    key = jax.random.PRNGKey(1)
    out, _ = _vrun(ex, g, jax.vmap(ex.init)(g), key)
    # manual replication of Eq. 3.2 through the fused tier
    cdc = compression.codec("rq8")
    inner = jnp.stack([
        cdc.flat_qdq(g[i], jax.random.fold_in(key, i)) for i in range(n)])
    expect = cdc.flat_qdq(inner.mean(0), jax.random.fold_in(key, 0x5E4E4))
    np.testing.assert_allclose(out[0], expect, rtol=1e-5, atol=1e-6)
    # identical broadcast on every worker (it is ONE message in the paper)
    for i in range(1, n):
        np.testing.assert_allclose(out[i], out[0], rtol=0, atol=0)


def test_csgd_ps_per_leaf_reference_form():
    """flat=False keeps the per-leaf reference formulation (leaf-wise
    tree_compress with split keys) bit-compatible with PR 1."""
    n = 4
    g = jax.random.normal(jax.random.PRNGKey(0), (n, 32))
    ex = C.CSGDPSExchange(compressor="rq8", flat=False)
    key = jax.random.PRNGKey(1)
    out, _ = _vrun(ex, g, jax.vmap(ex.init)(g), key)
    q_fn, _ = compression.get("rq8")
    inner = jnp.stack([
        compression.tree_compress(g[i], jax.random.fold_in(key, i), q_fn)
        for i in range(n)])
    expect = compression.tree_compress(inner.mean(0),
                                       jax.random.fold_in(key, 0x5E4E4), q_fn)
    np.testing.assert_allclose(out[0], expect, rtol=1e-5, atol=1e-6)


def test_ecsgd_lemma_3_4_1_recursion():
    """Lemma 3.4.1: x~_{t+1} = x~_t - lr * mean_n g_n  EXACTLY, where
    x~_t = x_t - lr * Omega_{t-1}, Omega = server_err + mean worker_err."""
    n, d, lr, steps = 4, 24, 0.1, 6
    key = jax.random.PRNGKey(0)
    ex = C.ECSGDExchange(compressor="sign1")
    x = jnp.zeros((d,))
    state = jax.vmap(ex.init)(jnp.zeros((n, d)))
    omega_prev = jnp.zeros((d,))
    x_tilde = x.copy()
    for t in range(steps):
        g = jax.random.normal(jax.random.fold_in(key, t), (n, d))
        out, state = _vrun(ex, g, state, jax.random.fold_in(key, 100 + t))
        x = x - lr * out[0]
        omega = state["server_err"][0] + state["worker_err"].mean(0)
        # Lemma: (x_t - lr*Omega_{t-1}) follows plain averaged-SGD
        x_tilde = x_tilde - lr * g.mean(0)
        np.testing.assert_allclose(x - lr * omega, x_tilde, rtol=1e-4,
                                   atol=1e-5)


def test_delayed_exchange_exact_tau_delay():
    """Assumption 5 with D(t) = t - tau: output at step t is the input mean
    from step t - tau (zeros during warmup)."""
    n, d, tau = 2, 8, 3
    ex = C.DelayedExchange(inner=C.MbSGDExchange(), tau=tau)
    state = jax.vmap(ex.init)(jnp.zeros((n, d)))
    outs, means = [], []
    for t in range(8):
        g = jnp.stack([jnp.full((d,), float(t * 10 + i)) for i in range(n)])
        means.append(g.mean(0))
        out, state = _vrun(ex, g, state, jax.random.PRNGKey(t))
        outs.append(out[0])
    for t in range(8):
        expect = jnp.zeros((d,)) if t < tau else means[t - tau]
        np.testing.assert_allclose(outs[t], expect, rtol=1e-6)


def test_gossip_ring_equals_w2_matrix():
    """GossipMix(ring) == X @ W2 with the paper's 1/3 ring matrix."""
    n, d = 8, 5
    x = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    mixed = jax.vmap(lambda xi: C.GossipMix("ring")(xi, axis_name=AXIS),
                     axis_name=AXIS)(x)
    w2 = mixing.ring(n)
    np.testing.assert_allclose(mixed, jnp.asarray(w2) @ x, rtol=1e-5,
                               atol=1e-6)


def test_gossip_full_equals_mean():
    n, d = 4, 7
    x = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    mixed = jax.vmap(lambda xi: C.GossipMix("full")(xi, axis_name=AXIS),
                     axis_name=AXIS)(x)
    np.testing.assert_allclose(mixed, jnp.broadcast_to(x.mean(0), (n, d)),
                               rtol=1e-5)


def test_csgd_ring_reduces_to_mean_without_noise():
    """With the identity compressor the ring chain is an exact mean."""
    n = 4
    g = jax.random.normal(jax.random.PRNGKey(0), (n, 16))
    ex = C.CSGDRingExchange(compressor="none")
    out, _ = _vrun(ex, g, jax.vmap(ex.init)(g), jax.random.PRNGKey(1))
    np.testing.assert_allclose(out, jnp.broadcast_to(g.mean(0), (n, 16)),
                               rtol=1e-5)


def test_gossip_torus_equals_torus_matrix():
    """GossipMix(topology='torus') == X @ torus_2d(near-square factors):
    the Birkhoff lowering to ppermutes is exact."""
    n, d = 8, 5
    x = jax.random.normal(jax.random.PRNGKey(2), (n, d))
    mixed = jax.vmap(lambda xi: C.GossipMix("torus")(xi, axis_name=AXIS),
                     axis_name=AXIS)(x)
    w = mixing.torus_2d(*mixing.near_square_factors(n))
    np.testing.assert_allclose(mixed, jnp.asarray(w) @ x, rtol=1e-5,
                               atol=1e-6)


def test_gossip_explicit_matrix_equals_matmul():
    """Any doubly stochastic mixing.py matrix runs as collectives."""
    n, d = 6, 3
    for w in (mixing.ring(n), mixing.fully_connected(n)):
        gm = C.GossipMix(w=w)
        x = jax.random.normal(jax.random.PRNGKey(3), (n, d))
        mixed = jax.vmap(lambda xi: gm(xi, axis_name=AXIS),
                         axis_name=AXIS)(x)
        np.testing.assert_allclose(mixed, jnp.asarray(w) @ x, rtol=1e-5,
                                   atol=1e-6)


def test_gossip_message_bytes_uses_matrix_degree():
    tree = jnp.zeros((10,))
    fp32 = 40.0
    assert C.GossipMix("torus").message_bytes(tree, n_workers=16) == 4 * fp32
    assert C.GossipMix("ring").message_bytes(tree, n_workers=16) == 2 * fp32
    assert C.GossipMix(w=mixing.fully_connected(4)).message_bytes(
        tree, n_workers=4) == 3 * fp32


def test_gossip_registry_accepts_torus():
    gm = C.make_exchange("gossip", topology="torus")
    assert gm.topology == "torus"


def test_delayed_exchange_schedule_replays_measured_staleness():
    """Trace-driven staleness: output at step t is the input mean from
    step t - s_t (zeros before the cluster produced one), s_t clipped to
    tau — Assumption 5 with D(t) measured instead of worst-case."""
    n, d, tau = 2, 8, 3
    sched = [0, 2, 1, 3, 0, 2, 9]   # 9 -> clipped to tau=3
    ex = C.DelayedExchange(inner=C.MbSGDExchange(), tau=tau, schedule=sched)
    state = jax.vmap(ex.init)(jnp.zeros((n, d)))
    outs, means = [], []
    for t in range(7):
        g = jnp.stack([jnp.full((d,), float(t * 10 + i)) for i in range(n)])
        means.append(g.mean(0))
        out, state = _vrun(ex, g, state, jax.random.PRNGKey(t))
        outs.append(out[0])
    for t in range(7):
        s = min(sched[t], tau)
        expect = jnp.zeros((d,)) if t < s else means[t - s]
        np.testing.assert_allclose(outs[t], expect, rtol=1e-6, err_msg=str(t))


def test_delayed_exchange_schedule_per_worker_rows():
    """A 2-D schedule gives each worker its own measured delay sequence."""
    n, d = 2, 4
    ex = C.DelayedExchange(inner=C.MbSGDExchange(), tau=2,
                           schedule=[[0, 1], [2, 0]])
    state = jax.vmap(ex.init)(jnp.zeros((n, d)))
    g0 = jnp.ones((n, d))
    out0, state = _vrun(ex, g0, state, jax.random.PRNGKey(0))
    # worker 0: s=0 -> fresh mean (1); worker 1: s=2 -> idle-start zeros
    np.testing.assert_allclose(out0[0], jnp.ones((d,)), rtol=1e-6)
    np.testing.assert_allclose(out0[1], jnp.zeros((d,)))
    g1 = 3.0 * jnp.ones((n, d))
    out1, state = _vrun(ex, g1, state, jax.random.PRNGKey(1))
    # worker 0: s=1 -> step-0 mean (1); worker 1: s=0 -> fresh mean (3)
    np.testing.assert_allclose(out1[0], jnp.ones((d,)), rtol=1e-6)
    np.testing.assert_allclose(out1[1], 3.0 * jnp.ones((d,)), rtol=1e-6)


def test_delayed_exchange_zero_schedule_is_inner_exchange():
    """s_t = 0 everywhere degenerates to the wrapped exchange exactly."""
    n, d = 3, 6
    g = jax.random.normal(jax.random.PRNGKey(5), (n, d))
    ex = C.DelayedExchange(inner=C.MbSGDExchange(), tau=4,
                           schedule=[0, 0, 0])
    state = jax.vmap(ex.init)(jnp.zeros((n, d)))
    out, _ = _vrun(ex, g, state, jax.random.PRNGKey(0))
    np.testing.assert_allclose(out, jnp.broadcast_to(g.mean(0), (n, d)),
                               rtol=1e-6)


def test_delayed_exchange_schedule_rejects_wrong_row_count():
    import pytest

    ex = C.DelayedExchange(inner=C.MbSGDExchange(), tau=2,
                           schedule=[[0, 1], [1, 0]])   # 2 rows
    n = 4                                               # but 4 workers
    state = jax.vmap(ex.init)(jnp.zeros((n, 3)))
    with pytest.raises(ValueError):
        _vrun(ex, jnp.ones((n, 3)), state, jax.random.PRNGKey(0))
