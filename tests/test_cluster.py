"""Virtual cluster engine: scheduler/eventsim cross-checks, trace
invariants, and the async-beats-sync acceptance run (Chapter 4's claims
on real training, not closed forms)."""
import numpy as np
import pytest

from repro import cluster
from repro.cluster import scheduler
from repro.core import eventsim, mixing


LAT, TR = 1.5, 5.0


def _spec(**kw):
    base = dict(n_workers=8, t_compute=1.0,
                multipliers=cluster.straggler_multipliers(8, factor=4.0),
                t_lat=1e-2, t_tr=2e-3, size_mb=1.0, codec="rq4")
    base.update(kw)
    return cluster.ClusterSpec(**base)


# ---------------------------------------------------------------------------
# scheduler <-> eventsim cross-checks
# ---------------------------------------------------------------------------


def test_sync_makespan_matches_eventsim_single_ps():
    """ACCEPTANCE: with zero compute the scheduler's sync-PS round IS the
    eventsim single-PS pattern — same simulate() calls, equal to 1e-9."""
    for n in (2, 4, 8):
        spec = cluster.ClusterSpec(n_workers=n, t_compute=0.0, t_lat=LAT,
                                   t_tr=TR, size_mb=1.0)
        tr = cluster.make_protocol("sync_ps").schedule(spec, rounds=1)
        ref = eventsim.single_ps_makespan(n, 1.0, t_lat=LAT, t_tr=TR)
        assert abs(tr.makespan - ref) < 1e-9


def test_async_scheduler_generalizes_eventsim_timeline():
    """With deterministic multipliers and zero jitter the event loop
    reproduces eventsim.async_ps_timeline event for event."""
    spec = cluster.ClusterSpec(n_workers=3, t_compute=1.0,
                               multipliers=(1.0, 1.0, 10.0),
                               t_lat=0.1, t_tr=0.2, size_mb=1.0)
    tr = cluster.make_protocol("async_ps").schedule(spec, horizon=60.0)
    ref = eventsim.async_ps_timeline(3, t_compute=[1.0, 1.0, 10.0],
                                     t_lat=0.1, t_tr=0.2, size=1.0,
                                     horizon=60.0)
    # the scheduler also clips on APPLY time (makespan <= horizon always);
    # the timeline helper clips on request time only
    ref = [u for u in ref if u[1] <= 60.0]
    assert tr.makespan <= 60.0
    got = [(e.worker, e.t_wall, e.staleness) for e in tr.updates()]
    assert len(got) == len(ref)
    for (w, t, s), (rw, rt, rs) in zip(got, ref):
        assert w == rw and s == rs
        assert t == pytest.approx(rt, abs=1e-12)


def test_sync_ring_allreduce_costing_matches_csgd_ring_makespan():
    """ACCEPTANCE: ClusterSpec(allreduce='ring') costs the averaging
    round as the partitioned ring — with zero compute the sync makespan
    equals eventsim.csgd_ring_makespan exactly, and the per-wire ledger
    records 2(N-1) messages SENT per worker per iteration."""
    for n in (2, 4, 8):
        spec = cluster.ClusterSpec(n_workers=n, t_compute=0.0, t_lat=LAT,
                                   t_tr=TR, size_mb=1.0, allreduce="ring")
        tr = cluster.make_protocol("sync_ps").schedule(spec, rounds=1)
        ref = eventsim.csgd_ring_makespan(n, 1.0, t_lat=LAT, t_tr=TR)
        assert abs(tr.makespan - ref) < 1e-9
        sent = {w: [m for m in tr.messages if m.src == w]
                for w in range(n)}
        for w in range(n):
            assert len(sent[w]) == 2 * (n - 1)
            assert sum(m.size for m in sent[w]) == \
                pytest.approx(2 * 1.0 * (n - 1) / n)
        assert tr.extra("allreduce") == "ring"
    # with compute, the ring is gated on the slowest worker
    spec = cluster.ClusterSpec(n_workers=4, t_compute=1.0,
                               multipliers=(1.0, 1.0, 1.0, 3.0),
                               t_lat=LAT, t_tr=TR, size_mb=1.0,
                               allreduce="ring")
    tr = cluster.make_protocol("sync_ps").schedule(spec, rounds=1)
    assert tr.makespan == pytest.approx(
        3.0 + eventsim.csgd_ring_makespan(4, 1.0, t_lat=LAT, t_tr=TR))
    # local_sgd honors the same knob; unknown values are rejected
    tr2 = cluster.make_protocol("local_sgd", period_h=2).schedule(
        cluster.ClusterSpec(n_workers=4, t_compute=0.0, t_lat=LAT,
                            t_tr=TR, size_mb=1.0, allreduce="ring"),
        rounds=2)
    assert tr2.extra("allreduce") == "ring"
    with pytest.raises(ValueError):
        cluster.make_protocol("sync_ps").schedule(
            cluster.ClusterSpec(allreduce="mesh"), rounds=1)


def test_trace_comm_ledger_consistent_with_deliveries():
    """Per-message records partition each delivery: k messages back to
    back, same span, sizes summing to the transfer."""
    spec = _spec(n_messages=3)
    tr = cluster.make_protocol("sync_ps").schedule(spec, rounds=2)
    assert len(tr.messages) == 3 * len(tr.comm)
    by_tag = {}
    for r in tr.messages:
        by_tag.setdefault((r.tag, r.src, r.dst), []).append(r)
    for d in tr.comm:
        recs = sorted(by_tag[(d.tag, d.src, d.dst)],
                      key=lambda r: r.t_start)
        assert recs[0].t_start == pytest.approx(d.t_start)
        assert recs[-1].t_end == pytest.approx(d.t_end)
        assert sum(r.size for r in recs) == pytest.approx(d.size)


# ---------------------------------------------------------------------------
# trace invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("proto,kw,skw", [
    ("sync_ps", {}, {"rounds": 4}),
    ("async_ps", {}, {"horizon": 30.0}),
    ("local_sgd", {"period_h": 4}, {"rounds": 3}),
    ("dsgd", {"topology": "torus"}, {"rounds": 4}),
    ("laq", {"skip": 2}, {"rounds": 6}),
])
def test_trace_sorted_and_versions_consistent(proto, kw, skw):
    tr = cluster.make_protocol(proto, **kw).schedule(_spec(), **skw)
    ts = [e.t_wall for e in tr.events]
    assert ts == sorted(ts)
    for e in tr.updates():
        assert e.staleness == e.version_applied - e.version_pulled >= 0
    assert tr.makespan >= ts[-1] - 1e-12


def test_async_staleness_grows_with_straggler_spread():
    uniform = cluster.make_protocol("async_ps").schedule(
        _spec(multipliers=()), horizon=60.0)
    straggled = cluster.make_protocol("async_ps").schedule(
        _spec(), horizon=60.0)
    assert uniform.max_staleness == 7          # n-1 at equal speeds
    assert straggled.max_staleness > uniform.max_staleness
    assert straggled.max_staleness <= 4 * 8    # factor * n bound


def test_jitter_is_seeded_and_order_independent():
    s1 = _spec(jitter=0.3, seed=5)
    s2 = _spec(jitter=0.3, seed=5)
    assert s1.compute_time(3, 11) == s2.compute_time(3, 11)
    assert s1.compute_time(3, 11) != s1.compute_time(3, 12)
    tr1 = cluster.make_protocol("sync_ps").schedule(s1, rounds=3)
    tr2 = cluster.make_protocol("sync_ps").schedule(s2, rounds=3)
    assert tr1.makespan == tr2.makespan


def test_laq_thins_the_uplink():
    """LAQ's whole point: ~n/skip uplink messages per round."""
    sync_tr = cluster.make_protocol("sync_ps").schedule(_spec(), rounds=6)
    laq_tr = cluster.make_protocol("laq", skip=2).schedule(_spec(), rounds=6)
    up = lambda t: [d for d in t.comm if d.tag.startswith("agg")]
    assert len(up(laq_tr)) == len(up(sync_tr)) // 2
    assert laq_tr.makespan < sync_tr.makespan
    assert laq_tr.max_staleness == 2   # a gradient serves `skip` rounds


def test_protocol_registry_mirrors_exchanges():
    assert set(cluster.PROTOCOLS) == {"sync_ps", "async_ps", "local_sgd",
                                      "dsgd", "dcd", "ecd", "laq"}
    with pytest.raises(KeyError):
        cluster.make_protocol("nope")
    # protocol objects are frozen dataclasses with a name, like EXCHANGES
    for name, cls in cluster.PROTOCOLS.items():
        assert cls().name == name


def test_dsgd_trace_costs_topology_degree():
    """The scheduler charges deg(W) sends per worker per round, matching
    eventsim.decentralized_makespan's accounting."""
    ring_tr = cluster.make_protocol("dsgd", topology="ring").schedule(
        _spec(), rounds=1)
    torus_tr = cluster.make_protocol("dsgd", topology="torus").schedule(
        _spec(), rounds=1)
    per_worker = lambda t: len(t.comm) / t.n_workers
    assert per_worker(ring_tr) == 2
    assert per_worker(torus_tr) == mixing.degree(
        mixing.torus_2d(*mixing.near_square_factors(8)))
    # the trace carries the very matrix it was costed with
    np.testing.assert_allclose(
        np.asarray(torus_tr.extra("w")),
        mixing.torus_2d(*mixing.near_square_factors(8)))


# ---------------------------------------------------------------------------
# replay: real training follows the trace
# ---------------------------------------------------------------------------


def test_acceptance_async_beats_sync_at_equal_wallclock():
    """ACCEPTANCE: async PS, 8 vmapped workers, one 4x straggler, fused
    rq4 codec — at sync-PS's simulated wall-clock the async run applies
    STRICTLY more updates and lands within 2x of sync's loss."""
    spec = _spec()
    wl = cluster.quadratic_workload(n_workers=8)
    sync_tr = cluster.make_protocol("sync_ps").schedule(spec, rounds=20)
    async_tr = cluster.make_protocol("async_ps").schedule(
        spec, horizon=sync_tr.makespan)
    # equal simulated wall-clock by construction
    assert async_tr.makespan <= sync_tr.makespan
    sync_res = cluster.replay(sync_tr, wl, codec="rq4", lr=0.1,
                              eval_every=5)
    async_res = cluster.replay(async_tr, wl, codec="rq4", lr=0.1,
                               eval_every=25)
    assert async_res.updates_applied > sync_res.updates_applied
    assert async_res.final_loss <= 2.0 * sync_res.final_loss
    # the trace's measured staleness actually occurred (it's an async run)
    assert async_res.max_staleness >= 1


def test_sync_replay_matches_parallel_mbsgd_convergence():
    """Sync replay is plain mb-SGD: loss decreases monotonically-ish and
    approaches the quadratic's floor."""
    spec = _spec()
    wl = cluster.quadratic_workload(n_workers=8)
    tr = cluster.make_protocol("sync_ps").schedule(spec, rounds=30)
    res = cluster.replay(tr, wl, codec="none", lr=0.2, eval_every=10)
    first, last = res.losses[0], res.losses[-1]
    assert last < first
    assert res.updates_applied == 30 * 8


def test_local_sgd_and_dsgd_replays_converge():
    spec = _spec()
    wl = cluster.quadratic_workload(n_workers=8)
    start = float(wl.eval_loss(wl.params0))
    for proto, kw, skw in [("local_sgd", {"period_h": 4}, {"rounds": 10}),
                           ("dsgd", {"topology": "torus"}, {"rounds": 40})]:
        tr = cluster.make_protocol(proto, **kw).schedule(spec, **skw)
        # dsgd traces carry their own W; replay uses it by default
        res = cluster.replay(tr, wl, codec="rq4", lr=0.2, eval_every=5)
        assert res.final_loss < 0.7 * start, proto


def test_laq_replay_reuses_stale_gradients_and_converges():
    spec = _spec()
    wl = cluster.quadratic_workload(n_workers=8)
    tr = cluster.make_protocol("laq", skip=2).schedule(spec, rounds=20)
    res = cluster.replay(tr, wl, codec="rq4", lr=0.1, eval_every=5)
    assert res.final_loss < float(wl.eval_loss(wl.params0))
    # half the uplink of sync at the same round count
    sync_tr = cluster.make_protocol("sync_ps").schedule(spec, rounds=20)
    assert res.n_wire_messages < len(sync_tr.messages)


def test_staleness_schedule_bridges_to_delayed_exchange():
    """A measured async trace replays through the algorithm tier: the
    per-worker schedule is bounded by tau and drives DelayedExchange."""
    import jax
    import jax.numpy as jnp

    from repro.core import communicators as C

    tr = cluster.make_protocol("async_ps").schedule(_spec(), horizon=40.0)
    sched = cluster.staleness_schedule(tr, tau=4)
    assert sched.shape[0] == 8
    assert sched.max() <= 4 and sched.min() >= 0

    ex = C.DelayedExchange(inner=C.MbSGDExchange(), tau=4, schedule=sched)
    state = jax.vmap(ex.init)(jnp.zeros((8, 4)))
    g = jnp.ones((8, 4))
    out, state = jax.vmap(
        lambda gi, si: ex(gi, si, jax.random.PRNGKey(0), axis_name="workers"),
        axis_name="workers")(g, state)
    # step 0: workers whose first measured staleness is 0 see the fresh
    # mean, the rest see the idle-start zeros
    fresh = np.asarray(sched[:, 0] == 0, dtype=float)
    np.testing.assert_allclose(np.asarray(out)[:, 0], fresh)
