"""End-to-end behaviour tests: the full training substrate working together
(data pipeline -> sharded train step -> optimizer -> checkpoint -> resume),
plus the production-tier compressed/EC gradient paths."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import load_state, save_state
from repro.data.pipeline import SyntheticLM
from repro.models.common import InputShape
from repro.optim import make_optimizer
from repro.train import steps

KEY = jax.random.PRNGKey(0)


def _run(n_steps=30, **step_kw):
    cfg = configs.get_config("qwen1.5-0.5b").reduced()
    data = SyntheticLM(vocab=cfg.vocab, seq_len=33, batch=8, seed=0)
    opt = make_optimizer("adamw", 3e-3)
    scfg = steps.TrainStepConfig(**step_kw)
    state = steps.init_train_state(cfg, opt, KEY, step_cfg=scfg)
    ts = jax.jit(steps.make_train_step(cfg, opt, scfg))
    losses = []
    for t in range(n_steps):
        state, m = ts(state, data.batch_at(t))
        losses.append(float(m["loss"]))
    return losses, state, (cfg, opt, scfg, data, ts)


def test_training_reduces_loss():
    losses, _, _ = _run(40)
    assert losses[-1] < losses[0] - 0.3
    assert all(np.isfinite(losses))


def test_training_with_compressed_grads_and_error_feedback():
    """Production-tier CSGD/EC path: still trains."""
    comp, state, _ = _run(30, grad_compression="rq8", error_feedback=True)
    assert comp[-1] < comp[0] - 0.2
    assert "ec_err" in state
    # error buffers are being used (non-zero)
    err = max(float(jnp.abs(l).max())
              for l in jax.tree_util.tree_leaves(state["ec_err"]))
    assert err > 0


def test_training_with_biased_compression_needs_error_feedback():
    naive, _, _ = _run(30, grad_compression="sign1", error_feedback=False)
    ec, _, _ = _run(30, grad_compression="sign1", error_feedback=True)
    assert ec[-1] <= naive[-1] + 0.1   # EC at least as good


def test_remat_equivalence():
    """Activation checkpointing must not change the math."""
    l1, _, _ = _run(5, remat=False)
    l2, _, _ = _run(5, remat=True)
    np.testing.assert_allclose(l1, l2, rtol=1e-4)


def test_scan_layers_training_works():
    """scan_layers trains (different param layout -> different init draw,
    so assert improvement, not trajectory equality; exact scanned==unrolled
    math equivalence is covered by tests/test_models.py)."""
    l2, _, _ = _run(40, scan_layers=True)
    assert l2[-1] < l2[0] - 0.15


def test_checkpoint_resume_bitexact(tmp_path):
    losses, state, (cfg, opt, scfg, data, ts) = _run(10)
    f = save_state(state, str(tmp_path), step=10)
    template = jax.eval_shape(lambda: state)
    restored = load_state(template, f)
    s1, m1 = ts(state, data.batch_at(11))
    s2, m2 = ts(restored, data.batch_at(11))
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-6)


def test_grad_clip_changes_updates():
    """AdamW is scale-invariant in steady state, so assert the clip bites
    where it must: the reported grad_norm is pre-clip, and the first-step
    moments differ between clipped and unclipped runs."""
    _, s_clip, _ = _run(1, grad_clip=1e-6)
    _, s_free, _ = _run(1, grad_clip=0.0)
    m_clip = max(float(jnp.abs(l).max())
                 for l in jax.tree_util.tree_leaves(s_clip["opt"]["m"]))
    m_free = max(float(jnp.abs(l).max())
                 for l in jax.tree_util.tree_leaves(s_free["opt"]["m"]))
    assert m_clip < 1e-6 < m_free


def test_data_pipeline_deterministic_and_learnable():
    data = SyntheticLM(vocab=128, seq_len=17, batch=4, seed=7)
    b1, b2 = data.batch_at(3), data.batch_at(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 16)
    # learnability: true successor appears among labels far above chance
    succ = data.succ
    tok = np.asarray(b1["tokens"]).reshape(-1)
    lab = np.asarray(b1["labels"]).reshape(-1)
    hits = np.mean([lab[i] in succ[tok[i]] for i in range(len(tok))])
    assert hits > 0.5   # chance would be ~8/128 = 0.0625
