"""Fault injection, elastic membership, and graceful degradation.

Covers the PR-7 robustness tier: deterministic seeded `FaultPlan`s,
the fault ledger's exact accounting against the wire ledger, quorum /
timeout (backup-worker) aggregation, live-set mixing-matrix
re-derivation at membership epochs, and the ACCEPTANCE criterion —
under 10% message loss plus one mid-run crash-restart, sync-PS-with-
quorum and async-PS replay within 2x of the healthy run's loss at
equal simulated wall-clock, on the quadratic and the reduced
repro-100m LM.
"""
import dataclasses
import math

import numpy as np
import pytest

from repro import cluster
from repro.cluster import faults
from repro.core import mixing

N = 8
INF = float("inf")


def _spec(**kw):
    base = dict(n_workers=N, t_compute=1.0,
                multipliers=cluster.straggler_multipliers(N, factor=4.0),
                t_lat=1e-2, t_tr=2e-3, size_mb=1.0)
    base.update(kw)
    return cluster.ClusterSpec(**base)


# ---------------------------------------------------------------------------
# FaultPlan membership semantics
# ---------------------------------------------------------------------------


def test_crash_window_membership():
    p = faults.FaultPlan(4, crashes=((1, 2.0, 5.0),))
    assert p.is_up(1, 1.9) and not p.is_up(1, 2.0)
    assert not p.is_up(1, 4.9) and p.is_up(1, 5.0)
    assert p.down_in(1, 1.0, 3.0)        # span enters the window
    assert p.down_in(1, 3.0, 3.5)        # span inside the window
    assert not p.down_in(1, 0.0, 1.9)    # span before the window
    assert p.restart_after(1, 3.0) == 5.0
    assert p.restart_after(1, 6.0) == 6.0
    assert p.alive_at(3.0) == (0, 2, 3)


def test_permanent_departure_and_join():
    p = faults.churn(4, departures=((3, 2.0),), joins=((2, 1.5),))
    assert not p.is_up(2, 1.0) and p.is_up(2, 1.5)
    assert p.join_time(2) == 1.5
    assert p.restart_after(3, 2.5) is None     # never comes back
    assert p.down_in(2, 0.0, 1.0)              # not born yet = absent
    assert p.alive_at(0.0) == (0, 1, 3)
    assert p.alive_at(3.0) == (0, 1, 2)


def test_plan_validates_inputs():
    with pytest.raises(ValueError, match="empty"):
        faults.FaultPlan(4, crashes=((0, 5.0, 2.0),))
    with pytest.raises(ValueError, match="names worker"):
        faults.FaultPlan(4, crashes=((9, 1.0, 2.0),))
    with pytest.raises(ValueError, match="names worker"):
        faults.FaultPlan(4, joins=((7, 1.0),))


def test_message_decisions_are_pure_functions():
    p = faults.FaultPlan(N, seed=5, p_drop=0.3, p_dup=0.2,
                         delay_scale=0.1)
    for _ in range(3):   # identical regardless of call order / repetition
        assert p.drops_msg(0, 8, "agg3", 0) == p.drops_msg(0, 8, "agg3", 0)
        assert p.extra_delay(2, 5, "gossip1") == \
            p.extra_delay(2, 5, "gossip1")
    # distinct identities draw independently (not all equal)
    draws = {p.drops_msg(s, 8, f"agg{r}", 0)
             for s in range(N) for r in range(20)}
    assert draws == {True, False}


# ---------------------------------------------------------------------------
# Determinism (satellite: bit-identical traces for every protocol)
# ---------------------------------------------------------------------------


def _schedule(name, spec, plan=None):
    kw = {"quorum": 6} if name in ("sync_ps", "laq") else {}
    return cluster.make_protocol(name, **kw).schedule(spec, rounds=3,
                                                      plan=plan)


def test_straggler_and_jitter_bit_identical_across_runs():
    assert cluster.straggler_multipliers(N, factor=4.0) == \
        cluster.straggler_multipliers(N, factor=4.0)
    s1, s2 = _spec(jitter=0.4, seed=11), _spec(jitter=0.4, seed=11)
    for w in range(N):
        for step in range(5):
            assert s1.compute_time(w, step) == s2.compute_time(w, step)


@pytest.mark.parametrize("name", sorted(cluster.PROTOCOLS))
def test_trace_deterministic_per_protocol(name):
    """Same seed -> identical trace, with straggler jitter AND a fault
    plan active (crash + drops + dups + delays)."""
    plan = faults.FaultPlan(N, seed=2, p_drop=0.15, p_dup=0.1,
                            delay_scale=0.05,
                            crashes=((2, 1.0, 4.0),))
    t1 = _schedule(name, _spec(jitter=0.3, seed=9), plan)
    t2 = _schedule(name, _spec(jitter=0.3, seed=9), plan)
    assert t1 == t2
    assert t1.faults == t2.faults
    faults.validate(t1)


@pytest.mark.parametrize("name", sorted(cluster.PROTOCOLS))
def test_healthy_trace_carries_no_ledger(name):
    tr = cluster.make_protocol(name).schedule(_spec(), rounds=2)
    assert tr.faults is None
    faults.validate(tr)   # empty story validates too


# ---------------------------------------------------------------------------
# Ledger exactness
# ---------------------------------------------------------------------------


def test_ledger_accounts_every_message_exactly():
    plan = faults.FaultPlan(N, seed=4, p_drop=0.2, p_dup=0.1)
    for name in ("sync_ps", "async_ps", "dsgd", "ecd"):
        tr = _schedule(name, _spec(), plan)
        tally = faults.validate(tr)
        lost = sum(1 for d in tr.comm if d.status == "lost")
        dup = sum(1 for d in tr.comm if d.status == "dup")
        assert tally["dropped"] == lost > 0, name
        assert tally["duplicated"] == dup
        assert tally["delivered"] == len(tr.comm) - lost - dup
        # reliable-channel retries ride the wire with ~a tags
        assert tally["retried"] == sum(
            1 for d in tr.comm if "~a" in d.tag and d.status != "dup")


def test_validate_catches_a_forged_ledger():
    plan = faults.lossy_network(N, p_drop=0.3, seed=0)
    tr = _schedule("sync_ps", _spec(), plan)
    assert tr.faults.n_dropped > 0
    forged = dataclasses.replace(tr, faults=faults.FaultLedger())
    with pytest.raises(AssertionError):
        faults.validate(forged)


def test_async_horizon_cut_reconciles_ledger():
    """Satellite: no in-flight message is dropped from the timeline but
    kept in the wire ledger — every recorded delivery completes inside
    the horizon and applied updates == delivered pushes."""
    spec = _spec(jitter=0.2, seed=3)
    for horizon in (5.0, 17.3, 40.0):
        tr = cluster.make_protocol("async_ps").schedule(spec,
                                                        horizon=horizon)
        assert all(d.t_end <= horizon + 1e-9 for d in tr.comm)
        n_push = sum(1 for d in tr.comm
                     if d.dst == N and d.status == "ok")
        assert n_push == tr.n_updates
        # per-switch records match deliveries 1:1 (n_messages = 1 here)
        assert len(tr.messages) == len(tr.comm)


# ---------------------------------------------------------------------------
# Quorum / timeout (backup-worker aggregation)
# ---------------------------------------------------------------------------


def test_collect_quorum_kth_arrival_and_deadline():
    led = faults._LedgerBuilder()
    arrivals = [(1.0, 0), (2.0, 1), (3.0, 2), (9.0, 3)]
    # quorum of 2: closes at the 2nd arrival, two stragglers recorded
    t_agg, contribs = faults.collect_quorum(
        arrivals, t_start=0.0, timeout=None, quorum=2, ledger=led,
        round_idx=0)
    assert t_agg == 2.0 and contribs == [0, 1]
    assert [r.worker for r in led.timeouts] == [2, 3]
    # deadline binds before the quorum is met -> shortfall
    led = faults._LedgerBuilder()
    t_agg, contribs = faults.collect_quorum(
        arrivals, t_start=0.0, timeout=2.5, quorum=4, ledger=led,
        round_idx=1)
    assert t_agg == 2.5 and contribs == [0, 1]
    assert led.shortfalls[0].n_got == 2 and led.shortfalls[0].n_wanted == 4
    # no limits: take everything that arrives
    led = faults._LedgerBuilder()
    t_agg, contribs = faults.collect_quorum(
        arrivals, t_start=0.0, timeout=None, quorum=None, ledger=led,
        round_idx=2)
    assert t_agg == 9.0 and contribs == [0, 1, 2, 3]
    assert not led.timeouts


def test_sync_quorum_drops_the_straggler():
    """With quorum N-1 the 4x straggler is cut every round: the quorum
    trace's makespan beats the barrier's by a wide margin."""
    spec = _spec()
    full = cluster.make_protocol("sync_ps").schedule(spec, rounds=4)
    q = cluster.make_protocol("sync_ps", quorum=N - 1).schedule(
        spec, rounds=4, plan=faults.FaultPlan(N))
    assert q.makespan < 0.5 * full.makespan
    straggler = int(np.argmax(spec.multipliers))
    assert all(r.worker == straggler for r in q.faults.timeouts)
    assert q.faults.n_timed_out == 4


# ---------------------------------------------------------------------------
# Elastic gossip: W over the live set
# ---------------------------------------------------------------------------


def test_live_mixing_matrix_doubly_stochastic_over_live_set():
    w = mixing.ring(N)
    for alive in ([0, 1, 2, 3, 4, 5, 6], [1, 3, 5], [0], list(range(N))):
        wl = faults.live_mixing_matrix(w, alive)
        assert np.allclose(wl.sum(0), 1.0) and np.allclose(wl.sum(1), 1.0)
        assert np.allclose(wl, wl.T)
        dead = [i for i in range(N) if i not in alive]
        for i in dead:   # absent workers are identity rows
            e = np.zeros(N)
            e[i] = 1.0
            assert np.allclose(wl[i], e)
        # still inside the Birkhoff polytope (what GossipMix lowers)
        terms = mixing.birkhoff_decomposition(wl)
        assert sum(c for c, _ in terms) == pytest.approx(1.0)


def test_gossip_rederives_matrix_at_each_epoch():
    plan = faults.churn(N, departures=((5, 3.0),), joins=((7, 4.0),))
    tr = cluster.make_protocol("dsgd").schedule(_spec(), rounds=6,
                                                plan=plan)
    epochs = tr.faults.epochs
    assert len(epochs) >= 2                      # membership changed
    assert len({e.alive for e in epochs}) == len(epochs)
    assert all(e.n_birkhoff_terms > 0 for e in epochs)
    # a rejoin (the mid-run join) pulled from a live donor
    assert any(r.worker == 7 and r.donor != 7 for r in tr.faults.rejoins)
    # per-round present sets ride in the extras for the replay
    present = tr.extra("present")
    assert any(5 not in p for p in present)
    assert any(7 in p for p in present)


def test_fault_path_rejects_ring_allreduce():
    spec = _spec(allreduce="ring")
    with pytest.raises(ValueError, match="ring"):
        cluster.make_protocol("sync_ps", quorum=4).schedule(
            spec, rounds=2, plan=faults.FaultPlan(N))


def test_reliable_channels_terminate_under_total_loss():
    """p_drop = 1: unreliable uplinks lose everything (shortfall rounds),
    reliable broadcasts force delivery at max_retries — simulation ends."""
    plan = faults.FaultPlan(N, seed=0, p_drop=1.0, max_retries=2)
    tr = cluster.make_protocol("sync_ps", quorum=4).schedule(
        _spec(), rounds=2, plan=plan)
    tally = faults.validate(tr)
    assert tally["shortfalls"] == 2          # no uplink ever arrives
    assert math.isfinite(tr.makespan)
    # every broadcast burned its retry budget, then landed
    assert tally["retried"] >= tally["shortfalls"]


# ---------------------------------------------------------------------------
# Faulty replays train (and the ACCEPTANCE criterion)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["local_sgd", "dsgd", "dcd", "ecd",
                                  "laq"])
def test_faulty_replay_trains_quadratic(name):
    plan = faults.FaultPlan(N, seed=1, p_drop=0.1,
                            crashes=((2, 2.0, 6.0),))
    wl = cluster.quadratic_workload(n_workers=N)
    tr = _schedule(name, _spec(), plan)
    faults.validate(tr)
    res = cluster.replay(tr, wl, lr=0.1, eval_every=1)
    assert np.isfinite(res.losses).all()
    assert res.final_loss < float(wl.eval_loss(wl.params0))


def _acceptance(workload, *, rounds, lr, tol=2.0):
    spec = _spec()
    healthy = cluster.make_protocol("sync_ps").schedule(spec,
                                                        rounds=rounds)
    t_eq = healthy.makespan          # the equal-wall-clock point
    ref = cluster.replay(healthy, workload, lr=lr, eval_every=1)
    # the quorum run outpaces the barrier run (straggler cut), so anchor
    # the crash window inside ITS span, not the healthy makespan's
    t_q = cluster.make_protocol("sync_ps", quorum=N - 2).schedule(
        spec, rounds=rounds, plan=faults.FaultPlan(N)).makespan
    plan = faults.FaultPlan(
        N, seed=0, p_drop=0.1,
        crashes=((1, 0.25 * t_q, 0.5 * t_q),))

    sync_q = cluster.make_protocol("sync_ps", quorum=N - 2).schedule(
        spec, rounds=rounds, plan=plan)
    tally = faults.validate(sync_q)   # exact accounting, or it throws
    assert tally["dropped"] > 0 and tally["rejoins"] >= 1
    res_s = cluster.replay(sync_q, workload, lr=lr, eval_every=1)

    asyn = cluster.make_protocol("async_ps").schedule(spec, horizon=t_eq,
                                                      plan=plan)
    tally_a = faults.validate(asyn)
    assert tally_a["dropped"] > 0 and tally_a["retried"] > 0
    res_a = cluster.replay(
        asyn, workload, lr=lr,
        eval_every=max(asyn.n_updates // 20, 1))

    ref_loss = ref.loss_at(t_eq)
    assert res_s.loss_at(t_eq) <= tol * ref_loss, \
        (res_s.loss_at(t_eq), ref_loss)
    assert res_a.loss_at(t_eq) <= tol * ref_loss, \
        (res_a.loss_at(t_eq), ref_loss)


def test_acceptance_quadratic_survives_loss_and_crash():
    """ACCEPTANCE: 10% drop + one crash-restart; sync-PS-with-quorum and
    async-PS within 2x of the healthy loss at equal simulated
    wall-clock, fault ledger exact."""
    _acceptance(cluster.quadratic_workload(n_workers=N), rounds=10,
                lr=0.1)


def test_acceptance_lm_smoke_survives_loss_and_crash():
    """ACCEPTANCE (repro-100m reduced LM variant)."""
    _acceptance(cluster.lm_workload(smoke=True), rounds=3, lr=0.05)
