"""Optimizer unit tests (hand-rolled substrate: no optax offline)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.optim import optimizers as O


def _params():
    return {"w": jnp.array([1.0, -2.0, 3.0]), "b": jnp.array([0.5])}


def _grads():
    return {"w": jnp.array([0.1, 0.2, -0.3]), "b": jnp.array([1.0])}


def test_sgd_is_plain_descent():
    opt = O.sgd(0.1)
    st_ = opt.init(_params())
    upd, st_ = opt.update(_grads(), st_, _params())
    np.testing.assert_allclose(upd["w"], -0.1 * _grads()["w"], rtol=1e-6)
    assert int(st_["step"]) == 1


def test_sgd_schedule_callable():
    opt = O.sgd(lambda step: 0.1 / (1.0 + step.astype(jnp.float32)))
    st_ = opt.init(_params())
    u0, st_ = opt.update(_grads(), st_, _params())
    u1, st_ = opt.update(_grads(), st_, _params())
    np.testing.assert_allclose(u1["w"], u0["w"] / 2, rtol=1e-6)


def test_momentum_accumulates():
    opt = O.momentum_sgd(1.0, beta=0.5)
    st_ = opt.init(_params())
    u0, st_ = opt.update(_grads(), st_, _params())
    u1, st_ = opt.update(_grads(), st_, _params())
    # m1 = g, m2 = 0.5 g + g = 1.5 g
    np.testing.assert_allclose(u1["w"], 1.5 * u0["w"], rtol=1e-6)


def test_adamw_first_step_is_signed_unit_step():
    """With bias correction, step 1 gives -lr * g/|g| elementwise (eps->0)."""
    opt = O.adamw(1e-2, b1=0.9, b2=0.999, eps=1e-12)
    st_ = opt.init(_params())
    upd, st_ = opt.update(_grads(), st_, _params())
    np.testing.assert_allclose(upd["w"], -1e-2 * jnp.sign(_grads()["w"]),
                               rtol=1e-4)


def test_adamw_weight_decay_shrinks_params():
    opt = O.adamw(1e-2, weight_decay=0.1)
    st_ = opt.init(_params())
    zero_g = jax.tree_util.tree_map(jnp.zeros_like, _grads())
    upd, _ = opt.update(zero_g, st_, _params())
    assert float(upd["w"][0]) < 0 and float(upd["w"][1]) > 0  # toward 0


def test_adamw_moment_dtype_bf16():
    opt = O.adamw(1e-3, moment_dtype=jnp.bfloat16)
    st_ = opt.init(_params())
    assert st_["m"]["w"].dtype == jnp.bfloat16
    upd, st_ = opt.update(_grads(), st_, _params())
    assert bool(jnp.isfinite(upd["w"]).all())


@given(st.floats(0.1, 100.0))
@settings(max_examples=20, deadline=None)
def test_clip_by_global_norm_property(max_norm):
    g = {"a": jnp.array([3.0, 4.0]), "b": jnp.array([12.0])}   # norm 13
    clipped, gn = O.clip_by_global_norm(g, max_norm)
    assert float(gn) == pytest.approx(13.0, rel=1e-5)
    new_norm = float(jnp.sqrt(sum(jnp.sum(l**2) for l in
                                  jax.tree_util.tree_leaves(clipped))))
    assert new_norm <= max_norm * (1 + 1e-5) or new_norm == pytest.approx(
        13.0, rel=1e-5)
    if max_norm < 13.0:
        assert new_norm == pytest.approx(max_norm, rel=1e-4)


def test_cosine_schedule_shape():
    lr = O.cosine_schedule(1.0, warmup=10, total=100, floor=0.1)
    assert float(lr(jnp.asarray(0))) == pytest.approx(0.0)
    assert float(lr(jnp.asarray(10))) == pytest.approx(1.0, rel=0.1)
    assert float(lr(jnp.asarray(100))) == pytest.approx(0.1, rel=1e-3)
    assert float(lr(jnp.asarray(55))) > float(lr(jnp.asarray(90)))
