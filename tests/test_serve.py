"""Serving engine: continuous batching, bulk prefill, checkpoint
hot-swap, admission control, and the shared registry idiom.

The load-bearing assertions:

  * bulk prefill (one fused lax.scan cache fill) is BIT-identical to
    the token-by-token serve_step loop — logits and every decode-state
    leaf — across block families and for the windowed ring cache;
  * continuous batching is semantically invisible: every request's
    greedy token stream equals an unbatched solo decode of the same
    request, even as finished sequences free slots mid-decode and
    queued requests are spliced in;
  * a hot swap mid-decode completes all in-flight requests (zero
    drops) and post-swap decode is bit-identical to a cold start from
    the same published checkpoint;
  * a corrupt published checkpoint (bit flip, or framed NaN garbage)
    is rejected without disturbing the serving params.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, serve
from repro.core import compression
from repro.core.registry import Registry
from repro.models import transformer_scan
from repro.train import steps


def _cfg(**kw):
    base = dict(slots=2, max_len=32, prompt_len=6, n_requests=4,
                mixed_gen=(3, 7), seed=1)
    base.update(kw)
    return serve.ServeConfig(**base)


def _prompt(mc, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, mc.vocab, size=n).astype(np.int32)


# ---------------------------------------------------------------------------
# bulk prefill
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch,window", [("qwen1.5-0.5b", 0),
                                         ("qwen1.5-0.5b", 4),
                                         ("rwkv6-3b", 0)])
def test_bulk_prefill_bit_identical(arch, window):
    """One fused cache fill == the token-by-token loop, bit for bit
    (logits AND every state leaf), including the ring-buffer cache."""
    mc = configs.get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = transformer_scan.init(mc, key)
    B, P = 2, 9
    toks = jax.random.randint(key, (B, P), 0, mc.vocab)
    st0 = transformer_scan.init_decode_state(params, mc, B, P + 4,
                                             window=window,
                                             dtype=jnp.float32)
    serve_step = jax.jit(steps.make_serve_step(mc, scan_layers=True))
    st = st0
    for i in range(P):
        logits, st = serve_step(params, st, {"tokens": toks[:, i:i + 1]})
    bulk = jax.jit(steps.make_bulk_prefill(mc, scan_layers=True))
    blogits, bst = bulk(params, st0, toks)
    assert jnp.array_equal(logits, blogits)
    for a, b in zip(jax.tree_util.tree_leaves(st),
                    jax.tree_util.tree_leaves(bst)):
        assert jnp.array_equal(a, b)


def test_bulk_prefill_rejects_non_token_frontends():
    mc = configs.get_config("seamless-m4t-large-v2").reduced()
    with pytest.raises(ValueError, match="token frontend"):
        steps.make_bulk_prefill(mc, scan_layers=True)


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------


def _solo_decode(params, mc, tokens, gen, max_len):
    """Unbatched greedy reference: one request, a plain serve_step loop."""
    serve_step = jax.jit(steps.make_serve_step(mc, scan_layers=True))
    st = transformer_scan.init_decode_state(params, mc, 1, max_len,
                                            dtype=jnp.float32)
    logits = None
    for i in range(len(tokens)):
        logits, st = serve_step(
            params, st, {"tokens": jnp.asarray(tokens[i:i + 1])[None]})
    out = [int(jnp.argmax(logits, -1)[0])]
    for _ in range(gen - 1):
        logits, st = serve_step(params, st,
                                {"tokens": jnp.asarray([[out[-1]]])})
        out.append(int(jnp.argmax(logits, -1)[0]))
    return out


def test_continuous_batching_matches_solo_decode():
    """Slot splicing is invisible: every request's greedy stream equals
    its unbatched solo decode — finished slots freed mid-batch, queued
    requests admitted without restarting anything."""
    cfg = _cfg(n_requests=6, mixed_gen=(3, 8))
    eng = serve.Engine(cfg)
    reqs = serve.synthetic_requests(cfg)
    res = serve.run(cfg, engine=eng, requests=reqs)
    assert res.n_completed == 6
    assert res.counters["dropped"] == 0
    # more requests than slots => slots were recycled mid-decode
    assert res.counters["admitted"] == 6 > cfg.slots
    for r in reqs:
        ref = _solo_decode(eng.params, eng.model_cfg, r.tokens,
                           r.max_new_tokens, cfg.max_len)
        assert res.completions[r.rid].tokens == ref


def test_static_mode_wastes_steps_on_mixed_lengths():
    """The gang-scheduled baseline needs strictly more decode steps for
    the same mixed-length workload (that gap is what BENCH_serve
    measures as tokens/s)."""
    results = {}
    for mode in ("static", "continuous"):
        cfg = _cfg(mode=mode, n_requests=8, mixed_gen=(2, 10))
        results[mode] = serve.run(cfg)
    assert results["static"].n_completed == 8
    assert results["continuous"].n_completed == 8
    assert (results["continuous"].decode_steps
            < results["static"].decode_steps)
    # identical streams either way: batching policy is not semantics
    for rid in range(8):
        assert (results["static"].completions[rid].tokens
                == results["continuous"].completions[rid].tokens)


def test_admission_control():
    cfg = _cfg(max_queue=2)
    eng = serve.Engine(cfg)
    mc = eng.model_cfg
    # oversized request: prompt + new tokens cannot fit the slot cache
    with pytest.raises(serve.AdmissionError, match="cache slots"):
        eng.submit(_prompt(mc, 30), 10)
    eng.submit(_prompt(mc, 4), 2)
    eng.submit(_prompt(mc, 4), 2)
    with pytest.raises(serve.AdmissionError, match="queue full"):
        eng.submit(_prompt(mc, 4), 2)
    assert eng.counters["rejected"] == 2
    eng.run()
    assert eng.counters["completed"] == 2


# ---------------------------------------------------------------------------
# checkpoint hot-swap
# ---------------------------------------------------------------------------


def test_hot_swap_zero_drops_and_bit_identical_to_cold_start():
    """The acceptance triple: (a) in-flight requests complete across
    the swap, zero dropped; (b) post-swap decode of a fresh request is
    bit-identical to a cold start from the SAME published checkpoint;
    (c) the swap actually happened."""
    cfg = _cfg(slots=2, max_len=48)
    eng = serve.Engine(cfg)
    channel = serve.CheckpointChannel()
    eng.subscribe(channel)
    eng.warmup([6])
    mc = eng.model_cfg

    in_flight = eng.submit(_prompt(mc, 6, seed=5), 16)
    for _ in range(4):
        eng.step()
    assert eng.result(in_flight) is None      # genuinely mid-decode

    trained = transformer_scan.init(mc, jax.random.PRNGKey(42))
    pub = channel.publish(trained, step=11, codec="rq8")
    post_swap = eng.submit(_prompt(mc, 6, seed=6), 8)
    eng.run()

    assert eng.counters["swaps"] == 1
    assert eng.counters["dropped"] == 0
    assert eng.result(in_flight).n_generated == 16

    # cold start from the published wire message (decode is frame-
    # verified: what the server holds IS what a restart would load)
    cold = serve.Engine(cfg, params=serve.CheckpointChannel.decode(pub))
    cold.warmup([6])
    rid = cold.submit(_prompt(mc, 6, seed=6), 8)
    cold.run()
    assert eng.result(post_swap).tokens == cold.result(rid).tokens


def test_corrupt_checkpoint_rejected_without_disturbing_params():
    cfg = _cfg(slots=1)
    eng = serve.Engine(cfg)
    channel = serve.CheckpointChannel()
    eng.subscribe(channel)
    mc = eng.model_cfg
    params_before = eng.params

    good = channel.publish(transformer_scan.init(mc, jax.random.PRNGKey(3)),
                           step=1)
    # flip one payload bit, keep the original frame -> CRC must fail
    channel.publish_packed(compression.flip_bit(good.packed, 77),
                           good.crc, step=2)
    assert not eng.maybe_swap()
    assert eng.counters["swaps_rejected"] == 1
    assert eng.params is params_before

    # framed-but-garbage publish: NaN params pass the CRC (the frame is
    # honest) and must die on the post-decode finite guard instead
    nan_params = jax.tree_util.tree_map(
        lambda a: jnp.full_like(a, jnp.nan), params_before)
    with pytest.raises(compression.WireCorruptionError, match="NaN"):
        serve.CheckpointChannel.decode(channel.publish(nan_params, step=3))
    assert not eng.maybe_swap()
    assert eng.params is params_before
    assert eng.counters["swaps_rejected"] == 2

    # the channel still works after rejects: a good publish swaps
    channel.publish(transformer_scan.init(mc, jax.random.PRNGKey(4)),
                    step=4)
    assert eng.maybe_swap()
    assert eng.params is not params_before


def test_publish_train_state_closes_the_loop():
    """The trainer-side one-liner: params straight off a live train
    state, decoded back to the exact rq8 x_hat the wire carries."""
    from repro.optim.optimizers import sgd
    mc = configs.get_config("qwen1.5-0.5b").reduced()
    opt = sgd(0.1)
    state = steps.init_train_state(mc, opt, jax.random.PRNGKey(0))
    channel = serve.CheckpointChannel()
    pub = serve.publish_train_state(channel, state, codec="rq8")
    assert pub.step == 0 and pub.codec == "rq8"
    decoded = serve.CheckpointChannel.decode(pub)
    want = compression.codec("rq8").tree_decode_flat(pub.packed)
    for a, b in zip(jax.tree_util.tree_leaves(decoded),
                    jax.tree_util.tree_leaves(want)):
        assert jnp.array_equal(a, b)
    # compressed wire format is really smaller than fp32
    fp32 = sum(l.size * 4 for l in jax.tree_util.tree_leaves(
        state["params"]))
    assert pub.wire_bytes < 0.3 * fp32


# ---------------------------------------------------------------------------
# programmatic entry point
# ---------------------------------------------------------------------------


def test_run_is_the_single_entry_point():
    cfg = _cfg(n_requests=3, mixed_gen=(2, 4))
    res = serve.run(cfg)
    assert isinstance(res, serve.ServeResult)
    assert res.n_completed == 3
    assert res.total_tokens == sum(
        c.n_generated for c in res.completions.values())
    assert res.tokens_per_s > 0 and res.p99_ms >= res.p50_ms
    row = res.row(scenario="x")
    assert row["scenario"] == "x" and row["dropped"] == 0

    # the CLI is a shim over the same function
    from repro.launch import serve as serve_cli
    out = serve_cli.main(["--reduced", "--slots", "2", "--prompt-len", "4",
                          "--gen", "3", "--requests", "3"])
    assert isinstance(out, serve.ServeResult) and out.n_completed == 3


# ---------------------------------------------------------------------------
# the shared registry idiom
# ---------------------------------------------------------------------------


def test_registry_uniform_error_and_mapping_protocol():
    reg = Registry("widget", {"a": int})
    assert "a" in reg and sorted(reg) == ["a"] and len(reg) == 1
    assert reg.get("a") is int and reg.make("a") == 0
    with pytest.raises(KeyError, match=r"unknown widget 'b'; have \['a'\]"):
        reg["b"]

    @reg.register("b")
    class B:
        pass

    assert reg.make("b").__class__ is B
    with pytest.raises(ValueError, match="already registered"):
        reg.register("a", float)
    reg.replace("a", float)
    assert reg.get("a") is float


def test_all_four_registries_share_the_idiom():
    from repro import cluster
    from repro.core import communicators, compression as comp
    from repro.cluster import aggregators

    for registry, sample in [(communicators.EXCHANGES, "csgd_ring"),
                             (cluster.PROTOCOLS, "sync_ps"),
                             (comp.CODECS, "rq4"),
                             (aggregators.AGGREGATORS, "mean")]:
        assert isinstance(registry, Registry)
        assert sample in registry
        with pytest.raises(KeyError,
                           match=f"unknown {registry.kind} 'nope'"):
            registry["nope"]
    # factories and accessors still work as before the migration
    assert communicators.make_exchange("gossip", topology=None)
    assert cluster.make_protocol("local_sgd", period_h=4).period_h == 4
    assert comp.codec("rq8").bits == 8
    assert aggregators.aggregator("mean") is aggregators.mean
