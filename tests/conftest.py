import os
import sys
import types

# Keep the default 1-device CPU view for smoke tests and benches; ONLY
# launch/dryrun.py forces 512 host devices (see the system design brief).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_enable_x64", False)

# Property tests use hypothesis when available (CI installs it from
# requirements.txt); offline containers fall back to the deterministic
# stub so the suite still collects and the properties still run.
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub

    _mod = types.ModuleType("hypothesis")
    _mod.given = _hypothesis_stub.given
    _mod.settings = _hypothesis_stub.settings
    _mod.strategies = types.ModuleType("hypothesis.strategies")
    _mod.strategies.integers = _hypothesis_stub.integers
    _mod.strategies.floats = _hypothesis_stub.floats
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies
