import os

# Keep the default 1-device CPU view for smoke tests and benches; ONLY
# launch/dryrun.py forces 512 host devices (see the system design brief).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_enable_x64", False)
