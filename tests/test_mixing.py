"""Gossip-matrix properties (Assumption 7) + the paper's rho examples."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import mixing


@given(st.integers(3, 64))
@settings(max_examples=20, deadline=None)
def test_ring_satisfies_assumption7(n):
    w = mixing.ring(n)
    mixing.check_assumption7(w)


@given(st.integers(2, 8), st.integers(2, 8))
@settings(max_examples=15, deadline=None)
def test_torus_satisfies_assumption7(r, c):
    if r * c < 3:
        return
    w = mixing.torus_2d(r, c)
    mixing.check_assumption7(w)


def test_fully_connected_rho_zero():
    """Paper: W1 = 11^T/N has rho = 0."""
    assert mixing.spectral_rho(mixing.fully_connected(8)) == pytest.approx(
        0.0, abs=1e-9)


def test_disconnected_rho_one():
    """Paper: W3 (disconnected) has rho = 1 -> DSGD does not mix."""
    w = mixing.disconnected(6)
    assert mixing.spectral_rho(w) == pytest.approx(1.0, abs=1e-9)
    with pytest.raises(ValueError):
        mixing.check_assumption7(w)


def test_ring_rho_exact_eigenvalue():
    """Exact: lambda_2 = (1 + 2 cos(2 pi/N)) / 3, i.e. rho ~ 1 - 4pi^2/(3N^2).

    PAPER ERRATUM: the text states rho ~= 1 - 16 pi^2 / (3 N^2); the exact
    eigenvalues of its own W2 give 1 - 4 pi^2 / (3 N^2) (Taylor of the
    cosine). We assert the exact value and record the discrepancy in
    EXPERIMENTS.md.
    """
    for n in (8, 16, 64, 256):
        got = mixing.spectral_rho(mixing.ring(n))
        exact = abs(1 + 2 * np.cos(2 * np.pi / n)) / 3
        assert got == pytest.approx(exact, abs=1e-9)
        taylor = 1 - 4 * np.pi**2 / (3 * n**2)
        assert got == pytest.approx(taylor, abs=30.0 / n**3)
        paper = mixing.ring_rho_paper_estimate(n)
        assert abs(got - paper) > abs(got - taylor)  # the erratum


def test_torus_mixes_faster_than_ring():
    """Beyond-paper: 2-D torus (deg 4) has a larger spectral gap than the
    ring (deg 2) at equal N — the topology lever on Thm 5.2.6's last term."""
    ring_rho = mixing.spectral_rho(mixing.ring(16))
    torus_rho = mixing.spectral_rho(mixing.torus_2d(4, 4))
    assert torus_rho < ring_rho


def test_degree():
    assert mixing.degree(mixing.ring(8)) == 2
    assert mixing.degree(mixing.torus_2d(4, 4)) == 4
    assert mixing.degree(mixing.fully_connected(8)) == 7
