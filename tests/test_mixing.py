"""Gossip-matrix properties (Assumption 7) + the paper's rho examples."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import mixing


@given(st.integers(3, 64))
@settings(max_examples=20, deadline=None)
def test_ring_satisfies_assumption7(n):
    w = mixing.ring(n)
    mixing.check_assumption7(w)


@given(st.integers(2, 8), st.integers(2, 8))
@settings(max_examples=15, deadline=None)
def test_torus_satisfies_assumption7(r, c):
    if r * c < 3:
        return
    w = mixing.torus_2d(r, c)
    mixing.check_assumption7(w)


def test_fully_connected_rho_zero():
    """Paper: W1 = 11^T/N has rho = 0."""
    assert mixing.spectral_rho(mixing.fully_connected(8)) == pytest.approx(
        0.0, abs=1e-9)


def test_disconnected_rho_one():
    """Paper: W3 (disconnected) has rho = 1 -> DSGD does not mix."""
    w = mixing.disconnected(6)
    assert mixing.spectral_rho(w) == pytest.approx(1.0, abs=1e-9)
    with pytest.raises(ValueError):
        mixing.check_assumption7(w)


def test_ring_rho_exact_eigenvalue():
    """Exact: lambda_2 = (1 + 2 cos(2 pi/N)) / 3, i.e. rho ~ 1 - 4pi^2/(3N^2).

    PAPER ERRATUM: the text states rho ~= 1 - 16 pi^2 / (3 N^2); the exact
    eigenvalues of its own W2 give 1 - 4 pi^2 / (3 N^2) (Taylor of the
    cosine). We assert the exact value and record the discrepancy in
    EXPERIMENTS.md.
    """
    for n in (8, 16, 64, 256):
        got = mixing.spectral_rho(mixing.ring(n))
        exact = abs(1 + 2 * np.cos(2 * np.pi / n)) / 3
        assert got == pytest.approx(exact, abs=1e-9)
        taylor = 1 - 4 * np.pi**2 / (3 * n**2)
        assert got == pytest.approx(taylor, abs=30.0 / n**3)
        paper = mixing.ring_rho_paper_estimate(n)
        assert abs(got - paper) > abs(got - taylor)  # the erratum


def test_torus_mixes_faster_than_ring():
    """Beyond-paper: 2-D torus (deg 4) has a larger spectral gap than the
    ring (deg 2) at equal N — the topology lever on Thm 5.2.6's last term."""
    ring_rho = mixing.spectral_rho(mixing.ring(16))
    torus_rho = mixing.spectral_rho(mixing.torus_2d(4, 4))
    assert torus_rho < ring_rho


def test_degree():
    assert mixing.degree(mixing.ring(8)) == 2
    assert mixing.degree(mixing.torus_2d(4, 4)) == 4
    assert mixing.degree(mixing.fully_connected(8)) == 7


def _reconstruct(n, terms):
    rec = np.zeros((n, n))
    for c, perm in terms:
        p = np.eye(n)
        if perm:
            p = np.zeros((n, n))
            for src, dst in perm:
                p[dst, src] = 1.0
        rec += c * p
    return rec


@pytest.mark.parametrize("w", [mixing.ring(8), mixing.torus_2d(2, 4),
                               mixing.torus_2d(3, 3),
                               mixing.fully_connected(6)])
def test_birkhoff_decomposition_reconstructs_w(w):
    """W = sum_k c_k P_k exactly: the lowering GossipMix executes as one
    ppermute per non-identity permutation."""
    terms = mixing.birkhoff_decomposition(w)
    n = w.shape[0]
    np.testing.assert_allclose(_reconstruct(n, terms), w, atol=1e-9)
    assert sum(c for c, _ in terms) == pytest.approx(1.0)
    for c, perm in terms:
        assert c > 0
        if perm:   # full permutation of the axis (ppermute's contract)
            assert sorted(s for s, _ in perm) == list(range(n))
            assert sorted(d for _, d in perm) == list(range(n))


def test_birkhoff_term_count_tracks_degree():
    """Sparse W lowers to few collectives: ring = identity + 2 shifts,
    torus = identity + 4 shifts; W1 needs one term per worker."""
    assert len(mixing.birkhoff_decomposition(mixing.ring(8))) == 3
    assert len(mixing.birkhoff_decomposition(mixing.torus_2d(3, 3))) == 5
    assert len(mixing.birkhoff_decomposition(mixing.fully_connected(6))) == 6


def test_birkhoff_rejects_non_doubly_stochastic():
    with pytest.raises(ValueError):
        mixing.birkhoff_decomposition(np.array([[0.5, 0.2], [0.5, 0.8]]))


def test_near_square_factors():
    assert mixing.near_square_factors(8) == (2, 4)
    assert mixing.near_square_factors(16) == (4, 4)
    assert mixing.near_square_factors(7) == (1, 7)   # prime -> 1-D torus
