"""Fused flat-buffer codec tier (the 'one packed message per exchange'
path): FlatLayout round trips, bucketed kernel equality across backends,
wire-byte savings vs the per-leaf reference, one-payload-per-hop ring
exchanges, and the per-message latency accounting in the cost models."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import communicators as C
from repro.core import compression, eventsim
from repro.kernels.quant import ops as q_ops

KEY = jax.random.PRNGKey(0)
AXIS = "w"


def _mixed_tree(n1=777, n2=95):
    """Mixed shapes/dtypes incl. odd sizes, a scalar, and a bf16 leaf."""
    k = jax.random.PRNGKey(42)
    return {
        "a": jax.random.normal(jax.random.fold_in(k, 0), (n1,)),
        "b": {"w": jax.random.normal(jax.random.fold_in(k, 1), (n2, 3)),
              "bf16": (jax.random.normal(jax.random.fold_in(k, 2), (33,))
                       .astype(jnp.bfloat16)),
              "scalar": jnp.float32(2.5)},
        "c": jax.random.normal(jax.random.fold_in(k, 3), (2, 5, 7)),
    }


def _assert_trees_equal(a, b):
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(
            np.asarray(x, np.float32), np.asarray(y, np.float32)), a, b)


# ------------------------------------------------------------ flat layout ----

@given(st.integers(min_value=1, max_value=4097),
       st.integers(min_value=1, max_value=600))
@settings(max_examples=12, deadline=None)
def test_flat_layout_round_trip(n1, n2):
    """unflatten(flatten(tree)) == tree bit-for-bit on mixed-shape /
    odd-size leaves (incl. bf16 and scalars)."""
    tree = _mixed_tree(n1, n2)
    layout = compression.FlatLayout.from_tree(tree)
    flat = layout.flatten(tree)
    assert flat.shape == (layout.total,) and flat.dtype == jnp.float32
    assert layout.total == sum(
        leaf.size for leaf in jax.tree_util.tree_leaves(tree))
    out = layout.unflatten(flat)
    for l_in, l_out in zip(jax.tree_util.tree_leaves(tree),
                           jax.tree_util.tree_leaves(out)):
        assert l_in.shape == l_out.shape and l_in.dtype == l_out.dtype
        np.testing.assert_array_equal(np.asarray(l_in, np.float32),
                                      np.asarray(l_out, np.float32))


def test_flat_layout_offsets_are_static():
    tree = _mixed_tree()
    layout = compression.FlatLayout.from_tree(tree)
    # offsets are cumulative leaf sizes (the documented offset table)
    assert layout.offsets[0] == 0
    for i in range(1, layout.n_leaves):
        assert layout.offsets[i] == layout.offsets[i - 1] + layout.sizes[i - 1]
    # hashable (usable as static pytree aux / jit cache key)
    assert layout == compression.FlatLayout.from_tree(tree)
    hash(layout)


# --------------------------------------------------- bucketed kernel tier ----

@pytest.mark.parametrize("bits", [8, 4, 2])
@pytest.mark.parametrize("bucket_elems", [2048, 1 << 22])
def test_flat_backends_identical_and_roundtrip_equals_qdq(bits, bucket_elems):
    """Pallas (interpret) and jnp produce identical FlatPacked messages,
    and decode(encode(.)) == qdq(.) bit-for-bit through the fused tier —
    in both the multi-bucket and single-bucket regimes."""
    tree = _mixed_tree()
    pallas = compression.QuantCodec(bits, backend="pallas")
    jnp_ref = compression.QuantCodec(bits, backend="jnp")
    fp_p = pallas.tree_encode_flat(tree, KEY, bucket_elems=bucket_elems)
    fp_j = jnp_ref.tree_encode_flat(tree, KEY, bucket_elems=bucket_elems)
    np.testing.assert_array_equal(fp_p.payload, fp_j.payload)
    np.testing.assert_array_equal(fp_p.params, fp_j.params)
    # geometry: one (lo, scale) row per bucket
    total = compression.FlatLayout.from_tree(tree).total
    _, _, nb, _, rows_kept = q_ops.flat_geometry(
        total, bits=bits, bucket_elems=bucket_elems)
    assert fp_p.params.shape == (nb, 2)
    assert fp_p.payload.shape == (rows_kept, q_ops.LANES)
    # wire path == fused path, across backends
    _assert_trees_equal(pallas.tree_decode_flat(fp_p),
                        jnp_ref.tree_qdq_flat(tree, KEY,
                                              bucket_elems=bucket_elems))
    _assert_trees_equal(pallas.tree_qdq_flat(tree, KEY,
                                             bucket_elems=bucket_elems),
                        jnp_ref.tree_qdq_flat(tree, KEY,
                                              bucket_elems=bucket_elems))


@pytest.mark.parametrize("bits", [8, 4, 2])
def test_bucket_params_match_per_bucket_reference(bits):
    """Each bucket's (lo, scale) row equals the per-leaf jnp reference's
    quant_params of that bucket's element slice — the fused tier is the
    per-leaf quantizer applied per contiguous bucket."""
    from repro.kernels.quant import ref

    tree = _mixed_tree(5000, 300)   # big enough for >1 bucket at all bits
    layout = compression.FlatLayout.from_tree(tree)
    flat = layout.flatten(tree)
    be = 2048
    fp = compression.QuantCodec(bits, backend="jnp").tree_encode_flat(
        tree, KEY, bucket_elems=be)
    _, cap, nb, _, _ = q_ops.flat_geometry(layout.total, bits=bits,
                                           bucket_elems=be)
    assert nb > 1   # exercise the grid-over-buckets path
    for b in range(nb):
        chunk = flat[b * cap: min((b + 1) * cap, layout.total)]
        lo, scale = ref.quant_params(chunk, bits)
        # lo is a pure min -> exact; scale may differ by 1 ulp between the
        # eager reference and the fused jit (XLA divide-by-constant), which
        # is why backend equality (above) is asserted WITHIN one trace
        np.testing.assert_array_equal(fp.params[b, 0], lo)
        np.testing.assert_allclose(fp.params[b, 1], scale, rtol=1e-6)


def test_flat_qdq_unbiased():
    """E[Q(x)] = x holds through the bucketed path (Assumption 3)."""
    cdc = compression.codec("rq4")
    x = jax.random.normal(KEY, (300,))
    keys = jax.random.split(jax.random.PRNGKey(1), 600)
    qs = jax.vmap(lambda k: cdc.flat_qdq(x, k, bucket_elems=128))(keys)
    assert float(jnp.abs(qs.mean(0) - x).max()) < 0.6


# -------------------------------------------------------------- wire bytes ---

@pytest.mark.parametrize("name,bits", [("rq8", 8), ("rq4", 4), ("rq2", 2)])
def test_fused_wire_bytes_beat_per_leaf(name, bits):
    """Fused pays <= 1 pad granule + one 8B params row per bucket; the
    per-leaf path pays up to one granule + one row per LEAF. Asserted
    against the exact wire-format arithmetic."""
    tree = {f"l{i}": jnp.zeros((100 + 13 * i,), jnp.float32)
            for i in range(40)}
    cdc = compression.codec(name)
    fused = cdc.tree_wire_bytes_flat(tree)
    per_leaf = cdc.tree_wire_bytes(tree)
    assert fused < per_leaf
    total = sum(leaf.size for leaf in jax.tree_util.tree_leaves(tree))
    pack = 8 // bits
    granule = pack * 512
    _, _, nb, _, rows_kept = q_ops.flat_geometry(total, bits=bits)
    # exact: fused = kept payload rows + one params row per bucket
    assert fused == rows_kept * 512 + nb * 8
    # bound: whole-tree payload <= ideal + ONE pad granule's bytes
    assert fused <= total * bits / 8 + granule * bits / 8 + nb * 8
    # per-leaf = sum of per-leaf granule-padded payloads + L headers
    want_leafwise = sum(
        -(-leaf.size // granule) * 512 + 8
        for leaf in jax.tree_util.tree_leaves(tree))
    assert per_leaf == want_leafwise


def test_repro_100m_fused_wire_bytes_strictly_lower():
    """Acceptance: measured wire bytes for the repro-100m gradient tree
    are strictly lower fused than per-leaf, by exactly the padding +
    params-header savings (eval_shape only — nothing is allocated)."""
    from repro import configs
    from repro.models import transformer

    cfg = configs.get_config("repro-100m")
    grads = jax.eval_shape(
        lambda: transformer.init(cfg, jax.random.PRNGKey(0)))
    leaves = jax.tree_util.tree_leaves(grads)
    total = sum(leaf.size for leaf in leaves)
    for name, bits in (("rq8", 8), ("rq4", 4), ("rq2", 2)):
        cdc = compression.codec(name)
        fused = cdc.tree_wire_bytes_flat(grads)
        per_leaf = cdc.tree_wire_bytes(grads)
        assert fused < per_leaf
        # the saving is exactly (per-leaf padding - fused padding) +
        # (L - n_buckets) params headers
        granule = (8 // bits) * 512
        _, _, nb, _, rows_kept = q_ops.flat_geometry(total, bits=bits)
        leaf_rows = sum(-(-leaf.size // granule) for leaf in leaves)
        pad_saving = (leaf_rows - rows_kept) * 512
        header_saving = (len(leaves) - nb) * 8
        assert per_leaf - fused == pad_saving + header_saving
        assert header_saving > 0   # far fewer params rows than leaves


# --------------------------------------------------------- fused exchanges ---

def _count_ppermute_calls(fn, *args):
    """Trace fn and count lax.ppermute call sites (the fori_loop hop body
    traces exactly once, so this is arrays shipped per hop)."""
    from jax import lax

    calls = {"n": 0}
    real = lax.ppermute

    def counting(x, axis_name, perm):
        calls["n"] += 1
        return real(x, axis_name, perm)

    C.lax.ppermute = counting
    try:
        jax.make_jaxpr(fn)(*args)
    finally:
        C.lax.ppermute = real
    return calls["n"]


def test_ring_ships_one_packed_payload_per_hop():
    """Per-hop array counts are leaf-count independent on both fused
    tiers: the partitioned ring ppermutes one partition payload + one
    partition header in EACH of its two phases (reduce-scatter +
    all-gather = 4 call sites); the monolithic chain ships one FlatPacked
    (2 call sites); the per-leaf reference ships 2 arrays per leaf."""
    n = 4
    tree = {f"l{i}": jax.random.normal(jax.random.fold_in(KEY, i),
                                       (n, 17 + i)) for i in range(5)}
    key = jax.random.PRNGKey(1)

    def run(ex):
        return lambda g: jax.vmap(
            lambda gg: ex(gg, (), key, axis_name=AXIS)[0],
            axis_name=AXIS)(g)

    partitioned = _count_ppermute_calls(
        run(C.CSGDRingExchange(compressor="rq4")), tree)
    assert partitioned == 4    # (payload, params) x two phases
    mono = _count_ppermute_calls(
        run(C.CSGDRingExchange(compressor="rq4", partitioned=False)), tree)
    assert mono == 2           # one payload + one (n_buckets, 2) header
    per_leaf = _count_ppermute_calls(
        run(C.CSGDRingExchange(compressor="rq4", flat=False)), tree)
    assert per_leaf == 2 * 5   # one (payload, params) pair per leaf


def test_csgd_ring_monolithic_matches_manual_flat_chain():
    """The monolithic chain (partitioned=False: ONE FlatPacked through
    ppermute, N-1 full hops) equals the flat-qdq chain formulation,
    because flat decode(encode(.)) == flat qdq. This is the reference
    the partitioned tier's per-partition chains are compared against —
    both satisfy Eq. (3.3)'s recursion, with different nesting orders."""
    n = 4
    g = {"a": jax.random.normal(KEY, (n, 33)),
         "b": jax.random.normal(jax.random.fold_in(KEY, 9), (n, 7, 5))}
    key = jax.random.PRNGKey(1)
    ex = C.CSGDRingExchange(compressor="rq4", partitioned=False)
    out, _ = jax.vmap(lambda gg: ex(gg, (), key, axis_name=AXIS),
                      axis_name=AXIS)(g)

    cdc = compression.codec("rq4")
    gi = lambda i: jax.tree_util.tree_map(lambda leaf: leaf[i], g)
    layout = compression.FlatLayout.from_tree(gi(0))
    accs = [cdc.flat_qdq(layout.flatten(gi(i)), jax.random.fold_in(key, i))
            for i in range(n)]
    for h in range(1, n):
        prev = list(accs)
        accs = [cdc.flat_qdq(
            prev[(i - 1) % n] + layout.flatten(gi(i)),
            jax.random.fold_in(jax.random.fold_in(key, i), h))
            for i in range(n)]
    for i in range(n):
        expect = layout.unflatten(accs[i] / n)
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_allclose(
                np.asarray(a)[i], np.asarray(b), rtol=1e-6, atol=1e-6),
            out, expect)


# ------------------------------------------------- partitioned ring tier ----

def _partition_reference_chains(tree, key, n, codec="rq4"):
    """Eq. (3.3) applied per partition: partition p's chain starts at
    worker p (key fold_in(key, p)) and is requantized at each of the
    n-1 downstream workers (key fold_in(fold_in(key, w), h)). Returns
    the (n, part_elems) finished partitions and the layout."""
    from repro.kernels.quant import ops as q

    cdc = compression.codec(codec)
    gi = lambda i: jax.tree_util.tree_map(lambda leaf: leaf[i], tree)
    layout = compression.FlatLayout.from_tree(gi(0))
    pe, _, _ = cdc.partition_geometry(layout.total, n)
    gparts = [np.asarray(q.edge_pad(layout.flatten(gi(i)),
                                    n * pe)).reshape(n, pe)
              for i in range(n)]
    final = np.zeros((n, pe), np.float32)
    for p in range(n):
        acc = cdc.flat_qdq(jnp.asarray(gparts[p][p]),
                           jax.random.fold_in(key, p))
        for h in range(1, n):
            w = (p + h) % n
            acc = cdc.flat_qdq(acc + jnp.asarray(gparts[w][p]),
                               jax.random.fold_in(
                                   jax.random.fold_in(key, w), h))
        final[p] = np.asarray(acc)
    return final, layout, pe


def test_partitioned_ring_chains_bit_exact_and_verbatim():
    """Acceptance for the partitioned ring: (a) every partition equals
    the per-partition reference chain BIT-FOR-BIT on that slice —
    Figure 3.3's chains, built from the same flat_qdq the monolithic
    reference uses; (b) the all-gather ships finished partitions
    verbatim, so all workers end bit-identical (no re-quantization
    drift) — unlike the monolithic chain's per-worker nesting orders."""
    n = 4
    tree = {"a": jax.random.normal(KEY, (n, 33)),
            "b": jax.random.normal(jax.random.fold_in(KEY, 9), (n, 7, 5))}
    key = jax.random.PRNGKey(1)
    ex = C.CSGDRingExchange(compressor="rq4")
    out, _ = jax.vmap(lambda gg: ex(gg, (), key, axis_name=AXIS),
                      axis_name=AXIS)(tree)

    # (b) verbatim all-gather: bit-identical result on every worker
    for leaf in jax.tree_util.tree_leaves(out):
        for i in range(1, n):
            np.testing.assert_array_equal(np.asarray(leaf[0]),
                                          np.asarray(leaf[i]))

    # (a) per-partition chains, bit-for-bit
    final, layout, pe = _partition_reference_chains(tree, key, n)
    expect = layout.unflatten(
        jnp.asarray(final.reshape(-1)[: layout.total] / n))
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a)[0], np.asarray(b)), out, expect)


def test_partitioned_roundtrip_equals_qdq_per_bucket_both_backends():
    """decode(encode(x)) == qdq(x) per bucket holds through the
    partitioned path on both backends, and the two backends produce
    identical PartitionedFlatPacked bits."""
    from repro.kernels.quant import ops as q

    tree = _mixed_tree(5000, 300)
    n_parts = 4
    for bits in (8, 4, 2):
        pallas = compression.QuantCodec(bits, backend="pallas")
        jnp_ref = compression.QuantCodec(bits, backend="jnp")
        pp = pallas.tree_encode_partitioned(tree, KEY, n_parts,
                                            bucket_elems=2048)
        pj = jnp_ref.tree_encode_partitioned(tree, KEY, n_parts,
                                             bucket_elems=2048)
        np.testing.assert_array_equal(pp.payload, pj.payload)
        np.testing.assert_array_equal(pp.params, pj.params)
        # per-partition decode == per-partition qdq (same fold_in keys)
        layout = compression.FlatLayout.from_tree(tree)
        pe = pp.part_elems
        padded = q.edge_pad(layout.flatten(tree), n_parts * pe)
        dec = pallas.flat_decode_partitioned(pp)
        for p in range(n_parts):
            want = q.qdq_flat(padded[p * pe:(p + 1) * pe],
                              jax.random.fold_in(KEY, p), bits=bits,
                              bucket_elems=2048, backend="jnp")
            got = np.asarray(dec[p * pe:min((p + 1) * pe, layout.total)])
            np.testing.assert_array_equal(got,
                                          np.asarray(want)[:got.shape[0]])


def test_partitioned_ring_wire_bytes_bandwidth_optimal():
    """Acceptance: per-worker wire bytes = 2*M*(N-1)/N within one pad
    granule (+ params rows) per partition, exactly reproducible from the
    partition geometry, and strictly below the monolithic (N-1)*M."""
    from repro.kernels.quant import ops as q

    tree = {f"l{i}": jnp.zeros((3000 + 13 * i,), jnp.float32)
            for i in range(25)}
    total = sum(leaf.size for leaf in jax.tree_util.tree_leaves(tree))
    for name, bits in (("rq8", 8), ("rq4", 4), ("rq2", 2)):
        for n in (2, 4, 8):
            ex = C.CSGDRingExchange(compressor=name)
            got = ex.message_bytes(tree, n_workers=n)
            pe, nb_p, rows_p = q.partition_geometry(total, n, bits=bits)
            # exact, from the geometry
            assert got == 2 * (n - 1) * (rows_p * 512 + nb_p * 8)
            # bandwidth-optimal bound: ideal payload 2*M*(n-1)/n, plus at
            # most one pad granule (512 payload B) + header per partition
            ideal = 2 * (n - 1) / n * (total * bits / 8)
            assert got >= ideal
            assert got <= ideal + 2 * (n - 1) * (512 + nb_p * 8)
            # strictly below the monolithic chain for n > 2
            mono = C.CSGDRingExchange(
                compressor=name, partitioned=False).message_bytes(
                    tree, n_workers=n)
            if n > 2:
                assert got < mono
            assert ex.n_wire_messages(n) == 2 * (n - 1)


def test_flat_layout_from_tree_is_cached():
    """Satellite: FlatLayout.from_tree memoizes on (treedef, shapes,
    dtypes) — repeat calls return the SAME object instead of rebuilding
    the offset table every trace."""
    tree = _mixed_tree()
    l1 = compression.FlatLayout.from_tree(tree)
    l2 = compression.FlatLayout.from_tree(tree)
    assert l1 is l2
    # different static structure -> different layout
    other = {"x": jnp.zeros((7,))}
    assert compression.FlatLayout.from_tree(other) is not l1


def _jaxpr_primitives(closed) -> set:
    acc = set()

    def rec(jaxpr):
        for e in jaxpr.eqns:
            acc.add(e.primitive.name)
            for v in e.params.values():
                if hasattr(v, "eqns"):
                    rec(v)
                elif hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns"):
                    rec(v.jaxpr)

    rec(closed.jaxpr)
    return acc


def test_fused_encode_jaxpr_has_no_concatenate():
    """Acceptance: the whole fused pipeline — flatten, stats, encode,
    qdq, decode — contains NO concatenate op anywhere in its jaxpr; head
    and tail are single-buffer dynamic_update_slice writes. (This is the
    op-count form of the perf assertion: the PR-2 regression came from
    flatten->concatenate->pad->re-concatenate materializing the buffer
    several times per encode.)"""
    tree = _mixed_tree(5000, 300)
    layout = compression.FlatLayout.from_tree(tree)
    key = KEY
    for backend in ("jnp", "pallas"):
        cdc = compression.QuantCodec(4, backend=backend)

        enc = jax.make_jaxpr(
            lambda t, k: cdc.tree_encode_flat(t, k, bucket_elems=2048))(
                tree, key)
        prims = _jaxpr_primitives(enc)
        assert "concatenate" not in prims, sorted(prims)
        assert "dynamic_update_slice" in prims

        qdq = jax.make_jaxpr(
            lambda t, k: cdc.tree_qdq_flat(t, k, bucket_elems=2048))(
                tree, key)
        assert "concatenate" not in _jaxpr_primitives(qdq)

        fp = cdc.tree_encode_flat(tree, key, bucket_elems=2048)
        dec = jax.make_jaxpr(cdc.tree_decode_flat)(fp)
        assert "concatenate" not in _jaxpr_primitives(dec)


@pytest.mark.skipif(not os.environ.get("RUN_PERF_TESTS"),
                    reason="timing on CI CPU is too noisy — the jaxpr "
                           "op-count test above is the CI-stable form; "
                           "set RUN_PERF_TESTS=1 to run")
def test_fused_steady_state_not_slower_than_per_leaf():
    """Satellite (timing form): fused steady-state tree-encode is no
    slower than per-leaf on the repro-100m gradient tree — the PR-2
    flat-path regression stays dead. BENCH_kernels.json carries the
    committed measurement (flat_vs_perleaf_speedup >= 1)."""
    import time

    from benchmarks.kernels_bench import _grad_tree

    grads = _grad_tree(smoke=True)
    cdc = compression.codec("rq8")
    key = KEY

    def best_of(fn, k=3):
        jax.block_until_ready(fn())      # warm-up / compile
        best = float("inf")
        for _ in range(k):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best = min(best, time.perf_counter() - t0)
        return best

    t_leaf = best_of(lambda: cdc.tree_encode(grads, key))
    t_flat = best_of(lambda: cdc.tree_encode_flat(grads, key))
    assert t_flat <= t_leaf * 1.1   # 10% noise floor


def test_ecsgd_flat_state_is_single_buffer():
    """flat=True carries ONE flat fp32 residual per side, and the Lemma
    3.4.1 recursion still holds on a multi-leaf tree."""
    n = 4
    params = {"a": jnp.zeros((24,)), "b": jnp.zeros((3, 5))}
    ex = C.ECSGDExchange(compressor="sign1")
    state = ex.init(params)
    total = compression.FlatLayout.from_tree(params).total
    assert state["worker_err"].shape == (total,)
    assert state["server_err"].shape == (total,)

    # Lemma 3.4.1 on the flat recursion: x~ follows plain averaged SGD
    lr, steps = 0.1, 5
    key = jax.random.PRNGKey(0)
    state = jax.vmap(ex.init)(
        jax.tree_util.tree_map(
            lambda p: jnp.broadcast_to(p[None], (n,) + p.shape), params))
    layout = compression.FlatLayout.from_tree(params)
    x = jnp.zeros((total,))
    x_tilde = x.copy()
    for t in range(steps):
        g = jax.random.normal(jax.random.fold_in(key, t), (n, total))
        gtree = jax.vmap(layout.unflatten)(g)
        out, state = jax.vmap(
            lambda gg, s: ex(gg, s, jax.random.fold_in(key, 100 + t),
                             axis_name=AXIS), axis_name=AXIS)(gtree, state)
        out0 = layout.flatten(
            jax.tree_util.tree_map(lambda leaf: leaf[0], out))
        x = x - lr * out0
        omega = state["server_err"][0] + state["worker_err"].mean(0)
        x_tilde = x_tilde - lr * g.mean(0)
        np.testing.assert_allclose(x - lr * omega, x_tilde, rtol=1e-4,
                                   atol=1e-5)


def test_make_exchange_gossip_registered():
    """Satellite: make_exchange('gossip', topology=...) works like every
    other pattern."""
    assert "gossip" in C.EXCHANGES
    gm = C.make_exchange("gossip", topology="full")
    assert isinstance(gm, C.GossipMix) and gm.topology == "full"
    n = 4
    x = jax.random.normal(KEY, (n, 6))
    mixed = jax.vmap(lambda xi: gm(xi, axis_name=AXIS), axis_name=AXIS)(x)
    np.testing.assert_allclose(
        np.asarray(mixed), np.broadcast_to(np.asarray(x).mean(0), (n, 6)),
        rtol=1e-5)
    ring = C.make_exchange("gossip", topology="ring")
    assert ring.topology == "ring"


def test_exchange_message_bytes_fused_lower_on_multi_leaf_tree():
    """Default (flat) exchanges report the fused message size, strictly
    below the per-leaf reference on a many-leaf tree."""
    tree = {f"l{i}": jnp.zeros((1000 + i,), jnp.float32) for i in range(20)}
    for flat_ex, leaf_ex in [
            (C.CSGDRingExchange(compressor="rq4"),
             C.CSGDRingExchange(compressor="rq4", flat=False)),
            (C.CSGDPSExchange(compressor="rq4"),
             C.CSGDPSExchange(compressor="rq4", flat=False)),
            (C.ECSGDExchange(compressor="rq4"),
             C.ECSGDExchange(compressor="rq4", flat=False))]:
        assert flat_ex.message_bytes(tree, n_workers=4) < \
            leaf_ex.message_bytes(tree, n_workers=4)
    # non-packable codec: ONE spec header instead of one per leaf
    sign = compression.codec("sign1")
    total = sum(l.size for l in jax.tree_util.tree_leaves(tree))
    assert sign.tree_wire_bytes_flat(tree) == \
        sign.spec.compressed_bytes(total)
    assert sign.tree_wire_bytes_flat(tree) < sign.tree_wire_bytes(tree)


# ------------------------------------------------------ cost-model users -----

def test_eventsim_per_message_latency_accounting():
    """n_messages multiplies the latency term only (transfer bytes are
    unchanged): the fused-vs-per-leaf gap is 2(n-1)(L-1) t_lat on the
    ring — the paper's §1.3 argument, now measurable."""
    n, lat, tr, size, L = 8, 1e-3, 1e-2, 100.0, 110
    fused = eventsim.ring_allreduce_makespan(n, size, t_lat=lat, t_tr=tr,
                                             n_messages=1)
    leafwise = eventsim.ring_allreduce_makespan(n, size, t_lat=lat,
                                                t_tr=tr, n_messages=L)
    assert leafwise - fused == pytest.approx(2 * (n - 1) * (L - 1) * lat)
    # transfer term identical
    assert fused - 2 * (n - 1) * lat == pytest.approx(
        leafwise - 2 * (n - 1) * L * lat)
    # same semantics in the discrete-event simulator itself
    d1 = eventsim.simulate([eventsim.Msg(0.0, 0, 1, size, "m", 1)],
                           t_lat=lat, t_tr=tr)
    dL = eventsim.simulate([eventsim.Msg(0.0, 0, 1, size, "m", L)],
                           t_lat=lat, t_tr=tr)
    assert dL.makespan - d1.makespan == pytest.approx((L - 1) * lat)
    # and in the PS / multi-PS / decentralized closed forms
    for fn in (eventsim.single_ps_makespan, eventsim.multi_ps_makespan,
               eventsim.decentralized_makespan):
        assert fn(n, size, t_lat=lat, t_tr=tr, n_messages=L) > \
            fn(n, size, t_lat=lat, t_tr=tr, n_messages=1)


def test_table1_1_fused_vs_per_leaf_block():
    """The benchmark's three-tier ring comparison exposes the per-message
    latency gap, the wire-byte saving, AND the partitioned tier's
    2M(N-1)/N accounting on a real gradient tree."""
    from benchmarks.table1_1 import fused_vs_per_leaf

    n = 8
    f = fused_vs_per_leaf(n_workers=n)
    assert f["n_leaves"] > 50
    assert f["fused_bytes"] < f["per_leaf_bytes"]
    # monolithic chains: n-1 hops, per-leaf pays (L-1) extra t_lat each
    assert f["latency_gap_s"] == pytest.approx(
        (n - 1) * (f["n_leaves"] - 1) * 1e-3)
    # acceptance: partitioned per-worker wire bytes == 2(n-1) partition
    # messages == 2*M*(n-1)/n up to one pad granule + header/partition,
    # and the table reports 2(n-1) wire messages per iteration
    assert f["n_wire_messages"] == 2 * (n - 1)
    assert f["partitioned_wire_bytes"] == \
        2 * (n - 1) * f["partitioned_part_bytes"]
    ideal = 2 * (n - 1) / n * (f["size_mb"] * 1e6 / 8)   # rq4: bits/8=0.5
    assert ideal <= f["partitioned_wire_bytes"] <= ideal * 1.01
    assert f["partitioned_wire_bytes"] < f["mono_wire_bytes"]
    assert f["partitioned_makespan_s"] < f["fused_makespan_s"]
