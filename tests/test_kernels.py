"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn import ops as fa_ops, ref as fa_ref
from repro.kernels.quant import ops as q_ops, ref as q_ref
from repro.kernels.wkv6 import ops as wkv_ops, ref as wkv_ref

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------- quant ----

@pytest.mark.parametrize("shape", [(1000,), (17, 300), (4, 128, 65)])
@pytest.mark.parametrize("bits", [2, 4, 8])
def test_quant_kernel_matches_oracle(shape, bits):
    x = jax.random.normal(jax.random.fold_in(KEY, hash(shape) % 997), shape)
    out = q_ops.quantize_dequantize(x, KEY, bits=bits, backend="pallas")
    lo, scale = q_ref.quant_params(x, bits)
    x2d = q_ops._to_2d(x, multiple=8 // bits)
    u = jax.random.uniform(KEY, x2d.shape, jnp.float32)
    expect = q_ref.decode(q_ref.encode(x2d, u, lo, scale, bits=bits),
                          lo, scale).reshape(-1)[:x.size].reshape(shape)
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bits", [2, 4, 8])
def test_quant_encode_decode_roundtrip(dtype, bits):
    x = (jax.random.normal(KEY, (513,)) * 2).astype(dtype)
    payload, params = q_ops.encode(x, KEY, bits=bits)
    assert payload.dtype == jnp.uint8
    # sub-byte packing: 8 // bits codes per payload byte
    assert payload.size * (8 // bits) >= x.size
    dec = q_ops.decode(payload, params, shape=(513,), bits=bits,
                       dtype=jnp.float32)
    tol = {8: 0.1, 4: 1.0, 2: 4.0}[bits]
    assert float(jnp.abs(dec - x.astype(jnp.float32)).max()) < tol


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_quant_packed_backends_bit_identical(bits):
    """pallas (interpret off-TPU) and the jnp reference produce the same
    payload, the same decode, and decode(encode(x)) == qdq(x)."""
    x = jax.random.normal(KEY, (1000,))
    qd = q_ops.quantize_dequantize(x, KEY, bits=bits, backend="jnp")
    pay_p, par_p = q_ops.encode(x, KEY, bits=bits, backend="pallas")
    pay_j, par_j = q_ops.encode(x, KEY, bits=bits, backend="jnp")
    np.testing.assert_array_equal(pay_p, pay_j)
    np.testing.assert_array_equal(par_p, par_j)
    dec = q_ops.decode(pay_p, par_p, shape=(1000,), bits=bits,
                       backend="pallas")
    np.testing.assert_array_equal(dec, qd)


def test_quant_kernel_unbiased():
    x = jax.random.normal(KEY, (2048,))
    qs = jax.vmap(lambda k: q_ops.quantize_dequantize(x, k, bits=4))(
        jax.random.split(KEY, 300))
    assert float(jnp.abs(qs.mean(0) - x).max()) < 0.1


# ----------------------------------------------------------- flash_attn ----

@pytest.mark.parametrize(
    "b,s,hq,hkv,d,causal,window,cap",
    [(2, 256, 4, 2, 64, True, 0, 0.0),
     (1, 128, 8, 1, 128, True, 0, 0.0),
     (2, 200, 4, 4, 64, True, 64, 0.0),       # window + pad
     (1, 256, 4, 2, 64, True, 0, 30.0),       # softcap (grok)
     (1, 192, 4, 2, 64, False, 0, 0.0),       # non-causal (encoder)
     (2, 96, 2, 2, 32, True, 0, 0.0)])
def test_flash_attention_matches_oracle(b, s, hq, hkv, d, causal, window,
                                        cap):
    kq, kk, kv = jax.random.split(jax.random.fold_in(KEY, s * hq), 3)
    q = jax.random.normal(kq, (b, s, hq, d), jnp.float32)
    k = jax.random.normal(kk, (b, s, hkv, d), jnp.float32)
    v = jax.random.normal(kv, (b, s, hkv, d), jnp.float32)
    out = fa_ops.flash_attention(q, k, v, causal=causal, window=window,
                                 softcap=cap)
    exp = fa_ref.attention(q, k, v, causal=causal, window=window,
                           softcap=cap)
    np.testing.assert_allclose(out, exp, rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    q = jax.random.normal(KEY, (1, 128, 4, 64), jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 128, 2, 64),
                          jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 128, 2, 64),
                          jnp.bfloat16)
    out = fa_ops.flash_attention(q, k, v, causal=True)
    exp = fa_ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               exp.astype(jnp.float32), rtol=0.05, atol=0.05)


# ----------------------------------------------------------------- wkv6 ----

@pytest.mark.parametrize("b,s,h,dk", [(2, 128, 2, 64), (1, 100, 4, 32),
                                      (2, 192, 1, 64)])
def test_wkv6_kernel_matches_recurrence(b, s, h, dk):
    ks = jax.random.split(jax.random.fold_in(KEY, s * h), 5)
    r = jax.random.normal(ks[0], (b, s, h, dk)) * 0.5
    k = jax.random.normal(ks[1], (b, s, h, dk)) * 0.5
    v = jax.random.normal(ks[2], (b, s, h, dk)) * 0.5
    lw = -jnp.exp(jax.random.normal(ks[3], (b, s, h, dk)) * 0.5 - 2.0)
    u = jax.random.normal(ks[4], (h, dk)) * 0.1
    s0 = jax.random.normal(jax.random.fold_in(KEY, 9), (b, h, dk, dk)) * 0.1
    out_k, st_k = wkv_ops.wkv6(r, k, v, lw, u, state0=s0)
    out_s, st_s = wkv_ref.wkv6_stepwise(r, k, v, lw, u, state0=s0)
    np.testing.assert_allclose(out_k, out_s, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(st_k, st_s, rtol=1e-4, atol=1e-4)


def test_wkv6_chunked_oracle_matches_recurrence():
    b, s, h, dk = 1, 96, 2, 32
    ks = jax.random.split(KEY, 5)
    r, k, v = (jax.random.normal(ks[i], (b, s, h, dk)) * 0.5
               for i in range(3))
    lw = -jnp.exp(jax.random.normal(ks[3], (b, s, h, dk)) * 0.3 - 2.5)
    u = jax.random.normal(ks[4], (h, dk)) * 0.1
    out_c, st_c = wkv_ref.wkv6(r, k, v, lw, u, chunk=32)
    out_s, st_s = wkv_ref.wkv6_stepwise(r, k, v, lw, u)
    np.testing.assert_allclose(out_c, out_s, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(st_c, st_s, rtol=1e-4, atol=1e-4)


# ----------------------------------------------- flash_attn: skip grid ----

def test_flash_skip_grid_prunes_masked_blocks():
    """Fully masked k-blocks are ABSENT from the grid, not predicated out:
    the causal pair table is the lower block-triangle, the windowed one a
    block-band, and both are strictly smaller than the full n_q * n_k
    grid the non-skipping kernel executes."""
    from repro.kernels.flash_attn.kernel import skip_grid
    full = skip_grid(1024, 128, 128, causal=False, window=0, s_valid=1024)
    assert full.shape[1] == 8 * 8
    causal = skip_grid(1024, 128, 128, causal=True, window=0, s_valid=1024)
    assert causal.shape[1] == 8 * 9 // 2          # lower block-triangle
    assert causal.shape[1] < full.shape[1]
    band = skip_grid(1024, 128, 128, causal=True, window=128, s_valid=1024)
    assert band.shape[1] == 8 + 7                 # diagonal + one off-band
    tail = skip_grid(1024, 128, 128, causal=False, window=0, s_valid=300)
    assert tail.shape[1] == 8 * 3                 # k-blocks past s_valid cut
    # first/last flags mark each q-block's k-run for scratch init/flush
    for maps in (full, causal, band, tail):
        qi, _, first, last = maps
        for qb in np.unique(qi):
            run = np.flatnonzero(qi == qb)
            assert first[run[0]] == 1 and last[run[-1]] == 1
            assert first[run[1:]].sum() == 0 and last[run[:-1]].sum() == 0


@pytest.mark.parametrize("s,causal,window",
                         [(300, True, 0),        # tail: 300 % 128 != 0
                          (300, True, 64),       # window + tail blocks
                          (200, False, 0),       # non-causal tail
                          (1024, True, 256)])    # banded, aligned
def test_flash_skip_matches_full_grid(s, causal, window):
    """Skip-grid output is BIT-identical to the non-skipping kernel (the
    dropped tiles contribute exactly nothing) and fp32-close to the jnp
    reference — including seq lens that are not block multiples."""
    kq, kk, kv = jax.random.split(jax.random.fold_in(KEY, s + window), 3)
    q = jax.random.normal(kq, (1, s, 4, 64), jnp.float32)
    k = jax.random.normal(kk, (1, s, 2, 64), jnp.float32)
    v = jax.random.normal(kv, (1, s, 2, 64), jnp.float32)
    kw = dict(causal=causal, window=window, block_q=128, block_k=128)
    out_skip = fa_ops.flash_attention(q, k, v, skip=True, **kw)
    out_full = fa_ops.flash_attention(q, k, v, skip=False, **kw)
    np.testing.assert_array_equal(out_skip, out_full)
    exp = fa_ref.attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(out_skip, exp, rtol=2e-5, atol=2e-5)


# ------------------------------------------ quant: fused ring hop (DAE) ----

@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("bits", [2, 4, 8])
def test_decode_add_encode_fused_equals_sequential(bits, backend):
    """The ONE-dispatch fused ring hop == decode; add; encode, bit for
    bit, per bucket, on both backends (granule-aligned multi-bucket
    buffer — the ring-partition regime)."""
    pack = 8 // bits
    total = 2048 * 2 + pack * 512          # 2 full buckets + short tail
    kx, kl = jax.random.split(jax.random.fold_in(KEY, bits), 2)
    x = jax.random.normal(kx, (total,))
    local = jax.random.normal(kl, (total,))
    ekey, hkey = jax.random.PRNGKey(5), jax.random.PRNGKey(6)
    pay, prm = q_ops.encode_flat(x, ekey, bits=bits, bucket_elems=2048,
                                 backend=backend)
    dec = q_ops.decode_flat(pay, prm, total=total, bits=bits,
                            bucket_elems=2048, backend=backend)
    want_pay, want_prm = q_ops.encode_flat(dec + local, hkey, bits=bits,
                                           bucket_elems=2048,
                                           backend=backend)
    got_pay, got_prm = q_ops.decode_add_encode_flat(
        pay, prm, local, hkey, bits=bits, bucket_elems=2048,
        backend=backend)
    np.testing.assert_array_equal(got_pay, want_pay)
    np.testing.assert_array_equal(got_prm, want_prm)


def test_decode_add_encode_backends_bit_identical():
    total = 2048 + 512
    x = jax.random.normal(KEY, (total,))
    local = jax.random.normal(jax.random.fold_in(KEY, 3), (total,))
    pay, prm = q_ops.encode_flat(x, KEY, bits=8, bucket_elems=2048,
                                 backend="jnp")
    outs = [q_ops.decode_add_encode_flat(pay, prm, local,
                                         jax.random.PRNGKey(9), bits=8,
                                         bucket_elems=2048, backend=be)
            for be in ("jnp", "pallas")]
    np.testing.assert_array_equal(outs[0][0], outs[1][0])
    np.testing.assert_array_equal(outs[0][1], outs[1][1])


def test_decode_add_encode_unaligned_fallback():
    """Non-granule-aligned totals take the sequential composition path
    and still match it exactly."""
    total = 3001                            # not a multiple of 512
    x = jax.random.normal(KEY, (total,))
    local = jax.random.normal(jax.random.fold_in(KEY, 7), (total,))
    hkey = jax.random.PRNGKey(4)
    pay, prm = q_ops.encode_flat(x, KEY, bits=8, bucket_elems=2048,
                                 backend="jnp")
    dec = q_ops.decode_flat(pay, prm, total=total, bits=8,
                            bucket_elems=2048, backend="jnp")
    want = q_ops.encode_flat(dec + local, hkey, bits=8, bucket_elems=2048,
                             backend="jnp")
    got = q_ops.decode_add_encode_flat(pay, prm, local, hkey, bits=8,
                                       bucket_elems=2048, backend="jnp")
    np.testing.assert_array_equal(got[0], want[0])
    np.testing.assert_array_equal(got[1], want[1])


def test_encode_partitioned_blocked_matches_vmapped_reference():
    """The cache-blocked from-leaves partitioned encode (the jnp tier of
    tree_encode_partitioned) is bit-identical to the vmapped
    flatten-then-encode reference — same fold_in(key, p) partition keys,
    same per-bucket draws, same edge-pad semantics."""
    from repro.core import compression as C
    tree = {"a": jax.random.normal(KEY, (300,)),
            "b": jax.random.normal(jax.random.fold_in(KEY, 1), (7, 11)),
            "c": jax.random.normal(jax.random.fold_in(KEY, 2), (1024,))}
    layout = C.FlatLayout.from_tree(tree)
    key = jax.random.PRNGKey(11)
    for n_parts, be in ((4, 2048), (8, 2048)):
        part_elems, _, _ = q_ops.partition_geometry(layout.total, n_parts,
                                                    bits=8,
                                                    bucket_elems=be)
        want = C._encode_partitions(layout.flatten(tree), key,
                                    n_parts=n_parts,
                                    part_elems=part_elems, bits=8,
                                    bucket_elems=be, backend="jnp")
        got = jax.jit(q_ops.encode_partitioned_blocked,
                      static_argnames=("offsets", "total", "n_parts",
                                      "bits", "bucket_elems"))(
            jax.tree_util.tree_leaves(tree), offsets=layout.offsets,
            total=layout.total, key=key, n_parts=n_parts, bits=8,
            bucket_elems=be)
        np.testing.assert_array_equal(want[0], got[0])
        np.testing.assert_array_equal(want[1], got[1])
