"""Convergence-claim validation on the quadratic testbed (Tables 1.1/1.2,
Theorems 1.1.1-5.2.6). These are the paper's own experiments in miniature;
EXPERIMENTS.md §Claims summarizes the numbers."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import parallel


def final_gnorm(res, k=20):
    return float(res.grad_norms[-k:].mean())


def test_gd_converges_to_stationary_point():
    """Thm 1.1.1: averaged grad norm -> 0 at rate ~ L/T."""
    res = parallel.run_quadratic("gd", steps=400, lr=0.5)
    g = np.asarray(res.grad_norms)
    assert g[-1] < 1e-3 * g[0]
    # 1/T rate: halving error needs ~2x steps (monotone decrease suffices
    # as a sanity proxy on a strongly-convex quadratic)
    assert np.all(np.diff(g[10:]) <= 1e-9)


def test_sgd_noise_floor_vs_minibatch():
    """Eq. (1.20): minibatching divides the variance term by B. The floor
    gamma*L*sigma^2/B only separates from numerical residue at a healthy
    learning rate (the testbed's L ~ (1+sqrt(d/M))^2/d ~ 0.04)."""
    sgd = parallel.run_quadratic("sgd", steps=600, lr=0.3, batch=1, seed=1)
    mb = parallel.run_quadratic("mbsgd", n_workers=8, steps=600, lr=0.3,
                                batch=1, seed=1)
    assert final_gnorm(mb, k=50) < 0.5 * final_gnorm(sgd, k=50)


def test_csgd_adds_variance_but_converges():
    """Eq. (3.6): CSGD converges; coarser quantization = more variance.

    The quantization noise is *relative* (Assumption 4: the knob spacing
    scales with the gradient range), so it does not create an absolute
    gnorm floor above the sampling noise on this testbed; the robust
    observable is the trajectory deviation from the uncompressed baseline
    under identical seeds — orders of magnitude larger for rq2 than rq8.
    """
    base = parallel.run_quadratic("mbsgd", n_workers=4, steps=300, lr=0.05)
    c8 = parallel.run_quadratic("csgd_ps", n_workers=4, steps=300, lr=0.05,
                                exchange_kw={"compressor": "rq8"})
    c2 = parallel.run_quadratic("csgd_ps", n_workers=4, steps=300, lr=0.05,
                                exchange_kw={"compressor": "rq2"})
    assert final_gnorm(c8) < 5e-2                      # converges
    assert final_gnorm(c2) < 5e-2                      # even rq2 converges
    dev8 = float(jnp.abs(c8.losses - base.losses).mean())
    dev2 = float(jnp.abs(c2.losses - base.losses).mean())
    assert dev2 > 5.0 * dev8                           # coarser = noisier


def test_ecsgd_beats_naive_biased_compression():
    """Section 3.3: with a biased compressor (sign), plain CSGD stalls or
    diverges while EC-SGD tracks mb-SGD."""
    ec = parallel.run_quadratic("ecsgd", n_workers=4, steps=400, lr=0.05,
                                exchange_kw={"compressor": "sign1"})
    naive = parallel.run_quadratic("csgd_ps", n_workers=4, steps=400,
                                   lr=0.05,
                                   exchange_kw={"compressor": "sign1"})
    ref = parallel.run_quadratic("mbsgd", n_workers=4, steps=400, lr=0.05)
    assert final_gnorm(ec) < 3 * final_gnorm(ref) + 1e-3
    assert final_gnorm(ec) < 0.65 * final_gnorm(naive)


def test_asgd_staleness_slows_but_converges():
    """Thm 4.2.2: bounded staleness keeps convergence; larger tau is not
    faster; tau=0-equivalent matches mb-SGD."""
    t0 = parallel.run_quadratic("mbsgd", n_workers=4, steps=400, lr=0.05)
    t4 = parallel.run_quadratic("asgd", n_workers=4, steps=400, lr=0.05,
                                exchange_kw={"tau": 4})
    t16 = parallel.run_quadratic("asgd", n_workers=4, steps=400, lr=0.05,
                                 exchange_kw={"tau": 16})
    assert final_gnorm(t4) < 5e-2
    assert final_gnorm(t16) >= final_gnorm(t4) - 1e-4
    assert final_gnorm(t4) >= final_gnorm(t0) - 1e-4


def test_asgd_too_large_staleness_with_large_lr_unstable():
    """The tau * lr * L <= 1/2 condition (Eq. 4.8) bites. The testbed's
    L ~ 0.04, so sync-SGD is stable up to lr ~ 2/L ~ 46 while tau = 16
    delay caps it at ~ 1/(tau L) ~ 1.5: lr = 30 separates the regimes."""
    stable = parallel.run_quadratic("mbsgd", n_workers=4, steps=200, lr=20.0)
    wild = parallel.run_quadratic("asgd", n_workers=4, steps=200, lr=20.0,
                                  exchange_kw={"tau": 16})
    w = final_gnorm(wild)
    assert (not np.isfinite(w)) or w > 10 * final_gnorm(stable)


def test_dsgd_consensus_and_convergence():
    """Thm 5.2.6 + Lemma 5.2.4: DSGD converges and the local models reach
    consensus (||x_n - x_bar|| -> small)."""
    res = parallel.run_quadratic("dsgd", n_workers=8, steps=500, lr=0.05,
                                 heterogeneity=0.3)
    assert final_gnorm(res) < 5e-2
    assert float(res.consensus[-1]) < float(res.consensus[5]) * 10
    assert float(res.consensus[-1]) < 1e-2


def test_dsgd_full_topology_matches_mbsgd():
    """Thm 5.2.6 consistency: rho = 0 (fully connected) reduces DSGD to
    mb-SGD exactly (same data partitioning)."""
    full = parallel.run_quadratic("dsgd", n_workers=4, steps=200, lr=0.05,
                                  gossip_topology="full")
    ring = parallel.run_quadratic("dsgd", n_workers=4, steps=200, lr=0.05)
    # both converge; full-topology consensus is exact (0)
    assert float(full.consensus[-1]) < 1e-10
    assert final_gnorm(full) < 5e-2 and final_gnorm(ring) < 5e-2


def test_dsgd_heterogeneity_raises_floor():
    """The varsigma (outer-variance) term of Thm 5.2.6 / Lemma 5.2.4.

    Outer variance enters through the consensus distance (workers pulled
    toward different local minima between gossip rounds); the averaged
    iterate of the quadratic still converges, so the robust observable is
    the steady-state consensus floor, not the gnorm at x_bar.
    """
    homo = parallel.run_quadratic("dsgd", n_workers=8, steps=300, lr=0.05,
                                  heterogeneity=0.0, seed=3)
    hetero = parallel.run_quadratic("dsgd", n_workers=8, steps=300, lr=0.05,
                                    heterogeneity=2.0, seed=3)
    homo_floor = float(homo.consensus[-50:].mean())
    hetero_floor = float(hetero.consensus[-50:].mean())
    assert hetero_floor > 3.0 * homo_floor
    # both still converge to a stationary neighborhood
    assert final_gnorm(hetero) < 5e-2 and final_gnorm(homo) < 5e-2
