"""End-to-end driver: train the ~115M-param `repro-100m` decoder LM for a
few hundred steps on the synthetic bigram corpus, with the paper's
compressed-gradient path enabled, checkpointing, and resume.

This is deliverable (b)'s end-to-end example: the full production substrate
(config -> data pipeline -> sharded train step -> optimizer schedule ->
checkpoint) driving a real model to a visibly lower loss.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
(a CPU-friendly seq/batch; pass --full-size for the real 100M config)
"""
import argparse

from repro.launch import train as train_cli


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full-size", action="store_true",
                    help="train the full 115M config (slow on CPU)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    argv = ["--arch", "repro-100m", "--steps", str(args.steps),
            "--batch", "8", "--seq", "128", "--lr", "3e-3",
            "--compression", "rq8", "--error-feedback",
            "--ckpt-dir", args.ckpt_dir, "--log-every", "20"]
    if not args.full_size:
        argv.append("--reduced")
    train_cli.main(argv)


if __name__ == "__main__":
    main()
