"""Quickstart: every system relaxation of the paper in one run.

Trains the same distributed least-squares problem (Section 1.1.3's example)
with 8 workers under each algorithm, prints the convergence table and the
modeled wall-clock per iteration under the Section 1.3 switch model —
reproducing the story of Table 1.1: relaxations don't beat mb-SGD on
iterations, they beat it on *time per iteration*.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import eventsim, mixing, parallel

N_WORKERS = 8
STEPS = 400
SIZE_MB = 100.0          # model size on the wire
ALPHA, BETA = 1e-3, 1e-2  # switch latency (s), s/MB at the NIC


def main():
    runs = {
        "mb-SGD (baseline)": ("mbsgd", {}, None),
        "CSGD rq4 (PS form)": ("csgd_ps", {"compressor": "rq4"}, None),
        "CSGD rq8 (ring form)": ("csgd_ring", {"compressor": "rq8"}, None),
        "EC-SGD 1-bit sign": ("ecsgd", {"compressor": "sign1"}, None),
        "ASGD tau=8": ("asgd", {"tau": 8}, None),
        "DSGD ring": ("dsgd", {}, None),
    }
    def comm_time(alpha, beta):
        return {
            "mb-SGD (baseline)": eventsim.ring_allreduce_makespan(
                N_WORKERS, SIZE_MB, t_lat=alpha, t_tr=beta),
            "CSGD rq4 (PS form)": eventsim.multi_ps_makespan(
                N_WORKERS, SIZE_MB, t_lat=alpha, t_tr=beta, compression=8),
            "CSGD rq8 (ring form)": eventsim.ring_allreduce_makespan(
                N_WORKERS, SIZE_MB, t_lat=alpha, t_tr=beta, compression=4),
            "EC-SGD 1-bit sign": eventsim.multi_ps_makespan(
                N_WORKERS, SIZE_MB, t_lat=alpha, t_tr=beta, compression=32),
            "ASGD tau=8": eventsim.single_ps_makespan(
                N_WORKERS, SIZE_MB, t_lat=alpha, t_tr=beta) / N_WORKERS,
            "DSGD ring": eventsim.decentralized_makespan(
                N_WORKERS, SIZE_MB, t_lat=alpha, t_tr=beta),
        }

    # bandwidth-bound datacenter vs latency-bound WAN (Section 1.3.2/5.1
    # discussions: compression helps the former, decentralization the latter)
    bw = comm_time(ALPHA, BETA)
    wan = comm_time(0.25, 1e-3)

    print(f"workers={N_WORKERS} steps={STEPS} | switch model: "
          f"datacenter(a={ALPHA}s b={BETA}s/MB) vs WAN(a=0.25s b=1ms/MB), "
          f"model={SIZE_MB}MB")
    print(f"ring rho = {mixing.spectral_rho(mixing.ring(N_WORKERS)):.4f}")
    print(f"\n{'algorithm':22s} {'final |grad|^2':>14s} {'consensus':>10s} "
          f"{'dc s/it':>9s} {'dc x':>6s} {'wan s/it':>9s} {'wan x':>6s}")
    base_bw, base_wan = bw["mb-SGD (baseline)"], wan["mb-SGD (baseline)"]
    for name, (method, kw, _) in runs.items():
        res = parallel.run_quadratic(method, n_workers=N_WORKERS,
                                     steps=STEPS, lr=0.1,
                                     exchange_kw=kw or None)
        g = float(np.asarray(res.grad_norms)[-20:].mean())
        c = float(res.consensus[-1])
        print(f"{name:22s} {g:14.6f} {c:10.6f} {bw[name]:9.3f} "
              f"{base_bw / bw[name]:5.1f}x {wan[name]:9.3f} "
              f"{base_wan / wan[name]:5.1f}x")
    print("\nReading: every relaxation converges (col 2), DSGD reaches "
          "consensus (col 3);\ncompression wins the bandwidth-bound "
          "datacenter, decentralization wins the\nlatency-bound WAN, and "
          "ASGD's win is straggler-hiding (benchmarks/comm_patterns.py) — "
          "the Table 1.1 story.")


if __name__ == "__main__":
    main()
