"""Decentralized vs centralized training (Section 5) on heterogeneous data.

Shows the three regimes of Theorem 5.2.6 side by side:
  * fully-connected gossip (rho = 0)  == mb-SGD,
  * ring gossip (rho ~ 1 - 4pi^2/3N^2) converges with a consensus phase,
  * heterogeneous data (varsigma > 0) raises the DSGD floor,
and the latency win that motivates it all: O(1) vs O(N) switch latency.

Run:  PYTHONPATH=src python examples/decentralized_vs_central.py
"""
import numpy as np

from repro.core import eventsim, mixing, parallel

N = 16
STEPS = 500


def tail(res):
    return float(np.asarray(res.grad_norms)[-20:].mean())


def main():
    ring_rho = mixing.spectral_rho(mixing.ring(N))
    print(f"N={N} workers | ring rho={ring_rho:.4f} "
          f"(exact 1-4pi^2/3N^2 ~ {1 - 4 * np.pi**2 / (3 * N**2):.4f}; "
          "the paper's 16pi^2 estimate is an erratum, see tests)")

    central = parallel.run_quadratic("mbsgd", n_workers=N, steps=STEPS,
                                     lr=0.1)
    ring_homo = parallel.run_quadratic("dsgd", n_workers=N, steps=STEPS,
                                       lr=0.1)
    ring_hetero = parallel.run_quadratic("dsgd", n_workers=N, steps=STEPS,
                                         lr=0.1, heterogeneity=1.0)
    full_topo = parallel.run_quadratic("dsgd", n_workers=N, steps=STEPS,
                                       lr=0.1, gossip_topology="full")

    print(f"\n{'setup':34s} {'final |grad|^2':>14s} {'consensus':>12s}")
    for name, res in [("centralized mb-SGD", central),
                      ("DSGD ring, homogeneous data", ring_homo),
                      ("DSGD ring, heterogeneous data", ring_hetero),
                      ("DSGD fully-connected (== mb-SGD)", full_topo)]:
        print(f"{name:34s} {tail(res):14.6f} "
              f"{float(res.consensus[-1]):12.8f}")

    print("\nPer-iteration communication (switch model, 100MB model, "
          "alpha=10ms [high-latency WAN], beta=1ms/MB):")
    for name, t in [
        ("AllReduce / multi-PS", eventsim.ring_allreduce_makespan(
            N, 100.0, t_lat=1e-2, t_tr=1e-3)),
        ("DSGD ring exchange", eventsim.decentralized_makespan(
            N, 100.0, t_lat=1e-2, t_tr=1e-3)),
    ]:
        print(f"  {name:28s} {t * 1e3:8.1f} ms")
    print("High latency is exactly where decentralization wins (Section 5).")


if __name__ == "__main__":
    main()
