"""Async vs sync vs local-SGD on a virtual cluster with one 4x straggler.

One command, the whole Chapter 4 story: 8 heterogeneous workers are
scheduled by the discrete-event cluster engine (repro.cluster), each trace
is replayed as REAL training on the §1.1.3 quadratic with the fused rq4
codec, and the table shows what the barrier costs — the straggler throttles
sync-PS to its pace, async-PS keeps every port busy (more updates/s, real
measured staleness), local-SGD(H=8) amortizes the barrier over H local
steps.

Run:  PYTHONPATH=src python examples/async_vs_sync.py
"""
import numpy as np

from repro import cluster

N = 8
ROUNDS = 25
LR = 0.1
CODEC = "rq4"


def main():
    spec = cluster.ClusterSpec(
        n_workers=N, t_compute=1.0,
        multipliers=cluster.straggler_multipliers(N, factor=4.0),
        t_lat=1e-2, t_tr=2e-3, size_mb=1.0, codec=CODEC)
    wl = cluster.quadratic_workload(n_workers=N)

    sync_tr = cluster.make_protocol("sync_ps").schedule(spec, rounds=ROUNDS)
    traces = {
        "sync PS": sync_tr,
        # async runs for exactly sync's simulated wall-clock
        "async PS": cluster.make_protocol("async_ps").schedule(
            spec, horizon=sync_tr.makespan),
        "local-SGD H=8": cluster.make_protocol(
            "local_sgd", period_h=8).schedule(spec, rounds=ROUNDS // 8),
    }
    results = {name: cluster.replay(t, wl, codec=CODEC, lr=LR,
                                    eval_every=max(t.n_updates // 40, 1))
               for name, t in traces.items()}

    target = results["sync PS"].final_loss
    print(f"{N} workers, one 4x straggler | switch model a={spec.t_lat}s "
          f"b={spec.t_tr}s/MB | fused {CODEC} codec "
          f"({spec.msg_mb():.3f} MB/msg on the wire)")
    print(f"\n{'protocol':16s} {'updates/s':>10s} {'max stale':>10s} "
          f"{'final loss':>11s} {'steps@sync-loss':>16s} "
          f"{'t@sync-loss':>12s}")
    for name, res in results.items():
        tput = res.updates_applied / res.makespan
        t_hit = res.time_to(target)
        # applied updates until the curve first reaches sync's final loss
        hit = np.nonzero(res.losses <= target)[0]
        steps = ((hit[0] + 1) * max(res.updates_applied // len(res.losses), 1)
                 if hit.size else res.updates_applied)
        print(f"{name:16s} {tput:10.2f} {res.max_staleness:10d} "
              f"{res.final_loss:11.5f} {steps:16d} {t_hit:12.2f}")
    print("\nReading: the barrier makes sync pay the straggler every round; "
          "async turns the\nsame wall-clock into many more applied updates "
          "(at real, measured staleness) and\nreaches sync's final loss "
          "first; local-SGD pays the barrier only every H steps.")


if __name__ == "__main__":
    main()
