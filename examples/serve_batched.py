"""Batched serving example: the engine API across three architecture
families, plus a live train->serve checkpoint hot-swap.

Part 1 runs the reduced configs of a dense (GQA), an SSM (RWKV6) and a
hybrid (RecurrentGemma) model through the SAME ``serve.run`` call — the
point being that the slot-paged decode-state abstraction (ring-buffer
KV cache, O(1) recurrent state) is uniform, so continuous batching and
admission control come for free for every family.

Part 2 closes the train->serve loop: a trainer publishes rq8-compressed
CRC-framed checkpoints into a ``CheckpointChannel`` while the engine is
mid-decode; the engine swaps params between decode steps with zero
dropped requests.

Run:  PYTHONPATH=src python examples/serve_batched.py

Note it calls ``serve.run(ServeConfig(...))`` directly — no argv lists;
the CLI in ``repro.launch.serve`` is just another client of the same
function.
"""
import jax
import numpy as np

from repro import serve
from repro.models import transformer_scan


def serve_three_families():
    for arch, window in [("qwen1.5-0.5b", 16),
                         ("rwkv6-3b", 0),
                         ("recurrentgemma-9b", 0)]:
        print(f"\n==== {arch} (reduced) ====")
        cfg = serve.ServeConfig(
            arch=arch, reduced=True, slots=2, window=window, max_len=32,
            n_requests=4, prompt_len=12, mixed_gen=(6, 12),
            temperature=1.0)
        print(serve.format_result(serve.run(cfg)))


def hot_swap_mid_decode():
    print("\n==== live checkpoint hot-swap (qwen1.5-0.5b reduced) ====")
    cfg = serve.ServeConfig(slots=2, max_len=64, prompt_len=8)
    engine = serve.Engine(cfg)
    channel = serve.CheckpointChannel()
    engine.subscribe(channel)
    engine.warmup([8])

    rng = np.random.default_rng(0)
    for _ in range(2):
        engine.submit(rng.integers(0, engine.model_cfg.vocab, 8), 24)
    for _ in range(6):            # decode a while on the initial params
        engine.step()

    # "training" publishes a compressed checkpoint; here: fresh params
    trained = transformer_scan.init(engine.model_cfg, jax.random.PRNGKey(7))
    pub = channel.publish(trained, step=100, codec="rq8")
    print(f"published seq={pub.seq} ({pub.wire_bytes/1e3:.1f} kB on the "
          f"wire vs {sum(l.size * 4 for l in jax.tree_util.tree_leaves(trained))/1e3:.1f} kB fp32)")

    engine.run()                  # swap applies between decode steps
    s = engine.stats()
    print(f"swaps={s['swaps']} dropped={s['dropped']} "
          f"completed={s['completed']} tokens={s['generated_tokens']}")


def main():
    serve_three_families()
    hot_swap_mid_decode()


if __name__ == "__main__":
    main()
