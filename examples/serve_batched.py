"""Batched serving example: prefill + autoregressive decode with KV /
recurrent caches across three architecture families.

Runs the reduced configs of a dense (GQA), an SSM (RWKV6) and a hybrid
(RecurrentGemma) model through the same serve_step API — the point being
that the decode state abstraction (ring-buffer KV cache, O(1) recurrent
state) is uniform, which is what lets `long_500k` lower for every family
in the dry-run.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
from repro.launch import serve as serve_cli


def main():
    for arch, extra in [("qwen1.5-0.5b", ["--window", "16"]),
                        ("rwkv6-3b", []),
                        ("recurrentgemma-9b", [])]:
        print(f"\n==== {arch} (reduced) ====")
        serve_cli.main(["--arch", arch, "--reduced", "--batch", "2",
                        "--prompt-len", "12", "--gen", "12"] + extra)


if __name__ == "__main__":
    main()
