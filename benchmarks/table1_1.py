"""Benchmark for Table 1.1: per-relaxation iteration counts to epsilon and
per-iteration communication cost.

Three views, printed side by side:
  analytic   - the paper's closed forms (repro.core.theory)
  simulated  - the §1.3 event simulator's makespan for one exchange
  empirical  - iterations-to-epsilon measured on the quadratic testbed with
               the REAL exchange implementations (repro.core.parallel)
"""
from __future__ import annotations

import numpy as np

from repro.core import eventsim, mixing, parallel, theory


def iterations_to_eps(res, eps: float) -> int:
    g = np.asarray(res.grad_norms)
    idx = np.nonzero(g <= eps)[0]
    return int(idx[0]) + 1 if idx.size else -1


def run(n_workers: int = 8, eps: float = 5e-3, steps: int = 800,
        size_mb: float = 100.0, alpha: float = 1e-3, beta: float = 1e-2):
    w = theory.Workload()
    rho = mixing.spectral_rho(mixing.ring(n_workers))
    rows = []

    empirical = {
        "mb-SGD": parallel.run_quadratic("mbsgd", n_workers=n_workers,
                                         steps=steps, lr=0.1),
        "CSGD": parallel.run_quadratic("csgd_ps", n_workers=n_workers,
                                       steps=steps, lr=0.1,
                                       exchange_kw={"compressor": "rq4"}),
        "EC-SGD": parallel.run_quadratic("ecsgd", n_workers=n_workers,
                                         steps=steps, lr=0.1,
                                         exchange_kw={"compressor": "sign1"}),
        "ASGD": parallel.run_quadratic("asgd", n_workers=n_workers,
                                       steps=steps, lr=0.1,
                                       exchange_kw={"tau": n_workers}),
        "DSGD": parallel.run_quadratic("dsgd", n_workers=n_workers,
                                       steps=steps, lr=0.1),
    }
    # message sizes come from the MEASURED codec wire format (packed
    # payload + params header), not a hand-written eta — see
    # repro.core.compression.Codec.wire_bytes
    comm = {
        "mb-SGD": eventsim.ring_allreduce_makespan(
            n_workers, size_mb, t_lat=alpha, t_tr=beta),
        "CSGD": eventsim.ring_allreduce_makespan(
            n_workers, size_mb, t_lat=alpha, t_tr=beta, codec="rq4"),
        "EC-SGD": eventsim.ring_allreduce_makespan(
            n_workers, size_mb, t_lat=alpha, t_tr=beta, codec="sign1"),
        "ASGD": eventsim.single_ps_makespan(
            n_workers, size_mb, t_lat=alpha, t_tr=beta) / n_workers,
        "DSGD": eventsim.decentralized_makespan(
            n_workers, size_mb, t_lat=alpha, t_tr=beta),
    }
    analytic = {
        "mb-SGD": theory.dist_sgd_iterations(w, eps, n_workers),
        "CSGD": theory.csgd_iterations(w, eps, n_workers),
        "EC-SGD": theory.ecsgd_iterations(w, eps, n_workers),
        "ASGD": theory.asgd_iterations(w, eps, n_workers),
        "DSGD": theory.dsgd_iterations(w, eps, n_workers, rho),
    }
    for name in empirical:
        it = iterations_to_eps(empirical[name], eps)
        rows.append((name, analytic[name], it, comm[name],
                     empirical[name].comm_bytes_per_step))
    return rows


def fused_vs_per_leaf(arch: str = "repro-100m", n_workers: int = 8,
                      codec: str = "rq4", alpha: float = 1e-3,
                      beta: float = 1e-2):
    """Fused flat-buffer vs per-leaf codec messaging on a real gradient
    tree (the §1.3 per-message latency charge, measured end to end).

    A per-leaf codec path ships one message per pytree leaf — n_messages
    = L per ring hop (latency ~ 2 N L t_lat); the fused tier ships ONE
    FlatPacked (~ 2 N t_lat). Wire bytes come from the MEASURED codec
    formats (eval_shape only — nothing is allocated).
    """
    import jax

    from repro import configs
    from repro.core import compression
    from repro.models import transformer

    cfg = configs.get_config(arch)
    grads = jax.eval_shape(
        lambda: transformer.init(cfg, jax.random.PRNGKey(0)))
    n_leaves = len(jax.tree_util.tree_leaves(grads))
    cdc = compression.codec(codec)
    per_leaf_b = cdc.tree_wire_bytes(grads)
    fused_b = cdc.tree_wire_bytes_flat(grads)
    size_mb = 4.0 * compression.FlatLayout.from_tree(grads).total / 1e6
    t_per_leaf = eventsim.ring_allreduce_makespan(
        n_workers, size_mb, t_lat=alpha, t_tr=beta, codec=codec,
        n_messages=n_leaves)
    t_fused = eventsim.ring_allreduce_makespan(
        n_workers, size_mb, t_lat=alpha, t_tr=beta, codec=codec,
        n_messages=1)
    return {"arch": arch, "codec": codec, "n_leaves": n_leaves,
            "size_mb": size_mb, "per_leaf_bytes": per_leaf_b,
            "fused_bytes": fused_b, "per_leaf_makespan_s": t_per_leaf,
            "fused_makespan_s": t_fused,
            "latency_gap_s": t_per_leaf - t_fused}


def main():
    print("# Table 1.1 — iterations to eps + comm cost per iteration")
    print(f"{'algorithm':10s} {'analytic_iters(arb)':>20s} "
          f"{'empirical_iters':>16s} {'comm_cost(s)':>14s} "
          f"{'wire_B/step':>12s}")
    derived = []
    for name, ana, emp, comm, wire_b in run():
        print(f"{name:10s} {ana:20.1f} {emp:16d} {comm:14.4f} {wire_b:12.0f}")
        derived.append(f"{name}:it={emp}")
    f = fused_vs_per_leaf()
    print(f"\n# Fused flat-buffer vs per-leaf messaging "
          f"({f['arch']} grads, {f['codec']}, ring n=8, "
          f"L={f['n_leaves']} leaves, {f['size_mb']:.1f} fp32 MB)")
    print(f"{'path':10s} {'n_messages/hop':>14s} {'wire_B/hop':>12s} "
          f"{'ring_makespan(s)':>17s}")
    print(f"{'per-leaf':10s} {f['n_leaves']:14d} "
          f"{f['per_leaf_bytes']:12.0f} {f['per_leaf_makespan_s']:17.4f}")
    print(f"{'fused':10s} {1:14d} {f['fused_bytes']:12.0f} "
          f"{f['fused_makespan_s']:17.4f}")
    print(f"# latency gap = {f['latency_gap_s']:.4f}s per exchange "
          f"(2(n-1)(L-1)*t_lat), wire saving = "
          f"{f['per_leaf_bytes'] - f['fused_bytes']:.0f} B "
          f"(pad granules + params headers)")
    derived.append(f"fused_gap_s={f['latency_gap_s']:.3f}")
    return ",".join(derived)


if __name__ == "__main__":
    main()
