"""Benchmark for Table 1.1: per-relaxation iteration counts to epsilon and
per-iteration communication cost.

Three views, printed side by side:
  analytic   - the paper's closed forms (repro.core.theory)
  simulated  - the §1.3 event simulator's makespan for one exchange
  empirical  - iterations-to-epsilon measured on the quadratic testbed with
               the REAL exchange implementations (repro.core.parallel)
"""
from __future__ import annotations

import numpy as np

from repro.core import eventsim, mixing, parallel, theory


def iterations_to_eps(res, eps: float) -> int:
    g = np.asarray(res.grad_norms)
    idx = np.nonzero(g <= eps)[0]
    return int(idx[0]) + 1 if idx.size else -1


def run(n_workers: int = 8, eps: float = 5e-3, steps: int = 800,
        size_mb: float = 100.0, alpha: float = 1e-3, beta: float = 1e-2):
    w = theory.Workload()
    rho = mixing.spectral_rho(mixing.ring(n_workers))
    rows = []

    empirical = {
        "mb-SGD": parallel.run_quadratic("mbsgd", n_workers=n_workers,
                                         steps=steps, lr=0.1),
        "CSGD": parallel.run_quadratic("csgd_ps", n_workers=n_workers,
                                       steps=steps, lr=0.1,
                                       exchange_kw={"compressor": "rq4"}),
        "EC-SGD": parallel.run_quadratic("ecsgd", n_workers=n_workers,
                                         steps=steps, lr=0.1,
                                         exchange_kw={"compressor": "sign1"}),
        "ASGD": parallel.run_quadratic("asgd", n_workers=n_workers,
                                       steps=steps, lr=0.1,
                                       exchange_kw={"tau": n_workers}),
        "DSGD": parallel.run_quadratic("dsgd", n_workers=n_workers,
                                       steps=steps, lr=0.1),
    }
    # message sizes come from the MEASURED codec wire format (packed
    # payload + params header), not a hand-written eta — see
    # repro.core.compression.Codec.wire_bytes
    comm = {
        "mb-SGD": eventsim.ring_allreduce_makespan(
            n_workers, size_mb, t_lat=alpha, t_tr=beta),
        # the partitioned compressed ring (CSGDRingExchange's default):
        # 2(n-1) partition messages, 2M(n-1)/n wire bytes per worker
        "CSGD": eventsim.csgd_ring_makespan(
            n_workers, size_mb, t_lat=alpha, t_tr=beta, codec="rq4"),
        "EC-SGD": eventsim.ring_allreduce_makespan(
            n_workers, size_mb, t_lat=alpha, t_tr=beta, codec="sign1"),
        "ASGD": eventsim.single_ps_makespan(
            n_workers, size_mb, t_lat=alpha, t_tr=beta) / n_workers,
        "DSGD": eventsim.decentralized_makespan(
            n_workers, size_mb, t_lat=alpha, t_tr=beta),
    }
    analytic = {
        "mb-SGD": theory.dist_sgd_iterations(w, eps, n_workers),
        "CSGD": theory.csgd_iterations(w, eps, n_workers),
        "EC-SGD": theory.ecsgd_iterations(w, eps, n_workers),
        "ASGD": theory.asgd_iterations(w, eps, n_workers),
        "DSGD": theory.dsgd_iterations(w, eps, n_workers, rho),
    }
    for name in empirical:
        it = iterations_to_eps(empirical[name], eps)
        rows.append((name, analytic[name], it, comm[name],
                     empirical[name].comm_bytes_per_step))
    return rows


def fused_vs_per_leaf(arch: str = "repro-100m", n_workers: int = 8,
                      codec: str = "rq4", alpha: float = 1e-3,
                      beta: float = 1e-2):
    """Per-leaf vs fused-monolithic vs partitioned ring messaging on a
    real gradient tree (§1.3's per-message latency charge AND §1.3.3's
    partitioning argument, measured end to end).

    Three tiers of CSGDRingExchange history:
      per-leaf     N-1 hops, L messages each (latency ~ N L t_lat),
                   full-tree bytes per hop;
      fused mono   N-1 hops, ONE FlatPacked each (~ N t_lat), still
                   full-tree bytes per hop -> (N-1)*M wire per worker;
      partitioned  reduce-scatter + all-gather: 2(N-1) hops of ONE
                   partition (M/N bytes) -> 2*M*(N-1)/N per worker, the
                   bandwidth-optimal decomposition (the default).

    Wire bytes come from the MEASURED codec formats (eval_shape only —
    nothing is allocated).
    """
    import jax

    from repro import configs
    from repro.core import compression
    from repro.models import transformer

    cfg = configs.get_config(arch)
    grads = jax.eval_shape(
        lambda: transformer.init(cfg, jax.random.PRNGKey(0)))
    n_leaves = len(jax.tree_util.tree_leaves(grads))
    cdc = compression.codec(codec)
    per_leaf_b = cdc.tree_wire_bytes(grads)
    fused_b = cdc.tree_wire_bytes_flat(grads)
    part_b = cdc.tree_wire_bytes_partitioned(grads, n_workers)
    size_mb = 4.0 * compression.FlatLayout.from_tree(grads).total / 1e6
    t_per_leaf = eventsim.csgd_ring_makespan(
        n_workers, size_mb, t_lat=alpha, t_tr=beta, codec=codec,
        partitioned=False, n_messages=n_leaves)
    t_mono = eventsim.csgd_ring_makespan(
        n_workers, size_mb, t_lat=alpha, t_tr=beta, codec=codec,
        partitioned=False, n_messages=1)
    t_part = eventsim.csgd_ring_makespan(
        n_workers, size_mb, t_lat=alpha, t_tr=beta, codec=codec,
        partitioned=True, n_messages=1)
    return {"arch": arch, "codec": codec, "n_leaves": n_leaves,
            "size_mb": size_mb,
            "per_leaf_bytes": per_leaf_b,
            "fused_bytes": fused_b,
            "partitioned_part_bytes": part_b,
            "partitioned_wire_bytes": 2 * (n_workers - 1) * part_b,
            "mono_wire_bytes": (n_workers - 1) * fused_b,
            "per_leaf_makespan_s": t_per_leaf,
            "fused_makespan_s": t_mono,
            "partitioned_makespan_s": t_part,
            "n_wire_messages": 2 * (n_workers - 1),
            "latency_gap_s": t_per_leaf - t_mono}


def main():
    print("# Table 1.1 — iterations to eps + comm cost per iteration")
    print(f"{'algorithm':10s} {'analytic_iters(arb)':>20s} "
          f"{'empirical_iters':>16s} {'comm_cost(s)':>14s} "
          f"{'wire_B/step':>12s}")
    derived = []
    for name, ana, emp, comm, wire_b in run():
        print(f"{name:10s} {ana:20.1f} {emp:16d} {comm:14.4f} {wire_b:12.0f}")
        derived.append(f"{name}:it={emp}")
    f = fused_vs_per_leaf()
    n = 8
    print(f"\n# CSGD ring messaging tiers "
          f"({f['arch']} grads, {f['codec']}, ring n={n}, "
          f"L={f['n_leaves']} leaves, {f['size_mb']:.1f} fp32 MB)")
    print(f"{'path':12s} {'msgs/iter':>10s} {'wire_B/msg':>12s} "
          f"{'wire_B/worker/iter':>19s} {'makespan(s)':>12s}")
    print(f"{'per-leaf':12s} {(n - 1) * f['n_leaves']:10d} "
          f"{f['per_leaf_bytes'] / f['n_leaves']:12.0f} "
          f"{(n - 1) * f['per_leaf_bytes']:19.0f} "
          f"{f['per_leaf_makespan_s']:12.4f}")
    print(f"{'fused-mono':12s} {n - 1:10d} {f['fused_bytes']:12.0f} "
          f"{f['mono_wire_bytes']:19.0f} {f['fused_makespan_s']:12.4f}")
    print(f"{'partitioned':12s} {f['n_wire_messages']:10d} "
          f"{f['partitioned_part_bytes']:12.0f} "
          f"{f['partitioned_wire_bytes']:19.0f} "
          f"{f['partitioned_makespan_s']:12.4f}")
    print(f"# per-message latency gap (per-leaf vs fused) = "
          f"{f['latency_gap_s']:.4f}s per exchange ((n-1)(L-1)*t_lat); "
          f"partitioned wire = 2M(n-1)/n = "
          f"{f['partitioned_wire_bytes'] / f['mono_wire_bytes']:.3f}x "
          f"the monolithic (n-1)M")
    derived.append(f"fused_gap_s={f['latency_gap_s']:.3f}")
    derived.append(
        f"part_vs_mono_bytes="
        f"{f['partitioned_wire_bytes'] / f['mono_wire_bytes']:.3f}")
    return ",".join(derived)


if __name__ == "__main__":
    main()
