"""Kernel micro-benchmarks (interpret mode on CPU: correctness-scale only;
the numbers that matter for the TPU target are the VMEM working sets and
roofline estimates printed alongside).

Emits machine-readable ``BENCH_kernels.json`` at the repo root —
``[{"op": ..., "us": ..., "us_median": ..., "first_call_us": ...,
"est": ...}, ...]`` — so every run extends the perf trajectory. ``us`` is
STEADY STATE (post warm-up, best of k reps — what the hardware does once
compiled); ``us_median`` is the median of the same reps (noise floor
indicator); ``first_call_us`` is the separate first-call time (compile +
dispatch), reported apart so dispatch/interpret overhead cannot pollute
the trajectory the way the 10 ms quant_qdq row once shadowed its 15 µs
roofline estimate. ``--smoke`` shrinks every shape to CI scale, where
``benchmarks/bench_delta.py`` diffs the numbers against the committed
``BENCH_kernels_smoke.json`` baseline and annotates regressions.

``--op SUBSTR`` runs only the rows whose name contains SUBSTR (setup for
unselected rows is never built, so iterating on one kernel doesn't re-run
the 100m tree encodes); filtered runs print but do NOT write the JSON —
a partial row list would clobber the committed trajectory. ``--repeat K``
controls the steady-state rep count (default 3).

The tree-encode rows compare the codec messaging tiers on the
repro-100m gradient tree: per-leaf pays one dispatch + one (lo, scale)
reduction + one padded message per pytree leaf; the fused flat-buffer
tier pays them once for the whole tree (its steady state must be no
slower — ``flat_vs_perleaf_speedup`` >= 1 is the PR-2-regression
acceptance bar); the partitioned row encodes the same buffer as the
ring AllReduce's N per-partition messages (blocked from-leaves encode —
must be no slower than the flat row).
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import time

import jax
import jax.numpy as jnp

from repro import obs
from repro.kernels.flash_attn import ops as fa_ops
from repro.kernels.quant import ops as q_ops
from repro.kernels.wkv6 import ops as wkv_ops
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16

OUT_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                        "BENCH_kernels.json")


def _time(fn, *args, reps=3):
    """(first_call_us, best_us, median_us): first call = compile +
    dispatch, timed alone; steady state = best/median of `reps` after the
    warm-up, each rep block_until_ready'd so async dispatch cannot smear
    across reps."""
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    first = (time.perf_counter() - t0) * 1e6
    samples = []
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append((time.perf_counter() - t0) * 1e6)
    return first, min(samples), statistics.median(samples)


def _grad_tree(smoke: bool):
    """A gradient-shaped pytree: repro-100m's param tree (reduced() dims
    under --smoke), filled with random values."""
    from repro import configs
    from repro.models import transformer

    cfg = configs.get_config("repro-100m")
    if smoke:
        cfg = cfg.reduced()
    shapes = jax.eval_shape(
        lambda: transformer.init(cfg, jax.random.PRNGKey(0)))
    leaves, treedef = jax.tree_util.tree_flatten(shapes)
    key = jax.random.PRNGKey(7)
    vals = [jax.random.normal(jax.random.fold_in(key, i), leaf.shape,
                              jnp.float32) for i, leaf in enumerate(leaves)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def main(smoke: bool = False, out_path: str = OUT_PATH,
         op: str | None = None, repeat: int = 3):
    from repro.core import compression

    key = jax.random.PRNGKey(0)
    tag = "reduced" if smoke else "100m"
    n_q = 1 << 14 if smoke else 1 << 20
    seq = 128 if smoke else 1024
    t_wkv = 64 if smoke else 512

    # (name, runner) pairs; runner() -> (timing, derived). Setup lives
    # INSIDE each runner so --op never builds what it doesn't time.
    def run_qdq():
        x = jax.random.normal(key, (n_q,))
        us = _time(lambda a: q_ops.quantize_dequantize(a, key, bits=8), x,
                   reps=repeat)
        # TPU estimate: pure-VPU 2 passes over 4B+4B read + 4B write
        est = (x.size * 12) / HBM_BW * 1e6
        return us, f"tpu_mem_bound_est={est:.1f}us"

    def run_flash():
        q = jax.random.normal(key, (1, seq, 8, 128), jnp.float32)
        k = jax.random.normal(key, (1, seq, 2, 128), jnp.float32)
        v = jax.random.normal(key, (1, seq, 2, 128), jnp.float32)
        us = _time(lambda a, b, c: fa_ops.flash_attention(a, b, c,
                                                          causal=True),
                   q, k, v, reps=repeat)
        flops = 2 * 2 * seq * seq * 8 * 128  # qk + av
        est = flops / PEAK_FLOPS_BF16 * 1e6
        return us, f"tpu_mxu_est={est:.1f}us"

    def run_wkv():
        r = jax.random.normal(key, (1, t_wkv, 4, 64)) * 0.5
        kk = jax.random.normal(key, (1, t_wkv, 4, 64)) * 0.5
        vv = jax.random.normal(key, (1, t_wkv, 4, 64)) * 0.5
        lw = -jnp.exp(jax.random.normal(key, (1, t_wkv, 4, 64)) * 0.3
                      - 2.5)
        u = jax.random.normal(key, (4, 64)) * 0.1
        us = _time(lambda *a: wkv_ops.wkv6(*a)[0], r, kk, vv, lw, u,
                   reps=repeat)
        return us, "chunked-scan"

    # codec messaging tiers on the repro-100m gradient tree: per-leaf
    # (L dispatches + L params reductions + L padded messages), fused
    # flat buffer (one of each), and the ring's partitioned encode
    # (n_workers per-partition messages over one backing buffer)
    tree_cache = {}

    def _tree_setup():
        if not tree_cache:
            tree_cache["grads"] = _grad_tree(smoke)
            tree_cache["cdc"] = compression.codec("rq8")
        return tree_cache["grads"], tree_cache["cdc"]

    n_workers = 8

    def run_leaf():
        grads, cdc = _tree_setup()
        n_leaves = len(jax.tree_util.tree_leaves(grads))
        us = _time(lambda t: cdc.tree_encode(t, key), grads, reps=repeat)
        b = cdc.tree_wire_bytes(grads)
        return us, f"wire_B={b:.0f},n_messages={n_leaves}"

    def run_flat():
        grads, cdc = _tree_setup()
        us = _time(lambda t: cdc.tree_encode_flat(t, key), grads,
                   reps=repeat)
        b = cdc.tree_wire_bytes_flat(grads)
        return us, f"wire_B={b:.0f},n_messages=1"

    def run_part():
        grads, cdc = _tree_setup()
        us = _time(lambda t: cdc.tree_encode_partitioned(t, key,
                                                         n_workers),
                   grads, reps=repeat)
        b = cdc.tree_wire_bytes_partitioned(grads, n_workers)
        return us, f"part_wire_B={b:.0f},n_parts={n_workers}"

    specs = [(f"quant_qdq_{n_q // 1024}K", run_qdq),
             (f"flash_attn_{seq}", run_flash),
             (f"wkv6_{t_wkv}", run_wkv),
             (f"tree_encode_per_leaf_{tag}", run_leaf),
             (f"tree_encode_flat_{tag}", run_flat),
             (f"tree_encode_partitioned_{tag}", run_part)]
    if op:
        specs = [s for s in specs if op in s[0]]
        if not specs:
            raise SystemExit(f"--op '{op}' matches no benchmark row")

    rows = [(name, *runner()) for name, runner in specs]
    by_name = {name: t for name, t, _ in rows}

    print("# Kernel microbenchmarks (CPU interpret mode — correctness "
          "tier; us = steady state best-of-k, first = compile + first "
          "dispatch)")
    print(f"{'name':30s} {'us_steady':>10s} {'us_median':>10s} "
          f"{'first_ms':>9s}  derived")
    for name, (first, us, med), derived in rows:
        print(f"{name:30s} {us:10.0f} {med:10.0f} {first / 1e3:9.0f}  "
              f"{derived}")

    speedup = None
    leaf_t = by_name.get(f"tree_encode_per_leaf_{tag}")
    flat_t = by_name.get(f"tree_encode_flat_{tag}")
    if leaf_t and flat_t:
        speedup = leaf_t[1] / flat_t[1]
        print(f"# flat_vs_perleaf_speedup = {speedup:.2f}x (steady "
              "state; >= 1 means the fused path is no slower than "
              "per-leaf)")

    if op:
        print("# --op filter active: JSON not written (partial rows "
              "would clobber the committed trajectory)")
    else:
        payload = []
        for name, (first, us, med), derived in rows:
            row = {"op": name, "us": round(us, 1),
                   "us_median": round(med, 1),
                   "first_call_us": round(first, 1), "est": derived}
            if name.startswith("tree_encode_flat") and speedup:
                row["flat_vs_perleaf_speedup"] = round(speedup, 3)
            payload.append(row)
        obs.stamp_rows(payload)
        with open(out_path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"# wrote {os.path.normpath(out_path)}")
    return ",".join(f"{n}={t[1]:.0f}us" for n, t, _ in rows)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes (CI-scale)")
    ap.add_argument("--out", default=OUT_PATH,
                    help="where to write BENCH_kernels.json")
    ap.add_argument("--op", default=None,
                    help="run only rows whose name contains this "
                         "substring (skips JSON write)")
    ap.add_argument("--repeat", type=int, default=3,
                    help="steady-state reps per row (best + median "
                         "reported)")
    args = ap.parse_args()
    main(smoke=args.smoke, out_path=args.out, op=args.op,
         repeat=args.repeat)
