"""Kernel micro-benchmarks (interpret mode on CPU: correctness-scale only;
the numbers that matter for the TPU target are the VMEM working sets and
roofline estimates printed alongside)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_attn import ops as fa_ops
from repro.kernels.quant import ops as q_ops
from repro.kernels.wkv6 import ops as wkv_ops
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6  # us


def main():
    key = jax.random.PRNGKey(0)
    rows = []

    x = jax.random.normal(key, (1 << 20,))
    us = _time(lambda a: q_ops.quantize_dequantize(a, key, bits=8), x)
    # TPU estimate: pure-VPU 2 passes over 4B+4B read + 4B write / 819GB/s
    est = (x.size * 12) / HBM_BW * 1e6
    rows.append(("quant_qdq_1M", us, f"tpu_mem_bound_est={est:.1f}us"))

    q = jax.random.normal(key, (1, 1024, 8, 128), jnp.float32)
    k = jax.random.normal(key, (1, 1024, 2, 128), jnp.float32)
    v = jax.random.normal(key, (1, 1024, 2, 128), jnp.float32)
    us = _time(lambda a, b, c: fa_ops.flash_attention(a, b, c, causal=True),
               q, k, v)
    flops = 2 * 2 * 1024 * 1024 * 8 * 128  # qk + av
    est = flops / PEAK_FLOPS_BF16 * 1e6
    rows.append(("flash_attn_1k", us, f"tpu_mxu_est={est:.1f}us"))

    r = jax.random.normal(key, (1, 512, 4, 64)) * 0.5
    kk = jax.random.normal(key, (1, 512, 4, 64)) * 0.5
    vv = jax.random.normal(key, (1, 512, 4, 64)) * 0.5
    lw = -jnp.exp(jax.random.normal(key, (1, 512, 4, 64)) * 0.3 - 2.5)
    u = jax.random.normal(key, (4, 64)) * 0.1
    us = _time(lambda *a: wkv_ops.wkv6(*a)[0], r, kk, vv, lw, u)
    rows.append(("wkv6_512", us, "chunked-scan"))

    print("# Kernel microbenchmarks (CPU interpret mode — correctness tier)")
    print(f"{'name':16s} {'us_per_call':>12s}  derived")
    for name, us, derived in rows:
        print(f"{name:16s} {us:12.0f}  {derived}")
    return ",".join(f"{n}={u:.0f}us" for n, u, _ in rows)


if __name__ == "__main__":
    main()
