"""Kernel micro-benchmarks (interpret mode on CPU: correctness-scale only;
the numbers that matter for the TPU target are the VMEM working sets and
roofline estimates printed alongside).

Emits machine-readable ``BENCH_kernels.json`` at the repo root —
``[{"op": ..., "us": ..., "first_call_us": ..., "est": ...}, ...]`` — so
every run extends the perf trajectory. ``us`` is STEADY STATE (post
warm-up, best of k reps — what the hardware does once compiled);
``first_call_us`` is the separate first-call time (compile + dispatch),
reported apart so dispatch/interpret overhead cannot pollute the
trajectory the way the 10 ms quant_qdq row once shadowed its 15 µs
roofline estimate. ``--smoke`` shrinks every shape to CI scale, where
``benchmarks/bench_delta.py`` diffs the numbers against the committed
``BENCH_kernels_smoke.json`` baseline and annotates >2x regressions.

The tree-encode rows compare the codec messaging tiers on the
repro-100m gradient tree: per-leaf pays one dispatch + one (lo, scale)
reduction + one padded message per pytree leaf; the fused flat-buffer
tier pays them once for the whole tree (its steady state must be no
slower — ``flat_vs_perleaf_speedup`` >= 1 is the PR-2-regression
acceptance bar); the partitioned row encodes the same buffer as the
ring AllReduce's N per-partition messages.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_attn import ops as fa_ops
from repro.kernels.quant import ops as q_ops
from repro.kernels.wkv6 import ops as wkv_ops
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16

OUT_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                        "BENCH_kernels.json")


def _time(fn, *args, reps=3):
    """(first_call_us, steady_us): first call = compile + dispatch, timed
    alone; steady state = best-of-reps after the warm-up, each rep
    block_until_ready'd so async dispatch cannot smear across reps."""
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    first = (time.perf_counter() - t0) * 1e6
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, (time.perf_counter() - t0) * 1e6)
    return first, best


def _grad_tree(smoke: bool):
    """A gradient-shaped pytree: repro-100m's param tree (reduced() dims
    under --smoke), filled with random values."""
    from repro import configs
    from repro.models import transformer

    cfg = configs.get_config("repro-100m")
    if smoke:
        cfg = cfg.reduced()
    shapes = jax.eval_shape(
        lambda: transformer.init(cfg, jax.random.PRNGKey(0)))
    leaves, treedef = jax.tree_util.tree_flatten(shapes)
    key = jax.random.PRNGKey(7)
    vals = [jax.random.normal(jax.random.fold_in(key, i), leaf.shape,
                              jnp.float32) for i, leaf in enumerate(leaves)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def main(smoke: bool = False, out_path: str = OUT_PATH):
    from repro.core import compression

    key = jax.random.PRNGKey(0)
    rows = []

    n_q = 1 << 14 if smoke else 1 << 20
    x = jax.random.normal(key, (n_q,))
    us = _time(lambda a: q_ops.quantize_dequantize(a, key, bits=8), x)
    # TPU estimate: pure-VPU 2 passes over 4B+4B read + 4B write / 819GB/s
    est = (x.size * 12) / HBM_BW * 1e6
    rows.append((f"quant_qdq_{n_q // 1024}K", us,
                 f"tpu_mem_bound_est={est:.1f}us"))

    seq = 128 if smoke else 1024
    q = jax.random.normal(key, (1, seq, 8, 128), jnp.float32)
    k = jax.random.normal(key, (1, seq, 2, 128), jnp.float32)
    v = jax.random.normal(key, (1, seq, 2, 128), jnp.float32)
    us = _time(lambda a, b, c: fa_ops.flash_attention(a, b, c, causal=True),
               q, k, v)
    flops = 2 * 2 * seq * seq * 8 * 128  # qk + av
    est = flops / PEAK_FLOPS_BF16 * 1e6
    rows.append((f"flash_attn_{seq}", us, f"tpu_mxu_est={est:.1f}us"))

    t_wkv = 64 if smoke else 512
    r = jax.random.normal(key, (1, t_wkv, 4, 64)) * 0.5
    kk = jax.random.normal(key, (1, t_wkv, 4, 64)) * 0.5
    vv = jax.random.normal(key, (1, t_wkv, 4, 64)) * 0.5
    lw = -jnp.exp(jax.random.normal(key, (1, t_wkv, 4, 64)) * 0.3 - 2.5)
    u = jax.random.normal(key, (4, 64)) * 0.1
    us = _time(lambda *a: wkv_ops.wkv6(*a)[0], r, kk, vv, lw, u)
    rows.append((f"wkv6_{t_wkv}", us, "chunked-scan"))

    # codec messaging tiers on the repro-100m gradient tree: per-leaf
    # (L dispatches + L params reductions + L padded messages), fused
    # flat buffer (one of each), and the ring's partitioned encode
    # (n_workers per-partition messages over one backing buffer)
    grads = _grad_tree(smoke)
    n_leaves = len(jax.tree_util.tree_leaves(grads))
    n_workers = 8
    cdc = compression.codec("rq8")
    us_leaf = _time(lambda t: cdc.tree_encode(t, key), grads)
    us_flat = _time(lambda t: cdc.tree_encode_flat(t, key), grads)
    us_part = _time(lambda t: cdc.tree_encode_partitioned(t, key,
                                                          n_workers),
                    grads)
    b_leaf = cdc.tree_wire_bytes(grads)
    b_flat = cdc.tree_wire_bytes_flat(grads)
    b_part = cdc.tree_wire_bytes_partitioned(grads, n_workers)
    tag = "reduced" if smoke else "100m"
    speedup = us_leaf[1] / us_flat[1]
    rows.append((f"tree_encode_per_leaf_{tag}", us_leaf,
                 f"wire_B={b_leaf:.0f},n_messages={n_leaves}"))
    rows.append((f"tree_encode_flat_{tag}", us_flat,
                 f"wire_B={b_flat:.0f},n_messages=1"))
    rows.append((f"tree_encode_partitioned_{tag}", us_part,
                 f"part_wire_B={b_part:.0f},n_parts={n_workers}"))

    print("# Kernel microbenchmarks (CPU interpret mode — correctness "
          "tier; us = steady state, first = compile + first dispatch)")
    print(f"{'name':30s} {'us_steady':>10s} {'first_ms':>9s}  derived")
    for name, (first, us), derived in rows:
        print(f"{name:30s} {us:10.0f} {first / 1e3:9.0f}  {derived}")
    print(f"# flat_vs_perleaf_speedup = {speedup:.2f}x (steady state; "
          ">= 1 means the fused path is no slower than per-leaf)")

    payload = []
    for name, (first, us), derived in rows:
        row = {"op": name, "us": round(us, 1),
               "first_call_us": round(first, 1), "est": derived}
        if name.startswith("tree_encode_flat"):
            row["flat_vs_perleaf_speedup"] = round(speedup, 3)
        payload.append(row)
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"# wrote {os.path.normpath(out_path)}")
    return ",".join(f"{n}={u:.0f}us" for n, (_, u), _ in rows)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes (CI-scale)")
    ap.add_argument("--out", default=OUT_PATH,
                    help="where to write BENCH_kernels.json")
    args = ap.parse_args()
    main(smoke=args.smoke, out_path=args.out)
