"""Virtual-cluster benchmark: time-to-loss under a 4x straggler.

Schedules sync-PS, async-PS, local-SGD(H), DSGD(ring), DCD/ECD
(compressed-delta gossip) and LAQ on the same
8-worker cluster (one 4x straggler, §4.1's Figure 4.1/4.2 setup), replays
every trace against REAL training (the §1.1.3 quadratic; ``--lm`` adds the
reduced repro-100m LM) with the fused ``rq4`` codec, and reports each
protocol's simulated makespan, applied updates, max staleness, wire
traffic, and time-to-loss — the Figure 4.3-style loss-vs-wall-clock sweep
the closed-form timelines could not produce.

The failure sweep adds time-to-loss rows under NAMED failure scenarios
(``lossy`` 10% message drop, ``crash_restart`` one mid-run crash +
checkpoint rejoin, ``churn`` a permanent departure + a mid-run join) —
each row carries its fault-ledger tallies and a ``loss_at_healthy_T``
column: the loss at the HEALTHY run's makespan, i.e. what the failure
cost at equal simulated wall-clock. Seeded fault plans make every row
deterministic, so the CI delta gate treats any drift as a semantics
change.

Emits machine-readable ``BENCH_cluster.json`` at the repo root; ``--smoke``
shrinks rounds/shapes to CI scale (the job uploads the JSON as an
artifact, so the benchmark cannot rot unnoticed).
"""
from __future__ import annotations

import argparse
import json
import math
import os

from repro import cluster, obs
from repro.cluster import faults

OUT_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                        "BENCH_cluster.json")

N = 8
STRAGGLER_FACTOR = 4.0


def run_quadratic_sweep(*, rounds: int, lr: float = 0.1,
                        codec: str = "rq4") -> list[dict]:
    spec = cluster.ClusterSpec(
        n_workers=N, t_compute=1.0,
        multipliers=cluster.straggler_multipliers(
            N, factor=STRAGGLER_FACTOR),
        t_lat=1e-2, t_tr=2e-3, size_mb=1.0, codec=codec)
    wl = cluster.quadratic_workload(n_workers=N)

    sync_tr = cluster.make_protocol("sync_ps").schedule(spec, rounds=rounds)
    traces = [
        sync_tr,
        # equal simulated wall-clock: async runs for sync's makespan
        cluster.make_protocol("async_ps").schedule(
            spec, horizon=sync_tr.makespan),
        cluster.make_protocol("local_sgd", period_h=8).schedule(
            spec, rounds=max(rounds // 8, 1)),
        cluster.make_protocol("dsgd").schedule(spec, rounds=rounds),
        # compressed decentralized tier: same deg(W) gossip sends, each
        # sized at the codec's measured delta wire bytes
        cluster.make_protocol("dcd").schedule(spec, rounds=rounds),
        cluster.make_protocol("ecd").schedule(spec, rounds=rounds),
        cluster.make_protocol("laq", skip=2).schedule(spec, rounds=rounds),
    ]
    results = [cluster.replay(t, wl, codec=codec, lr=lr,
                              eval_every=max(t.n_updates // 50, 1))
               for t in traces]
    target = results[0].final_loss   # sync's endpoint: who gets there first?
    rows = []
    for res in results:
        t_hit = res.time_to(target)
        rows.append({
            "workload": "quadratic",
            "protocol": res.protocol,
            "makespan_s": round(res.makespan, 3),
            "updates": res.updates_applied,
            "max_staleness": res.max_staleness,
            "wire_messages": res.n_wire_messages,
            "final_loss": round(res.final_loss, 5),
            # None (JSON null), not inf: the emitted file must stay
            # strict RFC-8259 JSON for jq/CI artifact consumers
            "t_to_sync_loss_s": round(t_hit, 3) if math.isfinite(t_hit)
                                else None,
        })
    return rows


def run_lm_sweep(*, rounds: int, smoke: bool, lr: float = 0.05,
                 codec: str = "rq4") -> list[dict]:
    spec = cluster.ClusterSpec(
        n_workers=N, t_compute=1.0,
        multipliers=cluster.straggler_multipliers(
            N, factor=STRAGGLER_FACTOR),
        t_lat=1e-2, t_tr=2e-3, size_mb=1.0, codec=codec)
    wl = cluster.lm_workload(smoke=smoke)
    rows = []
    for proto, kw, r in [("sync_ps", {}, rounds),
                         ("local_sgd", {"period_h": 2},
                          max(rounds // 2, 1)),
                         # the repro-100m LM under stragglers with
                         # compressed (difference-quantized) gossip
                         ("dcd", {}, rounds)]:
        tr = cluster.make_protocol(proto, **kw).schedule(spec, rounds=r)
        res = cluster.replay(tr, wl, codec=codec, lr=lr, eval_every=1)
        rows.append({
            "workload": wl.name,
            "protocol": res.protocol,
            "makespan_s": round(res.makespan, 3),
            "updates": res.updates_applied,
            "wire_messages": res.n_wire_messages,
            "final_loss": round(res.final_loss, 4),
        })
    return rows


def run_failure_sweep(*, rounds: int, lr: float = 0.1,
                      codec: str = "rq4") -> list[dict]:
    """Time-to-loss under the named failure scenarios of
    ``cluster.faults`` — sync-PS degrades via quorum (first 6 of 8),
    async-PS via bounded retry, DSGD via live-set mixing-matrix
    re-derivation. Every trace's fault ledger is cross-validated against
    its wire ledger before it is replayed."""
    spec = cluster.ClusterSpec(
        n_workers=N, t_compute=1.0,
        multipliers=cluster.straggler_multipliers(
            N, factor=STRAGGLER_FACTOR),
        t_lat=1e-2, t_tr=2e-3, size_mb=1.0, codec=codec)
    wl = cluster.quadratic_workload(n_workers=N)
    healthy = cluster.make_protocol("sync_ps").schedule(spec,
                                                        rounds=rounds)
    t_healthy = healthy.makespan
    scenarios = [
        ("lossy", faults.lossy_network(N, p_drop=0.1, seed=0),
         [("sync_ps", {"quorum": 6}), ("async_ps", {})]),
        ("crash_restart",
         faults.crash_restart(N, worker=1, t_down=0.25 * t_healthy,
                              t_up=0.5 * t_healthy, seed=0),
         [("sync_ps", {"quorum": 6}), ("async_ps", {})]),
        ("churn",
         faults.churn(N, departures=((N - 1, 0.3 * t_healthy),),
                      joins=((N - 2, 0.6 * t_healthy),), p_drop=0.05,
                      seed=0),
         [("dsgd", {})]),
        # PR-9 corruption / Byzantine tier: CRC-detected bit-flips +
        # NaN poison absorbed by the quorum, and the f=2 sign-flip
        # roster under the naive mean vs the robust trimmed mean
        ("corrupt_wire",
         faults.corrupt_wire(N, p_corrupt=0.1, p_poison=0.02, seed=0),
         [("sync_ps", {"quorum": 6})]),
        ("byzantine_mean",
         faults.byzantine_workers(N, f=2, mode="sign_flip", seed=0),
         [("sync_ps", {"aggregator": "mean"})]),
        ("byzantine_trimmed",
         faults.byzantine_workers(N, f=2, mode="sign_flip", seed=0),
         [("sync_ps", {"aggregator": "trimmed_mean"})]),
    ]
    rows = []
    for scenario, plan, protos in scenarios:
        for proto, kw in protos:
            p = cluster.make_protocol(proto, **kw)
            tr = (p.schedule(spec, horizon=t_healthy, plan=plan)
                  if proto == "async_ps"
                  else p.schedule(spec, rounds=rounds, plan=plan))
            tally = faults.validate(tr)
            res = cluster.replay(tr, wl, codec=codec, lr=lr,
                                 eval_every=max(tr.n_updates // 50, 1))
            rows.append({
                "workload": "quadratic",
                "protocol": res.protocol,
                "scenario": scenario,
                "makespan_s": round(res.makespan, 3),
                "updates": res.updates_applied,
                "wire_messages": res.n_wire_messages,
                "final_loss": round(res.final_loss, 5),
                "loss_at_healthy_T": round(res.loss_at(t_healthy), 5),
                "dropped": tally["dropped"],
                "retried": tally["retried"],
                "timed_out": tally["timed_out"],
                "rejoins": tally["rejoins"],
                "epochs": tally["epochs"],
                "corrupted": tally["corrupted"],
            })
    return rows


def export_timeline(trace_out: str, *, rounds: int) -> None:
    """Export the lossy sync-PS-quorum failure trace as a Perfetto
    timeline (the ISSUE-8 demo scenario through the same code path the
    sweeps run) — ``--trace-out`` turns this on."""
    from repro.obs import export as obs_export

    obs.enable()
    obs.tracer().reset()
    tr = obs_export.build_trace(protocol="sync_ps", rounds=rounds)
    faults.validate(tr)
    counts = obs_export.export_trace(tr, trace_out, into=obs.tracer())
    print(f"# wrote {trace_out} ({counts['wire_spans']} wire spans, "
          f"counts verified against the ledgers)")


def main(smoke: bool = False, lm: bool = False,
         out_path: str = OUT_PATH, trace_out: str = "") -> str:
    rounds = 8 if smoke else 40
    if trace_out:
        export_timeline(trace_out, rounds=rounds)
    rows = run_quadratic_sweep(rounds=rounds)
    rows += run_failure_sweep(rounds=rounds)
    if lm or smoke:   # smoke always exercises the LM replay path (tiny)
        rows += run_lm_sweep(rounds=2 if smoke else rounds // 4,
                             smoke=smoke or not lm)
    obs.stamp_rows(rows)

    print(f"# Virtual cluster: {N} workers, one {STRAGGLER_FACTOR:.0f}x "
          f"straggler, fused rq4 codec (time-to-loss at equal wall-clock)")
    print(f"{'workload':16s} {'protocol':10s} {'scenario':13s} "
          f"{'makespan':>9s} {'updates':>8s} {'stale':>6s} {'wire#':>7s} "
          f"{'loss':>9s} {'t@sync':>8s}")
    for r in rows:
        t_hit = r.get("t_to_sync_loss_s")
        print(f"{r['workload']:16s} {r['protocol']:10s} "
              f"{r.get('scenario', 'healthy'):13s} "
              f"{r['makespan_s']:9.2f} {r['updates']:8d} "
              f"{r.get('max_staleness', 0):6d} {r['wire_messages']:7d} "
              f"{r['final_loss']:9.4f} "
              f"{t_hit if t_hit is not None else float('nan'):8.2f}")

    with open(out_path, "w") as f:
        json.dump(rows, f, indent=2)
        f.write("\n")
    print(f"# wrote {os.path.normpath(out_path)}")
    return ",".join(f"{r['protocol']}={r['final_loss']}" for r in rows)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny rounds/shapes (CI-scale)")
    ap.add_argument("--lm", action="store_true",
                    help="add the repro-100m LM sweep (reduced dims)")
    ap.add_argument("--out", default=OUT_PATH,
                    help="where to write BENCH_cluster.json")
    ap.add_argument("--trace-out", default="",
                    help="also export the lossy sync-PS-quorum failure "
                         "trace as a Perfetto timeline JSON (enables "
                         "repro.obs)")
    args = ap.parse_args()
    main(smoke=args.smoke, lm=args.lm, out_path=args.out,
         trace_out=args.trace_out)
