"""Roofline analysis from the dry-run records (deliverable g).

Reads benchmarks/dryrun_results.jsonl (written by repro.launch.dryrun) and
derives, per (arch x input-shape) on the single-pod mesh:

  compute term    = HLO_dot_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = collective_bytes_per_device / ICI_link_bw

plus MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (prefill/decode), the
useful-compute ratio MODEL/HLO (catches remat + redundancy waste), the
dominant bottleneck, a what-would-move-it note, and the what-if collective
term under a gradient codec's MEASURED packed wire format
(repro.core.compression.Codec.wire_bytes — not an abstract bits ratio).

Byte caveat: XLA's `bytes accessed` counts while bodies once; we scale it by
the dot-FLOPs loop factor (trip-count-aware / body-once) — an approximation
recorded in EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import json
import os
from typing import Optional

from repro import configs
from repro.launch.mesh import HBM_BW, ICI_BW, ICI_LAT, PEAK_FLOPS_BF16
from repro.models.common import INPUT_SHAPES

RESULTS = os.path.join(os.path.dirname(__file__), "dryrun_results.jsonl")


def load_records(path: str = RESULTS, mesh: str = "16x16") -> dict:
    recs = {}
    if not os.path.exists(path):
        return recs
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            if r.get("mesh") == mesh:
                recs[(r["arch"], r["shape"])] = r   # last write wins
    return recs


def model_flops(arch: str, shape_name: str) -> float:
    cfg = configs.get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch          # decode: 1 token/seq


def compressed_collective_s(coll_bytes: float, codec_name: str, *,
                            elem_bytes: float = 4.0,
                            n_messages: int = 1) -> float:
    """Collective term if gradient sync shipped `codec_name`'s wire format.

    Uses the MEASURED Codec.wire_bytes of the packed payload (incl. params
    header and lane padding) for the element count implied by the HLO's
    collective bytes — not a hand-written bits ratio. `elem_bytes` is the
    wire dtype of the original collective (4 for fp32, 2 for the bf16
    programs dryrun compiles).

    Per-message accounting: each wire message pays the fixed ICI_LAT, so
    the term is wire/ICI_BW + n_messages * ICI_LAT. The fused flat-buffer
    codec tier ships ONE message per sync (n_messages=1, the default);
    per-leaf messaging would set n_messages to the gradient's leaf count,
    and the partitioned ring AllReduce (CSGDRingExchange's default wire
    pattern) sets n_messages = 2*(n_devices - 1) partition messages —
    what `derive` charges, since the per-device reducible bytes already
    reflect the bandwidth-optimal 2M(N-1)/N decomposition.
    """
    from repro.core import compression

    n_elements = max(1, int(coll_bytes / elem_bytes))
    wire = compression.codec(codec_name).wire_bytes_for(n_elements)
    return wire / ICI_BW + n_messages * ICI_LAT


def derive(rec: dict, *, grad_codec: Optional[str] = "rq8") -> dict:
    n_dev = rec["n_devices"]
    flops_dev = rec["dot_flops"]                  # per-device (trip-aware)
    body_once = max(rec.get("flops_body_once", 0.0), 1.0)
    loop_factor = max(1.0, flops_dev / body_once)
    bytes_dev = rec.get("bytes_accessed_body_once", 0.0) * loop_factor
    coll_dev = rec["collectives"]["total"]
    t_compute = flops_dev / PEAK_FLOPS_BF16
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    useful = mf / max(flops_dev * n_dev, 1.0)
    advice = {
        "compute": "raise MXU utilization: larger per-device batch/seq "
                   "tiles, fuse attention (flash kernel), drop remat "
                   "recompute on cheap blocks",
        "memory": "cut HBM traffic: bf16 activations end-to-end, fuse "
                  "elementwise chains, larger matmul tiles (reuse), "
                  "quantized KV cache",
        "collective": "cut bytes on the wire: gradient compression "
                      "(the paper's CSGD/EC-SGD), reduce-scatter instead "
                      "of all-reduce+all-gather, overlap collectives with "
                      "the scan body",
    }[dominant]
    out = {
        "arch": rec["arch"], "shape": rec["shape"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": mf, "hlo_flops_total": flops_dev * n_dev,
        "useful_ratio": useful, "advice": advice,
        "hbm_args_gib": rec["argument_size_in_bytes"] / 2**30,
        "hbm_temp_gib_per_dev": rec["temp_size_in_bytes"] / n_dev / 2**30,
    }
    if grad_codec is not None:
        # what-if: gradient compression only touches the reduction traffic
        # (all-reduce / reduce-scatter); all-gather of params, all-to-all
        # and permutes keep their fp32/bf16 bytes
        breakdown = rec["collectives"].get("collective_breakdown", {})
        reducible = breakdown.get("all-reduce", 0.0) \
            + breakdown.get("reduce-scatter", 0.0)
        rest = max(coll_dev - reducible, 0.0)
        # dryrun compiles the production programs in bf16 (2 B/element);
        # the compressed sync ships as a partitioned ring AllReduce:
        # 2(n-1) partition messages per device, each paying ICI_LAT
        comp = compressed_collective_s(reducible, grad_codec,
                                       elem_bytes=2.0,
                                       n_messages=2 * (n_dev - 1)) \
            if reducible > 0 else 0.0
        out["t_collective_compressed_s"] = rest / ICI_BW + comp
        out["grad_codec"] = grad_codec
        # what-if: replace the gradient sync entirely with DCD ring
        # gossip — deg(W)=2 neighbors each receive ONE fused compressed
        # delta of the reducible element count (wire measured, §5.1's
        # O(1)-in-N message count: 2 ICI_LAT per step, not 2(n-1))
        gossip_deg = 2
        per_nbr = compressed_collective_s(reducible, grad_codec,
                                          elem_bytes=2.0, n_messages=1) \
            if reducible > 0 else 0.0
        out["t_gossip_dcd_s"] = rest / ICI_BW + gossip_deg * per_nbr
        out["gossip_degree"] = gossip_deg
    return out


def full_table(mesh: str = "16x16") -> list:
    recs = load_records(mesh=mesh)
    rows = []
    for arch in configs.ASSIGNED:
        for shape in INPUT_SHAPES:
            if (arch, shape) in recs:
                rows.append(derive(recs[(arch, shape)]))
    return rows


def main():
    rows = full_table()
    if not rows:
        print("# roofline: no dry-run records found "
              "(run python -m repro.launch.dryrun --all first)")
        return "missing"
    print("# Roofline terms per (arch x shape), single-pod 16x16 "
          "(seconds/step; v5e constants; coll(rq8) = collective term under "
          "the measured rq8 packed wire format, shipped as a partitioned "
          "compressed ring AllReduce — 2(n-1) partition messages each "
          "paying ICI_LAT; per-leaf messaging would pay L per hop instead; "
          "dcd-gossip = the sync replaced by deg(W)=2 compressed-delta "
          "gossip sends, 2 ICI_LAT total)")
    print(f"{'arch':24s} {'shape':12s} {'compute':>10s} {'memory':>10s} "
          f"{'collect':>10s} {'coll(rq8)':>10s} {'dcd-gossip':>10s} "
          f"{'dominant':>10s} {'useful':>7s}")
    for r in rows:
        print(f"{r['arch']:24s} {r['shape']:12s} "
              f"{r['t_compute_s']:10.4f} {r['t_memory_s']:10.4f} "
              f"{r['t_collective_s']:10.4f} "
              f"{r.get('t_collective_compressed_s', 0.0):10.4f} "
              f"{r.get('t_gossip_dcd_s', 0.0):10.4f} "
              f"{r['dominant']:>10s} {r['useful_ratio']:7.2f}")
    dom = {}
    for r in rows:
        dom[r["dominant"]] = dom.get(r["dominant"], 0) + 1
    return ",".join(f"{k}={v}" for k, v in sorted(dom.items()))


if __name__ == "__main__":
    main()
