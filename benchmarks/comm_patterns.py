"""Benchmark for Figures 1.3-1.7 / 3.4-3.5 / 4.1-4.2 / 5.2-5.3: the
communication patterns under the §1.3 switch model, swept over worker count
and latency/bandwidth regimes."""
from __future__ import annotations

from repro.core import eventsim


def sweep(size_mb: float = 100.0):
    rows = []
    for n in (4, 8, 16, 64, 256):
        for (alpha, beta, regime) in ((1e-4, 1e-2, "bw-bound"),
                                      (1e-2, 1e-4, "lat-bound")):
            ps = eventsim.single_ps_makespan(n, size_mb, t_lat=alpha,
                                             t_tr=beta)
            ar = eventsim.ring_allreduce_makespan(n, size_mb, t_lat=alpha,
                                                  t_tr=beta)
            ar_nopart = eventsim.ring_allreduce_makespan(
                n, size_mb, t_lat=alpha, t_tr=beta, partitioned=False)
            # rq8's measured packed wire format (~4x vs fp32, incl. header)
            csgd = eventsim.ring_allreduce_makespan(
                n, size_mb, t_lat=alpha, t_tr=beta, codec="rq8")
            dec = eventsim.decentralized_makespan(n, size_mb, t_lat=alpha,
                                                  t_tr=beta)
            rows.append((n, regime, ps, ar, ar_nopart, csgd, dec))
    return rows


def async_vs_sync(n: int = 8):
    """Figure 4.1/4.2: updates per second, sync barrier vs async PS."""
    t_compute = [1.0] * (n - 1) + [4.0]       # one straggler
    sync = eventsim.sync_ps_throughput(n, t_compute_max=max(t_compute),
                                       t_lat=0.01, t_tr=0.002, size=1.0)
    updates = eventsim.async_ps_timeline(n, t_compute=t_compute, t_lat=0.01,
                                         t_tr=0.002, size=1.0, horizon=200.0)
    async_tput = len(updates) / 200.0
    max_stale = max(s for _, _, s in updates)
    return sync, async_tput, max_stale


def main():
    print("# Communication patterns under the Section 1.3 switch model "
          "(makespan, seconds)")
    print(f"{'N':>4s} {'regime':>9s} {'PS':>10s} {'ringAR':>10s} "
          f"{'AR-nopart':>10s} {'CSGD(4x)':>10s} {'DSGD':>10s}")
    for n, regime, ps, ar, nop, csgd, dec in sweep():
        print(f"{n:4d} {regime:>9s} {ps:10.3f} {ar:10.3f} {nop:10.3f} "
              f"{csgd:10.3f} {dec:10.3f}")
    sync, asyn, stale = async_vs_sync()
    print(f"\n# Figure 4.1/4.2 — sync vs async PS with one 4x straggler")
    print(f"sync updates/s {sync:.2f} | async updates/s {asyn:.2f} "
          f"(speedup {asyn / sync:.2f}x, max staleness {stale})")
    return f"async_speedup={asyn / sync:.2f}"


if __name__ == "__main__":
    main()
