"""Benchmark for Figures 1.3-1.7 / 3.4-3.5 / 4.1-4.2 / 5.2-5.3: the
communication patterns under the §1.3 switch model, swept over worker count
and latency/bandwidth regimes.

Emits machine-readable ``BENCH_comm.json`` at the repo root (one row per
(n, regime) cell plus the async-vs-sync Figure 4.1/4.2 summary); ``--smoke``
shrinks the sweep to CI scale, where the job uploads the JSON as an
artifact — same contract as kernels_bench / cluster_bench.
"""
from __future__ import annotations

import argparse
import json
import os

from repro import obs
from repro.core import eventsim, mixing

OUT_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                        "BENCH_comm.json")


def sweep(size_mb: float = 100.0, *, smoke: bool = False):
    rows = []
    for n in ((4, 8) if smoke else (4, 8, 16, 64, 256)):
        for (alpha, beta, regime) in ((1e-4, 1e-2, "bw-bound"),
                                      (1e-2, 1e-4, "lat-bound")):
            ps = eventsim.single_ps_makespan(n, size_mb, t_lat=alpha,
                                             t_tr=beta)
            ar = eventsim.ring_allreduce_makespan(n, size_mb, t_lat=alpha,
                                                  t_tr=beta)
            ar_nopart = eventsim.ring_allreduce_makespan(
                n, size_mb, t_lat=alpha, t_tr=beta, partitioned=False)
            # rq8's measured packed wire format (~4x vs fp32, incl.
            # header), as the partitioned compressed ring (2(n-1) hops of
            # size/n — CSGDRingExchange's default) vs the monolithic
            # chain ((n-1) full-size hops)
            csgd = eventsim.csgd_ring_makespan(
                n, size_mb, t_lat=alpha, t_tr=beta, codec="rq8")
            csgd_mono = eventsim.csgd_ring_makespan(
                n, size_mb, t_lat=alpha, t_tr=beta, codec="rq8",
                partitioned=False)
            dec = eventsim.decentralized_makespan(n, size_mb, t_lat=alpha,
                                                  t_tr=beta)
            # beyond-ring topology: the torus pays deg(W)=4 sends
            dec_torus = eventsim.decentralized_makespan(
                n, size_mb, t_lat=alpha, t_tr=beta,
                w=mixing.torus_2d(*mixing.near_square_factors(n)))
            # DCD-PSGD: same 2 ring-gossip messages, but each is the
            # measured rq4 wire size of the quantized delta (~8x fewer
            # bytes) — latency term unchanged, Figure 3.4/3.5 on §5.1
            dcd = eventsim.decentralized_makespan(
                n, size_mb, t_lat=alpha, t_tr=beta, codec="rq4")
            rows.append((n, regime, ps, ar, ar_nopart, csgd, csgd_mono,
                         dec, dec_torus, dcd))
    return rows


def async_vs_sync(n: int = 8):
    """Figure 4.1/4.2: updates per second, sync barrier vs async PS."""
    t_compute = [1.0] * (n - 1) + [4.0]       # one straggler
    sync = eventsim.sync_ps_throughput(n, t_compute_max=max(t_compute),
                                       t_lat=0.01, t_tr=0.002, size=1.0)
    updates = eventsim.async_ps_timeline(n, t_compute=t_compute, t_lat=0.01,
                                         t_tr=0.002, size=1.0, horizon=200.0)
    async_tput = len(updates) / 200.0
    max_stale = max(s for _, _, s in updates)
    return sync, async_tput, max_stale


def gossip_compression_row(size_mb: float = 100.0) -> dict:
    """Measured per-neighbor gossip wire MB: fp32 DSGD vs the DCD rq4
    compressed delta — the ≤1/4-of-fp32 acceptance number, reported in
    BENCH_comm.json and asserted in tests/test_dcd.py."""
    fp32 = eventsim.gossip_wire_mb_per_worker(size_mb, degree=2)
    dcd = eventsim.gossip_wire_mb_per_worker(size_mb, degree=2,
                                             codec="rq4")
    return {"fig": "5.dcd", "gossip_fp32_mb": round(fp32, 4),
            "gossip_dcd_rq4_mb": round(dcd, 4),
            "dcd_wire_ratio": round(dcd / fp32, 4)}


def main(smoke: bool = False, out_path: str = OUT_PATH):
    print("# Communication patterns under the Section 1.3 switch model "
          "(makespan, seconds; CSGD = partitioned compressed ring, "
          "CSGD-mono = monolithic (n-1)-full-hop chain, DCD = ring "
          "gossip shipping rq4 compressed deltas)")
    print(f"{'N':>4s} {'regime':>9s} {'PS':>10s} {'ringAR':>10s} "
          f"{'AR-nopart':>10s} {'CSGD(4x)':>10s} {'CSGD-mono':>10s} "
          f"{'DSGD':>10s} {'DSGD-torus':>10s} {'DCD(rq4)':>10s}")
    payload = []
    for (n, regime, ps, ar, nop, csgd, csgdm, dec, dect,
         dcd) in sweep(smoke=smoke):
        print(f"{n:4d} {regime:>9s} {ps:10.3f} {ar:10.3f} {nop:10.3f} "
              f"{csgd:10.3f} {csgdm:10.3f} {dec:10.3f} {dect:10.3f} "
              f"{dcd:10.3f}")
        payload.append({"n": n, "regime": regime, "ps": round(ps, 4),
                        "ring_ar": round(ar, 4),
                        "ar_nopart": round(nop, 4),
                        "csgd_rq8": round(csgd, 4),
                        "csgd_rq8_mono": round(csgdm, 4),
                        "dsgd_ring": round(dec, 4),
                        "dsgd_torus": round(dect, 4),
                        "dcd_rq4": round(dcd, 4)})
    gossip = gossip_compression_row()
    print(f"\n# DCD compressed gossip wire (per worker per mix, ring): "
          f"fp32 {gossip['gossip_fp32_mb']:.2f} MB -> rq4 "
          f"{gossip['gossip_dcd_rq4_mb']:.2f} MB "
          f"({gossip['dcd_wire_ratio']:.3f}x)")
    payload.append(gossip)
    sync, asyn, stale = async_vs_sync()
    print(f"\n# Figure 4.1/4.2 — sync vs async PS with one 4x straggler")
    print(f"sync updates/s {sync:.2f} | async updates/s {asyn:.2f} "
          f"(speedup {asyn / sync:.2f}x, max staleness {stale})")
    payload.append({"fig": "4.1/4.2", "sync_updates_per_s": round(sync, 4),
                    "async_updates_per_s": round(asyn, 4),
                    "max_staleness": stale})
    obs.stamp_rows(payload)
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"# wrote {os.path.normpath(out_path)}")
    return f"async_speedup={asyn / sync:.2f}"


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small-N sweep (CI-scale)")
    ap.add_argument("--out", default=OUT_PATH,
                    help="where to write BENCH_comm.json")
    args = ap.parse_args()
    main(smoke=args.smoke, out_path=args.out)
