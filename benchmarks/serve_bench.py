"""Serving benchmark: continuous batching vs the gang-scheduled
baseline, plus a live checkpoint hot-swap row.

Three scenarios over the same synthetic mixed-length heavy-traffic
workload (fixed prompt length, per-request generation lengths cycling
``mixed_gen`` — the spread that makes a static batch hold finished
slots hostage until the longest member drains):

  static      the old gang-scheduled loop: admit ``slots`` requests,
              decode until ALL finish, repeat
  continuous  in-flight batching: a finished sequence frees its slot
              mid-decode and the next queued request is spliced in
  hotswap     continuous serving while a compressed (rq8, CRC-framed)
              checkpoint is published mid-decode; the row records zero
              dropped requests and whether post-swap decode is
              BIT-identical to a cold start from the same published
              checkpoint (the bench exits 1 if either fails — the
              correctness half is not left to the warn-only delta gate)

Rows share the BENCH_*.json conventions (identity = ``op``/``scenario``;
``tokens_per_s`` is gated as bigger-is-better by its ``_per_s`` suffix;
``vs_static_speedup`` likewise). Emits ``BENCH_serve.json`` at the repo
root; ``--smoke`` shrinks the workload to CI scale and CI diffs the
result against the committed ``BENCH_serve_smoke.json`` with
``bench_delta.py`` (warn-only: serving throughput is wall-clock, not a
closed form, so drift warns instead of blocking).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

import jax
import numpy as np

from repro import obs, serve
from repro.models import transformer_scan

OUT_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                        "BENCH_serve.json")


def workload_config(*, smoke: bool) -> serve.ServeConfig:
    """The mixed-length workload both throughput scenarios share."""
    if smoke:
        return serve.ServeConfig(slots=4, max_len=24, n_requests=12,
                                 prompt_len=4, mixed_gen=(2, 4, 12))
    return serve.ServeConfig(slots=4, max_len=64, n_requests=24,
                             prompt_len=4, mixed_gen=(4, 8, 48))


def run_throughput(cfg: serve.ServeConfig) -> dict:
    rows = {}
    params = None
    for mode in ("static", "continuous"):
        mcfg = dataclasses.replace(cfg, mode=mode)
        eng = serve.Engine(mcfg, params=params)
        params = eng.params          # identical params across modes
        rows[mode] = serve.run(mcfg, engine=eng).row(op="serve",
                                                     scenario=mode)
    rows["continuous"]["vs_static_speedup"] = round(
        rows["continuous"]["tokens_per_s"] / rows["static"]["tokens_per_s"],
        3)
    return rows


def run_hotswap(cfg: serve.ServeConfig) -> dict:
    """Continuous serving across a mid-decode compressed-checkpoint
    swap; verifies the two acceptance properties inline."""
    cfg = dataclasses.replace(cfg, mode="continuous")
    eng = serve.Engine(cfg)
    channel = serve.CheckpointChannel()
    eng.subscribe(channel)
    reqs = serve.synthetic_requests(cfg)
    eng.warmup(sorted({len(r.tokens) for r in reqs}))

    import time
    eng._t0 = time.monotonic()
    for r in reqs:
        eng.submit(r.tokens, r.max_new_tokens, rid=r.rid)
    for _ in range(3):               # decode a while on the boot params
        eng.step()
    trained = transformer_scan.init(eng.model_cfg,
                                    jax.random.PRNGKey(2024))
    pub = channel.publish(trained, step=1,
                          codec=cfg.checkpoint_codec)
    eng.run()
    jax.block_until_ready(eng._tokens)
    stats = eng.stats()

    # post-swap decode must be bit-identical to a cold start from the
    # SAME published wire message
    probe = np.arange(cfg.prompt_len, dtype=np.int32) % eng.model_cfg.vocab
    rid_hot = eng.submit(probe, 8)
    eng.run()
    cold = serve.Engine(cfg, params=serve.CheckpointChannel.decode(pub))
    cold.warmup([cfg.prompt_len])
    rid_cold = cold.submit(probe, 8)
    cold.run()
    bit_identical = (eng.result(rid_hot).tokens
                     == cold.result(rid_cold).tokens)

    row = {
        "op": "serve", "scenario": "hotswap",
        "requests": stats["completed"],
        "decode_steps": stats["decode_steps"],
        "total_tokens": stats["generated_tokens"],
        "tokens_per_s": round(stats["tokens_per_s"], 2),
        "p50_ms": round(stats["p50_ms"], 2),
        "p99_ms": round(stats["p99_ms"], 2),
        "swaps": stats["swaps"],
        "dropped": stats["dropped"],
        "rejected": stats["rejected"],
        "ckpt_wire_kb": round(pub.wire_bytes / 1e3, 1),
        "bit_identical_post_swap": bool(bit_identical),
    }
    ok = (stats["swaps"] == 1 and stats["dropped"] == 0
          and stats["completed"] == cfg.n_requests and bit_identical)
    return row, ok


def main(*, smoke: bool, out_path: str) -> int:
    cfg = workload_config(smoke=smoke)
    through = run_throughput(cfg)
    hot_row, hot_ok = run_hotswap(cfg)
    rows = [through["static"], through["continuous"], hot_row]
    obs.stamp_rows(rows)

    speedup = through["continuous"]["vs_static_speedup"]
    print(f"# serve: {cfg.arch} (reduced), slots={cfg.slots}, "
          f"{cfg.n_requests} requests, prompt={cfg.prompt_len}, "
          f"gen={cfg.mixed_gen}")
    print(f"{'scenario':12s} {'tok/s':>8s} {'steps':>6s} {'p50ms':>8s} "
          f"{'p99ms':>8s} {'drop':>5s}")
    for r in rows:
        print(f"{r['scenario']:12s} {r['tokens_per_s']:8.1f} "
              f"{r['decode_steps']:6d} {r['p50_ms']:8.1f} "
              f"{r['p99_ms']:8.1f} {r['dropped']:5d}")
    print(f"# continuous vs static: {speedup:.2f}x | hot-swap "
          f"bit-identical={hot_row['bit_identical_post_swap']} "
          f"dropped={hot_row['dropped']} "
          f"wire={hot_row['ckpt_wire_kb']}kB")

    with open(out_path, "w") as f:
        json.dump(rows, f, indent=2)
        f.write("\n")
    print(f"# wrote {os.path.normpath(out_path)}")

    if not hot_ok:
        print("::error::serve_bench: hot-swap scenario failed "
              "(drop/swap/bit-identity)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload (CI-scale)")
    ap.add_argument("--out", default=OUT_PATH,
                    help="where to write BENCH_serve.json")
    args = ap.parse_args()
    sys.exit(main(smoke=args.smoke, out_path=args.out))
