"""Benchmark harness entry point — one module per paper table/figure:

  table1_1        Table 1.1  iterations-to-eps + comm cost per relaxation
  table1_2        Table 1.2  GD/SGD/mb-SGD iteration vs query complexity
  comm_patterns   Figures 1.3-1.7, 3.4/3.5, 4.1/4.2, 5.2/5.3 (switch model)
  cluster_bench   Figure 4.3-style time-to-loss on the virtual cluster
                  (sync/async/local-SGD/DSGD/LAQ under a 4x straggler)
  kernels_bench   Pallas kernel micro-benchmarks (interpret tier)
  roofline        Deliverable (g): per-(arch x shape) roofline terms from
                  the compiled dry-run records

Prints one ``name,us_per_call,derived`` CSV line per benchmark (wall time =
time to produce the table; the tables themselves go to stdout above it).
"""
from __future__ import annotations

import time


def main() -> None:
    from benchmarks import (cluster_bench, comm_patterns, kernels_bench,
                            roofline, table1_1, table1_2)
    csv_lines = []
    for name, mod in [("table1_1", table1_1), ("table1_2", table1_2),
                      ("comm_patterns", comm_patterns),
                      ("cluster_bench", cluster_bench),
                      ("kernels_bench", kernels_bench),
                      ("roofline", roofline)]:
        print(f"\n===== {name} =====")
        t0 = time.time()
        derived = mod.main()
        us = (time.time() - t0) * 1e6
        csv_lines.append(f"{name},{us:.0f},{derived}")
    print("\n# CSV")
    for line in csv_lines:
        print(line)


if __name__ == "__main__":
    main()
