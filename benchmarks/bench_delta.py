"""Bench-delta gate: diff fresh smoke benchmark numbers against the
committed baseline and ANNOTATE (never fail) on regressions.

CI runs ``kernels_bench.py --smoke --out <fresh>`` and then

    python benchmarks/bench_delta.py --baseline BENCH_kernels_smoke.json \
        --fresh <fresh> [--threshold 2.0]

Ops present in both files are compared on their steady-state ``us``; any
fresh/baseline ratio above the threshold prints a GitHub Actions
``::warning::`` annotation (CI machines vary in speed, so this warns
rather than fails — the point is that the next flat-path-style compute
regression is VISIBLE at PR time instead of landing silently, the way
PR 2's 2.3x tree_encode_flat regression did). Exit code is always 0;
``--strict`` flips regressions to exit 1 for local use.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.join(os.path.dirname(__file__), os.pardir)
DEFAULT_BASELINE = os.path.join(REPO, "BENCH_kernels_smoke.json")


def load(path: str) -> dict:
    with open(path) as f:
        rows = json.load(f)
    return {r["op"]: r for r in rows}


def compare(baseline: dict, fresh: dict, threshold: float) -> list:
    """[(op, base_us, fresh_us, ratio)] for every op above threshold."""
    regressions = []
    for op, row in fresh.items():
        if op not in baseline:
            continue
        base_us = float(baseline[op]["us"])
        fresh_us = float(row["us"])
        if base_us <= 0:
            continue
        ratio = fresh_us / base_us
        if ratio > threshold:
            regressions.append((op, base_us, fresh_us, ratio))
    return regressions


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--fresh", required=True)
    ap.add_argument("--threshold", type=float, default=2.0,
                    help="warn when fresh/baseline exceeds this ratio")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on regressions (local use; CI warns only)")
    args = ap.parse_args()

    if not os.path.exists(args.baseline):
        print(f"::notice::bench_delta: no baseline at {args.baseline}; "
              "skipping comparison")
        return 0
    baseline = load(args.baseline)
    fresh = load(args.fresh)
    shared = sorted(set(baseline) & set(fresh))
    print(f"# bench_delta: {len(shared)} shared ops "
          f"(threshold {args.threshold:.1f}x)")
    for op in shared:
        b, f = float(baseline[op]["us"]), float(fresh[op]["us"])
        ratio = f / b if b > 0 else float("inf")
        print(f"{op:32s} base={b:10.0f}us fresh={f:10.0f}us "
              f"ratio={ratio:5.2f}x")
    regressions = compare(baseline, fresh, args.threshold)
    for op, b, f, ratio in regressions:
        print(f"::warning::bench regression: {op} {ratio:.2f}x slower "
              f"than baseline ({b:.0f}us -> {f:.0f}us)")
    if not regressions:
        print("# no regressions above threshold")
    return 1 if (regressions and args.strict) else 0


if __name__ == "__main__":
    sys.exit(main())
