"""Bench-delta gate: diff fresh smoke benchmark numbers against a
committed baseline and flag regressions — for EVERY benchmark family,
not just the kernels.

CI runs each benchmark with ``--smoke`` and then

    python benchmarks/bench_delta.py --baseline BENCH_kernels_smoke.json \
        --fresh BENCH_kernels.json [--threshold 2.0]
    python benchmarks/bench_delta.py --baseline BENCH_comm_smoke.json \
        --fresh BENCH_comm.json
    python benchmarks/bench_delta.py --baseline BENCH_cluster_smoke.json \
        --fresh BENCH_cluster.json

Rows are matched on their identity fields (``op`` for the kernels file,
``workload``/``protocol`` for the cluster file, ``n``/``regime``/``fig``
for the comm file — whichever are present), and EVERY shared numeric
metric is compared. Any fresh/baseline ratio above the threshold prints
a GitHub Actions ``::warning::`` annotation, and ``--strict`` flips
regressions to exit 1. The comm/cluster numbers are deterministic
closed forms — any drift at all means the semantics changed — so CI
runs those two families with ``--strict`` (a semantic change must
regenerate the committed smoke baseline in the same PR); the
wall-clock kernels family is also strict but at a generous threshold,
since CI machines vary in speed. The point is that the next
flat-path-style compute regression, or a silent 2x makespan/loss jump
in the simulated families, BLOCKS at PR time instead of landing
silently, the way PR 2's 2.3x tree_encode_flat regression did.

``first_call_us`` is excluded: it is dominated by compile time, whose
variance would drown the steady-state signal the gate exists for.
``schema_version`` (and the string ``run_id``) are row identity stamps
from ``repro.obs.runinfo``, not measurements, and are excluded too.

Rows may GAIN metric fields over time (e.g. the telemetry tier adding
columns): fresh-only metrics are announced with a ``::notice::`` and
skipped — only metrics present in BOTH files gate. Metrics that vanish
from the fresh file are announced the same way (a rename would
otherwise silently stop gating).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.join(os.path.dirname(__file__), os.pardir)
DEFAULT_BASELINE = os.path.join(REPO, "BENCH_kernels_smoke.json")

# identity fields, in display order; a row's key is whichever it carries
KEY_FIELDS = ("op", "workload", "protocol", "scenario", "fig", "n",
              "regime")
EXCLUDED_METRICS = {"first_call_us", "schema_version"}
# bigger-is-better metrics regress DOWNWARD (a 2x drop in a speedup or a
# throughput is the regression; a 2x rise is an improvement)
HIGHER_IS_BETTER = ("_speedup", "_per_s", "updates")


def regression_ratio(name: str, base: float, fresh: float) -> float:
    """>1 means worse: slowdown for time-like metrics, shrinkage for
    bigger-is-better ones."""
    if name.endswith(HIGHER_IS_BETTER):
        return base / fresh if fresh > 0 else float("inf")
    return fresh / base


def row_key(row: dict) -> str:
    return "/".join(str(row[k]) for k in KEY_FIELDS if k in row)


def metrics(row: dict) -> dict:
    """Every comparable numeric field of a row (identity fields and the
    compile-time column excluded)."""
    return {k: float(v) for k, v in row.items()
            if k not in KEY_FIELDS and k not in EXCLUDED_METRICS
            and isinstance(v, (int, float)) and not isinstance(v, bool)}


def load(path: str) -> dict:
    with open(path) as f:
        rows = json.load(f)
    return {row_key(r): r for r in rows if row_key(r)}


def compare(baseline: dict, fresh: dict, threshold: float) -> list:
    """[(key, metric, base, fresh, ratio)] for every shared metric whose
    fresh/baseline ratio exceeds the threshold. Metrics present on only
    one side never gate (rows are allowed to gain columns)."""
    regressions = []
    for key, row in fresh.items():
        if key not in baseline:
            continue
        base_m = metrics(baseline[key])
        for name, fresh_v in metrics(row).items():
            base_v = base_m.get(name)
            if base_v is None or base_v <= 0:
                continue
            ratio = regression_ratio(name, base_v, fresh_v)
            if ratio > threshold:
                regressions.append((key, name, base_v, fresh_v, ratio))
    return regressions


def schema_drift(baseline: dict, fresh: dict) -> tuple:
    """(fresh_only, baseline_only) metric names across the shared rows —
    columns that appeared since the baseline was committed (tolerated,
    announced) or disappeared from the fresh run (announced: a renamed
    metric silently stops gating otherwise)."""
    fresh_only: set = set()
    base_only: set = set()
    for key, row in fresh.items():
        if key not in baseline:
            continue
        b, f = set(metrics(baseline[key])), set(metrics(row))
        fresh_only |= f - b
        base_only |= b - f
    return sorted(fresh_only), sorted(base_only)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--fresh", required=True)
    ap.add_argument("--threshold", type=float, default=2.0,
                    help="warn when fresh/baseline exceeds this ratio")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on regressions (CI uses this for every "
                         "family)")
    args = ap.parse_args()

    if not os.path.exists(args.baseline):
        print(f"::notice::bench_delta: no baseline at {args.baseline}; "
              "skipping comparison")
        return 0
    baseline = load(args.baseline)
    fresh = load(args.fresh)
    shared = sorted(set(baseline) & set(fresh))
    print(f"# bench_delta: {os.path.basename(args.baseline)} vs "
          f"{os.path.basename(args.fresh)} — {len(shared)} shared rows "
          f"(threshold {args.threshold:.1f}x)")
    for key in shared:
        base_m = metrics(baseline[key])
        both = [(m, base_m[m], v) for m, v in metrics(fresh[key]).items()
                if base_m.get(m, 0) > 0]
        if not both:
            continue
        # one line per row: its worst-moving metric
        m, b, f = max(both, key=lambda t: regression_ratio(*t))
        print(f"{key:40s} worst={m:20s} base={b:12.4f} fresh={f:12.4f} "
              f"ratio={regression_ratio(m, b, f):5.2f}x")
    fresh_only, base_only = schema_drift(baseline, fresh)
    if fresh_only:
        print(f"::notice::bench_delta: fresh-only metrics (tolerated, "
              f"not gated): {', '.join(fresh_only)}")
    if base_only:
        print(f"::notice::bench_delta: metrics missing from fresh rows "
              f"(no longer gated): {', '.join(base_only)}")
    regressions = compare(baseline, fresh, args.threshold)
    for key, m, b, f, ratio in regressions:
        print(f"::warning::bench regression: {key}:{m} {ratio:.2f}x over "
              f"baseline ({b:.4f} -> {f:.4f})")
    if not regressions:
        print("# no regressions above threshold")
    return 1 if (regressions and args.strict) else 0


if __name__ == "__main__":
    sys.exit(main())
