"""Regenerate the EXPERIMENTS.md §Dry-run and §Roofline markdown tables
from benchmarks/dryrun_results.jsonl. Run after a fresh dry-run sweep."""
from __future__ import annotations

import json
import sys

from repro import configs
from repro.models.common import INPUT_SHAPES

sys.path.insert(0, ".")
from benchmarks import roofline  # noqa: E402


def dryrun_table(mesh: str) -> str:
    recs = roofline.load_records(mesh=mesh)
    lines = [
        f"| arch | shape | dot FLOPs/dev | coll bytes/dev | temp GiB/dev "
        f"| args GiB/dev | compile s |",
        "|---|---|---|---|---|---|---|",
    ]
    for arch in configs.ASSIGNED:
        for shape in INPUT_SHAPES:
            r = recs.get((arch, shape))
            if not r:
                lines.append(f"| {arch} | {shape} | MISSING | | | | |")
                continue
            nd = r["n_devices"]
            lines.append(
                f"| {arch} | {shape} | {r['dot_flops']:.2e} | "
                f"{r['collectives']['total']:.2e} | "
                f"{r['temp_size_in_bytes'] / nd / 2**30:.2f} | "
                f"{r['argument_size_in_bytes'] / 2**30:.2f} | "
                f"{r['compile_s']:.0f} |")
    return "\n".join(lines)


def roofline_table() -> str:
    rows = roofline.full_table()
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant "
        "| MODEL/HLO |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.4f} | "
            f"{r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} |")
    return "\n".join(lines)


def fit_check(mesh: str = "16x16") -> str:
    recs = roofline.load_records(mesh=mesh)
    bad = []
    for (arch, shape), r in recs.items():
        per_dev = (r["temp_size_in_bytes"] / r["n_devices"]
                   + r["argument_size_in_bytes"]) / 2**30
        if per_dev > 16.0:
            bad.append((arch, shape, per_dev))
    if not bad:
        return ("All combinations fit: max per-device (temp/devices + args) "
                + f"= {max((r['temp_size_in_bytes']/r['n_devices'] + r['argument_size_in_bytes'])/2**30 for r in recs.values()):.2f}"
                + " GiB < 16 GiB HBM.")
    return "OVER HBM: " + ", ".join(f"{a}x{s}={g:.1f}GiB" for a, s, g in bad)


if __name__ == "__main__":
    print("## Single-pod 16x16\n")
    print(dryrun_table("16x16"))
    print("\n## Multi-pod 2x16x16\n")
    print(dryrun_table("2x16x16"))
    print("\n## Roofline\n")
    print(roofline_table())
    print("\n## HBM fit\n")
    print(fit_check())
