"""Benchmark for Table 1.2: GD vs SGD vs mb-SGD iteration AND query
complexity, measured empirically on the quadratic testbed and compared with
the closed forms."""
from __future__ import annotations

import numpy as np

from repro.core import parallel, theory


def iterations_to_eps(res, eps: float) -> int:
    g = np.asarray(res.grad_norms)
    idx = np.nonzero(g <= eps)[0]
    return int(idx[0]) + 1 if idx.size else -1


def run(eps: float = 5e-3, steps: int = 1500):
    m = 1024  # dataset size of the testbed
    batch = 4
    rows = []
    gd = parallel.run_quadratic("gd", steps=300, lr=0.5)
    sgd = parallel.run_quadratic("sgd", steps=steps, lr=0.1, batch=1)
    mb = parallel.run_quadratic("mbsgd", n_workers=8, steps=steps, lr=0.1,
                                batch=batch)
    it_gd = iterations_to_eps(gd, eps)
    it_sgd = iterations_to_eps(sgd, eps)
    it_mb = iterations_to_eps(mb, eps)
    rows.append(("GD", it_gd, it_gd * m))
    rows.append(("SGD", it_sgd, it_sgd * 1))
    rows.append(("mb-SGD(B=32)", it_mb, it_mb * batch * 8))
    return rows


def main():
    print("# Table 1.2 — iteration vs query complexity (quadratic testbed)")
    print(f"{'algorithm':14s} {'iters_to_eps':>12s} {'queries':>10s}")
    parts = []
    for name, iters, queries in run():
        print(f"{name:14s} {iters:12d} {queries:10d}")
        parts.append(f"{name}:q={queries}")
    # the paper's point: SGD >> GD in iterations but << GD in queries
    return ",".join(parts)


if __name__ == "__main__":
    main()
