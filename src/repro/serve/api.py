"""Programmatic serving entry point: ``serve.run(ServeConfig) -> ServeResult``.

The CLI (``repro.launch.serve``), the example
(``examples/serve_batched.py``) and the benchmark
(``benchmarks/serve_bench.py``) are all thin clients of this one
function — no more shelling through argv lists to reuse the serving
loop. ``run`` builds an ``Engine``, generates the synthetic mixed-
length workload the config describes, drives it to completion and
returns a structured result (throughput, latency percentiles, the
per-request completions, and the generated token streams).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import numpy as np

from repro import obs
from repro.serve.channel import CheckpointChannel
from repro.serve.engine import Completion, Engine, Request, ServeConfig

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ServeResult:
    """What a serve run measured (the machine-readable return value).

    completions: rid -> Completion (token streams + per-request latency)
    counters:    the engine's admitted/completed/rejected/dropped/swap
                 tallies
    """

    config: ServeConfig
    completions: dict
    counters: dict
    wall_s: float
    decode_steps: int
    total_tokens: int
    tokens_per_s: float
    p50_ms: float
    p99_ms: float

    @property
    def n_completed(self) -> int:
        return len(self.completions)

    def row(self, **identity) -> dict:
        """A BENCH_serve.json-shaped row (identity fields first)."""
        r = dict(identity)
        r.update({
            "requests": self.n_completed,
            "decode_steps": self.decode_steps,
            "total_tokens": self.total_tokens,
            "tokens_per_s": round(self.tokens_per_s, 2),
            "p50_ms": round(self.p50_ms, 2),
            "p99_ms": round(self.p99_ms, 2),
            "dropped": self.counters["dropped"],
            "rejected": self.counters["rejected"],
        })
        return r


def synthetic_requests(cfg: ServeConfig) -> list[Request]:
    """The deterministic mixed-length heavy-traffic workload: fixed
    prompt length (one compiled prefill), per-request generation
    lengths cycling through ``mixed_gen`` (or uniform ``gen_tokens``) —
    the length spread that makes gang-scheduled batches waste slots."""
    rng = np.random.default_rng(cfg.seed)
    gens = (list(cfg.mixed_gen) or [cfg.gen_tokens])
    reqs = []
    for i in range(cfg.n_requests):
        toks = rng.integers(0, _vocab(cfg), size=cfg.prompt_len,
                            dtype=np.int64).astype(np.int32)
        reqs.append(Request(i, toks, int(gens[i % len(gens)])))
    return reqs


def _vocab(cfg: ServeConfig) -> int:
    from repro import configs
    mc = configs.get_config(cfg.arch)
    return (mc.reduced() if cfg.reduced else mc).vocab


def run(cfg: ServeConfig, *,
        params: Optional[PyTree] = None,
        requests: Optional[list] = None,
        channel: Optional[CheckpointChannel] = None,
        engine: Optional[Engine] = None,
        warmup: bool = True) -> ServeResult:
    """Serve a workload to completion and measure it.

    params/requests/channel/engine let callers drop in a trained model,
    a custom request list, a live checkpoint channel, or a pre-built
    (pre-warmed) engine; by default everything is synthesized from the
    config. Compile time is excluded by warming the decode dispatch and
    each distinct prefill length before the clock starts.
    """
    if engine is None:
        engine = Engine(cfg, params=params)
    if channel is not None:
        engine.subscribe(channel)
    reqs = synthetic_requests(cfg) if requests is None else requests
    if warmup:
        engine.warmup(sorted({len(r.tokens) for r in reqs}))

    with obs.span(f"serve.run[{cfg.mode}]"):
        engine._t0 = _now()
        for r in reqs:
            engine.submit(r.tokens, r.max_new_tokens, rid=r.rid)
        engine.run()
        jax.block_until_ready(engine._tokens)
    stats = engine.stats()

    result = ServeResult(
        config=cfg,
        completions=engine.completions,
        counters=dict(engine.counters),
        wall_s=stats["wall_s"],
        decode_steps=stats["decode_steps"],
        total_tokens=stats["generated_tokens"],
        tokens_per_s=stats["tokens_per_s"],
        p50_ms=stats["p50_ms"],
        p99_ms=stats["p99_ms"],
    )
    if obs.enabled("metrics"):
        obs.histogram("serve.tokens_per_s", mode=cfg.mode).observe(
            result.tokens_per_s)
    return result


def _now() -> float:
    import time
    return time.monotonic()


def format_result(res: ServeResult) -> str:
    """The CLI's human-readable summary block."""
    c = res.config
    lines = [
        f"[serve] arch={c.arch}{' (reduced)' if c.reduced else ''} "
        f"mode={c.mode} slots={c.slots} requests={res.n_completed}",
        f"[serve] {res.total_tokens} tokens in {res.wall_s:.2f}s = "
        f"{res.tokens_per_s:.1f} tok/s over {res.decode_steps} decode "
        f"steps",
        f"[serve] latency p50={res.p50_ms:.1f}ms p99={res.p99_ms:.1f}ms"
        f" | dropped={res.counters['dropped']} "
        f"rejected={res.counters['rejected']} "
        f"swaps={res.counters['swaps']}",
    ]
    if res.completions:
        rid = min(res.completions)
        sample = res.completions[rid].tokens[:16]
        lines.append(f"[serve] sample request {rid} tokens[:16]: {sample}")
    return "\n".join(lines)
