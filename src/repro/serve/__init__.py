"""Serving tier: continuous-batching engine + live checkpoint hot-swap.

Public surface:

    from repro import serve

    cfg = serve.ServeConfig(slots=4, n_requests=16, mixed_gen=(4, 8, 32))
    res = serve.run(cfg)                     # ServeResult

    eng = serve.Engine(cfg)                  # request-level control
    rid = eng.submit(tokens, max_new_tokens=32)
    eng.subscribe(channel); eng.run()

    ch = serve.CheckpointChannel()           # train -> serve wire
    serve.publish_train_state(ch, train_state, codec="rq8")

See engine.py (slot plane, admission, the tick), channel.py (framed
compressed-checkpoint pub/sub), api.py (run/ServeResult).
"""
from repro.serve.api import (ServeResult, format_result, run,
                             synthetic_requests)
from repro.serve.channel import (CheckpointChannel, PublishedCheckpoint,
                                 publish_train_state)
from repro.serve.engine import (AdmissionError, Completion, Engine,
                                Request, ServeConfig)

__all__ = [
    "AdmissionError", "CheckpointChannel", "Completion", "Engine",
    "PublishedCheckpoint", "Request", "ServeConfig", "ServeResult",
    "format_result", "publish_train_state", "run", "synthetic_requests",
]
