"""Request-level serving engine: continuous batching + live hot-swap.

``Engine`` owns a fixed pool of ``slots`` decode lanes over ONE jitted,
slot-vmapped decode step. Each slot is an independent batch=1 decode
state (its own ring-buffer KV / recurrent state, its own position
cursor) stacked along a leading slot axis — ``jax.vmap`` over that axis
turns the per-slot scalar cursors of ``attention.init_cache`` into a
per-slot data plane without touching any model code. The engine tick
is:

  1. **swap** — poll the subscribed ``CheckpointChannel``; a fresh
     framed checkpoint is CRC-verified, decoded, and becomes the params
     argument of the NEXT decode dispatch. In-flight requests keep
     their caches and keep decoding (zero drops); a corrupt publish is
     rejected and the serving params stay untouched.
  2. **admit** — pop queued requests into free slots: one fused bulk-
     prefill call per request (``steps.make_bulk_prefill`` — a
     ``lax.scan`` of the decode step, bit-identical to token-by-token)
     fills a fresh batch=1 state, samples the first token, and a jitted
     splice writes it into the stacked plane at the slot index.
  3. **decode** — one vmapped decode step over all slots; finished
     sequences free their slots mid-batch and step 2 splices queued
     requests in without restarting anything (continuous batching).

``mode="static"`` degrades the same machinery into the old gang-
scheduled baseline — slots are admitted batch-at-a-time and a finished
sequence's slot stays dead until the WHOLE batch drains — which is what
``benchmarks/serve_bench.py`` measures continuous batching against.

Admission control: a bounded queue (``max_queue``) and a per-request
capacity check (prompt + new tokens must fit the slot's ``max_len``
cache) — violations raise ``AdmissionError`` at submit time instead of
corrupting a ring buffer mid-decode.

Everything observable threads through the ``obs`` tier: per-request
spans on the host track, queue-depth gauges, admitted/completed/
rejected/swap counters, latency and tokens/s come out of
``Engine.stats()``.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs, obs
from repro.core import compression
from repro.models import transformer_scan
from repro.obs import trace as obs_trace
from repro.serve.channel import CheckpointChannel, PublishedCheckpoint
from repro.train import steps

PyTree = Any


class AdmissionError(RuntimeError):
    """A request was refused at the door: queue full, or the prompt +
    generation budget cannot fit the slot cache."""


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Typed engine configuration (the programmatic entry point's input;
    ``launch/serve.py`` is a thin argv->ServeConfig shim).

    max_len bounds each slot's cache: a request needs
    prompt_len + max_new_tokens - 1 <= max_len slots.
    mixed_gen, when non-empty, cycles per-request generation lengths for
    the synthetic workload of ``serve.run`` (the heavy-traffic mixed-
    length case continuous batching exists for); gen_tokens is the
    uniform fallback.
    """

    arch: str = "qwen1.5-0.5b"
    reduced: bool = True
    slots: int = 4
    max_queue: int = 64
    max_len: int = 96
    window: int = 0               # sliding-window KV slots (0 = full)
    mode: str = "continuous"      # continuous | static
    temperature: float = 0.0      # 0 = greedy
    seed: int = 0
    # synthetic-workload knobs (serve.run)
    n_requests: int = 8
    prompt_len: int = 12
    gen_tokens: int = 16
    mixed_gen: tuple = ()
    # checkpoint channel
    checkpoint_codec: str = "rq8"

    def __post_init__(self):
        if self.mode not in ("continuous", "static"):
            raise ValueError(f"mode must be continuous|static, "
                             f"got '{self.mode}'")
        if self.slots < 1:
            raise ValueError("need at least one slot")


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int
    submitted_at: float = 0.0


@dataclasses.dataclass
class Completion:
    rid: int
    prompt_len: int
    tokens: list                  # generated token ids
    latency_s: float              # submit -> last token
    finished_at: float = 0.0

    @property
    def n_generated(self) -> int:
        return len(self.tokens)


@dataclasses.dataclass
class _Active:
    """A slot's in-flight bookkeeping (host side)."""

    request: Request
    generated: list
    remaining: int                # decode steps left after prefill
    done: bool = False            # static mode: finished but slot held


class Engine:
    """The serving facade: submit -> step/run -> results."""

    def __init__(self, cfg: ServeConfig, *,
                 params: Optional[PyTree] = None,
                 model_cfg=None,
                 key: Optional[jax.Array] = None):
        self.cfg = cfg
        mc = model_cfg if model_cfg is not None \
            else configs.get_config(cfg.arch)
        if model_cfg is None and cfg.reduced:
            mc = mc.reduced()
        if mc.frontend != "token":
            raise ValueError(
                f"the serve engine speaks token frontends only; "
                f"'{mc.arch_id}' has frontend '{mc.frontend}'")
        self.model_cfg = mc
        key = jax.random.PRNGKey(cfg.seed) if key is None else key
        self._key = key
        self.params = params if params is not None \
            else transformer_scan.init(mc, key)

        # -- jitted data plane ------------------------------------------
        serve_step = steps.make_serve_step(mc, scan_layers=True)
        bulk_prefill = steps.make_bulk_prefill(mc, scan_layers=True)
        S, temp = cfg.slots, cfg.temperature

        def _decode(params, state, toks, key):
            """(S,1,1) tokens through every slot lane; sample next."""
            logits, state = jax.vmap(
                lambda st, tok: serve_step(params, st, {"tokens": tok}),
                in_axes=(0, 0))(state, toks)
            logits = logits[:, 0]                       # (S, vocab)
            nxt = _sample(logits, key, temp, S)
            return nxt.reshape(S, 1, 1), logits, state

        def _prefill(params, state1, toks, key):
            """One request's fused cache fill; toks (1, P)."""
            logits, state1 = bulk_prefill(params, state1, toks)
            nxt = _sample(logits, key, temp, 1)
            return nxt.reshape(1, 1), logits, state1

        self._decode_fn = jax.jit(_decode, donate_argnums=(1,))
        self._prefill_fn = jax.jit(_prefill)
        self._splice_fn = _splice

        # -- slot-paged decode-state plane ------------------------------
        # one batch=1 state per slot, stacked on a leading slot axis;
        # _fresh is the reusable template a prefill starts from
        self._fresh = transformer_scan.init_decode_state(
            self.params, mc, 1, cfg.max_len, window=cfg.window,
            dtype=jnp.float32)
        self._state = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (S,) + a.shape) + 0,
            self._fresh)
        self._tokens = jnp.zeros((S, 1, 1), jnp.int32)

        # -- host bookkeeping -------------------------------------------
        self._slots: list[Optional[_Active]] = [None] * S
        self._queue: deque[Request] = deque()
        self._results: dict[int, Completion] = {}
        self._next_rid = 0
        self._step_idx = 0
        self._t0 = time.monotonic()
        self.counters = {"admitted": 0, "completed": 0, "rejected": 0,
                         "dropped": 0, "generated_tokens": 0,
                         "swaps": 0, "swaps_rejected": 0}

        # -- checkpoint subscription ------------------------------------
        self._channel: Optional[CheckpointChannel] = None
        self._seen_seq = 0

    # -- admission ---------------------------------------------------------

    def submit(self, tokens, max_new_tokens: int,
               rid: Optional[int] = None) -> int:
        """Enqueue one request. Raises AdmissionError when the queue is
        full or the request cannot fit a slot cache."""
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        if max_new_tokens < 1:
            raise AdmissionError("max_new_tokens must be >= 1")
        need = len(tokens) + max_new_tokens - 1
        cap = self.cfg.max_len if self.cfg.window == 0 else None
        if cap is not None and need > cap:
            self._count("rejected")
            raise AdmissionError(
                f"request needs {need} cache slots "
                f"(prompt {len(tokens)} + {max_new_tokens} new) but "
                f"max_len is {cap}")
        if len(self._queue) >= self.cfg.max_queue:
            self._count("rejected")
            raise AdmissionError(
                f"queue full ({self.cfg.max_queue} pending)")
        if rid is None:
            rid = self._next_rid
        self._next_rid = max(self._next_rid, rid) + 1
        self._queue.append(Request(rid, tokens, int(max_new_tokens),
                                   time.monotonic()))
        if obs.enabled("metrics"):
            obs.gauge("serve.queue_depth").set(len(self._queue))
        return rid

    # -- checkpoint hot-swap -----------------------------------------------

    def subscribe(self, channel: CheckpointChannel) -> None:
        """Watch a channel; ``step`` applies fresh checkpoints between
        decode dispatches."""
        self._channel = channel

    def maybe_swap(self) -> bool:
        """Apply the newest published checkpoint, if any. Returns True
        on a swap; a corrupt publish is rejected (counted, params kept)
        and its seq marked seen so one bad message can't wedge the
        engine in a retry loop."""
        if self._channel is None:
            return False
        pub = self._channel.poll(self._seen_seq)
        if pub is None:
            return False
        self._seen_seq = pub.seq
        try:
            new_params = CheckpointChannel.decode(pub)
        except compression.WireCorruptionError:
            self._count("swaps_rejected")
            if obs.enabled("metrics"):
                obs.counter("serve.swap.rejected").inc()
            return False
        self.params = new_params
        self._count("swaps")
        if obs.enabled("metrics"):
            obs.counter("serve.swap.applied").inc()
        if obs.enabled("trace"):
            obs_trace.tracer().instant(
                f"hot-swap seq={pub.seq} step={pub.step}",
                worker=obs_trace.HOST, lane="host",
                t=time.monotonic() - self._t0, cat="serve.swap")
        return True

    # -- the engine tick ---------------------------------------------------

    def step(self) -> bool:
        """One tick: swap -> admit -> one vmapped decode step.
        Returns False once idle (no active slots, empty queue)."""
        self.maybe_swap()
        self._admit()
        if not any(a is not None and not a.done for a in self._slots):
            return bool(self._queue)
        key = jax.random.fold_in(self._key, self._step_idx)
        nxt, _, self._state = self._decode_fn(
            self.params, self._state, self._tokens, key)
        self._tokens = nxt
        self._step_idx += 1
        toks = np.asarray(nxt).reshape(-1)
        for slot, active in enumerate(self._slots):
            if active is None or active.done:
                continue
            active.generated.append(int(toks[slot]))
            self._count("generated_tokens")
            active.remaining -= 1
            if active.remaining <= 0:
                self._finish(slot)
        return True

    def run(self) -> None:
        """Drive ticks until every queued/active request completed."""
        while self.step():
            pass

    def warmup(self, prompt_lens=()) -> None:
        """Compile the decode dispatch and each distinct prefill length
        outside the timed path (serve_bench excludes compile time the
        same way the kernel benches do)."""
        key = jax.random.PRNGKey(0)
        for plen in sorted(set(int(p) for p in prompt_lens)):
            toks = jnp.zeros((1, plen), jnp.int32)
            jax.block_until_ready(
                self._prefill_fn(self.params, self._fresh, toks, key))
        state = jax.tree_util.tree_map(jnp.copy, self._state)
        out = self._decode_fn(self.params, state, self._tokens, key)
        jax.block_until_ready(out[0])

    # -- results -----------------------------------------------------------

    def result(self, rid: int) -> Optional[Completion]:
        return self._results.get(rid)

    @property
    def completions(self) -> dict[int, Completion]:
        return dict(self._results)

    def stats(self) -> dict:
        """Aggregate throughput/latency over completed requests."""
        lats = sorted(c.latency_s for c in self._results.values())
        wall = time.monotonic() - self._t0
        out = dict(self.counters)
        out.update({
            "wall_s": wall,
            "decode_steps": self._step_idx,
            "tokens_per_s": (self.counters["generated_tokens"] / wall
                             if wall > 0 else 0.0),
            "p50_ms": _percentile(lats, 50), "p99_ms": _percentile(lats, 99),
        })
        return out

    # -- internals ---------------------------------------------------------

    def _count(self, name: str, v: int = 1) -> None:
        self.counters[name] += v

    def _free_slots(self) -> list[int]:
        return [i for i, a in enumerate(self._slots) if a is None]

    def _admit(self) -> None:
        free = self._free_slots()
        if self.cfg.mode == "static" and len(free) < len(self._slots):
            # gang scheduling: a new batch only forms once the pool is
            # fully drained (this is the baseline's whole pathology)
            return
        while self._queue and free:
            self._place(self._queue.popleft(), free.pop(0))
        if obs.enabled("metrics"):
            obs.gauge("serve.queue_depth").set(len(self._queue))

    def _place(self, req: Request, slot: int) -> None:
        """Prefill ``req`` into a fresh batch=1 state and splice it into
        the stacked plane at ``slot`` — the mid-decode admission path."""
        # per-request sampling key, disjoint from the per-step decode
        # keys (which fold in the small non-negative step index)
        key = jax.random.fold_in(self._key, 0x7FFFFFFF - req.rid)
        toks = jnp.asarray(req.tokens, jnp.int32)[None]
        tok, _, state1 = self._prefill_fn(self.params, self._fresh, toks,
                                          key)
        self._state = self._splice_fn(self._state, state1, slot)
        self._tokens = self._tokens.at[slot, 0, 0].set(tok[0, 0])
        first = int(np.asarray(tok).reshape(())[()])
        active = _Active(req, [first], req.max_new_tokens - 1)
        self._slots[slot] = active
        self._count("admitted")
        self._count("generated_tokens")
        if obs.enabled("metrics"):
            obs.counter("serve.admitted").inc()
        if active.remaining <= 0:
            self._finish(slot)

    def _finish(self, slot: int) -> None:
        active = self._slots[slot]
        now = time.monotonic()
        comp = Completion(active.request.rid, len(active.request.tokens),
                          active.generated,
                          now - active.request.submitted_at, now)
        self._results[comp.rid] = comp
        self._count("completed")
        if obs.enabled("metrics"):
            obs.counter("serve.completed").inc()
            obs.histogram("serve.latency_ms").observe(
                comp.latency_s * 1e3)
        if obs.enabled("trace"):
            obs_trace.tracer().sim_span(
                f"request {comp.rid}", worker=obs_trace.HOST, lane="host",
                t0=active.request.submitted_at - self._t0,
                t1=now - self._t0, cat="serve.request",
                args={"prompt": comp.prompt_len,
                      "generated": comp.n_generated})
        if self.cfg.mode == "static":
            # hold the slot dead until the gang drains
            active.done = True
            if all(a is None or a.done for a in self._slots):
                self._slots = [None] * len(self._slots)
        else:
            self._slots[slot] = None


def _sample(logits, key, temperature: float, n: int):
    """Greedy or temperature sampling over (n, vocab) logits."""
    if temperature > 0:
        keys = jax.random.split(key, n)
        return jax.vmap(
            lambda k, l: jax.random.categorical(k, l / temperature)
        )(keys, logits).astype(jnp.int32)
    return jnp.argmax(logits, -1).astype(jnp.int32)


@partial(jax.jit, donate_argnums=(0,))
def _splice(stacked: PyTree, state1: PyTree, slot) -> PyTree:
    """Write a batch=1 decode state into the stacked plane at ``slot``
    (traced index -> one compiled splice serves every slot)."""
    return jax.tree_util.tree_map(
        lambda s, n: jax.lax.dynamic_update_index_in_dim(s, n, slot, 0),
        stacked, state1)


def _percentile(sorted_vals: list, q: float) -> float:
    """q-th percentile (ms) of pre-sorted latency seconds."""
    if not sorted_vals:
        return 0.0
    return float(np.percentile(np.asarray(sorted_vals), q) * 1e3)
