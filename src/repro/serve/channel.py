"""Publish/subscribe checkpoint channel: the train -> serve wire.

The training tier (any cluster protocol, any exchange) publishes its
params as ONE codec-compressed ``FlatPacked`` message — the exact wire
object the gradient exchanges already ship, produced by
``Codec.tree_encode_flat`` — framed with the CRC32 wire-integrity
checksum from ``repro.core.compression``. A live serving engine
subscribes and swaps params between decode steps with zero dropped
requests (``Engine.step`` polls the channel once per tick).

This is the two-direction compression argument (Yu et al., "Double
Quantization") applied to the train->serve edge: the model leaves the
trainer quantized, travels as payload+params (at rq8, ~4x smaller than
fp32), and the server decodes the SAME bits a cold start from the
published checkpoint would — so a hot swap is bit-equivalent to a
restart, minus the downtime (asserted in tests/test_serve.py).

Integrity contract on receive (``decode``):

  * the CRC32 frame is verified over payload bytes then params bytes
    (``verify_wire``) — a bit-flipped checkpoint raises
    ``WireCorruptionError`` and the subscriber keeps its serving
    params;
  * the decoded tree passes the post-decode finite guard — a framed-
    but-garbage publish (NaN/Inf from a diverged trainer) is rejected
    the same way.

The channel is in-process and thread-safe (one lock, last-value
semantics: a slow subscriber sees the newest checkpoint, not a backlog
— stale intermediate checkpoints are worthless to a server).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Optional

import jax

from repro import obs
from repro.core import compression

PyTree = Any


@dataclasses.dataclass(frozen=True)
class PublishedCheckpoint:
    """One framed checkpoint message as it sits on the channel.

    seq:    channel-assigned monotone sequence number (subscription
            cursor).
    step:   the trainer's step counter (provenance, not ordering).
    codec:  registry name that encoded ``packed`` (decodes it too).
    packed: the ONE FlatPacked wire message for the whole param tree.
    crc:    CRC32 frame over payload bytes then params bytes.
    """

    seq: int
    step: int
    codec: str
    packed: compression.FlatPacked
    crc: int
    published_at: float = 0.0

    @property
    def wire_bytes(self) -> int:
        return self.packed.wire_bytes


class CheckpointChannel:
    """Last-value publish/subscribe channel for compressed checkpoints."""

    def __init__(self):
        self._lock = threading.Lock()
        self._seq = 0
        self._latest: Optional[PublishedCheckpoint] = None

    # -- publish (training side) ------------------------------------------

    def publish(self, params: PyTree, *, step: int = 0,
                codec: str = "rq8",
                key: Optional[jax.Array] = None) -> PublishedCheckpoint:
        """Encode ``params`` into one framed FlatPacked and make it the
        channel's latest. Returns the published record (so the trainer
        can log seq/bytes)."""
        cdc = compression.codec(codec)
        if key is None:
            key = jax.random.PRNGKey(step)
        packed = cdc.tree_encode_flat(params, key)
        # the frame is computed over the exact bytes that travel
        packed, crc = compression.frame(packed)
        return self.publish_packed(packed, crc, step=step, codec=codec)

    def publish_packed(self, packed: compression.FlatPacked, crc: int, *,
                       step: int = 0,
                       codec: str = "rq8") -> PublishedCheckpoint:
        """Publish an already-framed wire message verbatim (the path a
        relaying process — or a corruption test — uses)."""
        with self._lock:
            self._seq += 1
            pub = PublishedCheckpoint(self._seq, step, codec, packed,
                                      int(crc) & 0xFFFFFFFF, time.time())
            self._latest = pub
        if obs.enabled("metrics"):
            obs.counter("serve.ckpt.published", codec=codec).inc()
            obs.counter("serve.ckpt.published_bytes",
                        codec=codec).inc(pub.wire_bytes)
        return pub

    # -- subscribe (serving side) -----------------------------------------

    @property
    def latest(self) -> Optional[PublishedCheckpoint]:
        with self._lock:
            return self._latest

    def poll(self, since_seq: int = 0) -> Optional[PublishedCheckpoint]:
        """The newest checkpoint with seq > since_seq, else None."""
        with self._lock:
            pub = self._latest
        return pub if pub is not None and pub.seq > since_seq else None

    @staticmethod
    def decode(pub: PublishedCheckpoint) -> PyTree:
        """Frame-verified decode back to the param tree.

        Raises ``compression.WireCorruptionError`` on a CRC mismatch or
        a non-finite decode; the caller's params are untouched either
        way (decode never mutates subscriber state)."""
        where = f"checkpoint seq={pub.seq} step={pub.step}"
        compression.verify_wire(pub.packed, pub.crc, where=where)
        cdc = compression.codec(pub.codec)
        params = cdc.tree_decode_flat(pub.packed)
        compression.guard_finite(params, where=where)
        return params


def publish_train_state(channel: CheckpointChannel, state: dict, *,
                        codec: str = "rq8") -> PublishedCheckpoint:
    """Publish a live train state's params (the trainer-side one-liner:
    step number and param tree are read straight off the state dict the
    train step threads through)."""
    return channel.publish(state["params"], step=int(state["step"]),
                           codec=codec)
