"""Trip-count-aware analysis of compiled (post-SPMD) HLO text.

Why this exists: `compiled.cost_analysis()` counts a `while` body ONCE, so a
scan-over-layers program under-reports FLOPs/bytes/collectives by ~n_layers.
This module re-derives per-device, per-step totals by walking the HLO call
graph:

  * computations are parsed from the printed module, with a per-computation
    symbol table (%name -> shape) so operand shapes resolve;
  * `while` ops bind a body computation to a trip count. XLA's "wide" scan
    loops pass the bound as an operand, so the count is recovered as the
    MODE of the leading dims of the loop-carried tuple (scan xs/ys all have
    leading dim == trips — stacked layer params dominate the tuple); a
    constant found in the condition computation overrides when present;
  * call/fusion/to_apply edges propagate multipliers; each op's cost is
    weighted by the product of enclosing trip counts;
  * FLOPs counted for dot ops: 2 * prod(result dims) * prod(lhs contracting
    dims) — matmuls dominate transformer steps (elementwise ops are a
    lower-order term, excluded and documented);
  * collective bytes from result shapes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute (`-start` counted,
    `-done` skipped).

Validated by tests: scanned vs unrolled lowerings of the same model agree,
and the dot-FLOPs match the analytic 6ND estimate on a dense model.
"""
from __future__ import annotations

import dataclasses
import re
from collections import Counter, defaultdict
from typing import Optional

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
                "f8e4m3": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s")


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _type_bytes(text: str) -> float:
    total = 0.0
    for m in _SHAPE_RE.finditer(text):
        dt = m.group(1)
        if dt in _DTYPE_BYTES:
            total += _shape_elems(m.group(2)) * _DTYPE_BYTES[dt]
    return total


def _leading_dims(text: str) -> list:
    out = []
    for m in _SHAPE_RE.finditer(text):
        if m.group(1) in _DTYPE_BYTES and m.group(2):
            dims = [int(d) for d in m.group(2).split(",") if d]
            if dims:
                out.append(dims[0])
    return out


@dataclasses.dataclass
class Computation:
    name: str
    lines: list
    symbols: dict          # %name -> type string


def parse_computations(hlo: str) -> dict:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        if not line.startswith(" ") and "{" in line and "->" in line:
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", line)
            if m:
                cur = Computation(m.group(1), [], {})
                comps[cur.name] = cur
                continue
        stripped = line.strip()
        if stripped == "}":
            cur = None
            continue
        if cur is not None and stripped:
            cur.lines.append(stripped)
            dm = _DEF_RE.match(stripped)
            if dm:
                cur.symbols[dm.group(1)] = dm.group(2)
    return comps


def _find_entry(comps: dict, hlo: str) -> str:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
    if m and m.group(1) in comps:
        return m.group(1)
    called = set()
    for c in comps.values():
        for ln in c.lines:
            for cm in re.finditer(r"(?:body|condition|to_apply|calls)=%?"
                                  r"([\w.\-]+)", ln):
                called.add(cm.group(1))
    for name in comps:
        if name not in called:
            return name
    return next(iter(comps))


def _trip_count(while_line: str, cond: Optional[Computation]) -> int:
    """Prefer a compare-constant in the condition; else the mode of leading
    dims of the carried tuple (scan xs/ys share leading dim == trips)."""
    if cond is not None:
        consts = []
        for ln in cond.lines:
            if "compare" in ln or "constant" in ln:
                consts += [int(v) for v in
                           re.findall(r"constant\((\d+)\)", ln)]
        consts = [c for c in consts if c > 1]
        if consts:
            return max(consts)
    # result tuple is printed on the while line
    head = while_line.split(" while(", 1)[0]
    lead = [d for d in _leading_dims(head) if d > 1]
    if lead:
        return Counter(lead).most_common(1)[0][0]
    return 1


@dataclasses.dataclass
class HloCosts:
    dot_flops: float = 0.0
    collective_bytes: float = 0.0
    collective_breakdown: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    collective_counts: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    loops: list = dataclasses.field(default_factory=list)
    unknown_loops: int = 0

    def as_dict(self) -> dict:
        return {
            "dot_flops": self.dot_flops,
            "collective_bytes": self.collective_bytes,
            "collective_breakdown": dict(self.collective_breakdown),
            "collective_counts": {k: int(v) for k, v in
                                  self.collective_counts.items()},
            "loops": self.loops,
            "unknown_loops": self.unknown_loops,
        }


def _dot_flops_line(ln: str, symbols: dict) -> float:
    m = re.match(r"^\s*(?:ROOT\s+)?%[\w.\-]+\s*=\s*(\S+)\s+dot\(", ln)
    if not m:
        return 0.0
    res = _SHAPE_RE.search(m.group(1))
    if not res or res.group(1) not in _DTYPE_BYTES:
        return 0.0
    out_elems = _shape_elems(res.group(2))
    args = re.search(r"dot\(\s*%?([\w.\-]+)", ln)
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ln)
    if not args or not cm:
        return 0.0
    lhs_type = symbols.get(args.group(1), "")
    sm = _SHAPE_RE.search(lhs_type)
    if not sm:
        return 0.0
    lhs_dims = [int(d) for d in sm.group(2).split(",") if d]
    contract = 1
    for idx in cm.group(1).split(","):
        if idx and int(idx) < len(lhs_dims):
            contract *= lhs_dims[int(idx)]
    return 2.0 * out_elems * contract


def analyze_hlo(hlo: str) -> HloCosts:
    comps = parse_computations(hlo)
    entry = _find_entry(comps, hlo)
    costs = HloCosts()

    def walk(name: str, mult: float, stack: tuple):
        if name not in comps or name in stack:
            return
        comp = comps[name]
        for ln in comp.lines:
            if " while(" in ln:
                bm = re.search(r"body=%?([\w.\-]+)", ln)
                cm_ = re.search(r"condition=%?([\w.\-]+)", ln)
                cond = comps.get(cm_.group(1)) if cm_ else None
                trips = _trip_count(ln, cond)
                if trips == 1:
                    costs.unknown_loops += 1
                costs.loops.append({"body": bm.group(1) if bm else "?",
                                    "trips": trips, "mult": mult})
                if bm and bm.group(1) in comps:
                    walk(bm.group(1), mult * trips, stack + (name,))
                if cond is not None:
                    walk(cond.name, mult * trips, stack + (name,))
                continue
            if " dot(" in ln:
                costs.dot_flops += mult * _dot_flops_line(ln, comp.symbols)
            hit_collective = False
            for op in _COLLECTIVES:
                if re.search(rf"\b{op}(-start)?\(", ln) and \
                        f"{op}-done" not in ln:
                    head = ln.split(f" {op}", 1)[0]
                    nbytes = _type_bytes(head.split("=", 1)[-1])
                    costs.collective_bytes += mult * nbytes
                    costs.collective_breakdown[op] += mult * nbytes
                    costs.collective_counts[op] += mult
                    hit_collective = True
                    break
            if hit_collective:
                continue
            # nested computations (fusion bodies contain no collectives but
            # can contain dots? fusions inline dots as 'dot' inside the
            # fusion computation — traverse call edges)
            for cm2 in re.finditer(r"(?:calls|to_apply)=%?([\w.\-]+)", ln):
                walk(cm2.group(1), mult, stack + (name,))
            fm = re.search(r"fusion\(", ln)
            if fm:
                km = re.search(r"calls=%?([\w.\-]+)", ln)
                if km:
                    walk(km.group(1), mult, stack + (name,))
    walk(entry, 1.0, ())
    return costs
