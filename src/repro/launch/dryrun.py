import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^^ MUST run before any other import: jax locks the device count on first
# backend initialization. Everything below is ordinary.

"""Multi-pod dry-run: prove the distribution config is coherent.
(No `from __future__ import annotations` here: the XLA_FLAGS lines above
must stay the first statements in the module.)

For every (architecture x input shape) combination this lowers + compiles
the real step function (train_step / prefill_step / serve_step) against the
production mesh with ShapeDtypeStruct inputs — no arrays are allocated —
and extracts:

  * compiled.memory_analysis()   -> bytes/device (proves HBM fit)
  * compiled.cost_analysis()     -> HLO FLOPs + bytes accessed
  * collective bytes             -> parsed from the compiled HLO (all-gather
                                    / all-reduce / reduce-scatter /
                                    all-to-all / collective-permute)

Outputs a JSON record per combo consumed by benchmarks/roofline.py and
EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch command-r-35b \
      --shape train_4k [--multi-pod] [--all] [--out results.json]
"""
import argparse
import json
import re
import sys
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro import configs
from repro.data.pipeline import make_batch_shapes
from repro.dist import sharding
from repro.launch import mesh as mesh_lib
from repro.models import transformer_scan
from repro.models.common import INPUT_SHAPES, InputShape, ModelConfig
from repro.optim import make_optimizer
from repro.train import steps

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16, "f8e4m3": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


# --------------------------------------------------------------------------
# input specs
# --------------------------------------------------------------------------


def _serve_window(cfg: ModelConfig, shape: InputShape) -> int:
    """Sliding window used for attn-block KV caches at this shape.

    long_500k REQUIRES sub-quadratic state: dense/moe/vlm/audio archs use
    their sliding_window_decode; ssm/hybrid archs have O(1)/O(window) state
    anyway (their 'window' only applies to local_attn blocks, which always
    use cfg.local_window).
    """
    if shape.name == "long_500k":
        return cfg.sliding_window_decode
    return 0


def input_specs(arch: str, shape_name: str, *,
                optimizer: str = "adamw", moment_dtype=None,
                step_cfg: Optional[steps.TrainStepConfig] = None
                ) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every input of the step function."""
    cfg = configs.get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    out: dict[str, Any] = {"cfg": cfg, "shape": shape}
    batch = make_batch_shapes(cfg, shape, dtype=jnp.bfloat16)
    out["batch"] = batch
    if shape.kind == "train":
        scfg = step_cfg or default_train_cfg(cfg)
        opt = make_optimizer(optimizer, 3e-4,
                             moment_dtype=moment_dtype
                             or default_moment_dtype(cfg)) \
            if optimizer != "sgd" else make_optimizer("sgd", 3e-4)
        out["state"] = steps.abstract_train_state(cfg, opt, step_cfg=scfg)
        out["step_cfg"] = scfg
        out["optimizer"] = opt
    elif shape.kind == "decode":
        params = jax.eval_shape(
            lambda k: transformer_scan.init(cfg, k, dtype=jnp.bfloat16),
            jax.random.PRNGKey(0))
        out["params"] = params
        window = _serve_window(cfg, shape)
        mem = None
        if cfg.is_encdec:
            mem = jax.ShapeDtypeStruct(
                (shape.global_batch, shape.seq_len, cfg.d_model),
                jnp.bfloat16)
        out["decode_state"] = jax.eval_shape(
            lambda p, m: transformer_scan.init_decode_state(
                p, cfg, shape.global_batch, shape.seq_len, window=window,
                dtype=jnp.bfloat16, memory=m),
            params, mem)
    else:  # prefill
        params = jax.eval_shape(
            lambda k: transformer_scan.init(cfg, k, dtype=jnp.bfloat16),
            jax.random.PRNGKey(0))
        out["params"] = params
    return out


def default_train_cfg(cfg: ModelConfig) -> steps.TrainStepConfig:
    return steps.TrainStepConfig(remat=True, grad_clip=1.0,
                                 param_dtype=jnp.bfloat16, scan_layers=True)


def default_moment_dtype(cfg: ModelConfig):
    # grok's 314B needs bf16 Adam moments to fit 16GB/chip (EXPERIMENTS §Dry-run)
    big = cfg.param_count() > 80e9
    return jnp.bfloat16 if big else jnp.float32


# --------------------------------------------------------------------------
# lowering one combo
# --------------------------------------------------------------------------


def lower_combo(arch: str, shape_name: str, *, multi_pod: bool = False,
                optimizer: str = "adamw",
                step_cfg: Optional[steps.TrainStepConfig] = None):
    """Returns (lowered, specs) for the given combination."""
    spec = input_specs(arch, shape_name, optimizer=optimizer,
                       step_cfg=step_cfg)
    cfg, shape = spec["cfg"], spec["shape"]
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    sharding.set_activation_batch_axes(
        ("pod", "data") if multi_pod else ("data",))

    with mesh:
        if shape.kind == "train":
            fn = steps.make_train_step(cfg, spec["optimizer"],
                                       spec["step_cfg"])
            state_sh = _state_shardings(spec["state"], mesh)
            batch_sh = sharding.batch_shardings(spec["batch"], mesh)
            jitted = jax.jit(fn, in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(spec["state"], spec["batch"])
        elif shape.kind == "decode":
            fn = steps.make_serve_step(cfg, scan_layers=True)
            p_sh = sharding.params_shardings(spec["params"], mesh)
            c_sh = sharding.cache_shardings(spec["decode_state"], mesh)
            b_sh = sharding.batch_shardings(spec["batch"], mesh)
            jitted = jax.jit(fn, in_shardings=(p_sh, c_sh, b_sh),
                             out_shardings=(None, c_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(spec["params"], spec["decode_state"],
                                   spec["batch"])
        else:  # prefill
            fn = steps.make_prefill_step(cfg, scan_layers=True,
                                         logits_positions="last")
            p_sh = sharding.params_shardings(spec["params"], mesh)
            b_sh = sharding.batch_shardings(spec["batch"], mesh)
            jitted = jax.jit(fn, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(spec["params"], spec["batch"])
    return lowered, spec, mesh


def _state_shardings(state_shapes, mesh):
    """Train-state sharding: params/moments by param rules; the flat
    ec_err residual buffer FSDP-shards over the data axes; scalars and
    rng replicated."""
    from jax.sharding import NamedSharding, PartitionSpec

    def rule(path, leaf):
        names = sharding._path_names(path)
        if names and names[0] == "ec_err":
            # single flat fp32 buffer (fused codec tier): 1-D shard over
            # the full data-axis tuple when divisible, else replicate
            spec = PartitionSpec(sharding._maybe(
                sharding._ACT_BATCH_AXES, leaf.shape[0], mesh))
            return NamedSharding(mesh, spec)
        if names and names[0] == "params":
            return sharding.params_shardings_leaf(path[1:], leaf, mesh)
        if names and names[0] == "opt" and len(names) > 1 \
                and names[1] in ("m", "v"):
            return sharding.params_shardings_leaf(path[2:], leaf, mesh)
        return sharding.replicated(mesh)

    return jax.tree_util.tree_map_with_path(rule, state_shapes)


# --------------------------------------------------------------------------
# HLO analysis
# --------------------------------------------------------------------------


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum result-operand bytes of every collective op in the HLO."""
    totals = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    # e.g. "%all-reduce.1 = bf16[512,128]{1,0} all-reduce(...)"
    #      "... = (f32[128]{0}, f32[64]{0}) all-gather(...)"
    array_re = re.compile(r"(\w+)\[([\d,]*)\]")
    for line in hlo_text.splitlines():
        m = re.search(r"=\s+(\([^)]*\)|\S+)\s+(" + "|".join(_COLLECTIVES)
                      + r")(-start|-done)?\(", line)
        if not m:
            continue
        if m.group(3) == "-done":
            continue  # counted at -start
        result, op = m.group(1), m.group(2)
        nbytes = 0.0
        for dm in array_re.finditer(result):
            dt, dims = dm.group(1), dm.group(2)
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        totals[op] += nbytes
        counts[op] += 1
    totals["total"] = sum(totals[k] for k in _COLLECTIVES)
    totals["counts"] = counts
    return totals


def analyze(compiled, lowered=None) -> dict[str, Any]:
    from repro.launch import hlo_analysis
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    rec: dict[str, Any] = {
        # raw cost_analysis (counts while bodies ONCE - kept for reference)
        "flops_body_once": float(cost.get("flops", 0.0)),
        "bytes_accessed_body_once": float(cost.get("bytes accessed", 0.0)),
        "transcendentals": float(cost.get("transcendentals", 0.0)),
    }
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        rec[attr] = int(getattr(mem, attr, 0))
    # trip-count-aware per-device totals (launch/hlo_analysis.py)
    costs = hlo_analysis.analyze_hlo(compiled.as_text())
    rec["dot_flops"] = costs.dot_flops
    rec["collectives"] = costs.as_dict()
    rec["collectives"]["total"] = costs.collective_bytes
    return rec


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            optimizer: str = "adamw",
            step_cfg: Optional[steps.TrainStepConfig] = None,
            verbose: bool = True) -> dict[str, Any]:
    t0 = time.time()
    lowered, spec, mesh = lower_combo(arch, shape_name, multi_pod=multi_pod,
                                      optimizer=optimizer, step_cfg=step_cfg)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    rec = analyze(compiled)
    rec.update({
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": int(mesh.devices.size),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "params": configs.get_config(arch).param_count(),
        "active_params": configs.get_config(arch).active_param_count(),
    })
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} mesh={rec['mesh']} "
              f"dot_flops={rec['dot_flops']:.3e} "
              f"coll={rec['collectives']['total']:.3e}B "
              f"temp={rec['temp_size_in_bytes']/2**30:.2f}GiB "
              f"args={rec['argument_size_in_bytes']/2**30:.2f}GiB "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
        sys.stdout.flush()
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="all assigned archs x all shapes")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    args = ap.parse_args(argv)

    combos = []
    archs = list(configs.ASSIGNED) if (args.all or not args.arch) \
        else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                combos.append((a, s, mp))

    records = []
    failures = []
    for a, s, mp in combos:
        try:
            rec = run_one(a, s, multi_pod=mp)
            records.append(rec)
        except Exception as e:  # noqa: BLE001 — report, keep going
            failures.append((a, s, mp, repr(e)))
            print(f"[dryrun] FAIL {a} x {s} multi_pod={mp}: {e!r}")
    if args.out:
        with open(args.out, "a") as f:
            for rec in records:
                f.write(json.dumps(rec) + "\n")
    print(f"[dryrun] {len(records)} OK, {len(failures)} failed")
    if failures:
        for f_ in failures:
            print("  FAIL:", f_)
        sys.exit(1)


if __name__ == "__main__":
    main()
