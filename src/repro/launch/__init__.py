# Launcher package. NOTE: importing this package must never touch jax
# device state — dryrun.py sets XLA_FLAGS before any jax import, and
# mesh.py builds meshes only inside functions.
