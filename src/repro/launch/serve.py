"""Serving launcher — a thin argv shim over ``repro.serve.run``.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --reduced \
      --slots 4 --requests 16 --prompt-len 32 --gen 64 [--window 16] \
      [--mode static]

The engine itself (continuous batching, admission control, slot-paged
decode states, checkpoint hot-swap) lives in ``repro.serve``; this
module only parses flags into a ``ServeConfig`` and prints the
``ServeResult`` summary. Programmatic callers should skip argv and call
``serve.run(ServeConfig(...))`` directly — that is the supported API,
and what ``examples/serve_batched.py`` and ``benchmarks/serve_bench.py``
do.
"""
from __future__ import annotations

import argparse

from repro import serve


def build_config(args) -> serve.ServeConfig:
    n_requests = args.requests if args.requests else args.slots
    return serve.ServeConfig(
        arch=args.arch, reduced=args.reduced, slots=args.slots,
        max_len=args.prompt_len + args.gen + 1, window=args.window,
        mode=args.mode, temperature=args.temperature, seed=args.seed,
        n_requests=n_requests, prompt_len=args.prompt_len,
        gen_tokens=args.gen, mixed_gen=tuple(args.mixed_gen or ()))


def main(argv=None) -> serve.ServeResult:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--slots", "--batch", dest="slots", type=int, default=4,
                    help="decode lanes (the old --batch)")
    ap.add_argument("--requests", type=int, default=0,
                    help="synthetic requests to serve (default: one per "
                         "slot)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=64)
    ap.add_argument("--mixed-gen", type=int, nargs="*", default=None,
                    help="cycle these generation lengths across requests "
                         "(the mixed-length workload)")
    ap.add_argument("--window", type=int, default=0,
                    help="sliding-window KV cache size (0 = full)")
    ap.add_argument("--mode", choices=("continuous", "static"),
                    default="continuous")
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    result = serve.run(build_config(args))
    print(serve.format_result(result))
    return result


if __name__ == "__main__":
    main()
