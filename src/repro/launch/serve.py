"""Serving launcher: batched autoregressive decode with KV/recurrent caches.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --reduced \
      --batch 4 --prompt-len 32 --gen 64 [--window 16]

Prompts are synthetic token streams; the loop reports per-step latency and
tokens/sec. The same serve_step lowers against the production mesh in
launch/dryrun.py (decode_32k / long_500k input shapes).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import transformer_scan
from repro.train import steps


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=64)
    ap.add_argument("--window", type=int, default=0,
                    help="sliding-window KV cache size (0 = full)")
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(args.seed)
    params = transformer_scan.init(cfg, key)
    max_len = args.prompt_len + args.gen + 1
    memory = None
    if cfg.is_encdec:
        memory = transformer_scan.encode(
            params, cfg,
            jax.random.normal(key, (args.batch, args.prompt_len,
                                    cfg.d_model)) * 0.02)
    state = transformer_scan.init_decode_state(
        params, cfg, args.batch, max_len, window=args.window,
        dtype=jnp.float32, memory=memory)
    serve_step = jax.jit(steps.make_serve_step(cfg, scan_layers=True),
                         donate_argnums=(1,))

    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab)

    def feed(tok):
        if cfg.frontend == "token":
            return {"tokens": tok}
        return {"embeddings": jax.random.normal(
            jax.random.fold_in(key, int(tok[0, 0])),
            (args.batch, 1, cfg.d_model)) * 0.02}

    # prompt processing: token-by-token cache fill (bulk prefill is a
    # recorded §Perf optimization)
    t0 = time.time()
    logits = None
    for i in range(args.prompt_len):
        logits, state = serve_step(params, state, feed(prompts[:, i:i + 1]))
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits, -1)[:, None]
    out_tokens = [tok]
    t0 = time.time()
    for i in range(args.gen):
        logits, state = serve_step(params, state, feed(tok))
        gkey = jax.random.fold_in(key, 1000 + i)
        if args.temperature > 0:
            tok = jax.random.categorical(
                gkey, logits / args.temperature, axis=-1)[:, None]
        else:
            tok = jnp.argmax(logits, -1)[:, None]
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_gen = time.time() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"[serve] arch={cfg.arch_id} batch={args.batch} "
          f"prompt={args.prompt_len} gen={args.gen}")
    print(f"[serve] prompt phase {t_prefill:.2f}s | decode "
          f"{t_gen:.2f}s = {args.gen * args.batch / max(t_gen, 1e-9):.1f} tok/s")
    print(f"[serve] sample tokens[0,:16]: {gen[0, :16].tolist()}")
    return gen


if __name__ == "__main__":
    main()
