"""Production meshes.

Target hardware: TPU v5e pods, 256 chips/pod (16x16). Mesh axes:
  single-pod:  (16, 16)    ('data', 'model')
  multi-pod:   (2, 16, 16) ('pod', 'data', 'model')  — 512 chips

`make_production_mesh` is a FUNCTION (not a module constant) so importing
this module never initializes jax's device backend; the dry-run launcher
sets --xla_force_host_platform_device_count=512 before first jax use.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n: int | None = None, *, axes=("data",)):
    """Small mesh over the real host devices (examples / integration tests)."""
    n = n if n is not None else len(jax.devices())
    import numpy as np
    if len(axes) == 1:
        return jax.make_mesh((n,), axes)
    raise ValueError("host mesh supports a single axis")


# Hardware constants for the roofline model (TPU v5e).
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (~per chip, ring neighbor)
ICI_LAT = 1e-6                  # s fixed per-message latency on a link (the
                                # switch model's t_lat; charged once per wire
                                # message, so per-leaf gradient messaging
                                # pays it L times, the fused tier once)
VMEM_BYTES = 16 * 1024 * 1024
HBM_BYTES = 16 * 1024**3        # 16 GB per v5e chip
