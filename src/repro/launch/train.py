"""Training launcher.

Runs real training on the host devices (reduced or small archs on CPU;
the same code drives a TPU slice when one is attached) with the paper's
communication relaxations selectable from the CLI:

  PYTHONPATH=src python -m repro.launch.train --arch repro-100m \
      --steps 200 --batch 8 --seq 256 \
      [--compression rq8] [--error-feedback] [--reduced] \
      [--ckpt-dir /tmp/ckpt] [--scan-layers]

On a multi-device host, data parallelism uses a ('data','model') mesh over
the available devices.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.checkpoint import latest_checkpoint, load_state, save_state
from repro.data.pipeline import SyntheticLM
from repro.dist import sharding
from repro.optim import cosine_schedule, make_optimizer
from repro.train import steps


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="repro-100m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "momentum", "sgd"])
    ap.add_argument("--compression", default="none")
    ap.add_argument("--error-feedback", action="store_true")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-scale variant of the arch")
    ap.add_argument("--scan-layers", action="store_true")
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev, 1), ("data", "model"))
    sharding.set_activation_batch_axes(("data",))
    print(f"[train] arch={cfg.arch_id} params~{cfg.param_count()/1e6:.1f}M "
          f"devices={n_dev} batch={args.batch} seq={args.seq}")

    lr = cosine_schedule(args.lr, warmup=min(50, args.steps // 10 + 1),
                         total=args.steps)
    opt = make_optimizer(args.optimizer, lr)
    scfg = steps.TrainStepConfig(
        remat=args.remat, grad_compression=args.compression,
        error_feedback=args.error_feedback, scan_layers=args.scan_layers)
    state = steps.init_train_state(cfg, opt, jax.random.PRNGKey(args.seed),
                                   step_cfg=scfg)
    start = 0
    if args.ckpt_dir:
        ck = latest_checkpoint(args.ckpt_dir)
        if ck:
            state = load_state(jax.eval_shape(lambda: state), ck)
            start = int(state["step"])
            print(f"[train] resumed from {ck} at step {start}")

    data = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq + 1,
                       batch=args.batch, seed=args.seed)
    with mesh:
        state_sh = jax.tree_util.tree_map(
            lambda _: sharding.replicated(mesh), jax.eval_shape(lambda: state))
        train_step = jax.jit(steps.make_train_step(cfg, opt, scfg),
                             donate_argnums=(0,))
        t0 = time.time()
        for t in range(start, args.steps):
            batch = data.batch_at(t)
            batch = jax.device_put(
                batch, sharding.batch_shardings(batch, mesh))
            state, metrics = train_step(state, batch)
            if t % args.log_every == 0 or t == args.steps - 1:
                loss = float(metrics["loss"])
                dt = time.time() - t0
                tput = args.batch * args.seq * (t - start + 1) / max(dt, 1e-9)
                print(f"[train] step {t:5d} loss {loss:7.4f} "
                      f"gnorm {float(metrics['grad_norm']):7.3f} "
                      f"tok/s {tput:9.0f}")
            if args.ckpt_dir and (t + 1) % args.ckpt_every == 0:
                save_state(state, args.ckpt_dir, step=t + 1)
    if args.ckpt_dir:
        save_state(state, args.ckpt_dir, step=args.steps)
    print("[train] done")
    return state


if __name__ == "__main__":
    main()
