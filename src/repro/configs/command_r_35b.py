"""command-r-35b [dense] — Cohere Command-R v01 [hf:CohereForAI/c4ai-command-r-v01].

40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000. GQA, no bias.
Cohere specifics: parallel attention+FFN block sharing one LayerNorm,
tied input/output embeddings, rope_theta=8e6.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab=256_000,
    head_dim=128,
    qkv_bias=False,
    out_bias=False,
    rope_theta=8_000_000.0,
    norm="layernorm",
    act="silu",
    glu=True,
    parallel_block=True,
    tie_embeddings=True,
    sliding_window_decode=4096,   # long_500k sub-quadratic serving variant
)
