"""grok-1-314b [moe] — xAI Grok-1 [hf:xai-org/grok-1].

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072; MoE 8 experts
top-2. Attention-logit softcapping (30.0) as in the released model.
"""
from repro.models.common import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32_768,
    vocab=131_072,
    head_dim=128,
    qkv_bias=False,
    rope_theta=10_000.0,
    norm="rmsnorm",
    act="gelu",
    glu=True,
    logit_softcap=30.0,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32_768, n_shared=0),
    sliding_window_decode=4096,
)
