"""qwen2.5-14b [dense] — Qwen2.5 family [hf:Qwen/Qwen2.5-0.5B card lineage].

48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064. GQA with QKV bias.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=13_824,
    vocab=152_064,
    head_dim=128,
    qkv_bias=True,
    out_bias=False,
    rope_theta=1_000_000.0,
    norm="rmsnorm",
    act="silu",
    glu=True,
    sliding_window_decode=4096,
)
