"""seamless-m4t-large-v2 [audio] — SeamlessM4T v2 [arXiv:2308.11596].

24L d_model=1024 16H (GQA kv=16) d_ff=8192 vocab=256206. Encoder-decoder
multimodal backbone: 24 bidirectional encoder layers over precomputed
speech-frame embeddings (conformer/mel frontend is the allowed STUB; see
DESIGN.md §3) + 24 causal decoder layers with cross-attention.
Adaptation note: learned/sinusoidal positions replaced by RoPE (framework
uniformity; recorded in DESIGN.md hardware-adaptation notes).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,
    n_encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=256_206,
    head_dim=64,
    qkv_bias=True,
    out_bias=True,
    norm="layernorm",
    act="gelu",
    glu=False,
    embed_scale=True,
    frontend="frame_stub",
    sliding_window_decode=4096,
)
