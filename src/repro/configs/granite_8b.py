"""granite-8b [dense] — IBM Granite Code 8B [arXiv:2405.04324].

36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152. Llama-arch, code
model; tied embeddings.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="granite-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14_336,
    vocab=49_152,
    head_dim=128,
    qkv_bias=False,
    rope_theta=10_000_000.0,
    norm="rmsnorm",
    act="silu",
    glu=True,
    tie_embeddings=True,
    sliding_window_decode=4096,
)
