"""Architecture registry: ``--arch <id>`` resolves here.

Ten assigned architectures (six families) + the training-example model.
Each config file cites its source paper / model card.
"""
from __future__ import annotations

import importlib

from repro.models.common import INPUT_SHAPES, InputShape, ModelConfig

_MODULES = {
    "command-r-35b": "command_r_35b",
    "rwkv6-3b": "rwkv6_3b",
    "qwen2.5-14b": "qwen2_5_14b",
    "granite-8b": "granite_8b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "grok-1-314b": "grok_1_314b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "repro-100m": "repro_100m",
}

ASSIGNED = tuple(k for k in _MODULES if k != "repro-100m")


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch '{arch_id}'; have {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {k: get_config(k) for k in _MODULES}


def get_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]
