"""repro-100m — the end-to-end training-example model (~115M params).

Not an assigned architecture: a llama-style decoder sized so the
examples/train_lm.py driver can train a few hundred steps on CPU-class
hardware while exercising the same code paths as the production archs.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="repro-100m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=4,
    d_ff=3072,
    vocab=32_768,
    head_dim=64,
    norm="rmsnorm",
    act="silu",
    glu=True,
    tie_embeddings=True,
    sliding_window_decode=1024,
)
