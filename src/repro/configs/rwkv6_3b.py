"""rwkv6-3b [ssm] — RWKV-6 "Finch" [arXiv:2404.05892].

32L d_model=2560 (attention-free) d_ff=8960 vocab=65536; data-dependent
decay time-mix + squared-ReLU channel-mix. O(1) recurrent state -> runs
long_500k natively.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,            # informational; time-mix uses rwkv_head_dim
    n_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab=65_536,
    rope_variant="none",
    norm="layernorm",
    block_pattern=tuple(["rwkv"] * 32),
    rwkv_head_dim=64,
)
