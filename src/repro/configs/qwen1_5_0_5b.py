"""qwen1.5-0.5b [dense] — Qwen1.5 0.5B [hf:Qwen/Qwen1.5-0.5B].

24L d_model=1024 16H (GQA kv=16 = MHA) d_ff=2816 vocab=151936; QKV bias,
tied embeddings. Also the quickstart-scale architecture.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=2816,
    vocab=151_936,
    head_dim=64,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    norm="rmsnorm",
    act="silu",
    glu=True,
    tie_embeddings=True,
    sliding_window_decode=4096,
)
