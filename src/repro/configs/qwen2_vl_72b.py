"""qwen2-vl-72b [vlm] — Qwen2-VL [arXiv:2409.12191].

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064; M-RoPE with
sections (16,24,24) over (temporal,height,width) position ids; dynamic-
resolution ViT frontend is the allowed STUB — input_specs() supplies patch
embeddings + 3-axis position grids (DESIGN.md §3).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29_568,
    vocab=152_064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    rope_variant="mrope",
    mrope_sections=(16, 24, 24),
    norm="rmsnorm",
    act="silu",
    glu=True,
    frontend="patch_stub",
    sliding_window_decode=4096,
)
