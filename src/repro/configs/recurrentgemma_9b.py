"""recurrentgemma-9b [hybrid] — Griffin architecture [arXiv:2402.19427].

38L d_model=4096 16H (GQA kv=1 = MQA) d_ff=12288 vocab=256000; RG-LRU
recurrent blocks and local attention in 2:1 pattern (rg, rg, local_attn),
local window 2048, lru_width 5632 (model card), GeGLU MLP, scaled
embeddings. O(width) recurrent state + windowed attention -> long_500k
runs natively.
"""
from repro.models.common import ModelConfig

_PATTERN = tuple((["rglru", "rglru", "local_attn"] * 13)[:38])

CONFIG = ModelConfig(
    arch_id="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12_288,
    vocab=256_000,
    head_dim=256,
    qkv_bias=False,
    rope_theta=10_000.0,
    norm="rmsnorm",
    act="gelu",
    glu=True,
    block_pattern=_PATTERN,
    local_window=2048,
    rglu_width=5632,
    conv_width=4,
    embed_scale=True,
    tie_embeddings=True,
)
