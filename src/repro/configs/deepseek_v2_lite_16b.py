"""deepseek-v2-lite-16b [moe] — DeepSeek-V2-Lite [arXiv:2405.04434].

27L d_model=2048 16H d_ff=1408(expert width) vocab=102400; MLA with
kv_lora_rank=512 (qk_nope 128 / qk_rope 64 / v_head 128); MoE with 2 shared
+ 64 routed experts, top-6. Layer 0 keeps a dense FFN (as in the released
model). Note: the assignment bracket's "160 routed" matches full V2, not
Lite; we follow the explicit "64e top-6" spec. The latent KV cache is the
long-context story: decode state is (c_kv 512 + k_rope 64) per token.
"""
from repro.models.common import ModelConfig, MLAConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=102_400,
    head_dim=128,
    rope_theta=10_000.0,
    norm="rmsnorm",
    act="silu",
    glu=True,
    block_pattern=tuple(["mla"] * 27),
    mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2),
    sliding_window_decode=4096,
)
