"""Seeded, deterministic fault injection for the virtual cluster.

The paper's systems claim is that first-order methods tolerate imperfect
communication — stale gradients (async PS), lossy payloads (quantization),
partial views (gossip). Until now the cluster only ever simulated
*healthy* workers: static membership, lossless wires. This module is the
failure substrate every scale-out claim runs under:

  * ``FaultPlan`` — a declarative, seeded description of what goes wrong:
    crash/restart windows per worker (``t_up = inf`` is a permanent
    departure), mid-run joins, per-message drop / duplicate / extra-
    delay distributions, and the CORRUPT class: wire bit-flips caught by
    the CRC32 frame, NaN/Inf-poisoned gradients caught by the post-
    decode finite guard, bit-rotted checkpoint pulls, and persistent
    Byzantine workers (sign-flip / scaled / random gradients). Every
    decision is a pure function of ``(seed, stream, src, dst, tag,
    attempt)`` — the same plan yields the same faults regardless of
    event-loop visit order, so traces stay bit-reproducible (asserted in
    tests/test_faults.py).
  * ``FaultLedger`` — the accounting the scheduler emits alongside the
    wire ledger: every dropped wire message, every retry, every
    duplicate, every straggler cut by a quorum/timeout, every membership
    epoch, every rejoin. The invariant (``validate``): the ledger and
    the ``Trace.comm`` delivery statuses agree exactly — a message is
    delivered, lost, or a duplicate, never unaccounted.
  * ``inject`` — the per-message transform round-based protocols apply
    before ``eventsim.simulate``: extra in-network delay shifts the
    request, duplicates add a ``~dup`` twin (delivered but ignored),
    drops either lose the message (unreliable channels: the sync uplink,
    DSGD gossip) or chain deterministic retries with exponential backoff
    (reliable channels: the PS broadcast, DCD/ECD deltas — replicas must
    stay consistent, so loss becomes latency instead of error).
  * ``live_mixing_matrix`` — elastic membership for gossip: the mass a
    live worker would have sent to an absent neighbor returns to its
    self-weight, absent workers become identity rows. The result stays
    symmetric and doubly stochastic over the live set (Assumption 7 on
    the survivors), and is re-derived — and re-validated through
    ``mixing.birkhoff_decomposition`` — at every membership epoch.

Scenario factories (``lossy_network`` / ``crash_restart`` / ``churn``)
name the standard failure benchmarks ``benchmarks/cluster_bench.py``
publishes into ``BENCH_cluster.json``.
"""
from __future__ import annotations

import dataclasses
import math
import zlib
from typing import Iterable, Optional, Sequence

import numpy as np

from repro import obs
from repro.obs import flight as obs_flight
from repro.core import eventsim

INF = float("inf")

# persistent-adversary gradient transforms execute.py applies at replay
BYZANTINE_MODES = frozenset({"sign_flip", "scale", "random"})


# ---------------------------------------------------------------------------
# The plan: what can go wrong, decided deterministically
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Declarative fault schedule for an ``n_workers`` cluster.

    crashes:  ``(worker, t_down, t_up)`` triples — the worker is absent
              during ``[t_down, t_up)``; ``t_up = inf`` is a permanent
              departure. Work in flight when the window opens is lost.
    joins:    ``(worker, t_join)`` — the worker does not exist before
              ``t_join`` (mid-run scale-up); on arrival it pulls the
              current model through the compressed-checkpoint wire.
    p_drop:   per-wire-message loss probability (the sender still pays
              the send: the bytes went on the wire and vanished).
    p_dup:    probability a delivered message is duplicated (the twin is
              delivered and ignored — at-least-once wires).
    p_corrupt: probability a delivered payload arrives with flipped bits
              — the CRC32 wire frame detects it on receive; reliable
              channels retransmit, the unreliable uplink excludes the
              contribution from the quorum.
    p_poison: probability a payload decodes to NaN/Inf (corruption the
              checksum happened to pass, or a worker emitting garbage) —
              the post-decode finite guard skips-and-ledgers it.
    p_ckpt_corrupt: probability a donor's stored checkpoint fails its
              per-array CRC on arrival — the rejoiner re-fetches from
              the next live donor.
    byzantine: ``(worker, mode)`` pairs of persistently adversarial
              workers, mode one of ``sign_flip`` (sends ``-g``),
              ``scale`` (sends ``byzantine_scale * g``), ``random``
              (sends ``byzantine_scale``-sized noise). Content faults,
              not wire faults: the payload frames verify clean, so only
              a robust aggregation rule defends.
    delay_scale / delay_sigma: extra in-network delay per message,
              ``delay_scale * lognormal(0, delay_sigma)`` seconds.
    max_retries / backoff: reliable-channel retransmit policy — retry
              ``k`` waits ``backoff * 2**(k-1)`` after the failed
              attempt; after ``max_retries`` the transport escalates and
              the final attempt is treated as delivered (the simulation
              must terminate under p_drop = 1).

    Every stochastic decision is drawn from
    ``default_rng((seed, stream, src, dst, crc32(tag), attempt))`` — a
    pure function of the message identity, independent of simulation
    order.
    """

    n_workers: int
    seed: int = 0
    p_drop: float = 0.0
    p_dup: float = 0.0
    delay_scale: float = 0.0
    delay_sigma: float = 0.6
    crashes: tuple = ()
    joins: tuple = ()
    max_retries: int = 3
    backoff: float = 0.05
    p_corrupt: float = 0.0
    p_poison: float = 0.0
    p_ckpt_corrupt: float = 0.0
    byzantine: tuple = ()
    byzantine_scale: float = 8.0

    def __post_init__(self):
        crashes = tuple((int(w), float(a), float(b)) for w, a, b in
                        self.crashes)
        joins = tuple((int(w), float(t)) for w, t in self.joins)
        byz = tuple((int(w), str(m)) for w, m in self.byzantine)
        object.__setattr__(self, "crashes", crashes)
        object.__setattr__(self, "joins", joins)
        object.__setattr__(self, "byzantine", byz)
        for w, a, b in crashes:
            if not 0 <= w < self.n_workers:
                raise ValueError(f"crash names worker {w} of "
                                 f"{self.n_workers}")
            if not b > a:
                raise ValueError(f"crash window [{a}, {b}) is empty")
        for w, t in joins:
            if not 0 <= w < self.n_workers:
                raise ValueError(f"join names worker {w} of "
                                 f"{self.n_workers}")
        for w, mode in byz:
            if not 0 <= w < self.n_workers:
                raise ValueError(f"byzantine names worker {w} of "
                                 f"{self.n_workers}")
            if mode not in BYZANTINE_MODES:
                raise ValueError(f"byzantine mode {mode!r} not in "
                                 f"{sorted(BYZANTINE_MODES)}")

    # -- membership -------------------------------------------------------

    def join_time(self, worker: int) -> float:
        return max((t for w, t in self.joins if w == worker), default=0.0)

    def is_up(self, worker: int, t: float) -> bool:
        if t < self.join_time(worker):
            return False
        return not any(w == worker and a <= t < b
                       for w, a, b in self.crashes)

    def down_in(self, worker: int, t0: float, t1: float) -> bool:
        """True if the worker is absent at any point of ``[t0, t1]`` —
        the participation test: work spanning a crash window is lost."""
        if t0 < self.join_time(worker):
            return True
        return any(w == worker and a <= t1 and t0 < b
                   for w, a, b in self.crashes)

    def restart_after(self, worker: int, t: float) -> Optional[float]:
        """Earliest ``t' >= t`` the worker is up again (None: never)."""
        if math.isinf(t):
            return None
        t_up = max(t, self.join_time(worker))
        for _ in range(len(self.crashes) + 1):
            hit = [b for w, a, b in self.crashes
                   if w == worker and a <= t_up < b]
            if not hit:
                return t_up
            t_up = max(hit)
            if math.isinf(t_up):
                return None
        return t_up

    def alive_at(self, t: float) -> tuple:
        return tuple(w for w in range(self.n_workers) if self.is_up(w, t))

    @property
    def has_message_faults(self) -> bool:
        return (self.p_drop > 0.0 or self.p_dup > 0.0
                or self.delay_scale > 0.0 or self.p_corrupt > 0.0
                or self.p_poison > 0.0)

    # -- per-message decisions -------------------------------------------

    def _rng(self, stream: int, src: int, dst: int, tag: str,
             attempt: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.seed, stream, src + 1, dst + 1,
             zlib.crc32(tag.encode()), attempt))

    def drops_msg(self, src: int, dst: int, tag: str,
                  attempt: int = 0) -> bool:
        if self.p_drop <= 0.0:
            return False
        return bool(self._rng(2, src, dst, tag, attempt).random()
                    < self.p_drop)

    def dups_msg(self, src: int, dst: int, tag: str,
                 attempt: int = 0) -> bool:
        if self.p_dup <= 0.0:
            return False
        return bool(self._rng(3, src, dst, tag, attempt).random()
                    < self.p_dup)

    def extra_delay(self, src: int, dst: int, tag: str) -> float:
        if self.delay_scale <= 0.0:
            return 0.0
        return float(self.delay_scale
                     * self._rng(4, src, dst, tag, 0).lognormal(
                         0.0, self.delay_sigma))

    def retry_wait(self, attempt: int) -> float:
        """Backoff before retry ``attempt`` (1-based)."""
        return self.backoff * (2.0 ** (attempt - 1))

    # -- corruption class -------------------------------------------------

    def corrupts_msg(self, src: int, dst: int, tag: str,
                     attempt: int = 0) -> bool:
        """Bit-flip corruption the receiver's CRC32 check detects."""
        if self.p_corrupt <= 0.0:
            return False
        return bool(self._rng(5, src, dst, tag, attempt).random()
                    < self.p_corrupt)

    def poisons_msg(self, src: int, dst: int, tag: str,
                    attempt: int = 0) -> bool:
        """NaN/Inf poisoning the post-decode finite guard detects."""
        if self.p_poison <= 0.0:
            return False
        return bool(self._rng(6, src, dst, tag, attempt).random()
                    < self.p_poison)

    def corrupt_bit(self, src: int, dst: int, tag: str, attempt: int,
                    n_bits: int) -> int:
        """WHICH bit flips in an ``n_bits``-long frame — pure function
        of the message identity, so tests can materialize the exact
        corruption the plan modelled."""
        return int(self._rng(7, src, dst, tag, attempt).integers(
            0, max(n_bits, 1)))

    def bad_checkpoint(self, donor: int, worker: int,
                       round_idx: int) -> bool:
        """The donor's stored checkpoint fails its per-array CRC when it
        lands at the rejoiner (stream 8; ``attempt`` slots the round)."""
        if self.p_ckpt_corrupt <= 0.0:
            return False
        return bool(self._rng(8, donor, worker, "ckptsrc",
                              round_idx).random() < self.p_ckpt_corrupt)

    def byzantine_mode(self, worker: int) -> Optional[str]:
        for w, mode in self.byzantine:
            if w == worker:
                return mode
        return None

    def is_byzantine(self, worker: int) -> bool:
        return self.byzantine_mode(worker) is not None


# ---------------------------------------------------------------------------
# Scenario factories (the named failure benchmarks)
# ---------------------------------------------------------------------------


def lossy_network(n: int, *, p_drop: float = 0.1, p_dup: float = 0.0,
                  delay_scale: float = 0.0, seed: int = 0) -> FaultPlan:
    """Messages vanish (and optionally duplicate / stall) — membership
    is stable. The quantization story's evil twin: bits lost in flight
    instead of rounded away."""
    return FaultPlan(n, seed=seed, p_drop=p_drop, p_dup=p_dup,
                     delay_scale=delay_scale)


def crash_restart(n: int, *, worker: Optional[int] = None, t_down: float,
                  t_up: float, p_drop: float = 0.0,
                  seed: int = 0) -> FaultPlan:
    """One worker (default: worker 0) crashes during ``[t_down, t_up)``
    and rejoins by pulling the model through the compressed-checkpoint
    wire."""
    w = 0 if worker is None else worker
    return FaultPlan(n, seed=seed, p_drop=p_drop,
                     crashes=((w, t_down, t_up),))


def churn(n: int, *, departures: Sequence = (), joins: Sequence = (),
          p_drop: float = 0.0, seed: int = 0) -> FaultPlan:
    """Elastic membership: ``departures`` = (worker, t) permanent
    leaves, ``joins`` = (worker, t) mid-run arrivals."""
    return FaultPlan(n, seed=seed, p_drop=p_drop,
                     crashes=tuple((w, t, INF) for w, t in departures),
                     joins=tuple(joins))


def corrupt_wire(n: int, *, p_corrupt: float = 0.05,
                 p_poison: float = 0.0, p_drop: float = 0.0,
                 seed: int = 0) -> FaultPlan:
    """Bits rot in flight: payloads arrive with flipped bits (CRC-
    detected) and occasionally decode to NaN/Inf (guard-detected) —
    membership is stable."""
    return FaultPlan(n, seed=seed, p_drop=p_drop, p_corrupt=p_corrupt,
                     p_poison=p_poison)


def byzantine_workers(n: int, *, f: int = 2, mode: str = "sign_flip",
                      scale: float = 8.0, p_corrupt: float = 0.0,
                      seed: int = 0) -> FaultPlan:
    """``f`` persistently adversarial workers (the lowest ids — which
    ids is immaterial to the aggregators, and fixing them keeps every
    trace and its replay bit-reproducible). Their wire frames verify
    clean; only a robust aggregation rule defends."""
    if not 0 <= f <= n:
        raise ValueError(f"f={f} byzantine of n={n}")
    return FaultPlan(n, seed=seed, p_corrupt=p_corrupt,
                     byzantine=tuple((w, mode) for w in range(f)),
                     byzantine_scale=scale)


# ---------------------------------------------------------------------------
# The ledger: what actually went wrong
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DropRecord:
    """One wire message lost in flight (attempt 0 = the original)."""

    t: float
    src: int
    dst: int
    size: float
    tag: str
    attempt: int = 0


@dataclasses.dataclass(frozen=True)
class RetryRecord:
    """One retransmit of a reliable-channel message."""

    t: float
    src: int
    dst: int
    tag: str
    attempt: int


@dataclasses.dataclass(frozen=True)
class DupRecord:
    """One delivered-and-ignored duplicate."""

    t: float
    src: int
    dst: int
    tag: str


@dataclasses.dataclass(frozen=True)
class CorruptRecord:
    """One wire message that arrived bad and was detected on receive.

    ``kind``: ``bitflip`` (CRC32 frame mismatch), ``nan`` (frame passed
    but the decode produced non-finite values — the post-decode guard),
    ``checksum`` (a checkpoint pull whose per-array CRC failed). The
    bytes were paid for in full: detection happens after receipt."""

    t: float
    src: int
    dst: int
    size: float
    tag: str
    attempt: int = 0
    kind: str = "bitflip"


@dataclasses.dataclass(frozen=True)
class TimeoutRecord:
    """A contribution that arrived after the round's quorum/timeout cut
    — delivered, then discarded by the server (backup-worker style)."""

    round: int
    worker: int
    t_cut: float
    t_arrival: float


@dataclasses.dataclass(frozen=True)
class QuorumShortfall:
    """A round that closed with fewer contributions than its quorum."""

    round: int
    n_got: int
    n_wanted: int


@dataclasses.dataclass(frozen=True)
class EpochRecord:
    """A membership change: the live set at ``t`` and the size of the
    Birkhoff decomposition of the re-derived mixing matrix (0 for PS
    protocols, which have no W)."""

    t: float
    round: int
    alive: tuple
    n_birkhoff_terms: int = 0


@dataclasses.dataclass(frozen=True)
class RejoinRecord:
    """A worker coming back (restart or mid-run join) and pulling the
    current model through the compressed-checkpoint wire."""

    t: float
    worker: int
    round: int
    donor: int          # who served the checkpoint (PS = -1)


@dataclasses.dataclass(frozen=True)
class FaultLedger:
    """Everything that went wrong, exactly once each."""

    drops: tuple = ()
    retries: tuple = ()
    duplicates: tuple = ()
    timeouts: tuple = ()
    shortfalls: tuple = ()
    epochs: tuple = ()
    rejoins: tuple = ()
    lost_compute: tuple = ()    # (worker, t) — work killed by a crash
    corrupt: tuple = ()         # CorruptRecord — detected-bad payloads

    @property
    def n_dropped(self) -> int:
        return len(self.drops)

    @property
    def n_retried(self) -> int:
        return len(self.retries)

    @property
    def n_duplicated(self) -> int:
        return len(self.duplicates)

    @property
    def n_timed_out(self) -> int:
        return len(self.timeouts)

    @property
    def n_corrupted(self) -> int:
        return len(self.corrupt)

    def summary(self) -> dict:
        return {"dropped": self.n_dropped, "retried": self.n_retried,
                "duplicated": self.n_duplicated,
                "timed_out": self.n_timed_out,
                "shortfalls": len(self.shortfalls),
                "epochs": len(self.epochs),
                "rejoins": len(self.rejoins),
                "lost_compute": len(self.lost_compute),
                "corrupted": self.n_corrupted}


class _LedgerBuilder:
    """Mutable accumulator the scheduler fills, frozen at trace time."""

    def __init__(self):
        self.drops: list = []
        self.retries: list = []
        self.duplicates: list = []
        self.timeouts: list = []
        self.shortfalls: list = []
        self.epochs: list = []
        self.rejoins: list = []
        self.lost_compute: list = []
        self.corrupt: list = []

    def freeze(self) -> FaultLedger:
        return FaultLedger(tuple(self.drops), tuple(self.retries),
                           tuple(self.duplicates), tuple(self.timeouts),
                           tuple(self.shortfalls), tuple(self.epochs),
                           tuple(self.rejoins), tuple(self.lost_compute),
                           tuple(self.corrupt))


# ---------------------------------------------------------------------------
# Per-message injection for round-based protocols
# ---------------------------------------------------------------------------


def inject(msgs: Iterable[eventsim.Msg], plan: Optional[FaultPlan],
           ledger: _LedgerBuilder, *, reliable: bool,
           est_cost: float) -> tuple:
    """Apply the plan to a batch of logical messages.

    Input messages must have unique ``(src, dst, tag)``. Returns
    ``(wire_msgs, statuses, delivered)``:

      wire_msgs   every attempt that goes on the wire (originals, chained
                  retries tagged ``~a<k>``, duplicates tagged ``~dup``) —
                  all of them occupy ports in ``eventsim.simulate``;
      statuses    ``(src, dst, tag) -> 'lost' | 'dup' | 'corrupted'``
                  for simulate();
      delivered   ``(src, dst, base_tag) -> attempt_tag`` of the attempt
                  the receiver actually uses (absent: the message — and
                  on unreliable channels its payload — is gone).

    Reliable channels chain deterministic retries: retry ``k`` is
    requested one estimated transfer (``est_cost``) plus
    ``plan.retry_wait(k)`` after the failed attempt; attempt
    ``max_retries`` always succeeds so the round terminates. Corrupted
    arrivals (CRC mismatch, or NaN/Inf past the decode guard) ride the
    same retry chain — the receiver got the bytes, checked them, and
    asked again; on unreliable channels the contribution is simply
    excluded (the quorum absorbs it, like a drop that cost full
    transfer).
    """
    wire: list = []
    statuses: dict = {}
    delivered: dict = {}
    for m in msgs:
        if plan is None or not plan.has_message_faults:
            wire.append(m)
            delivered[(m.src, m.dst, m.tag)] = m.tag
            continue
        t_req = m.t_req + plan.extra_delay(m.src, m.dst, m.tag)
        attempt = 0
        while True:
            tag = m.tag if attempt == 0 else f"{m.tag}~a{attempt}"
            lost = plan.drops_msg(m.src, m.dst, m.tag, attempt)
            bad = None
            if not lost:
                if plan.corrupts_msg(m.src, m.dst, m.tag, attempt):
                    bad = "bitflip"
                elif plan.poisons_msg(m.src, m.dst, m.tag, attempt):
                    bad = "nan"
            if reliable and attempt >= plan.max_retries:
                lost = False        # transport escalation: must terminate
                bad = None
            wire.append(eventsim.Msg(t_req, m.src, m.dst, m.size, tag,
                                     m.n_messages))
            if lost:
                statuses[(m.src, m.dst, tag)] = "lost"
                ledger.drops.append(DropRecord(t_req, m.src, m.dst,
                                               m.size, m.tag, attempt))
                if obs.enabled("metrics"):
                    obs.counter("faults.dropped_msgs",
                                reliable=reliable).inc()
                    obs.counter("faults.dropped_mb").inc(m.size)
                obs_flight.record("faults.drop", t=t_req, src=m.src,
                                  dst=m.dst, tag=m.tag, attempt=attempt,
                                  reliable=reliable)
                if not reliable:
                    break
                attempt += 1
                ledger.retries.append(RetryRecord(t_req, m.src, m.dst,
                                                  m.tag, attempt))
                t_req = t_req + est_cost + plan.retry_wait(attempt)
                continue
            if bad is not None:
                # the bytes landed in full, then failed the receiver's
                # integrity check (CRC frame or finite guard)
                statuses[(m.src, m.dst, tag)] = "corrupted"
                ledger.corrupt.append(CorruptRecord(t_req, m.src, m.dst,
                                                    m.size, m.tag,
                                                    attempt, bad))
                if obs.enabled("metrics"):
                    obs.counter("faults.corrupted_msgs", kind=bad,
                                reliable=reliable).inc()
                obs_flight.record("faults.corrupt", t=t_req, src=m.src,
                                  dst=m.dst, tag=m.tag, attempt=attempt,
                                  corruption=bad, reliable=reliable)
                if not reliable:
                    break
                attempt += 1
                ledger.retries.append(RetryRecord(t_req, m.src, m.dst,
                                                  m.tag, attempt))
                t_req = t_req + est_cost + plan.retry_wait(attempt)
                continue
            if attempt > 0 and obs.enabled("metrics"):
                obs.counter("faults.retried_msgs").inc(attempt)
            delivered[(m.src, m.dst, m.tag)] = tag
            if plan.dups_msg(m.src, m.dst, m.tag, attempt):
                dtag = tag + "~dup"
                wire.append(eventsim.Msg(t_req, m.src, m.dst, m.size,
                                         dtag, m.n_messages))
                statuses[(m.src, m.dst, dtag)] = "dup"
                ledger.duplicates.append(DupRecord(t_req, m.src, m.dst,
                                                   m.tag))
            break
    return wire, statuses, delivered


def collect_quorum(arrivals: Sequence, *, t_start: float,
                   timeout: Optional[float], quorum: Optional[int],
                   ledger: _LedgerBuilder, round_idx: int,
                   n_expected: int = 0) -> tuple:
    """Backup-worker aggregation: when does the server stop collecting?

    ``arrivals`` is ``[(t_end, worker), ...]`` of DELIVERED uplinks. The
    server closes the round at the earlier of the ``quorum``-th arrival
    and ``t_start + timeout`` (whichever limits are set); with neither
    set — or when fewer than ``quorum`` messages ever arrive — it takes
    everything that does arrive (it cannot wait for bytes that were
    dropped). Returns ``(t_agg, contributors)``; arrivals after the cut
    are recorded as ``TimeoutRecord``s, shortfalls as
    ``QuorumShortfall``.

    ``n_expected`` is how many uplinks were sent this round: when EVERY
    one was lost/corrupted/excluded the round must still close as a
    recorded ``QuorumShortfall`` (the replay carries the previous
    params), never as an aggregation over an empty contributor set —
    even on a full-barrier schedule with no explicit quorum.
    """
    arr = sorted(arrivals)
    deadline = t_start + timeout if timeout is not None else INF
    t_q = arr[quorum - 1][0] if (quorum is not None
                                 and len(arr) >= quorum) else INF
    t_agg = min(t_q, deadline)
    if math.isinf(t_agg):
        t_agg = arr[-1][0] if arr else t_start
    contributors = [w for t_end, w in arr if t_end <= t_agg]
    for t_end, w in arr:
        if t_end > t_agg:
            ledger.timeouts.append(TimeoutRecord(round_idx, w, t_agg,
                                                 t_end))
            if obs.enabled("metrics"):
                obs.counter("faults.quorum_cuts").inc()
                obs.histogram("faults.quorum_wait_s").observe(
                    t_end - t_agg)
            obs_flight.record("faults.quorum_cut", round=round_idx,
                              worker=w, t_cut=t_agg, t_arrival=t_end)
    # an implicit quorum of 1 covers the all-excluded full-barrier edge
    want = quorum if quorum is not None else (1 if n_expected > 0 else 0)
    if len(contributors) < want:
        ledger.shortfalls.append(QuorumShortfall(round_idx,
                                                 len(contributors),
                                                 want))
        if obs.enabled("metrics"):
            obs.counter("faults.quorum_shortfalls").inc()
        obs_flight.record("faults.quorum_shortfall", round=round_idx,
                          got=len(contributors), wanted=want)
    return t_agg, contributors


# ---------------------------------------------------------------------------
# Elastic gossip: W over the live set
# ---------------------------------------------------------------------------


def live_mixing_matrix(w: np.ndarray, alive: Sequence[int]) -> np.ndarray:
    """Restrict a symmetric doubly stochastic W to the live workers.

    The mass a live worker would have exchanged with an absent neighbor
    returns to its self-weight; absent workers become identity rows (a
    frozen replica neither sends nor receives). The result is symmetric
    and doubly stochastic on the FULL index set — Assumption 7 holds on
    the live block, identity on the rest — so the same stacked-worker
    replay shape works across membership epochs.
    """
    w = np.array(w, dtype=float)
    n = w.shape[0]
    mask = np.zeros(n, dtype=bool)
    mask[list(alive)] = True
    live = np.where(np.outer(mask, mask), w, 0.0)
    np.fill_diagonal(live, 0.0)
    live[np.arange(n), np.arange(n)] = 1.0 - live.sum(axis=1)
    return live


def epoch_matrix(w: np.ndarray, alive: Sequence[int]) -> tuple:
    """Re-derive the gossip matrix for a membership epoch and validate
    it through ``mixing.birkhoff_decomposition`` (the exact lowering
    ``GossipMix`` would consume: one ppermute per non-identity term).
    Returns ``(w_live, n_terms)``; raises if the restriction ever left
    the Birkhoff polytope — i.e. the degradation semantics are checked,
    not assumed, at every epoch."""
    from repro.core import mixing

    w_live = live_mixing_matrix(w, alive)
    terms = mixing.birkhoff_decomposition(w_live)
    return w_live, len(terms)


# ---------------------------------------------------------------------------
# Trace <-> ledger cross-validation
# ---------------------------------------------------------------------------


def validate(trace) -> dict:
    """Assert the fault ledger and the wire ledger tell the same story.

    Checks, for any Trace (healthy traces carry an empty ledger story):
      * every ``lost`` delivery in ``trace.comm`` has exactly one
        ``DropRecord`` (same src/dst/base tag), and vice versa;
      * every ``dup`` delivery has exactly one ``DupRecord``;
      * every ``corrupted`` delivery has exactly one ``CorruptRecord``;
      * every ``~a<k>`` retry attempt on the wire has a ``RetryRecord``;
      * ok + lost + dup + corrupted == attempted (nothing unaccounted);
      * every update event lands at or before the makespan.

    Returns the tally so tests/benchmarks can publish it. When the
    flight recorder is enabled, a failed assertion dumps the ring buffer
    (``flight_faults_validate.json``) before re-raising — the forged-
    ledger class of bug leaves its recent history on disk.
    """
    try:
        return _validate(trace)
    except AssertionError as e:
        obs_flight.record("faults.validate_failed", error=str(e),
                          protocol=trace.protocol)
        obs_flight.dump_on_failure("faults.validate",
                                   f"AssertionError: {e}")
        raise


def _validate(trace) -> dict:
    led = trace.faults if trace.faults is not None else FaultLedger()

    def base(tag: str) -> str:
        return tag.split("~", 1)[0]

    lost = [d for d in trace.comm if getattr(d, "status", "ok") == "lost"]
    dups = [d for d in trace.comm if getattr(d, "status", "ok") == "dup"]
    ok = [d for d in trace.comm if getattr(d, "status", "ok") == "ok"]
    corr = [d for d in trace.comm
            if getattr(d, "status", "ok") == "corrupted"]
    retry_wires = [d for d in trace.comm
                   if "~a" in d.tag and getattr(d, "status", "ok") != "dup"]

    lost_keys = sorted((d.src, d.dst, base(d.tag)) for d in lost)
    drop_keys = sorted((r.src, r.dst, r.tag) for r in led.drops)
    assert lost_keys == drop_keys, (
        f"{len(lost_keys)} lost deliveries vs {len(drop_keys)} ledger "
        "drops")

    dup_keys = sorted((d.src, d.dst, base(d.tag)) for d in dups)
    dup_led = sorted((r.src, r.dst, r.tag) for r in led.duplicates)
    assert dup_keys == dup_led, (
        f"{len(dup_keys)} dup deliveries vs {len(dup_led)} ledger dups")

    corr_keys = sorted((d.src, d.dst, base(d.tag)) for d in corr)
    corr_led = sorted((r.src, r.dst, r.tag) for r in led.corrupt)
    assert corr_keys == corr_led, (
        f"{len(corr_keys)} corrupted deliveries vs {len(corr_led)} "
        "ledger corruptions")

    retry_keys = sorted((d.src, d.dst, base(d.tag)) for d in retry_wires)
    retry_led = sorted((r.src, r.dst, r.tag) for r in led.retries)
    assert retry_keys == retry_led, (
        f"{len(retry_keys)} retry wires vs {len(retry_led)} ledger "
        "retries")

    assert (len(ok) + len(lost) + len(dups) + len(corr)
            == len(trace.comm))
    for e in trace.events:
        assert e.t_wall <= trace.makespan + 1e-12

    return {"attempted": len(trace.comm), "delivered": len(ok),
            **led.summary()}
