"""Virtual cluster engine: event-driven heterogeneous workers running
real async / local-SGD / decentralized training.

  scheduler.py  discrete-event loop over the §1.3 switch model; emits a
                Trace of (worker, version_pulled, version_applied,
                staleness, t_wall) per applied gradient plus the full
                per-message wire ledger (cross-checks eventsim).
  protocols.py  registry of protocol objects (sync_ps / async_ps /
                local_sgd / dsgd / dcd / ecd / laq), mirroring EXCHANGES.
  execute.py    replays a Trace against real vmapped training (quadratic
                or repro-100m LM) through the fused flat-codec gradient
                path -> loss-vs-simulated-wall-clock curves.
  faults.py     seeded deterministic fault injection (FaultPlan) +
                the fault ledger, quorum/timeout aggregation, and the
                live-set mixing-matrix re-derivation every protocol's
                graceful degradation builds on; now also the corruption
                class (bit-flips, NaN poison, Byzantine workers).
  aggregators.py  Byzantine-robust PS aggregation registry (mean /
                norm_clip / trimmed_mean / coordinate_median).
"""
from repro.cluster.aggregators import AGGREGATORS, aggregator
from repro.cluster.execute import (ClusterRunResult, Workload,
                                   lm_workload, quadratic_workload, replay)
from repro.cluster.faults import (FaultLedger, FaultPlan,
                                  byzantine_workers, churn,
                                  corrupt_wire, crash_restart,
                                  live_mixing_matrix, lossy_network)
from repro.cluster.faults import validate as validate_trace
from repro.cluster.protocols import (PROTOCOLS, make_protocol,
                                     staleness_schedule)
from repro.cluster.scheduler import (ClusterSpec, Trace, TraceEvent,
                                     straggler_multipliers)

__all__ = [
    "AGGREGATORS", "ClusterRunResult", "ClusterSpec", "FaultLedger",
    "FaultPlan", "PROTOCOLS", "Trace", "TraceEvent", "Workload",
    "aggregator", "byzantine_workers", "churn", "corrupt_wire",
    "crash_restart", "live_mixing_matrix", "lm_workload", "lossy_network",
    "make_protocol", "quadratic_workload", "replay", "staleness_schedule",
    "straggler_multipliers", "validate_trace",
]
