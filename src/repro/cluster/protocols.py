"""Protocol registry for the virtual cluster — mirrors ``EXCHANGES``.

Each protocol is a frozen dataclass holding its hyper-parameters (local
period H, gossip matrix, LAQ skip, ...) with two duties:

  * ``schedule(spec, *, rounds=..., horizon=...)`` — run the discrete-
    event loop of ``repro.cluster.scheduler`` and return a ``Trace``;
  * name the replay semantics ``repro.cluster.execute.replay`` dispatches
    on (``Trace.protocol``).

``PROTOCOLS`` / ``make_protocol`` follow the exact conventions of
``repro.core.communicators.EXCHANGES`` / ``make_exchange`` so the two
registries read the same:

    make_protocol("local_sgd", period_h=8).schedule(spec, rounds=20)

``staleness_schedule`` bridges the scheduler back into the algorithm
tier: it converts a measured async trace into the per-worker delay table
a trace-driven ``DelayedExchange(schedule=...)`` replays (Assumption 5
with D(t) taken from the cluster instead of the worst case).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import numpy as np

from repro.cluster import scheduler
from repro.cluster.scheduler import ClusterSpec, Trace
from repro.core.registry import Registry, make_factory


@dataclasses.dataclass(frozen=True)
class SyncPS:
    """Synchronous parameter server (§1.3.2): the barrier baseline.

    ``ClusterSpec(allreduce="ring")`` swaps the PS uplink+broadcast for
    the partitioned ring AllReduce (2(N-1) rounds of size/N partition
    messages — the same wire pattern and 2M(N-1)/N per-worker bytes as
    ``CSGDRingExchange``); the protocol semantics (barrier, staleness 0)
    are unchanged, only the comm costing differs.

    ``aggregator`` names the PS aggregation rule from
    ``cluster.aggregators`` (mean / norm_clip / trimmed_mean /
    coordinate_median) — the robust-aggregation knob the Byzantine
    scenarios turn; the replay trains under the named rule."""

    name: str = "sync_ps"
    timeout: Optional[float] = None     # graceful degradation: per-round
    quorum: Optional[int] = None        # deadline + backup-worker quorum
    aggregator: str = "mean"            # robust aggregation rule

    def schedule(self, spec: ClusterSpec, *, rounds: int = 1,
                 horizon: Optional[float] = None,
                 plan: Optional[scheduler.F.FaultPlan] = None) -> Trace:
        del horizon
        return scheduler.schedule_sync_ps(spec, rounds=rounds, plan=plan,
                                          timeout=self.timeout,
                                          quorum=self.quorum,
                                          aggregator=self.aggregator)


@dataclasses.dataclass(frozen=True)
class AsyncPS:
    """Asynchronous parameter server (§4.1): no barrier, real staleness."""

    name: str = "async_ps"

    def schedule(self, spec: ClusterSpec, *, rounds: Optional[int] = None,
                 horizon: Optional[float] = None,
                 plan: Optional[scheduler.F.FaultPlan] = None) -> Trace:
        if horizon is None:
            if rounds is None:
                raise ValueError("async_ps needs horizon= (or rounds= to "
                                 "borrow the sync-PS makespan)")
            # equal-wall-clock convention: run as long as sync-PS would
            # UNDER THE SAME PLAN (faults slow both sides equally)
            horizon = scheduler.schedule_sync_ps(spec, rounds=rounds,
                                                 plan=plan).makespan
        return scheduler.schedule_async_ps(spec, horizon=horizon,
                                           plan=plan)


@dataclasses.dataclass(frozen=True)
class LocalSGD:
    """Local SGD with period H: H local steps between averaging rounds
    (averaging costed as PS or, with ``ClusterSpec(allreduce="ring")``,
    as the partitioned ring AllReduce)."""

    period_h: int = 8
    name: str = "local_sgd"
    timeout: Optional[float] = None
    quorum: Optional[int] = None

    def schedule(self, spec: ClusterSpec, *, rounds: int = 1,
                 horizon: Optional[float] = None,
                 plan: Optional[scheduler.F.FaultPlan] = None) -> Trace:
        del horizon
        return scheduler.schedule_local_sgd(spec, period_h=self.period_h,
                                            rounds=rounds, plan=plan,
                                            timeout=self.timeout,
                                            quorum=self.quorum)


@dataclasses.dataclass(frozen=True)
class Decentralized:
    """DSGD gossip rounds (§5.1) over any ``mixing.py`` matrix.

    ``topology`` in {'ring', 'torus', 'full'} builds the matrix from the
    axis size; an explicit ``w`` (nested tuple / array) wins. The same
    matrix drives both the comm cost (deg(W) sends per round) and the
    replay's mixing step, and matches what ``GossipMix`` lowers to
    ppermutes."""

    topology: str = "ring"
    w: Any = None
    name: str = "dsgd"

    def __post_init__(self):
        if self.w is not None:
            w = np.asarray(self.w, dtype=float)
            object.__setattr__(self, "w",
                               tuple(tuple(row) for row in w.tolist()))

    def matrix(self, n: int) -> np.ndarray:
        from repro.core import mixing

        if self.w is not None:
            w = np.asarray(self.w)
            if w.shape != (n, n):
                raise ValueError(f"W is {w.shape}, cluster has {n} workers")
            return w
        if self.topology == "ring":
            return mixing.ring(n)
        if self.topology == "torus":
            return mixing.torus_2d(*mixing.near_square_factors(n))
        if self.topology == "full":
            return mixing.fully_connected(n)
        raise ValueError(f"unknown topology {self.topology}")

    def schedule(self, spec: ClusterSpec, *, rounds: int = 1,
                 horizon: Optional[float] = None,
                 plan: Optional[scheduler.F.FaultPlan] = None) -> Trace:
        del horizon
        return scheduler.schedule_decentralized(
            spec, rounds=rounds, w=self.matrix(spec.n_workers), plan=plan)


@dataclasses.dataclass(frozen=True)
class CompressedDecentralized(Decentralized):
    """Difference-compressed DSGD (DCD-PSGD): same gossip rounds as
    ``Decentralized`` — deg(W) sends per worker per round — but every
    message is the codec's MEASURED wire bytes of the quantized model
    delta instead of the full fp32 model, and the replay applies the
    ``DCDGossipExchange`` semantics (public copies advanced by decoded
    deltas, bit-identical on every holder)."""

    compressor: str = "rq4"
    name: str = "dcd"

    def schedule(self, spec: ClusterSpec, *, rounds: int = 1,
                 horizon: Optional[float] = None,
                 plan: Optional[scheduler.F.FaultPlan] = None) -> Trace:
        del horizon
        return scheduler.schedule_decentralized(
            spec, rounds=rounds, w=self.matrix(spec.n_workers),
            codec=self.compressor, protocol=self.name, plan=plan)


@dataclasses.dataclass(frozen=True)
class ECDecentralized(CompressedDecentralized):
    """Error-compensated compressed DSGD (the ``ECDGossipExchange``
    semantics): a flat fp32 residual feeds the compression error of each
    broadcast back into the next one, so biased codecs (the default
    1-bit ``sign1``) survive decentralized mixing."""

    compressor: str = "sign1"
    name: str = "ecd"


@dataclasses.dataclass(frozen=True)
class LAQ:
    """Lazily aggregated sync PS: each worker uploads every `skip`-th
    round; the server reuses stored gradients in between."""

    skip: int = 2
    name: str = "laq"
    timeout: Optional[float] = None
    quorum: Optional[int] = None

    def schedule(self, spec: ClusterSpec, *, rounds: int = 1,
                 horizon: Optional[float] = None,
                 plan: Optional[scheduler.F.FaultPlan] = None) -> Trace:
        del horizon
        return scheduler.schedule_laq(spec, rounds=rounds, skip=self.skip,
                                      plan=plan, timeout=self.timeout,
                                      quorum=self.quorum)


PROTOCOLS: Registry = Registry("protocol", {
    "sync_ps": SyncPS,
    "async_ps": AsyncPS,
    "local_sgd": LocalSGD,
    "dsgd": Decentralized,
    "dcd": CompressedDecentralized,
    "ecd": ECDecentralized,
    "laq": LAQ,
})

make_protocol = make_factory(PROTOCOLS)


def staleness_schedule(trace: Trace, *, tau: Optional[int] = None
                       ) -> np.ndarray:
    """Per-worker staleness table for ``DelayedExchange(schedule=...)``.

    Row w holds worker w's measured staleness sequence from the trace,
    clipped to ``tau`` (default: the trace's own max — Assumption 5's
    bound as observed) and padded by repeating its last value so every
    row has equal length T. Feeding this to the algorithm tier replays
    the cluster's delay distribution through a vmapped exchange instead
    of the fixed worst-case FIFO."""
    ups = trace.updates()
    if not ups:
        raise ValueError("trace has no update events")
    bound = trace.max_staleness if tau is None else tau
    rows = []
    t_max = max(len(trace.updates_of(w)) for w in range(trace.n_workers))
    for w in range(trace.n_workers):
        s = [min(e.staleness, bound) for e in trace.updates_of(w)] or [0]
        s = s + [s[-1]] * (t_max - len(s))
        rows.append(s)
    return np.asarray(rows, dtype=int)
