"""Discrete-event scheduler for a virtual cluster of heterogeneous workers.

This generalizes ``eventsim.async_ps_timeline`` (a closed-form heapq
walk-through of Figure 4.2) into a protocol-pluggable event loop over the
same §1.3 switch model: N workers with per-(worker, step) compute times —
deterministic straggler multipliers x seeded lognormal jitter — exchange
messages whose port occupancy is costed by ``eventsim.simulate`` (round
protocols) or the PS send/recv ports directly (the async loop). Every
protocol emits a ``Trace``:

  * ``events`` — one ``TraceEvent`` per applied gradient
    ``(worker, step, version_pulled, version_applied, staleness, t_wall)``,
    sorted by apply time. ``staleness = version_applied - version_pulled``
    is the paper's D(t) (Assumption 5); sync protocols keep it 0.
  * ``comm`` / ``messages`` — the ``eventsim.Delivery`` and per-wire
    ``eventsim.MsgRecord`` ledgers of every transfer, so scheduler and
    eventsim timings cross-check: the sync-PS makespan with zero compute
    IS ``eventsim.single_ps_makespan`` (same simulate() calls, asserted
    in tests/test_cluster.py to 1e-9).

The trace is pure timing/ordering — no gradients exist here. Feeding it to
``repro.cluster.execute.replay`` turns it into REAL training (vmapped
per-worker replicas, fused flat-codec gradient path) with loss plotted
against this file's simulated wall-clock.

Protocols (see ``repro.cluster.protocols`` for the registry objects):

  sync_ps        rounds of compute -> uplink -> gated broadcast (§1.3.2)
  async_ps       free-running pull/compute/push per worker (§4.1)
  local_sgd      H local steps between averaging rounds (§4/LocalSGD)
  decentralized  gossip rounds over ANY mixing.py matrix W (§5.1); with a
                 codec, the deg(W) per-round sends are sized at the
                 codec's measured wire bytes — the DCD/ECD compressed-
                 delta gossip tier (protocols "dcd"/"ecd")
  laq            sync PS where each worker uploads every `skip`-th round
                 (round-robin lazy aggregation a la LAQ, arXiv 1909.07588;
                 the server reuses the stored gradient in between)
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Optional, Sequence

import numpy as np

from repro import obs
from repro.obs import flight as obs_flight
from repro.cluster import faults as F
from repro.core import eventsim

PS = -1   # symbolic parameter-server id in TraceEvents (msgs use index n)


# ---------------------------------------------------------------------------
# Telemetry taps (no-ops unless repro.obs is enabled; see obs/state.py)
# ---------------------------------------------------------------------------


def _span_compute(worker: int, step: int, t0: float, t1: float) -> None:
    """Live compute span for the timeline — the one row the wire/fault
    ledgers cannot reconstruct post-hoc. Callers guard on
    ``obs.enabled("trace")`` so the off path stays one dict lookup."""
    obs.tracer().sim_span("compute", worker=worker, lane="compute",
                          t0=t0, t1=t1, cat="sim,compute",
                          args={"step": step})


def _observe_trace(trace: Trace) -> Trace:
    """Metrics/flight tap every ``schedule_*`` return passes through."""
    if obs.enabled("metrics"):
        p = trace.protocol
        obs.counter("cluster.traces", protocol=p).inc()
        obs.gauge("cluster.makespan_s", protocol=p).set(trace.makespan)
        stale = obs.histogram("cluster.staleness", protocol=p)
        n_updates = 0
        for e in trace.events:
            if e.kind == "update":
                n_updates += 1
                stale.observe(e.staleness)
        obs.counter("cluster.updates", protocol=p).inc(n_updates)
        by_status: dict = {}
        mb = 0.0
        for d in trace.comm:
            s = getattr(d, "status", "ok")
            by_status[s] = by_status.get(s, 0) + 1
            mb += d.size
        for s, c in by_status.items():
            obs.counter("cluster.wire_msgs", protocol=p, status=s).inc(c)
        obs.counter("cluster.wire_mb", protocol=p).inc(mb)
        led = trace.faults
        if led is not None:
            for name, v in led.summary().items():
                obs.counter(f"cluster.faults.{name}", protocol=p).inc(v)
    if obs.enabled("flight"):
        obs.flight_record("scheduler.trace", protocol=trace.protocol,
                          n_workers=trace.n_workers,
                          makespan=trace.makespan,
                          n_events=len(trace.events),
                          n_comm=len(trace.comm))
    return trace


# ---------------------------------------------------------------------------
# Cluster description: who computes how fast, what a message costs
# ---------------------------------------------------------------------------


def straggler_multipliers(n: int, *, straggler: Optional[int] = None,
                          factor: float = 4.0) -> tuple:
    """Per-worker speed multipliers: all 1.0 with worker `straggler`
    (default: the last one) `factor`x slower — the Figure 4.1/4.2 setup."""
    m = [1.0] * n
    m[straggler if straggler is not None else n - 1] = factor
    return tuple(m)


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """N heterogeneous workers hanging off one §1.3 switch.

    ``multipliers`` is the deterministic straggler model (per-worker slow-
    down of the base ``t_compute``); ``jitter`` adds seeded lognormal
    noise per (worker, step) — ``exp(N(0, jitter))``, median 1 — so
    stragglers can be persistent, stochastic, or both. ``size_mb`` is the
    fp32 gradient/model message; pass ``codec`` to replace it with the
    measured wire size of the packed payload (``Codec.wire_bytes``),
    exactly like the eventsim builders.
    """

    n_workers: int = 8
    t_compute: float = 1.0
    multipliers: tuple = ()        # () -> homogeneous
    jitter: float = 0.0            # lognormal sigma
    t_lat: float = 1e-2
    t_tr: float = 2e-3             # s/MB at the NIC
    size_mb: float = 1.0
    codec: Optional[str] = None    # measured wire size instead of size_mb
    n_messages: int = 1            # wire messages per logical transfer
    allreduce: str = "ps"          # "ps" | "ring" — how sync/local-SGD
                                   # averaging rounds are costed: PS
                                   # uplink+broadcast, or the partitioned
                                   # ring (2(N-1) rounds of size/N chunks,
                                   # matching CSGDRingExchange)
    seed: int = 0

    def __post_init__(self):
        if self.allreduce not in ("ps", "ring"):
            raise ValueError(f"unknown allreduce '{self.allreduce}'; "
                             "have 'ps', 'ring'")

    def multiplier(self, worker: int) -> float:
        if not self.multipliers:
            return 1.0
        return float(self.multipliers[worker])

    def compute_time(self, worker: int, step: int) -> float:
        """Duration of one local gradient computation. Deterministic in
        (seed, worker, step) regardless of event-loop visit order."""
        base = self.t_compute * self.multiplier(worker)
        if self.jitter > 0.0:
            rng = np.random.default_rng((self.seed, worker, step))
            base *= float(rng.lognormal(0.0, self.jitter))
        return base

    def msg_mb(self) -> float:
        """Wire MB of one gradient/model message (codec-measured if set).

        Delegates to eventsim's chunk sizing so scheduler and eventsim
        makespans stay bit-identical (the 1e-9 cross-check)."""
        return eventsim._msg_mb(self.size_mb, 1.0, self.codec)

    def partition_msg_mb(self) -> float:
        """Wire MB of ONE ring partition message (1/n_workers of the
        buffer, codec-measured if set) — the chunk each of the 2(N-1)
        partitioned-AllReduce rounds moves per worker. Same sizing as
        ``eventsim.csgd_ring_makespan``'s, by construction."""
        return eventsim._msg_mb(self.size_mb, 1.0, self.codec,
                                n_chunks=self.n_workers)

    def msg_cost(self) -> float:
        """Port occupancy of one logical transfer."""
        return self.n_messages * self.t_lat + self.msg_mb() * self.t_tr


# ---------------------------------------------------------------------------
# Trace schema
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One applied gradient (kind='update') or a barrier marker.

    kind:            'update' | 'sync' (averaging barrier) | 'gossip'
                     | 'rejoin' (a worker restarting/joining pulled the
                     current model through the checkpoint wire)
    worker:          worker id (PS = -1 for barrier markers)
    step:            worker-local step index
    version_pulled:  model version the gradient was computed at
    version_applied: model version it was applied to
    staleness:       version_applied - version_pulled (Assumption 5's D(t))
    t_wall:          simulated wall-clock of the apply
    """

    kind: str
    worker: int
    step: int
    version_pulled: int
    version_applied: int
    staleness: int
    t_wall: float


@dataclasses.dataclass(frozen=True)
class Trace:
    protocol: str
    n_workers: int
    events: tuple                  # TraceEvent, sorted by t_wall
    comm: tuple                    # eventsim.Delivery ledger
    messages: tuple                # eventsim.MsgRecord per-wire ledger
    makespan: float
    extras: tuple = ()             # protocol knobs as (name, value) pairs
    faults: Optional[F.FaultLedger] = None   # fault accounting (None:
                                   # scheduled without a FaultPlan)

    def updates(self) -> list:
        return [e for e in self.events if e.kind == "update"]

    @property
    def n_updates(self) -> int:
        return len(self.updates())

    @property
    def max_staleness(self) -> int:
        ups = self.updates()
        return max((e.staleness for e in ups), default=0)

    def updates_of(self, worker: int) -> list:
        return [e for e in self.updates() if e.worker == worker]

    def extra(self, name: str):
        return dict(self.extras)[name]

    def extra_or(self, name: str, default=None):
        return dict(self.extras).get(name, default)


def _sorted_events(events: list) -> tuple:
    return tuple(sorted(events, key=lambda e: (e.t_wall, e.worker, e.step)))


# ---------------------------------------------------------------------------
# Round-synchronous protocols (compute phase + eventsim-costed comm phase)
# ---------------------------------------------------------------------------


def _ring_allreduce_round(spec: ClusterSpec, t0: float,
                          r: int) -> eventsim.SimResult:
    """One bulk-synchronous partitioned ring AllReduce, gated at t0 (the
    slowest worker's compute): 2(n-1) rounds — n-1 reduce-scatter + n-1
    all-gather — each moving ONE size/n partition per worker to its right
    neighbor, the exact wire pattern of ``CSGDRingExchange``. Makespan is
    t0 + 2(n-1)(n_messages*t_lat + chunk*t_tr); the per-wire ledger
    records 2(n-1) sends per worker per iteration."""
    n = spec.n_workers
    chunk = spec.partition_msg_mb()
    msgs = [eventsim.Msg(t0, w, (w + 1) % n, chunk,
                         f"{'reduce' if h < n - 1 else 'gather'}{r}.{h}",
                         spec.n_messages)
            for h in range(2 * (n - 1)) for w in range(n)]
    return eventsim.simulate(msgs, t_lat=spec.t_lat, t_tr=spec.t_tr)


@obs_flight.guarded("scheduler.sync_ps")
def schedule_sync_ps(spec: ClusterSpec, *, rounds: int = 1,
                     plan: Optional[F.FaultPlan] = None,
                     timeout: Optional[float] = None,
                     quorum: Optional[int] = None,
                     aggregator: str = "mean") -> Trace:
    """§1.3.2 synchronous PS: every round is compute -> uplink (serialized
    at the PS recv port) -> broadcast gated on full aggregation.

    With zero compute and one round this is *identical arithmetic* to
    ``eventsim.single_ps_makespan`` (same two simulate() calls), which is
    the scheduler<->eventsim cross-check tests pin to 1e-9.

    ``spec.allreduce == "ring"`` replaces the PS exchange with the
    partitioned ring AllReduce (2(n-1) rounds of size/n chunks, gated on
    the slowest worker — the bulk-synchronous decomposition of
    ``CSGDRingExchange``); with zero compute its makespan equals
    ``eventsim.csgd_ring_makespan`` exactly.

    Graceful degradation (``plan`` / ``timeout`` / ``quorum``): with a
    ``FaultPlan`` the round runs over the live membership (crashed
    workers skip rounds and rejoin through a checkpoint pull; dropped
    uplinks are lost — the broadcast is reliable and retries);
    ``quorum``/``timeout`` turn the barrier into backup-worker
    aggregation — the PS closes each round at the earlier of the
    ``quorum``-th arrival and ``t_round_start + timeout``, discarding
    stragglers (ledgered as timeouts). ``aggregator`` names the robust
    aggregation rule (``cluster.aggregators``) the replay applies at the
    PS — the schedule's timing is rule-independent (every rule reads the
    same contributions), but the choice rides in the trace extras so
    ``execute.replay`` trains under it. Healthy full-barrier arithmetic
    is bit-identical to before when plan/timeout/quorum are None and the
    aggregator is the default mean.
    """
    if (plan is not None or timeout is not None or quorum is not None
            or aggregator != "mean"):
        return _schedule_ps_rounds(spec, rounds=rounds, plan=plan,
                                   timeout=timeout, quorum=quorum,
                                   protocol="sync_ps",
                                   aggregator=aggregator)
    n, ps, s = spec.n_workers, spec.n_workers, spec.msg_mb()
    t = 0.0
    version = 0
    events: list = []
    comm: list = []
    recs: list = []
    for r in range(rounds):
        done = [t + spec.compute_time(w, r) for w in range(n)]
        if obs.enabled("trace"):
            for w in range(n):
                _span_compute(w, r, t, done[w])
        if obs.enabled("metrics"):
            obs.histogram("cluster.straggler_lag_s",
                          protocol="sync_ps").observe(max(done) - min(done))
        if spec.allreduce == "ring":
            res = _ring_allreduce_round(spec, max(done), r)
            comm += list(res.deliveries)
            recs += list(res.messages)
            t = res.makespan if res.deliveries else max(done)
            for w in range(n):
                events.append(TraceEvent("update", w, r, version, version,
                                         0, t))
            version += 1
            events.append(TraceEvent("sync", PS, r, version - 1, version,
                                     0, t))
            continue
        up = eventsim.simulate(
            [eventsim.Msg(done[w], w, ps, s, f"agg{r}", spec.n_messages)
             for w in range(n)], t_lat=spec.t_lat, t_tr=spec.t_tr)
        t_agg = up.makespan
        down = eventsim.simulate(
            [eventsim.Msg(t_agg, ps, w, s, f"bc{r}", spec.n_messages)
             for w in range(n)], t_lat=spec.t_lat, t_tr=spec.t_tr)
        comm += list(up.deliveries) + list(down.deliveries)
        recs += list(up.messages) + list(down.messages)
        for d in up.deliveries:
            events.append(TraceEvent("update", d.src, r, version, version,
                                     0, d.t_end))
        version += 1
        t = down.makespan
        events.append(TraceEvent("sync", PS, r, version - 1, version, 0, t))
    return _observe_trace(Trace(
        "sync_ps", n, _sorted_events(events), tuple(comm), tuple(recs), t,
        (("rounds", rounds), ("allreduce", spec.allreduce))))


@obs_flight.guarded("scheduler.local_sgd")
def schedule_local_sgd(spec: ClusterSpec, *, period_h: int = 8,
                       rounds: int = 1,
                       plan: Optional[F.FaultPlan] = None,
                       timeout: Optional[float] = None,
                       quorum: Optional[int] = None) -> Trace:
    """Local SGD: H local steps per worker between model-averaging rounds
    (the §4 relaxation that trades staleness for H-fold fewer barriers).
    Each local step is an applied update on that worker's replica; the
    averaging round is a PS-pattern exchange of the MODEL —
    or the partitioned ring AllReduce when ``spec.allreduce == "ring"``
    (2(n-1) rounds of size/n chunks, same as schedule_sync_ps).
    ``plan``/``timeout``/``quorum`` follow ``schedule_sync_ps``: live
    workers take their H steps, the averaging round aggregates the first
    K uploads, the broadcast retries, rejoiners pull a checkpoint."""
    if plan is not None or timeout is not None or quorum is not None:
        return _schedule_ps_rounds(spec, rounds=rounds, plan=plan,
                                   timeout=timeout, quorum=quorum,
                                   protocol="local_sgd",
                                   period_h=period_h)
    n, ps, s = spec.n_workers, spec.n_workers, spec.msg_mb()
    t = 0.0
    version = 0
    events: list = []
    comm: list = []
    recs: list = []
    for r in range(rounds):
        done = [t] * n
        for h in range(period_h):
            step = r * period_h + h
            for w in range(n):
                t_h0 = done[w]
                done[w] += spec.compute_time(w, step)
                if obs.enabled("trace"):
                    _span_compute(w, step, t_h0, done[w])
                events.append(TraceEvent("update", w, step, version,
                                         version, 0, done[w]))
        if spec.allreduce == "ring":
            res = _ring_allreduce_round(spec, max(done), r)
            comm += list(res.deliveries)
            recs += list(res.messages)
            t = res.makespan if res.deliveries else max(done)
        else:
            up = eventsim.simulate(
                [eventsim.Msg(done[w], w, ps, s, f"agg{r}",
                              spec.n_messages)
                 for w in range(n)], t_lat=spec.t_lat, t_tr=spec.t_tr)
            down = eventsim.simulate(
                [eventsim.Msg(up.makespan, ps, w, s, f"bc{r}",
                              spec.n_messages)
                 for w in range(n)], t_lat=spec.t_lat, t_tr=spec.t_tr)
            comm += list(up.deliveries) + list(down.deliveries)
            recs += list(up.messages) + list(down.messages)
            t = down.makespan
        version += 1
        events.append(TraceEvent("sync", PS, r, version - 1, version, 0, t))
    return _observe_trace(Trace(
        "local_sgd", n, _sorted_events(events), tuple(comm), tuple(recs),
        t, (("rounds", rounds), ("period_h", period_h),
            ("allreduce", spec.allreduce))))


@obs_flight.guarded("scheduler.decentralized")
def schedule_decentralized(spec: ClusterSpec, *, rounds: int = 1,
                           w: Optional[np.ndarray] = None,
                           codec: Optional[str] = None,
                           protocol: str = "dsgd",
                           plan: Optional[F.FaultPlan] = None) -> Trace:
    """§5.1 DSGD gossip rounds over any mixing matrix W (default: the
    paper's ring W2): each round every worker takes one local step, then
    ships its FULL model to each W-neighbor (deg(W) sends, serialized at
    its send port — O(1) in N for sparse W).

    ``codec`` switches the per-neighbor message from the fp32 model to
    the codec's MEASURED wire bytes — the compressed-delta gossip of
    ``DCDGossipExchange``/``ECDGossipExchange`` (the degree-many sends
    per round are unchanged; only their size shrinks). ``protocol``
    names the replay semantics (``"dcd"``/``"ecd"`` dispatch the
    difference-compressed replays in ``execute.py``).

    Elastic membership (``plan``): every round runs over the live set;
    at each membership epoch the mixing matrix is re-derived —
    ``faults.epoch_matrix`` folds absent workers' mass into the
    survivors' self-weights and re-validates the result through
    ``mixing.birkhoff_decomposition``, so W stays symmetric doubly
    stochastic over whoever is actually present. Plain DSGD tolerates
    message loss (a dropped model just isn't mixed that round — the
    receiver keeps its own weight); DCD/ECD deltas are RELIABLE (a lost
    delta would fork the public replicas, so drops retry with backoff —
    loss becomes latency, not error). Rejoiners pull the model from
    their lowest-id live peer through the compressed-checkpoint wire.
    """
    from repro.core import mixing

    if protocol != "dsgd" and codec is None:
        # a compressed trace must carry the codec its ledger was sized
        # with, or the replay would quantize what the ledger charged fp32
        raise ValueError(f"protocol '{protocol}' needs codec=")
    n = spec.n_workers
    s = (eventsim._msg_mb(spec.size_mb, 1.0, codec) if codec is not None
         else spec.msg_mb())
    w_mat = mixing.ring(n) if w is None else np.asarray(w)
    w_rows = tuple(tuple(row) for row in w_mat.tolist())
    if plan is not None:
        return _schedule_decentralized_faulty(
            spec, rounds=rounds, w_mat=w_mat, w_rows=w_rows, s=s,
            codec=codec, protocol=protocol, plan=plan)
    nbrs = [[j for j in range(n) if j != i and abs(w_mat[j, i]) > 1e-12]
            for i in range(n)]   # i sends to every j weighting x_i
    t = 0.0
    events: list = []
    comm: list = []
    recs: list = []
    for r in range(rounds):
        done = [t + spec.compute_time(i, r) for i in range(n)]
        for i in range(n):
            if obs.enabled("trace"):
                _span_compute(i, r, t, done[i])
            events.append(TraceEvent("update", i, r, r, r, 0, done[i]))
        res = eventsim.simulate(
            [eventsim.Msg(done[i], i, j, s, f"gossip{r}", spec.n_messages)
             for i in range(n) for j in nbrs[i]],
            t_lat=spec.t_lat, t_tr=spec.t_tr)
        comm += list(res.deliveries)
        recs += list(res.messages)
        t = res.makespan
        events.append(TraceEvent("gossip", PS, r, r, r + 1, 0, t))
    # the trace carries W itself (nested tuple) so the replay mixes with
    # exactly the matrix whose comm cost was charged here; compressed
    # protocols also carry the codec their messages were sized with
    return _observe_trace(Trace(
        protocol, n, _sorted_events(events), tuple(comm), tuple(recs), t,
        (("rounds", rounds), ("degree", mixing.degree(w_mat)),
         ("w", w_rows), ("codec", codec))))


def _schedule_decentralized_faulty(spec: ClusterSpec, *, rounds: int,
                                   w_mat: np.ndarray, w_rows: tuple,
                                   s: float, codec: Optional[str],
                                   protocol: str,
                                   plan: F.FaultPlan) -> Trace:
    """Gossip rounds over elastic membership; see schedule_decentralized.

    Extras carry per-round ``present`` (the live mixers — the replay
    re-derives each epoch's W from these with the same
    ``faults.live_mixing_matrix`` that costed it), ``rejoiners`` as
    ``(worker, donor)`` pairs, and — DSGD only — ``dropped_edges``: the
    ``(src, dst)`` gossip messages that were lost, whose weight the
    receiving replay folds back into its self-weight."""
    from repro.core import mixing

    n = spec.n_workers
    reliable = protocol in ("dcd", "ecd")
    led = F._LedgerBuilder()
    t = 0.0
    events: list = []
    comm: list = []
    recs: list = []
    present_rounds: list = []
    rejoin_rounds: list = []
    dropped_rounds: list = []
    has_state = set(plan.alive_at(0.0))
    prev_present: Optional[tuple] = None
    w_live = w_mat
    for r in range(rounds):
        t_start = t
        up_now = [w for w in range(n) if plan.is_up(w, t_start)]
        for w in range(n):
            if w not in up_now:
                has_state.discard(w)
        # -- rejoiners pull a compressed checkpoint from a live peer;
        # a pull whose per-array CRC fails on arrival (plan.
        # bad_checkpoint) is ledgered as a checksum CorruptRecord and
        # re-fetched from the NEXT donor (tag suffix ``.d<i>``) — the
        # last live donor's copy is taken as-is (no one else to ask)
        rejoiners = sorted(w for w in up_now if w not in has_state)
        t_ready = {w: t_start for w in up_now}
        rejoin_pairs = []
        ck_msgs = []
        bad_msgs = []
        bad_status: dict = {}
        ck_tag: dict = {}
        for w in rejoiners:
            donors = sorted(x for x in up_now
                            if x != w and x in has_state)
            if not donors:
                rejoin_pairs.append((w, PS))
                continue
            t_req = t_start
            for di, donor in enumerate(donors):
                tag = (f"ckpt{r}.{w}" if di == 0
                       else f"ckpt{r}.{w}.d{di}")
                if (di < len(donors) - 1
                        and plan.bad_checkpoint(donor, w, r)):
                    bad_msgs.append(eventsim.Msg(
                        t_req, donor, w, spec.msg_mb(), tag,
                        spec.n_messages))
                    bad_status[(donor, w, tag)] = "corrupted"
                    led.corrupt.append(F.CorruptRecord(
                        t_req, donor, w, spec.msg_mb(), tag, di,
                        "checksum"))
                    t_req += spec.msg_cost() + plan.retry_wait(di + 1)
                    continue
                rejoin_pairs.append((w, donor))
                ck_msgs.append(eventsim.Msg(t_req, donor, w,
                                            spec.msg_mb(), tag,
                                            spec.n_messages))
                ck_tag[w] = (donor, tag)
                break
        if ck_msgs or bad_msgs:
            wire, statuses, delivered = F.inject(
                ck_msgs, plan, led, reliable=True,
                est_cost=spec.msg_cost())
            wire += bad_msgs
            statuses.update(bad_status)
            res = eventsim.simulate(wire, t_lat=spec.t_lat,
                                    t_tr=spec.t_tr, statuses=statuses)
            comm += list(res.deliveries)
            recs += list(res.messages)
            ends = {(d.src, d.dst, d.tag): d.t_end
                    for d in res.deliveries}
            for w, (donor, tag) in ck_tag.items():
                t_ready[w] = ends[(donor, w,
                                   delivered[(donor, w, tag)])]
        for (w, donor) in rejoin_pairs:
            led.rejoins.append(F.RejoinRecord(t_ready[w], w, r, donor))
            events.append(TraceEvent("rejoin", w, r, r, r, 0,
                                     t_ready[w]))
            has_state.add(w)
        # -- compute (a crash inside the span kills the round's work)
        participants = []
        done = {}
        for w in up_now:
            d = t_ready[w] + spec.compute_time(w, r)
            if plan.down_in(w, t_ready[w], d):
                led.lost_compute.append((w, t_ready[w]))
                has_state.discard(w)
                continue
            if obs.enabled("trace"):
                _span_compute(w, r, t_ready[w], d)
            participants.append(w)
            done[w] = d
        # -- membership epoch: re-derive + re-validate W over the live set
        if prev_present is None or tuple(participants) != prev_present:
            w_live, n_terms = F.epoch_matrix(w_mat, participants)
            led.epochs.append(F.EpochRecord(t_start, r,
                                            tuple(participants),
                                            n_terms))
        prev_present = tuple(participants)
        for w in participants:
            events.append(TraceEvent("update", w, r, r, r, 0, done[w]))
        # -- gossip over the epoch matrix's support
        gossip = [eventsim.Msg(done[i], i, j, s, f"gossip{r}",
                               spec.n_messages)
                  for i in participants for j in participants
                  if j != i and abs(w_live[j, i]) > 1e-12]
        _, arrival = _simulate_injected(spec, gossip, plan, led,
                                        reliable=reliable, comm=comm,
                                        recs=recs)
        dropped = tuple((m.src, m.dst) for m in gossip
                        if (m.src, m.dst, m.tag) not in arrival)
        t = max([t_start] + [done[w] for w in participants]
                + list(arrival.values()))
        events.append(TraceEvent("gossip", PS, r, r, r + 1, 0, t))
        present_rounds.append(tuple(participants))
        rejoin_rounds.append(tuple(rejoin_pairs))
        dropped_rounds.append(dropped)
    return _observe_trace(Trace(
        protocol, n, _sorted_events(events), tuple(comm), tuple(recs), t,
        (("rounds", rounds), ("degree", mixing.degree(w_mat)),
         ("w", w_rows), ("codec", codec),
         ("present", tuple(present_rounds)),
         ("rejoiners", tuple(rejoin_rounds)),
         ("dropped_edges", tuple(dropped_rounds))),
        led.freeze()))


@obs_flight.guarded("scheduler.laq")
def schedule_laq(spec: ClusterSpec, *, rounds: int = 1, skip: int = 2,
                 plan: Optional[F.FaultPlan] = None,
                 timeout: Optional[float] = None,
                 quorum: Optional[int] = None) -> Trace:
    """LAQ-style lazy aggregation (arXiv 1909.07588), deterministic
    round-robin variant: worker w uploads only on rounds where
    ``(r - w) % skip == 0``; in between the server reuses w's stored
    gradient (the replay does exactly that). The broadcast still reaches
    everyone, so versions advance every round but the uplink carries
    ~n/skip messages instead of n. The gradient-norm trigger of real LAQ
    needs the training loop (execute.py) — the scheduler models its
    communication-thinning effect.

    Under a ``plan``, a dropped upload IS the LAQ relaxation: the server
    simply keeps serving that worker's stored gradient one ``skip``
    cycle longer (no retry on the uplink; the broadcast retries)."""
    if plan is not None or timeout is not None or quorum is not None:
        return _schedule_ps_rounds(spec, rounds=rounds, plan=plan,
                                   timeout=timeout, quorum=quorum,
                                   protocol="laq", laq_skip=skip)
    n, ps, s = spec.n_workers, spec.n_workers, spec.msg_mb()
    t = 0.0
    version = 0
    last_sent = [0] * n
    events: list = []
    comm: list = []
    recs: list = []
    for r in range(rounds):
        senders = [w for w in range(n) if (r - w) % skip == 0]
        done = {w: t + spec.compute_time(w, r) for w in senders}
        if obs.enabled("trace"):
            for w in senders:
                _span_compute(w, r, t, done[w])
        up = eventsim.simulate(
            [eventsim.Msg(done[w], w, ps, s, f"agg{r}", spec.n_messages)
             for w in senders], t_lat=spec.t_lat, t_tr=spec.t_tr)
        t_agg = up.makespan if senders else t
        down = eventsim.simulate(
            [eventsim.Msg(t_agg, ps, w, s, f"bc{r}", spec.n_messages)
             for w in range(n)], t_lat=spec.t_lat, t_tr=spec.t_tr)
        comm += list(up.deliveries) + list(down.deliveries)
        recs += list(up.messages) + list(down.messages)
        for d in up.deliveries:
            w = d.src
            # version_pulled = the version of the gradient the server had
            # been lazily reusing for w; this fresh upload retires it
            # after `staleness` rounds of service
            events.append(TraceEvent("update", w, r, last_sent[w], version,
                                     version - last_sent[w], d.t_end))
            last_sent[w] = version
        version += 1
        t = down.makespan
        events.append(TraceEvent("sync", PS, r, version - 1, version, 0, t))
    return _observe_trace(Trace(
        "laq", n, _sorted_events(events), tuple(comm), tuple(recs), t,
        (("rounds", rounds), ("skip", skip))))


# ---------------------------------------------------------------------------
# Fault-aware PS rounds (sync_ps / local_sgd / laq under a FaultPlan
# and/or quorum+timeout backup-worker aggregation)
# ---------------------------------------------------------------------------


def _simulate_injected(spec: ClusterSpec, msgs: list, plan, led, *,
                       reliable: bool, comm: list,
                       recs: list) -> tuple:
    """Inject the plan into a logical message batch, simulate the wire,
    append to the trace ledgers, and return ``(result, arrival)`` where
    ``arrival[(src, dst, base_tag)]`` is the t_end of the attempt the
    receiver uses (missing: lost on an unreliable channel)."""
    wire, statuses, delivered = F.inject(msgs, plan, led,
                                         reliable=reliable,
                                         est_cost=spec.msg_cost())
    res = eventsim.simulate(wire, t_lat=spec.t_lat, t_tr=spec.t_tr,
                            statuses=statuses)
    comm += list(res.deliveries)
    recs += list(res.messages)
    ends = {(d.src, d.dst, d.tag): d.t_end for d in res.deliveries}
    arrival = {key: ends[(key[0], key[1], tag)]
               for key, tag in delivered.items()}
    return res, arrival


def _schedule_ps_rounds(spec: ClusterSpec, *, rounds: int,
                        plan: Optional[F.FaultPlan],
                        timeout: Optional[float],
                        quorum: Optional[int], protocol: str,
                        period_h: int = 1,
                        laq_skip: Optional[int] = None,
                        aggregator: str = "mean") -> Trace:
    """PS-pattern rounds (sync_ps / local_sgd / laq) under fault
    injection and/or backup-worker aggregation.

    Per round: rejoiners pull the model through the checkpoint wire
    (reliable), live workers compute (``period_h`` steps; a crash window
    inside the compute span kills the round's work), uploads go over the
    UNRELIABLE uplink (drops are lost and corrupted frames are excluded
    — the quorum absorbs both), the PS closes the round per
    ``faults.collect_quorum`` (a round whose every uplink was excluded
    terminates as a ``QuorumShortfall``, never an empty aggregation),
    and the broadcast goes over the RELIABLE downlink (drops AND
    CRC-failed frames retry with backoff — every surviving member must
    hold the new model). Extras carry the per-round ``present`` /
    ``contributors`` / ``receivers`` / ``rejoiners`` lists the replay
    masks on, plus the ``aggregator`` rule and the plan's ``byzantine``
    roster so ``execute.replay`` trains under the same adversary.
    """
    from repro.cluster import aggregators as _agg

    _agg.aggregator(aggregator)     # fail fast on unknown rules
    if spec.allreduce == "ring":
        raise ValueError(
            "fault injection / quorum rounds use PS costing; the bulk-"
            "synchronous ring AllReduce has no straggler-drop semantics "
            "(use allreduce='ps')")
    n, ps, s = spec.n_workers, spec.n_workers, spec.msg_mb()
    led = F._LedgerBuilder()
    t = 0.0
    version = 0
    last_sent = [0] * n                 # laq lazy-gradient bookkeeping
    events: list = []
    comm: list = []
    recs: list = []
    present_rounds: list = []
    contrib_rounds: list = []
    receiver_rounds: list = []
    rejoin_rounds: list = []
    # who holds the current model (receives broadcasts without a pull)
    has_state = (set(plan.alive_at(0.0)) if plan is not None
                 else set(range(n)))
    prev_up: Optional[set] = None
    for r in range(rounds):
        t_start = t
        up_now = ([w for w in range(n) if plan.is_up(w, t_start)]
                  if plan is not None else list(range(n)))
        for w in range(n):
            if w not in up_now:
                has_state.discard(w)    # a down worker's state is gone
        if plan is not None and (prev_up is None or set(up_now) != prev_up):
            led.epochs.append(F.EpochRecord(t_start, r, tuple(up_now)))
        prev_up = set(up_now)
        # -- rejoiners: checkpoint pull from the PS (reliable)
        rejoiners = sorted(w for w in up_now if w not in has_state)
        t_ready = {w: t_start for w in up_now}
        if rejoiners:
            ck = [eventsim.Msg(t_start, ps, w, s, f"ckpt{r}.{w}",
                               spec.n_messages) for w in rejoiners]
            _, arrival = _simulate_injected(spec, ck, plan, led,
                                            reliable=True, comm=comm,
                                            recs=recs)
            for w in rejoiners:
                t_ready[w] = arrival[(ps, w, f"ckpt{r}.{w}")]
                led.rejoins.append(F.RejoinRecord(t_ready[w], w, r, PS))
                events.append(TraceEvent("rejoin", w, r, version,
                                         version, 0, t_ready[w]))
                has_state.add(w)
        # -- compute phase (participation = up through the whole span)
        participants: list = []
        step_times: dict = {}
        for w in up_now:
            d = t_ready[w]
            times = []
            for h in range(period_h):
                d += spec.compute_time(w, r * period_h + h)
                times.append(d)
            if plan is not None and plan.down_in(w, t_ready[w], d):
                led.lost_compute.append((w, t_ready[w]))
                has_state.discard(w)    # crashed mid-compute
                continue
            if obs.enabled("trace"):
                t_h0 = t_ready[w]
                for h, t_h1 in enumerate(times):
                    _span_compute(w, r * period_h + h, t_h0, t_h1)
                    t_h0 = t_h1
            participants.append(w)
            step_times[w] = times
        if protocol == "local_sgd":
            for w in participants:
                for h, t_h in enumerate(step_times[w]):
                    events.append(TraceEvent("update", w,
                                             r * period_h + h, version,
                                             version, 0, t_h))
        # -- uplink (unreliable: the quorum absorbs losses)
        senders = (participants if laq_skip is None else
                   [w for w in participants if (r - w) % laq_skip == 0])
        up_msgs = [eventsim.Msg(step_times[w][-1], w, ps, s, f"agg{r}",
                                spec.n_messages) for w in senders]
        _, arrival = _simulate_injected(spec, up_msgs, plan, led,
                                        reliable=False, comm=comm,
                                        recs=recs)
        arrivals = [(arrival[(w, ps, f"agg{r}")], w) for w in senders
                    if (w, ps, f"agg{r}") in arrival]
        t_agg, contribs = F.collect_quorum(
            arrivals, t_start=t_start, timeout=timeout, quorum=quorum,
            ledger=led, round_idx=r, n_expected=len(senders))
        t_agg = max(t_agg, t_start)
        if obs.enabled("metrics") and arrivals:
            # how long the round would have waited past the quorum cut
            obs.histogram("cluster.straggler_lag_s",
                          protocol=protocol).observe(
                              max(t_end for t_end, _ in arrivals) - t_agg)
        by_worker = dict((w, t_end) for t_end, w in arrivals)
        for w in contribs:
            if protocol == "sync_ps":
                events.append(TraceEvent("update", w, r, version,
                                         version, 0, by_worker[w]))
            elif protocol == "laq":
                events.append(TraceEvent("update", w, r, last_sent[w],
                                         version, version - last_sent[w],
                                         by_worker[w]))
                last_sent[w] = version
        # -- broadcast (reliable: surviving members must converge on the
        #    new version; workers that crashed since round start miss it
        #    and will rejoin through the checkpoint wire)
        receivers = [w for w in up_now if w in has_state
                     and (plan is None or plan.is_up(w, t_agg))]
        for w in list(has_state):
            if w not in receivers:
                has_state.discard(w)
        bc = [eventsim.Msg(t_agg, ps, w, s, f"bc{r}", spec.n_messages)
              for w in receivers]
        down, _ = _simulate_injected(spec, bc, plan, led, reliable=True,
                                     comm=comm, recs=recs)
        t = max(t_agg, down.makespan if receivers else t_agg)
        version += 1
        events.append(TraceEvent("sync", PS, r, version - 1, version, 0,
                                 t))
        present_rounds.append(tuple(participants))
        contrib_rounds.append(tuple(contribs))
        receiver_rounds.append(tuple(receivers))
        rejoin_rounds.append(tuple((w, PS) for w in rejoiners))
    extras = [("rounds", rounds), ("allreduce", spec.allreduce),
              ("timeout", timeout), ("quorum", quorum),
              ("aggregator", aggregator),
              ("byzantine", plan.byzantine if plan is not None else ()),
              ("byzantine_scale",
               plan.byzantine_scale if plan is not None else 1.0),
              ("present", tuple(present_rounds)),
              ("contributors", tuple(contrib_rounds)),
              ("receivers", tuple(receiver_rounds)),
              ("rejoiners", tuple(rejoin_rounds))]
    if protocol == "local_sgd":
        extras.append(("period_h", period_h))
    if protocol == "laq":
        extras.append(("skip", laq_skip))
    return _observe_trace(Trace(protocol, n, _sorted_events(events),
                                tuple(comm), tuple(recs), t,
                                tuple(extras), led.freeze()))


# ---------------------------------------------------------------------------
# Asynchronous PS (the free-running §4.1 loop, generalized from
# eventsim.async_ps_timeline to heterogeneous per-step compute times)
# ---------------------------------------------------------------------------


@obs_flight.guarded("scheduler.async_ps")
def schedule_async_ps(spec: ClusterSpec, *, horizon: float,
                      plan: Optional[F.FaultPlan] = None) -> Trace:
    """§4.1 async PS: each worker loops pull -> compute -> push with no
    barrier; pulls serialize at the PS send port, pushes at its recv port.
    Staleness of an update = applied updates since its worker pulled.

    With homogeneous multipliers and zero jitter this reproduces
    ``eventsim.async_ps_timeline`` event for event (asserted in tests) —
    that closed-form walk-through is the special case this loop
    generalizes. Two differences: updates whose APPLY lands past
    `horizon` are dropped (the timeline helper cuts on request time
    only), and a pull whose DELIVERY would land past `horizon` is never
    put on the wire at all — so ``makespan <= horizon`` always holds,
    every recorded delivery completes inside the horizon, and the wire
    ledger counts exactly the messages the timeline kept (asserted at
    the end of this function).

    Faults (``plan``): both PS channels are reliable-with-retry — a
    dropped pull or push chains bounded retries with exponential
    backoff (``plan.max_retries`` / ``plan.backoff``; the final attempt
    always lands so the loop terminates). A worker that crashes
    mid-compute (or while holding an unacknowledged gradient) loses that
    work and, once back up, re-enters the loop with a fresh pull —
    recorded as a rejoin. Permanent departures simply stop looping."""
    n = spec.n_workers
    msg = spec.msg_cost()
    s = spec.msg_mb()
    ps = n
    ps_send_free = 0.0
    ps_recv_free = 0.0
    version = 0
    versions_at_pull = [0] * n
    steps = [0] * n
    events: list = []
    comm: list = []
    recs: list = []
    led = F._LedgerBuilder()

    def record(t0: float, src: int, dst: int, tag: str,
               status: str = "ok") -> None:
        comm.append(eventsim.Delivery(t0, t0 + msg, src, dst, s, tag,
                                      status))
        recs.extend(eventsim.split_msg_records(t0, src, dst, s, tag,
                                               spec.n_messages,
                                               t_lat=spec.t_lat,
                                               t_tr=spec.t_tr))

    # queue entries: (t, seq, kind, worker, t_begin, attempt) —
    # t_begin is the start of the phase that produced this event, so a
    # crash anywhere inside [t_begin, t] is detected at the pop
    q: list = []
    seq = 0
    for i in range(n):
        t0 = 0.0
        if plan is not None and not plan.is_up(i, 0.0):
            t_up = plan.restart_after(i, 0.0)
            if t_up is None or t_up > horizon:
                continue              # never participates
            t0 = t_up
            led.rejoins.append(F.RejoinRecord(t0, i, 0, ps))
            events.append(TraceEvent("rejoin", i, 0, 0, 0, 0, t0))
        q.append((t0, seq, "pull", i, t0, 0))
        seq += 1
    heapq.heapify(q)

    def reschedule_after_crash(w: int, t: float) -> None:
        """Worker w is down (or lost work) at t: re-enter with a fresh
        pull at its next up-time, if any inside the horizon."""
        nonlocal seq
        t_up = plan.restart_after(w, t)
        if t_up is None or t_up > horizon:
            return                    # permanent departure (or too late)
        led.rejoins.append(F.RejoinRecord(t_up, w, steps[w], ps))
        events.append(TraceEvent("rejoin", w, steps[w], version, version,
                                 0, t_up))
        heapq.heappush(q, (t_up, seq, "pull", w, t_up, 0))
        seq += 1

    while q:
        t, _, kind, w, t_begin, attempt = heapq.heappop(q)
        if t > horizon:
            continue
        if plan is not None:
            if kind == "pull" and not plan.is_up(w, t):
                reschedule_after_crash(w, t)
                seq += 1
                continue
            if kind == "push" and (not plan.is_up(w, t)
                                   or plan.down_in(w, t_begin, t)):
                # the gradient computed (or buffered for retry) since
                # t_begin died with the worker
                led.lost_compute.append((w, t_begin))
                reschedule_after_crash(w, t)
                seq += 1
                continue
        if kind == "pull":
            t0 = max(t, ps_send_free)
            if t0 + msg > horizon:    # would never be delivered: the
                continue              # timeline AND the ledger drop it
            base = f"pull{w}.{steps[w]}"
            tag = base if attempt == 0 else f"{base}~a{attempt}"
            ps_send_free = t0 + msg
            lost = (plan is not None and attempt < plan.max_retries
                    and plan.drops_msg(ps, w, base, attempt))
            bad = None
            if plan is not None and not lost and attempt < plan.max_retries:
                if plan.corrupts_msg(ps, w, base, attempt):
                    bad = "bitflip"
                elif plan.poisons_msg(ps, w, base, attempt):
                    bad = "nan"
            record(t0, ps, w, tag,
                   "lost" if lost else ("corrupted" if bad else "ok"))
            if lost:
                led.drops.append(F.DropRecord(t0, ps, w, s, base,
                                              attempt))
                led.retries.append(F.RetryRecord(t0, ps, w, base,
                                                 attempt + 1))
                t_retry = t0 + msg + plan.retry_wait(attempt + 1)
                heapq.heappush(q, (t_retry, seq, "pull", w, t,
                                   attempt + 1))
                seq += 1
                continue
            if bad is not None:
                # arrived in full, failed the worker's integrity check:
                # the reliable pull channel re-requests it
                led.corrupt.append(F.CorruptRecord(t0, ps, w, s, base,
                                                   attempt, bad))
                led.retries.append(F.RetryRecord(t0, ps, w, base,
                                                 attempt + 1))
                t_retry = t0 + msg + plan.retry_wait(attempt + 1)
                heapq.heappush(q, (t_retry, seq, "pull", w, t,
                                   attempt + 1))
                seq += 1
                continue
            if (plan is not None and plan.dups_msg(ps, w, base, attempt)
                    and t0 + 2 * msg <= horizon):
                record(t0 + msg, ps, w, tag + "~dup", "dup")
                ps_send_free = t0 + 2 * msg
                led.duplicates.append(F.DupRecord(t0 + msg, ps, w, base))
            versions_at_pull[w] = version
            t_next = t0 + msg + spec.compute_time(w, steps[w])
            if obs.enabled("trace"):
                _span_compute(w, steps[w], t0 + msg, t_next)
            heapq.heappush(q, (t_next, seq, "push", w, t0 + msg, 0))
        else:
            t0 = max(t, ps_recv_free)
            t_applied = t0 + msg
            if t_applied > horizon:   # would land after the cutoff
                continue
            base = f"push{w}.{steps[w]}"
            tag = base if attempt == 0 else f"{base}~a{attempt}"
            ps_recv_free = t_applied
            lost = (plan is not None and attempt < plan.max_retries
                    and plan.drops_msg(w, ps, base, attempt))
            bad = None
            if plan is not None and not lost and attempt < plan.max_retries:
                if plan.corrupts_msg(w, ps, base, attempt):
                    bad = "bitflip"
                elif plan.poisons_msg(w, ps, base, attempt):
                    bad = "nan"
            record(t0, w, ps, tag,
                   "lost" if lost else ("corrupted" if bad else "ok"))
            if lost:
                led.drops.append(F.DropRecord(t0, w, ps, s, base,
                                              attempt))
                led.retries.append(F.RetryRecord(t0, w, ps, base,
                                                 attempt + 1))
                t_retry = t_applied + plan.retry_wait(attempt + 1)
                # t_begin survives: a crash while the gradient waits to
                # be retransmitted still loses it
                heapq.heappush(q, (t_retry, seq, "push", w, t_begin,
                                   attempt + 1))
                seq += 1
                continue
            if bad is not None:
                # the PS read the bytes, failed the CRC/finite check,
                # and NACKed: the worker retransmits the same gradient
                led.corrupt.append(F.CorruptRecord(t0, w, ps, s, base,
                                                   attempt, bad))
                led.retries.append(F.RetryRecord(t0, w, ps, base,
                                                 attempt + 1))
                t_retry = t_applied + plan.retry_wait(attempt + 1)
                heapq.heappush(q, (t_retry, seq, "push", w, t_begin,
                                   attempt + 1))
                seq += 1
                continue
            if (plan is not None and plan.dups_msg(w, ps, base, attempt)
                    and t_applied + msg <= horizon):
                record(t_applied, w, ps, tag + "~dup", "dup")
                ps_recv_free = t_applied + msg
                led.duplicates.append(F.DupRecord(t_applied, w, ps,
                                                  base))
            events.append(TraceEvent(
                "update", w, steps[w], versions_at_pull[w], version,
                version - versions_at_pull[w], t_applied))
            version += 1
            steps[w] += 1
            heapq.heappush(q, (t_applied, seq, "pull", w, t_applied, 0))
        seq += 1
    # -- ledger/timeline reconciliation (the horizon-cut invariant):
    # every recorded wire message completes inside the horizon, applied
    # updates == delivered pushes, and the per-switch record count
    # matches the deliveries exactly
    assert all(d.t_end <= horizon + 1e-9 for d in comm)
    n_updates = sum(1 for e in events if e.kind == "update")
    n_ok_push = sum(1 for d in comm
                    if d.dst == ps and d.status == "ok")
    assert n_ok_push == n_updates, (n_ok_push, n_updates)
    assert len(recs) == len(comm) * spec.n_messages
    makespan = max((e.t_wall for e in events), default=0.0)
    return _observe_trace(Trace(
        "async_ps", n, _sorted_events(events), tuple(comm), tuple(recs),
        makespan, (("horizon", horizon),),
        led.freeze() if plan is not None else None))
