"""Discrete-event scheduler for a virtual cluster of heterogeneous workers.

This generalizes ``eventsim.async_ps_timeline`` (a closed-form heapq
walk-through of Figure 4.2) into a protocol-pluggable event loop over the
same §1.3 switch model: N workers with per-(worker, step) compute times —
deterministic straggler multipliers x seeded lognormal jitter — exchange
messages whose port occupancy is costed by ``eventsim.simulate`` (round
protocols) or the PS send/recv ports directly (the async loop). Every
protocol emits a ``Trace``:

  * ``events`` — one ``TraceEvent`` per applied gradient
    ``(worker, step, version_pulled, version_applied, staleness, t_wall)``,
    sorted by apply time. ``staleness = version_applied - version_pulled``
    is the paper's D(t) (Assumption 5); sync protocols keep it 0.
  * ``comm`` / ``messages`` — the ``eventsim.Delivery`` and per-wire
    ``eventsim.MsgRecord`` ledgers of every transfer, so scheduler and
    eventsim timings cross-check: the sync-PS makespan with zero compute
    IS ``eventsim.single_ps_makespan`` (same simulate() calls, asserted
    in tests/test_cluster.py to 1e-9).

The trace is pure timing/ordering — no gradients exist here. Feeding it to
``repro.cluster.execute.replay`` turns it into REAL training (vmapped
per-worker replicas, fused flat-codec gradient path) with loss plotted
against this file's simulated wall-clock.

Protocols (see ``repro.cluster.protocols`` for the registry objects):

  sync_ps        rounds of compute -> uplink -> gated broadcast (§1.3.2)
  async_ps       free-running pull/compute/push per worker (§4.1)
  local_sgd      H local steps between averaging rounds (§4/LocalSGD)
  decentralized  gossip rounds over ANY mixing.py matrix W (§5.1); with a
                 codec, the deg(W) per-round sends are sized at the
                 codec's measured wire bytes — the DCD/ECD compressed-
                 delta gossip tier (protocols "dcd"/"ecd")
  laq            sync PS where each worker uploads every `skip`-th round
                 (round-robin lazy aggregation a la LAQ, arXiv 1909.07588;
                 the server reuses the stored gradient in between)
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Optional, Sequence

import numpy as np

from repro.core import eventsim

PS = -1   # symbolic parameter-server id in TraceEvents (msgs use index n)


# ---------------------------------------------------------------------------
# Cluster description: who computes how fast, what a message costs
# ---------------------------------------------------------------------------


def straggler_multipliers(n: int, *, straggler: Optional[int] = None,
                          factor: float = 4.0) -> tuple:
    """Per-worker speed multipliers: all 1.0 with worker `straggler`
    (default: the last one) `factor`x slower — the Figure 4.1/4.2 setup."""
    m = [1.0] * n
    m[straggler if straggler is not None else n - 1] = factor
    return tuple(m)


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """N heterogeneous workers hanging off one §1.3 switch.

    ``multipliers`` is the deterministic straggler model (per-worker slow-
    down of the base ``t_compute``); ``jitter`` adds seeded lognormal
    noise per (worker, step) — ``exp(N(0, jitter))``, median 1 — so
    stragglers can be persistent, stochastic, or both. ``size_mb`` is the
    fp32 gradient/model message; pass ``codec`` to replace it with the
    measured wire size of the packed payload (``Codec.wire_bytes``),
    exactly like the eventsim builders.
    """

    n_workers: int = 8
    t_compute: float = 1.0
    multipliers: tuple = ()        # () -> homogeneous
    jitter: float = 0.0            # lognormal sigma
    t_lat: float = 1e-2
    t_tr: float = 2e-3             # s/MB at the NIC
    size_mb: float = 1.0
    codec: Optional[str] = None    # measured wire size instead of size_mb
    n_messages: int = 1            # wire messages per logical transfer
    allreduce: str = "ps"          # "ps" | "ring" — how sync/local-SGD
                                   # averaging rounds are costed: PS
                                   # uplink+broadcast, or the partitioned
                                   # ring (2(N-1) rounds of size/N chunks,
                                   # matching CSGDRingExchange)
    seed: int = 0

    def __post_init__(self):
        if self.allreduce not in ("ps", "ring"):
            raise ValueError(f"unknown allreduce '{self.allreduce}'; "
                             "have 'ps', 'ring'")

    def multiplier(self, worker: int) -> float:
        if not self.multipliers:
            return 1.0
        return float(self.multipliers[worker])

    def compute_time(self, worker: int, step: int) -> float:
        """Duration of one local gradient computation. Deterministic in
        (seed, worker, step) regardless of event-loop visit order."""
        base = self.t_compute * self.multiplier(worker)
        if self.jitter > 0.0:
            rng = np.random.default_rng((self.seed, worker, step))
            base *= float(rng.lognormal(0.0, self.jitter))
        return base

    def msg_mb(self) -> float:
        """Wire MB of one gradient/model message (codec-measured if set).

        Delegates to eventsim's chunk sizing so scheduler and eventsim
        makespans stay bit-identical (the 1e-9 cross-check)."""
        return eventsim._msg_mb(self.size_mb, 1.0, self.codec)

    def partition_msg_mb(self) -> float:
        """Wire MB of ONE ring partition message (1/n_workers of the
        buffer, codec-measured if set) — the chunk each of the 2(N-1)
        partitioned-AllReduce rounds moves per worker. Same sizing as
        ``eventsim.csgd_ring_makespan``'s, by construction."""
        return eventsim._msg_mb(self.size_mb, 1.0, self.codec,
                                n_chunks=self.n_workers)

    def msg_cost(self) -> float:
        """Port occupancy of one logical transfer."""
        return self.n_messages * self.t_lat + self.msg_mb() * self.t_tr


# ---------------------------------------------------------------------------
# Trace schema
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One applied gradient (kind='update') or a barrier marker.

    kind:            'update' | 'sync' (averaging barrier) | 'gossip'
    worker:          worker id (PS = -1 for barrier markers)
    step:            worker-local step index
    version_pulled:  model version the gradient was computed at
    version_applied: model version it was applied to
    staleness:       version_applied - version_pulled (Assumption 5's D(t))
    t_wall:          simulated wall-clock of the apply
    """

    kind: str
    worker: int
    step: int
    version_pulled: int
    version_applied: int
    staleness: int
    t_wall: float


@dataclasses.dataclass(frozen=True)
class Trace:
    protocol: str
    n_workers: int
    events: tuple                  # TraceEvent, sorted by t_wall
    comm: tuple                    # eventsim.Delivery ledger
    messages: tuple                # eventsim.MsgRecord per-wire ledger
    makespan: float
    extras: tuple = ()             # protocol knobs as (name, value) pairs

    def updates(self) -> list:
        return [e for e in self.events if e.kind == "update"]

    @property
    def n_updates(self) -> int:
        return len(self.updates())

    @property
    def max_staleness(self) -> int:
        ups = self.updates()
        return max((e.staleness for e in ups), default=0)

    def updates_of(self, worker: int) -> list:
        return [e for e in self.updates() if e.worker == worker]

    def extra(self, name: str):
        return dict(self.extras)[name]


def _sorted_events(events: list) -> tuple:
    return tuple(sorted(events, key=lambda e: (e.t_wall, e.worker, e.step)))


# ---------------------------------------------------------------------------
# Round-synchronous protocols (compute phase + eventsim-costed comm phase)
# ---------------------------------------------------------------------------


def _ring_allreduce_round(spec: ClusterSpec, t0: float,
                          r: int) -> eventsim.SimResult:
    """One bulk-synchronous partitioned ring AllReduce, gated at t0 (the
    slowest worker's compute): 2(n-1) rounds — n-1 reduce-scatter + n-1
    all-gather — each moving ONE size/n partition per worker to its right
    neighbor, the exact wire pattern of ``CSGDRingExchange``. Makespan is
    t0 + 2(n-1)(n_messages*t_lat + chunk*t_tr); the per-wire ledger
    records 2(n-1) sends per worker per iteration."""
    n = spec.n_workers
    chunk = spec.partition_msg_mb()
    msgs = [eventsim.Msg(t0, w, (w + 1) % n, chunk,
                         f"{'reduce' if h < n - 1 else 'gather'}{r}.{h}",
                         spec.n_messages)
            for h in range(2 * (n - 1)) for w in range(n)]
    return eventsim.simulate(msgs, t_lat=spec.t_lat, t_tr=spec.t_tr)


def schedule_sync_ps(spec: ClusterSpec, *, rounds: int = 1) -> Trace:
    """§1.3.2 synchronous PS: every round is compute -> uplink (serialized
    at the PS recv port) -> broadcast gated on full aggregation.

    With zero compute and one round this is *identical arithmetic* to
    ``eventsim.single_ps_makespan`` (same two simulate() calls), which is
    the scheduler<->eventsim cross-check tests pin to 1e-9.

    ``spec.allreduce == "ring"`` replaces the PS exchange with the
    partitioned ring AllReduce (2(n-1) rounds of size/n chunks, gated on
    the slowest worker — the bulk-synchronous decomposition of
    ``CSGDRingExchange``); with zero compute its makespan equals
    ``eventsim.csgd_ring_makespan`` exactly.
    """
    n, ps, s = spec.n_workers, spec.n_workers, spec.msg_mb()
    t = 0.0
    version = 0
    events: list = []
    comm: list = []
    recs: list = []
    for r in range(rounds):
        done = [t + spec.compute_time(w, r) for w in range(n)]
        if spec.allreduce == "ring":
            res = _ring_allreduce_round(spec, max(done), r)
            comm += list(res.deliveries)
            recs += list(res.messages)
            t = res.makespan if res.deliveries else max(done)
            for w in range(n):
                events.append(TraceEvent("update", w, r, version, version,
                                         0, t))
            version += 1
            events.append(TraceEvent("sync", PS, r, version - 1, version,
                                     0, t))
            continue
        up = eventsim.simulate(
            [eventsim.Msg(done[w], w, ps, s, f"agg{r}", spec.n_messages)
             for w in range(n)], t_lat=spec.t_lat, t_tr=spec.t_tr)
        t_agg = up.makespan
        down = eventsim.simulate(
            [eventsim.Msg(t_agg, ps, w, s, f"bc{r}", spec.n_messages)
             for w in range(n)], t_lat=spec.t_lat, t_tr=spec.t_tr)
        comm += list(up.deliveries) + list(down.deliveries)
        recs += list(up.messages) + list(down.messages)
        for d in up.deliveries:
            events.append(TraceEvent("update", d.src, r, version, version,
                                     0, d.t_end))
        version += 1
        t = down.makespan
        events.append(TraceEvent("sync", PS, r, version - 1, version, 0, t))
    return Trace("sync_ps", n, _sorted_events(events), tuple(comm),
                 tuple(recs), t,
                 (("rounds", rounds), ("allreduce", spec.allreduce)))


def schedule_local_sgd(spec: ClusterSpec, *, period_h: int = 8,
                       rounds: int = 1) -> Trace:
    """Local SGD: H local steps per worker between model-averaging rounds
    (the §4 relaxation that trades staleness for H-fold fewer barriers).
    Each local step is an applied update on that worker's replica; the
    averaging round is a PS-pattern exchange of the MODEL —
    or the partitioned ring AllReduce when ``spec.allreduce == "ring"``
    (2(n-1) rounds of size/n chunks, same as schedule_sync_ps)."""
    n, ps, s = spec.n_workers, spec.n_workers, spec.msg_mb()
    t = 0.0
    version = 0
    events: list = []
    comm: list = []
    recs: list = []
    for r in range(rounds):
        done = [t] * n
        for h in range(period_h):
            step = r * period_h + h
            for w in range(n):
                done[w] += spec.compute_time(w, step)
                events.append(TraceEvent("update", w, step, version,
                                         version, 0, done[w]))
        if spec.allreduce == "ring":
            res = _ring_allreduce_round(spec, max(done), r)
            comm += list(res.deliveries)
            recs += list(res.messages)
            t = res.makespan if res.deliveries else max(done)
        else:
            up = eventsim.simulate(
                [eventsim.Msg(done[w], w, ps, s, f"agg{r}",
                              spec.n_messages)
                 for w in range(n)], t_lat=spec.t_lat, t_tr=spec.t_tr)
            down = eventsim.simulate(
                [eventsim.Msg(up.makespan, ps, w, s, f"bc{r}",
                              spec.n_messages)
                 for w in range(n)], t_lat=spec.t_lat, t_tr=spec.t_tr)
            comm += list(up.deliveries) + list(down.deliveries)
            recs += list(up.messages) + list(down.messages)
            t = down.makespan
        version += 1
        events.append(TraceEvent("sync", PS, r, version - 1, version, 0, t))
    return Trace("local_sgd", n, _sorted_events(events), tuple(comm),
                 tuple(recs), t,
                 (("rounds", rounds), ("period_h", period_h),
                  ("allreduce", spec.allreduce)))


def schedule_decentralized(spec: ClusterSpec, *, rounds: int = 1,
                           w: Optional[np.ndarray] = None,
                           codec: Optional[str] = None,
                           protocol: str = "dsgd") -> Trace:
    """§5.1 DSGD gossip rounds over any mixing matrix W (default: the
    paper's ring W2): each round every worker takes one local step, then
    ships its FULL model to each W-neighbor (deg(W) sends, serialized at
    its send port — O(1) in N for sparse W).

    ``codec`` switches the per-neighbor message from the fp32 model to
    the codec's MEASURED wire bytes — the compressed-delta gossip of
    ``DCDGossipExchange``/``ECDGossipExchange`` (the degree-many sends
    per round are unchanged; only their size shrinks). ``protocol``
    names the replay semantics (``"dcd"``/``"ecd"`` dispatch the
    difference-compressed replays in ``execute.py``)."""
    from repro.core import mixing

    if protocol != "dsgd" and codec is None:
        # a compressed trace must carry the codec its ledger was sized
        # with, or the replay would quantize what the ledger charged fp32
        raise ValueError(f"protocol '{protocol}' needs codec=")
    n = spec.n_workers
    s = (eventsim._msg_mb(spec.size_mb, 1.0, codec) if codec is not None
         else spec.msg_mb())
    w_mat = mixing.ring(n) if w is None else np.asarray(w)
    nbrs = [[j for j in range(n) if j != i and abs(w_mat[j, i]) > 1e-12]
            for i in range(n)]   # i sends to every j weighting x_i
    t = 0.0
    events: list = []
    comm: list = []
    recs: list = []
    for r in range(rounds):
        done = [t + spec.compute_time(i, r) for i in range(n)]
        for i in range(n):
            events.append(TraceEvent("update", i, r, r, r, 0, done[i]))
        res = eventsim.simulate(
            [eventsim.Msg(done[i], i, j, s, f"gossip{r}", spec.n_messages)
             for i in range(n) for j in nbrs[i]],
            t_lat=spec.t_lat, t_tr=spec.t_tr)
        comm += list(res.deliveries)
        recs += list(res.messages)
        t = res.makespan
        events.append(TraceEvent("gossip", PS, r, r, r + 1, 0, t))
    # the trace carries W itself (nested tuple) so the replay mixes with
    # exactly the matrix whose comm cost was charged here; compressed
    # protocols also carry the codec their messages were sized with
    w_rows = tuple(tuple(row) for row in w_mat.tolist())
    return Trace(protocol, n, _sorted_events(events), tuple(comm),
                 tuple(recs), t,
                 (("rounds", rounds), ("degree", mixing.degree(w_mat)),
                  ("w", w_rows), ("codec", codec)))


def schedule_laq(spec: ClusterSpec, *, rounds: int = 1,
                 skip: int = 2) -> Trace:
    """LAQ-style lazy aggregation (arXiv 1909.07588), deterministic
    round-robin variant: worker w uploads only on rounds where
    ``(r - w) % skip == 0``; in between the server reuses w's stored
    gradient (the replay does exactly that). The broadcast still reaches
    everyone, so versions advance every round but the uplink carries
    ~n/skip messages instead of n. The gradient-norm trigger of real LAQ
    needs the training loop (execute.py) — the scheduler models its
    communication-thinning effect."""
    n, ps, s = spec.n_workers, spec.n_workers, spec.msg_mb()
    t = 0.0
    version = 0
    last_sent = [0] * n
    events: list = []
    comm: list = []
    recs: list = []
    for r in range(rounds):
        senders = [w for w in range(n) if (r - w) % skip == 0]
        done = {w: t + spec.compute_time(w, r) for w in senders}
        up = eventsim.simulate(
            [eventsim.Msg(done[w], w, ps, s, f"agg{r}", spec.n_messages)
             for w in senders], t_lat=spec.t_lat, t_tr=spec.t_tr)
        t_agg = up.makespan if senders else t
        down = eventsim.simulate(
            [eventsim.Msg(t_agg, ps, w, s, f"bc{r}", spec.n_messages)
             for w in range(n)], t_lat=spec.t_lat, t_tr=spec.t_tr)
        comm += list(up.deliveries) + list(down.deliveries)
        recs += list(up.messages) + list(down.messages)
        for d in up.deliveries:
            w = d.src
            # version_pulled = the version of the gradient the server had
            # been lazily reusing for w; this fresh upload retires it
            # after `staleness` rounds of service
            events.append(TraceEvent("update", w, r, last_sent[w], version,
                                     version - last_sent[w], d.t_end))
            last_sent[w] = version
        version += 1
        t = down.makespan
        events.append(TraceEvent("sync", PS, r, version - 1, version, 0, t))
    return Trace("laq", n, _sorted_events(events), tuple(comm),
                 tuple(recs), t, (("rounds", rounds), ("skip", skip)))


# ---------------------------------------------------------------------------
# Asynchronous PS (the free-running §4.1 loop, generalized from
# eventsim.async_ps_timeline to heterogeneous per-step compute times)
# ---------------------------------------------------------------------------


def schedule_async_ps(spec: ClusterSpec, *, horizon: float) -> Trace:
    """§4.1 async PS: each worker loops pull -> compute -> push with no
    barrier; pulls serialize at the PS send port, pushes at its recv port.
    Staleness of an update = applied updates since its worker pulled.

    With homogeneous multipliers and zero jitter this reproduces
    ``eventsim.async_ps_timeline`` event for event (asserted in tests) —
    that closed-form walk-through is the special case this loop
    generalizes. One difference: updates whose APPLY lands past `horizon`
    are dropped (the timeline helper cuts on request time only), so
    ``makespan <= horizon`` always holds and equal-wall-clock comparisons
    against a sync trace are not biased by a message draining after the
    cutoff."""
    n = spec.n_workers
    msg = spec.msg_cost()
    s = spec.msg_mb()
    ps = n
    ps_send_free = 0.0
    ps_recv_free = 0.0
    version = 0
    versions_at_pull = [0] * n
    steps = [0] * n
    events: list = []
    comm: list = []
    recs: list = []

    def record(t0: float, src: int, dst: int, tag: str) -> None:
        comm.append(eventsim.Delivery(t0, t0 + msg, src, dst, s, tag))
        recs.extend(eventsim.split_msg_records(t0, src, dst, s, tag,
                                               spec.n_messages,
                                               t_lat=spec.t_lat,
                                               t_tr=spec.t_tr))

    q: list = [(0.0, i, "pull", i) for i in range(n)]
    heapq.heapify(q)
    seq = n
    while q:
        t, _, kind, w = heapq.heappop(q)
        if t > horizon:
            continue
        if kind == "pull":
            t0 = max(t, ps_send_free)
            ps_send_free = t0 + msg
            record(t0, ps, w, f"pull{w}.{steps[w]}")
            versions_at_pull[w] = version
            t_next = t0 + msg + spec.compute_time(w, steps[w])
            heapq.heappush(q, (t_next, seq, "push", w))
        else:
            t0 = max(t, ps_recv_free)
            t_applied = t0 + msg
            if t_applied > horizon:   # would land after the cutoff
                continue
            ps_recv_free = t_applied
            record(t0, w, ps, f"push{w}.{steps[w]}")
            events.append(TraceEvent(
                "update", w, steps[w], versions_at_pull[w], version,
                version - versions_at_pull[w], t_applied))
            version += 1
            steps[w] += 1
            heapq.heappush(q, (t_applied, seq, "pull", w))
        seq += 1
    makespan = max((e.t_wall for e in events), default=0.0)
    return Trace("async_ps", n, _sorted_events(events), tuple(comm),
                 tuple(recs), makespan, (("horizon", horizon),))
