"""Byzantine-robust aggregation rules for the sync-PS quorum step.

The PS's mean is a single point of statistical failure: one worker
shipping ``-g`` (or ``8g``, or noise) moves the aggregate by design —
compression and the wire CRC cannot help, because an adversarial payload
frames perfectly. The classical defense is to replace the mean with a
rule whose breakdown point tolerates ``f`` bad rows out of ``n``:

  mean               the baseline (breakdown 0) — bit-identical to the
                     masked average the quorum replay always used.
  norm_clip          rows are scaled down to the masked median gradient
                     norm before averaging: defeats large-norm attacks
                     (``scale`` mode), not directional ones.
  trimmed_mean       per coordinate, drop the f smallest and f largest
                     contributions and average the rest (f = n // 4,
                     at least 1): tolerates f arbitrary rows.
  coordinate_median  per coordinate, the masked median: breakdown 1/2,
                     the most conservative rule here.

Every rule is mask-aware — ``mask`` is the (n,) 0/1 float row mask of
quorum contributors, so excluded uplinks (lost, corrupted, timed out)
never touch the statistic — and every rule is pure jnp on the stacked
worker axis, usable inside the jitted replay round step. An empty mask
yields a zero update (the round carries the previous params), matching
the scheduler's ``QuorumShortfall`` semantics.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.registry import Registry

# sorts masked-out rows past every real fp32 gradient without the NaN
# semantics of +inf arithmetic
_BIG = 3.0e38


def _bcast(mask: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    return mask.reshape((mask.shape[0],) + (1,) * (q.ndim - 1))


def _count_scale(mask: jnp.ndarray) -> tuple:
    count = mask.sum()
    scale = jnp.where(count > 0, 1.0 / jnp.maximum(count, 1.0), 0.0)
    return count, scale


def mean(q_w, mask: jnp.ndarray):
    """Masked average — exactly the quorum replay's original arithmetic
    (the default rule must stay bit-identical to the pre-registry
    path)."""
    count, scale = _count_scale(mask)
    del count
    return jax.tree_util.tree_map(
        lambda q: (q * _bcast(mask, q)).sum(0) * scale, q_w)


def _masked_sort(q: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Ascending per-coordinate sort with masked-out rows pushed past
    the top (the first ``count`` rows are the real values)."""
    return jnp.sort(jnp.where(_bcast(mask, q) > 0, q, _BIG), axis=0)


def _take_row(s: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Row ``idx`` (a traced scalar) of the sorted (n, ...) stack."""
    i = jnp.broadcast_to(idx.astype(jnp.int32).reshape((1,) * s.ndim),
                         (1,) + s.shape[1:])
    return jnp.take_along_axis(s, i, axis=0)[0]


def trimmed_mean(q_w, mask: jnp.ndarray):
    """Per coordinate, drop the ``f`` smallest and ``f`` largest masked
    contributions (f = n//4, at least 1) and average the middle; falls
    back to the masked mean when fewer than ``2f + 1`` rows survive."""
    n = mask.shape[0]
    f = max(1, n // 4)
    count, _ = _count_scale(mask)

    def leaf(q):
        s = _masked_sort(q, mask)
        idx = jnp.arange(n).reshape((n,) + (1,) * (q.ndim - 1))
        keep = (idx >= f) & (idx < count - f)
        kept = jnp.where(keep, s, 0.0).sum(0)
        robust = kept / jnp.maximum(count - 2 * f, 1.0)
        _, scale = _count_scale(mask)
        fallback = (q * _bcast(mask, q)).sum(0) * scale
        return jnp.where(count > 2 * f, robust, fallback)

    return jax.tree_util.tree_map(leaf, q_w)


def coordinate_median(q_w, mask: jnp.ndarray):
    """Per-coordinate masked median (even counts average the two middle
    values) — breakdown point 1/2; an empty mask yields zero."""
    count, _ = _count_scale(mask)
    cnt = count.astype(jnp.int32)

    def leaf(q):
        s = _masked_sort(q, mask)
        n = mask.shape[0]
        lo = jnp.clip((cnt - 1) // 2, 0, n - 1)
        hi = jnp.clip(cnt // 2, 0, n - 1)
        med = 0.5 * (_take_row(s, lo) + _take_row(s, hi))
        return jnp.where(count > 0, med, 0.0)

    return jax.tree_util.tree_map(leaf, q_w)


def norm_clip(q_w, mask: jnp.ndarray):
    """Clip each contribution's GLOBAL (whole-tree) norm to the masked
    median norm, then take the masked mean — the large-norm-attack
    defense; directional attacks at honest norms pass through."""
    n = mask.shape[0]
    leaves = jax.tree_util.tree_leaves(q_w)
    sq = sum(jnp.square(q).reshape(n, -1).sum(axis=1) for q in leaves)
    norms = jnp.sqrt(sq)                                        # (n,)
    s = jnp.sort(jnp.where(mask > 0, norms, _BIG))
    count, scale = _count_scale(mask)
    cnt = count.astype(jnp.int32)
    lo = jnp.clip((cnt - 1) // 2, 0, n - 1)
    hi = jnp.clip(cnt // 2, 0, n - 1)
    med = 0.5 * (s[lo] + s[hi])
    clip = jnp.where(norms > med, med / jnp.maximum(norms, 1e-30), 1.0)
    return jax.tree_util.tree_map(
        lambda q: (q * _bcast(clip * mask, q)).sum(0) * scale, q_w)


AGGREGATORS: Registry = Registry("aggregator", {
    "mean": mean,
    "norm_clip": norm_clip,
    "trimmed_mean": trimmed_mean,
    "coordinate_median": coordinate_median,
})


def aggregator(name: str) -> Callable:
    return AGGREGATORS.get(name)
