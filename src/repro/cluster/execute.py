"""Replay a scheduler ``Trace`` against REAL training.

The scheduler decides *when* and *at what staleness* every gradient lands;
this module makes those gradients real: vmapped per-worker replicas
compute minibatch gradients (the §1.1.3 quadratic or the repro-100m LM
through ``train.steps.make_loss_fn``), every gradient ships through the
fused flat-buffer Codec path (``Codec.tree_qdq_flat`` — ONE bucketed
message per transfer, same bits as decode(encode(.))), and updates are
applied in trace order at the trace's recorded staleness. The result is a
loss-vs-simulated-wall-clock curve — the Figure 4.3-style "loss vs time"
artifact the closed-form timelines could not produce.

Replay semantics per protocol (dispatch on ``Trace.protocol``):

  sync_ps   one model; per round all N workers' codec'd gradients are
            averaged into one update (vmapped over the worker axis).
  async_ps  one model + a version history ring; update k uses the
            gradient computed at ``params[version_pulled]`` and applies
            it to ``params[version_applied]`` — measured staleness, not
            a worst-case FIFO.
  local_sgd per-worker replicas take H codec'd local steps (vmapped),
            then average at each sync event.
  dsgd      per-worker replicas take one local step per round, then mix
            X <- X W with the SAME matrix the scheduler costed.
  dcd/ecd   difference-compressed DSGD: per-worker PUBLIC copies x̂ are
            mixed (X̂ W), each worker broadcasts the fused-flat-quantized
            delta of its half-step against x̂, and every copy advances by
            the DECODED delta — the ``DCDGossipExchange`` semantics, with
            the trace's own codec sizing the wire. ecd adds the flat
            fp32 residual (error feedback) of ``ECDGossipExchange``.
  laq       the server keeps each worker's last uploaded (codec'd)
            gradient; only the trace's senders refresh theirs each round
            — the others are reused stale, the LAQ relaxation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.cluster.scheduler import Trace
from repro.core import compression

PyTree = Any


# ---------------------------------------------------------------------------
# Workloads
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Workload:
    """One trainable problem: initial params, a per-worker minibatch
    gradient (key -> batch is drawn inside), and a deterministic eval
    loss for the curves."""

    name: str
    params0: PyTree
    grad_fn: Callable[[PyTree, jax.Array], PyTree]
    eval_loss: Callable[[PyTree], jnp.ndarray]


def quadratic_workload(*, n_workers: int = 8, d: int = 32, m: int = 1024,
                       batch: int = 4, noise: float = 0.1,
                       heterogeneity: float = 0.0,
                       seed: int = 0) -> Workload:
    """The paper's §1.1.3 distributed least-squares testbed."""
    from repro.core import parallel

    prob = parallel.Quadratic.make(
        jax.random.PRNGKey(seed), m=m, d=d, noise=noise,
        heterogeneity=heterogeneity, n_workers=n_workers)

    def grad_fn(params, key):
        idx = jax.random.randint(key, (batch,), 0, m)
        return jax.grad(prob.loss_on)(params, idx)

    return Workload("quadratic", jnp.zeros((d,)), grad_fn, prob.full_loss)


def lm_workload(*, smoke: bool = True, batch: int = 2, seq: int = 32,
                seed: int = 0) -> Workload:
    """repro-100m language model (``reduced()`` dims under smoke) through
    the production loss path (train.steps.make_loss_fn); batches are
    synthetic next-token streams drawn from the key."""
    from repro import configs
    from repro.models import transformer
    from repro.train import steps as train_steps

    cfg = configs.get_config("repro-100m")
    if smoke:
        cfg = cfg.reduced(n_layers=2, d_model=128, vocab=256)
    loss = train_steps.make_loss_fn(cfg)
    params0 = transformer.init(cfg, jax.random.PRNGKey(seed))

    def make_batch(key):
        tokens = jax.random.randint(key, (batch, seq + 1), 0, cfg.vocab)
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}

    def grad_fn(params, key):
        return jax.grad(loss)(params, make_batch(key))

    eval_batch = make_batch(jax.random.PRNGKey(seed + 1))

    def eval_loss(params):
        return loss(params, eval_batch)

    return Workload("repro-100m" + ("-reduced" if smoke else ""),
                    params0, grad_fn, eval_loss)


# ---------------------------------------------------------------------------
# Replay
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ClusterRunResult:
    """A loss-vs-simulated-wall-clock curve plus the trace's vitals."""

    protocol: str
    t_wall: np.ndarray          # eval times (simulated seconds)
    losses: np.ndarray          # eval loss at those times
    updates_applied: int
    max_staleness: int
    makespan: float
    n_wire_messages: int

    @property
    def final_loss(self) -> float:
        return float(self.losses[-1])

    def time_to(self, target: float) -> float:
        """First simulated time the eval loss reaches `target` (inf if
        never) — the time-to-loss metric of the cluster benchmark."""
        hit = np.nonzero(self.losses <= target)[0]
        return float(self.t_wall[hit[0]]) if hit.size else float("inf")

    def loss_at(self, t: float) -> float:
        """Eval loss of the last evaluation at simulated time <= ``t``
        (the first recorded loss if none) — the equal-wall-clock
        comparison point the fault acceptance tests use."""
        idx = int(np.searchsorted(self.t_wall, t, side="right")) - 1
        return float(self.losses[max(idx, 0)])


def _sub(params, upd, lr):
    return jax.tree_util.tree_map(lambda p, u: p - lr * u, params, upd)


def _stack(params, n):
    return jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p[None], (n,) + p.shape), params)


def _mean0(params_w):
    return jax.tree_util.tree_map(lambda p: p.mean(0), params_w)


def replay(trace: Trace, workload: Workload, *, codec: str = "rq4",
           lr: float = 0.1, eval_every: int = 1, seed: int = 0,
           mixing_w: Optional[np.ndarray] = None) -> ClusterRunResult:
    """Train `workload` exactly as `trace` dictates; see module docstring.

    ``eval_every`` thins the eval cadence (every k applied updates for
    async, every k rounds otherwise). ``mixing_w`` overrides the
    dsgd/dcd/ecd replay matrix (default: the matrix the trace was
    scheduled with — decentralized traces carry W in their extras).
    Note for dcd/ecd traces: the broadcast delta is compressed with the
    TRACE's own codec (the one its wire ledger was sized with), not this
    ``codec`` argument, which only shapes the gradient path of the other
    protocols — keeping the replayed bits consistent with the charged
    bytes."""
    cdc = compression.codec(codec)
    root = jax.random.PRNGKey(seed)
    n = trace.n_workers

    def wkey(worker, step):
        return jax.random.fold_in(jax.random.fold_in(root, worker), step)

    def qgrad(params, key):
        """One worker's gradient through the fused flat-codec wire."""
        return cdc.tree_qdq_flat(workload.grad_fn(params, key),
                                 jax.random.fold_in(key, 7))

    def qmodel(params, key):
        """A model pulled through the compressed-checkpoint wire — the
        payload a crashed replica rejoins with (same flat-codec bits the
        scheduler charged for the ``ckpt*`` messages)."""
        return cdc.tree_qdq_flat(params, key)

    replays = {"sync_ps": _replay_sync, "async_ps": _replay_async,
               "local_sgd": _replay_local_sgd, "dsgd": _replay_dsgd,
               "dcd": _replay_dcd, "ecd": _replay_ecd, "laq": _replay_laq}
    if trace.protocol not in replays:
        raise KeyError(f"no replay for protocol '{trace.protocol}'")
    with obs.span(f"replay.{trace.protocol}",
                  args={"workload": workload.name, "codec": codec,
                        "n_workers": n}):
        ts, losses = replays[trace.protocol](
            trace, workload, qgrad, lr=lr, eval_every=eval_every, n=n,
            wkey=wkey, mixing_w=mixing_w, qmodel=qmodel)
    if obs.enabled("metrics"):
        p = trace.protocol
        obs.counter("replay.updates", protocol=p).inc(trace.n_updates)
        obs.gauge("replay.final_loss", protocol=p,
                  workload=workload.name).set(float(losses[-1]))
        obs.histogram("replay.eval_loss", protocol=p,
                      workload=workload.name).observe_many(
                          float(v) for v in losses)
    obs.flight_record("replay.done", protocol=trace.protocol,
                      workload=workload.name, codec=codec,
                      final_loss=float(losses[-1]), n_evals=len(losses))
    return ClusterRunResult(trace.protocol, np.asarray(ts),
                            np.asarray(losses, dtype=float),
                            trace.n_updates, trace.max_staleness,
                            trace.makespan, len(trace.messages))


def _sync_times(trace, kinds=("sync", "gossip")):
    return [e.t_wall for e in trace.events if e.kind in kinds]


def _row_mask(workers, n) -> jnp.ndarray:
    m = np.zeros((n,), np.float32)
    m[list(workers)] = 1.0
    return jnp.asarray(m)


def _where_rows(mask, a, b):
    """Per-leaf ``where`` over the stacked worker axis."""
    return jax.tree_util.tree_map(
        lambda x, y: jnp.where(
            mask.reshape((mask.shape[0],) + (1,) * (x.ndim - 1)) > 0,
            x, y), a, b)


def _set_row(params_w, w, row):
    return jax.tree_util.tree_map(lambda pw, p: pw.at[w].set(p),
                                  params_w, row)


def _get_row(params_w, w):
    return jax.tree_util.tree_map(lambda pw: pw[w], params_w)


def _byzantine_transform(byz, bscale, n):
    """Per-round gradient sabotage for the trace's Byzantine roster:
    ``sign_flip`` rows send ``-g``, ``scale`` rows ``bscale * g``,
    ``random`` rows ``bscale``-sized keyed noise. Returns None when the
    roster is empty so the honest replay graph is untouched (bit-
    identical to the pre-registry path)."""
    if not byz:
        return None
    sign_m = _row_mask([w for w, m in byz if m == "sign_flip"], n)
    scale_m = _row_mask([w for w, m in byz if m == "scale"], n)
    rand_m = _row_mask([w for w, m in byz if m == "random"], n)
    has_rand = bool(np.asarray(rand_m).sum() > 0)
    fac = 1.0 - 2.0 * sign_m + (bscale - 1.0) * scale_m       # (n,)

    def transform(q_w, keys):
        leaves, treedef = jax.tree_util.tree_flatten(q_w)
        out = []
        for i, q in enumerate(leaves):
            shaped = fac.reshape((n,) + (1,) * (q.ndim - 1))
            v = q * shaped
            if has_rand:
                noise = jax.vmap(lambda k: bscale * jax.random.normal(
                    jax.random.fold_in(k, 104729 + i),
                    q.shape[1:]))(keys)
                rm = rand_m.reshape((n,) + (1,) * (q.ndim - 1))
                v = jnp.where(rm > 0, noise, v)
            out.append(v)
        return jax.tree_util.tree_unflatten(treedef, out)

    return transform


def _replay_sync(trace, workload, qgrad, *, lr, eval_every, n, wkey,
                 mixing_w, qmodel):
    del mixing_w, qmodel
    from repro.cluster import aggregators as _aggs

    rounds = trace.extra("rounds")
    contributors = trace.extra_or("contributors")
    agg_name = trace.extra_or("aggregator", "mean") or "mean"
    byz = tuple(trace.extra_or("byzantine", ()) or ())
    bscale = float(trace.extra_or("byzantine_scale", 1.0) or 1.0)
    agg_fn = _aggs.aggregator(agg_name)
    sabotage = _byzantine_transform(byz, bscale, n)
    # the masked path also serves robust rules / Byzantine rosters on a
    # full barrier (mask = everyone)
    masked = (contributors is not None or agg_name != "mean"
              or sabotage is not None)

    @jax.jit
    def round_step(params, r):
        keys = jax.vmap(lambda w: wkey(w, r))(jnp.arange(n))
        q_w = jax.vmap(lambda k: qgrad(params, k))(keys)
        return _sub(params, _mean0(q_w), lr)

    @jax.jit
    def round_step_quorum(params, mask, r):
        # graceful degradation: aggregate the quorum's gradients only;
        # an empty round leaves the model untouched (zero update — the
        # scheduler ledgered it as a QuorumShortfall)
        keys = jax.vmap(lambda w: wkey(w, r))(jnp.arange(n))
        q_w = jax.vmap(lambda k: qgrad(params, k))(keys)
        if sabotage is not None:
            q_w = sabotage(q_w, keys)
        return _sub(params, agg_fn(q_w, mask), lr)

    params = workload.params0
    full = _row_mask(range(n), n)
    ts, losses = [], []
    t_sync = _sync_times(trace)
    for r in range(rounds):
        if not masked:
            params = round_step(params, r)
        else:
            mask = (_row_mask(contributors[r], n)
                    if contributors is not None else full)
            params = round_step_quorum(params, mask, r)
        if (r + 1) % eval_every == 0 or r == rounds - 1:
            ts.append(t_sync[r])
            losses.append(float(workload.eval_loss(params)))
    return ts, losses


def _replay_async(trace, workload, qgrad, *, lr, eval_every, n, wkey,
                  mixing_w, qmodel):
    # faults need no special handling here: the scheduler already folded
    # drops/retries/crashes into the update-event sequence (a crashed
    # worker simply contributes no events while down; its rejoin pull is
    # the next version it computes against)
    del n, mixing_w, qmodel

    @jax.jit
    def apply_one(p_pulled, p_cur, key):
        return _sub(p_cur, qgrad(p_pulled, key), lr)

    events = trace.updates()
    keep = trace.max_staleness + 2
    hist = {0: workload.params0}
    params = workload.params0
    version = 0
    ts, losses = [], []
    for i, e in enumerate(events):
        if e.version_applied != version:
            raise ValueError("trace apply order is inconsistent "
                             f"({e.version_applied} != {version})")
        params = apply_one(hist[e.version_pulled], params,
                           wkey(e.worker, e.step))
        version += 1
        hist[version] = params
        hist.pop(version - keep, None)
        if (i + 1) % eval_every == 0 or i == len(events) - 1:
            ts.append(e.t_wall)
            losses.append(float(workload.eval_loss(params)))
    return ts, losses


def _replay_local_sgd(trace, workload, qgrad, *, lr, eval_every, n, wkey,
                      mixing_w, qmodel):
    del mixing_w
    rounds, h = trace.extra("rounds"), trace.extra("period_h")
    present = trace.extra_or("present")

    @jax.jit
    def local_step(params_w, step):
        keys = jax.vmap(lambda w: wkey(w, step))(jnp.arange(n))
        return jax.vmap(lambda p, k: _sub(p, qgrad(p, k), lr))(params_w,
                                                               keys)

    @jax.jit
    def average(params_w):
        return _stack(_mean0(params_w), n)

    if present is None:
        params_w = _stack(workload.params0, n)
        ts, losses = [], []
        t_sync = _sync_times(trace)
        for r in range(rounds):
            for k in range(h):
                params_w = local_step(params_w, r * h + k)
            params_w = average(params_w)
            if (r + 1) % eval_every == 0 or r == rounds - 1:
                ts.append(t_sync[r])
                losses.append(float(workload.eval_loss(_mean0(params_w))))
        return ts, losses

    # -- fault path: present rows step, the quorum's contributors are
    # averaged into the PS model, receivers adopt it, rejoiners pull it
    # through the compressed-checkpoint wire
    contributors = trace.extra("contributors")
    receivers = trace.extra("receivers")
    rejoiners = trace.extra("rejoiners")

    @jax.jit
    def local_step_masked(params_w, mask, step):
        stepped = local_step(params_w, step)
        return _where_rows(mask, stepped, params_w)

    @jax.jit
    def masked_avg(params_w, mask):
        count = mask.sum()
        scale = jnp.where(count > 0, 1.0 / jnp.maximum(count, 1.0), 0.0)
        return jax.tree_util.tree_map(
            lambda p: (p * mask.reshape((n,) + (1,) * (p.ndim - 1))
                       ).sum(0) * scale, params_w)

    model = workload.params0        # the PS's broadcast copy
    params_w = _stack(model, n)
    ts, losses = [], []
    t_sync = _sync_times(trace)
    for r in range(rounds):
        for w, _donor in rejoiners[r]:
            pulled = qmodel(model, jax.random.fold_in(wkey(w, r), 999983))
            params_w = _set_row(params_w, w, pulled)
        mask_p = _row_mask(present[r], n)
        for k in range(h):
            params_w = local_step_masked(params_w, mask_p, r * h + k)
        if contributors[r]:
            model = masked_avg(params_w, _row_mask(contributors[r], n))
        params_w = _where_rows(_row_mask(receivers[r], n),
                               _stack(model, n), params_w)
        if (r + 1) % eval_every == 0 or r == rounds - 1:
            ts.append(t_sync[r])
            losses.append(float(workload.eval_loss(model)))
    return ts, losses


def _replay_dsgd(trace, workload, qgrad, *, lr, eval_every, n, wkey,
                 mixing_w, qmodel):
    rounds = trace.extra("rounds")
    if mixing_w is None:
        # the matrix the scheduler costed rides in the trace itself
        mixing_w = np.asarray(trace.extra("w"))
    w_mat = jnp.asarray(np.asarray(mixing_w), jnp.float32)
    present = trace.extra_or("present")

    @jax.jit
    def round_step(params_w, r):
        keys = jax.vmap(lambda w: wkey(w, r))(jnp.arange(n))
        stepped = jax.vmap(lambda p, k: _sub(p, qgrad(p, k), lr))(params_w,
                                                                  keys)
        # X <- X W on the stacked worker axis (Eq. 5.2)
        return jax.tree_util.tree_map(
            lambda p: jnp.tensordot(w_mat, p, axes=[[1], [0]]), stepped)

    if present is None:
        params_w = _stack(workload.params0, n)
        ts, losses = [], []
        t_sync = _sync_times(trace)
        for r in range(rounds):
            params_w = round_step(params_w, r)
            if (r + 1) % eval_every == 0 or r == rounds - 1:
                ts.append(t_sync[r])
                losses.append(float(workload.eval_loss(_mean0(params_w))))
        return ts, losses

    # -- fault path: each membership epoch re-derives W over the live
    # set (the same matrix the scheduler validated through the Birkhoff
    # decomposition); a lost gossip message returns its weight to the
    # receiver's self-weight (the sender's column just leaks — that send
    # was paid and vanished); rejoiners pull their donor's model through
    # the compressed-checkpoint wire
    from repro.cluster import faults as _faults

    rejoiners = trace.extra("rejoiners")
    dropped = trace.extra("dropped_edges")
    base_w = np.asarray(np.asarray(mixing_w), dtype=float)

    @jax.jit
    def round_step_masked(params_w, w_eff, mask, r):
        keys = jax.vmap(lambda w: wkey(w, r))(jnp.arange(n))
        stepped = jax.vmap(lambda p, k: _sub(p, qgrad(p, k), lr))(params_w,
                                                                  keys)
        stepped = _where_rows(mask, stepped, params_w)
        return jax.tree_util.tree_map(
            lambda p: jnp.tensordot(w_eff, p, axes=[[1], [0]]), stepped)

    params_w = _stack(workload.params0, n)
    ts, losses = [], []
    t_sync = _sync_times(trace)
    for r in range(rounds):
        for w, donor in rejoiners[r]:
            if donor >= 0:
                pulled = qmodel(_get_row(params_w, donor),
                                jax.random.fold_in(wkey(w, r), 999983))
                params_w = _set_row(params_w, w, pulled)
        w_eff = _faults.live_mixing_matrix(base_w, present[r])
        for src, dst in dropped[r]:
            w_eff[dst, dst] += w_eff[dst, src]
            w_eff[dst, src] = 0.0
        params_w = round_step_masked(params_w,
                                     jnp.asarray(w_eff, jnp.float32),
                                     _row_mask(present[r], n), r)
        if (r + 1) % eval_every == 0 or r == rounds - 1:
            rows = list(present[r]) or list(range(n))
            live = jax.tree_util.tree_map(
                lambda p: p[np.asarray(rows)].mean(0), params_w)
            ts.append(t_sync[r])
            losses.append(float(workload.eval_loss(live)))
    return ts, losses


def _replay_compressed_decentralized(trace, workload, *, lr, eval_every, n,
                                     wkey, mixing_w, ec):
    """Shared DCD/ECD replay: stacked PUBLIC copies x̂_w advance by the
    decoded quantized delta of each worker's half-step (gradients are NOT
    compressed — only the broadcast delta is, exactly the
    DCD/ECDGossipExchange wire), mixed with the trace's own W and sized
    by the trace's own codec.

    Fault traces: deltas are RELIABLE (the scheduler retried every drop),
    so the only degradation is membership — each epoch mixes with the
    re-derived live matrix, absent workers' public copies freeze, and
    rejoiners pull their donor's x̂ through the compressed-checkpoint
    wire (error-feedback residual reset to zero: the errors it accrued
    before crashing died with it)."""
    rounds = trace.extra("rounds")
    if mixing_w is None:
        mixing_w = np.asarray(trace.extra("w"))
    w_mat = jnp.asarray(np.asarray(mixing_w), jnp.float32)
    cdc = compression.codec(trace.extra("codec"))   # guaranteed by scheduler
    layout = compression.FlatLayout.from_tree(workload.params0)
    present = trace.extra_or("present")

    @jax.jit
    def round_step(xhat_w, err_w, r):
        keys = jax.vmap(lambda w: wkey(w, r))(jnp.arange(n))
        params_w = jax.vmap(layout.unflatten)(xhat_w)
        g_w = jax.vmap(workload.grad_fn)(params_w, keys)
        gflat_w = jax.vmap(layout.flatten)(g_w)
        x_half = w_mat @ xhat_w - lr * gflat_w
        v = x_half - xhat_w + (err_w if ec else 0.0)
        q = jax.vmap(lambda x, k: cdc.flat_qdq(x, jax.random.fold_in(k, 7))
                     )(v, keys)
        return xhat_w + q, (v - q if ec else err_w)

    @jax.jit
    def round_step_masked(xhat_w, err_w, w_eff, mask, r):
        keys = jax.vmap(lambda w: wkey(w, r))(jnp.arange(n))
        params_w = jax.vmap(layout.unflatten)(xhat_w)
        g_w = jax.vmap(workload.grad_fn)(params_w, keys)
        gflat_w = jax.vmap(layout.flatten)(g_w) * mask[:, None]
        x_half = w_eff @ xhat_w - lr * gflat_w
        v = x_half - xhat_w + (err_w if ec else 0.0)
        q = jax.vmap(lambda x, k: cdc.flat_qdq(x, jax.random.fold_in(k, 7))
                     )(v, keys) * mask[:, None]
        err_new = (jnp.where(mask[:, None] > 0, v - q, err_w) if ec
                   else err_w)
        return xhat_w + q, err_new

    xhat_w = jax.vmap(layout.flatten)(_stack(workload.params0, n))
    err_w = jnp.zeros_like(xhat_w)
    ts, losses = [], []
    t_sync = _sync_times(trace)

    if present is None:
        for r in range(rounds):
            xhat_w, err_w = round_step(xhat_w, err_w, r)
            if (r + 1) % eval_every == 0 or r == rounds - 1:
                ts.append(t_sync[r])
                losses.append(float(workload.eval_loss(
                    layout.unflatten(xhat_w.mean(0)))))
        return ts, losses

    from repro.cluster import faults as _faults

    rejoiners = trace.extra("rejoiners")
    base_w = np.asarray(np.asarray(mixing_w), dtype=float)
    for r in range(rounds):
        for w, donor in rejoiners[r]:
            if donor >= 0:
                key = jax.random.fold_in(wkey(w, r), 999983)
                xhat_w = xhat_w.at[w].set(cdc.flat_qdq(xhat_w[donor],
                                                       key))
                err_w = err_w.at[w].set(0.0)
        w_eff = _faults.live_mixing_matrix(base_w, present[r])
        xhat_w, err_w = round_step_masked(
            xhat_w, err_w, jnp.asarray(w_eff, jnp.float32),
            _row_mask(present[r], n), r)
        if (r + 1) % eval_every == 0 or r == rounds - 1:
            rows = np.asarray(list(present[r]) or list(range(n)))
            ts.append(t_sync[r])
            losses.append(float(workload.eval_loss(
                layout.unflatten(xhat_w[rows].mean(0)))))
    return ts, losses


def _replay_dcd(trace, workload, qgrad, *, lr, eval_every, n, wkey,
                mixing_w, qmodel):
    del qgrad, qmodel   # DCD compresses the broadcast delta + checkpoint
    return _replay_compressed_decentralized(
        trace, workload, lr=lr, eval_every=eval_every, n=n, wkey=wkey,
        mixing_w=mixing_w, ec=False)


def _replay_ecd(trace, workload, qgrad, *, lr, eval_every, n, wkey,
                mixing_w, qmodel):
    del qgrad, qmodel
    return _replay_compressed_decentralized(
        trace, workload, lr=lr, eval_every=eval_every, n=n, wkey=wkey,
        mixing_w=mixing_w, ec=True)


def _replay_laq(trace, workload, qgrad, *, lr, eval_every, n, wkey,
                mixing_w, qmodel):
    # fault traces need no special handling: the senders-by-round table
    # below is read from the update events, which already carry only the
    # contributions that survived drops/timeouts/crashes
    del mixing_w, qmodel
    rounds = trace.extra("rounds")
    senders_by_round = np.zeros((rounds, n), bool)
    for e in trace.updates():
        senders_by_round[e.step, e.worker] = True

    @jax.jit
    def round_step(params, stored_w, mask, r):
        keys = jax.vmap(lambda w: wkey(w, r))(jnp.arange(n))
        q_w = jax.vmap(lambda k: qgrad(params, k))(keys)
        # only the trace's senders refresh their stored gradient; the
        # server reuses the rest stale (the LAQ relaxation)
        stored_w = jax.tree_util.tree_map(
            lambda s, q: jnp.where(
                mask.reshape((n,) + (1,) * (q.ndim - 1)), q, s),
            stored_w, q_w)
        return _sub(params, _mean0(stored_w), lr), stored_w

    params = workload.params0
    stored_w = _stack(jax.tree_util.tree_map(jnp.zeros_like,
                                             workload.params0), n)
    ts, losses = [], []
    t_sync = _sync_times(trace)
    for r in range(rounds):
        params, stored_w = round_step(params, stored_w,
                                      jnp.asarray(senders_by_round[r]), r)
        if (r + 1) % eval_every == 0 or r == rounds - 1:
            ts.append(t_sync[r])
            losses.append(float(workload.eval_loss(params)))
    return ts, losses
