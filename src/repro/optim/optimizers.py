"""Hand-rolled first-order optimizers (no optax in the container).

All optimizers share one interface:
    opt = sgd(lr) | momentum_sgd(lr, beta) | adamw(lr, ...)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

`moment_dtype` controls optimizer-state precision — the fp32-vs-bf16 moment
tradeoff is what lets grok-1-314b's train state fit 16 GB/chip (recorded in
EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], tuple[PyTree, PyTree]]
    name: str = "opt"


def _tmap(fn, *trees):
    return jax.tree_util.tree_map(fn, *trees)


def sgd(lr: float | Callable[[jnp.ndarray], jnp.ndarray]) -> Optimizer:
    """Plain SGD — the paper's Eq. (1.10); lr may be a schedule fn(step)."""

    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"]
        eta = lr(step) if callable(lr) else lr
        updates = _tmap(lambda g: (-eta * g).astype(g.dtype), grads)
        return updates, {"step": step + 1}

    return Optimizer(init, update, "sgd")


def momentum_sgd(lr: float, beta: float = 0.9, *,
                 moment_dtype=jnp.float32) -> Optimizer:
    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "m": _tmap(lambda p: jnp.zeros(p.shape, moment_dtype), params)}

    def update(grads, state, params):
        step = state["step"]
        eta = lr(step) if callable(lr) else lr
        m = _tmap(lambda m_, g: beta * m_ + g.astype(moment_dtype),
                  state["m"], grads)
        updates = _tmap(lambda m_, p: (-eta * m_).astype(p.dtype), m, params)
        return updates, {"step": step + 1, "m": m}

    return Optimizer(init, update, "momentum")


def adamw(lr: float | Callable, *, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0,
          moment_dtype=jnp.float32) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, moment_dtype)
        return {"step": jnp.zeros((), jnp.int32),
                "m": _tmap(z, params), "v": _tmap(z, params)}

    def update(grads, state, params):
        step = state["step"] + 1
        eta = lr(step) if callable(lr) else lr
        m = _tmap(lambda m_, g: (b1 * m_ + (1 - b1) * g).astype(moment_dtype),
                  state["m"], grads)
        v = _tmap(lambda v_, g: (b2 * v_ + (1 - b2) * g * g)
                  .astype(moment_dtype), state["v"], grads)
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(m_, v_, p):
            mhat = m_.astype(jnp.float32) / bc1
            vhat = v_.astype(jnp.float32) / bc2
            u = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (-eta * u).astype(p.dtype)

        updates = _tmap(upd, m, v, params)
        return updates, {"step": step, "m": m, "v": v}

    return Optimizer(init, update, "adamw")


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return _tmap(lambda p, u: p + u, params, updates)


def clip_by_global_norm(grads: PyTree, max_norm: float) -> tuple[PyTree, jnp.ndarray]:
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return _tmap(lambda g: (g * scale).astype(g.dtype), grads), gn


def cosine_schedule(peak_lr: float, *, warmup: int = 100,
                    total: int = 10_000, floor: float = 0.1) -> Callable:
    def lr(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = peak_lr * s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(s < warmup, warm, cos)

    return lr


def make_optimizer(name: str, lr, **kw) -> Optimizer:
    if name == "sgd":
        return sgd(lr)
    if name == "momentum":
        return momentum_sgd(lr, **kw)
    if name == "adamw":
        return adamw(lr, **kw)
    raise KeyError(f"unknown optimizer '{name}'")
