from repro.optim.optimizers import (Optimizer, adamw, momentum_sgd, sgd,
                                    clip_by_global_norm, cosine_schedule,
                                    make_optimizer)

__all__ = ["Optimizer", "adamw", "momentum_sgd", "sgd",
           "clip_by_global_norm", "cosine_schedule", "make_optimizer"]
