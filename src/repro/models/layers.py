"""Shared layer primitives (pure functions over param subtrees).

Parameters are plain nested dicts of jnp arrays; every function takes its
param subtree first. Initializers return (shapes-only) trees when given
``abstract=True`` callers — abstract init is done via jax.eval_shape at the
launcher level, so these stay ordinary.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp


def dense_init(key, d_in: int, d_out: int, *, bias: bool = False,
               scale: Optional[float] = None, dtype=jnp.float32):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32)
               * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def norm_init(d: int, kind: str, dtype=jnp.float32):
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p, x, *, kind: str, eps: float):
    x32 = x.astype(jnp.float32)
    if kind == "rmsnorm":
        x32 = x32 * jax.lax.rsqrt(jnp.mean(x32**2, -1, keepdims=True) + eps)
        return (x32 * p["scale"].astype(jnp.float32)).astype(x.dtype)
    mu = jnp.mean(x32, -1, keepdims=True)
    var = jnp.var(x32, -1, keepdims=True)
    x32 = (x32 - mu) * jax.lax.rsqrt(var + eps)
    out = x32 * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE / M-RoPE
# --------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, *, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, D/2)
    ang = ang[..., None, :]                             # (..., S, 1, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray, *, theta: float,
                sections: Sequence[int]) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE [arXiv:2409.12191].

    positions3: (..., 3, S) — temporal/height/width position ids. Frequency
    slots are split into `sections` (per half-dim), each slot taking its
    angle from the corresponding positional axis.
    """
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    # build a (..., S, D/2) angle tensor choosing the axis per section
    sec_ids = jnp.repeat(jnp.arange(len(sections)),
                         jnp.array(sections), total_repeat_length=d // 2)
    # positions3: (..., 3, S) -> (..., S, 3)
    pos = jnp.moveaxis(positions3, -2, -1).astype(jnp.float32)
    # angle for slot k = pos[..., sec_ids[k]] * freqs[k]
    pos_per_slot = jnp.take(pos, sec_ids, axis=-1)     # (..., S, D/2)
    ang = pos_per_slot * freqs                          # (..., S, D/2)
    ang = ang[..., None, :]                             # (..., S, 1, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def text_mrope_positions(positions: jnp.ndarray) -> jnp.ndarray:
    """For pure-text streams all three M-RoPE axes share the position id."""
    return jnp.stack([positions, positions, positions], axis=-2)


# --------------------------------------------------------------------------
# MLP (gated or plain)
# --------------------------------------------------------------------------


def mlp_init(key, d_model: int, d_ff: int, *, glu: bool, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {"up": dense_init(ks[0], d_model, d_ff, dtype=dtype),
         "down": dense_init(ks[1], d_ff, d_model, dtype=dtype)}
    if glu:
        p["gate"] = dense_init(ks[2], d_model, d_ff, dtype=dtype)
    return p


def mlp(p, x, *, act: str, glu: bool):
    a = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[act]
    up = dense(p["up"], x)
    h = a(dense(p["gate"], x)) * up if glu else a(up)
    return dense(p["down"], h)


def softcap(logits: jnp.ndarray, cap: float) -> jnp.ndarray:
    if cap <= 0:
        return logits
    return cap * jnp.tanh(logits / cap)
