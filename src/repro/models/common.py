"""Model configuration system.

One ``ModelConfig`` describes any architecture in the zoo (6 families). The
per-layer ``block_pattern`` composes heterogeneous stacks (e.g. recurrent-
gemma's RG-LRU/RG-LRU/local-attn 2:1 pattern). ``reduced()`` derives the
CPU smoke-test variant required per assigned architecture.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int               # routed experts
    top_k: int
    d_ff_expert: int             # per-expert hidden width
    n_shared: int = 0            # always-on shared experts (DeepSeek-V2)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01   # load-balance loss weight


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2)."""

    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    # attention details
    qkv_bias: bool = False
    out_bias: bool = False
    rope_theta: float = 10_000.0
    rope_variant: str = "rope"   # rope | mrope | none
    mrope_sections: Sequence[int] = (16, 24, 24)
    logit_softcap: float = 0.0
    local_window: int = 0        # window for 'local_attn' blocks
    # block composition; entries: attn | local_attn | rwkv | rglru | mla
    block_pattern: Sequence[str] = ()
    # norm / mlp
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    norm_eps: float = 1e-5
    act: str = "silu"            # silu | gelu
    glu: bool = True             # gated MLP (SwiGLU/GeGLU) vs plain 2-layer
    parallel_block: bool = False  # Cohere-style attn+mlp in parallel
    # families
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    rwkv_head_dim: int = 64
    rglu_width: int = 0          # RG-LRU width (0 -> d_model)
    conv_width: int = 4          # temporal conv in recurrent blocks
    # embeddings
    tie_embeddings: bool = False
    embed_scale: bool = False    # multiply embeddings by sqrt(d_model)
    # encoder-decoder (audio family)
    n_encoder_layers: int = 0
    # modality frontend: token | patch_stub | frame_stub
    frontend: str = "token"
    # serving
    sliding_window_decode: int = 0  # >0: windowed KV cache for long-context

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if not self.block_pattern:
            kind = "mla" if self.mla is not None else "attn"
            object.__setattr__(self, "block_pattern",
                               tuple([kind] * self.n_layers))
        if len(self.block_pattern) != self.n_layers:
            raise ValueError(
                f"{self.arch_id}: block_pattern len {len(self.block_pattern)}"
                f" != n_layers {self.n_layers}")

    # ---- derived quantities -------------------------------------------------

    @property
    def is_encdec(self) -> bool:
        return self.n_encoder_layers > 0

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs in roofline)."""
        from repro.models import transformer  # local import, avoids cycle
        return transformer.count_params(self)

    def active_param_count(self) -> int:
        """Activated params per token (= param_count for non-MoE)."""
        from repro.models import transformer
        return transformer.count_params(self, active_only=True)

    def reduced(self, *, n_layers: int = 2, d_model: int = 256,
                vocab: int = 512) -> "ModelConfig":
        """Smoke-test variant: same family/block kinds, tiny dims."""
        n_heads = max(2, min(4, self.n_heads))
        head_dim = d_model // n_heads
        n_kv = min(self.n_kv_heads, n_heads)
        # preserve the flavor of the pattern in 2 layers
        kinds = list(dict.fromkeys(self.block_pattern))  # unique, ordered
        pattern = tuple((kinds * n_layers)[:n_layers])
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe, n_experts=min(4, self.moe.n_experts),
                top_k=min(2, self.moe.top_k),
                n_shared=min(1, self.moe.n_shared),
                d_ff_expert=d_model)
        mla = None
        if self.mla is not None:
            mla = MLAConfig(kv_lora_rank=64, qk_nope_head_dim=head_dim,
                            qk_rope_head_dim=head_dim // 2,
                            v_head_dim=head_dim)
        return dataclasses.replace(
            self, n_layers=n_layers, d_model=d_model, n_heads=n_heads,
            n_kv_heads=n_kv, head_dim=head_dim, d_ff=2 * d_model, vocab=vocab,
            block_pattern=pattern, moe=moe, mla=mla,
            local_window=min(self.local_window, 64) if self.local_window else 0,
            rglu_width=0, mrope_sections=_reduced_sections(self, head_dim),
            n_encoder_layers=min(self.n_encoder_layers, n_layers),
            sliding_window_decode=(64 if self.sliding_window_decode else 0))


def _reduced_sections(cfg: ModelConfig, head_dim: int) -> Sequence[int]:
    if cfg.rope_variant != "mrope":
        return cfg.mrope_sections
    half = head_dim // 2
    a = half // 4
    return (half - 2 * a, a, a)


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One of the four assigned global input shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
