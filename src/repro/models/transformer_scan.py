"""Scan-over-layers model assembly (production path).

Compiling an unrolled 80-layer graph makes XLA's SPMD partitioner the
bottleneck (minutes -> tens of minutes per dry-run combo); `lax.scan` over
stacked per-layer params compiles the block body once — the standard
production technique (MaxText et al.). This module mirrors
repro.models.transformer (same block primitives, same math) with stacked
parameters; tests assert scanned == unrolled on reduced configs.

Layout: the block pattern is split into
    prefix  (unrolled; e.g. deepseek's dense-FFN layer 0)
  + unit * n_rep  (scanned; unit = minimal repeating cycle, e.g.
                   recurrentgemma's (rglru, rglru, local_attn))
  + suffix (unrolled remainder; e.g. recurrentgemma's trailing 2 layers)

Param tree: {embed, final_norm, lm_head?, prefix_layers: [block...],
             scan_blocks: [stacked-block per unit position],
             suffix_layers: [block...], encoder?: {scan_blocks, final_norm}}
Stacked leaves carry a leading n_rep dim; sharding rules replicate that dim
(dist/sharding.py strips it).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import attention, layers, mla, moe, rglru, rwkv
from repro.models.common import ModelConfig
from repro.models.transformer import (_block_apply, _block_init, _ffn_apply,
                                      _lm_head, _moe_skipped, _norm,
                                      _positions, embed_inputs)

PyTree = Any


def pattern_segments(cfg: ModelConfig):
    """-> (prefix_kinds, unit_kinds, n_rep, suffix_kinds)."""
    pattern = tuple(cfg.block_pattern)
    start = 1 if (cfg.moe is not None and _moe_skipped(cfg, 0)) else 0
    rest = pattern[start:]
    unit, n_rep = rest[:1] or ("attn",), 0
    for u in (1, 2, 3, 4, 6):
        if not rest or len(rest) < u:
            break
        reps = len(rest) // u
        if reps >= 1 and all(rest[i] == rest[i % u] for i in range(reps * u)):
            unit, n_rep = rest[:u], reps
            break
    suffix = rest[n_rep * len(unit):]
    return pattern[:start], unit, n_rep, suffix


def init(cfg: ModelConfig, key: jax.Array, *, dtype=jnp.float32) -> PyTree:
    prefix, unit, n_rep, suffix = pattern_segments(cfg)
    ks = jax.random.split(key, 8)
    params: dict = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dtype),
        "final_norm": layers.norm_init(cfg.d_model, cfg.norm, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.dense_init(ks[1], cfg.d_model, cfg.vocab,
                                              dtype=dtype)
    params["prefix_layers"] = [
        _block_init(jax.random.fold_in(ks[2], i), cfg, kind, i,
                    cross=cfg.is_encdec, dtype=dtype)
        for i, kind in enumerate(prefix)]
    params["scan_blocks"] = []
    for j, kind in enumerate(unit):
        if n_rep == 0:
            continue
        keys = jax.random.split(jax.random.fold_in(ks[3], j), n_rep)
        stacked = jax.vmap(
            lambda k: _block_init(k, cfg, kind, len(prefix) + j,
                                  cross=cfg.is_encdec, dtype=dtype))(keys)
        params["scan_blocks"].append(stacked)
    off = len(prefix) + n_rep * len(unit)
    params["suffix_layers"] = [
        _block_init(jax.random.fold_in(ks[4], i), cfg, kind, off + i,
                    cross=cfg.is_encdec, dtype=dtype)
        for i, kind in enumerate(suffix)]
    if cfg.is_encdec:
        enc_keys = jax.random.split(ks[5], cfg.n_encoder_layers)
        params["encoder"] = {
            "scan_blocks": jax.vmap(
                lambda k: _block_init(k, cfg, "attn", 1, dtype=dtype))(
                    enc_keys),
            "final_norm": layers.norm_init(cfg.d_model, cfg.norm, dtype),
        }
    return params


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------


def encode(params, cfg: ModelConfig, src_embeddings, *, remat: bool = False):
    enc = params["encoder"]
    b, s, _ = src_embeddings.shape
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(x, p):
        h = _norm(cfg, p["ln1"], x)
        x = x + attention.attention(p["mixer"], cfg, h, pos, causal=False)
        h2 = _norm(cfg, p["ln2"], x)
        ffn_out, _ = _ffn_apply(p["ffn"], cfg, h2, 1)
        return x + ffn_out, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, src_embeddings, enc["scan_blocks"])
    return _norm(cfg, enc["final_norm"], x)


def apply(params, cfg: ModelConfig, batch, *, use_flash: bool = False,
          remat: bool = False, logits_positions: str = "all",
          remat_policy: str = "full"):
    """logits_positions='last' unembeds only the final position — the
    serving-prefill fast path (a 32k-seq prefill otherwise computes and
    communicates a (B, 32768, V) logits tensor just to slice one row;
    EXPERIMENTS.md §Perf iteration 2)."""
    prefix, unit, n_rep, suffix = pattern_segments(cfg)
    x = embed_inputs(params, cfg, batch)
    b, s, _ = x.shape
    positions = _positions(cfg, b, s, batch)

    mkv_prefix = mkv_scan = mkv_suffix = None
    if cfg.is_encdec:
        memory = encode(params, cfg, batch["src_embeddings"], remat=remat)
        mk = lambda p: attention.memory_kv(p["cross"], cfg, memory)
        mkv_prefix = [mk(p) for p in params["prefix_layers"]]
        mkv_scan = [jax.vmap(mk)(sp) for sp in params["scan_blocks"]]
        mkv_suffix = [mk(p) for p in params["suffix_layers"]]

    aux_total = 0.0
    for i, (p, kind) in enumerate(zip(params["prefix_layers"], prefix)):
        x, aux = _block_apply(p, cfg, kind, i, x, positions,
                              memory_kv=None if mkv_prefix is None
                              else mkv_prefix[i], use_flash=use_flash)
        aux_total = aux_total + aux

    if n_rep:
        from repro.dist.sharding import constrain_act

        def body(carry, inp):
            x, aux = carry
            for j, kind in enumerate(unit):
                p_j = inp[f"p{j}"]
                mkv_j = inp.get(f"mkv{j}")
                x, a = _block_apply(p_j, cfg, kind, len(prefix) + j, x,
                                    positions, memory_kv=mkv_j,
                                    use_flash=use_flash)
                x = constrain_act(x)
                aux = aux + a
            return (x, aux), None

        if remat:
            policy = (jax.checkpoint_policies
                      .dots_with_no_batch_dims_saveable
                      if remat_policy == "dots" else None)
            body = jax.checkpoint(body, policy=policy)
        inp = {f"p{j}": sp for j, sp in enumerate(params["scan_blocks"])}
        if mkv_scan is not None:
            inp.update({f"mkv{j}": m for j, m in enumerate(mkv_scan)})
        (x, aux_total), _ = jax.lax.scan(
            body, (x, jnp.asarray(aux_total, jnp.float32)), inp)

    off = len(prefix) + n_rep * len(unit)
    for i, (p, kind) in enumerate(zip(params["suffix_layers"], suffix)):
        x, aux = _block_apply(p, cfg, kind, off + i, x, positions,
                              memory_kv=None if mkv_suffix is None
                              else mkv_suffix[i], use_flash=use_flash)
        aux_total = aux_total + aux

    if logits_positions == "last":
        x = x[:, -1:]
    x = _norm(cfg, params["final_norm"], x)
    return _lm_head(params, cfg, x), aux_total


def loss_fn(params, cfg: ModelConfig, batch, *, use_flash: bool = False,
            remat: bool = False, remat_policy: str = "full"):
    from repro.models.transformer import sharded_cross_entropy
    logits, aux = apply(params, cfg, batch, use_flash=use_flash, remat=remat,
                        remat_policy=remat_policy)
    ce = sharded_cross_entropy(logits, batch["labels"],
                               softcap=cfg.logit_softcap)
    return ce + aux


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------


def _init_block_state(cfg: ModelConfig, kind: str, batch: int, seq_len: int,
                      window: int, dtype, quantize_kv: bool = False):
    if kind == "attn":
        return attention.init_cache(cfg, batch, seq_len, window=window,
                                    dtype=dtype, quantize=quantize_kv)
    if kind == "local_attn":
        return attention.init_cache(cfg, batch, seq_len,
                                    window=cfg.local_window, dtype=dtype,
                                    quantize=quantize_kv)
    if kind == "mla":
        return mla.init_cache(cfg, batch, seq_len, window=window, dtype=dtype)
    if kind == "rwkv":
        st = rwkv.init_state(cfg, batch)
        st["prev_x_ffn"] = jnp.zeros((batch, cfg.d_model), jnp.float32)
        return st
    if kind == "rglru":
        return rglru.init_state(cfg, batch, dtype=dtype)
    raise ValueError(kind)


def init_decode_state(params, cfg: ModelConfig, batch: int, seq_len: int, *,
                      window: int = 0, dtype=jnp.bfloat16,
                      memory: Optional[jnp.ndarray] = None,
                      quantize_kv: bool = False) -> PyTree:
    prefix, unit, n_rep, suffix = pattern_segments(cfg)
    mk = lambda k: _init_block_state(cfg, k, batch, seq_len, window, dtype,
                                     quantize_kv)
    state: dict = {
        "prefix": [mk(k) for k in prefix],
        "scan": [jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (n_rep,) + a.shape)
            .astype(a.dtype), mk(k))
            for k in unit] if n_rep else [],
        "suffix": [mk(k) for k in suffix],
    }
    if cfg.is_encdec:
        if memory is None:
            raise ValueError("enc-dec decode needs encoder memory")
        mk = lambda p: attention.memory_kv(p["cross"], cfg, memory)
        state["memory_kv_prefix"] = [mk(p) for p in params["prefix_layers"]]
        state["memory_kv_scan"] = [jax.vmap(mk)(sp)
                                   for sp in params["scan_blocks"]]
        state["memory_kv_suffix"] = [mk(p) for p in params["suffix_layers"]]
    return state


def _block_decode(p, cfg: ModelConfig, kind: str, layer_idx: int, x, st,
                  memory_kv=None):
    if kind == "rwkv":
        h = _norm(cfg, p["ln1"], x)
        tm_state = {"prev_x": st["prev_x"], "wkv": st["wkv"]}
        mix, tm_state = rwkv.time_mix_decode(p["mixer"], cfg, h, tm_state)
        x = x + mix
        h2 = _norm(cfg, p["ln2"], x)
        ffn_out, new_prev = rwkv.channel_mix_decode(p["ffn"], cfg, h2,
                                                    st["prev_x_ffn"])
        x = x + ffn_out
        return x, {"prev_x": tm_state["prev_x"], "wkv": tm_state["wkv"],
                   "prev_x_ffn": new_prev}

    h = _norm(cfg, p["ln1"], x)
    if kind in ("attn", "local_attn"):
        mix, st = attention.decode_attention(p["mixer"], cfg, h, st)
    elif kind == "mla":
        mix, st = mla.decode_attention(p["mixer"], cfg, h, st)
    elif kind == "rglru":
        mix, st = rglru.rglru_block_decode(p["mixer"], cfg, h, st)
    else:
        raise ValueError(kind)

    if cfg.parallel_block:
        ffn_out, _ = _ffn_apply(p["ffn"], cfg, h, layer_idx)
        return x + mix + ffn_out, st
    x = x + mix
    if memory_kv is not None:
        hc = _norm(cfg, p["ln_cross"], x)
        x = x + attention.cross_attention(p["cross"], cfg, hc, memory_kv)
    h2 = _norm(cfg, p["ln2"], x)
    ffn_out, _ = _ffn_apply(p["ffn"], cfg, h2, layer_idx)
    return x + ffn_out, st


def decode_step(params, cfg: ModelConfig, inputs, state) -> tuple:
    prefix, unit, n_rep, suffix = pattern_segments(cfg)
    x = embed_inputs(params, cfg, inputs)
    new_state = dict(state)

    new_prefix = []
    for i, (p, kind) in enumerate(zip(params["prefix_layers"], prefix)):
        mkv = state.get("memory_kv_prefix", [None] * len(prefix))[i] \
            if cfg.is_encdec else None
        x, st = _block_decode(p, cfg, kind, i, x, state["prefix"][i], mkv)
        new_prefix.append(st)
    new_state["prefix"] = new_prefix

    if n_rep:
        from repro.dist.sharding import constrain_act

        def body(x, inp):
            new_sts = {}
            for j, kind in enumerate(unit):
                mkv = inp.get(f"mkv{j}")
                x, st = _block_decode(inp[f"p{j}"], cfg, kind,
                                      len(prefix) + j, x, inp[f"s{j}"], mkv)
                x = constrain_act(x)
                new_sts[f"s{j}"] = st
            return x, new_sts

        inp = {f"p{j}": sp for j, sp in enumerate(params["scan_blocks"])}
        inp.update({f"s{j}": ss for j, ss in enumerate(state["scan"])})
        if cfg.is_encdec:
            inp.update({f"mkv{j}": m
                        for j, m in enumerate(state["memory_kv_scan"])})
        x, new_scan = jax.lax.scan(body, x, inp)
        new_state["scan"] = [new_scan[f"s{j}"] for j in range(len(unit))]

    off = len(prefix) + n_rep * len(unit)
    new_suffix = []
    for i, (p, kind) in enumerate(zip(params["suffix_layers"], suffix)):
        mkv = state.get("memory_kv_suffix", [None] * len(suffix))[i] \
            if cfg.is_encdec else None
        x, st = _block_decode(p, cfg, kind, off + i, x, state["suffix"][i],
                              mkv)
        new_suffix.append(st)
    new_state["suffix"] = new_suffix

    x = _norm(cfg, params["final_norm"], x)
    return _lm_head(params, cfg, x), new_state
