"""Mixture-of-Experts layer (GShard/Mixtral-style grouped capacity dispatch).

TPU-native design: tokens are split into GROUPS (one group per sequence for
training/prefill, one group for a decode micro-batch); routing within a
group is a dense one-hot dispatch einsum, so expert compute is a single
batched matmul over the expert axis — shardable over the `model` mesh axis
(expert-parallel / expert-ff-parallel) and partitionable over groups on the
`data` axis. Grouping bounds the dispatch tensor at
group_size^2 * top_k * capacity_factor elements per group (the classic
GShard trick); dispatching over the flat global batch would be O(T^2) and
was caught by the dry-run FLOPs audit (EXPERIMENTS.md §Perf).

Router aux (load-balance) loss follows Shazeer/Fedus:
E * sum_e fraction_tokens_e * mean_router_prob_e, averaged over groups.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.common import ModelConfig, MoEConfig

MAX_GROUP = 4096


def moe_init(key, cfg: ModelConfig, *, dtype=jnp.float32):
    mcfg = cfg.moe
    ks = jax.random.split(key, 3 + mcfg.n_shared)
    d, f, e = cfg.d_model, mcfg.d_ff_expert, mcfg.n_experts
    scale = 1.0 / d**0.5
    p = {
        "router": layers.dense_init(ks[0], d, e, dtype=jnp.float32),
        # fused expert banks: (E, d, f) x2 + (E, f, d)
        "w_gate": (jax.random.normal(ks[1], (e, d, f), jnp.float32)
                   * scale).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f), jnp.float32)
                 * scale).astype(dtype),
        "w_down": (jax.random.normal(jax.random.fold_in(ks[2], 1),
                                     (e, f, d), jnp.float32)
                   * (1.0 / f**0.5)).astype(dtype),
    }
    for i in range(mcfg.n_shared):
        p[f"shared_{i}"] = layers.mlp_init(ks[3 + i], d, f, glu=True,
                                           dtype=dtype)
    return p


def _group_shape(n_tokens: int) -> tuple[int, int]:
    """(n_groups, group_size) with group_size <= MAX_GROUP dividing T."""
    g = min(n_tokens, MAX_GROUP)
    while n_tokens % g:
        g -= 1
    return n_tokens // g, g


def _capacity(mcfg: MoEConfig, group_size: int) -> int:
    cap = int(group_size * mcfg.top_k * mcfg.capacity_factor / mcfg.n_experts)
    return max(1, min(group_size, cap))


def moe_apply(p, cfg: ModelConfig, x, *, act: str = "silu"):
    """x: (B, S, d). Returns (out, aux_loss)."""
    mcfg = cfg.moe
    b, s, d = x.shape
    t = b * s
    n_groups, g = _group_shape(t)
    cap = _capacity(mcfg, g)
    e, k = mcfg.n_experts, mcfg.top_k
    xg = x.reshape(n_groups, g, d)

    logits = layers.dense(p["router"], xg.astype(jnp.float32))    # (G,g,E)
    probs = jax.nn.softmax(logits, -1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)                # (G,g,k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    onehot = jax.nn.one_hot(expert_ids, e, dtype=jnp.float32)      # (G,g,k,E)
    # position of each (token, choice) within its expert, choice-major
    flat = onehot.reshape(n_groups, g * k, e)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat).reshape(
        n_groups, g, k, e)
    keep = onehot * (pos_in_expert < cap)
    slot = (pos_in_expert * keep).astype(jnp.int32)

    slot_oh = jax.nn.one_hot(slot, cap, dtype=jnp.float32) * keep[..., None]
    dispatch = slot_oh.sum(2)                                      # (G,g,E,C)
    combine = jnp.einsum("Gtk,GtkEC->GtEC", gate_vals, slot_oh)

    xe = jnp.einsum("Gtd,GtEC->GECd", xg.astype(jnp.float32), dispatch)
    xe = xe.astype(x.dtype)                                        # (G,E,C,d)
    a = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[act]
    h = a(jnp.einsum("GECd,Edf->GECf", xe, p["w_gate"])) * \
        jnp.einsum("GECd,Edf->GECf", xe, p["w_up"])
    ye = jnp.einsum("GECf,Efd->GECd", h, p["w_down"])              # (G,E,C,d)
    out = jnp.einsum("GECd,GtEC->Gtd", ye.astype(jnp.float32), combine)
    out = out.reshape(b, s, d)

    xt = x.reshape(t, d)
    for i in range(mcfg.n_shared):
        out = out + layers.mlp(p[f"shared_{i}"], xt, act=act,
                               glu=True).astype(jnp.float32).reshape(b, s, d)

    # load-balance auxiliary loss (mean over groups)
    frac_tokens = keep.sum((1, 2)) / jnp.maximum(1.0, float(g))    # (G,E)
    mean_prob = probs.mean(1)                                      # (G,E)
    aux = mcfg.router_aux_weight * e * jnp.mean(
        jnp.sum(frac_tokens * mean_prob, -1))
    return out.astype(x.dtype), aux
