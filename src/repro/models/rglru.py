"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Real-Gated Linear Recurrent Unit:
    r_t = sigmoid(W_a x_t)            recurrence gate
    i_t = sigmoid(W_x x_t)            input gate
    log a_t = -c * softplus(Lambda) * r_t        (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The recurrence is elementwise (diagonal), so prefill/training uses
`lax.associative_scan` — O(log S) depth, TPU-parallel — and decode carries a
(B, width) state: O(1) memory, which is why recurrentgemma runs `long_500k`.
Block layout (Griffin "recurrent block"): two branches — GeLU gate, and
conv1d(width 4) -> RG-LRU — multiplied, then projected out.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.common import ModelConfig

RG_C = 8.0


def _width(cfg: ModelConfig) -> int:
    return cfg.rglu_width or cfg.d_model


def rglru_block_init(key, cfg: ModelConfig, *, dtype=jnp.float32):
    d, w = cfg.d_model, _width(cfg)
    ks = jax.random.split(key, 6)
    return {
        "in_gate": layers.dense_init(ks[0], d, w, dtype=dtype),
        "in_rec": layers.dense_init(ks[1], d, w, dtype=dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, w), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "gate_a": layers.dense_init(ks[3], w, w, dtype=dtype),
        "gate_x": layers.dense_init(ks[4], w, w, dtype=dtype),
        # Lambda param: softplus(lam) in ~U[...] so a^c in [0.9, 0.999]
        "lam": jnp.linspace(0.3, 1.5, w).astype(dtype),
        "out": layers.dense_init(ks[5], w, d, dtype=dtype),
    }


def _causal_conv(p, x, *, state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv1d. x: (B,S,W); state: (B,conv_width-1,W)."""
    cw = p["conv_w"].shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    xx = jnp.concatenate([state, x], axis=1)
    out = sum(xx[:, i:i + x.shape[1]] * p["conv_w"][i] for i in range(cw))
    return out + p["conv_b"], xx[:, -(cw - 1):]


def _rglru_coeffs(p, x):
    r = jax.nn.sigmoid(layers.dense(p["gate_a"], x).astype(jnp.float32))
    i = jax.nn.sigmoid(layers.dense(p["gate_x"], x).astype(jnp.float32))
    log_a = -RG_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated_x = i * x.astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated_x
    return a, b


def rglru_scan(a, b, *, h0: Optional[jnp.ndarray] = None):
    """h_t = a_t h_{t-1} + b_t via associative scan. a,b: (B,S,W)."""
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    av, bv = jax.lax.associative_scan(combine, (a, b), axis=1)
    return bv


def rglru_block(p, cfg: ModelConfig, x, *, state=None):
    """Full-sequence recurrent block. Returns (out, new_state)."""
    gate = jax.nn.gelu(layers.dense(p["in_gate"], x))
    rec_in = layers.dense(p["in_rec"], x)
    conv_state = None if state is None else state["conv"]
    h0 = None if state is None else state["h"]
    rec_in, new_conv = _causal_conv(p, rec_in, state=conv_state)
    a, b = _rglru_coeffs(p, rec_in)
    h = rglru_scan(a, b, h0=h0)
    out = layers.dense(p["out"], (h.astype(x.dtype) * gate))
    return out, {"conv": new_conv, "h": h[:, -1]}


def rglru_block_decode(p, cfg: ModelConfig, x, state):
    """One-token step. x: (B,1,d)."""
    gate = jax.nn.gelu(layers.dense(p["in_gate"], x))
    rec_in = layers.dense(p["in_rec"], x)
    rec_in, new_conv = _causal_conv(p, rec_in, state=state["conv"])
    a, b = _rglru_coeffs(p, rec_in)
    h = a[:, 0] * state["h"] + b[:, 0]
    out = layers.dense(p["out"], (h[:, None].astype(x.dtype) * gate))
    return out, {"conv": new_conv, "h": h}


def init_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    w = _width(cfg)
    return {"conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
            "h": jnp.zeros((batch, w), jnp.float32)}
