"""Model zoo: composable layers + the 10 assigned architectures."""
from repro.models import (attention, common, layers, mla, moe, rglru, rwkv,
                          transformer)
from repro.models.common import INPUT_SHAPES, InputShape, ModelConfig

__all__ = ["attention", "common", "layers", "mla", "moe", "rglru", "rwkv",
           "transformer", "ModelConfig", "InputShape", "INPUT_SHAPES"]
