"""RWKV-6 "Finch" time-mix and channel-mix blocks (arXiv:2404.05892).

Attention-free: per-head matrix state S in R^{K x V} with DATA-DEPENDENT
decay w_t (the v6 novelty) and a bonus u for the current token:

    o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T,   w_t = exp(-exp(wd(x_t)))

Training/prefill uses a chunked parallel form (intra-chunk (C,C) matmuls +
inter-chunk state carry) — the same schedule the Pallas kernel
(repro.kernels.wkv6) implements on TPU; this module is its jnp oracle.
Decode carries (S, token-shift tail) as the recurrent cache: O(1) state,
which is why rwkv6 runs `long_500k` natively.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.common import ModelConfig

CHUNK = 64


def _heads(cfg: ModelConfig) -> int:
    return cfg.d_model // cfg.rwkv_head_dim


def time_mix_init(key, cfg: ModelConfig, *, dtype=jnp.float32):
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    h, hd = _heads(cfg), cfg.rwkv_head_dim
    lora = max(32, d // 16)
    return {
        "mu": jnp.full((5, d), 0.5, dtype),      # token-shift mixes r,w,k,v,g
        "r": layers.dense_init(ks[0], d, d, dtype=dtype),
        "k": layers.dense_init(ks[1], d, d, dtype=dtype),
        "v": layers.dense_init(ks[2], d, d, dtype=dtype),
        "g": layers.dense_init(ks[3], d, d, dtype=dtype),
        "o": layers.dense_init(ks[4], d, d, dtype=dtype),
        # data-dependent decay LoRA: w = exp(-exp(w0 + tanh(x A) B))
        "w0": jnp.full((d,), -6.0, dtype),
        "wA": layers.dense_init(ks[5], d, lora, dtype=dtype),
        "wB": (jax.random.normal(ks[6], (lora, d), jnp.float32)
               * 0.01).astype(dtype),
        "u": (jax.random.normal(ks[7], (h, hd), jnp.float32)
              * 0.1).astype(dtype),
        "ln_x": layers.norm_init(d, "layernorm", dtype),  # per-head groupnorm
    }


def channel_mix_init(key, cfg: ModelConfig, *, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return {
        "mu": jnp.full((2, cfg.d_model), 0.5, dtype),
        "k": layers.dense_init(ks[0], cfg.d_model, cfg.d_ff, dtype=dtype),
        "v": layers.dense_init(ks[1], cfg.d_ff, cfg.d_model, dtype=dtype),
        "r": layers.dense_init(ks[2], cfg.d_model, cfg.d_model, dtype=dtype),
    }


def _token_shift(x, prev: Optional[jnp.ndarray]):
    """x: (B,S,d). prev: (B,d) last token of previous segment (or None)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, 0])
    prev = prev.astype(x.dtype)   # recurrent state may be carried in fp32
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def _mix(x, x_prev, mu):
    return x * mu + x_prev * (1.0 - mu)


def _rwkv_projections(p, cfg, x, x_prev):
    """Compute r,k,v,g,log_w from token-shifted inputs."""
    b, s, d = x.shape
    h, hd = _heads(cfg), cfg.rwkv_head_dim
    xr = _mix(x, x_prev, p["mu"][0])
    xw = _mix(x, x_prev, p["mu"][1])
    xk = _mix(x, x_prev, p["mu"][2])
    xv = _mix(x, x_prev, p["mu"][3])
    xg = _mix(x, x_prev, p["mu"][4])
    r = layers.dense(p["r"], xr).reshape(b, s, h, hd)
    k = layers.dense(p["k"], xk).reshape(b, s, h, hd)
    v = layers.dense(p["v"], xv).reshape(b, s, h, hd)
    g = jax.nn.silu(layers.dense(p["g"], xg))
    # log decay in (-inf, 0): log w = -exp(w0 + lora(xw))
    lw = -jnp.exp(p["w0"].astype(jnp.float32)
                  + jnp.tanh(xw.astype(jnp.float32)
                             @ p["wA"]["w"].astype(jnp.float32))
                  @ p["wB"].astype(jnp.float32))
    log_w = lw.reshape(b, s, h, hd)
    return r, k, v, g, log_w


def wkv_chunked(r, k, v, log_w, u, *, chunk: int = CHUNK,
                state0: Optional[jnp.ndarray] = None):
    """Chunked-parallel WKV6 scan (jnp oracle for the Pallas kernel).

    r,k,v,log_w: (B,S,H,K) fp32; u: (H,K). Returns (out (B,S,H,K), state
    (B,H,K,K)). K==V dims here (square state).
    """
    b, s, h, dk = r.shape
    pad = (-s) % chunk
    if pad:
        # padded steps are identity on the state: k = 0, log_w = 0
        zeros = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, v = jnp.pad(r, zeros), jnp.pad(v, zeros)
        k, log_w = jnp.pad(k, zeros), jnp.pad(log_w, zeros)
    s_pad = s + pad
    nc = s_pad // chunk
    rc = r.reshape(b, nc, chunk, h, dk)
    s = s_pad
    kc = k.reshape(b, nc, chunk, h, dk)
    vc = v.reshape(b, nc, chunk, h, dk)
    lwc = log_w.reshape(b, nc, chunk, h, dk)
    if state0 is None:
        state0 = jnp.zeros((b, h, dk, dk), jnp.float32)

    causal = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)  # strict lower

    def per_chunk(state, inputs):
        rc_, kc_, vc_, lwc_ = inputs                 # (B,C,H,K) each
        cum = jnp.cumsum(lwc_, axis=1)               # inclusive cum log decay
        # inter-chunk: q_t attends to state with decay prod_{s<=t-1} w
        decay_in = jnp.exp(cum - lwc_)               # prod up to t-1
        q_in = rc_ * decay_in                        # (B,C,H,K)
        out_inter = jnp.einsum("bchk,bhkv->bchv", q_in, state)
        # intra-chunk pairwise: t attends s<t with decay cum_{t-1}-cum_s
        qd = rc_ * jnp.exp(cum - lwc_)               # (B,C,H,K)
        kd = kc_ * jnp.exp(-cum)                     # (B,C,H,K)
        att = jnp.einsum("bthk,bshk->bhts", qd, kd)  # (B,H,C,C)
        att = jnp.where(causal[None, None], att, 0.0)
        out_intra = jnp.einsum("bhts,bshv->bthv", att, vc_)
        # bonus (current token)
        bonus = jnp.einsum("bchk,hk,bchk->bch", rc_, u, kc_)
        out_bonus = bonus[..., None] * vc_
        # state update: S' = diag(prod w) S + sum_s (prod_{r>s} w ⊙ k_s) v_s^T
        total = cum[:, -1]                           # (B,H,K)
        k_carry = kc_ * jnp.exp(total[:, None] - cum)
        state = (jnp.exp(total)[..., None] * state
                 + jnp.einsum("bshk,bshv->bhkv", k_carry, vc_))
        return state, out_inter + out_intra + out_bonus

    # scan over chunks
    inputs = tuple(jnp.moveaxis(t, 1, 0) for t in (rc, kc, vc, lwc))
    state, out = jax.lax.scan(per_chunk, state0, inputs)
    out = jnp.moveaxis(out, 0, 1).reshape(b, s, h, dk)
    if pad:
        out = out[:, :s - pad]
    return out, state


def wkv_recurrent_step(r, k, v, log_w, u, state):
    """Single-token recurrence (decode). r,k,v,log_w: (B,H,K); state (B,H,K,K)."""
    att = jnp.einsum("bhk,bhkv->bhv", r, state)
    bonus = jnp.einsum("bhk,hk,bhk->bh", r, u, k)[..., None] * v
    new_state = (jnp.exp(log_w)[..., None] * state
                 + jnp.einsum("bhk,bhv->bhkv", k, v))
    return att + bonus, new_state


def time_mix(p, cfg: ModelConfig, x, *, state=None, use_kernel: bool = False):
    """Full-sequence time-mix. state: optional dict(prev_x, wkv) for chunked
    streaming; returns (out, new_state)."""
    b, s, d = x.shape
    h, hd = _heads(cfg), cfg.rwkv_head_dim
    prev_x = None if state is None else state["prev_x"]
    s0 = None if state is None else state["wkv"]
    x_prev = _token_shift(x, prev_x)
    r, k, v, g, log_w = _rwkv_projections(p, cfg, x, x_prev)
    if use_kernel:
        from repro.kernels.wkv6 import ops as wkv_ops
        out, new_s = wkv_ops.wkv6(r.astype(jnp.float32),
                                  k.astype(jnp.float32),
                                  v.astype(jnp.float32), log_w,
                                  p["u"].astype(jnp.float32), state0=s0)
    else:
        out, new_s = wkv_chunked(r.astype(jnp.float32), k.astype(jnp.float32),
                                 v.astype(jnp.float32), log_w,
                                 p["u"].astype(jnp.float32), state0=s0)
    out = out.reshape(b, s, d).astype(x.dtype)
    out = layers.apply_norm(p["ln_x"], out, kind="layernorm", eps=1e-5)
    out = layers.dense(p["o"], out * g)
    return out, {"prev_x": x[:, -1].astype(jnp.float32), "wkv": new_s}


def time_mix_decode(p, cfg: ModelConfig, x, state):
    """One-token decode. x: (B,1,d)."""
    b, _, d = x.shape
    h, hd = _heads(cfg), cfg.rwkv_head_dim
    x_prev = state["prev_x"][:, None].astype(x.dtype)
    r, k, v, g, log_w = _rwkv_projections(p, cfg, x, x_prev)
    out, new_wkv = wkv_recurrent_step(
        r[:, 0].astype(jnp.float32), k[:, 0].astype(jnp.float32),
        v[:, 0].astype(jnp.float32), log_w[:, 0],
        p["u"].astype(jnp.float32), state["wkv"])
    out = out.reshape(b, 1, d).astype(x.dtype)
    out = layers.apply_norm(p["ln_x"], out, kind="layernorm", eps=1e-5)
    out = layers.dense(p["o"], out * g)
    return out, {"prev_x": x[:, 0].astype(jnp.float32), "wkv": new_wkv}


def init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    h, hd = _heads(cfg), cfg.rwkv_head_dim
    return {"prev_x": jnp.zeros((batch, cfg.d_model), dtype),
            "wkv": jnp.zeros((batch, h, hd, hd), jnp.float32)}


def channel_mix(p, cfg: ModelConfig, x, *, prev_x=None):
    x_prev = _token_shift(x, prev_x)
    xk = _mix(x, x_prev, p["mu"][0])
    xr = _mix(x, x_prev, p["mu"][1])
    kk = jnp.square(jax.nn.relu(layers.dense(p["k"], xk)))
    out = jax.nn.sigmoid(layers.dense(p["r"], xr)) * layers.dense(p["v"], kk)
    return out, x[:, -1].astype(jnp.float32)


def channel_mix_decode(p, cfg: ModelConfig, x, prev_x):
    out, new_prev = channel_mix(p, cfg, x, prev_x=prev_x)
    return out, new_prev
