"""Model assembly: heterogeneous block stacks, enc-dec, caches, losses.

One code path serves all 10 assigned architectures; `cfg.block_pattern`
selects the mixer per layer:

  attn        full-causal GQA (dense/moe/vlm families)
  local_attn  sliding-window GQA (recurrentgemma; window = cfg.local_window)
  mla         multi-head latent attention (deepseek-v2)
  rwkv        RWKV6 time-mix (+ its own channel-mix FFN)
  rglru       Griffin RG-LRU recurrent block

FFN position holds a gated MLP, or the MoE layer when cfg.moe is set
(except layers listed in dense_ffn_layers-style overrides — deepseek keeps
layer 0 dense, handled in its config via `moe_skip_layers`).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import attention, layers, mla, moe, rglru, rwkv
from repro.models.common import ModelConfig

PyTree = Any


# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------


def _block_init(key, cfg: ModelConfig, kind: str, layer_idx: int, *,
                cross: bool = False, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    p: dict = {}
    if kind in ("attn", "local_attn"):
        p["ln1"] = layers.norm_init(cfg.d_model, cfg.norm, dtype)
        p["mixer"] = attention.attn_init(ks[0], cfg, dtype=dtype)
    elif kind == "mla":
        p["ln1"] = layers.norm_init(cfg.d_model, cfg.norm, dtype)
        p["mixer"] = mla.mla_init(ks[0], cfg, dtype=dtype)
    elif kind == "rwkv":
        p["ln1"] = layers.norm_init(cfg.d_model, "layernorm", dtype)
        p["mixer"] = rwkv.time_mix_init(ks[0], cfg, dtype=dtype)
        p["ln2"] = layers.norm_init(cfg.d_model, "layernorm", dtype)
        p["ffn"] = rwkv.channel_mix_init(ks[1], cfg, dtype=dtype)
        return p
    elif kind == "rglru":
        p["ln1"] = layers.norm_init(cfg.d_model, cfg.norm, dtype)
        p["mixer"] = rglru.rglru_block_init(ks[0], cfg, dtype=dtype)
    else:
        raise ValueError(f"unknown block kind {kind}")
    if cross:
        p["ln_cross"] = layers.norm_init(cfg.d_model, cfg.norm, dtype)
        p["cross"] = attention.attn_init(ks[2], cfg, dtype=dtype)
    if not cfg.parallel_block:
        p["ln2"] = layers.norm_init(cfg.d_model, cfg.norm, dtype)
    if cfg.moe is not None and not _moe_skipped(cfg, layer_idx):
        p["ffn"] = moe.moe_init(ks[1], cfg, dtype=dtype)
    else:
        p["ffn"] = layers.mlp_init(ks[1], cfg.d_model, cfg.d_ff,
                                   glu=cfg.glu, dtype=dtype)
    return p


def _moe_skipped(cfg: ModelConfig, layer_idx: int) -> bool:
    # DeepSeek-V2 keeps the first layer dense; encoded per-arch via arch_id.
    return cfg.arch_id.startswith("deepseek") and layer_idx == 0


def init(cfg: ModelConfig, key: jax.Array, *, dtype=jnp.float32) -> PyTree:
    n_keys = cfg.n_layers + cfg.n_encoder_layers + 3
    ks = jax.random.split(key, n_keys)
    params: dict = {}
    if cfg.frontend == "token":
        params["embed"] = (jax.random.normal(
            ks[0], (cfg.vocab, cfg.d_model), jnp.float32) * 0.02).astype(dtype)
    else:
        # frontend stub: inputs arrive as embeddings; still need the LM head
        params["embed"] = (jax.random.normal(
            ks[0], (cfg.vocab, cfg.d_model), jnp.float32) * 0.02).astype(dtype)
    params["final_norm"] = layers.norm_init(cfg.d_model, cfg.norm, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.dense_init(ks[1], cfg.d_model, cfg.vocab,
                                              dtype=dtype)
    params["layers"] = [
        _block_init(ks[3 + i], cfg, kind, i, cross=cfg.is_encdec, dtype=dtype)
        for i, kind in enumerate(cfg.block_pattern)]
    if cfg.is_encdec:
        enc_keys = jax.random.split(ks[2], cfg.n_encoder_layers + 1)
        params["encoder"] = {
            "layers": [_block_init(enc_keys[i], cfg, "attn", i, dtype=dtype)
                       for i in range(cfg.n_encoder_layers)],
            "final_norm": layers.norm_init(cfg.d_model, cfg.norm, dtype),
        }
    return params


# --------------------------------------------------------------------------
# Forward (train / prefill)
# --------------------------------------------------------------------------


def _norm(cfg, p, x):
    return layers.apply_norm(p, x, kind=cfg.norm, eps=cfg.norm_eps)


def _positions(cfg: ModelConfig, b: int, s: int, batch) -> jnp.ndarray:
    if "positions3" in batch:
        return batch["positions3"]
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    if cfg.rope_variant == "mrope":
        return layers.text_mrope_positions(pos)
    return pos


def _ffn_apply(p, cfg: ModelConfig, x, layer_idx: int):
    if cfg.moe is not None and not _moe_skipped(cfg, layer_idx) \
            and "router" in p:
        return moe.moe_apply(p, cfg, x, act=cfg.act)
    return layers.mlp(p, x, act=cfg.act, glu=cfg.glu), 0.0


def _block_apply(p, cfg: ModelConfig, kind: str, layer_idx: int, x,
                 positions, *, memory_kv=None, use_flash: bool = False):
    """Returns (x, aux)."""
    aux = 0.0
    if kind == "rwkv":
        mix_out, _ = rwkv.time_mix(p["mixer"], cfg, _norm(cfg, p["ln1"], x),
                                   use_kernel=False)
        x = x + mix_out
        ffn_out, _ = rwkv.channel_mix(p["ffn"], cfg, _norm(cfg, p["ln2"], x))
        return x + ffn_out, aux

    h = _norm(cfg, p["ln1"], x)
    if kind in ("attn", "local_attn"):
        window = cfg.local_window if kind == "local_attn" else 0
        mixer_out = attention.attention(p["mixer"], cfg, h, positions,
                                        causal=True, window=window,
                                        use_flash=use_flash)
    elif kind == "mla":
        mixer_out = mla.mla_attention(p["mixer"], cfg, h, positions)
    elif kind == "rglru":
        mixer_out, _ = rglru.rglru_block(p["mixer"], cfg, h)
    else:
        raise ValueError(kind)

    if cfg.parallel_block:
        ffn_out, aux = _ffn_apply(p["ffn"], cfg, h, layer_idx)
        return x + mixer_out + ffn_out, aux

    x = x + mixer_out
    if memory_kv is not None:
        hc = _norm(cfg, p["ln_cross"], x)
        x = x + attention.cross_attention(p["cross"], cfg, hc, memory_kv)
    h2 = _norm(cfg, p["ln2"], x)
    ffn_out, aux = _ffn_apply(p["ffn"], cfg, h2, layer_idx)
    return x + ffn_out, aux


def embed_inputs(params, cfg: ModelConfig, batch) -> jnp.ndarray:
    from repro.dist.sharding import constrain_act
    if cfg.frontend == "token" or "tokens" in batch:
        x = params["embed"][batch["tokens"]]
    else:
        x = batch["embeddings"]
    if cfg.embed_scale:
        x = x * jnp.sqrt(jnp.asarray(cfg.d_model, jnp.float32)).astype(x.dtype)
    # pin batch sharding: stops XLA propagating the embedding table's FSDP
    # layout into token-replicated activations (see dist/sharding.py)
    return constrain_act(x)


def encode(params, cfg: ModelConfig, src_embeddings) -> jnp.ndarray:
    """Bidirectional encoder over frontend-stub embeddings (audio family)."""
    enc = params["encoder"]
    b, s, _ = src_embeddings.shape
    x = src_embeddings
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    for i, p in enumerate(enc["layers"]):
        h = _norm(cfg, p["ln1"], x)
        attn_out = attention.attention(p["mixer"], cfg, h, pos, causal=False)
        x = x + attn_out
        h2 = _norm(cfg, p["ln2"], x)
        ffn_out, _ = _ffn_apply(p["ffn"], cfg, h2, i)
        x = x + ffn_out
    return _norm(cfg, enc["final_norm"], x)


def apply(params, cfg: ModelConfig, batch, *, use_flash: bool = False,
          remat: bool = False):
    """Full-sequence forward. Returns (logits (B,S,V), aux_loss scalar).

    remat=True checkpoints each block (activation recomputation) — the
    standard memory/compute trade for the big train configs; its effect is
    visible in the dry-run cost_analysis as HLO_FLOPs > MODEL_FLOPS.
    """
    x = embed_inputs(params, cfg, batch)
    b, s, _ = x.shape
    positions = _positions(cfg, b, s, batch)

    memory_kvs = [None] * cfg.n_layers
    if cfg.is_encdec:
        memory = encode(params, cfg, batch["src_embeddings"])
        memory_kvs = [attention.memory_kv(p["cross"], cfg, memory)
                      for p in params["layers"]]

    aux_total = 0.0
    for i, (p, kind) in enumerate(zip(params["layers"], cfg.block_pattern)):
        def block(p_, x_, positions_, mkv_, kind=kind, i=i):
            return _block_apply(p_, cfg, kind, i, x_, positions_,
                                memory_kv=mkv_, use_flash=use_flash)

        if remat:
            block = jax.checkpoint(block)
        x, aux = block(p, x, positions, memory_kvs[i])
        aux_total = aux_total + aux

    x = _norm(cfg, params["final_norm"], x)
    logits = _lm_head(params, cfg, x)
    return logits, aux_total


def _lm_head(params, cfg: ModelConfig, x):
    if cfg.tie_embeddings:
        return x @ params["embed"].T
    return layers.dense(params["lm_head"], x)


def sharded_cross_entropy(logits, labels, *, softcap: float = 0.0):
    """CE that stays partitionable when the vocab dim is 'model'-sharded.

    `take_along_axis` is a gather along vocab, which forces XLA to
    all-gather the full (B,S,V) logits (measured at ~1.2 TB/device/step for
    a 152k vocab at train_4k — EXPERIMENTS.md §Perf iteration 1). The
    max / sum-exp / one-hot-dot formulation keeps every vocab reduction a
    tiny (B,S)-shaped collective instead.
    """
    logits = layers.softcap(logits.astype(jnp.float32), softcap)
    m = jax.lax.stop_gradient(jnp.max(logits, -1, keepdims=True))
    lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(logits - m), -1))
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    label_logit = jnp.sum(logits * onehot, -1)
    nll = lse - label_logit
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1.0)


def loss_fn(params, cfg: ModelConfig, batch, *, use_flash: bool = False,
            remat: bool = False):
    logits, aux = apply(params, cfg, batch, use_flash=use_flash, remat=remat)
    ce = sharded_cross_entropy(logits, batch["labels"],
                               softcap=cfg.logit_softcap)
    return ce + aux


# --------------------------------------------------------------------------
# Decode (serving): per-layer recurrent/KV state, one token per step
# --------------------------------------------------------------------------


def init_decode_state(params, cfg: ModelConfig, batch: int, seq_len: int, *,
                      window: int = 0, dtype=jnp.bfloat16,
                      memory: Optional[jnp.ndarray] = None,
                      quantize_kv: bool = False) -> PyTree:
    """`window` > 0 selects the sliding-window KV cache for attn blocks
    (long_500k configs); local_attn always uses cfg.local_window.
    quantize_kv stores int8 K/V (+fp32 scales): 2x smaller persistent
    serving state."""
    state: list = []
    for kind in cfg.block_pattern:
        if kind == "attn":
            state.append(attention.init_cache(cfg, batch, seq_len,
                                              window=window, dtype=dtype,
                                              quantize=quantize_kv))
        elif kind == "local_attn":
            state.append(attention.init_cache(cfg, batch, seq_len,
                                              window=cfg.local_window,
                                              dtype=dtype,
                                              quantize=quantize_kv))
        elif kind == "mla":
            state.append(mla.init_cache(cfg, batch, seq_len, window=window,
                                        dtype=dtype))
        elif kind == "rwkv":
            st = rwkv.init_state(cfg, batch)
            st["prev_x_ffn"] = jnp.zeros((batch, cfg.d_model), jnp.float32)
            state.append(st)
        elif kind == "rglru":
            state.append(rglru.init_state(cfg, batch, dtype=dtype))
    out = {"layers": state}
    if cfg.is_encdec:
        if memory is None:
            raise ValueError("enc-dec decode needs encoder memory")
        out["memory_kv"] = [attention.memory_kv(p["cross"], cfg, memory)
                            for p in params["layers"]]
    return out


def decode_step(params, cfg: ModelConfig, inputs, state) -> tuple:
    """One token for the whole stack.

    inputs: {"tokens": (B,1)} or {"embeddings": (B,1,d)}.
    Returns (logits (B,1,V), new_state).
    """
    x = embed_inputs(params, cfg, inputs)
    new_layers = []
    for i, (p, kind) in enumerate(zip(params["layers"], cfg.block_pattern)):
        st = state["layers"][i]
        if kind in ("attn", "local_attn"):
            h = _norm(cfg, p["ln1"], x)
            mix, st = attention.decode_attention(p["mixer"], cfg, h, st)
        elif kind == "mla":
            h = _norm(cfg, p["ln1"], x)
            mix, st = mla.decode_attention(p["mixer"], cfg, h, st)
        elif kind == "rglru":
            h = _norm(cfg, p["ln1"], x)
            mix, st = rglru.rglru_block_decode(p["mixer"], cfg, h, st)
        elif kind == "rwkv":
            h = _norm(cfg, p["ln1"], x)
            tm_state = {"prev_x": st["prev_x"], "wkv": st["wkv"]}
            mix, tm_state = rwkv.time_mix_decode(p["mixer"], cfg, h, tm_state)
            x = x + mix
            h2 = _norm(cfg, p["ln2"], x)
            ffn_out, new_prev = rwkv.channel_mix_decode(
                p["ffn"], cfg, h2, st["prev_x_ffn"])
            x = x + ffn_out
            st = {"prev_x": tm_state["prev_x"], "wkv": tm_state["wkv"],
                  "prev_x_ffn": new_prev}
            new_layers.append(st)
            continue
        else:
            raise ValueError(kind)

        if cfg.parallel_block:
            ffn_out, _ = _ffn_apply(p["ffn"], cfg, h, i)
            x = x + mix + ffn_out
        else:
            x = x + mix
            if cfg.is_encdec:
                hc = _norm(cfg, p["ln_cross"], x)
                x = x + attention.cross_attention(p["cross"], cfg, hc,
                                                  state["memory_kv"][i])
            h2 = _norm(cfg, p["ln2"], x)
            ffn_out, _ = _ffn_apply(p["ffn"], cfg, h2, i)
            x = x + ffn_out
        new_layers.append(st)

    x = _norm(cfg, params["final_norm"], x)
    logits = _lm_head(params, cfg, x)
    new_state = dict(state)
    new_state["layers"] = new_layers
    return logits, new_state


# --------------------------------------------------------------------------
# Parameter counting (roofline MODEL_FLOPS = 6 N D uses these)
# --------------------------------------------------------------------------


def count_params(cfg: ModelConfig, *, active_only: bool = False) -> int:
    shapes = jax.eval_shape(
        lambda k: init(cfg, k), jax.random.PRNGKey(0))
    total = sum(int(jnp.prod(jnp.array(l.shape)))
                for l in jax.tree_util.tree_leaves(shapes))
    if not active_only or cfg.moe is None:
        return total
    # subtract inactive routed-expert params
    m = cfg.moe
    per_expert = 3 * cfg.d_model * m.d_ff_expert
    n_moe_layers = sum(1 for i in range(cfg.n_layers)
                       if not _moe_skipped(cfg, i))
    inactive = n_moe_layers * (m.n_experts - m.top_k) * per_expert
    return total - inactive
