"""GQA attention: training/prefill (full or local-windowed) + cached decode.

The jnp path below is the reference implementation (and the oracle for the
Pallas flash kernel in repro.kernels.flash_attn). `use_flash=True` routes
prefill/train through the kernel.

KV caches:
  * full cache: (B, S_max, n_kv, hd) with a write cursor;
  * sliding-window ring cache (Mistral-style) for long-context decode — the
    sub-quadratic variant used by the `long_500k` configs (DESIGN.md §3).
Both store post-RoPE keys, so decode never re-rotates history.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.common import ModelConfig

NEG_INF = -2.0**30


def attn_init(key, cfg: ModelConfig, *, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    return {
        "q": layers.dense_init(ks[0], cfg.d_model, cfg.q_dim,
                               bias=cfg.qkv_bias, dtype=dtype),
        "k": layers.dense_init(ks[1], cfg.d_model, cfg.kv_dim,
                               bias=cfg.qkv_bias, dtype=dtype),
        "v": layers.dense_init(ks[2], cfg.d_model, cfg.kv_dim,
                               bias=cfg.qkv_bias, dtype=dtype),
        "o": layers.dense_init(ks[3], cfg.q_dim, cfg.d_model,
                               bias=cfg.out_bias, dtype=dtype),
    }


def _rotate(cfg: ModelConfig, x, positions):
    if cfg.rope_variant == "rope":
        return layers.apply_rope(x, positions, theta=cfg.rope_theta)
    if cfg.rope_variant == "mrope":
        return layers.apply_mrope(x, positions, theta=cfg.rope_theta,
                                  sections=cfg.mrope_sections)
    return x


def sdpa_reference(q, k, v, mask, *, softcap: float = 0.0):
    """Grouped-query scaled-dot-product attention, fp32 softmax.

    mask: bool, broadcastable to (B, Sq, Sk); True = attend.
    """
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    group = hq // hkv
    qg = q.reshape(b, sq, hkv, group, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(d)
    logits = layers.softcap(logits, softcap)
    m = jnp.broadcast_to(mask[:, None, None], logits.shape) if mask.ndim == 3 \
        else mask
    logits = jnp.where(m, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v.astype(jnp.float32))
    return out.reshape(b, sq, hq, d).astype(q.dtype)


CHUNKED_THRESHOLD = 4096   # switch to q-chunked attention at/above this S
Q_CHUNK = 1024


def chunked_sdpa(q, k, v, *, causal: bool, window: int, softcap: float,
                 q_chunk: int = Q_CHUNK) -> jnp.ndarray:
    """Memory-bounded attention: scan over query chunks (XLA-level flash
    analog — exact softmax per chunk over all keys, O(q_chunk * S) logits).

    Shapes: q (B,S,H,D) with FULL q heads; k/v (B,S,H,D) already repeated
    to q-head count so the head dim shards cleanly over 'model' even for
    ragged head counts (XLA pads 40 heads over 16 shards). Replaces the
    full-S^2 reference at long sequence lengths, where the materialized
    (B,H,S,S) logits were measured at 40 GiB/device and the ragged-head
    partial-sum all-reduces at ~2 TB/device/step (EXPERIMENTS.md §Perf
    iteration 2).
    """
    b, s, h, d = q.shape
    scale = 1.0 / math.sqrt(d)
    n_chunks = s // q_chunk
    assert s % q_chunk == 0, f"seq {s} % q_chunk {q_chunk} != 0"
    qt = jnp.moveaxis(q, 1, 2)                    # (B,H,S,D)
    kt = jnp.moveaxis(k, 1, 2)
    vt = jnp.moveaxis(v, 1, 2)
    qc = qt.reshape(b, h, n_chunks, q_chunk, d)
    kj = jnp.arange(s)

    def one_chunk(ci):
        qb = qc[:, :, ci]                         # (B,H,C,D)
        logits = jnp.einsum("bhqd,bhkd->bhqk", qb.astype(jnp.float32),
                            kt.astype(jnp.float32)) * scale
        logits = layers.softcap(logits, softcap)
        qi = ci * q_chunk + jnp.arange(q_chunk)
        m = jnp.ones((q_chunk, s), bool)
        if causal:
            m &= kj[None, :] <= qi[:, None]
        if window > 0:
            m &= kj[None, :] > qi[:, None] - window
        logits = jnp.where(m[None, None], logits, NEG_INF)
        w = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bhqk,bhkd->bhqd", w, vt.astype(jnp.float32))

    out = jax.lax.map(one_chunk, jnp.arange(n_chunks))   # (N,B,H,C,D)
    out = jnp.moveaxis(out, 0, 2).reshape(b, h, s, d)
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)       # (B,S,H,D)


def make_mask(sq: int, sk: int, *, causal: bool, window: int = 0,
              q_offset: int = 0) -> jnp.ndarray:
    """(sq, sk) bool mask; q position i attends to k position j."""
    qi = jnp.arange(sq)[:, None] + q_offset
    kj = jnp.arange(sk)[None, :]
    m = jnp.ones((sq, sk), bool)
    if causal:
        m &= kj <= qi
    if window > 0:
        m &= kj > qi - window
    return m


def attention(p, cfg: ModelConfig, x, positions, *, causal: bool = True,
              window: int = 0, use_flash: bool = False):
    """Train/prefill path. x: (B,S,d); positions: (B,S) or (B,3,S) mrope.

    Backend selection: the Pallas flash kernel on TPU (use_flash), the
    q-chunked exact path for long sequences (memory-bounded, shardable),
    or the full-S^2 reference for short sequences (also the oracle).
    """
    b, s, _ = x.shape
    q = layers.dense(p["q"], x).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = layers.dense(p["k"], x).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = layers.dense(p["v"], x).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    q = _rotate(cfg, q, positions)
    k = _rotate(cfg, k, positions)
    if use_flash:
        from repro.kernels.flash_attn import ops as flash_ops
        out = flash_ops.flash_attention(q, k, v, causal=causal, window=window,
                                        softcap=cfg.logit_softcap)
    elif s >= CHUNKED_THRESHOLD and s % Q_CHUNK == 0:
        from repro.dist.sharding import constrain_heads
        group = cfg.n_heads // cfg.n_kv_heads
        kf = jnp.repeat(k, group, axis=2)   # full q-head kv: clean sharding
        vf = jnp.repeat(v, group, axis=2)
        q, kf, vf = (constrain_heads(t) for t in (q, kf, vf))
        out = chunked_sdpa(q, kf, vf, causal=causal, window=window,
                           softcap=cfg.logit_softcap)
    else:
        mask = make_mask(s, s, causal=causal, window=window)[None]
        out = sdpa_reference(q, k, v, mask, softcap=cfg.logit_softcap)
    return layers.dense(p["o"], out.reshape(b, s, cfg.q_dim))


def cross_attention(p, cfg: ModelConfig, x, memory_kv):
    """Enc-dec cross attention; memory_kv = (k, v) precomputed from encoder."""
    b, s, _ = x.shape
    q = layers.dense(p["q"], x).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k, v = memory_kv
    mask = jnp.ones((1, s, k.shape[1]), bool)
    out = sdpa_reference(q, k, v, mask, softcap=cfg.logit_softcap)
    return layers.dense(p["o"], out.reshape(b, s, cfg.q_dim))


def memory_kv(p, cfg: ModelConfig, memory):
    """Precompute cross-attention K/V from encoder output (no RoPE)."""
    b, s, _ = memory.shape
    k = layers.dense(p["k"], memory).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = layers.dense(p["v"], memory).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    return k, v


# --------------------------------------------------------------------------
# KV caches
# --------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, *,
               window: int = 0, dtype=jnp.bfloat16,
               quantize: bool = False):
    """window > 0 -> ring buffer of `window` slots; else full seq_len.

    quantize=True stores int8 K/V with a per-(slot, head) fp32 scale —
    the paper's quantization idea (Section 3.1.1) applied to the serving
    memory bottleneck: 2x smaller persistent KV state at <1% attention
    error (EXPERIMENTS.md §Perf iteration 9).
    """
    slots = min(window, seq_len) if window > 0 else seq_len
    shape = (batch, slots, cfg.n_kv_heads, cfg.head_dim)
    cache = {
        # absolute position currently held by each slot (-1 = empty)
        "slot_pos": jnp.full((batch, slots), -1, jnp.int32),
        "cursor": jnp.zeros((), jnp.int32),   # next absolute position
        "window": jnp.asarray(window if window > 0 else 0, jnp.int32),
    }
    if quantize:
        cache["k"] = jnp.zeros(shape, jnp.int8)
        cache["v"] = jnp.zeros(shape, jnp.int8)
        cache["k_scale"] = jnp.zeros(shape[:3] + (1,), jnp.float32)
        cache["v_scale"] = jnp.zeros(shape[:3] + (1,), jnp.float32)
    else:
        cache["k"] = jnp.zeros(shape, dtype)
        cache["v"] = jnp.zeros(shape, dtype)
    return cache


def _quantize_kv(kv: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(B,1,H,D) -> int8 codes + per-(slot,head) scale (symmetric max-abs)."""
    scale = jnp.max(jnp.abs(kv.astype(jnp.float32)), -1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(kv.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def _dequantize_kv(codes: jnp.ndarray, scale: jnp.ndarray, dtype):
    return (codes.astype(jnp.float32) * scale).astype(dtype)


def decode_attention(p, cfg: ModelConfig, x, cache):
    """One-token decode. x: (B,1,d). Returns (out, new_cache)."""
    b = x.shape[0]
    pos = cache["cursor"]                                   # scalar abs pos
    positions = jnp.full((b, 1), pos, jnp.int32)
    if cfg.rope_variant == "mrope":
        positions = layers.text_mrope_positions(positions)
    q = layers.dense(p["q"], x).reshape(b, 1, cfg.n_heads, cfg.head_dim)
    k = layers.dense(p["k"], x).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
    v = layers.dense(p["v"], x).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
    q = _rotate(cfg, q, positions)
    k = _rotate(cfg, k, positions)

    slots = cache["k"].shape[1]
    slot = jnp.where(cache["window"] > 0, pos % slots,
                     jnp.minimum(pos, slots - 1)).astype(jnp.int32)
    quantized = "k_scale" in cache
    new_cache = dict(cache)
    if quantized:
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        ck = _write_slot(cache["k"], kq, slot)
        cv = _write_slot(cache["v"], vq, slot)
        new_cache["k_scale"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k_scale"], ks, slot, axis=1)
        new_cache["v_scale"] = jax.lax.dynamic_update_slice_in_dim(
            cache["v_scale"], vs, slot, axis=1)
        k_eff = _dequantize_kv(ck, new_cache["k_scale"], q.dtype)
        v_eff = _dequantize_kv(cv, new_cache["v_scale"], q.dtype)
    else:
        ck = _write_slot(cache["k"], k, slot)
        cv = _write_slot(cache["v"], v, slot)
        k_eff = ck.astype(q.dtype)
        v_eff = cv.astype(q.dtype)
    spos = cache["slot_pos"].at[:, slot].set(pos)

    # valid slots: filled AND (no window OR within window of pos)
    valid = spos >= 0
    valid &= jnp.where(cache["window"] > 0, spos > pos - cache["window"], True)
    mask = valid[:, None, :]                                # (B,1,slots)
    out = sdpa_reference(q, k_eff, v_eff, mask, softcap=cfg.logit_softcap)
    new_cache.update({"k": ck, "v": cv, "slot_pos": spos,
                      "cursor": pos + 1})
    return layers.dense(p["o"], out.reshape(b, 1, cfg.q_dim)), new_cache


def _write_slot(buf, kv, slot):
    return jax.lax.dynamic_update_slice_in_dim(
        buf, kv.astype(buf.dtype), slot, axis=1)


def prefill_cache(cfg: ModelConfig, cache, k, v, positions):
    """Bulk-write prefill K/V (already rotated) into a fresh cache."""
    s = k.shape[1]
    slots = cache["k"].shape[1]
    if s > slots:  # windowed cache: keep the tail
        k, v = k[:, -slots:], v[:, -slots:]
        positions = positions[:, -slots:]
        s = slots
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"],
                                             k.astype(cache["k"].dtype), 0, 1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"],
                                             v.astype(cache["v"].dtype), 0, 1)
    spos = cache["slot_pos"].at[:, :s].set(positions)
    return {"k": ck, "v": cv, "slot_pos": spos,
            "cursor": jnp.asarray(positions[0, -1] + 1, jnp.int32),
            "window": cache["window"]}
