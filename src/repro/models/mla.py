"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

K/V are compressed to a shared latent c_kv of rank `kv_lora_rank`; queries
split into a no-RoPE part (against up-projected keys) and a RoPE part
(against a single shared rotary key). The decode cache stores ONLY
(c_kv, k_rope) — the paper's KV-memory reduction — and decodes via the
"absorbed" matmul trick (latent-space attention) so per-step FLOPs stay
O(rank) instead of O(heads * head_dim).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.common import ModelConfig

NEG_INF = -2.0**30


def mla_init(key, cfg: ModelConfig, *, dtype=jnp.float32):
    m = cfg.mla
    h = cfg.n_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "q_proj": layers.dense_init(ks[0], cfg.d_model, h * qk_dim,
                                    dtype=dtype),
        "kv_down": layers.dense_init(ks[1], cfg.d_model,
                                     m.kv_lora_rank + m.qk_rope_head_dim,
                                     dtype=dtype),
        "kv_norm": layers.norm_init(m.kv_lora_rank, "rmsnorm", dtype),
        "k_up": layers.dense_init(ks[2], m.kv_lora_rank,
                                  h * m.qk_nope_head_dim, dtype=dtype),
        "v_up": layers.dense_init(ks[3], m.kv_lora_rank,
                                  h * m.v_head_dim, dtype=dtype),
        "o": layers.dense_init(ks[4], h * m.v_head_dim, cfg.d_model,
                               dtype=dtype),
    }


def _split_kv_down(cfg: ModelConfig, kvd):
    m = cfg.mla
    c_kv, k_rope = kvd[..., :m.kv_lora_rank], kvd[..., m.kv_lora_rank:]
    return c_kv, k_rope


def mla_attention(p, cfg: ModelConfig, x, positions, *, causal: bool = True):
    """Train/prefill path. x: (B,S,d)."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim

    q = layers.dense(p["q_proj"], x).reshape(b, s, h, qk_dim)
    q_nope, q_rope = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_rope = layers.apply_rope(q_rope, positions, theta=cfg.rope_theta)

    kvd = layers.dense(p["kv_down"], x)
    c_kv, k_rope = _split_kv_down(cfg, kvd)
    c_kv = layers.apply_norm(p["kv_norm"], c_kv, kind="rmsnorm",
                             eps=cfg.norm_eps)
    k_rope = layers.apply_rope(k_rope[:, :, None], positions,
                               theta=cfg.rope_theta)          # (B,S,1,Dr)
    k_nope = layers.dense(p["k_up"], c_kv).reshape(b, s, h, m.qk_nope_head_dim)
    v = layers.dense(p["v_up"], c_kv).reshape(b, s, h, m.v_head_dim)

    scale = 1.0 / math.sqrt(qk_dim)
    logits = (jnp.einsum("bqhd,bkhd->bhqk", q_nope.astype(jnp.float32),
                         k_nope.astype(jnp.float32))
              + jnp.einsum("bqhd,bkxd->bhqk", q_rope.astype(jnp.float32),
                           k_rope.astype(jnp.float32))) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, -1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32))
    out = out.reshape(b, s, h * m.v_head_dim).astype(x.dtype)
    return layers.dense(p["o"], out)


# --------------------------------------------------------------------------
# Cached decode: latent-space ("absorbed") attention over (c_kv, k_rope)
# --------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, *,
               window: int = 0, dtype=jnp.bfloat16):
    m = cfg.mla
    slots = min(window, seq_len) if window > 0 else seq_len
    return {
        "c_kv": jnp.zeros((batch, slots, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, slots, m.qk_rope_head_dim), dtype),
        "slot_pos": jnp.full((batch, slots), -1, jnp.int32),
        "cursor": jnp.zeros((), jnp.int32),
        "window": jnp.asarray(window if window > 0 else 0, jnp.int32),
    }


def decode_attention(p, cfg: ModelConfig, x, cache):
    """One-token decode with the latent cache. x: (B,1,d)."""
    m = cfg.mla
    b = x.shape[0]
    h = cfg.n_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    pos = cache["cursor"]
    positions = jnp.full((b, 1), pos, jnp.int32)

    q = layers.dense(p["q_proj"], x).reshape(b, 1, h, qk_dim)
    q_nope, q_rope = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_rope = layers.apply_rope(q_rope, positions, theta=cfg.rope_theta)

    kvd = layers.dense(p["kv_down"], x)
    c_new, kr_new = _split_kv_down(cfg, kvd)
    c_new = layers.apply_norm(p["kv_norm"], c_new, kind="rmsnorm",
                              eps=cfg.norm_eps)
    kr_new = layers.apply_rope(kr_new[:, :, None], positions,
                               theta=cfg.rope_theta)[:, :, 0]

    slots = cache["c_kv"].shape[1]
    slot = jnp.where(cache["window"] > 0, pos % slots,
                     jnp.minimum(pos, slots - 1)).astype(jnp.int32)
    c_kv = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), slot, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), slot, axis=1)
    spos = cache["slot_pos"].at[:, slot].set(pos)

    # absorbed attention: project q_nope into latent space via k_up^T
    w_kup = p["k_up"]["w"].reshape(m.kv_lora_rank, h, m.qk_nope_head_dim)
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope.astype(jnp.float32),
                       w_kup.astype(jnp.float32))             # (B,1,H,rank)
    scale = 1.0 / math.sqrt(qk_dim)
    logits = (jnp.einsum("bqhr,bkr->bhqk", q_lat,
                         c_kv.astype(jnp.float32))
              + jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(jnp.float32),
                           k_rope.astype(jnp.float32))) * scale
    valid = spos >= 0
    valid &= jnp.where(cache["window"] > 0, spos > pos - cache["window"], True)
    logits = jnp.where(valid[:, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, -1)
    # attend in latent space, then up-project once per step
    ctx_lat = jnp.einsum("bhqk,bkr->bqhr", w, c_kv.astype(jnp.float32))
    w_vup = p["v_up"]["w"].reshape(m.kv_lora_rank, h, m.v_head_dim)
    out = jnp.einsum("bqhr,rhd->bqhd", ctx_lat, w_vup.astype(jnp.float32))
    out = out.reshape(b, 1, h * m.v_head_dim).astype(x.dtype)
    new_cache = {"c_kv": c_kv, "k_rope": k_rope, "slot_pos": spos,
                 "cursor": pos + 1, "window": cache["window"]}
    return layers.dense(p["o"], out), new_cache
