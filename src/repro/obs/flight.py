"""Flight recorder: a bounded ring buffer of recent telemetry events,
dumped to disk when something goes wrong.

While the flight switch is on, instrumentation points push small dict
events (``flight.record("scheduler.round", protocol=..., r=...)``) into
a ``deque(maxlen=capacity)``; nothing is written anywhere in the happy
path. Two failure hooks dump the buffer as JSON:

  * ``faults.validate`` dumps on a fault-ledger/wire-ledger mismatch
    (the forged-ledger class of bug) before re-raising;
  * ``@flight.guarded("scheduler.<proto>")`` wraps every scheduler
    entry point and dumps on any uncaught exception.

Dumps land in ``REPRO_OBS_DIR`` (default: the current directory) as
``flight_<scope>.json`` with the failure reason, the run identity
(``repro.obs.runinfo.run_id``), and the buffered events in order —
cross-referenceable with BENCH rows and exported timelines through the
shared ``run_id``.

``kernel_scope(name)`` is the jax-profiler annotation hook for the
bucketed Pallas kernels: ``jax.named_scope`` when tracing is enabled
(names show up in ``jax.profiler`` traces and HLO metadata), a no-op
nullcontext otherwise. jax is imported lazily so the obs package stays
importable without it.
"""
from __future__ import annotations

import contextlib
import functools
import itertools
import json
import os
import threading
from collections import deque
from typing import Optional

from repro.obs import state

DEFAULT_CAPACITY = 4096


class FlightRecorder:
    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        self._lock = threading.Lock()
        self._buf: deque = deque(maxlen=capacity)
        self._seq = itertools.count()

    @property
    def capacity(self) -> int:
        return self._buf.maxlen

    def set_capacity(self, capacity: int) -> None:
        with self._lock:
            self._buf = deque(self._buf, maxlen=capacity)

    def record(self, kind: str, **fields) -> None:
        """Push one event (no-op unless the flight switch is on)."""
        if not state.enabled("flight"):
            return
        with self._lock:
            self._buf.append({"seq": next(self._seq), "kind": kind,
                              **fields})

    def snapshot(self) -> list:
        with self._lock:
            return list(self._buf)

    def reset(self) -> None:
        with self._lock:
            self._buf.clear()
            self._seq = itertools.count()

    def dump(self, *, reason: str, scope: str = "obs",
             path: Optional[str] = None) -> str:
        """Write the buffer (+ run identity) as JSON; returns the path."""
        from repro.obs import runinfo

        if path is None:
            out_dir = os.environ.get("REPRO_OBS_DIR", ".")
            os.makedirs(out_dir, exist_ok=True)
            safe = scope.replace("/", "_").replace(".", "_")
            path = os.path.join(out_dir, f"flight_{safe}.json")
        payload = {"reason": reason, "scope": scope,
                   "run_id": runinfo.run_id(),
                   "schema_version": runinfo.SCHEMA_VERSION,
                   "n_events": len(self._buf),
                   "capacity": self.capacity,
                   "events": self.snapshot()}
        with open(path, "w") as f:
            json.dump(payload, f, indent=1, default=str)
            f.write("\n")
        return path


_RECORDER = FlightRecorder()


def recorder() -> FlightRecorder:
    return _RECORDER


def record(kind: str, **fields) -> None:
    _RECORDER.record(kind, **fields)


def reset() -> None:
    _RECORDER.reset()


def dump_on_failure(scope: str, reason: str) -> Optional[str]:
    """Failure hook: dump the ring buffer if flight recording is on
    (nothing was buffered otherwise). Never raises — this runs on the
    way OUT of a failing assert, and must not mask it."""
    if not state.enabled("flight"):
        return None
    try:
        path = _RECORDER.dump(reason=reason, scope=scope)
    except OSError:
        return None
    return path


def guarded(scope: str):
    """Decorator: dump the flight buffer on any uncaught exception from
    the wrapped function (the scheduler entry points use this), then
    re-raise unchanged."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            try:
                return fn(*args, **kwargs)
            except Exception as e:                 # noqa: BLE001
                dump_on_failure(scope, f"{type(e).__name__}: {e}")
                raise
        return wrapper
    return deco


def kernel_scope(name: str):
    """``jax.named_scope`` around a kernel call when tracing is on —
    the annotation shows up in jax.profiler timelines and in the lowered
    HLO's metadata — else a free nullcontext."""
    if not state.enabled("trace"):
        return contextlib.nullcontext()
    import jax

    return jax.named_scope(name)


def kernel_annotation(name: str):
    """Decorator form of ``kernel_scope`` for jitted kernel entry points.

    Stack it UNDER ``jax.jit`` so the scope is open while the function
    traces (names land in the lowered HLO / jax.profiler timeline) and
    costs nothing on cached executions — the wrapper body only runs at
    trace time."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            with kernel_scope(name):
                return fn(*args, **kwargs)
        return wrapper
    return deco
