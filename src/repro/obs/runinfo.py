"""Run identity: every emitted artifact (BENCH_*.json rows, exported
timelines, flight-recorder dumps) carries the same ``run_id`` — the git
SHA of the working tree plus the seed — and a ``schema_version``, so
benches, traces, and dumps from one run cross-reference exactly.

``stamp_rows`` is what the benchmark writers call right before
``json.dump``; ``bench_delta`` excludes both fields from metric
comparison (identity, not measurement).
"""
from __future__ import annotations

import functools
import subprocess

# bump when the shape of BENCH rows / flight dumps / timeline args
# changes incompatibly
SCHEMA_VERSION = 2


@functools.lru_cache(maxsize=1)
def git_sha(short: bool = True) -> str:
    """Current git SHA (short by default); 'nogit' outside a checkout."""
    cmd = ["git", "rev-parse"] + (["--short"] if short else []) + ["HEAD"]
    try:
        out = subprocess.run(cmd, capture_output=True, text=True,
                             timeout=5, check=False)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "nogit"
    except (OSError, subprocess.TimeoutExpired):
        return "nogit"


def run_id(seed: int = 0) -> str:
    return f"{git_sha()}-s{seed}"


def stamp_rows(rows: list, *, seed: int = 0) -> list:
    """Add run_id + schema_version to every row dict, in place."""
    rid = run_id(seed)
    for row in rows:
        row["run_id"] = rid
        row["schema_version"] = SCHEMA_VERSION
    return rows
