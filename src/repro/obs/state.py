"""Master switches for the telemetry tier.

Everything in ``repro.obs`` is OFF by default: every instrumentation
point in the stack guards itself with ``state.enabled(kind)``, which is
one dict lookup on a module-level dict — the measured overhead budget
(<2% on ``cluster_bench --smoke``, gated in CI) is spent here, so this
module must stay dependency-free and branch-cheap.

Kinds:
  trace    span/event tracer (repro.obs.trace) + jax.named_scope kernel
           annotations
  metrics  counters/gauges/histograms (repro.obs.metrics)
  flight   bounded ring buffer of recent events (repro.obs.flight)

``REPRO_OBS=1`` in the environment enables all three at import time
(the CI tracing job uses exactly this). ``REPRO_OBS=trace,metrics``
enables a subset.
"""
from __future__ import annotations

import os

_KINDS = ("trace", "metrics", "flight")
_ON = {k: False for k in _KINDS}


def enable(*, trace: bool = True, metrics: bool = True,
           flight: bool = True) -> None:
    """Turn telemetry kinds on (all three by default)."""
    if trace:
        _ON["trace"] = True
    if metrics:
        _ON["metrics"] = True
    if flight:
        _ON["flight"] = True


def disable() -> None:
    """Turn every telemetry kind off (the default state)."""
    for k in _KINDS:
        _ON[k] = False


def enabled(kind: str = "trace") -> bool:
    """Is this telemetry kind on? The single hot-path check every
    instrumentation point performs."""
    return _ON[kind]


def any_enabled() -> bool:
    return any(_ON.values())


def _from_env() -> None:
    val = os.environ.get("REPRO_OBS", "").strip()
    if not val or val == "0":
        return
    if val == "1" or val.lower() in ("all", "true", "on"):
        enable()
        return
    kinds = {k.strip() for k in val.split(",")}
    enable(trace="trace" in kinds, metrics="metrics" in kinds,
           flight="flight" in kinds)


_from_env()
