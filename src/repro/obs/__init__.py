"""Unified telemetry tier: structured tracing, metrics, flight recorder.

Zero-dependency (stdlib-only core; jax touched lazily and only for
``named_scope`` annotations), off by default, threaded through every
layer of the stack:

  state.py    master switches (``REPRO_OBS=1`` env or ``obs.enable()``)
  trace.py    span/event tracer -> Chrome-trace/Perfetto JSON; renders
              scheduler Traces as per-worker tracks (compute, uplink,
              downlink, gossip, faults) with exact ledger accounting
  metrics.py  counters/gauges/histograms with named scopes (wire bytes
              by codec tier, staleness distributions, retry/drop/dup/
              quorum counts, per-bucket quant range)
  flight.py   bounded ring buffer of recent events, dumped to disk on
              fault-ledger validation failure or uncaught scheduler
              exception; jax.named_scope hooks for the Pallas kernels
  runinfo.py  run_id (git SHA + seed) + schema version stamped on every
              BENCH row, timeline, and flight dump
  export.py   ``python -m repro.obs.export trace`` — openable timeline

Instrumentation contract: every call site guards on ``obs.enabled(...)``
(one dict lookup when off); values inside ``jit`` are never recorded at
trace time — they ride out as auxiliary outputs and are observed on the
host (``metrics.observe_array`` skips tracers).
"""
from repro.obs.flight import (kernel_scope, record as flight_record,
                              recorder as flight_recorder)
from repro.obs.metrics import (counter, gauge, histogram, observe_array,
                               registry as metrics_registry)
from repro.obs.runinfo import SCHEMA_VERSION, run_id, stamp_rows
from repro.obs.state import disable, enable, enabled
from repro.obs.trace import span, timeline_from_trace, tracer

__all__ = [
    "SCHEMA_VERSION", "counter", "disable", "enable", "enabled",
    "flight_record", "flight_recorder", "gauge", "histogram",
    "kernel_scope", "metrics_registry", "observe_array", "run_id",
    "span", "stamp_rows", "timeline_from_trace", "tracer",
]
