"""Span/event tracer emitting Chrome-trace / Perfetto JSON timelines.

Two clocks, one event stream:

  * **wall spans** (``Tracer.span`` context manager) — host-side phases
    (a replay, a benchmark row, an export) timed on the monotonic clock;
  * **sim spans / instants** (``Tracer.sim_span`` / ``Tracer.instant``)
    — events at explicit *simulated* times, the currency of the cluster
    scheduler: every span carries the worker (``PS = -1`` is the
    server) and a ``lane`` string, and the tracer assigns one Perfetto
    process per worker with one thread per lane, so the exported JSON
    opens as per-worker tracks in https://ui.perfetto.dev.

``timeline_from_trace`` renders a scheduler ``Trace`` post-hoc from its
ledgers alone — deterministically, with an exact accounting contract:

  * ONE complete ('X') span per ``Delivery`` in ``trace.comm``, on the
    worker-side endpoint's track (uplink: sender; downlink: receiver;
    gossip: sender), ``cat = "wire,<direction>,<status>"`` — so
    ok+lost+dup+corrupted wire spans == the wire ledger, mirroring
    ``faults.validate``;
  * ONE instant per ``TraceEvent`` (updates/barriers/rejoins) and per
    fault-ledger record (drops, retries, dups, corruptions, shortfalls,
    epochs, lost compute), plus one 'X' quorum-wait span per ``TimeoutRecord``
    (the late arrival's [cut, arrival] window).

Those counts are asserted by ``repro.obs.export`` at export time and by
tests/test_obs.py, so a timeline can never silently disagree with the
ledgers it renders. Live scheduler instrumentation (compute spans) adds
rows to the same tracks when tracing is enabled during scheduling.

Sim seconds are exported as microseconds (ts = t * 1e6); wall spans use
microseconds since the tracer's first event. Zero dependencies beyond
the stdlib.
"""
from __future__ import annotations

import json
import threading
import time
from contextlib import contextmanager
from typing import Any, Optional

from repro.obs import state

PS = -1              # symbolic server id, matching repro.cluster.scheduler
HOST = -2            # the host process (wall-clock spans)

# stable pids: host = 1, server = 10, worker w = 100 + w
_HOST_PID = 1
_PS_PID = 10
_WORKER_PID0 = 100

# lane -> tid, one per track kind; unknown lanes get allocated past these
_LANES = ("compute", "uplink", "downlink", "gossip", "faults", "host")


def _pid(worker: int) -> int:
    if worker == HOST:
        return _HOST_PID
    if worker == PS:
        return _PS_PID
    return _WORKER_PID0 + worker


def _process_name(worker: int) -> str:
    if worker == HOST:
        return "host"
    if worker == PS:
        return "server (PS)"
    return f"worker {worker}"


class Tracer:
    """An append-only event buffer with Chrome-trace export."""

    def __init__(self):
        self._lock = threading.Lock()
        self._events: list[dict] = []
        self._tracks: dict = {}      # (worker, lane) -> tid
        self._t0_ns: Optional[int] = None

    # -- recording --------------------------------------------------------

    def _tid(self, worker: int, lane: str) -> int:
        key = (worker, lane)
        tid = self._tracks.get(key)
        if tid is None:
            tid = (_LANES.index(lane) if lane in _LANES
                   else len(_LANES) + sum(1 for (_, ln) in self._tracks
                                          if ln not in _LANES))
            self._tracks[key] = tid
        return tid

    def _append(self, ev: dict) -> None:
        with self._lock:
            self._events.append(ev)

    def sim_span(self, name: str, *, worker: int, lane: str, t0: float,
                 t1: float, cat: str = "", args: Optional[dict] = None
                 ) -> None:
        """A complete span at explicit simulated times (seconds)."""
        self._append({"name": name, "cat": cat or lane, "ph": "X",
                      "ts": t0 * 1e6, "dur": max(t1 - t0, 0.0) * 1e6,
                      "pid": _pid(worker), "tid": self._tid(worker, lane),
                      "args": args or {}})

    def instant(self, name: str, *, worker: int, lane: str, t: float,
                cat: str = "", args: Optional[dict] = None) -> None:
        """A zero-duration marker at an explicit simulated time."""
        self._append({"name": name, "cat": cat or lane, "ph": "i",
                      "ts": t * 1e6, "s": "t", "pid": _pid(worker),
                      "tid": self._tid(worker, lane),
                      "args": args or {}})

    def sim_counter(self, name: str, *, worker: int, t: float,
                    values: dict) -> None:
        """A Perfetto counter track sample at a simulated time."""
        self._append({"name": name, "ph": "C", "ts": t * 1e6,
                      "pid": _pid(worker), "args": dict(values)})

    @contextmanager
    def span(self, name: str, *, cat: str = "host",
             args: Optional[dict] = None):
        """Wall-clock span on the host track (monotonic clock); records
        only if tracing is enabled at entry."""
        if not state.enabled("trace"):
            yield
            return
        if self._t0_ns is None:
            self._t0_ns = time.perf_counter_ns()
        t0 = time.perf_counter_ns()
        try:
            yield
        finally:
            t1 = time.perf_counter_ns()
            self._append({"name": name, "cat": cat, "ph": "X",
                          "ts": (t0 - self._t0_ns) / 1e3,
                          "dur": (t1 - t0) / 1e3, "pid": _pid(HOST),
                          "tid": self._tid(HOST, "host"),
                          "args": args or {}})

    # -- export -----------------------------------------------------------

    def _metadata(self) -> list[dict]:
        meta = []
        for worker in sorted({w for (w, _) in self._tracks}):
            meta.append({"name": "process_name", "ph": "M",
                         "pid": _pid(worker),
                         "args": {"name": _process_name(worker)}})
        for (worker, lane), tid in sorted(self._tracks.items()):
            meta.append({"name": "thread_name", "ph": "M",
                         "pid": _pid(worker), "tid": tid,
                         "args": {"name": lane}})
        return meta

    def to_chrome_trace(self) -> dict:
        """The Perfetto-loadable JSON object (metadata + events)."""
        with self._lock:
            events = list(self._events)
        return {"traceEvents": self._metadata() + events,
                "displayTimeUnit": "ms"}

    def write(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f, indent=1)
            f.write("\n")
        return path

    def reset(self) -> None:
        with self._lock:
            self._events.clear()
            self._tracks.clear()
            self._t0_ns = None

    @property
    def n_events(self) -> int:
        return len(self._events)

    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)


_TRACER = Tracer()


def tracer() -> Tracer:
    """The process-global tracer the instrumentation points write to."""
    return _TRACER


def reset() -> None:
    _TRACER.reset()


@contextmanager
def span(name: str, *, cat: str = "host", args: Optional[dict] = None):
    """Module-level wall-span shorthand: ``with obs.span("replay"):``."""
    with _TRACER.span(name, cat=cat, args=args):
        yield


# ---------------------------------------------------------------------------
# Scheduler Trace -> per-worker timeline
# ---------------------------------------------------------------------------


def _wire_lane_owner(d, ps: int) -> tuple:
    """(lane, owning worker) of one Delivery under the track contract."""
    if d.dst == ps:
        return "uplink", d.src
    if d.src == ps:
        return "downlink", d.dst
    return "gossip", d.src


def timeline_from_trace(cluster_trace, *, into: Optional[Tracer] = None
                        ) -> Tracer:
    """Render a scheduler ``Trace``'s ledgers as per-worker tracks.

    Accounting contract (asserted by ``export.verify_timeline``): one
    'X' wire span per ``trace.comm`` Delivery, one quorum-wait span per
    ``TimeoutRecord``, one instant per ``TraceEvent`` and per remaining
    fault-ledger record. ``into`` appends to an existing tracer (e.g.
    one that captured live compute spans during scheduling).
    """
    tr = into if into is not None else Tracer()
    ps = cluster_trace.n_workers

    for d in cluster_trace.comm:
        lane, owner = _wire_lane_owner(d, ps)
        status = getattr(d, "status", "ok")
        tr.sim_span(d.tag, worker=owner, lane=lane, t0=d.t_start,
                    t1=d.t_end, cat=f"wire,{lane},{status}",
                    args={"src": d.src, "dst": d.dst, "mb": d.size,
                          "status": status})

    for e in cluster_trace.events:
        if e.kind == "update":
            tr.instant("update", worker=e.worker, lane="compute",
                       t=e.t_wall, cat="event,update",
                       args={"step": e.step,
                             "version_pulled": e.version_pulled,
                             "version_applied": e.version_applied,
                             "staleness": e.staleness})
        elif e.kind == "rejoin":
            tr.instant("rejoin", worker=e.worker, lane="faults",
                       t=e.t_wall, cat="event,rejoin",
                       args={"step": e.step})
        else:   # sync / gossip barrier markers live on the server track
            tr.instant(e.kind, worker=PS, lane="compute", t=e.t_wall,
                       cat=f"event,{e.kind}",
                       args={"round": e.step,
                             "version": e.version_applied})

    led = cluster_trace.faults
    if led is not None:
        def wtrack(idx: int) -> int:
            return PS if idx >= ps else idx

        for r in led.drops:
            tr.instant("drop", worker=wtrack(r.src), lane="faults",
                       t=r.t, cat="fault,drop",
                       args={"dst": r.dst, "tag": r.tag,
                             "attempt": r.attempt})
        for r in led.retries:
            tr.instant("retry", worker=wtrack(r.src), lane="faults",
                       t=r.t, cat="fault,retry",
                       args={"dst": r.dst, "tag": r.tag,
                             "attempt": r.attempt})
        for r in led.duplicates:
            tr.instant("dup", worker=wtrack(r.src), lane="faults",
                       t=r.t, cat="fault,dup",
                       args={"dst": r.dst, "tag": r.tag})
        for r in led.corrupt:
            tr.instant("corrupt", worker=wtrack(r.src), lane="faults",
                       t=r.t, cat="fault,corrupt",
                       args={"dst": r.dst, "tag": r.tag,
                             "attempt": r.attempt, "kind": r.kind})
        for r in led.timeouts:
            # the quorum wait the straggler lost: [cut, late arrival]
            tr.sim_span("quorum-late", worker=r.worker, lane="faults",
                        t0=r.t_cut, t1=r.t_arrival, cat="fault,quorum",
                        args={"round": r.round})
        for r in led.shortfalls:
            tr.instant("quorum-shortfall", worker=PS, lane="faults",
                       t=0.0, cat="fault,shortfall",
                       args={"round": r.round, "got": r.n_got,
                             "wanted": r.n_wanted})
        for r in led.epochs:
            tr.instant("membership-epoch", worker=PS, lane="faults",
                       t=r.t, cat="fault,epoch",
                       args={"round": r.round,
                             "alive": list(r.alive),
                             "birkhoff_terms": r.n_birkhoff_terms})
        for r in led.rejoins:
            tr.instant("rejoin-pull", worker=r.worker, lane="faults",
                       t=r.t, cat="fault,rejoin",
                       args={"round": r.round, "donor": r.donor})
        for (w, t) in led.lost_compute:
            tr.instant("lost-compute", worker=w, lane="faults", t=t,
                       cat="fault,lost_compute", args={})
    return tr
