"""Metrics registry: counters, gauges, histograms with named scopes.

Instrumentation points across the stack (``scheduler.py``,
``execute.py``, ``faults.py``, ``compression.py``, ``communicators.py``,
the quant/flash-attn op layers) call

    metrics.counter("cluster.wire_mb", protocol="sync_ps").inc(mb)

When the metrics switch is off (the default) ``counter``/``gauge``/
``histogram`` return a shared no-op instrument — the whole call is one
dict lookup and one branch, which is how instrumentation stays under
the <2% overhead gate. Names are dotted scopes; keyword labels render
into the name as ``scope[k=v,...]`` so one instrument exists per label
set (wire bytes by codec tier, staleness per protocol, ...).

jax-safety: instruments accept plain Python numbers only. Values
produced **inside** ``jit`` are tracers — ``observe_array`` silently
skips them (recording at trace time would count once per compile, not
once per step); the supported pattern is to return such values as
auxiliary outputs of the jitted function and feed the concrete results
to ``observe_array`` afterwards (host callbacks only outside jit).

``Histogram`` keeps count/sum/min/max plus power-of-two magnitude
buckets — enough for staleness distributions, straggler lag, and
compression ratios without reservoir bookkeeping.
"""
from __future__ import annotations

import json
import math
import threading
from typing import Optional

from repro.obs import state


def scoped_name(name: str, **labels) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}[{inner}]"


class Counter:
    """Monotonic count (messages, bytes, retries, kernel launches)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v

    def snapshot(self) -> dict:
        return {"type": "counter", "value": self.value}


class Gauge:
    """Last-written value (current compression ratio, live-set size)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = None

    def set(self, v: float) -> None:
        self.value = float(v)

    def snapshot(self) -> dict:
        return {"type": "gauge", "value": self.value}


class Histogram:
    """count/sum/min/max + power-of-two magnitude buckets.

    Bucket i counts values in (2**(i-1), 2**i] (bucket 0: (0, 1];
    ``neg``/``zero`` catch the rest) — coarse, allocation-free, and
    enough to see a staleness or straggler-lag distribution move.
    """

    __slots__ = ("name", "count", "total", "vmin", "vmax", "buckets",
                 "neg", "zero")

    def __init__(self, name: str):
        self.name = name
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self.buckets: dict[int, int] = {}
        self.neg = 0
        self.zero = 0

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)
        if v < 0:
            self.neg += 1
        elif v == 0:
            self.zero += 1
        else:
            b = max(0, math.ceil(math.log2(v)))
            self.buckets[b] = self.buckets.get(b, 0) + 1

    def observe_many(self, vals) -> None:
        for v in vals:
            self.observe(v)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def snapshot(self) -> dict:
        return {"type": "histogram", "count": self.count,
                "sum": self.total,
                "min": self.vmin if self.count else None,
                "max": self.vmax if self.count else None,
                "mean": self.mean if self.count else None,
                "neg": self.neg, "zero": self.zero,
                "pow2_buckets": {str(k): v for k, v in
                                 sorted(self.buckets.items())}}


class _Null:
    """Shared no-op instrument returned while metrics are disabled."""

    __slots__ = ()

    def inc(self, v: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def observe_many(self, vals) -> None:
        pass


_NULL = _Null()


class Registry:
    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: dict = {}

    def _get(self, cls, name: str, labels: dict):
        key = scoped_name(name, **labels)
        inst = self._instruments.get(key)
        if inst is None:
            with self._lock:
                inst = self._instruments.setdefault(key, cls(key))
        if not isinstance(inst, cls):
            raise TypeError(f"metric '{key}' already registered as "
                            f"{type(inst).__name__}")
        return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(Histogram, name, labels)

    def snapshot(self) -> dict:
        """{name: {type, ...}} for every instrument, sorted by name."""
        with self._lock:
            return {k: self._instruments[k].snapshot()
                    for k in sorted(self._instruments)}

    def write(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=2)
            f.write("\n")
        return path

    def reset(self) -> None:
        with self._lock:
            self._instruments.clear()


_REGISTRY = Registry()


def registry() -> Registry:
    return _REGISTRY


def reset() -> None:
    _REGISTRY.reset()


def counter(name: str, **labels):
    """Counter by scoped name — the no-op instrument when disabled."""
    if not state.enabled("metrics"):
        return _NULL
    return _REGISTRY.counter(name, **labels)


def gauge(name: str, **labels):
    if not state.enabled("metrics"):
        return _NULL
    return _REGISTRY.gauge(name, **labels)


def histogram(name: str, **labels):
    if not state.enabled("metrics"):
        return _NULL
    return _REGISTRY.histogram(name, **labels)


def _is_tracer(x) -> bool:
    # recognize jax tracers without importing jax (obs stays zero-dep):
    # abstract values flowing through jit/vmap subclass jax.core.Tracer,
    # concrete jax arrays do not
    return any(c.__name__ == "Tracer" for c in type(x).__mro__)


def observe_array(name: str, arr, **labels) -> None:
    """Histogram-observe every element of an array-like — jax-safe.

    Inside ``jit`` the value is a tracer: recording it would count per
    COMPILE, not per call, so tracers are skipped silently. Pass the
    value out as an auxiliary output and call this on the concrete
    result instead.
    """
    if not state.enabled("metrics") or arr is None or _is_tracer(arr):
        return
    hist = _REGISTRY.histogram(name, **labels)
    try:
        flat = arr.ravel().tolist() if hasattr(arr, "ravel") else list(arr)
    except TypeError:
        flat = [arr]
    hist.observe_many(float(v) for v in flat)
