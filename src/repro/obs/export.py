"""Export a cluster trace as a Perfetto-loadable timeline.

    PYTHONPATH=src python -m repro.obs.export trace --out timeline.json

schedules the demo scenario — sync-PS with a first-6-of-8 quorum under
10% message drop plus one mid-run crash/restart (the ISSUE-8 acceptance
scenario) — cross-validates its fault ledger (``faults.validate``),
renders the wire + fault ledgers as per-worker tracks
(``trace.timeline_from_trace``), **verifies the rendered event counts
against the ledgers exactly** (``verify_timeline``), and writes Chrome
trace JSON openable at https://ui.perfetto.dev.

Flags pick protocol / rounds / fault mix; ``--protocol async_ps`` runs
the free-running loop instead (``--rounds`` then sets the sync-makespan
horizon). ``--metrics-out`` additionally snapshots the metrics registry
the scheduling pass filled.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Optional

from repro.obs import metrics, runinfo, state
from repro.obs import trace as obs_trace


def demo_plan(n: int, *, p_drop: float, crash: bool, makespan_hint: float,
              seed: int):
    from repro.cluster import faults

    # the hint is the HEALTHY sync makespan (gated on the 4x straggler);
    # the faulty quorum run cuts the straggler and finishes in roughly
    # half that, so the restart must land well before 0.5*hint for the
    # rejoin/checkpoint-pull to appear inside the run
    crashes = ((1, 0.15 * makespan_hint, 0.3 * makespan_hint),) if crash \
        else ()
    return faults.FaultPlan(n, seed=seed, p_drop=p_drop, crashes=crashes)


def build_trace(*, protocol: str = "sync_ps", n: int = 8, rounds: int = 8,
                p_drop: float = 0.1, crash: bool = True,
                quorum: Optional[int] = 6, seed: int = 0):
    """Schedule the faulty demo scenario and return its Trace."""
    from repro import cluster

    spec = cluster.ClusterSpec(
        n_workers=n, t_compute=1.0,
        multipliers=cluster.straggler_multipliers(n, factor=4.0),
        t_lat=1e-2, t_tr=2e-3, size_mb=1.0, codec="rq4", seed=seed)
    healthy = cluster.make_protocol("sync_ps").schedule(spec, rounds=rounds)
    plan = demo_plan(n, p_drop=p_drop, crash=crash,
                     makespan_hint=healthy.makespan, seed=seed)
    kw = {"quorum": quorum} if protocol in ("sync_ps", "local_sgd",
                                            "laq") else {}
    proto = cluster.make_protocol(protocol, **kw)
    if protocol == "async_ps":
        return proto.schedule(spec, horizon=healthy.makespan, plan=plan)
    return proto.schedule(spec, rounds=rounds, plan=plan)


def expected_counts(cluster_trace) -> dict:
    """Event counts the timeline must reproduce, from the ledgers alone."""
    led = cluster_trace.faults
    n_fault_instants = 0
    n_quorum_spans = 0
    if led is not None:
        n_fault_instants = (len(led.drops) + len(led.retries)
                            + len(led.duplicates) + len(led.corrupt)
                            + len(led.shortfalls) + len(led.epochs)
                            + len(led.rejoins) + len(led.lost_compute))
        n_quorum_spans = len(led.timeouts)
    by_status = {"ok": 0, "lost": 0, "dup": 0, "corrupted": 0}
    for d in cluster_trace.comm:
        by_status[getattr(d, "status", "ok")] += 1
    return {"wire_spans": len(cluster_trace.comm),
            "wire_by_status": by_status,
            "event_instants": len(cluster_trace.events),
            "fault_instants": n_fault_instants,
            "quorum_spans": n_quorum_spans}


def timeline_counts(events: list) -> dict:
    """The same tally, read back from exported traceEvents."""
    cats = [(e.get("cat", ""), e.get("ph")) for e in events]
    by_status = {"ok": 0, "lost": 0, "dup": 0, "corrupted": 0}
    for e in events:
        cat = e.get("cat", "")
        if e.get("ph") == "X" and cat.startswith("wire,"):
            by_status[cat.rsplit(",", 1)[1]] += 1
    return {
        "wire_spans": sum(1 for c, ph in cats
                          if ph == "X" and c.startswith("wire,")),
        "wire_by_status": by_status,
        "event_instants": sum(1 for c, ph in cats
                              if ph == "i" and c.startswith("event,")),
        "fault_instants": sum(1 for c, ph in cats
                              if ph == "i" and c.startswith("fault,")),
        "quorum_spans": sum(1 for c, ph in cats
                            if ph == "X" and c.startswith("fault,quorum")),
    }


def verify_timeline(cluster_trace, tracer: obs_trace.Tracer) -> dict:
    """Assert the rendered timeline and the scheduler's ledgers agree
    event for event (the export-side twin of ``faults.validate``)."""
    want = expected_counts(cluster_trace)
    got = timeline_counts(tracer.events())
    assert got == want, f"timeline/ledger mismatch: {got} != {want}"
    # the ok+lost+dup+corrupted == comm partition, per faults.validate
    assert sum(want["wire_by_status"].values()) == len(cluster_trace.comm)
    return want


def export_trace(cluster_trace, out_path: str, *,
                 into: Optional[obs_trace.Tracer] = None,
                 seed: int = 0) -> dict:
    """Render, verify, and write one cluster trace; returns the tally."""
    tracer = obs_trace.timeline_from_trace(cluster_trace, into=into)
    counts = verify_timeline(cluster_trace, tracer)
    doc = tracer.to_chrome_trace()
    doc["metadata"] = {"run_id": runinfo.run_id(seed),
                       "schema_version": runinfo.SCHEMA_VERSION,
                       "protocol": cluster_trace.protocol,
                       "n_workers": cluster_trace.n_workers,
                       "makespan_s": cluster_trace.makespan,
                       "counts": counts}
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    return counts


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="cmd")
    tp = sub.add_parser("trace", help="export a faulty cluster timeline")
    tp.add_argument("--protocol", default="sync_ps",
                    choices=["sync_ps", "async_ps", "local_sgd", "laq",
                             "dsgd"])
    tp.add_argument("--n", type=int, default=8)
    tp.add_argument("--rounds", type=int, default=8)
    tp.add_argument("--drop", type=float, default=0.1,
                    help="per-message drop probability")
    tp.add_argument("--no-crash", action="store_true",
                    help="disable the mid-run crash/restart window")
    tp.add_argument("--quorum", type=int, default=6,
                    help="backup-worker quorum for PS rounds (0: full "
                         "barrier)")
    tp.add_argument("--seed", type=int, default=0)
    tp.add_argument("--out", default="timeline.json")
    tp.add_argument("--metrics-out", default=None,
                    help="also snapshot the metrics registry to this path")
    args = ap.parse_args(argv)
    if args.cmd is None:
        ap.print_help()
        return 2

    from repro.cluster import faults

    # live tracing during scheduling captures the compute spans the
    # ledgers alone cannot reconstruct; metrics ride along for free
    state.enable(trace=True, metrics=True, flight=True)
    live = obs_trace.tracer()
    live.reset()
    tr = build_trace(protocol=args.protocol, n=args.n, rounds=args.rounds,
                     p_drop=args.drop, crash=not args.no_crash,
                     quorum=args.quorum or None, seed=args.seed)
    tally = faults.validate(tr)
    counts = export_trace(tr, args.out, into=live, seed=args.seed)
    if args.metrics_out:
        metrics.registry().write(args.metrics_out)
        print(f"# wrote {args.metrics_out}")
    print(f"# {tr.protocol}: {tr.n_workers} workers, "
          f"makespan {tr.makespan:.2f}s simulated")
    print(f"# wire ledger: {tally['attempted']} attempted = "
          f"{tally['delivered']} ok + {tally['dropped']} lost + "
          f"{tally['duplicated']} dup | retries {tally['retried']}, "
          f"timeouts {tally['timed_out']}, rejoins {tally['rejoins']}")
    print(f"# timeline: {counts['wire_spans']} wire spans "
          f"{counts['wire_by_status']}, {counts['event_instants']} event "
          f"+ {counts['fault_instants']} fault instants, "
          f"{counts['quorum_spans']} quorum-wait spans — counts verified "
          "against the ledgers")
    print(f"# wrote {args.out} (open at https://ui.perfetto.dev)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
