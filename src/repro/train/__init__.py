from repro.train.steps import (TrainStepConfig, init_train_state,
                               make_prefill_step, make_serve_step,
                               make_train_step)

__all__ = ["TrainStepConfig", "init_train_state", "make_train_step",
           "make_serve_step", "make_prefill_step"]
