"""Production train / serve steps (pjit tier).

`make_train_step` builds one jit-able function:
    state, metrics = train_step(state, batch)
with the paper's communication relaxations attached at the gradient-exchange
point of the *sharded* trainer (the production tier of the two-tier
compression story — the exact per-worker algorithms live in
repro.core.communicators, the algorithm tier):

  * grad_compression='rq8'/...  — server-side compression of the device-owned
    gradient shard (the multi-server-PS view of Eq. 3.2: each device is the
    parameter server of its FSDP partition, so quantizing its shard is
    exactly the PS's outgoing Q; README.md "Compression story" records why
    worker-side Q is not interceptable under pjit autodiff). Compression is
    obtained from the Codec registry and runs through the FUSED flat-buffer
    tier: the whole gradient tree is flattened onto a FlatLayout and
    quantized per size-capped bucket in one pass — one message, one kernel
    launch, one (n_buckets, 2) params reduction, instead of one per pytree
    leaf. Metrics report the measured wire bytes of that one fused message.
  * error_feedback=True — single-sided DoubleSqueeze (Eq. 3.10-3.11) on the
    same shard: the residual delta is a SINGLE flat fp32 buffer in the
    train state (state['ec_err'], shape (n_params,)).
  * The exact two-sided algorithms live in repro.core.parallel (algorithm
    tier) and are validated against the theorems there.

`make_serve_step` builds the single-token decode step used by the decode
input shapes (decode_32k / long_500k) and the serving example.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import compression
from repro.models import transformer, transformer_scan
from repro.models.common import ModelConfig
from repro.optim.optimizers import (Optimizer, apply_updates,
                                    clip_by_global_norm)

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    remat: bool = False
    use_flash: bool = False
    grad_clip: float = 1.0
    grad_compression: str = "none"    # compression registry key
    error_feedback: bool = False      # single-sided EC on the grad shard
    param_dtype: Any = jnp.float32
    scan_layers: bool = False         # stacked params + lax.scan over blocks
    remat_policy: str = "full"        # full | dots (save matmul outputs)


def _impl(scan_layers: bool):
    return transformer_scan if scan_layers else transformer


def init_train_state(cfg: ModelConfig, optimizer: Optimizer, key: jax.Array,
                     *, step_cfg: TrainStepConfig = TrainStepConfig()) -> dict:
    params = _impl(step_cfg.scan_layers).init(cfg, key,
                                              dtype=step_cfg.param_dtype)
    state = {
        "params": params,
        "opt": optimizer.init(params),
        "step": jnp.zeros((), jnp.int32),
        "rng": key,
    }
    if step_cfg.error_feedback:
        # single flat fp32 residual buffer over the whole gradient tree
        # (the fused-tier analogue of a per-leaf error pytree)
        total = compression.FlatLayout.from_tree(params).total
        state["ec_err"] = jnp.zeros((total,), jnp.float32)
    return state


def abstract_train_state(cfg: ModelConfig, optimizer: Optimizer, *,
                         step_cfg: TrainStepConfig = TrainStepConfig()):
    """ShapeDtypeStruct train state (dry-run: nothing is allocated)."""
    return jax.eval_shape(
        lambda k: init_train_state(cfg, optimizer, k, step_cfg=step_cfg),
        jax.random.PRNGKey(0))


def make_loss_fn(cfg: ModelConfig,
                 step_cfg: TrainStepConfig = TrainStepConfig()):
    """The production loss closure, ``loss(params, batch) -> scalar``.

    Factored out of ``make_train_step`` so other drivers — notably the
    virtual-cluster replay (``repro.cluster.execute``), which applies
    gradients in trace order rather than through one jit'd step — run the
    exact same forward/remat/flash configuration as production training.
    """
    impl = _impl(step_cfg.scan_layers)

    def loss(params, batch):
        kw = {}
        if step_cfg.scan_layers:
            kw["remat_policy"] = step_cfg.remat_policy
        return impl.loss_fn(params, cfg, batch,
                            use_flash=step_cfg.use_flash,
                            remat=step_cfg.remat, **kw)

    return loss


def make_train_step(cfg: ModelConfig, optimizer: Optimizer,
                    step_cfg: TrainStepConfig = TrainStepConfig()):
    q_codec = compression.codec(step_cfg.grad_compression)

    loss_fn = make_loss_fn(cfg, step_cfg)

    def train_step(state: dict, batch: dict) -> tuple[dict, dict]:
        loss_val, grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch))(state["params"])
        if step_cfg.grad_clip > 0:
            grads, grad_norm = clip_by_global_norm(grads, step_cfg.grad_clip)
        else:
            grad_norm = jnp.zeros(())

        new_state = dict(state)
        comm_bytes = 0.0
        if step_cfg.grad_compression != "none":
            qkey = jax.random.fold_in(state["rng"], state["step"])
            # fused flat-buffer path: flatten once (single-buffer writes,
            # layout from the lru cache), quantize per bucket in one
            # pass, ship ONE message
            layout = compression.FlatLayout.from_tree(grads)
            gflat = layout.flatten(grads)
            if step_cfg.error_feedback:
                # v survives the qdq (residual needs it) -> no donation
                v = gflat + state["ec_err"]
                qflat = q_codec.flat_qdq(v, qkey)
                new_state["ec_err"] = v - qflat
            else:
                # gflat is dead after the qdq -> donate its storage
                qflat = q_codec.flat_qdq(gflat, qkey, donate=True)
            grads = layout.unflatten(qflat)
            # measured wire bytes of the one fused gradient message (a
            # trace-time constant: shapes are static under jit)
            comm_bytes = q_codec.tree_wire_bytes_flat(grads)

        updates, new_opt = optimizer.update(grads, state["opt"],
                                            state["params"])
        new_state["params"] = apply_updates(state["params"], updates)
        new_state["opt"] = new_opt
        new_state["step"] = state["step"] + 1
        metrics = {"loss": loss_val, "grad_norm": grad_norm,
                   "step": state["step"],
                   "comm_bytes": jnp.asarray(comm_bytes, jnp.float32)}
        return new_state, metrics

    return train_step


# --------------------------------------------------------------------------
# Serving
# --------------------------------------------------------------------------


def make_serve_step(cfg: ModelConfig, *, scan_layers: bool = False):
    """decode: (params, decode_state, inputs) -> (next_token_logits, state)."""
    impl = _impl(scan_layers)

    def serve_step(params, decode_state, inputs):
        logits, new_state = impl.decode_step(params, cfg, inputs,
                                             decode_state)
        return logits[:, -1], new_state

    return serve_step


def make_bulk_prefill(cfg: ModelConfig, *, scan_layers: bool = False):
    """Bulk cache fill: (params, decode_state, tokens (B, S)) ->
    (last_logits (B, V), filled decode_state) in ONE fused call.

    This is the recorded §Perf optimization that replaces the serving
    tier's token-by-token Python prompt loop (one dispatch per prompt
    position) with a single ``lax.scan`` of ``decode_step`` over the
    prompt axis — one compiled program, one dispatch, per prompt LENGTH
    instead of per prompt TOKEN. Because the scan body IS the decode
    step, the filled cache and the per-position logits are bit-identical
    to the incremental path by construction, across every block family
    (attn ring-buffer KV, MLA, RWKV/RG-LRU recurrent state) — asserted
    in tests/test_serve.py.

    Token-frontend models only (the serving engine's domain); the
    embedding frontends go through ``make_prefill_step`` below.
    """
    impl = _impl(scan_layers)
    if cfg.frontend != "token":
        raise ValueError(
            f"bulk prefill needs a token frontend, got '{cfg.frontend}'")

    def bulk_prefill(params, decode_state, tokens):
        def body(state, tok):
            logits, state = impl.decode_step(params, cfg,
                                             {"tokens": tok[:, None]}, state)
            return state, logits[:, -1]

        state, logits = jax.lax.scan(body, decode_state,
                                     jnp.moveaxis(tokens, 1, 0))
        return logits[-1], state

    return bulk_prefill


def make_prefill_step(cfg: ModelConfig, *, use_flash: bool = False,
                      scan_layers: bool = False,
                      logits_positions: str = "all"):
    """prefill: full-sequence forward returning last-position logits.

    (Cache population for subsequent decode goes through
    ``make_bulk_prefill`` above; this full-sequence forward remains the
    logits-only path the dry-run input shapes lower.)
    """

    impl = _impl(scan_layers)

    def prefill_step(params, batch):
        kw = {}
        if scan_layers:
            kw["logits_positions"] = logits_positions
        logits, _ = impl.apply(params, cfg, batch, use_flash=use_flash,
                               remat=scan_layers, **kw)
        return logits[:, -1]

    return prefill_step
