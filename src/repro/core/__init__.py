"""Core library: the paper's contribution as composable JAX modules.

  compression    - Q(.) operators (Section 3.1.1) + wire-cost specs
  communicators  - mb-SGD / CSGD / EC-SGD / ASGD / DSGD exchanges
  parallel       - N-worker algorithm-tier trainer + quadratic testbed
  eventsim       - Section 1.3 simplified communication model (discrete events)
  theory         - Tables 1.1/1.2 closed forms + theorem learning rates
  mixing         - gossip matrices W, spectral gap rho (Assumption 7)
"""
from repro.core import (communicators, compression, eventsim, mixing,
                        parallel, theory)

__all__ = ["communicators", "compression", "eventsim", "mixing", "parallel",
           "theory"]
