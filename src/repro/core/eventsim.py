"""Discrete-event simulator for the paper's simplified communication model (§1.3).

Model (Figure 1.2):
  * all workers hang off one "logical switch" of infinite bandwidth;
  * every message pays a constant switch latency t_lat;
  * a worker sends at most one message at a time, receives at most one at a
    time, and may do one send and one receive concurrently;
  * moving one unit (MB) takes t_tr seconds at the worker NIC.

Semantics used here (documented in README.md — the paper's Figure 1.3 is not
fully specified by its text): a message holds its sender's send-port AND its
receiver's recv-port for the full (t_lat + size * t_tr) duration, and a message
begins only when both ports are free. This reproduces every closed form the
paper states:

  single PS, N workers:            2 N (t_lat + t_tr)          (§1.3.2)
  ring AllReduce, partitioned:     ~2 N t_lat + 2 t_tr         (§1.3.3)
  ring AllReduce, unpartitioned:   2 N (t_lat + t_tr)          (§1.3.3 caveat)
  multi-server PS:                 ~2 N t_lat + 2 t_tr         (§1.3.4)
  decentralized (ring gossip):     2 t_lat + 2 t_tr            (§5.1)
  K-times compression: divides every t_tr term by K, latency unchanged
                                                       (Figures 3.4/3.5)

Compressed-delta gossip (the DCD/ECD tier): pass ``codec=`` to
``decentralized_makespan`` / ``gossip_wire_mb_per_worker`` and each of
the deg(W) per-mix messages is sized at the codec's measured wire bytes
— message COUNT (and hence the t_lat term) is unchanged, exactly the
Figure 3.4/3.5 story carried over to Section 5's pattern.

Message sizes can be taken from the *measured* wire format instead of an
abstract ratio: every pattern builder accepts ``codec='rq4'`` (a name from
repro.core.compression's Codec registry) and then replaces `size` — read
as the uncompressed fp32 message MB — with ``Codec.wire_bytes`` of the
actual packed payload for that element count (including the params header
and the pad-to-lane-granule overhead). The scalar ``compression=K`` knob
remains for the paper's closed-form sweeps.

Per-message accounting: every builder also accepts ``n_messages`` — how
many wire messages one logical exchange step is split into. Each message
pays the fixed t_lat, so a logical transfer costs
``n_messages * t_lat + size * t_tr`` (the bytes are unchanged). This is
exactly the fused-vs-per-leaf codec gap: a gradient pytree shipped leaf
by leaf sets n_messages = L (ring exchange latency ~ 2 N L t_lat), the
fused flat-buffer tier sets n_messages = 1 (~ 2 N t_lat) — the paper's
own argument for why latency, not bandwidth, dominates small messages.

``csgd_ring_makespan`` / ``ring_wire_mb_per_worker`` cost the REAL
CSGDRingExchange: partitioned (default) is the reduce-scatter +
all-gather decomposition — 2(N-1) partition messages per worker, size/N
each, total 2M(N-1)/N wire bytes — vs the monolithic chain's N-1 full-M
hops; both match the exchange's ``message_bytes``/``n_wire_messages``.

Example 1.3.2's "14 vs 9 units" figure reads one unit differently than these
semantics (we get 13 vs 8) but the *saving* — exactly the halved transfer
time, latency untouched — matches; asserted in tests.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Sequence


@dataclasses.dataclass(frozen=True)
class Msg:
    """A point-to-point message request.

    n_messages: wire messages this logical transfer is split into
    (back-to-back on the same port pair). Each pays t_lat; the size is
    the TOTAL across them, so duration = n_messages*t_lat + size*t_tr.
    """

    t_req: float          # earliest time the sender wants to start
    src: int
    dst: int
    size: float           # in MB (or any unit consistent with t_tr)
    tag: str = ""
    n_messages: int = 1


@dataclasses.dataclass(frozen=True)
class Delivery:
    """One completed transfer.

    ``status`` makes the ledger self-describing under fault injection
    (``repro.cluster.faults``): 'ok' reached its receiver, 'lost' went
    on the wire and vanished (the ports were still occupied — the
    sender paid), 'dup' is a delivered-and-ignored duplicate. Healthy
    simulations only ever emit 'ok'.
    """

    t_start: float
    t_end: float
    src: int
    dst: int
    size: float
    tag: str = ""
    status: str = "ok"


@dataclasses.dataclass(frozen=True)
class MsgRecord:
    """ONE wire message (a Delivery is n_messages of these, back to back).

    Message ``index`` of a split transfer occupies
    ``[t_start, t_start + t_lat + (size_total/n_messages) * t_tr]`` on the
    port pair — the per-message ledger that external schedulers (the
    ``repro.cluster`` event loop) cross-check their timings against.
    """

    t_start: float
    t_end: float
    src: int
    dst: int
    size: float           # this message's share of the transfer
    tag: str = ""
    index: int = 0        # position within the split transfer
    n_messages: int = 1


@dataclasses.dataclass(frozen=True)
class SimResult:
    deliveries: tuple
    makespan: float           # last completion - 0
    span: float               # last completion - first request
    messages: tuple = ()      # MsgRecord per wire message (per-message view
                              # of `deliveries`; same total occupancy)

    def end_of(self, tag: str) -> float:
        return max(d.t_end for d in self.deliveries if d.tag == tag)

    @property
    def n_wire_messages(self) -> int:
        return len(self.messages)


def split_msg_records(t0: float, src: int, dst: int, size: float, tag: str,
                      n_messages: int, *, t_lat: float,
                      t_tr: float) -> list[MsgRecord]:
    """The per-wire view of one transfer occupying [t0, ...]: k messages
    back to back, each paying t_lat + its share of the transfer time.
    Single source of the MsgRecord contract — used by simulate() and by
    external schedulers (repro.cluster) so the ledgers stay comparable."""
    k = max(n_messages, 1)
    per = t_lat + (size / k) * t_tr
    return [MsgRecord(t0 + i * per, t0 + (i + 1) * per, src, dst, size / k,
                      tag, i, k) for i in range(k)]


def simulate(msgs: Iterable[Msg], *, t_lat: float, t_tr: float,
             statuses: Optional[dict] = None) -> SimResult:
    """Run the switch model over a set of message requests.

    Messages become eligible at t_req (or when their FIFO predecessor on the
    same (src,dst,tag-order) finished, whichever is later — we model simple
    per-request eligibility). Eligible messages start as soon as both the
    sender send-port and receiver recv-port are free; ties break by request
    time then insertion order, which matches the paper's walk-throughs.

    ``statuses`` (fault injection) maps ``(src, dst, tag)`` to a
    ``Delivery.status`` — 'lost' and 'dup' messages still occupy ports
    and appear in the ledgers (the wire carried them), they just never
    reach the protocol.
    """
    msgs = list(msgs)
    n = 0
    for m in msgs:
        n = max(n, m.src + 1, m.dst + 1)
    send_free = [0.0] * n
    recv_free = [0.0] * n
    deliveries: list[Delivery] = []
    records: list[MsgRecord] = []
    # Greedy event loop: repeatedly pick the eligible message that can start
    # earliest (then FIFO). O(k^2) is fine for the sizes we simulate.
    remaining = sorted((m.t_req, i, m) for i, m in enumerate(msgs))
    done: list[bool] = [False] * len(remaining)
    for _ in range(len(remaining)):
        best = None
        best_key = None
        for idx, (t_req, seq, m) in enumerate(remaining):
            if done[idx]:
                continue
            t0 = max(t_req, send_free[m.src], recv_free[m.dst])
            key = (t0, t_req, seq)
            if best_key is None or key < best_key:
                best_key = key
                best = idx
        t_req, seq, m = remaining[best]
        done[best] = True
        t0 = max(t_req, send_free[m.src], recv_free[m.dst])
        dur = m.n_messages * t_lat + m.size * t_tr
        t_end = t0 + dur
        send_free[m.src] = t_end
        recv_free[m.dst] = t_end
        status = (statuses or {}).get((m.src, m.dst, m.tag), "ok")
        deliveries.append(Delivery(t0, t_end, m.src, m.dst, m.size, m.tag,
                                   status))
        records += split_msg_records(t0, m.src, m.dst, m.size, m.tag,
                                     m.n_messages, t_lat=t_lat, t_tr=t_tr)
    makespan = max(d.t_end for d in deliveries) if deliveries else 0.0
    t_first = min(m.t_req for m in msgs) if msgs else 0.0
    return SimResult(tuple(deliveries), makespan, makespan - t_first,
                     tuple(records))


# ---------------------------------------------------------------------------
# Communication-pattern builders (the paper's §1.3 walk-throughs). All return
# the message list for computing/broadcasting S = sum_i w_i of a `size`-MB
# parameter vector across `n` workers.
# ---------------------------------------------------------------------------


def wire_size_mb(codec: str, n_elements: int) -> float:
    """MEASURED wire MB of one message of n_elements fp32 values under
    `codec` (payload + params header of the actual packed arrays)."""
    from repro.core import compression   # lazy: keep eventsim jax-free

    return compression.codec(codec).wire_bytes_for(n_elements) / 1e6


def _msg_mb(size: float, compression: float, codec: Optional[str],
            n_chunks: int = 1) -> float:
    """One chunk's wire MB: `size` MB of fp32 split into n_chunks, shipped
    under `codec` (measured) or divided by the scalar `compression`."""
    if codec is not None:
        n_el = size * 1e6 / 4.0 / n_chunks
        return wire_size_mb(codec, max(1, int(n_el)))
    return size / n_chunks / compression


def single_ps_makespan(n: int, size: float, *, t_lat: float, t_tr: float,
                       compression: float = 1.0,
                       codec: Optional[str] = None,
                       n_messages: int = 1) -> float:
    """Simulated PS makespan with the broadcast gated on aggregation."""
    ps = n
    s = _msg_mb(size, compression, codec)
    up = simulate([Msg(0.0, w, ps, s, "agg", n_messages) for w in range(n)],
                  t_lat=t_lat, t_tr=t_tr)
    t_sum = up.makespan
    down = simulate([Msg(t_sum, ps, w, s, "bc", n_messages)
                     for w in range(n)], t_lat=t_lat, t_tr=t_tr)
    return down.makespan


def ring_allreduce_msgs(n: int, size: float, *, partitioned: bool = True,
                        compression: float = 1.0,
                        codec: Optional[str] = None,
                        n_messages: int = 1) -> list[Msg]:
    """§1.3.3: reduce-scatter + all-gather on a logical ring.

    partitioned=True: model split into n chunks (the paper's key design
    choice); False reproduces the "why do we partition" strawman.
    """
    msgs: list[Msg] = []
    if partitioned:
        chunk = _msg_mb(size, compression, codec, n_chunks=n)
        rounds = 2 * (n - 1)
        for r in range(rounds):
            phase = "reduce" if r < n - 1 else "gather"
            for w in range(n):
                msgs.append(Msg(0.0, w, (w + 1) % n, chunk, f"{phase}{r}",
                                n_messages))
    else:
        chunk = _msg_mb(size, compression, codec)
        # one token circles the ring twice (2(n-1) sequential hops); model as
        # chained requests via tags — simulate() serializes on ports anyway
        for r in range(2 * (n - 1)):
            w = r % n
            msgs.append(Msg(0.0, w, (w + 1) % n, chunk, f"hop{r}",
                            n_messages))
    return msgs


def ring_allreduce_makespan(n: int, size: float, *, t_lat: float, t_tr: float,
                            partitioned: bool = True,
                            compression: float = 1.0,
                            codec: Optional[str] = None,
                            n_messages: int = 1) -> float:
    """Round-synchronous ring AllReduce makespan.

    Each of the 2(n-1) rounds moves one chunk per worker concurrently
    (every worker sends one + receives one, allowed by the model), so a
    round costs n_messages * t_lat + chunk * t_tr — per-leaf codec paths
    set n_messages = leaf count L (latency ~ 2 N L t_lat), the fused
    flat-buffer tier sets 1 (~ 2 N t_lat).
    """
    chunk = _msg_mb(size, compression, codec, n_chunks=n if partitioned else 1)
    return 2 * (n - 1) * (n_messages * t_lat + chunk * t_tr)


def csgd_ring_makespan(n: int, size: float, *, t_lat: float, t_tr: float,
                       partitioned: bool = True, compression: float = 1.0,
                       codec: Optional[str] = None,
                       n_messages: int = 1) -> float:
    """Cost of ONE CSGDRingExchange iteration under the switch model.

    partitioned=True (the exchange's default): reduce-scatter +
    all-gather — 2(n-1) rounds, each moving ONE partition (size/n) per
    worker, so per-worker wire bytes are 2*M*(n-1)/n and the makespan is
    2(n-1)(n_messages*t_lat + (size/n)*t_tr). partitioned=False is the
    monolithic chain: n-1 hops each shipping the FULL buffer (every
    worker builds its own complete nesting, no gather phase) —
    (n-1)(n_messages*t_lat + size*t_tr) with per-worker wire bytes
    (n-1)*M. Codec sizing is measured per message (`wire_size_mb` of a
    partition's / the buffer's element count), matching the exchange's
    `message_bytes` to within one pad granule per partition.
    """
    if partitioned:
        chunk = _msg_mb(size, compression, codec, n_chunks=n)
        return 2 * (n - 1) * (n_messages * t_lat + chunk * t_tr)
    full = _msg_mb(size, compression, codec)
    return (n - 1) * (n_messages * t_lat + full * t_tr)


def ring_wire_mb_per_worker(n: int, size: float, *,
                            partitioned: bool = True,
                            compression: float = 1.0,
                            codec: Optional[str] = None) -> float:
    """Wire MB ONE worker sends per ring AllReduce iteration:
    2(n-1) * size/n partitioned (the bandwidth-optimal 2M(N-1)/N), vs
    (n-1) * size monolithic."""
    if partitioned:
        return 2 * (n - 1) * _msg_mb(size, compression, codec, n_chunks=n)
    return (n - 1) * _msg_mb(size, compression, codec)


def multi_ps_makespan(n: int, size: float, *, t_lat: float, t_tr: float,
                      compression: float = 1.0,
                      codec: Optional[str] = None,
                      n_messages: int = 1) -> float:
    """§1.3.4: every worker hosts 1/n of the model; same cost as ring AR.

    Phase 1: n-1 incoming shards per server, perfectly staggered (Example
    1.3.4) -> (n-1)(n_messages t_lat + chunk t_tr); phase 2 symmetric.
    """
    chunk = _msg_mb(size, compression, codec, n_chunks=n)
    return 2 * (n - 1) * (n_messages * t_lat + chunk * t_tr)


def decentralized_makespan(n: int, size: float, *, t_lat: float, t_tr: float,
                           degree: int = 2, w=None,
                           compression: float = 1.0,
                           codec: Optional[str] = None,
                           n_messages: int = 1) -> float:
    """§5.1: each worker exchanges its FULL model with `degree` neighbors.

    Sends serialize at each worker's send port ->
    degree * (n_messages t_lat + size t_tr), = 2 t_lat + 2 t_tr for the
    ring with one fused message (paper's closed form). Pass a gossip
    matrix ``w`` (any ``mixing.py`` matrix, e.g. ``torus_2d``) to charge
    its actual ``mixing.degree(W)`` instead of the ring's 2 — the torus
    pays 4 sends, W1 pays n-1.
    """
    del n
    if w is not None:
        from repro.core import mixing   # lazy: keep eventsim numpy-free
        degree = mixing.degree(w)
    return degree * (n_messages * t_lat
                     + _msg_mb(size, compression, codec) * t_tr)


def gossip_wire_mb_per_worker(size: float, *, degree: int = 2, w=None,
                              compression: float = 1.0,
                              codec: Optional[str] = None) -> float:
    """Wire MB ONE worker sends per gossip mix: deg(W) full-model
    messages, each at the codec's MEASURED wire size when ``codec`` is
    set — the DCD/ECD compressed-delta tier ships deg(W) quantized
    deltas instead of deg(W) fp32 models (same message count, ~K-fold
    fewer bytes; the decentralized analogue of ``ring_wire_mb_per_worker``)."""
    if w is not None:
        from repro.core import mixing   # lazy: keep eventsim numpy-free
        degree = mixing.degree(w)
    return degree * _msg_mb(size, compression, codec)


def async_ps_timeline(n: int, *, t_compute: Sequence[float], t_lat: float,
                      t_tr: float, size: float, horizon: float) -> list[tuple]:
    """§4.1 single-server async PS timeline.

    Each worker loops: pull model (t_lat + size*t_tr, serialized at PS send
    port), compute (t_compute[w]), push gradient (serialized at PS recv port).
    Returns a list of (worker, t_update_applied, staleness_in_updates) and
    demonstrates Figure 4.2's behavior: no global barrier, staleness grows
    with worker-speed spread.
    """
    import heapq

    msg_cost = t_lat + size * t_tr
    ps_send_free = 0.0
    ps_recv_free = 0.0
    version = 0
    versions_at_pull = [0] * n
    updates: list[tuple] = []   # (worker, t_applied, staleness)
    # event queue: (time, seq, kind, worker); processed in global time order
    # so PS port reservations are FIFO-by-request-time (no future booking).
    q: list[tuple] = [(0.0, i, "pull", i) for i in range(n)]
    heapq.heapify(q)
    seq = n
    while q:
        t, _, kind, w = heapq.heappop(q)
        if t > horizon:
            continue
        if kind == "pull":
            t0 = max(t, ps_send_free)
            ps_send_free = t0 + msg_cost
            versions_at_pull[w] = version
            heapq.heappush(q, (t0 + msg_cost + t_compute[w], seq, "push", w))
        else:  # push
            t0 = max(t, ps_recv_free)
            ps_recv_free = t0 + msg_cost
            t_applied = t0 + msg_cost
            staleness = version - versions_at_pull[w]
            version += 1
            updates.append((w, t_applied, staleness))
            heapq.heappush(q, (t_applied, seq, "pull", w))
        seq += 1
    return sorted(updates, key=lambda u: u[1])


def sync_ps_throughput(n: int, *, t_compute_max: float, t_lat: float,
                       t_tr: float, size: float) -> float:
    """Updates/sec for the synchronous baseline (Figure 4.1): every round =
    max compute + full PS exchange; n gradient updates land per round."""
    round_time = t_compute_max + 2 * n * (t_lat + size * t_tr)
    return n / round_time
