"""N-worker data-parallel first-order training with swappable exchanges.

This is the paper-faithful algorithm tier: every worker has its own gradient
stream, compression randomness, error state and (for DSGD) model replica. The
worker axis is a real named axis — `jax.vmap(..., axis_name=...)` on one
device, or `shard_map` across host devices — so the very same communicator
code runs in simulation and on a real mesh.

Used by tests (convergence-rate claims), examples/quickstart.py, and
benchmarks/table1_1.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.communicators import GossipMix, MbSGDExchange

PyTree = Any
AXIS = "workers"


@dataclasses.dataclass(frozen=True)
class RunResult:
    losses: jnp.ndarray        # (steps,) f at the (averaged) iterate
    grad_norms: jnp.ndarray    # (steps,) ||f'(x_bar)||^2 (the paper's metric)
    params: PyTree             # final per-worker params, leading axis N
    consensus: jnp.ndarray     # (steps,) mean ||x_n - x_bar||^2 (DSGD Lemma 5.2.4)
    comm_bytes_per_step: float = 0.0   # measured wire bytes one worker puts
                                       # on the wire per iteration (codec-
                                       # measured; see Codec.wire_bytes)


def _broadcast(params: PyTree, n: int) -> PyTree:
    return jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p[None], (n,) + p.shape), params)


def run_distributed(
    loss_fn: Callable[[PyTree, Any], jnp.ndarray],
    full_loss_fn: Callable[[PyTree], jnp.ndarray],
    full_grad_fn: Callable[[PyTree], PyTree],
    params0: PyTree,
    sample_batch: Callable[[jax.Array], Any],
    *,
    n_workers: int,
    steps: int,
    lr: float,
    exchange: Any = None,
    gossip: Optional[GossipMix] = None,
    seed: int = 0,
) -> RunResult:
    """Run `steps` iterations of (C/EC/A/D-)SGD with `n_workers`.

    loss_fn(params, batch): one worker's minibatch loss.
    full_loss_fn / full_grad_fn: deterministic f and f' for metrics.
    sample_batch(key): draws one worker-minibatch (workers get split keys).
    exchange: gradient communicator (None + gossip => pure DSGD local step).
    gossip: optional model-mixing operator applied after the SGD update —
        stateless (GossipMix: params -> params) or stateful (DCD/ECD:
        exposes ``init_stacked(params_w)`` and threads replica state
        through the scan like an exchange does).
    """
    exchange = exchange if exchange is not None else MbSGDExchange()
    params_w = _broadcast(params0, n_workers)
    ex_state_w = jax.vmap(exchange.init)(params_w)
    stateful_gossip = gossip is not None and hasattr(gossip, "init_stacked")
    g_state_w = gossip.init_stacked(params_w) if stateful_gossip else ()
    root = jax.random.PRNGKey(seed)

    grad_local = jax.grad(loss_fn)

    def scan_body(carry, t):
        params_w, ex_state_w, g_state_w = carry
        step_key = jax.random.fold_in(root, t)
        keys = jax.random.split(step_key, n_workers)
        # exchanges consume the SAME base key on every worker for the shared
        # (server/broadcast) compression; worker-local keys come from fold_in
        # on axis_index inside the exchange. So pass the per-worker batch key
        # for sampling but the shared step_key for the exchange.
        def one(params, ex_state, g_state, bkey):
            batch = sample_batch(bkey)
            g = grad_local(params, batch)
            upd, ex_state = exchange(g, ex_state, step_key, axis_name=AXIS)
            new_params = jax.tree_util.tree_map(
                lambda p, u: p - lr * u, params, upd)
            if stateful_gossip:
                new_params, g_state = gossip(new_params, g_state, step_key,
                                             axis_name=AXIS)
            elif gossip is not None:
                new_params = gossip(new_params, axis_name=AXIS)
            return new_params, ex_state, g_state

        params_w, ex_state_w, g_state_w = jax.vmap(one, axis_name=AXIS)(
            params_w, ex_state_w, g_state_w, keys)
        x_bar = jax.tree_util.tree_map(lambda p: p.mean(0), params_w)
        loss = full_loss_fn(x_bar)
        g_bar = full_grad_fn(x_bar)
        gnorm = sum(jnp.sum(g**2) for g in jax.tree_util.tree_leaves(g_bar))
        cons = sum(
            jnp.sum((p - p.mean(0, keepdims=True)) ** 2) / p.shape[0]
            for p in jax.tree_util.tree_leaves(params_w))
        return (params_w, ex_state_w, g_state_w), (loss, gnorm, cons)

    (params_w, _, _), (losses, gnorms, cons) = lax.scan(
        scan_body, (params_w, ex_state_w, g_state_w), jnp.arange(steps))
    comm = 0.0
    if hasattr(exchange, "message_bytes"):
        comm += float(exchange.message_bytes(params0, n_workers=n_workers))
    if gossip is not None:
        comm += float(gossip.message_bytes(params0, n_workers=n_workers))
    return RunResult(losses, gnorms, params_w, cons, comm)


# ---------------------------------------------------------------------------
# Canonical testbed: distributed least squares (the paper's §1.1.3 example,
# F_m = 1/2 (a_m^T x - b_m)^2) with controllable inner variance sigma and
# outer (across-worker) variance varsigma — the knobs of Assumptions 2 and 6.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Quadratic:
    a: jnp.ndarray         # (M, d) design
    b: jnp.ndarray         # (M,) targets
    worker_slices: int     # workers partition rows (varsigma > 0) if > 1

    @staticmethod
    def make(key: jax.Array, *, m: int = 1024, d: int = 32,
             noise: float = 0.1, heterogeneity: float = 0.0,
             n_workers: int = 1) -> "Quadratic":
        k1, k2, k3, k4 = jax.random.split(key, 4)
        a = jax.random.normal(k1, (m, d)) / jnp.sqrt(d)
        x_true = jax.random.normal(k2, (d,))
        b = a @ x_true + noise * jax.random.normal(k3, (m,))
        if heterogeneity > 0:
            # shift each worker's targets -> nonzero outer variance varsigma
            shifts = heterogeneity * jax.random.normal(k4, (n_workers,))
            rows_per = m // n_workers
            b = b + jnp.repeat(shifts, rows_per, total_repeat_length=m)
        return Quadratic(a, b, n_workers)

    def full_loss(self, x: jnp.ndarray) -> jnp.ndarray:
        r = self.a @ x - self.b
        return 0.5 * jnp.mean(r**2)

    def full_grad(self, x: jnp.ndarray) -> jnp.ndarray:
        return jax.grad(self.full_loss)(x)

    def lipschitz(self) -> float:
        """L = lambda_max(A^T A / M)."""
        h = (self.a.T @ self.a) / self.a.shape[0]
        return float(jnp.linalg.eigvalsh(h)[-1])

    def minimum(self) -> jnp.ndarray:
        sol = jnp.linalg.lstsq(self.a, self.b)[0]
        return self.full_loss(sol)

    def loss_on(self, x: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
        r = self.a[idx] @ x - self.b[idx]
        return 0.5 * jnp.mean(r**2)

    def make_sampler(self, batch: int, *, worker_partition: bool = False,
                     n_workers: int = 1) -> Callable[[jax.Array], jnp.ndarray]:
        """Returns sample_batch(key) -> row indices.

        worker_partition=True gives each worker a disjoint row range
        (decentralized data, D_n of Eq. 3.7) keyed by axis_index.
        """
        m = self.a.shape[0]
        if not worker_partition:
            return lambda key: jax.random.randint(key, (batch,), 0, m)

        rows_per = m // n_workers

        def sampler(key):
            w = lax.axis_index(AXIS)
            lo = w * rows_per
            return lo + jax.random.randint(key, (batch,), 0, rows_per)

        return sampler


class LocalExchange:
    """No gradient exchange: plain local SGD step (the D/DCD/ECD-SGD
    gradient tier — all communication happens in the gossip operator)."""

    name = "local"

    def init(self, params):
        return ()

    def __call__(self, grad, state, key, *, axis_name):
        return grad, state


def run_quadratic(method: str, *, n_workers: int = 8, steps: int = 300,
                  lr: float = 0.1, batch: int = 4, seed: int = 0,
                  d: int = 32, heterogeneity: float = 0.0,
                  exchange_kw: dict | None = None,
                  gossip_topology: str | None = None,
                  gossip_w=None) -> RunResult:
    """One-call driver used by tests/benchmarks: method in
    {gd, sgd, mbsgd, csgd_ps, csgd_ring, ecsgd, asgd, dsgd, dcd, ecd}.

    dsgd/dcd/ecd accept ``gossip_topology`` in {'ring', 'torus', 'full'}
    or an explicit doubly stochastic ``gossip_w`` matrix (any
    ``mixing.py`` matrix — lowered to ppermutes via the Birkhoff
    decomposition); dcd/ecd route their neighbor deltas through the
    fused flat Codec path (``exchange_kw={'compressor': ...}`` picks the
    codec). ``asgd`` accepts ``exchange_kw={'schedule': ...}`` to replay
    a measured per-step staleness table from the cluster scheduler.
    ``d`` sets the quadratic's dimension (wire-byte assertions want
    trees big enough to amortize the packed format's lane padding)."""
    from repro.core import communicators as C

    key = jax.random.PRNGKey(seed)
    prob = Quadratic.make(key, d=d, n_workers=n_workers,
                          heterogeneity=heterogeneity)
    x0 = jnp.zeros(prob.a.shape[1])
    exchange_kw = dict(exchange_kw or {})

    gossip = None
    if method == "gd":
        exchange, n_workers, sampler = C.MbSGDExchange(), 1, (
            lambda key: jnp.arange(prob.a.shape[0]))
    elif method in ("sgd", "mbsgd"):
        exchange = C.MbSGDExchange()
        n_workers = 1 if method == "sgd" else n_workers
        sampler = prob.make_sampler(batch)
    elif method == "csgd_ps":
        exchange = C.CSGDPSExchange(**exchange_kw)
        sampler = prob.make_sampler(batch)
    elif method == "csgd_ring":
        exchange = C.CSGDRingExchange(**exchange_kw)
        sampler = prob.make_sampler(batch)
    elif method == "ecsgd":
        exchange = C.ECSGDExchange(**exchange_kw)
        sampler = prob.make_sampler(batch)
    elif method == "asgd":
        exchange = C.DelayedExchange(inner=C.MbSGDExchange(), **exchange_kw)
        sampler = prob.make_sampler(batch)
    elif method == "dsgd":
        # DSGD does NOT all-reduce gradients: local step + gossip
        exchange = LocalExchange()
        gossip = GossipMix(topology=gossip_topology or "ring", w=gossip_w)
        sampler = prob.make_sampler(batch, worker_partition=True,
                                    n_workers=n_workers)
    elif method in ("dcd", "ecd"):
        exchange = LocalExchange()
        cls = C.DCDGossipExchange if method == "dcd" else C.ECDGossipExchange
        gossip = cls(topology=gossip_topology or "ring", w=gossip_w,
                     **exchange_kw)
        sampler = prob.make_sampler(batch, worker_partition=True,
                                    n_workers=n_workers)
    else:
        raise ValueError(f"unknown method {method}")

    return run_distributed(
        prob.loss_on, prob.full_loss, prob.full_grad, x0, sampler,
        n_workers=n_workers, steps=steps, lr=lr, exchange=exchange,
        gossip=gossip, seed=seed)
