"""Gossip (confusion) matrices W for decentralized SGD (Section 5).

Assumption 7 requires W symmetric, doubly stochastic, with spectral gap
1 - rho > 0 where rho = max_{n>=2} |lambda_n(W)|. The paper's examples:
  W1 = 11^T / N            (fully connected,  rho = 0)
  W2 = ring, self+2 nbrs   (rho ~= 1 - 16 pi^2 / (3 N^2) for large N)
  W3 = disconnected        (rho = 1, DSGD does NOT converge)
"""
from __future__ import annotations

import numpy as np


def fully_connected(n: int) -> np.ndarray:
    return np.full((n, n), 1.0 / n)


def ring(n: int) -> np.ndarray:
    """Paper's W2: average of self + immediate left/right neighbors."""
    w = np.zeros((n, n))
    for i in range(n):
        w[i, i] = 1.0 / 3.0
        w[i, (i + 1) % n] = 1.0 / 3.0
        w[i, (i - 1) % n] = 1.0 / 3.0
    if n == 1:
        w[0, 0] = 1.0
    if n == 2:
        # self + one neighbor twice -> 1/3 + 2/3
        w = np.array([[1 / 3, 2 / 3], [2 / 3, 1 / 3]])
    return w


def torus_2d(rows: int, cols: int) -> np.ndarray:
    """4-neighbor 2-D torus gossip (beyond-paper topology; deg(G) = 4)."""
    n = rows * cols
    w = np.zeros((n, n))
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            nbrs = {
                ((r + 1) % rows) * cols + c,
                ((r - 1) % rows) * cols + c,
                r * cols + (c + 1) % cols,
                r * cols + (c - 1) % cols,
            } - {i}
            for j in nbrs:
                w[i, j] = 1.0 / (len(nbrs) + 1)
            w[i, i] = 1.0 - w[i].sum()
    return w


def disconnected(n: int) -> np.ndarray:
    """Paper's W3: block-diagonal, rho = 1, provably non-mixing."""
    w = np.eye(n)
    if n >= 3:
        w[: n - 1, : n - 1] = fully_connected(n - 1)
        w[n - 1, n - 1] = 1.0
    return w


def spectral_rho(w: np.ndarray) -> float:
    """rho = second largest |eigenvalue| (Assumption 7)."""
    eig = np.sort(np.abs(np.linalg.eigvalsh(w)))[::-1]
    return float(eig[1]) if eig.shape[0] > 1 else 0.0


def check_assumption7(w: np.ndarray, *, atol: float = 1e-8) -> None:
    """Raise if W violates symmetry / double-stochasticity / spectral gap."""
    if not np.allclose(w, w.T, atol=atol):
        raise ValueError("W is not symmetric")
    if not np.allclose(w.sum(axis=0), 1.0, atol=atol):
        raise ValueError("W is not doubly stochastic (columns)")
    if not np.allclose(w.sum(axis=1), 1.0, atol=atol):
        raise ValueError("W is not doubly stochastic (rows)")
    if (w < -atol).any():
        raise ValueError("W has negative entries")
    if spectral_rho(w) >= 1.0 - 1e-12:
        raise ValueError("W has no spectral gap (rho = 1): network disconnected")


def ring_rho_paper_estimate(n: int) -> float:
    """Paper's closed-form estimate rho ~= 1 - 16 pi^2 / (3 N^2)."""
    return 1.0 - 16.0 * np.pi**2 / (3.0 * n**2)


def degree(w: np.ndarray) -> int:
    """deg(G): max off-diagonal nonzeros per row (Table 1.1 comm cost)."""
    off = (np.abs(w) > 1e-12).sum(axis=1) - 1
    return int(off.max())
