"""Gossip (confusion) matrices W for decentralized SGD (Section 5).

Assumption 7 requires W symmetric, doubly stochastic, with spectral gap
1 - rho > 0 where rho = max_{n>=2} |lambda_n(W)|. The paper's examples:
  W1 = 11^T / N            (fully connected,  rho = 0)
  W2 = ring, self+2 nbrs   (rho ~= 1 - 16 pi^2 / (3 N^2) for large N)
  W3 = disconnected        (rho = 1, DSGD does NOT converge)
"""
from __future__ import annotations

from typing import Optional

import numpy as np


def fully_connected(n: int) -> np.ndarray:
    return np.full((n, n), 1.0 / n)


def ring(n: int) -> np.ndarray:
    """Paper's W2: average of self + immediate left/right neighbors."""
    w = np.zeros((n, n))
    for i in range(n):
        w[i, i] = 1.0 / 3.0
        w[i, (i + 1) % n] = 1.0 / 3.0
        w[i, (i - 1) % n] = 1.0 / 3.0
    if n == 1:
        w[0, 0] = 1.0
    if n == 2:
        # self + one neighbor twice -> 1/3 + 2/3
        w = np.array([[1 / 3, 2 / 3], [2 / 3, 1 / 3]])
    return w


def torus_2d(rows: int, cols: int) -> np.ndarray:
    """4-neighbor 2-D torus gossip (beyond-paper topology; deg(G) = 4)."""
    n = rows * cols
    w = np.zeros((n, n))
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            nbrs = {
                ((r + 1) % rows) * cols + c,
                ((r - 1) % rows) * cols + c,
                r * cols + (c + 1) % cols,
                r * cols + (c - 1) % cols,
            } - {i}
            for j in nbrs:
                w[i, j] = 1.0 / (len(nbrs) + 1)
            w[i, i] = 1.0 - w[i].sum()
    return w


def near_square_factors(n: int) -> tuple[int, int]:
    """(rows, cols) with rows*cols = n, rows the largest divisor <= sqrt(n)
    (how GossipMix folds a 1-D worker axis onto a 2-D torus)."""
    r = int(np.sqrt(n))
    while n % r:
        r -= 1
    return r, n // r


def disconnected(n: int) -> np.ndarray:
    """Paper's W3: block-diagonal, rho = 1, provably non-mixing."""
    w = np.eye(n)
    if n >= 3:
        w[: n - 1, : n - 1] = fully_connected(n - 1)
        w[n - 1, n - 1] = 1.0
    return w


def spectral_rho(w: np.ndarray) -> float:
    """rho = second largest |eigenvalue| (Assumption 7)."""
    eig = np.sort(np.abs(np.linalg.eigvalsh(w)))[::-1]
    return float(eig[1]) if eig.shape[0] > 1 else 0.0


def check_assumption7(w: np.ndarray, *, atol: float = 1e-8) -> None:
    """Raise if W violates symmetry / double-stochasticity / spectral gap."""
    if not np.allclose(w, w.T, atol=atol):
        raise ValueError("W is not symmetric")
    if not np.allclose(w.sum(axis=0), 1.0, atol=atol):
        raise ValueError("W is not doubly stochastic (columns)")
    if not np.allclose(w.sum(axis=1), 1.0, atol=atol):
        raise ValueError("W is not doubly stochastic (rows)")
    if (w < -atol).any():
        raise ValueError("W has negative entries")
    if spectral_rho(w) >= 1.0 - 1e-12:
        raise ValueError("W has no spectral gap (rho = 1): network disconnected")


def ring_rho_paper_estimate(n: int) -> float:
    """Paper's closed-form estimate rho ~= 1 - 16 pi^2 / (3 N^2)."""
    return 1.0 - 16.0 * np.pi**2 / (3.0 * n**2)


def degree(w: np.ndarray) -> int:
    """deg(G): max off-diagonal nonzeros per row (Table 1.1 comm cost)."""
    off = (np.abs(w) > 1e-12).sum(axis=1) - 1
    return int(off.max())


def _perfect_matching(support: np.ndarray) -> Optional[list]:
    """Kuhn's augmenting-path matching on a boolean (dst, src) support
    matrix. Returns match[dst] = src covering every row, or None."""
    n = support.shape[0]
    match_of_src = [-1] * n   # src -> dst

    def try_row(dst: int, seen: list) -> bool:
        for src in range(n):
            if support[dst, src] and not seen[src]:
                seen[src] = True
                if match_of_src[src] < 0 or try_row(match_of_src[src], seen):
                    match_of_src[src] = dst
                    return True
        return False

    for dst in range(n):
        if not try_row(dst, [False] * n):
            return None
    match = [-1] * n
    for src, dst in enumerate(match_of_src):
        match[dst] = src
    return match


def birkhoff_decomposition(w: np.ndarray, *, atol: float = 1e-9
                           ) -> list[tuple[float, tuple]]:
    """Birkhoff-von Neumann: W = sum_k c_k P_k with c_k > 0, sum c_k = 1.

    Each term is ``(c_k, perm_k)`` where ``perm_k`` is a tuple of
    ``(src, dst)`` pairs (the ``lax.ppermute`` convention: value moves
    src -> dst, so P_k[dst, src] = 1 and (P_k x)_dst = x_src). Every
    perm is FULL (fixed points appear as (i, i) — ppermute requires a
    complete permutation of the axis); the identity term carries
    ``perm_k = ()`` so callers skip the collective entirely.

    This is how an arbitrary doubly stochastic gossip matrix is lowered
    onto collective hardware: one ppermute per non-identity permutation,
    scaled by the scalar c_k (GossipMix consumes this). Greedy peeling via
    perfect matchings on the remaining support; terminates because W
    doubly stochastic keeps every remainder/total doubly stochastic
    (Birkhoff's theorem) and each peel zeroes >= 1 entry.
    """
    w = np.array(w, dtype=float)
    if (w < -atol).any():
        raise ValueError("W has negative entries")
    if not (np.allclose(w.sum(0), 1.0, atol=1e-6)
            and np.allclose(w.sum(1), 1.0, atol=1e-6)):
        raise ValueError("W is not doubly stochastic")
    n = w.shape[0]
    terms: list[tuple[float, tuple]] = []
    remaining = w.copy()
    for _ in range(n * n + 1):
        if remaining.max() <= atol:
            break
        match = _perfect_matching(remaining > atol)
        if match is None:   # numerically exhausted support
            break
        c = float(min(remaining[dst, match[dst]] for dst in range(n)))
        if all(match[dst] == dst for dst in range(n)):
            perm: tuple = ()
        else:
            perm = tuple((match[dst], dst) for dst in range(n))
        terms.append((c, perm))
        for dst in range(n):
            remaining[dst, match[dst]] -= c
    total = sum(c for c, _ in terms)
    if abs(total - 1.0) > 1e-6:
        raise ValueError(f"decomposition lost mass: sum c_k = {total}")
    return terms
