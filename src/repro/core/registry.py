"""THE registry idiom: one name -> entry table for every pluggable tier.

Four registries grew up independently — ``EXCHANGES``/``make_exchange``
(core.communicators), ``PROTOCOLS``/``make_protocol``
(cluster.protocols), the codec table (core.compression) and the
Byzantine aggregator table (cluster.aggregators) — each hand-rolling
the same dict lookup and its own flavor of "unknown X" error text. This
module is the single implementation they all share:

    CODECS = Registry("compression", {...})
    CODECS.get("rq8")            # stored entry, as-is (instances, fns)
    EXCHANGES.make("csgd_ring", compressor="rq4")   # factory call
    @PROTOCOLS.register("laq")   # decorator registration
    class LAQ: ...

``Registry`` is a ``Mapping``, so every existing call-site idiom keeps
working unchanged: ``sorted(EXCHANGES)``, ``"gossip" in EXCHANGES``,
``PROTOCOLS.items()``, ``AGGREGATORS[name]``. Lookup failures raise a
uniform ``KeyError`` naming the registry kind and listing the valid
choices — the error contract the four hand-rolled versions each
re-implemented (and tests match on).
"""
from __future__ import annotations

from collections.abc import Mapping
from typing import Any, Callable, Iterator, Optional


class Registry(Mapping):
    """An ordered name -> entry table with uniform error reporting.

    kind:    the human name used in error text ("exchange", "protocol",
             "compression", "aggregator").
    entries: optional initial {name: entry} dict. Entries may be
             factories (classes/callables ``make`` instantiates) or
             ready objects (codec instances, plain functions) returned
             verbatim by ``get``.
    """

    def __init__(self, kind: str,
                 entries: Optional[dict[str, Any]] = None):
        self.kind = kind
        self._entries: dict[str, Any] = dict(entries or {})

    # -- Mapping protocol (keeps dict-shaped call sites working) ---------

    def __getitem__(self, name: str) -> Any:
        try:
            return self._entries[name]
        except KeyError:
            raise self._unknown(name) from None

    def __iter__(self) -> Iterator[str]:
        return iter(self._entries)

    def __len__(self) -> int:
        return len(self._entries)

    def __repr__(self) -> str:
        return f"Registry({self.kind!r}, {self.names()})"

    # -- the shared idiom -------------------------------------------------

    def _unknown(self, name: str) -> KeyError:
        return KeyError(f"unknown {self.kind} '{name}'; "
                        f"have {self.names()}")

    def names(self) -> list[str]:
        """Sorted valid choices (what the KeyError lists)."""
        return sorted(self._entries)

    def register(self, name: str, entry: Any = None):
        """Register an entry, or use as a decorator when entry is None.

        Duplicate names raise — two tiers silently fighting over a
        registry slot is exactly the bug a shared registry exists to
        prevent; re-registration must be an explicit ``replace``.
        """
        if entry is None:
            return lambda e: self.register(name, e) or e
        if name in self._entries:
            raise ValueError(
                f"{self.kind} '{name}' already registered")
        self._entries[name] = entry

    def replace(self, name: str, entry: Any) -> None:
        """Overwrite an existing entry (tests swapping in doubles)."""
        if name not in self._entries:
            raise self._unknown(name)
        self._entries[name] = entry

    def get(self, name: str) -> Any:  # type: ignore[override]
        """The stored entry, verbatim — for registries of ready objects
        (codec instances, aggregator functions)."""
        return self[name]

    def make(self, name: str, **kw) -> Any:
        """Instantiate a factory entry: ``registry[name](**kw)`` — for
        registries of classes (exchanges, protocols)."""
        return self[name](**kw)


def make_factory(registry: Registry) -> Callable[..., Any]:
    """A module-level ``make_<kind>(name, **kw)`` bound to a registry
    (the public spelling the exchange/protocol tiers already export)."""

    def make(name: str, **kw) -> Any:
        return registry.make(name, **kw)

    make.__name__ = f"make_{registry.kind}"
    make.__doc__ = (f"Instantiate a registered {registry.kind}: "
                    f"``{registry.kind.upper()}S[name](**kw)``.")
    return make
