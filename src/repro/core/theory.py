"""Analytic complexity + learning-rate rules from the paper's theorems.

These are the closed forms behind Tables 1.1 and 1.2. The benchmark
`benchmarks/table1_1.py` prints them next to the event-simulator measurements
and the empirical iterations-to-epsilon from the quadratic testbed.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class Workload:
    L: float = 1.0            # Lipschitz gradient constant
    sigma: float = 1.0        # stochastic-gradient std (Assumption 2)
    sigma_c: float = 0.5      # compression-induced std sigma' (Assumption 4)
    varsigma: float = 0.5     # outer/data variance among workers (Assumption 6)
    f_gap: float = 1.0        # f(x1) - f*
    M: int = 10_000           # dataset size
    d: int = 1_000_000        # model dimension


# --- Table 1.2: iteration / query complexity (to average grad-norm <= eps) ---

def gd_iterations(w: Workload, eps: float) -> float:
    return w.f_gap * w.L / eps


def gd_queries(w: Workload, eps: float) -> float:
    return w.M * gd_iterations(w, eps)


def sgd_iterations(w: Workload, eps: float) -> float:
    return w.f_gap * (w.L / eps + w.L * w.sigma**2 / eps**2)


def mbsgd_iterations(w: Workload, eps: float, batch: int) -> float:
    return w.f_gap * (w.L / eps + w.L * w.sigma**2 / (batch * eps**2))


def mbsgd_queries(w: Workload, eps: float, batch: int) -> float:
    return batch * mbsgd_iterations(w, eps, batch)


# --- Table 1.1: iterations for each system relaxation (N workers) ---

def dist_sgd_iterations(w: Workload, eps: float, n: int) -> float:
    """mb-SGD baseline, Eq. (2.2): O(1/eps + sigma^2/(N eps^2))."""
    return w.f_gap * (1.0 / eps + w.sigma**2 / (n * eps**2))


def csgd_iterations(w: Workload, eps: float, n: int) -> float:
    """Eq. (3.6): adds the compression-variance term sigma'^2/eps^2."""
    return w.f_gap * (1.0 / eps + w.sigma**2 / (n * eps**2)
                      + w.sigma_c**2 / eps**2)


def ecsgd_iterations(w: Workload, eps: float, n: int) -> float:
    """Thm 3.4.2: 1/T + sigma/sqrt(TN) + sigma'^{2/3}/T^{2/3}  =>  solve for T."""
    return w.f_gap * (1.0 / eps + w.sigma**2 / (n * eps**2)
                      + w.sigma_c / eps ** 1.5)


def asgd_iterations(w: Workload, eps: float, n: int, tau: float | None = None) -> float:
    """Thm 4.2.2 with tau ~ N (paper: staleness proportional to #workers)."""
    tau = float(n) if tau is None else tau
    return w.f_gap * ((tau + 1.0) / eps + w.sigma**2 / (n * eps**2))


def dsgd_iterations(w: Workload, eps: float, n: int, rho: float) -> float:
    """Thm 5.2.6: 1/T + sigma/sqrt(NT) + (varsigma rho/((1-rho)T))^{2/3}."""
    return w.f_gap * (1.0 / eps + w.sigma**2 / (n * eps**2)
                      + (w.varsigma * rho / max(1e-12, 1.0 - rho)) / eps ** 1.5)


# --- Table 1.1: communication cost per iteration (alpha latency, beta bw) ---

def comm_cost_ps(n: int, alpha: float, beta: float) -> float:
    return 2 * n * (alpha + beta)


def comm_cost_allreduce(n: int, alpha: float, beta: float) -> float:
    return 2 * n * alpha + 2 * beta


def comm_cost_compressed(n: int, alpha: float, beta: float, eta: float) -> float:
    """Compression ratio eta < 1 scales only the bandwidth term."""
    return 2 * n * alpha + 2 * beta * eta


def comm_cost_decentralized(deg: int, alpha: float, beta: float) -> float:
    return deg * (alpha + beta)


# --- learning-rate rules (used by the optimizers' `paper_lr` helpers) ---

def lr_gd(w: Workload) -> float:
    return 1.0 / w.L                                        # Thm 1.1.1


def lr_sgd(w: Workload, T: int) -> float:
    return 1.0 / (w.L + w.sigma * math.sqrt(T * w.L))       # Thm 1.2.1


def lr_csgd(w: Workload, T: int) -> float:
    return 1.0 / (w.L + w.sigma_c * math.sqrt(T * w.L))     # Eq. (3.5)


def lr_ecsgd(w: Workload, T: int, n: int) -> float:
    return 1.0 / (2 * w.L + math.sqrt(T / n) * w.sigma
                  + T ** (1 / 3) * w.sigma_c ** (2 / 3))    # Thm 3.4.2


def lr_asgd(w: Workload, T: int, tau: float) -> float:
    return 1.0 / (w.L * (tau + 1) + math.sqrt(T * w.L) * w.sigma)  # Eq. (4.10)


def lr_dsgd(w: Workload, T: int, n: int, rho: float) -> float:
    return 1.0 / (1.0 + math.sqrt(T * n) * w.sigma
                  + T ** (1 / 3) * w.varsigma ** (2 / 3)
                  * rho ** (2 / 3) * (1 - rho) ** (-2 / 3))  # Thm 5.2.6
