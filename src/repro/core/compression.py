"""Lossy compression codecs Q(.) from Section 3 of the paper.

The central abstraction is the **Codec**: one object per operator owning

  encode(x, key)  -> Packed     the wire object (uint8 payload + params)
  decode(packed)  -> x_hat      dequantize a wire object
  qdq(x, key)     -> x_hat      fused encode+decode (what update rules eat)
  wire_bytes(x)   -> float      MEASURED bytes of encode(x)'s arrays

For the quantizer family (rq8/rq4/rq2) encode really packs sub-byte
codes into a uint8 payload (kernels/quant: Pallas on TPU, jnp reference
elsewhere) and `decode(encode(x, key)) == qdq(x, key)` bit-for-bit, so
communicators can ship the Packed payload through collectives whenever
the algebra allows (ring hops) and fall back to qdq where a summation
needs fp32 (PS reduce) without changing the math. Operators with no
packed implementation yet (sparsifiers, sign, clipping) are qdq-only
codecs: `packable` is False and wire_bytes comes from the static spec.

On top of the per-leaf tier sits the **fused flat-buffer tier** (the
production default): a `FlatLayout` flattens the whole gradient pytree
into ONE contiguous fp32 buffer, segments it into size-capped buckets
each owning a `(lo, scale)` row of an `(n_buckets, 2)` params array, and
`tree_encode_flat` / `tree_decode_flat` / `tree_qdq_flat` move the whole
tree as ONE `FlatPacked` message — one kernel launch, one params
reduction, at most one pad granule, and 2 arrays per collective instead
of 2 per leaf. In the paper's §1.3 switch model every message pays a
fixed `t_lat`, so per-leaf messaging costs `2N*L*t_lat` per ring
exchange while the fused tier pays `2N*t_lat`; eventsim's `n_messages`
knob makes that gap measurable. The per-leaf paths remain the reference
the fused tier is tested against (bit-identical per bucket).

`CompressionSpec` remains the static metadata *inside* each codec; the
cost-model consumers (eventsim / roofline / table1_1 / comm_patterns)
take `Codec.wire_bytes(...)`, which for packable codecs is measured from
the actual payload shapes (eval_shape — no compute), so every downstream
byte count traces to the real wire format.

Unbiased operators satisfy E[Q(x)] = x (Assumption 3); every operator
reports its wire-format cost so the event simulator / roofline collective
term can account for the actual bytes moved (compression changes
*transfer time*, never latency — Figure 3.4/3.5).

All randomness is explicit (jax.random keys) so runs are reproducible and
the operators are usable inside jit/shard_map.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionSpec:
    """Static description of a compression operator.

    name:        registry key.
    unbiased:    whether E[Q(x)] = x (Assumption 3). CSGD requires True;
                 EC-SGD works either way (Section 3.3).
    bits_per_el: wire bits per *kept* element (payload).
    density:     fraction of elements kept (1.0 for quantizers).
    overhead_bytes: per-message header (scales, indices bookkeeping).
    """

    name: str
    unbiased: bool
    bits_per_el: float
    density: float = 1.0
    overhead_bytes: int = 8

    def compressed_bytes(self, n_elements: int) -> float:
        """Wire bytes for a message of n_elements (fp32 baseline = 4n)."""
        payload = n_elements * self.density * self.bits_per_el / 8.0
        if self.density < 1.0:
            # sparse formats also ship indices (4 bytes each)
            payload += n_elements * self.density * 4.0
        return payload + self.overhead_bytes

    def ratio(self, n_elements: int) -> float:
        """Compression ratio eta < 1 relative to fp32 (paper's Table 1.1)."""
        return self.compressed_bytes(n_elements) / (4.0 * n_elements)


# Fused flat-buffer tier: elements per quantization bucket. One bucket =
# one (lo, scale) row in the FlatPacked params array; 4Mi elements keeps a
# 100M-param gradient at ~30 rows. Single source of truth lives next to
# the bucketed kernels.
from repro.kernels.quant.ops import DEFAULT_BUCKET_ELEMS  # noqa: E402


# ---------------------------------------------------------------------------
# The flat layout: static element offsets for the fused (whole-pytree)
# wire format.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FlatLayout:
    """Static offset table mapping a pytree onto ONE contiguous fp32 buffer.

    Computed once from the treedef + leaf shapes (cheap; shapes are static
    under jit): leaf i occupies flat[offsets[i] : offsets[i] + sizes[i]],
    reshaped to shapes[i] and cast back to dtypes[i] on unflatten.
    `unflatten(flatten(tree))` is bit-exact for float leaves (fp32 round
    trips exactly; bf16 -> fp32 -> bf16 is the identity).

    Frozen + hashable so it can ride in FlatPacked's static pytree aux and
    key jit caches.
    """

    treedef: Any
    shapes: tuple          # tuple[tuple[int, ...], ...]
    dtypes: tuple          # tuple[np.dtype, ...]
    offsets: tuple         # element offset of each leaf in the flat buffer
    sizes: tuple           # element count of each leaf
    total: int             # sum(sizes)

    @classmethod
    def from_tree(cls, tree) -> "FlatLayout":
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        shapes = tuple(tuple(leaf.shape) for leaf in leaves)
        dtypes = tuple(jnp.dtype(getattr(leaf, "dtype", jnp.float32))
                       for leaf in leaves)
        sizes, offsets, off = [], [], 0
        for shape in shapes:
            n = 1
            for d in shape:
                n *= d
            sizes.append(n)
            offsets.append(off)
            off += n
        return cls(treedef, shapes, dtypes, tuple(offsets), tuple(sizes),
                   off)

    @property
    def n_leaves(self) -> int:
        return len(self.shapes)

    def flatten(self, tree) -> jnp.ndarray:
        """Pytree -> one contiguous (total,) fp32 buffer."""
        leaves = jax.tree_util.tree_leaves(tree)
        return jnp.concatenate(
            [leaf.reshape(-1).astype(jnp.float32) for leaf in leaves])

    def unflatten(self, flat: jnp.ndarray):
        """(total,) buffer -> pytree with the original shapes/dtypes."""
        leaves = [
            flat[o:o + n].reshape(shape).astype(dtype)
            for o, n, shape, dtype in zip(self.offsets, self.sizes,
                                          self.shapes, self.dtypes)
        ]
        return jax.tree_util.tree_unflatten(self.treedef, leaves)


# ---------------------------------------------------------------------------
# The wire objects
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Packed:
    """A compressed message as it would travel on the wire.

    payload: uint8 array of packed codes (the bulk bytes).
    params:  small fp32 array of dequantization params (the header).
    shape/dtype: static metadata to restore the original leaf.
    codec:   registry name of the codec that produced it.

    Registered as a pytree whose children are (payload, params), so a
    Packed (or a tree of them) moves through ``lax.ppermute``, ``vmap``
    and ``lax.fori_loop`` carries like any other array bundle.
    """

    payload: jnp.ndarray
    params: jnp.ndarray
    shape: tuple
    dtype: Any
    codec: str

    def tree_flatten(self):
        return (self.payload, self.params), (self.shape, self.dtype,
                                             self.codec)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)

    @property
    def wire_bytes(self) -> int:
        """Measured size: payload bytes + header (params) bytes."""
        payload = self.payload.size * jnp.dtype(self.payload.dtype).itemsize
        header = self.params.size * jnp.dtype(self.params.dtype).itemsize
        return int(payload + header)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class FlatPacked:
    """ONE compressed message for a whole pytree (the fused wire object).

    payload: (rows_kept, 512) uint8 — the bucketed packed codes of the
             entire flat buffer (at most one pad granule, at the very end).
    params:  (n_buckets, 2) fp32 — one [lo, scale] row per bucket.
    layout:  the FlatLayout that unflattens the decode back into the tree.
    codec / bucket_elems: static decode metadata.

    Registered as a pytree whose children are (payload, params): a ring hop
    ppermutes exactly TWO arrays per exchange — one payload, one header —
    instead of two per pytree leaf.
    """

    payload: jnp.ndarray
    params: jnp.ndarray
    layout: FlatLayout
    codec: str
    bucket_elems: int

    def tree_flatten(self):
        return (self.payload, self.params), (self.layout, self.codec,
                                             self.bucket_elems)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)

    @property
    def wire_bytes(self) -> int:
        """Measured size: payload bytes + header (params) bytes."""
        payload = self.payload.size * jnp.dtype(self.payload.dtype).itemsize
        header = self.params.size * jnp.dtype(self.params.dtype).itemsize
        return int(payload + header)


# ---------------------------------------------------------------------------
# Codecs
# ---------------------------------------------------------------------------


class Codec:
    """One compression operator: packed wire format + fused qdq.

    Subclasses set `spec` and implement `qdq`; packable codecs also
    implement `encode`/`decode` with decode(encode(x, k)) == qdq(x, k).
    """

    spec: CompressionSpec
    packable: bool = False

    @property
    def name(self) -> str:
        return self.spec.name

    # -- single leaf ------------------------------------------------------

    def qdq(self, x: jnp.ndarray, key: Optional[jax.Array]) -> jnp.ndarray:
        raise NotImplementedError

    def encode(self, x: jnp.ndarray, key: Optional[jax.Array]) -> Packed:
        raise NotImplementedError(
            f"codec '{self.name}' has no packed wire format; use qdq")

    def decode(self, packed: Packed) -> jnp.ndarray:
        raise NotImplementedError(
            f"codec '{self.name}' has no packed wire format; use qdq")

    def wire_bytes(self, x) -> float:
        """Measured wire bytes for one leaf (array / ShapeDtypeStruct)."""
        if not self.packable:
            return self.spec.compressed_bytes(x.size)
        leaf = jax.ShapeDtypeStruct(x.shape, getattr(x, "dtype", jnp.float32))
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
        out = jax.eval_shape(self.encode, leaf, key)
        return float(out.wire_bytes)

    def wire_bytes_for(self, n_elements: int) -> float:
        """Measured wire bytes for a flat fp32 message of n elements."""
        return self.wire_bytes(
            jax.ShapeDtypeStruct((int(n_elements),), jnp.float32))

    # -- fused flat-buffer tier -------------------------------------------
    #
    # One message per exchange instead of one per pytree leaf: the tree is
    # flattened onto a FlatLayout, quantized per size-capped bucket in a
    # single kernel pass, and shipped as ONE FlatPacked. The per-leaf
    # methods above remain the reference the fused path is tested against.

    def flat_qdq(self, flat: jnp.ndarray, key: Optional[jax.Array], *,
                 bucket_elems: int = DEFAULT_BUCKET_ELEMS) -> jnp.ndarray:
        """Fused qdq over one flat fp32 buffer (one message's worth).

        Base implementation: a single application of the operator to the
        whole buffer — qdq-only codecs get the fused (one-pass, one-
        message) semantics for free. QuantCodec overrides this with the
        bucketed kernel."""
        del bucket_elems
        return self.qdq(flat, key)

    def flat_encode(self, flat: jnp.ndarray, key: Optional[jax.Array],
                    layout: FlatLayout, *,
                    bucket_elems: int = DEFAULT_BUCKET_ELEMS) -> FlatPacked:
        raise NotImplementedError(
            f"codec '{self.name}' has no packed wire format; use flat_qdq")

    def flat_decode(self, packed: FlatPacked) -> jnp.ndarray:
        raise NotImplementedError(
            f"codec '{self.name}' has no packed wire format; use flat_qdq")

    def tree_qdq_flat(self, tree, key: Optional[jax.Array], *,
                      bucket_elems: int = DEFAULT_BUCKET_ELEMS):
        """Whole-tree fused qdq through the flat buffer (one pass)."""
        layout = FlatLayout.from_tree(tree)
        flat = self.flat_qdq(layout.flatten(tree), key,
                             bucket_elems=bucket_elems)
        return layout.unflatten(flat)

    def tree_encode_flat(self, tree, key: Optional[jax.Array], *,
                         bucket_elems: int = DEFAULT_BUCKET_ELEMS
                         ) -> FlatPacked:
        """Whole tree -> ONE FlatPacked wire message."""
        layout = FlatLayout.from_tree(tree)
        return self.flat_encode(layout.flatten(tree), key, layout,
                                bucket_elems=bucket_elems)

    def tree_decode_flat(self, packed: FlatPacked):
        """Inverse of tree_encode_flat (FlatPacked -> tree of arrays)."""
        return packed.layout.unflatten(self.flat_decode(packed))

    def tree_wire_bytes_flat(self, tree, *,
                             bucket_elems: int = DEFAULT_BUCKET_ELEMS
                             ) -> float:
        """Measured wire bytes of the ONE fused message for `tree`."""
        layout = FlatLayout.from_tree(tree)
        if not self.packable:
            # one message -> one static-spec header, not one per leaf
            return self.spec.compressed_bytes(layout.total)
        flat = jax.ShapeDtypeStruct((layout.total,), jnp.float32)
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
        out = jax.eval_shape(
            partial(self.flat_encode, layout=layout,
                    bucket_elems=bucket_elems), flat, key)
        return float(out.wire_bytes)

    # -- pytrees ----------------------------------------------------------

    def tree_qdq(self, tree, key: jax.Array):
        return tree_compress(tree, key, self.qdq)

    def tree_encode(self, tree, key: jax.Array):
        """Leaf-wise encode with independent keys -> tree of Packed."""
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        keys = jax.random.split(key, len(leaves))
        out = [self.encode(leaf, k) for leaf, k in zip(leaves, keys)]
        return jax.tree_util.tree_unflatten(treedef, out)

    def tree_decode(self, tree):
        """Inverse of tree_encode (tree of Packed -> tree of arrays)."""
        return jax.tree_util.tree_map(
            self.decode, tree, is_leaf=lambda n: isinstance(n, Packed))

    def tree_wire_bytes(self, tree) -> float:
        return sum(self.wire_bytes(leaf)
                   for leaf in jax.tree_util.tree_leaves(tree))


class QuantCodec(Codec):
    """Randomized uniform quantization, Eq. (3.1) + Figure 3.1, with the
    packed sub-byte wire format from kernels/quant.

    backend: 'auto' (Pallas on TPU, jnp reference elsewhere), 'pallas',
    or 'jnp' — both produce identical bits for the same key.
    """

    packable = True

    def __init__(self, bits: int, *, backend: str = "auto"):
        self.bits = bits
        self.backend = backend
        self.spec = CompressionSpec(f"rq{bits}", True, float(bits))

    def qdq(self, x, key):
        from repro.kernels.quant import ops
        return ops.quantize_dequantize(x, key, bits=self.bits,
                                       backend=self.backend)

    def encode(self, x, key) -> Packed:
        from repro.kernels.quant import ops
        payload, params = ops.encode(x, key, bits=self.bits,
                                     backend=self.backend)
        return Packed(payload, params, tuple(x.shape), x.dtype, self.name)

    def decode(self, packed: Packed):
        from repro.kernels.quant import ops
        return ops.decode(packed.payload, packed.params,
                          shape=packed.shape, bits=self.bits,
                          dtype=packed.dtype, backend=self.backend)

    # fused flat-buffer tier: bucketed kernels (grid over buckets)

    def flat_qdq(self, flat, key, *, bucket_elems=DEFAULT_BUCKET_ELEMS):
        from repro.kernels.quant import ops
        return ops.qdq_flat(flat, key, bits=self.bits,
                            bucket_elems=bucket_elems, backend=self.backend)

    def flat_encode(self, flat, key, layout: FlatLayout, *,
                    bucket_elems=DEFAULT_BUCKET_ELEMS) -> FlatPacked:
        from repro.kernels.quant import ops
        payload, params = ops.encode_flat(flat, key, bits=self.bits,
                                          bucket_elems=bucket_elems,
                                          backend=self.backend)
        return FlatPacked(payload, params, layout, self.name, bucket_elems)

    def flat_decode(self, packed: FlatPacked):
        from repro.kernels.quant import ops
        return ops.decode_flat(packed.payload, packed.params,
                               total=packed.layout.total, bits=self.bits,
                               bucket_elems=packed.bucket_elems,
                               backend=self.backend)


class QdqCodec(Codec):
    """Adapter for operators without a packed wire format (yet): the
    algorithmic effect of Q is fully captured by `fn`; the wire cost comes
    from the static spec."""

    packable = False

    def __init__(self, fn: Callable, spec: CompressionSpec):
        self._fn = fn
        self.spec = spec

    def qdq(self, x, key=None):
        return self._fn(x, key)


# ---------------------------------------------------------------------------
# Operators. Each returns the *dequantized* array (same shape/dtype as
# input). These remain available as plain functions; the registry wraps
# them into codecs.
# ---------------------------------------------------------------------------


def randomized_quantize(x: jnp.ndarray, key: jax.Array, *, bits: int = 8) -> jnp.ndarray:
    """Unbiased randomized uniform quantization, Eq. (3.1) + Figure 3.1.

    Knobs c_i are uniform on [min(x), max(x)]; each element rounds to the
    bracketing knob with probability proportional to proximity, making
    E[Q(x)] = x elementwise. (Reference formulation on the original
    layout; QuantCodec routes through the packed kernels instead.)
    """
    x32 = x.astype(jnp.float32)
    lo = jnp.min(x32)
    hi = jnp.max(x32)
    levels = (1 << bits) - 1
    scale = jnp.where(hi > lo, (hi - lo) / levels, 1.0)
    norm = (x32 - lo) / scale               # in [0, levels]
    floor = jnp.floor(norm)
    frac = norm - floor
    u = jax.random.uniform(key, x.shape, dtype=jnp.float32)
    q = floor + (u < frac).astype(jnp.float32)   # stochastic round
    q = jnp.clip(q, 0.0, levels)
    return (q * scale + lo).astype(x.dtype)


def randomized_sparsify(x: jnp.ndarray, key: jax.Array, *, p: float = 0.1) -> jnp.ndarray:
    """Unbiased randomized sparsification (Wangni et al., 2018).

    Keep each coordinate with probability p, rescale kept ones by 1/p.
    """
    mask = jax.random.bernoulli(key, p, x.shape)
    return jnp.where(mask, x / p, jnp.zeros_like(x)).astype(x.dtype)


def topk_sparsify(x: jnp.ndarray, key: Optional[jax.Array] = None, *, frac: float = 0.01) -> jnp.ndarray:
    """Biased top-k (by magnitude) sparsification (Section 3.1.1 caveat 3)."""
    del key
    flat = x.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(jnp.abs(flat).astype(jnp.float32), k)[0][-1]
    kept = jnp.where(jnp.abs(flat) >= thresh, flat, jnp.zeros_like(flat))
    return kept.reshape(x.shape)


def onebit_sign(x: jnp.ndarray, key: Optional[jax.Array] = None) -> jnp.ndarray:
    """Biased 1-bit quantization ||x||_1/d * sign(x) (Bernstein et al., 2018)."""
    del key
    x32 = x.astype(jnp.float32)
    scale = jnp.mean(jnp.abs(x32))
    return (scale * jnp.sign(x32)).astype(x.dtype)


def clip_lowbits(x: jnp.ndarray, key: Optional[jax.Array] = None, *, keep_bits: int = 16) -> jnp.ndarray:
    """Biased deterministic clipping: zero the low mantissa bits (Section 3.2).

    keep_bits=16 reproduces fp32->bf16 truncation.
    """
    del key
    x32 = x.astype(jnp.float32)
    raw = jax.lax.bitcast_convert_type(x32, jnp.uint32)
    mask = jnp.uint32(0xFFFFFFFF) << jnp.uint32(32 - keep_bits)
    return jax.lax.bitcast_convert_type(raw & mask, jnp.float32).astype(x.dtype)


def identity(x: jnp.ndarray, key: Optional[jax.Array] = None) -> jnp.ndarray:
    del key
    return x


# ---------------------------------------------------------------------------
# Registry: name -> Codec (the only compression entry point for
# communicators, train steps, eventsim, and benchmarks).
# ---------------------------------------------------------------------------

CODECS: dict[str, Codec] = {
    "none": QdqCodec(identity,
                     CompressionSpec("none", True, 32.0, overhead_bytes=0)),
    "rq8": QuantCodec(8),
    "rq4": QuantCodec(4),
    "rq2": QuantCodec(2),
    "rand_sparse_10": QdqCodec(
        partial(randomized_sparsify, p=0.1),
        CompressionSpec("rand_sparse_10", True, 32.0, density=0.1)),
    "topk_1": QdqCodec(partial(topk_sparsify, frac=0.01),
                       CompressionSpec("topk_1", False, 32.0, density=0.01)),
    "sign1": QdqCodec(onebit_sign, CompressionSpec("sign1", False, 1.0)),
    "clip16": QdqCodec(clip_lowbits, CompressionSpec("clip16", False, 16.0)),
}


def codec(name: str) -> Codec:
    if name not in CODECS:
        raise KeyError(f"unknown compression '{name}'; have {sorted(CODECS)}")
    return CODECS[name]


# Legacy view: name -> (fn(x, key) -> x_hat, CompressionSpec). Kept ONLY so
# existing tests/notebooks can grab the raw operator; production call sites
# go through codec() and never handle (fn, spec) tuples themselves.
REGISTRY: dict[str, tuple[Callable, CompressionSpec]] = {
    name: (c.qdq, c.spec) for name, c in CODECS.items()
}


def get(name: str) -> tuple[Callable, CompressionSpec]:
    if name not in REGISTRY:
        raise KeyError(f"unknown compression '{name}'; have {sorted(REGISTRY)}")
    return REGISTRY[name]


def tree_compress(tree, key: jax.Array, fn: Callable) -> tuple:
    """Apply Q leaf-wise with independent keys. Returns compressed tree."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    out = [fn(leaf, k) for leaf, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, out)


def tree_bytes(tree, spec: CompressionSpec) -> float:
    """Total wire bytes for a pytree message under a static `spec`.

    Prefer Codec.tree_wire_bytes (measured) — this remains for spec-only
    arithmetic."""
    return sum(spec.compressed_bytes(leaf.size) for leaf in jax.tree_util.tree_leaves(tree))
