"""Lossy compression operators Q(.) from Section 3 of the paper.

Each operator acts on a single jnp array (communicators map them over pytrees).
Unbiased operators satisfy E[Q(x)] = x (Assumption 3); every operator also
reports its wire-format cost so the event simulator / roofline collective term
can account for the actual bytes moved (compression changes *transfer time*,
never latency — Figure 3.4/3.5).

All randomness is explicit (jax.random keys) so runs are reproducible and the
operators are usable inside jit/shard_map.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionSpec:
    """Static description of a compression operator.

    name:        registry key.
    unbiased:    whether E[Q(x)] = x (Assumption 3). CSGD requires True;
                 EC-SGD works either way (Section 3.3).
    bits_per_el: wire bits per *kept* element (payload).
    density:     fraction of elements kept (1.0 for quantizers).
    overhead_bytes: per-message header (scales, indices bookkeeping).
    """

    name: str
    unbiased: bool
    bits_per_el: float
    density: float = 1.0
    overhead_bytes: int = 8

    def compressed_bytes(self, n_elements: int) -> float:
        """Wire bytes for a message of n_elements (fp32 baseline = 4n)."""
        payload = n_elements * self.density * self.bits_per_el / 8.0
        if self.density < 1.0:
            # sparse formats also ship indices (4 bytes each)
            payload += n_elements * self.density * 4.0
        return payload + self.overhead_bytes

    def ratio(self, n_elements: int) -> float:
        """Compression ratio eta < 1 relative to fp32 (paper's Table 1.1)."""
        return self.compressed_bytes(n_elements) / (4.0 * n_elements)


# ---------------------------------------------------------------------------
# Operators. Each returns the *dequantized* array (same shape/dtype as input):
# the algorithmic effect of Q is fully captured; the wire format is captured
# by CompressionSpec. kernels/quant provides the packed TPU implementation.
# ---------------------------------------------------------------------------


def randomized_quantize(x: jnp.ndarray, key: jax.Array, *, bits: int = 8) -> jnp.ndarray:
    """Unbiased randomized uniform quantization, Eq. (3.1) + Figure 3.1.

    Knobs c_i are uniform on [min(x), max(x)]; each element rounds to the
    bracketing knob with probability proportional to proximity, making
    E[Q(x)] = x elementwise.
    """
    x32 = x.astype(jnp.float32)
    lo = jnp.min(x32)
    hi = jnp.max(x32)
    levels = (1 << bits) - 1
    scale = jnp.where(hi > lo, (hi - lo) / levels, 1.0)
    norm = (x32 - lo) / scale               # in [0, levels]
    floor = jnp.floor(norm)
    frac = norm - floor
    u = jax.random.uniform(key, x.shape, dtype=jnp.float32)
    q = floor + (u < frac).astype(jnp.float32)   # stochastic round
    q = jnp.clip(q, 0.0, levels)
    return (q * scale + lo).astype(x.dtype)


def randomized_sparsify(x: jnp.ndarray, key: jax.Array, *, p: float = 0.1) -> jnp.ndarray:
    """Unbiased randomized sparsification (Wangni et al., 2018).

    Keep each coordinate with probability p, rescale kept ones by 1/p.
    """
    mask = jax.random.bernoulli(key, p, x.shape)
    return jnp.where(mask, x / p, jnp.zeros_like(x)).astype(x.dtype)


def topk_sparsify(x: jnp.ndarray, key: Optional[jax.Array] = None, *, frac: float = 0.01) -> jnp.ndarray:
    """Biased top-k (by magnitude) sparsification (Section 3.1.1 caveat 3)."""
    del key
    flat = x.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(jnp.abs(flat).astype(jnp.float32), k)[0][-1]
    kept = jnp.where(jnp.abs(flat) >= thresh, flat, jnp.zeros_like(flat))
    return kept.reshape(x.shape)


def onebit_sign(x: jnp.ndarray, key: Optional[jax.Array] = None) -> jnp.ndarray:
    """Biased 1-bit quantization ||x||_1/d * sign(x) (Bernstein et al., 2018)."""
    del key
    x32 = x.astype(jnp.float32)
    scale = jnp.mean(jnp.abs(x32))
    return (scale * jnp.sign(x32)).astype(x.dtype)


def clip_lowbits(x: jnp.ndarray, key: Optional[jax.Array] = None, *, keep_bits: int = 16) -> jnp.ndarray:
    """Biased deterministic clipping: zero the low mantissa bits (Section 3.2).

    keep_bits=16 reproduces fp32->bf16 truncation.
    """
    del key
    x32 = x.astype(jnp.float32)
    raw = jax.lax.bitcast_convert_type(x32, jnp.uint32)
    mask = jnp.uint32(0xFFFFFFFF) << jnp.uint32(32 - keep_bits)
    return jax.lax.bitcast_convert_type(raw & mask, jnp.float32).astype(x.dtype)


def identity(x: jnp.ndarray, key: Optional[jax.Array] = None) -> jnp.ndarray:
    del key
    return x


# name -> (fn(x, key) -> x_hat, CompressionSpec)
REGISTRY: dict[str, tuple[Callable, CompressionSpec]] = {
    "none": (identity, CompressionSpec("none", True, 32.0, overhead_bytes=0)),
    "rq8": (partial(randomized_quantize, bits=8), CompressionSpec("rq8", True, 8.0)),
    "rq4": (partial(randomized_quantize, bits=4), CompressionSpec("rq4", True, 4.0)),
    "rq2": (partial(randomized_quantize, bits=2), CompressionSpec("rq2", True, 2.0)),
    "rand_sparse_10": (
        partial(randomized_sparsify, p=0.1),
        CompressionSpec("rand_sparse_10", True, 32.0, density=0.1),
    ),
    "topk_1": (
        partial(topk_sparsify, frac=0.01),
        CompressionSpec("topk_1", False, 32.0, density=0.01),
    ),
    "sign1": (onebit_sign, CompressionSpec("sign1", False, 1.0)),
    "clip16": (clip_lowbits, CompressionSpec("clip16", False, 16.0)),
}


def get(name: str) -> tuple[Callable, CompressionSpec]:
    if name not in REGISTRY:
        raise KeyError(f"unknown compression '{name}'; have {sorted(REGISTRY)}")
    return REGISTRY[name]


def tree_compress(tree, key: jax.Array, fn: Callable) -> tuple:
    """Apply Q leaf-wise with independent keys. Returns compressed tree."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    out = [fn(leaf, k) for leaf, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, out)


def tree_bytes(tree, spec: CompressionSpec) -> float:
    """Total wire bytes for a pytree message under `spec`."""
    return sum(spec.compressed_bytes(leaf.size) for leaf in jax.tree_util.tree_leaves(tree))
