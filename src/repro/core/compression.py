"""Lossy compression codecs Q(.) from Section 3 of the paper.

The central abstraction is the **Codec**: one object per operator owning

  encode(x, key)  -> Packed     the wire object (uint8 payload + params)
  decode(packed)  -> x_hat      dequantize a wire object
  qdq(x, key)     -> x_hat      fused encode+decode (what update rules eat)
  wire_bytes(x)   -> float      MEASURED bytes of encode(x)'s arrays

For the quantizer family (rq8/rq4/rq2) encode really packs sub-byte
codes into a uint8 payload (kernels/quant: Pallas on TPU, jnp reference
elsewhere) and `decode(encode(x, key)) == qdq(x, key)` bit-for-bit, so
communicators can ship the Packed payload through collectives whenever
the algebra allows (ring hops) and fall back to qdq where a summation
needs fp32 (PS reduce) without changing the math. Operators with no
packed implementation yet (sparsifiers, sign, clipping) are qdq-only
codecs: `packable` is False and wire_bytes comes from the static spec.

On top of the per-leaf tier sits the **fused flat-buffer tier** (the
production default): a `FlatLayout` flattens the whole gradient pytree
into ONE contiguous fp32 buffer, segments it into size-capped buckets
each owning a `(lo, scale)` row of an `(n_buckets, 2)` params array, and
`tree_encode_flat` / `tree_decode_flat` / `tree_qdq_flat` move the whole
tree as ONE `FlatPacked` message — one kernel launch, one params
reduction, at most one pad granule, and 2 arrays per collective instead
of 2 per leaf. In the paper's §1.3 switch model every message pays a
fixed `t_lat`, so per-leaf messaging costs `2N*L*t_lat` per ring
exchange while the fused tier pays `2N*t_lat`; eventsim's `n_messages`
knob makes that gap measurable. The per-leaf paths remain the reference
the fused tier is tested against (bit-identical per bucket).

The flat pipeline is **zero-copy**: flatten writes every leaf into one
preallocated buffer (`dynamic_update_slice`, never `concatenate`),
per-bucket (lo, scale) come out of ONE fused min+max read, head and
tail payload land in one preallocated output, and the whole
flatten->stats->encode chain traces as a single jitted program keyed on
the (lru-cached) FlatLayout. A donated qdq variant lets callers hand a
dead buffer's storage to the output.

The **partitioned view** (`PartitionedFlatPacked`,
`tree_encode_partitioned`) slices the same flat buffer into N equal,
granule-aligned partitions — each with its own bucket rows, all views
over one backing buffer — the wire unit of the bandwidth-optimal ring
AllReduce (Figure 3.3's per-partition chains): a reduce-scatter hop
ships ONE partition (M/N bytes), the all-gather hops forward finished
partitions verbatim, so a worker puts 2*M*(N-1)/N bytes on the wire per
iteration instead of the monolithic chain's (N-1)*M.

`CompressionSpec` remains the static metadata *inside* each codec; the
cost-model consumers (eventsim / roofline / table1_1 / comm_patterns)
take `Codec.wire_bytes(...)`, which for packable codecs is measured from
the actual payload shapes (eval_shape — no compute), so every downstream
byte count traces to the real wire format.

Unbiased operators satisfy E[Q(x)] = x (Assumption 3); every operator
reports its wire-format cost so the event simulator / roofline collective
term can account for the actual bytes moved (compression changes
*transfer time*, never latency — Figure 3.4/3.5).

All randomness is explicit (jax.random keys) so runs are reproducible and
the operators are usable inside jit/shard_map.
"""
from __future__ import annotations

import dataclasses
import zlib
from functools import lru_cache, partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro import obs


@dataclasses.dataclass(frozen=True)
class CompressionSpec:
    """Static description of a compression operator.

    name:        registry key.
    unbiased:    whether E[Q(x)] = x (Assumption 3). CSGD requires True;
                 EC-SGD works either way (Section 3.3).
    bits_per_el: wire bits per *kept* element (payload).
    density:     fraction of elements kept (1.0 for quantizers).
    overhead_bytes: per-message header (scales, indices bookkeeping).
    """

    name: str
    unbiased: bool
    bits_per_el: float
    density: float = 1.0
    overhead_bytes: int = 8

    def compressed_bytes(self, n_elements: int) -> float:
        """Wire bytes for a message of n_elements (fp32 baseline = 4n)."""
        payload = n_elements * self.density * self.bits_per_el / 8.0
        if self.density < 1.0:
            # sparse formats also ship indices (4 bytes each)
            payload += n_elements * self.density * 4.0
        return payload + self.overhead_bytes

    def ratio(self, n_elements: int) -> float:
        """Compression ratio eta < 1 relative to fp32 (paper's Table 1.1)."""
        return self.compressed_bytes(n_elements) / (4.0 * n_elements)


# Fused flat-buffer tier: elements per quantization bucket. One bucket =
# one (lo, scale) row in the FlatPacked params array; 4Mi elements keeps a
# 100M-param gradient at ~30 rows. Single source of truth lives next to
# the bucketed kernels.
from repro.kernels.quant.ops import DEFAULT_BUCKET_ELEMS  # noqa: E402


# ---------------------------------------------------------------------------
# The flat layout: static element offsets for the fused (whole-pytree)
# wire format.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FlatLayout:
    """Static offset table mapping a pytree onto ONE contiguous fp32 buffer.

    Computed once from the treedef + leaf shapes (cheap; shapes are static
    under jit): leaf i occupies flat[offsets[i] : offsets[i] + sizes[i]],
    reshaped to shapes[i] and cast back to dtypes[i] on unflatten.
    `unflatten(flatten(tree))` is bit-exact for float leaves (fp32 round
    trips exactly; bf16 -> fp32 -> bf16 is the identity).

    Frozen + hashable so it can ride in FlatPacked's static pytree aux and
    key jit caches.
    """

    treedef: Any
    shapes: tuple          # tuple[tuple[int, ...], ...]
    dtypes: tuple          # tuple[np.dtype, ...]
    offsets: tuple         # element offset of each leaf in the flat buffer
    sizes: tuple           # element count of each leaf
    total: int             # sum(sizes)

    @classmethod
    def from_tree(cls, tree) -> "FlatLayout":
        """Layout for `tree`, cached on (treedef, shapes, dtypes).

        Exchanges and train steps call this on every trace; the offset
        table only depends on the static structure, so repeat calls hit
        an lru_cache instead of rebuilding it."""
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        shapes = tuple(tuple(leaf.shape) for leaf in leaves)
        dtypes = tuple(jnp.dtype(getattr(leaf, "dtype", jnp.float32))
                       for leaf in leaves)
        return _cached_layout(treedef, shapes, dtypes)

    @property
    def n_leaves(self) -> int:
        return len(self.shapes)

    def flatten(self, tree) -> jnp.ndarray:
        """Pytree -> one contiguous (total,) fp32 buffer.

        Under a trace, every leaf is written into ONE preallocated
        buffer via ``dynamic_update_slice`` (static offsets) instead of
        ``jnp.concatenate`` — XLA turns the chain into in-place writes,
        so the buffer is materialized once and the fused codec entry
        points (see QuantCodec) keep their jaxprs concatenate-free.
        Eagerly, that same chain would copy the WHOLE buffer once per
        leaf (O(L * total)), so un-traced calls use the one-pass
        concatenate instead."""
        leaves = jax.tree_util.tree_leaves(tree)
        if not any(isinstance(leaf, jax.core.Tracer) for leaf in leaves):
            return jnp.concatenate(
                [leaf.reshape(-1).astype(jnp.float32) for leaf in leaves])
        out = jnp.zeros((self.total,), jnp.float32)
        for leaf, off in zip(leaves, self.offsets):
            out = lax.dynamic_update_slice(
                out, leaf.reshape(-1).astype(jnp.float32), (off,))
        return out

    def unflatten(self, flat: jnp.ndarray):
        """(total,) buffer -> pytree with the original shapes/dtypes."""
        leaves = [
            flat[o:o + n].reshape(shape).astype(dtype)
            for o, n, shape, dtype in zip(self.offsets, self.sizes,
                                          self.shapes, self.dtypes)
        ]
        return jax.tree_util.tree_unflatten(self.treedef, leaves)


@lru_cache(maxsize=512)
def _cached_layout(treedef, shapes: tuple, dtypes: tuple) -> "FlatLayout":
    """Offset-table construction, memoized on the static structure so
    ``CSGDRingExchange.__call__`` / ``ECSGD`` / ``make_train_step`` stop
    rebuilding the table on every trace."""
    sizes, offsets, off = [], [], 0
    for shape in shapes:
        n = 1
        for d in shape:
            n *= d
        sizes.append(n)
        offsets.append(off)
        off += n
    return FlatLayout(treedef, shapes, dtypes, tuple(offsets), tuple(sizes),
                      off)


# ---------------------------------------------------------------------------
# The wire objects
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Packed:
    """A compressed message as it would travel on the wire.

    payload: uint8 array of packed codes (the bulk bytes).
    params:  small fp32 array of dequantization params (the header).
    shape/dtype: static metadata to restore the original leaf.
    codec:   registry name of the codec that produced it.

    Registered as a pytree whose children are (payload, params), so a
    Packed (or a tree of them) moves through ``lax.ppermute``, ``vmap``
    and ``lax.fori_loop`` carries like any other array bundle.
    """

    payload: jnp.ndarray
    params: jnp.ndarray
    shape: tuple
    dtype: Any
    codec: str

    def tree_flatten(self):
        return (self.payload, self.params), (self.shape, self.dtype,
                                             self.codec)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)

    @property
    def wire_bytes(self) -> int:
        """Measured size: payload bytes + header (params) bytes."""
        payload = self.payload.size * jnp.dtype(self.payload.dtype).itemsize
        header = self.params.size * jnp.dtype(self.params.dtype).itemsize
        return int(payload + header)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class FlatPacked:
    """ONE compressed message for a whole pytree (the fused wire object).

    payload: (rows_kept, 512) uint8 — the bucketed packed codes of the
             entire flat buffer (at most one pad granule, at the very end).
    params:  (n_buckets, 2) fp32 — one [lo, scale] row per bucket.
    layout:  the FlatLayout that unflattens the decode back into the tree.
    codec / bucket_elems: static decode metadata.

    Registered as a pytree whose children are (payload, params): a ring hop
    ppermutes exactly TWO arrays per exchange — one payload, one header —
    instead of two per pytree leaf.
    """

    payload: jnp.ndarray
    params: jnp.ndarray
    layout: FlatLayout
    codec: str
    bucket_elems: int

    def tree_flatten(self):
        return (self.payload, self.params), (self.layout, self.codec,
                                             self.bucket_elems)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)

    @property
    def wire_bytes(self) -> int:
        """Measured size: payload bytes + header (params) bytes."""
        payload = self.payload.size * jnp.dtype(self.payload.dtype).itemsize
        header = self.params.size * jnp.dtype(self.params.dtype).itemsize
        return int(payload + header)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PartitionedFlatPacked:
    """A whole-tree compressed message as N per-partition views over ONE
    backing buffer (the partitioned ring AllReduce's wire object).

    payload: (n_parts, rows_p, 512) uint8 — partition p's packed codes
             are the contiguous slab ``payload[p]``; no copies, the
             partition view is plain leading-axis indexing of the single
             backing buffer.
    params:  (n_parts, nb_p, 2) fp32 — partition p's own bucket rows.
    layout / codec / bucket_elems / part_elems: static decode metadata;
             part_elems is the granule-aligned elements per partition
             (the flat buffer is edge-padded to n_parts * part_elems).

    The ring's reduce-scatter hops ship ONE partition (``part(p)``: two
    arrays, M/N payload bytes); the all-gather hops copy finished
    partitions into this buffer verbatim — the object every worker ends
    the exchange holding, bit-identical across workers.
    """

    payload: jnp.ndarray
    params: jnp.ndarray
    layout: FlatLayout
    codec: str
    bucket_elems: int
    part_elems: int

    def tree_flatten(self):
        return (self.payload, self.params), (self.layout, self.codec,
                                             self.bucket_elems,
                                             self.part_elems)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)

    @property
    def n_parts(self) -> int:
        return self.payload.shape[0]

    def part(self, p) -> tuple:
        """Partition p's (payload, params) — views over the backing
        buffer (leading-axis indexing), never a copy."""
        return self.payload[p], self.params[p]

    @property
    def part_wire_bytes(self) -> int:
        """Measured bytes of ONE partition message (what a ring hop
        ships): its payload slab + its own params rows."""
        pay = (self.payload.size // self.n_parts
               * jnp.dtype(self.payload.dtype).itemsize)
        hdr = (self.params.size // self.n_parts
               * jnp.dtype(self.params.dtype).itemsize)
        return int(pay + hdr)

    @property
    def wire_bytes(self) -> int:
        """Measured size of all partitions: payload + params bytes."""
        payload = self.payload.size * jnp.dtype(self.payload.dtype).itemsize
        header = self.params.size * jnp.dtype(self.params.dtype).itemsize
        return int(payload + header)


# ---------------------------------------------------------------------------
# Codecs
# ---------------------------------------------------------------------------


class Codec:
    """One compression operator: packed wire format + fused qdq.

    Subclasses set `spec` and implement `qdq`; packable codecs also
    implement `encode`/`decode` with decode(encode(x, k)) == qdq(x, k).
    """

    spec: CompressionSpec
    packable: bool = False

    @property
    def name(self) -> str:
        return self.spec.name

    # -- single leaf ------------------------------------------------------

    def qdq(self, x: jnp.ndarray, key: Optional[jax.Array]) -> jnp.ndarray:
        raise NotImplementedError

    def encode(self, x: jnp.ndarray, key: Optional[jax.Array]) -> Packed:
        raise NotImplementedError(
            f"codec '{self.name}' has no packed wire format; use qdq")

    def decode(self, packed: Packed) -> jnp.ndarray:
        raise NotImplementedError(
            f"codec '{self.name}' has no packed wire format; use qdq")

    def wire_bytes(self, x) -> float:
        """Measured wire bytes for one leaf (array / ShapeDtypeStruct)."""
        if not self.packable:
            b = self.spec.compressed_bytes(x.size)
        else:
            leaf = jax.ShapeDtypeStruct(x.shape,
                                        getattr(x, "dtype", jnp.float32))
            key = jax.ShapeDtypeStruct((2,), jnp.uint32)
            out = jax.eval_shape(self.encode, leaf, key)
            b = float(out.wire_bytes)
        self._observe_wire(b, x.size, tier="leaf")
        return b

    def _observe_wire(self, wire_b: float, n_elements: int, *,
                      tier: str) -> None:
        """Metrics tap on every host-side wire sizing (not in jit)."""
        if not obs.enabled("metrics"):
            return
        obs.counter("compression.wire_bytes", codec=self.name,
                    tier=tier).inc(wire_b)
        obs.counter("compression.sized_msgs", codec=self.name,
                    tier=tier).inc()
        if wire_b > 0:
            obs.histogram("compression.ratio", codec=self.name).observe(
                4.0 * n_elements / wire_b)

    def wire_bytes_for(self, n_elements: int) -> float:
        """Measured wire bytes for a flat fp32 message of n elements."""
        return self.wire_bytes(
            jax.ShapeDtypeStruct((int(n_elements),), jnp.float32))

    # -- fused flat-buffer tier -------------------------------------------
    #
    # One message per exchange instead of one per pytree leaf: the tree is
    # flattened onto a FlatLayout, quantized per size-capped bucket in a
    # single kernel pass, and shipped as ONE FlatPacked. The per-leaf
    # methods above remain the reference the fused path is tested against.

    def flat_qdq(self, flat: jnp.ndarray, key: Optional[jax.Array], *,
                 bucket_elems: int = DEFAULT_BUCKET_ELEMS,
                 donate: bool = False) -> jnp.ndarray:
        """Fused qdq over one flat fp32 buffer (one message's worth).

        Base implementation: a single application of the operator to the
        whole buffer — qdq-only codecs get the fused (one-pass, one-
        message) semantics for free. QuantCodec overrides this with the
        bucketed kernel. ``donate=True`` hands the buffer's storage to
        the output (same shape/dtype) — pass it only when the caller's
        buffer is dead after the call (a hop temporary, a fresh flatten);
        ignored here in the base class."""
        del bucket_elems, donate
        return self.qdq(flat, key)

    def flat_encode(self, flat: jnp.ndarray, key: Optional[jax.Array],
                    layout: FlatLayout, *,
                    bucket_elems: int = DEFAULT_BUCKET_ELEMS) -> FlatPacked:
        raise NotImplementedError(
            f"codec '{self.name}' has no packed wire format; use flat_qdq")

    def flat_decode(self, packed: FlatPacked) -> jnp.ndarray:
        raise NotImplementedError(
            f"codec '{self.name}' has no packed wire format; use flat_qdq")

    def tree_qdq_flat(self, tree, key: Optional[jax.Array], *,
                      bucket_elems: int = DEFAULT_BUCKET_ELEMS):
        """Whole-tree fused qdq through the flat buffer (one pass)."""
        layout = FlatLayout.from_tree(tree)
        flat = self.flat_qdq(layout.flatten(tree), key,
                             bucket_elems=bucket_elems)
        return layout.unflatten(flat)

    def tree_encode_flat(self, tree, key: Optional[jax.Array], *,
                         bucket_elems: int = DEFAULT_BUCKET_ELEMS
                         ) -> FlatPacked:
        """Whole tree -> ONE FlatPacked wire message."""
        layout = FlatLayout.from_tree(tree)
        return self.flat_encode(layout.flatten(tree), key, layout,
                                bucket_elems=bucket_elems)

    def tree_decode_flat(self, packed: FlatPacked):
        """Inverse of tree_encode_flat (FlatPacked -> tree of arrays)."""
        return packed.layout.unflatten(self.flat_decode(packed))

    def tree_wire_bytes_flat(self, tree, *,
                             bucket_elems: int = DEFAULT_BUCKET_ELEMS
                             ) -> float:
        """Measured wire bytes of the ONE fused message for `tree`."""
        layout = FlatLayout.from_tree(tree)
        if not self.packable:
            # one message -> one static-spec header, not one per leaf
            b = self.spec.compressed_bytes(layout.total)
        else:
            flat = jax.ShapeDtypeStruct((layout.total,), jnp.float32)
            key = jax.ShapeDtypeStruct((2,), jnp.uint32)
            out = jax.eval_shape(
                partial(self.flat_encode, layout=layout,
                        bucket_elems=bucket_elems), flat, key)
            b = float(out.wire_bytes)
        self._observe_wire(b, layout.total, tier="flat")
        return b

    def tree_wire_bytes_partitioned(self, tree, n_parts: int, *,
                                    bucket_elems: int = DEFAULT_BUCKET_ELEMS
                                    ) -> float:
        """Measured wire bytes of ONE partition message — the unit the
        partitioned ring ships per hop (2(N-1) of them per worker per
        iteration = 2*M*(N-1)/N total, up to one pad granule per
        partition). Base implementation: the static-spec bytes of a
        1/n_parts slice; QuantCodec measures the packed format."""
        del bucket_elems
        layout = FlatLayout.from_tree(tree)
        return self.spec.compressed_bytes(-(-layout.total // n_parts))

    # -- pytrees ----------------------------------------------------------

    def tree_qdq(self, tree, key: jax.Array):
        return tree_compress(tree, key, self.qdq)

    def tree_encode(self, tree, key: jax.Array):
        """Leaf-wise encode with independent keys -> tree of Packed."""
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        keys = jax.random.split(key, len(leaves))
        out = [self.encode(leaf, k) for leaf, k in zip(leaves, keys)]
        return jax.tree_util.tree_unflatten(treedef, out)

    def tree_decode(self, tree):
        """Inverse of tree_encode (tree of Packed -> tree of arrays)."""
        return jax.tree_util.tree_map(
            self.decode, tree, is_leaf=lambda n: isinstance(n, Packed))

    def tree_wire_bytes(self, tree) -> float:
        return sum(self.wire_bytes(leaf)
                   for leaf in jax.tree_util.tree_leaves(tree))


# End-to-end jitted fused tree paths (QuantCodec): flatten + stats +
# encode/decode trace as ONE XLA program keyed on the (cached, hashable)
# FlatLayout, so no intermediate buffer is materialized between the
# pipeline stages and repeat calls re-dispatch one compiled executable.


@partial(jax.jit, static_argnames=("layout", "bits", "bucket_elems",
                                   "backend"))
def _tree_qdq_flat_fused(tree, key, *, layout: FlatLayout, bits: int,
                         bucket_elems: int, backend: str):
    from repro.kernels.quant import ops
    flat = ops.qdq_flat(layout.flatten(tree), key, bits=bits,
                        bucket_elems=bucket_elems, backend=backend)
    return layout.unflatten(flat)


@partial(jax.jit, static_argnames=("layout", "bits", "bucket_elems",
                                   "backend"))
def _tree_encode_flat_fused(tree, key, *, layout: FlatLayout, bits: int,
                            bucket_elems: int, backend: str):
    from repro.kernels.quant import ops
    if not ops._use_pallas(backend):
        # jnp reference tier: cache-blocked encode straight from the
        # leaves — the flat buffer is never materialized; each bucket is
        # assembled, statted, drawn, and packed while cache-hot.
        # Bit-identical to the flatten + encode_flat pipeline below.
        return ops.encode_flat_blocked(
            jax.tree_util.tree_leaves(tree), layout.offsets, layout.total,
            key, bits=bits, bucket_elems=bucket_elems)
    return ops.encode_flat(layout.flatten(tree), key, bits=bits,
                           bucket_elems=bucket_elems, backend=backend)


@partial(jax.jit, static_argnames=("layout", "bits", "bucket_elems",
                                   "backend"))
def _tree_decode_flat_fused(payload, params, *, layout: FlatLayout,
                            bits: int, bucket_elems: int, backend: str):
    from repro.kernels.quant import ops
    flat = ops.decode_flat(payload, params, total=layout.total, bits=bits,
                           bucket_elems=bucket_elems, backend=backend)
    return layout.unflatten(flat)


def _encode_partitions(flat, key, *, n_parts: int, part_elems: int,
                       bits: int, bucket_elems: int, backend: str):
    """THE partition-encode pipeline: edge-pad the flat buffer to
    n_parts * part_elems, view it as equal partitions, and encode
    partition p under fold_in(key, p) (one vmapped draw — bit-identical
    to per-key draws). Single source of the partition keying, shared by
    ``flat_encode_partitioned`` and the fused tree path; the ring
    exchange's per-hop re-encodes use per-(worker, hop) keys instead,
    by construction of Eq. (3.3)'s chains."""
    from repro.kernels.quant import ops
    padded = ops.edge_pad(flat.reshape(-1).astype(jnp.float32),
                          n_parts * part_elems)
    parts = padded.reshape(n_parts, part_elems)
    return jax.vmap(
        lambda x, p: ops.encode_flat(x, jax.random.fold_in(key, p),
                                     bits=bits, bucket_elems=bucket_elems,
                                     backend=backend)
    )(parts, jnp.arange(n_parts))


@partial(jax.jit, static_argnames=("layout", "n_parts", "bits",
                                   "bucket_elems", "backend"))
def _tree_encode_partitioned_fused(tree, key, *, layout: FlatLayout,
                                   n_parts: int, bits: int,
                                   bucket_elems: int, backend: str):
    """Flatten + partition + encode in ONE jitted program (an eager
    flatten would copy the whole buffer once per leaf).

    jnp tier: cache-blocked from-leaves encode — the vmapped
    flatten-then-encode pipeline turns the per-partition edge-pad and
    head/tail dynamic_update_slice writes into full-buffer scatters,
    which made the partitioned encode cost ~3x the flat encode.
    Bit-identical to ``_encode_partitions`` (asserted in
    tests/test_flat_codec.py)."""
    from repro.kernels.quant import ops
    part_elems, _, _ = ops.partition_geometry(layout.total, n_parts,
                                              bits=bits,
                                              bucket_elems=bucket_elems)
    if backend == "jnp" or (backend == "auto"
                            and jax.default_backend() != "tpu"):
        return ops.encode_partitioned_blocked(
            jax.tree_util.tree_leaves(tree), layout.offsets, layout.total,
            key, n_parts=n_parts, bits=bits, bucket_elems=bucket_elems)
    return _encode_partitions(layout.flatten(tree), key, n_parts=n_parts,
                              part_elems=part_elems, bits=bits,
                              bucket_elems=bucket_elems, backend=backend)


class QuantCodec(Codec):
    """Randomized uniform quantization, Eq. (3.1) + Figure 3.1, with the
    packed sub-byte wire format from kernels/quant.

    backend: 'auto' (Pallas on TPU, jnp reference elsewhere), 'pallas',
    or 'jnp' — both produce identical bits for the same key.
    """

    packable = True

    def __init__(self, bits: int, *, backend: str = "auto"):
        self.bits = bits
        self.backend = backend
        self.spec = CompressionSpec(f"rq{bits}", True, float(bits))

    def qdq(self, x, key):
        from repro.kernels.quant import ops
        return ops.quantize_dequantize(x, key, bits=self.bits,
                                       backend=self.backend)

    def encode(self, x, key) -> Packed:
        from repro.kernels.quant import ops
        payload, params = ops.encode(x, key, bits=self.bits,
                                     backend=self.backend)
        return Packed(payload, params, tuple(x.shape), x.dtype, self.name)

    def decode(self, packed: Packed):
        from repro.kernels.quant import ops
        return ops.decode(packed.payload, packed.params,
                          shape=packed.shape, bits=self.bits,
                          dtype=packed.dtype, backend=self.backend)

    # fused flat-buffer tier: bucketed kernels (grid over buckets)

    def flat_qdq(self, flat, key, *, bucket_elems=DEFAULT_BUCKET_ELEMS,
                 donate=False):
        from repro.kernels.quant import ops
        fn = ops.qdq_flat_donated if donate else ops.qdq_flat
        return fn(flat, key, bits=self.bits,
                  bucket_elems=bucket_elems, backend=self.backend)

    def flat_encode(self, flat, key, layout: FlatLayout, *,
                    bucket_elems=DEFAULT_BUCKET_ELEMS) -> FlatPacked:
        from repro.kernels.quant import ops
        payload, params = ops.encode_flat(flat, key, bits=self.bits,
                                          bucket_elems=bucket_elems,
                                          backend=self.backend)
        self._observe_buckets(params)
        return FlatPacked(payload, params, layout, self.name, bucket_elems)

    def _observe_buckets(self, params) -> None:
        """Per-bucket quant range tap. ``params`` is the encode output
        ((n_buckets, 2) of (lo, scale)) — concrete on the host path,
        a tracer inside jit (where ``observe_array`` skips it; the
        caller sees the concrete params as the jitted function's output
        and can feed them back if it wants in-jit coverage)."""
        if obs.enabled("metrics"):
            levels = (1 << self.bits) - 1
            obs.observe_array("quant.bucket_range",
                              params[:, 1] * levels, codec=self.name)

    def flat_decode(self, packed: FlatPacked):
        from repro.kernels.quant import ops
        return ops.decode_flat(packed.payload, packed.params,
                               total=packed.layout.total, bits=self.bits,
                               bucket_elems=packed.bucket_elems,
                               backend=self.backend)

    # fused tree entry points: ONE jit spanning flatten -> stats -> encode
    # (keyed on the cached FlatLayout), so the flat buffer and every view
    # of it live inside a single XLA program — flatten's
    # dynamic_update_slice writes fuse with the encode read instead of
    # materializing eager intermediates (the PR-2 copy tax).

    def tree_qdq_flat(self, tree, key, *,
                      bucket_elems: int = DEFAULT_BUCKET_ELEMS):
        layout = FlatLayout.from_tree(tree)
        return _tree_qdq_flat_fused(tree, key, layout=layout,
                                    bits=self.bits,
                                    bucket_elems=bucket_elems,
                                    backend=self.backend)

    def tree_encode_flat(self, tree, key, *,
                         bucket_elems: int = DEFAULT_BUCKET_ELEMS
                         ) -> FlatPacked:
        layout = FlatLayout.from_tree(tree)
        payload, params = _tree_encode_flat_fused(
            tree, key, layout=layout, bits=self.bits,
            bucket_elems=bucket_elems, backend=self.backend)
        self._observe_buckets(params)
        return FlatPacked(payload, params, layout, self.name, bucket_elems)

    def tree_decode_flat(self, packed: FlatPacked):
        return _tree_decode_flat_fused(
            packed.payload, packed.params, layout=packed.layout,
            bits=self.bits, bucket_elems=packed.bucket_elems,
            backend=self.backend)

    # partitioned tier: the flat buffer as n_parts equal, granule-aligned
    # slices, each bucketed and packed independently — the unit of the
    # ring AllReduce's reduce-scatter / all-gather hops.

    def partition_geometry(self, total: int, n_parts: int, *,
                           bucket_elems: int = DEFAULT_BUCKET_ELEMS):
        """(part_elems, nb_p, rows_p) of the N-way partition view."""
        from repro.kernels.quant import ops
        return ops.partition_geometry(total, n_parts, bits=self.bits,
                                      bucket_elems=bucket_elems)

    def encode_partition(self, part: jnp.ndarray, key, *,
                         bucket_elems: int = DEFAULT_BUCKET_ELEMS):
        """ONE partition (a granule-aligned (part_elems,) slice) ->
        (payload (rows_p, 512) uint8, params (nb_p, 2)) — the ring hop's
        wire message."""
        from repro.kernels.quant import ops
        return ops.encode_flat(part, key, bits=self.bits,
                               bucket_elems=bucket_elems,
                               backend=self.backend)

    def decode_partition(self, payload, params, *, part_elems: int,
                         bucket_elems: int = DEFAULT_BUCKET_ELEMS):
        """Inverse of encode_partition: -> (part_elems,) fp32."""
        from repro.kernels.quant import ops
        return ops.decode_flat(payload, params, total=part_elems,
                               bits=self.bits, bucket_elems=bucket_elems,
                               backend=self.backend)

    def decode_add_encode_partition(self, payload, params, local, key, *,
                                    bucket_elems=DEFAULT_BUCKET_ELEMS):
        """THE fused ring hop: decode the incoming partition message, add
        the local fp32 slice, and re-encode under `key` in ONE dispatch
        (single VMEM-resident pass on the Pallas backend) — bit-identical
        to ``encode_partition(decode_partition(...) + local, key)``.
        Returns the outgoing (payload, params) wire message."""
        from repro.kernels.quant import ops
        return ops.decode_add_encode_flat(payload, params, local, key,
                                          bits=self.bits,
                                          bucket_elems=bucket_elems,
                                          backend=self.backend)

    def flat_encode_partitioned(self, flat, key, layout: FlatLayout, *,
                                n_parts: int,
                                bucket_elems: int = DEFAULT_BUCKET_ELEMS
                                ) -> PartitionedFlatPacked:
        """Encode every partition of a flat buffer into ONE backing
        (n_parts, rows_p, 512) payload + (n_parts, nb_p, 2) params pair
        (partition p under key fold_in(key, p))."""
        part_elems, _, _ = self.partition_geometry(
            layout.total, n_parts, bucket_elems=bucket_elems)
        payload, params = _encode_partitions(
            flat, key, n_parts=n_parts, part_elems=part_elems,
            bits=self.bits, bucket_elems=bucket_elems,
            backend=self.backend)
        return PartitionedFlatPacked(payload, params, layout, self.name,
                                     bucket_elems, part_elems)

    def flat_decode_partitioned(self, packed: PartitionedFlatPacked):
        """All partitions -> the (total,) fp32 flat buffer (pad trimmed)."""
        dec = jax.vmap(
            lambda p, pr: self.decode_partition(
                p, pr, part_elems=packed.part_elems,
                bucket_elems=packed.bucket_elems)
        )(packed.payload, packed.params)
        return dec.reshape(-1)[: packed.layout.total]

    def tree_encode_partitioned(self, tree, key, n_parts: int, *,
                                bucket_elems: int = DEFAULT_BUCKET_ELEMS
                                ) -> PartitionedFlatPacked:
        """Whole tree -> n_parts partition messages over one buffer
        (flatten + partition + encode as one jitted program)."""
        layout = FlatLayout.from_tree(tree)
        part_elems, _, _ = self.partition_geometry(
            layout.total, n_parts, bucket_elems=bucket_elems)
        payload, params = _tree_encode_partitioned_fused(
            tree, key, layout=layout, n_parts=n_parts, bits=self.bits,
            bucket_elems=bucket_elems, backend=self.backend)
        return PartitionedFlatPacked(payload, params, layout, self.name,
                                     bucket_elems, part_elems)

    def tree_decode_partitioned(self, packed: PartitionedFlatPacked):
        """Inverse of tree_encode_partitioned."""
        return packed.layout.unflatten(self.flat_decode_partitioned(packed))

    def tree_wire_bytes_partitioned(self, tree, n_parts: int, *,
                                    bucket_elems: int = DEFAULT_BUCKET_ELEMS
                                    ) -> float:
        from repro.kernels.quant import ops
        layout = FlatLayout.from_tree(tree)
        _, nb_p, rows_p = self.partition_geometry(
            layout.total, n_parts, bucket_elems=bucket_elems)
        return float(rows_p * ops.LANES + nb_p * 8)


class QdqCodec(Codec):
    """Adapter for operators without a packed wire format (yet): the
    algorithmic effect of Q is fully captured by `fn`; the wire cost comes
    from the static spec."""

    packable = False

    def __init__(self, fn: Callable, spec: CompressionSpec):
        self._fn = fn
        self.spec = spec

    def qdq(self, x, key=None):
        return self._fn(x, key)


# ---------------------------------------------------------------------------
# Operators. Each returns the *dequantized* array (same shape/dtype as
# input). These remain available as plain functions; the registry wraps
# them into codecs.
# ---------------------------------------------------------------------------


def randomized_quantize(x: jnp.ndarray, key: jax.Array, *, bits: int = 8) -> jnp.ndarray:
    """Unbiased randomized uniform quantization, Eq. (3.1) + Figure 3.1.

    Knobs c_i are uniform on [min(x), max(x)]; each element rounds to the
    bracketing knob with probability proportional to proximity, making
    E[Q(x)] = x elementwise. (Reference formulation on the original
    layout; QuantCodec routes through the packed kernels instead.)
    """
    x32 = x.astype(jnp.float32)
    lo = jnp.min(x32)
    hi = jnp.max(x32)
    levels = (1 << bits) - 1
    scale = jnp.where(hi > lo, (hi - lo) / levels, 1.0)
    norm = (x32 - lo) / scale               # in [0, levels]
    floor = jnp.floor(norm)
    frac = norm - floor
    u = jax.random.uniform(key, x.shape, dtype=jnp.float32)
    q = floor + (u < frac).astype(jnp.float32)   # stochastic round
    q = jnp.clip(q, 0.0, levels)
    return (q * scale + lo).astype(x.dtype)


def randomized_sparsify(x: jnp.ndarray, key: jax.Array, *, p: float = 0.1) -> jnp.ndarray:
    """Unbiased randomized sparsification (Wangni et al., 2018).

    Keep each coordinate with probability p, rescale kept ones by 1/p.
    """
    mask = jax.random.bernoulli(key, p, x.shape)
    return jnp.where(mask, x / p, jnp.zeros_like(x)).astype(x.dtype)


def topk_sparsify(x: jnp.ndarray, key: Optional[jax.Array] = None, *, frac: float = 0.01) -> jnp.ndarray:
    """Biased top-k (by magnitude) sparsification (Section 3.1.1 caveat 3)."""
    del key
    flat = x.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(jnp.abs(flat).astype(jnp.float32), k)[0][-1]
    kept = jnp.where(jnp.abs(flat) >= thresh, flat, jnp.zeros_like(flat))
    return kept.reshape(x.shape)


def onebit_sign(x: jnp.ndarray, key: Optional[jax.Array] = None) -> jnp.ndarray:
    """Biased 1-bit quantization ||x||_1/d * sign(x) (Bernstein et al., 2018)."""
    del key
    x32 = x.astype(jnp.float32)
    scale = jnp.mean(jnp.abs(x32))
    return (scale * jnp.sign(x32)).astype(x.dtype)


def clip_lowbits(x: jnp.ndarray, key: Optional[jax.Array] = None, *, keep_bits: int = 16) -> jnp.ndarray:
    """Biased deterministic clipping: zero the low mantissa bits (Section 3.2).

    keep_bits=16 reproduces fp32->bf16 truncation.
    """
    del key
    x32 = x.astype(jnp.float32)
    raw = jax.lax.bitcast_convert_type(x32, jnp.uint32)
    mask = jnp.uint32(0xFFFFFFFF) << jnp.uint32(32 - keep_bits)
    return jax.lax.bitcast_convert_type(raw & mask, jnp.float32).astype(x.dtype)


def identity(x: jnp.ndarray, key: Optional[jax.Array] = None) -> jnp.ndarray:
    del key
    return x


# ---------------------------------------------------------------------------
# Registry: name -> Codec (the only compression entry point for
# communicators, train steps, eventsim, and benchmarks). A
# ``repro.core.registry.Registry`` of ready instances, sharing the
# lookup/error idiom with EXCHANGES / PROTOCOLS / AGGREGATORS.
# ---------------------------------------------------------------------------

from repro.core.registry import Registry  # noqa: E402

CODECS: Registry = Registry("compression", {
    "none": QdqCodec(identity,
                     CompressionSpec("none", True, 32.0, overhead_bytes=0)),
    "rq8": QuantCodec(8),
    "rq4": QuantCodec(4),
    "rq2": QuantCodec(2),
    "rand_sparse_10": QdqCodec(
        partial(randomized_sparsify, p=0.1),
        CompressionSpec("rand_sparse_10", True, 32.0, density=0.1)),
    "topk_1": QdqCodec(partial(topk_sparsify, frac=0.01),
                       CompressionSpec("topk_1", False, 32.0, density=0.01)),
    "sign1": QdqCodec(onebit_sign, CompressionSpec("sign1", False, 1.0)),
    "clip16": QdqCodec(clip_lowbits, CompressionSpec("clip16", False, 16.0)),
})


def codec(name: str) -> Codec:
    return CODECS.get(name)


# Legacy view: name -> (fn(x, key) -> x_hat, CompressionSpec). Kept ONLY so
# existing tests/notebooks can grab the raw operator; production call sites
# go through codec() and never handle (fn, spec) tuples themselves.
REGISTRY: dict[str, tuple[Callable, CompressionSpec]] = {
    name: (c.qdq, c.spec) for name, c in CODECS.items()
}


def get(name: str) -> tuple[Callable, CompressionSpec]:
    if name not in REGISTRY:
        raise KeyError(f"unknown compression '{name}'; have {sorted(REGISTRY)}")
    return REGISTRY[name]


def tree_compress(tree, key: jax.Array, fn: Callable) -> tuple:
    """Apply Q leaf-wise with independent keys. Returns compressed tree."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    out = [fn(leaf, k) for leaf, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, out)


def tree_bytes(tree, spec: CompressionSpec) -> float:
    """Total wire bytes for a pytree message under a static `spec`.

    Prefer Codec.tree_wire_bytes (measured) — this remains for spec-only
    arithmetic."""
    return sum(spec.compressed_bytes(leaf.size) for leaf in jax.tree_util.tree_leaves(tree))


# ---------------------------------------------------------------------------
# Wire integrity: CRC32 framing over packed codes + params
# ---------------------------------------------------------------------------
#
# A flipped bit in a packed payload silently corrupts an entire bucket of
# quantization codes (and a flipped bit in a params row rescales one), so
# every Packed / FlatPacked / PartitionedFlatPacked message can be framed
# with a CRC32 over its payload bytes followed by its params bytes. The
# checksum is a HOST-SIDE sidecar, not a pytree child: the wire classes'
# children stay exactly (payload, params), so collective lowerings,
# measured `wire_bytes`, and the event-simulator byte accounting are all
# unchanged — framing rides next to the message (a 4-byte header the size
# model treats as noise), it never perturbs it. Verification is therefore
# a host-boundary operation (send/receive edges); the in-graph exchange
# paths (jit/shard_map ppermutes) instead rely on the post-decode finite
# guard plus the scheduler's modelled CRC detection.


class WireCorruptionError(ValueError):
    """A packed wire message failed its integrity check on receive."""


def _wire_children(packed) -> tuple:
    """(payload, params) as host numpy arrays, any wire class."""
    return (np.asarray(jax.device_get(packed.payload)),
            np.asarray(jax.device_get(packed.params)))


def wire_crc32(packed) -> int:
    """CRC32 over the packed codes then the dequantization params."""
    pay, par = _wire_children(packed)
    return zlib.crc32(par.tobytes(), zlib.crc32(pay.tobytes())) & 0xFFFFFFFF


def wire_bits(packed) -> int:
    """Total framed bits (payload + params) — the bit-flip domain."""
    pay, par = _wire_children(packed)
    return (pay.nbytes + par.nbytes) * 8


def frame(packed) -> tuple:
    """``(packed, crc)`` — what a framed send puts on the wire."""
    return packed, wire_crc32(packed)


def verify_wire(packed, crc: int, *, where: str = "wire") -> None:
    """Raise ``WireCorruptionError`` unless the frame checks out."""
    got = wire_crc32(packed)
    want = int(crc) & 0xFFFFFFFF
    if got != want:
        raise WireCorruptionError(
            f"{where}: CRC32 mismatch on packed message "
            f"(got 0x{got:08x}, frame says 0x{want:08x}) — payload or "
            "params corrupted in flight")


def checked_decode(cdc: Codec, packed, crc: int, *, where: str = "wire"):
    """Verify the frame, then decode; the receive edge in one call."""
    verify_wire(packed, crc, where=where)
    out = (cdc.flat_decode(packed) if isinstance(packed, FlatPacked)
           else cdc.decode(packed))
    guard_finite(out, where=where)
    return out


def flip_bit(packed, bit: int):
    """A copy of the wire message with exactly one bit flipped —
    payload bits first, then params bits (the ``wire_bits`` order the
    fault plan's ``corrupt_bit`` indexes into)."""
    children, treedef = jax.tree_util.tree_flatten(packed)
    pay, par = (np.asarray(jax.device_get(c)) for c in children)
    if not 0 <= bit < (pay.nbytes + par.nbytes) * 8:
        raise ValueError(f"bit {bit} outside the "
                         f"{(pay.nbytes + par.nbytes) * 8}-bit frame")

    def _flipped(arr, b):
        buf = bytearray(arr.tobytes())
        buf[b // 8] ^= 1 << (b % 8)
        return np.frombuffer(bytes(buf),
                             dtype=arr.dtype).reshape(arr.shape)

    if bit < pay.nbytes * 8:
        pay = _flipped(pay, bit)
    else:
        par = _flipped(par, bit - pay.nbytes * 8)
    return jax.tree_util.tree_unflatten(
        treedef, [jnp.asarray(pay), jnp.asarray(par)])


def tree_finite(tree) -> bool:
    """Host-side all-finite check over a decoded pytree."""
    return all(bool(jnp.isfinite(leaf).all())
               for leaf in jax.tree_util.tree_leaves(tree))


def guard_finite(tree, *, where: str = "decode") -> None:
    """The post-decode guard: NaN/Inf that slipped past the checksum
    (or a worker emitting garbage) raises instead of poisoning the
    aggregate — the scheduler ledgers the skip as a ``CorruptRecord``."""
    if not tree_finite(tree):
        raise WireCorruptionError(
            f"{where}: decoded payload contains NaN/Inf — contribution "
            "skipped (post-decode finite guard)")
