"""The paper's system relaxations as composable gradient/model exchanges.

Everything here runs inside a mapped context (``shard_map``/``vmap``/``pmap``)
with a named worker axis — each call sees ONE worker's local tensors plus
collectives over ``axis_name``. This is the faithful algorithm tier: per-worker
compression randomness, per-worker error state, exact update rules.

  MbSGDExchange      distributed baseline, Eq. (2.2)        pmean
  CSGDPSExchange     Eq. (3.2)  Q(1/N sum Q(g_n))           multi-server PS form
  CSGDRingExchange   Eq. (3.3)  per-partition chains        partitioned ring
                     (reduce-scatter + all-gather, Fig 3.3) AllReduce
  ECSGDExchange      Eqs. (3.8)-(3.12) DoubleSqueeze        two-sided EC
  DelayedExchange    Assumption 5 bounded staleness (tau)   wraps any exchange
  GossipMix          Eq. (5.2)  X <- (X - gamma G) W        ppermute ring / pmean
  DCDGossipExchange  difference-compressed DSGD             compressed gossip
                     (DCD-PSGD, Tang et al. 2018)           over any W
  ECDGossipExchange  error-compensated DCD variant          + flat residual
                     (ECD-PSGD-style, cf. DoubleSqueeze)    buffer

Compression is obtained from the Codec registry (repro.core.compression).
The compressed exchanges default to the **fused flat-buffer tier**
(``flat=True``): the whole gradient pytree is flattened onto a
FlatLayout and moves as ONE bucketed message per exchange step — a ring
hop ppermutes exactly one packed payload + one (n_buckets, 2) params
header instead of one pair per pytree leaf, so an L-leaf gradient pays
``t_lat`` once per hop, not L times (§1.3's per-message latency charge).
``flat=False`` keeps the per-leaf reference path: there the ring moves a
tree of Packed objects through ``ppermute`` and the PS forms fall back
to leaf-wise qdq. Both tiers are numerically honest — decode(encode(.))
== qdq(.) bit-for-bit per bucket/leaf for the packable codecs; where a
summation needs fp32 (the PS pmean) the fused qdq is used directly.
Every exchange reports its measured per-iteration wire bytes via
``message_bytes`` (consumed by eventsim / table1_1).

The production (pjit) tier reuses the same codec registry on the
device-owned gradient shard (multi-server-PS view: devices ARE the
servers of their FSDP partition); see train/steps.py.

Wire integrity: every decode site here runs INSIDE the mapped graph
(shard_map/ppermute), where a checksum branch would perturb the
bit-identity contracts above — so integrity framing lives one layer
down, host-side, in ``repro.core.compression`` (``frame`` /
``verify_wire`` / ``checked_decode`` compute a CRC32 over a FlatPacked's
payload + params bytes, and ``guard_finite`` catches NaN/Inf that a CRC
cannot, since a poisoned-but-consistent payload frames correctly). The
cluster tier models detection outcomes on the simulated wire
(``faults.FaultPlan.corrupts_msg``); the 4-byte CRC sidecar is
deliberately NOT charged to ``message_bytes`` so measured wire bytes —
and every eventsim makespan derived from them — are unchanged.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache, wraps
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro import obs
from repro.core import compression

PyTree = Any


def _sized(fn):
    """Metrics tap on every ``message_bytes`` sizing call: the measured
    per-iteration wire bytes one worker pays under this exchange, by
    exchange name (host-side sizing only — never runs inside jit)."""
    @wraps(fn)
    def wrapper(self, tree, **kw):
        b = fn(self, tree, **kw)
        if obs.enabled("metrics"):
            obs.gauge("comm.message_bytes", exchange=self.name).set(b)
            obs.counter("comm.sized_total_bytes",
                        exchange=self.name).inc(b)
        return b
    return wrapper


def _tree_map2(fn, a, b):
    return jax.tree_util.tree_map(fn, a, b)


def _worker_key(key: jax.Array, axis_name: str) -> jax.Array:
    return jax.random.fold_in(key, lax.axis_index(axis_name))


def _axis_size(axis_name: str):
    """Static size of a named axis (psum of a unit literal is constant-
    folded to a Python int under vmap/pmap/shard_map)."""
    return lax.psum(1, axis_name)


def _tree_ppermute(tree, axis_name: str, perm):
    """ppermute every array leaf of a pytree (incl. Packed wire objects)."""
    return jax.tree_util.tree_map(
        lambda a: lax.ppermute(a, axis_name, perm), tree)


def _fp32_bytes(tree) -> float:
    """Uncompressed fp32 wire bytes of one message (via the 'none' codec
    so all byte accounting flows through the registry)."""
    return compression.codec("none").tree_wire_bytes(tree)


# `message_bytes(tree, n_workers=...)` on every exchange reports the wire
# bytes ONE worker sends per iteration under the exchange's native
# pattern — the quantity RunResult.comm_bytes_per_step and table1_1's
# wire_B/step column print.


@dataclasses.dataclass(frozen=True)
class MbSGDExchange:
    """Synchronous data-parallel baseline: exact mean of worker gradients."""

    name: str = "mbsgd"

    def init(self, params: PyTree) -> PyTree:
        return ()

    def __call__(self, grad: PyTree, state: PyTree, key: jax.Array, *,
                 axis_name: str) -> tuple[PyTree, PyTree]:
        return lax.pmean(grad, axis_name), state

    @_sized
    def message_bytes(self, tree, *, n_workers: int = 1) -> float:
        """Uplink + broadcast share, fp32 — same multi-server-PS
        convention as the compressed exchanges so the columns compare."""
        del n_workers
        return 2.0 * _fp32_bytes(tree)


@dataclasses.dataclass(frozen=True)
class CSGDPSExchange:
    """CSGD, multi-server parameter-server form, Eq. (3.2).

    Workers quantize independently (per-worker key); the server's outgoing
    compression uses a key shared by all workers so the broadcast value is
    identical everywhere (it is one physical message in the paper).

    The server-side mean needs fp32 arithmetic, so both directions use the
    fused qdq (identical bits to a decode(encode(.)) round trip); the
    measured wire cost of the packed payload is still what
    ``message_bytes`` reports.

    flat=True (default) runs both directions through the fused
    flat-buffer tier: one flatten, one bucketed qdq per direction, ONE
    logical message per direction instead of one per leaf.
    """

    compressor: str = "rq8"
    name: str = "csgd_ps"
    flat: bool = True

    def init(self, params: PyTree) -> PyTree:
        return ()

    def __call__(self, grad, state, key, *, axis_name):
        cdc = compression.codec(self.compressor)
        wkey = _worker_key(key, axis_name)
        skey = jax.random.fold_in(key, 0x5E4E4)
        if self.flat:
            layout = compression.FlatLayout.from_tree(grad)
            # both inputs are dead temporaries (a fresh flatten, a pmean
            # result) -> donate their storage to the qdq output
            local_q = cdc.flat_qdq(layout.flatten(grad), wkey, donate=True)
            out = cdc.flat_qdq(lax.pmean(local_q, axis_name), skey,
                               donate=True)
            return layout.unflatten(out), state
        local_q = cdc.tree_qdq(grad, wkey)
        mean_q = lax.pmean(local_q, axis_name)
        out = cdc.tree_qdq(mean_q, skey)
        return out, state

    @_sized
    def message_bytes(self, tree, *, n_workers: int = 1) -> float:
        """One worker->server message + this worker's share of the
        broadcast (in the multi-server view each worker also serves its
        partition of the outgoing message, one partition per peer)."""
        del n_workers
        cdc = compression.codec(self.compressor)
        if self.flat:
            return 2.0 * cdc.tree_wire_bytes_flat(tree)
        return 2.0 * cdc.tree_wire_bytes(tree)


@dataclasses.dataclass(frozen=True)
class CSGDRingExchange:
    """CSGD, ring-AllReduce form, Eq. (3.3) — partitioned by default.

    partitioned=True (default, needs a packable codec): the classic
    bandwidth-optimal reduce-scatter + all-gather decomposition with the
    paper's per-partition requantization chains (Figure 3.3). The flat
    gradient buffer is sliced into N equal granule-aligned partitions,
    each bucketed and packed independently:

      * reduce-scatter, N-1 hops: at hop h worker i receives the encoded
        partial sum of partition (i-h) mod N from its left neighbor,
        decodes, adds its OWN slice of that partition, re-encodes — so
        partition p accumulates Q(..Q(Q(g_p[p]) + g_{p+1}[p]).. + g_{p+N-1}[p]),
        exactly Eq. (3.3) applied per partition. Every hop ships ONE
        partition: M/N wire bytes.
      * all-gather, N-1 hops: finished partitions circulate VERBATIM
        (payload + params bytes copied into the backing
        PartitionedFlatPacked buffer, no re-quantization) until every
        worker holds all N — hence the result is bit-identical across
        workers, unlike the monolithic chain where each worker ends with
        its own nesting order (both satisfy Eq. (3.3)'s recursion).

    Per-worker wire bytes: 2(N-1) partition messages = 2*M*(N-1)/N (plus
    at most one pad granule + params rows per partition), vs the
    monolithic chain's (N-1)*M — the §1.3.3 "why do we partition"
    argument, now carried by the real exchange.

    partitioned=False keeps the monolithic chains: flat=True ships ONE
    whole-tree FlatPacked per hop ((N-1 hops, full M each, per-worker
    nesting orders); flat=False is the per-leaf reference (a tree of
    Packed objects, 2L arrays through ppermute per hop). Non-packable
    codecs always fall back to the monolithic qdq formulation — the
    all-gather's verbatim forwarding needs a wire format.
    """

    compressor: str = "rq8"
    name: str = "csgd_ring"
    flat: bool = True
    partitioned: bool = True

    def init(self, params: PyTree) -> PyTree:
        return ()

    def __call__(self, grad, state, key, *, axis_name):
        cdc = compression.codec(self.compressor)
        n = _axis_size(axis_name)
        perm = [(i, (i + 1) % n) for i in range(n)]
        wkey = _worker_key(key, axis_name)

        if (self.flat and self.partitioned and cdc.packable
                and isinstance(n, int) and n > 1):
            return self._partitioned_allreduce(grad, state, key, cdc, n,
                                               perm, axis_name)
        if self.flat and cdc.packable and isinstance(n, int) and n > 1:
            layout = compression.FlatLayout.from_tree(grad)
            gflat = layout.flatten(grad)
            acc = cdc.flat_encode(gflat, wkey, layout)

            def hop(h, acc):
                shifted = _tree_ppermute(acc, axis_name, perm)
                summed = cdc.flat_decode(shifted) + gflat
                return cdc.flat_encode(summed, jax.random.fold_in(wkey, h),
                                       layout)

            acc = lax.fori_loop(1, n, hop, acc)
            return layout.unflatten(cdc.flat_decode(acc) / n), state
        if cdc.packable and isinstance(n, int) and n > 1:
            acc = cdc.tree_encode(grad, wkey)

            def hop(h, acc):
                shifted = _tree_ppermute(acc, axis_name, perm)
                summed = _tree_map2(lambda a, g: a + g,
                                    cdc.tree_decode(shifted), grad)
                return cdc.tree_encode(summed, jax.random.fold_in(wkey, h))

            acc = lax.fori_loop(1, n, hop, acc)
            out = cdc.tree_decode(acc)
        else:
            tree_qdq = cdc.tree_qdq_flat if self.flat else cdc.tree_qdq
            out = tree_qdq(grad, wkey)

            def hop_qdq(h, acc):
                shifted = lax.ppermute(acc, axis_name, perm)
                summed = _tree_map2(lambda a, g: a + g, shifted, grad)
                return tree_qdq(summed, jax.random.fold_in(wkey, h))

            if isinstance(n, int) and n > 1:
                out = lax.fori_loop(1, n, hop_qdq, out)
        return jax.tree_util.tree_map(lambda a: a / n, out), state

    def _partitioned_allreduce(self, grad, state, key, cdc, n: int, perm,
                               axis_name: str):
        """Reduce-scatter + all-gather over the N-way partition view."""
        i = lax.axis_index(axis_name)
        wkey = _worker_key(key, axis_name)
        layout = compression.FlatLayout.from_tree(grad)
        part_elems, _, _ = cdc.partition_geometry(layout.total, n)
        from repro.kernels.quant import ops as _qops
        padded = _qops.edge_pad(layout.flatten(grad), n * part_elems)
        gparts = padded.reshape(n, part_elems)

        def local_slice(pidx):
            return lax.dynamic_index_in_dim(gparts, pidx, 0,
                                            keepdims=False)

        # --- reduce-scatter: hop h ships the partial sum of partition
        # (i - h) mod N; the decode-add-re-encode runs as ONE fused
        # dispatch over 1/N of the buffer (partitions are granule-aligned
        # by construction, so the fused path always applies) —
        # bit-identical to the decode; add; encode composition.
        pay, prm = cdc.encode_partition(local_slice(i), wkey)

        def rs_hop(h, carry):
            pay, prm = carry
            pay = lax.ppermute(pay, axis_name, perm)
            prm = lax.ppermute(prm, axis_name, perm)
            pidx = (i - h) % n
            return cdc.decode_add_encode_partition(
                pay, prm, local_slice(pidx), jax.random.fold_in(wkey, h))

        pay, prm = lax.fori_loop(1, n, rs_hop, (pay, prm))

        # --- all-gather: worker i finished partition (i+1) mod N; N-1
        # hops forward finished partitions VERBATIM (no re-encode) into
        # one backing buffer — every worker ends bit-identical.
        payload_all = jnp.zeros((n,) + pay.shape, pay.dtype)
        params_all = jnp.zeros((n,) + prm.shape, prm.dtype)
        own = (i + 1) % n
        payload_all = lax.dynamic_update_index_in_dim(payload_all, pay,
                                                      own, 0)
        params_all = lax.dynamic_update_index_in_dim(params_all, prm,
                                                     own, 0)

        def ag_hop(g, carry):
            pa, pr, pay, prm = carry
            pay = lax.ppermute(pay, axis_name, perm)
            prm = lax.ppermute(prm, axis_name, perm)
            idx = (i + 1 - g) % n
            pa = lax.dynamic_update_index_in_dim(pa, pay, idx, 0)
            pr = lax.dynamic_update_index_in_dim(pr, prm, idx, 0)
            return pa, pr, pay, prm

        payload_all, params_all, _, _ = lax.fori_loop(
            1, n, ag_hop, (payload_all, params_all, pay, prm))

        packed = compression.PartitionedFlatPacked(
            payload_all, params_all, layout, cdc.name,
            compression.DEFAULT_BUCKET_ELEMS, part_elems)
        out = cdc.flat_decode_partitioned(packed) / n
        return layout.unflatten(out), state

    @_sized
    def message_bytes(self, tree, *, n_workers: int = 2) -> float:
        """Partitioned: 2(n-1) partition messages per iteration
        (= 2*M*(n-1)/n + pad/header overhead); monolithic: n-1 hops of
        one whole-tree message each."""
        cdc = compression.codec(self.compressor)
        hops = max(n_workers - 1, 1)
        if self.flat and self.partitioned and cdc.packable and n_workers > 1:
            return 2.0 * hops * cdc.tree_wire_bytes_partitioned(
                tree, n_workers)
        per_hop = (cdc.tree_wire_bytes_flat(tree) if self.flat
                   else cdc.tree_wire_bytes(tree))
        return hops * per_hop

    def n_wire_messages(self, n_workers: int) -> int:
        """Wire messages one worker sends per iteration (eventsim's
        per-message latency accounting): 2(n-1) partition messages on the
        partitioned path, n-1 whole-buffer messages on the monolithic
        chains."""
        cdc = compression.codec(self.compressor)
        hops = max(n_workers - 1, 1)
        if self.flat and self.partitioned and cdc.packable and n_workers > 1:
            return 2 * hops
        return hops


@dataclasses.dataclass(frozen=True)
class ECSGDExchange:
    """Error-compensated SGD / DoubleSqueeze, Eqs. (3.8)-(3.12).

    Worker side:  v_n = g_n + delta_n ; send Q(v_n) ; delta_n = v_n - Q(v_n)
    Server side:  v = mean_n Q(v_n) + delta ; bcast Q(v) ; delta = v - Q(v)
    Works with ANY codec, biased ones included (Section 3.3); tested via
    Lemma 3.4.1's x_tilde recursion. Both sides need the dequantized value
    for the error recursion, so this uses the fused qdq throughout.

    flat=True (default): both error buffers are SINGLE flat fp32
    residual vectors over the whole gradient tree, and the compression /
    error recursion runs on the flat buffer — one fused pass per side,
    one logical message per direction. flat=False keeps per-leaf error
    trees (the reference formulation).
    """

    compressor: str = "sign1"
    name: str = "ecsgd"
    flat: bool = True

    def init(self, params: PyTree) -> PyTree:
        if self.flat:
            total = compression.FlatLayout.from_tree(params).total
            return {"worker_err": jnp.zeros((total,), jnp.float32),
                    "server_err": jnp.zeros((total,), jnp.float32)}
        z = jax.tree_util.tree_map(jnp.zeros_like, params)
        return {"worker_err": z, "server_err": z}

    def __call__(self, grad, state, key, *, axis_name):
        cdc = compression.codec(self.compressor)
        wkey = _worker_key(key, axis_name)
        skey = jax.random.fold_in(key, 0x5E4E4)
        if self.flat:
            layout = compression.FlatLayout.from_tree(grad)
            gflat = layout.flatten(grad)
            # worker side (Eqs. 3.8-3.9) on the flat residual buffer
            v_n = gflat + state["worker_err"]
            q_n = cdc.flat_qdq(v_n, wkey)
            # server side (Eqs. 3.10-3.11); shared key -> identical everywhere
            v = lax.pmean(q_n, axis_name) + state["server_err"]
            out = cdc.flat_qdq(v, skey)
            return layout.unflatten(out), {"worker_err": v_n - q_n,
                                           "server_err": v - out}
        # worker side (Eqs. 3.8-3.9)
        v_n = _tree_map2(lambda g, d: g + d, grad, state["worker_err"])
        q_n = cdc.tree_qdq(v_n, wkey)
        new_worker_err = _tree_map2(lambda v, q: v - q, v_n, q_n)
        # server side (Eqs. 3.10-3.11); shared key -> identical on all workers
        v = _tree_map2(lambda m, d: m + d, lax.pmean(q_n, axis_name),
                       state["server_err"])
        out = cdc.tree_qdq(v, skey)
        new_server_err = _tree_map2(lambda a, b: a - b, v, out)
        return out, {"worker_err": new_worker_err, "server_err": new_server_err}

    @_sized
    def message_bytes(self, tree, *, n_workers: int = 1) -> float:
        """As CSGDPSExchange: worker->server + broadcast share."""
        del n_workers
        cdc = compression.codec(self.compressor)
        if self.flat:
            return 2.0 * cdc.tree_wire_bytes_flat(tree)
        return 2.0 * cdc.tree_wire_bytes(tree)


@dataclasses.dataclass(frozen=True)
class DelayedExchange:
    """Bounded-staleness wrapper (ASGD, Section 4, Assumption 5).

    Default (``schedule=None``): a length-tau FIFO — the update returned at
    step t is the one computed at step t - tau (the D(t) = t - tau worst
    case). The first tau steps replay the oldest available gradient of the
    warmup buffer (zeros), matching an idle-start cluster.

    ``schedule``: TRACE-DRIVEN per-step staleness. A 1-D sequence s_t (all
    workers share it) or a 2-D (n_workers, T) table (row per worker) of
    integer delays, each clipped to [0, tau] (Assumption 5's bound); the
    update returned at step t is the one computed at step t - s_t, with
    zeros before the cluster produced one. This is how a measured
    ``repro.cluster`` scheduler trace (staleness column of its
    TraceEvents) is replayed through the algorithm tier — see
    ``repro.cluster.protocols.staleness_schedule``. Steps past the end of
    the schedule wrap around (periodic replay).
    """

    inner: Any = dataclasses.field(default_factory=MbSGDExchange)
    tau: int = 4
    name: str = "asgd"
    schedule: Any = None      # None | 1-D | 2-D ints; tuple-ized below

    def __post_init__(self):
        if self.schedule is not None:
            import numpy as np
            s = np.asarray(self.schedule, dtype=int)
            if s.ndim == 1:
                sched = tuple(int(v) for v in s)
            elif s.ndim == 2:
                sched = tuple(tuple(int(v) for v in row) for row in s)
            else:
                raise ValueError("schedule must be 1-D or 2-D")
            # nested tuple keeps the frozen dataclass hashable
            object.__setattr__(self, "schedule", sched)

    def _cap(self) -> int:
        # schedule mode needs tau+1 slots: s=0 must read the value written
        # THIS step, while s=tau still reads step t-tau un-clobbered
        return self.tau + 1 if self.schedule is not None else max(self.tau, 1)

    def init(self, params: PyTree) -> PyTree:
        buf = jax.tree_util.tree_map(
            lambda p: jnp.zeros((self._cap(),) + p.shape, p.dtype), params)
        return {"inner": self.inner.init(params), "buffer": buf,
                "head": jnp.zeros((), jnp.int32)}

    def __call__(self, grad, state, key, *, axis_name):
        fresh, inner_state = self.inner(grad, state["inner"], key,
                                        axis_name=axis_name)
        if self.schedule is not None:
            return self._delayed_by_schedule(fresh, state, inner_state,
                                             axis_name)
        if self.tau <= 0:
            return fresh, {"inner": inner_state, "buffer": state["buffer"],
                           "head": state["head"]}
        head = state["head"]
        stale = jax.tree_util.tree_map(
            lambda b: lax.dynamic_index_in_dim(b, head, 0, keepdims=False),
            state["buffer"])
        buf = _tree_map2(
            lambda b, f: lax.dynamic_update_index_in_dim(b, f, head, 0),
            state["buffer"], fresh)
        return stale, {"inner": inner_state, "buffer": buf,
                       "head": (head + 1) % self.tau}

    def _delayed_by_schedule(self, fresh, state, inner_state, axis_name):
        """Write fresh at slot t mod (tau+1), read slot (t - s_t)."""
        step = state["head"]          # reused as the step counter
        sched = jnp.asarray(self.schedule, jnp.int32)
        if sched.ndim == 2:
            n = _axis_size(axis_name)
            if sched.shape[0] != n:
                # without this, jax's clamping gather would silently give
                # out-of-range workers the last row's delays
                raise ValueError(f"2-D schedule has {sched.shape[0]} rows "
                                 f"but the '{axis_name}' axis has {n} "
                                 "workers")
            s_t = sched[lax.axis_index(axis_name), step % sched.shape[1]]
        else:
            s_t = sched[step % sched.shape[0]]
        s_t = jnp.clip(s_t, 0, self.tau)
        cap = self._cap()
        slot = step % cap
        buf = _tree_map2(
            lambda b, f: lax.dynamic_update_index_in_dim(b, f, slot, 0),
            state["buffer"], fresh)
        read = (step - s_t) % cap
        # a not-yet-produced gradient (t - s_t < 0) is the idle-start zero
        stale = jax.tree_util.tree_map(
            lambda b: jnp.where(
                step >= s_t,
                lax.dynamic_index_in_dim(b, read, 0, keepdims=False),
                jnp.zeros(b.shape[1:], b.dtype)),
            buf)
        return stale, {"inner": inner_state, "buffer": buf,
                       "head": step + 1}

    @_sized
    def message_bytes(self, tree, *, n_workers: int = 1) -> float:
        return self.inner.message_bytes(tree, n_workers=n_workers)


def _freeze_w(obj) -> None:
    """Store a frozen dataclass's ``w`` matrix as a nested tuple (keeps
    the exchange hashable/comparable — shared by GossipMix and DCD/ECD)."""
    if obj.w is not None:
        import numpy as np
        w = np.asarray(obj.w, dtype=float)
        object.__setattr__(obj, "w",
                           tuple(tuple(row) for row in w.tolist()))


def _resolve_matrix(w, topology: str, n: int):
    """Explicit (n, n) gossip matrix for a (w, topology) spec: an
    explicit ``w`` wins; otherwise the named ``mixing.py`` builder."""
    import numpy as np

    from repro.core import mixing
    if w is not None:
        w = np.asarray(w)
        if w.shape != (n, n):
            raise ValueError(f"W is {w.shape}, axis has {n} workers")
        return w
    if topology == "ring":
        return mixing.ring(n)
    if topology == "torus":
        return mixing.torus_2d(*mixing.near_square_factors(n))
    if topology == "full":
        return mixing.fully_connected(n)
    raise ValueError(f"unknown topology {topology}")


@dataclasses.dataclass(frozen=True)
class GossipMix:
    """Decentralized model mixing, Eq. (5.2): X_{t+1} = (X_t - gamma G_t) W.

    ``topology='ring'`` implements the paper's W2 (self + both neighbors, all
    1/3) with two ppermutes — the O(1)-latency pattern of §5.1.
    ``topology='full'`` is W1 = 11^T/N (reduces DSGD to mb-SGD, Thm 5.2.6
    consistency check). TPU note: ppermute on a ring maps directly onto ICI
    neighbor links; this is the decentralized pattern's native home.

    Beyond the two built-ins, ANY ``mixing.py`` matrix runs as collectives:
    ``topology='torus'`` folds the worker axis onto ``mixing.torus_2d``
    (near-square rows x cols), and ``w=<matrix>`` takes an explicit doubly
    stochastic W. Both are lowered via ``mixing.birkhoff_decomposition``:
    W = sum_k c_k P_k, executed as one ``lax.ppermute`` per non-identity
    permutation P_k, scaled by the scalar c_k — deg(W) is therefore exactly
    the number of wire messages each worker sends per mix (§5.1's cost).
    """

    topology: str = "ring"
    name: str = "gossip"
    w: Any = None        # explicit doubly stochastic matrix (overrides
                         # topology); stored as nested tuple, see __post_init__

    def __post_init__(self):
        _freeze_w(self)

    def _matrix(self, n: int):
        """The explicit W to lower for this axis size, or None for the
        ring/full ppermute fast paths."""
        if self.w is None and self.topology in ("ring", "full"):
            return None
        return _resolve_matrix(self.w, self.topology, n)

    def __call__(self, params: PyTree, *, axis_name: str) -> PyTree:
        from repro.core import mixing

        n = _axis_size(axis_name)
        w = self._matrix(n)
        if w is not None:
            if n == 1:
                return params
            terms = mixing.birkhoff_decomposition(w)

            def mix(x):
                acc = jnp.zeros_like(x)
                for c, perm in terms:
                    acc = acc + c * (x if not perm
                                     else lax.ppermute(x, axis_name,
                                                       list(perm)))
                return acc

            return jax.tree_util.tree_map(mix, params)
        if self.topology == "full":
            return lax.pmean(params, axis_name)
        right = [(i, (i + 1) % n) for i in range(n)]
        left = [(i, (i - 1) % n) for i in range(n)]

        def mix(x):
            if n == 1:
                return x
            xr = lax.ppermute(x, axis_name, right)
            xl = lax.ppermute(x, axis_name, left)
            if n == 2:  # both neighbors are the same worker: 1/3 self + 2/3 nbr
                return x / 3.0 + 2.0 * xr / 3.0
            return (x + xr + xl) / 3.0

        return jax.tree_util.tree_map(mix, params)

    @_sized
    def message_bytes(self, tree, *, n_workers: int = 3) -> float:
        """Full fp32 model to each neighbor: deg(W) sends per mix — 2 on
        the ring (both directions), 4 on the torus, n-1 under W1."""
        from repro.core import mixing

        w = self._matrix(n_workers)
        if w is not None:
            degree = mixing.degree(w)
        else:
            degree = 2 if self.topology == "ring" else max(n_workers - 1, 1)
            if self.topology == "ring" and n_workers == 2:
                degree = 1   # both neighbors are the same worker
        return degree * _fp32_bytes(tree)


@lru_cache(maxsize=64)
def _birkhoff_terms_cached(w_rows: tuple):
    """(c_identity, ((c_k, perm_k), ...)) of W's Birkhoff-von Neumann
    decomposition (perm_k in lax.ppermute's (src, dst) convention), cached
    on the nested-tuple matrix so traces don't re-peel the same W."""
    import numpy as np

    from repro.core import mixing

    terms = mixing.birkhoff_decomposition(np.asarray(w_rows))
    c_id = sum(c for c, perm in terms if not perm)
    nonid = tuple((c, perm) for c, perm in terms if perm)
    return float(c_id), nonid


@dataclasses.dataclass(frozen=True)
class DCDGossipExchange:
    """Difference-compressed decentralized mixing: DCD-PSGD over any W.

    The paper's culminating combination (Section 5 + *Decentralized
    training with compressed communication*, Tang et al. 2018; cf.
    Khirirat et al. 2018): every worker keeps its own *public copy*
    ``x̂_i`` — the value every neighbor's replica of it holds — and per
    iteration

      1. ``x_i^{t+1/2} = sum_j W_ij x̂_j^t - gamma g_i``   (mix on replicas)
      2. ``delta_i = x_i^{t+1/2} - x̂_i^t``                 (the difference)
      3. broadcast ``Q(delta_i)`` through the fused flat Codec path —
         ONE FlatPacked per neighbor, compressed bytes on the wire;
      4. every holder (the worker itself included) applies the *decoded*
         delta: ``x̂_i^{t+1} = x̂_i^t + decode(Q(delta_i))`` — so the
         worker's model and all replicas of it stay BIT-IDENTICAL (the
         replica-drift lemma; decode(encode(.)) == qdq(.) for packable
         codecs), and the compression error enters through an
         ever-shrinking delta instead of the full model.

    The mixing runs over ANY doubly stochastic ``W`` via
    ``mixing.birkhoff_decomposition``: one ``lax.ppermute`` of the packed
    wire object per non-identity permutation term, so neighbors'
    replicas are maintained per term (state ``nbr[k]`` tracks the
    term-k source's public copy) and the model average
    ``sum_j W_ij x̂_j`` is assembled from scalars c_k times replicas.
    Wire cost: deg(W) compressed-delta messages per mix (the §5.1
    serialization), vs GossipMix's deg(W) full fp32 models.

    State (flat fp32 buffers over the whole model tree):
      xhat: (total,)    this worker's public copy (== its model)
      nbr:  (K, total)  decoded replica per non-identity Birkhoff term

    Like ``GossipMix`` this is a model operator applied after the local
    SGD step, but stateful: ``init_stacked(params_w)`` builds the
    replica state OUTSIDE the mapped context (it needs the worker count
    from the stacked leading axis), then ``__call__(params, state, key,
    axis_name=...)`` runs per worker under vmap/shard_map.
    """

    compressor: str = "rq4"
    topology: str = "ring"
    w: Any = None
    name: str = "dcd"
    error_compensated = False        # class attr (ECD subclass flips it)

    def __post_init__(self):
        _freeze_w(self)

    def _matrix(self, n: int):
        """The explicit W for this axis size (unlike GossipMix there is
        no matrix-free fast path — the replicas are keyed on W's
        Birkhoff terms)."""
        return _resolve_matrix(self.w, self.topology, n)

    def birkhoff_terms(self, n: int):
        """(c_identity, ((c_k, perm_k), ...)) — the ppermute lowering."""
        w = self._matrix(n)
        return _birkhoff_terms_cached(tuple(tuple(row) for row in
                                            w.tolist()))

    def degree(self, n: int) -> int:
        from repro.core import mixing
        return mixing.degree(self._matrix(n))

    def init_stacked(self, params_w: PyTree) -> PyTree:
        """Replica state from the (n_workers, ...) stacked params — call
        OUTSIDE vmap (the worker count comes from the leading axis).
        nbr[w, k] starts at the term-k source's flattened params, so the
        replica invariant holds from step 0 even if workers start from
        different models."""
        import numpy as np

        leaves = jax.tree_util.tree_leaves(params_w)
        n = int(leaves[0].shape[0])
        per_worker = jax.tree_util.tree_map(lambda p: p[0], params_w)
        layout = compression.FlatLayout.from_tree(per_worker)
        xhat = jax.vmap(layout.flatten)(params_w)            # (n, total)
        _, terms = self.birkhoff_terms(n)
        if terms:
            idx = np.zeros((len(terms), n), dtype=int)       # idx[k, dst]=src
            for k, (_, perm) in enumerate(terms):
                for src, dst in perm:
                    idx[k, dst] = src
            nbr = jnp.swapaxes(xhat[jnp.asarray(idx)], 0, 1)  # (n, K, total)
        else:
            nbr = jnp.zeros((n, 0, layout.total), jnp.float32)
        state = {"xhat": xhat, "nbr": nbr}
        if self.error_compensated:
            state["err"] = jnp.zeros((n, layout.total), jnp.float32)
        return state

    def __call__(self, params: PyTree, state: PyTree, key: jax.Array, *,
                 axis_name: str) -> tuple[PyTree, PyTree]:
        cdc = compression.codec(self.compressor)
        n = _axis_size(axis_name)
        layout = compression.FlatLayout.from_tree(params)
        c_id, terms = self.birkhoff_terms(n)
        xhat = state["xhat"]
        # the call site hands us x̂_i - gamma g_i (model == public copy)
        y = layout.flatten(params)
        z = c_id * xhat                       # sum_j W_ij x̂_j from replicas
        for k, (c, _) in enumerate(terms):
            z = z + c * state["nbr"][k]
        x_half = (y - xhat) + z               # = sum_j W_ij x̂_j - gamma g_i
        v = x_half - xhat                     # the broadcast delta
        if self.error_compensated:
            v = v + state["err"]
        wkey = _worker_key(key, axis_name)
        if cdc.packable:
            wire = cdc.flat_encode(v, wkey, layout)
            q = cdc.flat_decode(wire)         # == flat_qdq(v, wkey) bits
        else:
            wire = q = cdc.flat_qdq(v, wkey)
        new_xhat = xhat + q
        nbr = state["nbr"]
        for k, (_, perm) in enumerate(terms):
            # the compressed wire object itself moves; receivers decode
            # and apply — replicas advance on exactly the wire bytes
            shifted = _tree_ppermute(wire, axis_name, list(perm))
            dq = cdc.flat_decode(shifted) if cdc.packable else shifted
            nbr = nbr.at[k].add(dq)
        new_state = {"xhat": new_xhat, "nbr": nbr}
        if self.error_compensated:
            new_state["err"] = v - q
        return layout.unflatten(new_xhat), new_state

    @_sized
    def message_bytes(self, tree, *, n_workers: int = 3) -> float:
        """deg(W) compressed-delta messages per mix: each neighbor gets
        ONE fused flat message (payload + params header), vs GossipMix's
        deg(W) full fp32 models."""
        cdc = compression.codec(self.compressor)
        return self.degree(n_workers) * cdc.tree_wire_bytes_flat(tree)

    def n_wire_messages(self, n_workers: int) -> int:
        """Wire messages one worker sends per mix (eventsim's per-message
        latency accounting): one fused message per neighbor."""
        return self.degree(n_workers)


@dataclasses.dataclass(frozen=True)
class ECDGossipExchange(DCDGossipExchange):
    """Error-compensated compressed decentralized mixing (the ECD-PSGD
    slot of Tang et al. 2018, realized in the DoubleSqueeze/EC form of
    ``ECSGDExchange``): identical to DCD except the broadcast carries a
    residual-corrected delta

        v_i = (x_i^{t+1/2} - x̂_i) + e_i ;  ship Q(v_i) ;  e_i <- v_i - Q(v_i)

    with ``e_i`` a SINGLE flat fp32 residual buffer over the whole model
    (exactly the shape of ``ECSGDExchange(flat=True)``'s error state).
    The feedback makes biased codecs usable — the default is the 1-bit
    ``sign1`` operator, which plain DCD cannot survive — while the
    replica invariant (model == public copy on every holder) is kept.
    """

    compressor: str = "sign1"
    name: str = "ecd"
    error_compensated = True


from repro.core.registry import Registry, make_factory  # noqa: E402

EXCHANGES: Registry = Registry("exchange", {
    "mbsgd": MbSGDExchange,
    "csgd_ps": CSGDPSExchange,
    "csgd_ring": CSGDRingExchange,
    "ecsgd": ECSGDExchange,
    "asgd": DelayedExchange,
    # model-mixing operator (params -> params, no gradient/state protocol);
    # registered so make_exchange("gossip", topology=...) works like every
    # other pattern instead of requiring a direct import
    "gossip": GossipMix,
    # stateful compressed-gossip operators (replica state via init_stacked)
    "dcd": DCDGossipExchange,
    "ecd": ECDGossipExchange,
})

make_exchange = make_factory(EXCHANGES)
