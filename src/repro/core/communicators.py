"""The paper's system relaxations as composable gradient/model exchanges.

Everything here runs inside a mapped context (``shard_map``/``vmap``/``pmap``)
with a named worker axis — each call sees ONE worker's local tensors plus
collectives over ``axis_name``. This is the faithful algorithm tier: per-worker
compression randomness, per-worker error state, exact update rules.

  MbSGDExchange      distributed baseline, Eq. (2.2)        pmean
  CSGDPSExchange     Eq. (3.2)  Q(1/N sum Q(g_n))           multi-server PS form
  CSGDRingExchange   Eq. (3.3)  Q(..Q(Q(g_1)+g_2)..+g_N)    ring AllReduce form
  ECSGDExchange      Eqs. (3.8)-(3.12) DoubleSqueeze        two-sided EC
  DelayedExchange    Assumption 5 bounded staleness (tau)   wraps any exchange
  GossipMix          Eq. (5.2)  X <- (X - gamma G) W        ppermute ring / pmean

The production (pjit) tier reuses the same compression registry but applies it
to the device-owned gradient shard (multi-server-PS view: devices ARE the
servers of their FSDP partition); see train/steps.py and DESIGN.md §2.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import compression

PyTree = Any


def _tree_map2(fn, a, b):
    return jax.tree_util.tree_map(fn, a, b)


def _worker_key(key: jax.Array, axis_name: str) -> jax.Array:
    return jax.random.fold_in(key, lax.axis_index(axis_name))


@dataclasses.dataclass(frozen=True)
class MbSGDExchange:
    """Synchronous data-parallel baseline: exact mean of worker gradients."""

    name: str = "mbsgd"

    def init(self, params: PyTree) -> PyTree:
        return ()

    def __call__(self, grad: PyTree, state: PyTree, key: jax.Array, *,
                 axis_name: str) -> tuple[PyTree, PyTree]:
        return lax.pmean(grad, axis_name), state


@dataclasses.dataclass(frozen=True)
class CSGDPSExchange:
    """CSGD, multi-server parameter-server form, Eq. (3.2).

    Workers quantize independently (per-worker key); the server's outgoing
    compression uses a key shared by all workers so the broadcast value is
    identical everywhere (it is one physical message in the paper).
    """

    compressor: str = "rq8"
    name: str = "csgd_ps"

    def init(self, params: PyTree) -> PyTree:
        return ()

    def __call__(self, grad, state, key, *, axis_name):
        q_fn, _ = compression.get(self.compressor)
        wkey = _worker_key(key, axis_name)
        local_q = compression.tree_compress(grad, wkey, q_fn)
        mean_q = lax.pmean(local_q, axis_name)
        out = compression.tree_compress(mean_q, jax.random.fold_in(key, 0x5E4E4), q_fn)
        return out, state


@dataclasses.dataclass(frozen=True)
class CSGDRingExchange:
    """CSGD, ring-AllReduce form, Eq. (3.3).

    The partial sum is re-compressed at every hop: after N-1 ppermute hops a
    worker holds Q(..Q(Q(g_{i+1}) + g_{i+2}).. + g_i) — each worker ends with
    a different nesting order, exactly like the per-partition chains of the
    paper's Figure 3.3.
    """

    compressor: str = "rq8"
    name: str = "csgd_ring"

    def init(self, params: PyTree) -> PyTree:
        return ()

    def __call__(self, grad, state, key, *, axis_name):
        q_fn, _ = compression.get(self.compressor)
        n = lax.axis_size(axis_name)
        perm = [(i, (i + 1) % n) for i in range(n)]
        wkey = _worker_key(key, axis_name)

        acc = compression.tree_compress(grad, wkey, q_fn)

        def hop(h, acc):
            shifted = lax.ppermute(acc, axis_name, perm)
            summed = _tree_map2(lambda a, g: a + g, shifted, grad)
            hop_key = jax.random.fold_in(wkey, h)
            return compression.tree_compress(summed, hop_key, q_fn)

        acc = lax.fori_loop(1, n, hop, acc) if isinstance(n, int) and n > 1 else acc
        return jax.tree_util.tree_map(lambda a: a / n, acc), state


@dataclasses.dataclass(frozen=True)
class ECSGDExchange:
    """Error-compensated SGD / DoubleSqueeze, Eqs. (3.8)-(3.12).

    Worker side:  v_n = g_n + delta_n ; send Q(v_n) ; delta_n = v_n - Q(v_n)
    Server side:  v = mean_n Q(v_n) + delta ; bcast Q(v) ; delta = v - Q(v)
    Works with ANY compressor, biased ones included (Section 3.3); tested via
    Lemma 3.4.1's x_tilde recursion.
    """

    compressor: str = "sign1"
    name: str = "ecsgd"

    def init(self, params: PyTree) -> PyTree:
        z = jax.tree_util.tree_map(jnp.zeros_like, params)
        return {"worker_err": z, "server_err": z}

    def __call__(self, grad, state, key, *, axis_name):
        q_fn, _ = compression.get(self.compressor)
        wkey = _worker_key(key, axis_name)
        # worker side (Eqs. 3.8-3.9)
        v_n = _tree_map2(lambda g, d: g + d, grad, state["worker_err"])
        q_n = compression.tree_compress(v_n, wkey, q_fn)
        new_worker_err = _tree_map2(lambda v, q: v - q, v_n, q_n)
        # server side (Eqs. 3.10-3.11); shared key -> identical on all workers
        v = _tree_map2(lambda m, d: m + d, lax.pmean(q_n, axis_name),
                       state["server_err"])
        out = compression.tree_compress(v, jax.random.fold_in(key, 0x5E4E4), q_fn)
        new_server_err = _tree_map2(lambda a, b: a - b, v, out)
        return out, {"worker_err": new_worker_err, "server_err": new_server_err}


@dataclasses.dataclass(frozen=True)
class DelayedExchange:
    """Bounded-staleness wrapper (ASGD, Section 4, Assumption 5).

    Maintains a length-tau FIFO of exchanged gradients; the update returned at
    step t is the one computed at step t - tau (the D(t) = t - tau worst case).
    The first tau steps replay the oldest available gradient of the warmup
    buffer (zeros), matching an idle-start cluster.
    """

    inner: Any = dataclasses.field(default_factory=MbSGDExchange)
    tau: int = 4
    name: str = "asgd"

    def init(self, params: PyTree) -> PyTree:
        buf = jax.tree_util.tree_map(
            lambda p: jnp.zeros((max(self.tau, 1),) + p.shape, p.dtype), params)
        return {"inner": self.inner.init(params), "buffer": buf,
                "head": jnp.zeros((), jnp.int32)}

    def __call__(self, grad, state, key, *, axis_name):
        fresh, inner_state = self.inner(grad, state["inner"], key,
                                        axis_name=axis_name)
        if self.tau <= 0:
            return fresh, {"inner": inner_state, "buffer": state["buffer"],
                           "head": state["head"]}
        head = state["head"]
        stale = jax.tree_util.tree_map(
            lambda b: lax.dynamic_index_in_dim(b, head, 0, keepdims=False),
            state["buffer"])
        buf = _tree_map2(
            lambda b, f: lax.dynamic_update_index_in_dim(b, f, head, 0),
            state["buffer"], fresh)
        return stale, {"inner": inner_state, "buffer": buf,
                       "head": (head + 1) % self.tau}


@dataclasses.dataclass(frozen=True)
class GossipMix:
    """Decentralized model mixing, Eq. (5.2): X_{t+1} = (X_t - gamma G_t) W.

    ``topology='ring'`` implements the paper's W2 (self + both neighbors, all
    1/3) with two ppermutes — the O(1)-latency pattern of §5.1.
    ``topology='full'`` is W1 = 11^T/N (reduces DSGD to mb-SGD, Thm 5.2.6
    consistency check). TPU note: ppermute on a ring maps directly onto ICI
    neighbor links; this is the decentralized pattern's native home.
    """

    topology: str = "ring"
    name: str = "gossip"

    def __call__(self, params: PyTree, *, axis_name: str) -> PyTree:
        n = lax.axis_size(axis_name)
        if self.topology == "full":
            return lax.pmean(params, axis_name)
        if self.topology != "ring":
            raise ValueError(f"unknown topology {self.topology}")
        right = [(i, (i + 1) % n) for i in range(n)]
        left = [(i, (i - 1) % n) for i in range(n)]

        def mix(x):
            if n == 1:
                return x
            xr = lax.ppermute(x, axis_name, right)
            xl = lax.ppermute(x, axis_name, left)
            if n == 2:  # both neighbors are the same worker: 1/3 self + 2/3 nbr
                return x / 3.0 + 2.0 * xr / 3.0
            return (x + xr + xl) / 3.0

        return jax.tree_util.tree_map(mix, params)


EXCHANGES: dict[str, Callable[..., Any]] = {
    "mbsgd": MbSGDExchange,
    "csgd_ps": CSGDPSExchange,
    "csgd_ring": CSGDRingExchange,
    "ecsgd": ECSGDExchange,
    "asgd": DelayedExchange,
}


def make_exchange(name: str, **kw) -> Any:
    if name not in EXCHANGES:
        raise KeyError(f"unknown exchange '{name}'; have {sorted(EXCHANGES)}")
    return EXCHANGES[name](**kw)
