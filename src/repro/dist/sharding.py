"""Sharding rules for the production (pjit) tier.

One place holds every placement decision:

  * params  — FSDP + tensor parallelism by parameter *name*:
      - matmul weights (d_in, d_out): column-parallel P('data', 'model')
        by default; output/down projections are row-parallel
        P('model', 'data') so the block needs exactly one all-reduce;
      - stacked banks (scan_blocks layer stacks, MoE expert banks) carry
        leading replicated dims and shard their input dim over ALL
        data-like axes (('pod', 'data') on the multi-pod mesh) — these are
        the dominant parameters, so they take the widest FSDP axis set;
      - the embedding table is fully sharded P('model', 'data'); the
        activations it produces are re-pinned by `constrain_act` (stops
        XLA propagating the table layout into token-replicated
        activations);
      - vectors (norm scales, biases) are replicated.
  * batches — leading batch dim over the activation batch axes
    (set_activation_batch_axes; ('data',) single-pod, ('pod', 'data')
    multi-pod), skipped when the dim does not divide.
  * caches  — (batch, seq, heads, head_dim) KV layouts shard batch by
    'data' and heads by 'model', falling back to head_dim when the head
    count does not divide the model axis (GQA with few KV heads).

Every rule degrades to replication when a dim does not divide the axis —
`_maybe` is the single divisibility gate, so a 1x1 test mesh exercises
the full rule logic without constraining anything.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

# Activation-batch axes: ('data',) single-pod, ('pod', 'data') multi-pod.
# Stacked parameter banks reuse this tuple as their FSDP axis set.
_ACT_BATCH_AXES: tuple = ("data",)

# Modules whose 2D weight is row-parallel (contracting dim sharded by
# 'model'): attention/mixer output projections and MLP down projections.
_ROW_PARALLEL = ("o", "down", "out")

# MoE expert banks: (n_experts, d_in, d_out) with the expert dim replicated.
_MOE_COL = ("w_gate", "w_up")
_MOE_ROW = ("w_down",)


def set_activation_batch_axes(axes: Sequence[str]) -> None:
    """Declare the mesh axes that carry the batch dim of activations."""
    global _ACT_BATCH_AXES
    _ACT_BATCH_AXES = tuple(axes)


def _axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _maybe(axis, dim: int, mesh):
    """`axis` if `dim` divides its mesh size, else None (replicate).

    `axis` may be a single name or a tuple of names (product of sizes);
    names absent from the mesh always replicate.
    """
    if axis is None:
        return None
    sizes = _axis_sizes(mesh)
    names = axis if isinstance(axis, tuple) else (axis,)
    total = 1
    for a in names:
        if a not in sizes:
            return None
        total *= sizes[a]
    return axis if total > 0 and dim % total == 0 else None


def _path_names(path) -> tuple:
    """Key path (DictKey/SequenceKey/GetAttrKey/...) -> tuple of names."""
    names = []
    for p in path:
        if hasattr(p, "key"):
            names.append(str(p.key))
        elif hasattr(p, "name"):
            names.append(str(p.name))
        elif hasattr(p, "idx"):
            names.append(str(p.idx))
        else:
            names.append(str(p))
    return tuple(names)


# --------------------------------------------------------------------------
# Params
# --------------------------------------------------------------------------


def param_spec(path, shape: tuple, mesh) -> P:
    """PartitionSpec for one parameter leaf, keyed by its tree path."""
    names = _path_names(path)
    leaf = names[-1] if names else ""
    parent = names[-2] if len(names) > 1 else ""

    if leaf == "embed" and len(shape) == 2:
        # fully sharded table: vocab x model, features x data (FSDP)
        return P(_maybe("model", shape[0], mesh),
                 _maybe("data", shape[1], mesh))

    if leaf in _MOE_COL + _MOE_ROW and len(shape) >= 3:
        lead = (None,) * (len(shape) - 2)
        din, dout = shape[-2], shape[-1]
        if leaf in _MOE_ROW:
            return P(*lead, _maybe("model", din, mesh),
                     _maybe(_ACT_BATCH_AXES, dout, mesh))
        return P(*lead, _maybe(_ACT_BATCH_AXES, din, mesh),
                 _maybe("model", dout, mesh))

    if len(shape) >= 2:
        lead = (None,) * (len(shape) - 2)
        din, dout = shape[-2], shape[-1]
        # stacked (scan) params shard over the full data-axis tuple; plain
        # 2D weights use the bare 'data' axis
        dax = _ACT_BATCH_AXES if lead else "data"
        row = parent in _ROW_PARALLEL or (parent == "v" and "ffn" in names)
        if row:
            return P(*lead, _maybe("model", din, mesh),
                     _maybe(dax, dout, mesh))
        return P(*lead, _maybe(dax, din, mesh), _maybe("model", dout, mesh))

    return P()   # vectors / scalars replicate


def params_shardings_leaf(path, leaf, mesh) -> NamedSharding:
    return NamedSharding(mesh, param_spec(path, leaf.shape, mesh))


def params_shardings(params, mesh):
    return jax.tree_util.tree_map_with_path(
        lambda p, l: params_shardings_leaf(p, l, mesh), params)


# --------------------------------------------------------------------------
# Batches and activations
# --------------------------------------------------------------------------


def batch_spec(shape: tuple, mesh) -> P:
    """Leading dim over the activation batch axes; everything else replicated."""
    if not shape:
        return P()
    return P(_maybe(_ACT_BATCH_AXES, shape[0], mesh),
             *(None,) * (len(shape) - 1))


def batch_shardings(batch, mesh):
    return jax.tree_util.tree_map(
        lambda l: NamedSharding(mesh, batch_spec(l.shape, mesh)), batch)


def replicated(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def _ctx_mesh() -> Optional[Any]:
    """The mesh installed by the enclosing `with mesh:` block, if any."""
    from jax.interpreters import pxla
    mesh = pxla.thread_resources.env.physical_mesh
    return None if mesh.empty else mesh


def constrain_act(x):
    """Pin an activation's batch-dim sharding inside jit (no-op off-mesh)."""
    mesh = _ctx_mesh()
    if mesh is None:
        return x
    spec = batch_spec(x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_heads(x):
    """Pin a (batch, seq, heads, head_dim) activation: batch over the data
    axes, heads over 'model' (head_dim fallback for narrow GQA)."""
    mesh = _ctx_mesh()
    if mesh is None or x.ndim != 4:
        return x
    b, _, h, dh = x.shape
    ba = _maybe(_ACT_BATCH_AXES, b, mesh)
    if _maybe("model", h, mesh):
        spec = P(ba, None, "model", None)
    elif _maybe("model", dh, mesh):
        spec = P(ba, None, None, "model")
    else:
        spec = P(ba, None, None, None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# --------------------------------------------------------------------------
# Decode-state caches
# --------------------------------------------------------------------------


def cache_spec(path, shape: tuple, mesh) -> P:
    """KV caches (batch, seq, heads, head_dim): batch x 'data', heads x
    'model' with head_dim fallback; other state leaves shard batch only."""
    del path
    if len(shape) == 4:
        b, _, h, dh = shape
        ba = _maybe("data", b, mesh)
        if _maybe("model", h, mesh):
            return P(ba, None, "model", None)
        if _maybe("model", dh, mesh):
            return P(ba, None, None, "model")
        return P(ba, None, None, None)
    if not shape:
        return P()
    return P(_maybe("data", shape[0], mesh), *(None,) * (len(shape) - 1))


def cache_shardings(state, mesh):
    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, cache_spec(p, l.shape, mesh)),
        state)
