"""Distribution utilities: sharding rules for params, batches, and caches."""
