"""Pallas TPU kernel: RWKV6 chunked linear-attention scan.

Schedule (DESIGN.md §4): grid = (B, H, nChunks); the chunk axis is LAST, so
TPU's sequential grid carries the (K, V) state matrix in VMEM scratch across
chunks — the inter-chunk recurrence never touches HBM. Per chunk:

    intra: (C,C) pairwise-decay attention (two MXU matmuls)
    inter: (C,K) @ (K,K) state read
    state: S <- diag(exp(cum_C)) S + k_carry^T @ v   (one MXU matmul)

Tiles: r/k/v/lw chunk tiles are (1, 1, C, K) with C=64, K=head_dim(64) —
(64, 64) MXU plane; the state scratch is (K, K) fp32. Working set ≈
4*C*K + K*K + C*C floats ≈ 100 KB — far under VMEM; larger C would
amortize better and is a recorded §Perf candidate.

Decay math is fp32 throughout; within-chunk cumulative log-decays are
bounded by C * |log w|, so exp() stays in range for the decays RWKV6
produces (w = exp(-exp(w0 + lora)), w0 ≈ -6 at init).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_out_ref,
                state_scr, *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    r = r_ref[0, 0].astype(jnp.float32)        # (C, K)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)           # (K,)

    cum = jnp.cumsum(lw, axis=0)               # (C, K) inclusive
    state = state_scr[...]                     # (K, K)

    # inter-chunk: q_t reads the chunk-entry state with decay prod_{s<t} w
    q_in = r * jnp.exp(cum - lw)
    out_inter = jax.lax.dot(q_in, state)       # (C, K)

    # intra-chunk pairwise (strict lower triangle)
    kd = k * jnp.exp(-cum)
    att = jax.lax.dot_general(q_in, kd, (((1,), (1,)), ((), ())))  # (C, C)
    t_pos = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_pos = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    att = jnp.where(s_pos < t_pos, att, 0.0)
    out_intra = jax.lax.dot(att, v)

    # current-token bonus
    bonus = jnp.sum(r * u[None, :] * k, axis=1, keepdims=True)
    out_bonus = bonus * v

    o_ref[0, 0] = (out_inter + out_intra + out_bonus).astype(o_ref.dtype)

    # state carry
    total = cum[-1]                            # (K,)
    k_carry = k * jnp.exp(total[None, :] - cum)
    new_state = (jnp.exp(total)[:, None] * state
                 + jax.lax.dot_general(k_carry, v, (((0,), (0,)), ((), ()))))
    state_scr[...] = new_state

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        s_out_ref[0, 0] = new_state


def wkv6_bhsk(r, k, v, log_w, u, *, chunk: int, interpret: bool):
    """r,k,v,log_w: (B,H,S,K) fp32; u: (H,K). Returns (out, final_state)."""
    b, h, s, dk = r.shape
    assert s % chunk == 0, f"S={s} must be a multiple of chunk={chunk}"
    n_chunks = s // chunk
    kernel = functools.partial(_wkv_kernel, chunk=chunk, n_chunks=n_chunks)
    seq_spec = pl.BlockSpec((1, 1, chunk, dk),
                            lambda b_, h_, c: (b_, h_, c, 0))
    out, state = pl.pallas_call(
        kernel,
        grid=(b, h, n_chunks),
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec,
                  pl.BlockSpec((1, dk), lambda b_, h_, c: (h_, 0))],
        out_specs=[seq_spec,
                   pl.BlockSpec((1, 1, dk, dk),
                                lambda b_, h_, c: (b_, h_, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((b, h, s, dk), jnp.float32),
                   jax.ShapeDtypeStruct((b, h, dk, dk), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((dk, dk), jnp.float32)],
        interpret=interpret,
    )(r, k, v, log_w, u)
    return out, state
