"""jit'd wrapper for the WKV6 kernel: (B,S,H,K) public layout, chunk padding,
state0 injection, interpret fallback off-TPU."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.wkv6 import kernel

CHUNK = 64


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("chunk",))
def wkv6(r, k, v, log_w, u, *, state0=None, chunk: int = CHUNK):
    """r,k,v,log_w: (B,S,H,K); u: (H,K). Returns (out (B,S,H,K), state)."""
    b, s, h, dk = r.shape
    pad = (-s) % chunk
    def prep(x):
        x = jnp.moveaxis(x, 2, 1)              # (B,H,S,K)
        if pad:
            x = jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
        return x.astype(jnp.float32)

    rp, kp, vp = prep(r), prep(k), prep(v)
    # padded steps must be identity on the state: log_w = 0 (w=1), k = 0
    lwp = jnp.moveaxis(log_w, 2, 1).astype(jnp.float32)
    if pad:
        lwp = jnp.pad(lwp, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kp = kp.at[:, :, s:].set(0.0)
    out, state = kernel.wkv6_bhsk(rp, kp, vp, lwp, u.astype(jnp.float32),
                                  chunk=chunk, interpret=_interpret())
    if state0 is not None:
        # fold a nonzero entry state in analytically: the kernel ran with
        # S_0 = 0, and the recurrence is linear in the state, so add the
        # homogeneous part: out_t += (r_t * prod-decay) @ S0.
        lw_cum = jnp.cumsum(lwp, axis=2)
        q_in = rp * jnp.exp(lw_cum - lwp)
        out = out + jnp.einsum("bhsk,bhkv->bhsv", q_in, state0)
        state = state + jnp.exp(lw_cum[:, :, -1])[..., None] * state0
    out = jnp.moveaxis(out, 1, 2)[:, :s]
    return out, state
