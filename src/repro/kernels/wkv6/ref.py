"""Oracle for the WKV6 kernel = the model's own chunked jnp implementation
(repro.models.rwkv.wkv_chunked), plus a step-by-step recurrence used to
cross-check both."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.rwkv import wkv_chunked, wkv_recurrent_step


def wkv6(r, k, v, log_w, u, *, state0=None, chunk: int = 64):
    """r,k,v,log_w: (B,S,H,K); u: (H,K)."""
    return wkv_chunked(r, k, v, log_w, u, chunk=chunk, state0=state0)


def wkv6_stepwise(r, k, v, log_w, u, *, state0=None):
    """Token-by-token recurrence (ground truth for both implementations)."""
    b, s, h, dk = r.shape
    state = (jnp.zeros((b, h, dk, dk), jnp.float32)
             if state0 is None else state0)

    def step(state, inputs):
        r_, k_, v_, lw_ = inputs
        out, state = wkv_recurrent_step(r_, k_, v_, lw_, u, state)
        return state, out

    inputs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, log_w))
    state, out = jax.lax.scan(step, state, inputs)
    return jnp.moveaxis(out, 0, 1), state
