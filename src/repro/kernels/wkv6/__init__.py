from repro.kernels.wkv6 import ops, ref

__all__ = ["ops", "ref"]
