"""Pallas TPU kernels for the framework's compute hot spots.

  quant       stochastic uniform quantization (Eq. 3.1) — the compression
              operator on every CSGD/EC-SGD iteration's critical path
  flash_attn  blockwise-softmax GQA attention (prefill/train hot spot)
  wkv6        RWKV6 chunked linear-attention scan

Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper; interpret=True on CPU), ref.py (pure-jnp oracle). Tests sweep
shapes/dtypes and assert_allclose kernel vs oracle.
"""
