"""Pallas TPU kernel: blockwise-softmax (flash) GQA attention.

TPU adaptation notes (DESIGN.md §4):
  * grid = (B, Hq, nQ, nK) — the LAST axis is the reduction axis: TPU grids
    execute sequentially, so the running max/denominator/accumulator live in
    VMEM scratch carried across the k-block steps (revisiting pattern);
  * BlockSpecs: q tile (1, 1, BQ, D), k/v tiles (1, 1, BK, D); the kv-head
    index map folds GQA (kv_head = q_head // group) so no head replication
    is materialized in HBM;
  * BQ = BK = 128 keeps tiles MXU-aligned (128 lanes) and the working set
    (q + k + v + acc + stats ~ 5 * 128 * D * 4B) far under VMEM;
  * causal + sliding-window masking is computed from program ids; fully
    masked k-blocks still execute (no early-exit on TPU grids) — skipping
    them via a grid-shrink is a recorded §Perf candidate;
  * online softmax keeps fp32 stats; output cast back to q.dtype.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0**30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int, causal: bool,
                  window: int, softcap: float, n_k: int, s_valid: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)           # (BQ, D)
    k = k_ref[0, 0].astype(jnp.float32)           # (BK, D)
    v = v_ref[0, 0].astype(jnp.float32)           # (BK, D)

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ()))) * scale    # (BQ, BK)
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = k_pos < s_valid                      # padded keys never attended
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_scr[...]                            # (BQ, 1)
    m_cur = jnp.max(logits, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(logits - m_new)                    # (BQ, BK)
    alpha = jnp.exp(m_prev - m_new)                # (BQ, 1)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(p, v)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool, window: int,
                         softcap: float, block_q: int, block_k: int,
                         s_valid: int, interpret: bool) -> jnp.ndarray:
    """q: (B,Hq,S,D); k,v: (B,Hkv,S,D) — layout chosen in ops.py.

    s_valid: real (unpadded) sequence length; keys beyond it are masked.
    """
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    n_q = pl.cdiv(s, block_q)
    n_k = pl.cdiv(s, block_k)
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, window=window, softcap=softcap, n_k=n_k,
        s_valid=s_valid)

    return pl.pallas_call(
        kernel,
        grid=(b, hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, h, qi, ki: (b_, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, qi, ki, group=group:
                         (b_, h // group, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, qi, ki, group=group:
                         (b_, h // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h, qi, ki: (b_, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom l
            pltpu.VMEM((block_q, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
