"""Pallas TPU kernel: blockwise-softmax (flash) GQA attention, skip-grid.

TPU adaptation notes:
  * the grid is (B, n_pairs) where n_pairs enumerates only the
    (q-block, k-block) tiles that are NOT fully masked.  Causal, sliding
    window and the valid-length tail are all *static* predicates, so the
    surviving pairs are computed at trace time (`skip_grid`) and shipped to
    the kernel as a scalar-prefetched int32 table; the BlockSpec index maps
    read the table (PrefetchScalarGridSpec) to place each step.  Fully
    masked k-blocks therefore never execute — they are absent from the
    grid, not predicated out (the former §Perf candidate, now landed);
  * pairs are ordered q-block-major, so the output block's revisits are
    consecutive (a TPU requirement: an output block is flushed when the
    block index changes) and the online-softmax scratch carries across the
    k-steps of one q-block exactly as in the classic (…, nQ, nK) grid;
  * the whole head axis is folded into the block (tiles are (1, Hq, BQ, D)
    / (1, Hkv, BK, D)): with head-folding the per-step tile does GQA as a
    single batched matmul over the Hkv groups, cutting grid steps by Hq×
    — the dominant cost both for interpret mode (per-step dispatch) and
    for small-batch TPU launches.  VMEM at the retuned BQ=256, BK=128,
    D=128, Hq=8: q 1.0 MiB + k/v 0.125 MiB each + acc 1.0 MiB + logits
    0.5 MiB ≈ 2.8 MiB, comfortably under the ~16 MiB budget;
  * retuned tiles BQ=256, BK=128 (was 128x128): the taller q-tile
    amortizes per-step overhead across the folded heads, while keeping
    the k-tile at 128 holds the causal over-execution ratio at 1.25×
    useful area (a square 256 tile has the same executed area but
    measured ~2× slower per element on the seq-1K bench shape; 512x128
    ties, 64-wide k-tiles lose to step overhead — swept {64..1024}_q ×
    {64..256}_k);
  * scale is fused into the q-tile load (one VPU multiply on the small q
    tile) and softcap into the logits pass, so the online-softmax inner
    loop needs no separate scaling sweep;
  * online softmax keeps fp32 stats; output cast back to q.dtype.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0**30


def skip_grid(s_pad: int, block_q: int, block_k: int, *, causal: bool,
              window: int, s_valid: int) -> np.ndarray:
    """Static (4, n_pairs) table of surviving (q-block, k-block) tiles.

    Row 0: q-block index, row 1: k-block index, row 2: 1 iff the pair is
    the first k-step of its q-block (scratch init), row 3: 1 iff it is the
    last (finalize + output flush).  Pairs are q-block-major so output
    revisits are consecutive.  A pair is dropped iff every (q_pos, k_pos)
    in its tile is masked:
      * tail:   k_pos >= s_valid for the whole tile,
      * causal: min k_pos > max q_pos,
      * window: max k_pos <= min q_pos - window.
    """
    n_q = -(-s_pad // block_q)
    n_k = -(-s_pad // block_k)
    qi_l, ki_l, first_l, last_l = [], [], [], []
    for qi in range(n_q):
        q_lo, q_hi = qi * block_q, qi * block_q + block_q - 1
        kis = []
        for ki in range(n_k):
            k_lo, k_hi = ki * block_k, ki * block_k + block_k - 1
            if k_lo >= s_valid:
                continue
            if causal and k_lo > q_hi:
                continue
            if window > 0 and k_hi <= q_lo - window:
                continue
            kis.append(ki)
        for j, ki in enumerate(kis):
            qi_l.append(qi)
            ki_l.append(ki)
            first_l.append(1 if j == 0 else 0)
            last_l.append(1 if j == len(kis) - 1 else 0)
    return np.asarray([qi_l, ki_l, first_l, last_l], dtype=np.int32)


def _flash_kernel(maps_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
                  acc_scr, *, scale: float, block_q: int, block_k: int,
                  causal: bool, window: int, softcap: float, s_valid: int,
                  hq: int, hkv: int):
    t = pl.program_id(1)
    qi = maps_ref[0, t]
    ki = maps_ref[1, t]
    group = hq // hkv
    gbq = group * block_q

    @pl.when(maps_ref[2, t] == 1)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # head-folded tiles; scale fused into the q load (one small multiply)
    q = (q_ref[0].astype(jnp.float32) * scale).reshape(hkv, gbq, -1)
    k = k_ref[0].astype(jnp.float32)               # (Hkv, BK, D)
    v = v_ref[0].astype(jnp.float32)               # (Hkv, BK, D)

    logits = jax.lax.dot_general(                  # (Hkv, gBQ, BK)
        q, k, (((2,), (2,)), ((0,), (0,))))
    if softcap > 0:
        logits = softcap * jnp.tanh(logits * (1.0 / softcap))

    row = jax.lax.broadcasted_iota(jnp.int32, (gbq, block_k), 0)
    q_pos = qi * block_q + jax.lax.rem(row, block_q)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (gbq, block_k), 1)
    mask = k_pos < s_valid                      # padded keys never attended
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask[None], logits, NEG_INF)

    m_prev = m_scr[...]                            # (Hkv, gBQ, 1)
    m_cur = jnp.max(logits, axis=2, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # the where guards the ALL-masked tile (m_new == NEG_INF -> exp(0) = 1
    # for every masked lane); such tiles only execute with skip=False —
    # elsewhere exp(NEG_INF - finite) is exactly 0, so this is a no-op
    p = jnp.where(mask[None], jnp.exp(logits - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)                # (Hkv, gBQ, 1)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=2, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((2,), (1,)), ((0,), (0,))))        # (Hkv, gBQ, D)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(maps_ref[3, t] == 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).reshape(
            hq, block_q, -1).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool, window: int,
                         softcap: float, block_q: int, block_k: int,
                         s_valid: int, skip: bool = True,
                         interpret: bool) -> jnp.ndarray:
    """q: (B,Hq,S,D); k,v: (B,Hkv,S,D) — layout chosen in ops.py.

    s_valid: real (unpadded) sequence length; keys beyond it are masked.
    skip=False builds the FULL pair table (predicates disabled at grid
    construction, still applied in-kernel) — the non-skipping baseline.
    """
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    scale = 1.0 / math.sqrt(d)

    maps = (skip_grid(s, block_q, block_k, causal=causal, window=window,
                      s_valid=s_valid) if skip else
            skip_grid(s, block_q, block_k, causal=False, window=0,
                      s_valid=s))
    n_pairs = maps.shape[1]

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, window=window, softcap=softcap, s_valid=s_valid,
        hq=hq, hkv=hkv)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, n_pairs),
        in_specs=[
            pl.BlockSpec((1, hq, block_q, d),
                         lambda b_, t, maps_: (b_, 0, maps_[0, t], 0)),
            pl.BlockSpec((1, hkv, block_k, d),
                         lambda b_, t, maps_: (b_, 0, maps_[1, t], 0)),
            pl.BlockSpec((1, hkv, block_k, d),
                         lambda b_, t, maps_: (b_, 0, maps_[1, t], 0)),
        ],
        out_specs=pl.BlockSpec((1, hq, block_q, d),
                               lambda b_, t, maps_: (b_, 0, maps_[0, t], 0)),
        scratch_shapes=[
            pltpu.VMEM((hkv, group * block_q, 1), jnp.float32),  # max m
            pltpu.VMEM((hkv, group * block_q, 1), jnp.float32),  # denom l
            pltpu.VMEM((hkv, group * block_q, d), jnp.float32),  # acc
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hq, s, d), q.dtype),
        interpret=interpret,
    )(jnp.asarray(maps), q, k, v)
