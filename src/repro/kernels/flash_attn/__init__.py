from repro.kernels.flash_attn import ops, ref

__all__ = ["ops", "ref"]
