"""Pure-jnp oracle for the flash-attention kernel: full-materialization
grouped-query SDPA with causal / sliding-window masking and logit softcap.
Delegates to repro.models.attention.sdpa_reference (one source of truth)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import make_mask, sdpa_reference


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              softcap: float = 0.0) -> jnp.ndarray:
    """q: (B,S,Hq,D); k,v: (B,S,Hkv,D)."""
    s = q.shape[1]
    mask = make_mask(s, s, causal=causal, window=window)[None]
    return sdpa_reference(q, k, v, mask, softcap=softcap)
