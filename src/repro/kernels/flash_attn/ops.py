"""jit'd wrapper for the flash-attention kernel.

Public layout matches the model code: q (B, S, Hq, D); k/v (B, S, Hkv, D).
The wrapper transposes to (B, H, S, D) (head-major tiles so the kernel's
last two dims are the MXU-aligned (S, D) plane), pads S to a block multiple,
and picks block sizes; off-TPU it runs interpret=True.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.obs import flight as obs_flight
from repro.kernels.flash_attn import kernel

# Retuned for the skip-grid kernel (see kernel.py docstring): an
# asymmetric 256x128 tile measured fastest on the seq-1K bench shape.
DEFAULT_BLOCK_Q = 256
DEFAULT_BLOCK_K = 128
DEFAULT_BLOCK = DEFAULT_BLOCK_Q  # back-compat alias


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                   "block_q", "block_k", "skip"))
@obs_flight.kernel_annotation("flash_attn.forward")
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    skip: bool = True) -> jnp.ndarray:
    """skip=False keeps the full (q-block, k-block) grid (masking still
    applied in-kernel) — the non-skipping baseline the skip-grid kernel
    is bit-matched against in tests."""
    b, s, hq, d = q.shape
    block_q = min(block_q, max(8, 1 << (s - 1).bit_length()))
    block_k = min(block_k, block_q)
    pad = (-s) % max(block_q, block_k)
    qt = jnp.moveaxis(q, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if pad:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))
    out = kernel.flash_attention_bhsd(
        qt, kt, vt, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, s_valid=s, skip=skip,
        interpret=_interpret())
    out = jnp.moveaxis(out, 1, 2)
    return out[:, :s] if pad else out
