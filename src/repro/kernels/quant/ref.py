"""Pure-jnp oracle for the stochastic uniform quantization kernels.

Matches the Pallas kernels bit-for-bit when given the same uniform draws
(and exactly, by construction, in interpret mode); split into encode /
pack / unpack / decode so the packed wire format is visible to tests and
to the roofline byte accounting.

Wire format: see kernels/quant/kernel.py — b-bit codes are packed
8 // b per uint8 across `pack` contiguous segments of the padded flat
array: payload[r, c] = sum_k codes[k, r, c] << (k * b).
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def quant_params(x: jnp.ndarray, bits: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Global (lo, scale) for b-bit uniform knobs over [min(x), max(x)].

    min and max come out of ONE variadic reduction pass (see
    minmax_bucketed) instead of a min pass plus a max pass; min/max are
    exact, so the result is bit-identical either way."""
    lo, hi = minmax_bucketed(x.astype(jnp.float32).reshape(1, -1))
    lo, hi = lo[0], hi[0]
    levels = (1 << bits) - 1
    scale = jnp.where(hi > lo, (hi - lo) / levels, 1.0)
    return lo, scale


def encode(x: jnp.ndarray, u: jnp.ndarray, lo, scale, *, bits: int) -> jnp.ndarray:
    """Stochastic round to b-bit codes (stored in uint8 for bits <= 8)."""
    levels = (1 << bits) - 1
    norm = (x.astype(jnp.float32) - lo) / scale
    floor = jnp.floor(norm)
    frac = norm - floor
    q = floor + (u < frac).astype(jnp.float32)
    return jnp.clip(q, 0.0, levels).astype(jnp.uint8 if bits <= 8 else jnp.int32)


def decode(codes: jnp.ndarray, lo, scale) -> jnp.ndarray:
    return codes.astype(jnp.float32) * scale + lo


def pack_codes(codes3: jnp.ndarray, *, bits: int) -> jnp.ndarray:
    """(pack, R, C) codes -> (R, C) uint8 payload (sub-byte bit-packing)."""
    pack = codes3.shape[0]
    assert pack == 8 // bits, (codes3.shape, bits)
    acc = jnp.zeros(codes3.shape[1:], jnp.int32)
    for k in range(pack):
        acc = acc | (codes3[k].astype(jnp.int32) << (k * bits))
    return acc.astype(jnp.uint8)


def unpack_codes(payload: jnp.ndarray, *, bits: int) -> jnp.ndarray:
    """(R, C) uint8 payload -> (pack, R, C) codes.

    Written as a broadcasted shift (not a stack/concatenate): XLA CPU
    miscompiles fused concatenate -> reshape -> odd-length slice chains
    (observed on jax 0.4.37: garbage at the first post-concat element),
    and downstream callers slice the flat view back to the input size.
    """
    pack = 8 // bits
    mask = (1 << bits) - 1
    shifts = (jnp.arange(pack, dtype=jnp.int32) * bits)[:, None, None]
    return ((payload.astype(jnp.int32)[None] >> shifts) & mask).astype(
        jnp.uint8)


def encode_packed(x3: jnp.ndarray, u3: jnp.ndarray, lo, scale, *,
                  bits: int) -> jnp.ndarray:
    """(pack, R, C) segments -> (R, C) uint8 payload."""
    return pack_codes(encode(x3, u3, lo, scale, bits=bits), bits=bits)


def decode_packed(payload: jnp.ndarray, lo, scale, *, bits: int) -> jnp.ndarray:
    """(R, C) uint8 payload -> (pack, R, C) dequantized fp32 segments."""
    return decode(unpack_codes(payload, bits=bits), lo, scale)


def qdq(x: jnp.ndarray, u: jnp.ndarray, lo, scale, *, bits: int) -> jnp.ndarray:
    """Direct quantize-dequantize: bit-identical to
    decode(encode(x, u, lo, scale)) — the codes are exact small integers
    in fp32, so the uint8 cast round trip is a lossless detour — but one
    fused elementwise chain for XLA instead of an encode pass, a uint8
    store/load, and a decode pass."""
    levels = (1 << bits) - 1
    norm = (x.astype(jnp.float32) - lo) / scale
    floor = jnp.floor(norm)
    q = floor + (u < (norm - floor)).astype(jnp.float32)
    return jnp.clip(q, 0.0, levels) * scale + lo


def quantize_dequantize(x: jnp.ndarray, u: jnp.ndarray, *, bits: int) -> jnp.ndarray:
    lo, scale = quant_params(x, bits)
    return qdq(x, u, lo, scale, bits=bits).astype(x.dtype)


# ---------------------------------------------------------------------------
# Bucketed (fused flat-buffer) variants: one (lo, scale) row per bucket.
#
# The flat gradient buffer is viewed as (n_buckets, pack, Rb, C): bucket b
# owns the contiguous element range [b*cap, (b+1)*cap) and is segment-packed
# *within itself* exactly like the per-leaf wire format above, so one payload
# row never mixes elements from two buckets. lo/scale arrive as (n_buckets,)
# vectors; broadcasting against the leading bucket axis reuses the same
# encode/decode math elementwise.
# ---------------------------------------------------------------------------


def _bcast(v: jnp.ndarray) -> jnp.ndarray:
    """(B,) per-bucket param -> broadcastable against (B, pack, Rb, C)."""
    return v[:, None, None, None]


def minmax_bucketed(x2: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-bucket (lo, hi) of a (B, cap) view in ONE read of the buffer.

    A variadic ``lax.reduce`` computes min and max in the same reduction
    pass — one XLA Reduce over the data instead of the separate min pass
    + max pass two ``jnp.min``/``jnp.max`` calls lower to. min/max are
    exact ops, so the result is bit-identical to the two-pass version
    regardless of reduction order.
    """
    x2 = x2.astype(jnp.float32)
    return lax.reduce(
        (x2, x2),
        (jnp.float32(jnp.inf), jnp.float32(-jnp.inf)),
        lambda a, b: (jnp.minimum(a[0], b[0]), jnp.maximum(a[1], b[1])),
        (1,))


def encode_packed_bucketed(x4: jnp.ndarray, u4: jnp.ndarray, lo, scale, *,
                           bits: int) -> jnp.ndarray:
    """(B, pack, Rb, C) segments + per-bucket (B,) params -> (B, Rb, C)."""
    codes = encode(x4, u4, _bcast(lo), _bcast(scale), bits=bits)
    pack = codes.shape[1]
    assert pack == 8 // bits, (codes.shape, bits)
    acc = jnp.zeros((codes.shape[0],) + codes.shape[2:], jnp.int32)
    for k in range(pack):
        acc = acc | (codes[:, k].astype(jnp.int32) << (k * bits))
    return acc.astype(jnp.uint8)


def decode_packed_bucketed(payload: jnp.ndarray, lo, scale, *,
                           bits: int) -> jnp.ndarray:
    """(B, Rb, C) payload + per-bucket (B,) params -> (B, pack, Rb, C)."""
    pack = 8 // bits
    mask = (1 << bits) - 1
    shifts = (jnp.arange(pack, dtype=jnp.int32) * bits)[None, :, None, None]
    codes = ((payload.astype(jnp.int32)[:, None] >> shifts) & mask)
    return codes.astype(jnp.float32) * _bcast(scale) + _bcast(lo)


def qdq_bucketed(x4: jnp.ndarray, u4: jnp.ndarray, lo, scale, *,
                 bits: int) -> jnp.ndarray:
    """Fused per-bucket quantize-dequantize on the (B, pack, Rb, C) view."""
    lo4, scale4 = _bcast(lo), _bcast(scale)
    return decode(encode(x4, u4, lo4, scale4, bits=bits), lo4,
                  scale4).astype(x4.dtype)
