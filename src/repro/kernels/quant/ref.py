"""Pure-jnp oracle for the stochastic uniform quantization kernel.

Matches repro.core.compression.randomized_quantize bit-for-bit when given
the same uniform draws; split into encode (codes) / decode so the packed
wire format is visible to tests and to the roofline byte accounting.
"""
from __future__ import annotations

import jax.numpy as jnp


def quant_params(x: jnp.ndarray, bits: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Global (lo, scale) for b-bit uniform knobs over [min(x), max(x)]."""
    x32 = x.astype(jnp.float32)
    lo = jnp.min(x32)
    hi = jnp.max(x32)
    levels = (1 << bits) - 1
    scale = jnp.where(hi > lo, (hi - lo) / levels, 1.0)
    return lo, scale


def encode(x: jnp.ndarray, u: jnp.ndarray, lo, scale, *, bits: int) -> jnp.ndarray:
    """Stochastic round to b-bit codes (stored in int8 for bits <= 8)."""
    levels = (1 << bits) - 1
    norm = (x.astype(jnp.float32) - lo) / scale
    floor = jnp.floor(norm)
    frac = norm - floor
    q = floor + (u < frac).astype(jnp.float32)
    return jnp.clip(q, 0.0, levels).astype(jnp.uint8 if bits <= 8 else jnp.int32)


def decode(codes: jnp.ndarray, lo, scale) -> jnp.ndarray:
    return codes.astype(jnp.float32) * scale + lo


def quantize_dequantize(x: jnp.ndarray, u: jnp.ndarray, *, bits: int) -> jnp.ndarray:
    lo, scale = quant_params(x, bits)
    return decode(encode(x, u, lo, scale, bits=bits), lo, scale).astype(x.dtype)
