"""Pallas TPU kernels: fused stochastic quantize-dequantize (Eq. 3.1) and
the packed wire-format encode/decode pair.

Layout/tiling rationale (TPU v5e):
  * the array is viewed as (R, C) with C a multiple of 128 (lane width);
    the wrapper pads/reshapes arbitrary tensors into this layout;
  * grid over row-tiles; BLOCK_R is chosen in ops.py per kernel from the
    actual resident operand dtypes so VMEM stays under budget;
  * (lo, scale) arrive as a (1, 2) operand (global-scale quantization —
    min/max is a cheap jnp reduction outside the kernel);
  * pure VPU elementwise work, no MXU; stochastic rounding compares the
    uniform draw against the fractional part.

Wire format (sub-byte packing): for b-bit codes, pack = 8 // b codes share
one uint8. The wrapper views the padded flat input as (pack, R, C) — pack
contiguous *segments* — and the encode kernel folds the segments'
codes into one (R, C) uint8 payload:

    payload[r, c] = sum_k codes[k, r, c] << (k * b)

Segment packing (rather than packing adjacent lanes) keeps every kernel
access a full aligned (BLOCK_R, C) tile — no cross-lane shuffles — so the
same kernel body serves b in {8, 4, 2} (pack in {1, 2, 4}). The decode
kernel runs a (pack, n_row_tiles) grid, extracting field k = program_id(0)
of each payload tile. The payload IS the wire array: its byte count is
what communicators ship and what the roofline/eventsim consume.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _quantize(x, u, lo, scale, levels: int):
    """Shared stochastic-rounding body: fp32 in, fp32 integer codes out."""
    norm = (x.astype(jnp.float32) - lo) / scale
    floor = jnp.floor(norm)
    frac = norm - floor
    q = floor + (u < frac).astype(jnp.float32)
    return jnp.clip(q, 0.0, float(levels))


def _qdq_kernel(params_ref, x_ref, u_ref, o_ref, *, levels: int):
    lo = params_ref[0, 0]
    scale = params_ref[0, 1]
    q = _quantize(x_ref[...], u_ref[...], lo, scale, levels)
    o_ref[...] = (q * scale + lo).astype(o_ref.dtype)


def _encode_packed_kernel(params_ref, x_ref, u_ref, o_ref, *, bits: int):
    """x_ref, u_ref: (pack, BLOCK_R, C) — all segments of one row tile."""
    pack = 8 // bits
    levels = (1 << bits) - 1
    lo = params_ref[0, 0]
    scale = params_ref[0, 1]
    acc = None
    for k in range(pack):
        q = _quantize(x_ref[k], u_ref[k], lo, scale, levels)
        q = q.astype(jnp.int32) << (k * bits)
        acc = q if acc is None else acc | q
    o_ref[...] = acc.astype(jnp.uint8)


def _decode_packed_kernel(params_ref, c_ref, o_ref, *, bits: int):
    k = pl.program_id(0)
    lo = params_ref[0, 0]
    scale = params_ref[0, 1]
    mask = (1 << bits) - 1
    field = (c_ref[...].astype(jnp.int32) >> (k * bits)) & mask
    o_ref[0] = (field.astype(jnp.float32) * scale + lo).astype(o_ref.dtype)


def qdq(x: jnp.ndarray, u: jnp.ndarray, params: jnp.ndarray, *, bits: int,
        block_r: int, interpret: bool) -> jnp.ndarray:
    """x, u: (R, C); params: (1, 2) [lo, scale]. Returns dequantized x."""
    r, c = x.shape
    kernel = functools.partial(_qdq_kernel, levels=(1 << bits) - 1)
    return pl.pallas_call(
        kernel,
        grid=(pl.cdiv(r, block_r),),
        in_specs=[
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
            pl.BlockSpec((block_r, c), lambda i: (i, 0)),
            pl.BlockSpec((block_r, c), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c), x.dtype),
        interpret=interpret,
    )(params, x, u)


def encode_packed(x3: jnp.ndarray, u3: jnp.ndarray, params: jnp.ndarray, *,
                  bits: int, block_r: int, interpret: bool) -> jnp.ndarray:
    """x3, u3: (pack, R, C) segments; returns the (R, C) uint8 payload."""
    pack, r, c = x3.shape
    assert pack == 8 // bits, (pack, bits)
    kernel = functools.partial(_encode_packed_kernel, bits=bits)
    # one (pack, BLOCK_R, C) block per grid step: every segment's tile of
    # the same rows is resident together (pack * BLOCK_R * C fp32 each for
    # x and u — ops.py budgets BLOCK_R accordingly)
    seg_spec = pl.BlockSpec((pack, block_r, c), lambda i: (0, i, 0))
    return pl.pallas_call(
        kernel,
        grid=(pl.cdiv(r, block_r),),
        in_specs=[pl.BlockSpec((1, 2), lambda i: (0, 0)), seg_spec,
                  seg_spec],
        out_specs=pl.BlockSpec((block_r, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.uint8),
        interpret=interpret,
    )(params, x3, u3)


def decode_packed(payload: jnp.ndarray, params: jnp.ndarray, *, bits: int,
                  out_dtype, block_r: int, interpret: bool) -> jnp.ndarray:
    """payload: (R, C) uint8 -> (pack, R, C) dequantized segments."""
    r, c = payload.shape
    pack = 8 // bits
    kernel = functools.partial(_decode_packed_kernel, bits=bits)
    return pl.pallas_call(
        kernel,
        grid=(pack, pl.cdiv(r, block_r)),
        in_specs=[
            pl.BlockSpec((1, 2), lambda k, i: (0, 0)),
            pl.BlockSpec((block_r, c), lambda k, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_r, c), lambda k, i: (k, i, 0)),
        out_shape=jax.ShapeDtypeStruct((pack, r, c), out_dtype),
        interpret=interpret,
    )(params, payload)


# ---------------------------------------------------------------------------
# Bucketed (fused flat-buffer) kernels. The whole gradient pytree arrives as
# ONE (n_buckets, pack, Rb, C) buffer; each bucket has its own (lo, scale)
# row in an (n_buckets, 2) params array. The grid gains a leading bucket
# dimension whose index selects the params row, so every block still reads a
# full aligned tile and the kernel bodies stay pure-VPU elementwise — the
# same shapes-in/shapes-out contract as the per-leaf kernels, just with
# per-bucket scales. Bit-identical to ref.*_bucketed for the same uniforms.
# ---------------------------------------------------------------------------


def _qdq_bucketed_kernel(params_ref, x_ref, u_ref, o_ref, *, levels: int):
    """x_ref, u_ref, o_ref: (1, pack, BLOCK_R, C); params_ref is the FULL
    (n_buckets, 2) params array, hoisted into VMEM once for the whole grid
    (constant index map — no per-step refetch of the (lo, scale) row; the
    kernel picks its bucket's row by program id)."""
    bi = pl.program_id(0)
    lo = params_ref[bi, 0]
    scale = params_ref[bi, 1]
    q = _quantize(x_ref[...], u_ref[...], lo, scale, levels)
    o_ref[...] = (q * scale + lo).astype(o_ref.dtype)


def _encode_packed_bucketed_kernel(params_ref, x_ref, u_ref, o_ref, *,
                                   bits: int):
    """x_ref, u_ref: (1, pack, BLOCK_R, C) — one bucket's row tile, all
    segments; o_ref: (1, BLOCK_R, C) packed payload tile; params_ref: the
    full hoisted (n_buckets, 2) array (see _qdq_bucketed_kernel)."""
    pack = 8 // bits
    levels = (1 << bits) - 1
    bi = pl.program_id(0)
    lo = params_ref[bi, 0]
    scale = params_ref[bi, 1]
    acc = None
    for k in range(pack):
        q = _quantize(x_ref[0, k], u_ref[0, k], lo, scale, levels)
        q = q.astype(jnp.int32) << (k * bits)
        acc = q if acc is None else acc | q
    o_ref[0] = acc.astype(jnp.uint8)


def _decode_packed_bucketed_kernel(params_ref, c_ref, o_ref, *, bits: int):
    k = pl.program_id(0)
    bi = pl.program_id(1)
    lo = params_ref[bi, 0]
    scale = params_ref[bi, 1]
    mask = (1 << bits) - 1
    field = (c_ref[0].astype(jnp.int32) >> (k * bits)) & mask
    o_ref[0, 0] = (field.astype(jnp.float32) * scale + lo).astype(o_ref.dtype)


def qdq_bucketed(x4: jnp.ndarray, u4: jnp.ndarray, params: jnp.ndarray, *,
                 bits: int, block_r: int, interpret: bool) -> jnp.ndarray:
    """x4, u4: (B, pack, Rb, C); params: (B, 2). Returns dequantized x4."""
    b, pack, r, c = x4.shape
    kernel = functools.partial(_qdq_bucketed_kernel, levels=(1 << bits) - 1)
    seg = pl.BlockSpec((1, pack, block_r, c), lambda bi, i: (bi, 0, i, 0))
    return pl.pallas_call(
        kernel,
        grid=(b, pl.cdiv(r, block_r)),
        in_specs=[pl.BlockSpec((b, 2), lambda bi, i: (0, 0)), seg, seg],
        out_specs=seg,
        out_shape=jax.ShapeDtypeStruct((b, pack, r, c), x4.dtype),
        interpret=interpret,
    )(params, x4, u4)


def encode_packed_bucketed(x4: jnp.ndarray, u4: jnp.ndarray,
                           params: jnp.ndarray, *, bits: int, block_r: int,
                           interpret: bool) -> jnp.ndarray:
    """x4, u4: (B, pack, Rb, C) bucket segments; returns (B, Rb, C) uint8."""
    b, pack, r, c = x4.shape
    assert pack == 8 // bits, (x4.shape, bits)
    kernel = functools.partial(_encode_packed_bucketed_kernel, bits=bits)
    seg = pl.BlockSpec((1, pack, block_r, c), lambda bi, i: (bi, 0, i, 0))
    return pl.pallas_call(
        kernel,
        grid=(b, pl.cdiv(r, block_r)),
        in_specs=[pl.BlockSpec((b, 2), lambda bi, i: (0, 0)), seg, seg],
        out_specs=pl.BlockSpec((1, block_r, c), lambda bi, i: (bi, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, r, c), jnp.uint8),
        interpret=interpret,
    )(params, x4, u4)


def _minmax_bucketed_kernel(x_ref, o_ref, *, n_rows: int, block_r: int):
    """x_ref: (1, BLOCK_R, C) one bucket's row tile; o_ref: (1, 2) the
    bucket's [lo, hi], accumulated across the (sequential) row-tile grid
    dimension — the output block revisits for every row tile of the same
    bucket, so this is a single-read fused min+max reduction. Rows past
    n_rows (grid padding of the last tile) are masked out of the
    reduction: padded values must never touch the bucket's range."""
    i = pl.program_id(1)
    x = x_ref[0]
    row = i * block_r + jax.lax.broadcasted_iota(jnp.int32, x.shape, 0)
    valid = row < n_rows
    tile_lo = jnp.min(jnp.where(valid, x, jnp.inf))
    tile_hi = jnp.max(jnp.where(valid, x, -jnp.inf))

    @pl.when(i == 0)
    def _init():
        o_ref[0, 0] = tile_lo
        o_ref[0, 1] = tile_hi

    @pl.when(i > 0)
    def _acc():
        o_ref[0, 0] = jnp.minimum(o_ref[0, 0], tile_lo)
        o_ref[0, 1] = jnp.maximum(o_ref[0, 1], tile_hi)


def minmax_bucketed(x3: jnp.ndarray, *, block_r: int,
                    interpret: bool) -> jnp.ndarray:
    """x3: (B, R, C) fp32 bucket view -> (B, 2) per-bucket [lo, hi].

    One read of the buffer (min and max in the same pass), vs the two
    separate reduction passes of jnp.min + jnp.max. min/max accumulate
    exactly, so the result is bit-identical to the jnp reference.
    """
    b, r, c = x3.shape
    kernel = functools.partial(_minmax_bucketed_kernel, n_rows=r,
                               block_r=block_r)
    return pl.pallas_call(
        kernel,
        grid=(b, pl.cdiv(r, block_r)),
        in_specs=[pl.BlockSpec((1, block_r, c), lambda bi, i: (bi, i, 0))],
        out_specs=pl.BlockSpec((1, 2), lambda bi, i: (bi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 2), jnp.float32),
        interpret=interpret,
    )(x3)


# ---------------------------------------------------------------------------
# Fused ring hop: decode + add + re-encode in ONE kernel. A reduce-scatter
# hop's work on a partition used to be three dispatches with two full fp32
# temporaries between them (the decoded message, then the sum); here the
# grid runs TWO phases over each bucket — steps [0, n_tiles) decode the
# payload tile, add the local tile, and min/max-accumulate the new bucket
# range into a (1, 2) VMEM scratch; steps [n_tiles, 2*n_tiles) recompute
# the same decode+add (recompute beats materializing: the fp32 sum never
# exists outside VMEM) and quantize/bit-pack it with the scratch-held
# (lo, scale). The params output is written at the last stats step; the
# payload output's index map parks all stats steps on block 0, so every
# output block's revisits stay consecutive (TPU flush rule) and its final
# visit is the encode step that writes it. Bit-identical to the sequential
# decode -> add -> minmax -> encode chain: same decoded values, same adds,
# exact min/max, same _quantize math, same (externally drawn) uniforms.
# ---------------------------------------------------------------------------


def _decode_add_encode_bucketed_kernel(params_ref, pay_ref, x_ref, u_ref,
                                       out_ref, pout_ref, mm_scr, *,
                                       bits: int, n_tiles: int, n_rows: int,
                                       block_r: int):
    """params_ref: full hoisted (B, 2) [lo, scale] of the INCOMING message;
    pay_ref: (1, BLOCK_R, C) incoming payload tile; x_ref, u_ref: (1, pack,
    BLOCK_R, C) local-addend / uniform tiles; out_ref: (1, BLOCK_R, C)
    re-encoded payload tile; pout_ref: (1, 2) this bucket's new params;
    mm_scr: (1, 2) VMEM carry — [lo, hi] during stats, [lo, scale] after."""
    bi = pl.program_id(0)
    i = pl.program_id(1)
    pack = 8 // bits
    levels = (1 << bits) - 1
    lo_in = params_ref[bi, 0]
    scale_in = params_ref[bi, 1]

    # decode + add — needed by both phases (recompute, never materialized)
    codes = pay_ref[0].astype(jnp.int32)
    summed = [
        ((codes >> (k * bits)) & levels).astype(jnp.float32) * scale_in
        + lo_in + x_ref[0, k].astype(jnp.float32)
        for k in range(pack)
    ]

    @pl.when(i == 0)
    def _init():
        mm_scr[0, 0] = jnp.float32(jnp.inf)
        mm_scr[0, 1] = jnp.float32(-jnp.inf)

    @pl.when(i < n_tiles)
    def _stats():
        # rows past n_rows are grid padding of the last tile — masked out
        row = (jax.lax.rem(i, n_tiles) * block_r
               + jax.lax.broadcasted_iota(jnp.int32, summed[0].shape, 0))
        valid = row < n_rows
        lo_t = jnp.float32(jnp.inf)
        hi_t = jnp.float32(-jnp.inf)
        for s in summed:
            lo_t = jnp.minimum(lo_t, jnp.min(jnp.where(valid, s, jnp.inf)))
            hi_t = jnp.maximum(hi_t, jnp.max(jnp.where(valid, s, -jnp.inf)))
        mm_scr[0, 0] = jnp.minimum(mm_scr[0, 0], lo_t)
        mm_scr[0, 1] = jnp.maximum(mm_scr[0, 1], hi_t)

    @pl.when(i == n_tiles - 1)
    def _finalize_params():
        lo = mm_scr[0, 0]
        hi = mm_scr[0, 1]
        scale = jnp.where(hi > lo, (hi - lo) / levels, 1.0)
        pout_ref[0, 0] = lo
        pout_ref[0, 1] = scale
        mm_scr[0, 1] = scale          # phase 2 reads [lo, scale]

    @pl.when(i >= n_tiles)
    def _encode():
        lo = mm_scr[0, 0]
        scale = mm_scr[0, 1]
        acc = None
        for k in range(pack):
            q = _quantize(summed[k], u_ref[0, k], lo, scale, levels)
            q = q.astype(jnp.int32) << (k * bits)
            acc = q if acc is None else acc | q
        out_ref[0] = acc.astype(jnp.uint8)


def decode_add_encode_bucketed(payload: jnp.ndarray, params: jnp.ndarray,
                               x4: jnp.ndarray, u4: jnp.ndarray, *,
                               bits: int, block_r: int, interpret: bool):
    """Fused per-bucket ring hop. payload: (B, Rb, C) uint8 incoming;
    params: (B, 2) its [lo, scale] rows; x4: (B, pack, Rb, C) fp32 local
    addend segments; u4: matching uniforms for the re-encode. Returns
    (payload_out (B, Rb, C) uint8, params_out (B, 2) fp32)."""
    b, r, c = payload.shape
    _, pack, _, _ = x4.shape
    assert pack == 8 // bits, (x4.shape, bits)
    n_tiles = pl.cdiv(r, block_r)
    kernel = functools.partial(
        _decode_add_encode_bucketed_kernel, bits=bits, n_tiles=n_tiles,
        n_rows=r, block_r=block_r)
    seg = pl.BlockSpec((1, pack, block_r, c),
                       lambda bi, i, nt=n_tiles:
                       (bi, 0, jax.lax.rem(i, nt), 0))
    return pl.pallas_call(
        kernel,
        grid=(b, 2 * n_tiles),
        in_specs=[
            pl.BlockSpec((b, 2), lambda bi, i: (0, 0)),   # hoisted params
            pl.BlockSpec((1, block_r, c),
                         lambda bi, i, nt=n_tiles:
                         (bi, jax.lax.rem(i, nt), 0)),
            seg,
            seg,
        ],
        out_specs=[
            # stats steps park on block 0 so revisits stay consecutive;
            # its last visit (the first encode step) writes it
            pl.BlockSpec((1, block_r, c),
                         lambda bi, i, nt=n_tiles:
                         (bi, jnp.where(i < nt, 0, i - nt), 0)),
            pl.BlockSpec((1, 2), lambda bi, i: (bi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, r, c), jnp.uint8),
            jax.ShapeDtypeStruct((b, 2), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, 2), jnp.float32)],
        interpret=interpret,
    )(params, payload, x4, u4)


def decode_packed_bucketed(payload: jnp.ndarray, params: jnp.ndarray, *,
                           bits: int, out_dtype, block_r: int,
                           interpret: bool) -> jnp.ndarray:
    """payload: (B, Rb, C) uint8 -> (B, pack, Rb, C) dequantized segments."""
    b, r, c = payload.shape
    pack = 8 // bits
    kernel = functools.partial(_decode_packed_bucketed_kernel, bits=bits)
    return pl.pallas_call(
        kernel,
        grid=(pack, b, pl.cdiv(r, block_r)),
        in_specs=[
            pl.BlockSpec((b, 2), lambda k, bi, i: (0, 0)),
            pl.BlockSpec((1, block_r, c), lambda k, bi, i: (bi, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_r, c),
                               lambda k, bi, i: (bi, k, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, pack, r, c), out_dtype),
        interpret=interpret,
    )(params, payload)
