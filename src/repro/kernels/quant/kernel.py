"""Pallas TPU kernel: fused stochastic quantize-dequantize (Eq. 3.1).

Layout/tiling rationale (TPU v5e):
  * the array is viewed as (R, C) with C a multiple of 128 (lane width);
    the wrapper pads/reshapes arbitrary tensors into this layout;
  * grid over row-tiles; each step holds a (BLOCK_R, C) fp32 tile of x and
    of the pre-drawn uniforms in VMEM (x + u + out = 3 tiles; BLOCK_R is
    chosen in ops.py so 3 * BLOCK_R * C * 4B stays well under ~16 MB VMEM);
  * (lo, scale) arrive as a (1, 2) SMEM operand (global-scale quantization —
    min/max is a cheap jnp reduction outside the kernel);
  * pure VPU elementwise work, no MXU; stochastic rounding compares the
    uniform draw against the fractional part.

Encode emits int8 codes (the wire format whose byte count feeds the
roofline collective term); the fused qdq variant returns the dequantized
values directly (what CSGD's update rule consumes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _qdq_kernel(params_ref, x_ref, u_ref, o_ref, *, levels: int):
    lo = params_ref[0, 0]
    scale = params_ref[0, 1]
    x = x_ref[...].astype(jnp.float32)
    u = u_ref[...]
    norm = (x - lo) / scale
    floor = jnp.floor(norm)
    frac = norm - floor
    q = floor + (u < frac).astype(jnp.float32)
    q = jnp.clip(q, 0.0, float(levels))
    o_ref[...] = (q * scale + lo).astype(o_ref.dtype)


def _encode_kernel(params_ref, x_ref, u_ref, o_ref, *, levels: int):
    lo = params_ref[0, 0]
    scale = params_ref[0, 1]
    x = x_ref[...].astype(jnp.float32)
    u = u_ref[...]
    norm = (x - lo) / scale
    floor = jnp.floor(norm)
    frac = norm - floor
    q = floor + (u < frac).astype(jnp.float32)
    o_ref[...] = jnp.clip(q, 0.0, float(levels)).astype(jnp.uint8)


def _decode_kernel(params_ref, c_ref, o_ref):
    lo = params_ref[0, 0]
    scale = params_ref[0, 1]
    o_ref[...] = (c_ref[...].astype(jnp.float32) * scale + lo).astype(
        o_ref.dtype)


def qdq(x: jnp.ndarray, u: jnp.ndarray, params: jnp.ndarray, *, bits: int,
        block_r: int, interpret: bool) -> jnp.ndarray:
    """x, u: (R, C); params: (1, 2) [lo, scale]. Returns dequantized x."""
    r, c = x.shape
    kernel = functools.partial(_qdq_kernel, levels=(1 << bits) - 1)
    return pl.pallas_call(
        kernel,
        grid=(pl.cdiv(r, block_r),),
        in_specs=[
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
            pl.BlockSpec((block_r, c), lambda i: (i, 0)),
            pl.BlockSpec((block_r, c), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c), x.dtype),
        interpret=interpret,
    )(params, x, u)


def encode(x: jnp.ndarray, u: jnp.ndarray, params: jnp.ndarray, *, bits: int,
           block_r: int, interpret: bool) -> jnp.ndarray:
    r, c = x.shape
    kernel = functools.partial(_encode_kernel, levels=(1 << bits) - 1)
    return pl.pallas_call(
        kernel,
        grid=(pl.cdiv(r, block_r),),
        in_specs=[
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
            pl.BlockSpec((block_r, c), lambda i: (i, 0)),
            pl.BlockSpec((block_r, c), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.uint8),
        interpret=interpret,
    )(params, x, u)


def decode(codes: jnp.ndarray, params: jnp.ndarray, *, out_dtype,
           block_r: int, interpret: bool) -> jnp.ndarray:
    r, c = codes.shape
    return pl.pallas_call(
        _decode_kernel,
        grid=(pl.cdiv(r, block_r),),
        in_specs=[
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
            pl.BlockSpec((block_r, c), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c), out_dtype),
        interpret=interpret,
    )(params, codes)
