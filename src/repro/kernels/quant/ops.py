"""jit'd wrappers for the quantization kernels, with backend dispatch.

Handles arbitrary shapes (pad + reshape to C=512 lanes), draws the
uniforms, computes global (lo, scale), picks BLOCK_R per kernel from the
actual resident operand dtypes, and dispatches between the two backends:

  backend='pallas'  the TPU kernels (interpret=True off-TPU)
  backend='jnp'     the pure-jnp reference (ref.py)
  backend='auto'    pallas on TPU, jnp elsewhere

Both backends consume the *same* (lo, scale) and the same uniform draws —
`jax.random.uniform` fills shapes in flat C-order, so the (pack, R, C)
segment view of encode and the (R*pack, C) view of qdq read identical
per-element uniforms. Consequence (asserted in tests/test_codec.py):

    decode(encode(x, key)) == quantize_dequantize(x, key)   bit-for-bit
    pallas(interpret) == jnp                                bit-for-bit

Wire layout: the padded flat array is split into pack = 8 // bits
contiguous segments of R rows x 512 lanes; element i of the flat input
lives at segment i // (R*512), bit-field (i // (R*512)) * bits of
payload byte i % (R*512). Padding: inputs are zero-padded to a multiple
of pack * 512 elements; payload bytes = ceil(n / (pack*512)) * 512.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.quant import kernel, ref

LANES = 512
VMEM_BUDGET = 8 * 1024 * 1024   # conservative half of ~16MB usable

# Fused flat-buffer tier: elements per quantization bucket (4Mi elements =
# 16 MiB fp32 per bucket -> a 100M-param gradient is ~25-31 (lo, scale)
# rows instead of one per pytree leaf). Canonical definition;
# repro.core.compression re-exports it.
DEFAULT_BUCKET_ELEMS = 1 << 22


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _use_pallas(backend: str) -> bool:
    if backend == "auto":
        return jax.default_backend() == "tpu"
    if backend not in ("pallas", "jnp"):
        raise ValueError(f"unknown backend '{backend}'")
    return backend == "pallas"


def _block_r(c: int, bytes_per_out_row_elem: int) -> int:
    """Rows per grid step such that all resident tiles fit VMEM_BUDGET.

    `bytes_per_out_row_elem` sums, over every operand tile resident during
    one grid step, the bytes that correspond to ONE element-column of one
    output row (per-kernel: qdq has 3 fp32 tiles = 12; packed encode has
    pack fp32 x-segments + pack fp32 u-segments + 1 uint8 out = 8*pack+1;
    decode has 1 uint8 in + 1 fp32 out = 5).
    """
    rows = VMEM_BUDGET // (bytes_per_out_row_elem * c)
    rows = max(8, min(1024, rows))
    return int(rows) & ~7 or 8   # multiple of 8 sublanes


def _to_2d(x: jnp.ndarray, multiple: int = 1) -> jnp.ndarray:
    """Flatten + zero-pad to (R, LANES) with R a multiple of `multiple`."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % (LANES * multiple)
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, LANES)


def _params_for(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    lo, scale = ref.quant_params(x, bits)
    return jnp.stack([lo, scale]).reshape(1, 2)


@partial(jax.jit, static_argnames=("bits", "backend"))
def quantize_dequantize(x: jnp.ndarray, key: jax.Array, *, bits: int = 8,
                        backend: str = "auto") -> jnp.ndarray:
    """Fused Q(x) with stochastic rounding; same statistics as
    repro.core.compression.randomized_quantize."""
    params = _params_for(x, bits)
    # pad to the same multiple as the packed wire layout so qdq and
    # decode(encode(.)) consume identical uniform draws (threefry bit
    # generation is not prefix-stable across different totals)
    x2d = _to_2d(x, multiple=8 // bits)
    u = jax.random.uniform(key, x2d.shape, jnp.float32)
    if _use_pallas(backend):
        out = kernel.qdq(x2d, u, params, bits=bits,
                         block_r=_block_r(x2d.shape[1], 3 * 4),
                         interpret=_interpret())
    else:
        lo, scale = params[0, 0], params[0, 1]
        out = ref.decode(ref.encode(x2d, u, lo, scale, bits=bits), lo, scale)
    return out.reshape(-1)[: x.size].reshape(x.shape).astype(x.dtype)


@partial(jax.jit, static_argnames=("bits", "backend"))
def encode(x: jnp.ndarray, key: jax.Array, *, bits: int = 8,
           backend: str = "auto"):
    """Returns (payload uint8 (R, 512), params (1, 2)).

    The payload is the packed wire array: payload.size bytes carry
    8 // bits codes per byte. Wire bytes = payload.nbytes + params.nbytes.
    """
    pack = 8 // bits
    params = _params_for(x, bits)
    x3 = _to_2d(x, multiple=pack).reshape(pack, -1, LANES)
    u = jax.random.uniform(key, x3.shape, jnp.float32)
    if _use_pallas(backend):
        payload = kernel.encode_packed(
            x3, u, params, bits=bits,
            block_r=_block_r(x3.shape[2], 8 * pack + 1),
            interpret=_interpret())
    else:
        payload = ref.encode_packed(x3, u, params[0, 0], params[0, 1],
                                    bits=bits)
    return payload, params


@partial(jax.jit, static_argnames=("bits", "shape", "dtype", "backend"))
def decode(payload: jnp.ndarray, params: jnp.ndarray, *, shape: tuple,
           bits: int = 8, dtype=jnp.float32, backend: str = "auto"):
    """Unpack + dequantize a wire payload back to `shape`."""
    if _use_pallas(backend):
        out3 = kernel.decode_packed(
            payload, params, bits=bits, out_dtype=jnp.float32,
            block_r=_block_r(payload.shape[1], 1 + 4),
            interpret=_interpret())
    else:
        out3 = ref.decode_packed(payload, params[0, 0], params[0, 1],
                                 bits=bits)
    size = 1
    for d in shape:
        size *= d
    return out3.reshape(-1)[:size].reshape(shape).astype(dtype)


# ---------------------------------------------------------------------------
# Fused flat-buffer tier: the whole gradient pytree as ONE buffer, segmented
# into size-capped buckets with an (n_buckets, 2) params array. Wire layout:
# bucket b owns the contiguous element range [b*cap, (b+1)*cap) of the flat
# buffer and is segment-packed *within itself* (the per-leaf layout, applied
# per bucket). Full buckets contribute Rb = cap // (pack*512) payload rows
# each; the (possibly short) LAST bucket is padded only to the pack*512
# granule and gets its own, smaller segment view of Rt = ceil(t / (pack*512))
# rows — trimming rows of a cap-sized view would drop real elements, because
# segment packing interleaves the whole bucket range into every row. So the
# whole-tree message pays at most ONE pad granule (the tail's) plus one
# 8-byte params row per bucket — vs one granule + one row per leaf on the
# per-leaf paths. Kernel cost is O(1) in the leaf count: one bucketed call
# for the full buckets + one per-leaf-style call for the tail.
# ---------------------------------------------------------------------------


def _align_up(x: int, m: int) -> int:
    return -(-x // m) * m


def flat_geometry(total: int, *, bits: int,
                  bucket_elems: int = DEFAULT_BUCKET_ELEMS):
    """Static bucket geometry for a flat buffer of `total` elements.

    Returns (pack, cap, n_buckets, rows_per_bucket, rows_kept):
      cap             elements per full bucket (granule-aligned cap on
                      `bucket_elems`, shrunk for small buffers);
      rows_per_bucket payload rows each full bucket contributes;
      rows_kept       total payload rows on the wire — Rb per full bucket
                      plus the tail bucket's granule-aligned Rt.
    """
    if total <= 0:
        raise ValueError(f"empty flat buffer (total={total})")
    pack = 8 // bits
    granule = pack * LANES                      # elements per payload row
    cap = _align_up(min(bucket_elems, total), granule)
    n_buckets = -(-total // cap)
    rows_b = cap // granule
    tail = total - (n_buckets - 1) * cap        # in (0, cap]
    rows_kept = (n_buckets - 1) * rows_b + -(-tail // granule)
    return pack, cap, n_buckets, rows_b, rows_kept


def _bucket_views(flat: jnp.ndarray, key, *, bits: int, bucket_elems: int):
    """Split a flat buffer into head/tail segment views + per-bucket params.

    head: the n_buckets-1 full buckets as a (B-1, pack, Rb, C) view (None
    when there is a single bucket); tail: the last bucket, edge-padded to
    its own granule, as a (pack, Rt, C) view. ONE uniform draw covers
    head + padded tail, so qdq_flat and encode_flat consume identical
    per-element uniforms (bit-identical results). Edge-mode padding
    repeats the last real element, so the pad never perturbs the tail
    bucket's (lo, hi)."""
    pack, cap, nb, rows_b, _ = flat_geometry(flat.size, bits=bits,
                                             bucket_elems=bucket_elems)
    granule = pack * LANES
    flat = flat.reshape(-1).astype(jnp.float32)
    head_elems = (nb - 1) * cap
    tail = flat[head_elems:]
    t = tail.shape[0]
    rt = -(-t // granule)
    # per-bucket [lo, scale] rows (tail's from its REAL elements only)
    levels = (1 << bits) - 1
    los, his = [], []
    if nb > 1:
        head2 = flat[:head_elems].reshape(nb - 1, cap)
        los.append(jnp.min(head2, axis=1))
        his.append(jnp.max(head2, axis=1))
    los.append(jnp.min(tail)[None])
    his.append(jnp.max(tail)[None])
    lo = jnp.concatenate(los)
    hi = jnp.concatenate(his)
    scale = jnp.where(hi > lo, (hi - lo) / levels, 1.0)
    params = jnp.stack([lo, scale], axis=1)          # (n_buckets, 2)
    # one uniform draw over head + granule-padded tail: encode and qdq see
    # the same per-element randomness
    u = (None if key is None else
         jax.random.uniform(key, (head_elems + rt * granule,), jnp.float32))
    x4 = u4 = None
    if nb > 1:
        x4 = flat[:head_elems].reshape(nb - 1, pack, rows_b, LANES)
        if u is not None:
            u4 = u[:head_elems].reshape(x4.shape)
    tail_pad = jnp.pad(tail, (0, rt * granule - t), mode="edge")
    x3 = tail_pad.reshape(pack, rt, LANES)
    u3 = None if u is None else u[head_elems:].reshape(x3.shape)
    return x4, u4, x3, u3, params, (pack, nb, rows_b, rt, t)


@partial(jax.jit, static_argnames=("bits", "bucket_elems", "backend"))
def qdq_flat(flat: jnp.ndarray, key: jax.Array, *, bits: int = 8,
             bucket_elems: int = DEFAULT_BUCKET_ELEMS,
             backend: str = "auto") -> jnp.ndarray:
    """Fused per-bucket Q(x) over a flat buffer (whole pytree, one pass).

    Bit-identical to decode_flat(encode_flat(flat, key)) — same uniform
    draws, same per-bucket params, same rounding."""
    x4, u4, x3, u3, params, (pack, nb, _, rt, t) = _bucket_views(
        flat, key, bits=bits, bucket_elems=bucket_elems)
    parts = []
    if _use_pallas(backend):
        if nb > 1:
            h = kernel.qdq_bucketed(
                x4, u4, params[:nb - 1], bits=bits,
                block_r=_block_r(LANES, 12 * pack), interpret=_interpret())
            parts.append(h.reshape(-1))
        tl = kernel.qdq(x3.reshape(pack * rt, LANES),
                        u3.reshape(pack * rt, LANES), params[nb - 1:nb],
                        bits=bits, block_r=_block_r(LANES, 3 * 4),
                        interpret=_interpret())
        parts.append(tl.reshape(-1)[:t])
    else:
        if nb > 1:
            h = ref.qdq_bucketed(x4, u4, params[:nb - 1, 0],
                                 params[:nb - 1, 1], bits=bits)
            parts.append(h.reshape(-1))
        lo, scale = params[nb - 1, 0], params[nb - 1, 1]
        tl = ref.decode(ref.encode(x3, u3, lo, scale, bits=bits), lo, scale)
        parts.append(tl.reshape(-1)[:t])
    return jnp.concatenate(parts).astype(flat.dtype)


@partial(jax.jit, static_argnames=("bits", "bucket_elems", "backend"))
def encode_flat(flat: jnp.ndarray, key: jax.Array, *, bits: int = 8,
                bucket_elems: int = DEFAULT_BUCKET_ELEMS,
                backend: str = "auto"):
    """Bucketed encode of a flat fp32 buffer.

    Returns (payload uint8 (rows_kept, 512), params fp32 (n_buckets, 2)).
    Wire bytes = payload.nbytes + params.nbytes: the ONE message the
    fused exchanges ship per hop."""
    x4, u4, x3, u3, params, (pack, nb, _, rt, t) = _bucket_views(
        flat, key, bits=bits, bucket_elems=bucket_elems)
    parts = []
    if _use_pallas(backend):
        if nb > 1:
            h = kernel.encode_packed_bucketed(
                x4, u4, params[:nb - 1], bits=bits,
                block_r=_block_r(LANES, 8 * pack + 1),
                interpret=_interpret())
            parts.append(h.reshape(-1, LANES))
        parts.append(kernel.encode_packed(
            x3, u3, params[nb - 1:nb], bits=bits,
            block_r=_block_r(LANES, 8 * pack + 1), interpret=_interpret()))
    else:
        if nb > 1:
            parts.append(ref.encode_packed_bucketed(
                x4, u4, params[:nb - 1, 0], params[:nb - 1, 1],
                bits=bits).reshape(-1, LANES))
        parts.append(ref.encode_packed(x3, u3, params[nb - 1, 0],
                                       params[nb - 1, 1], bits=bits))
    return jnp.concatenate(parts, axis=0), params


@partial(jax.jit, static_argnames=("bits", "total", "bucket_elems",
                                   "backend"))
def decode_flat(payload: jnp.ndarray, params: jnp.ndarray, *, total: int,
                bits: int = 8, bucket_elems: int = DEFAULT_BUCKET_ELEMS,
                backend: str = "auto") -> jnp.ndarray:
    """Unpack + dequantize a bucketed wire payload back to (total,) fp32."""
    pack, cap, nb, rows_b, rows_kept = flat_geometry(
        total, bits=bits, bucket_elems=bucket_elems)
    granule = pack * LANES
    head_rows = (nb - 1) * rows_b
    t = total - (nb - 1) * cap
    parts = []
    if _use_pallas(backend):
        if nb > 1:
            h = kernel.decode_packed_bucketed(
                payload[:head_rows].reshape(nb - 1, rows_b, LANES),
                params[:nb - 1], bits=bits, out_dtype=jnp.float32,
                block_r=_block_r(LANES, 1 + 4), interpret=_interpret())
            parts.append(h.reshape(-1))
        tl = kernel.decode_packed(
            payload[head_rows:], params[nb - 1:nb], bits=bits,
            out_dtype=jnp.float32, block_r=_block_r(LANES, 1 + 4),
            interpret=_interpret())
        parts.append(tl.reshape(-1)[:t])
    else:
        if nb > 1:
            h = ref.decode_packed_bucketed(
                payload[:head_rows].reshape(nb - 1, rows_b, LANES),
                params[:nb - 1, 0], params[:nb - 1, 1], bits=bits)
            parts.append(h.reshape(-1))
        tl = ref.decode_packed(payload[head_rows:], params[nb - 1, 0],
                               params[nb - 1, 1], bits=bits)
        parts.append(tl.reshape(-1)[:t])
    return jnp.concatenate(parts)
