"""jit'd wrapper for the quantization kernel.

Handles arbitrary shapes (pad + reshape to (R, C=512) lanes), draws the
uniforms, computes global (lo, scale), picks BLOCK_R for the VMEM budget,
and falls back to interpret=True off-TPU.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.quant import kernel, ref

LANES = 512
VMEM_BUDGET = 8 * 1024 * 1024   # conservative half of ~16MB usable


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _block_r(c: int) -> int:
    # 3 fp32 tiles (x, u, out) resident
    rows = VMEM_BUDGET // (3 * 4 * c)
    rows = max(8, min(1024, rows))
    return int(rows) & ~7 or 8   # multiple of 8 sublanes


def _to_2d(x: jnp.ndarray) -> tuple[jnp.ndarray, int]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % LANES
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, LANES), pad


@partial(jax.jit, static_argnames=("bits",))
def quantize_dequantize(x: jnp.ndarray, key: jax.Array, *,
                        bits: int = 8) -> jnp.ndarray:
    """Fused Q(x) with stochastic rounding; same statistics as
    repro.core.compression.randomized_quantize."""
    lo, scale = ref.quant_params(x, bits)
    params = jnp.stack([lo, scale]).reshape(1, 2)
    x2d, _ = _to_2d(x)
    u = jax.random.uniform(key, x2d.shape, jnp.float32)
    out = kernel.qdq(x2d, u, params, bits=bits,
                     block_r=_block_r(x2d.shape[1]), interpret=_interpret())
    return out.reshape(-1)[: x.size].reshape(x.shape).astype(x.dtype)


@partial(jax.jit, static_argnames=("bits",))
def encode(x: jnp.ndarray, key: jax.Array, *, bits: int = 8):
    """Returns (codes int8 (R,C), params (1,2), orig_size). Wire bytes =
    codes.size * bits / 8 (+ 8B header) — fed to the roofline model."""
    lo, scale = ref.quant_params(x, bits)
    params = jnp.stack([lo, scale]).reshape(1, 2)
    x2d, _ = _to_2d(x)
    u = jax.random.uniform(key, x2d.shape, jnp.float32)
    codes = kernel.encode(x2d, u, params, bits=bits,
                          block_r=_block_r(x2d.shape[1]),
                          interpret=_interpret())
    return codes, params


@partial(jax.jit, static_argnames=("shape", "dtype"))
def decode(codes: jnp.ndarray, params: jnp.ndarray, *, shape: tuple,
           dtype=jnp.float32) -> jnp.ndarray:
    out = kernel.decode(codes, params, out_dtype=dtype,
                        block_r=_block_r(codes.shape[1]),
                        interpret=_interpret())
    size = 1
    for d in shape:
        size *= d
    return out.reshape(-1)[:size].reshape(shape)
