"""jit'd wrappers for the quantization kernels, with backend dispatch.

Handles arbitrary shapes (pad + reshape to C=512 lanes), draws the
uniforms, computes global (lo, scale), picks BLOCK_R per kernel from the
actual resident operand dtypes, and dispatches between the two backends:

  backend='pallas'  the TPU kernels (interpret=True off-TPU)
  backend='jnp'     the pure-jnp reference (ref.py)
  backend='auto'    pallas on TPU, jnp elsewhere

Both backends consume the *same* (lo, scale) and the same uniform draws —
`jax.random.uniform` fills shapes in flat C-order, so the (pack, R, C)
segment view of encode and the (R*pack, C) view of qdq read identical
per-element uniforms. Consequence (asserted in tests/test_codec.py):

    decode(encode(x, key)) == quantize_dequantize(x, key)   bit-for-bit
    pallas(interpret) == jnp                                bit-for-bit

Wire layout: the padded flat array is split into pack = 8 // bits
contiguous segments of R rows x 512 lanes; element i of the flat input
lives at segment i // (R*512), bit-field (i // (R*512)) * bits of
payload byte i % (R*512). Padding: inputs are zero-padded to a multiple
of pack * 512 elements; payload bytes = ceil(n / (pack*512)) * 512.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.quant import kernel, ref

LANES = 512
VMEM_BUDGET = 8 * 1024 * 1024   # conservative half of ~16MB usable


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _use_pallas(backend: str) -> bool:
    if backend == "auto":
        return jax.default_backend() == "tpu"
    if backend not in ("pallas", "jnp"):
        raise ValueError(f"unknown backend '{backend}'")
    return backend == "pallas"


def _block_r(c: int, bytes_per_out_row_elem: int) -> int:
    """Rows per grid step such that all resident tiles fit VMEM_BUDGET.

    `bytes_per_out_row_elem` sums, over every operand tile resident during
    one grid step, the bytes that correspond to ONE element-column of one
    output row (per-kernel: qdq has 3 fp32 tiles = 12; packed encode has
    pack fp32 x-segments + pack fp32 u-segments + 1 uint8 out = 8*pack+1;
    decode has 1 uint8 in + 1 fp32 out = 5).
    """
    rows = VMEM_BUDGET // (bytes_per_out_row_elem * c)
    rows = max(8, min(1024, rows))
    return int(rows) & ~7 or 8   # multiple of 8 sublanes


def _to_2d(x: jnp.ndarray, multiple: int = 1) -> jnp.ndarray:
    """Flatten + zero-pad to (R, LANES) with R a multiple of `multiple`."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % (LANES * multiple)
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, LANES)


def _params_for(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    lo, scale = ref.quant_params(x, bits)
    return jnp.stack([lo, scale]).reshape(1, 2)


@partial(jax.jit, static_argnames=("bits", "backend"))
def quantize_dequantize(x: jnp.ndarray, key: jax.Array, *, bits: int = 8,
                        backend: str = "auto") -> jnp.ndarray:
    """Fused Q(x) with stochastic rounding; same statistics as
    repro.core.compression.randomized_quantize."""
    params = _params_for(x, bits)
    # pad to the same multiple as the packed wire layout so qdq and
    # decode(encode(.)) consume identical uniform draws (threefry bit
    # generation is not prefix-stable across different totals)
    x2d = _to_2d(x, multiple=8 // bits)
    u = jax.random.uniform(key, x2d.shape, jnp.float32)
    if _use_pallas(backend):
        out = kernel.qdq(x2d, u, params, bits=bits,
                         block_r=_block_r(x2d.shape[1], 3 * 4),
                         interpret=_interpret())
    else:
        lo, scale = params[0, 0], params[0, 1]
        out = ref.decode(ref.encode(x2d, u, lo, scale, bits=bits), lo, scale)
    return out.reshape(-1)[: x.size].reshape(x.shape).astype(x.dtype)


@partial(jax.jit, static_argnames=("bits", "backend"))
def encode(x: jnp.ndarray, key: jax.Array, *, bits: int = 8,
           backend: str = "auto"):
    """Returns (payload uint8 (R, 512), params (1, 2)).

    The payload is the packed wire array: payload.size bytes carry
    8 // bits codes per byte. Wire bytes = payload.nbytes + params.nbytes.
    """
    pack = 8 // bits
    params = _params_for(x, bits)
    x3 = _to_2d(x, multiple=pack).reshape(pack, -1, LANES)
    u = jax.random.uniform(key, x3.shape, jnp.float32)
    if _use_pallas(backend):
        payload = kernel.encode_packed(
            x3, u, params, bits=bits,
            block_r=_block_r(x3.shape[2], 8 * pack + 1),
            interpret=_interpret())
    else:
        payload = ref.encode_packed(x3, u, params[0, 0], params[0, 1],
                                    bits=bits)
    return payload, params


@partial(jax.jit, static_argnames=("bits", "shape", "dtype", "backend"))
def decode(payload: jnp.ndarray, params: jnp.ndarray, *, shape: tuple,
           bits: int = 8, dtype=jnp.float32, backend: str = "auto"):
    """Unpack + dequantize a wire payload back to `shape`."""
    if _use_pallas(backend):
        out3 = kernel.decode_packed(
            payload, params, bits=bits, out_dtype=jnp.float32,
            block_r=_block_r(payload.shape[1], 1 + 4),
            interpret=_interpret())
    else:
        out3 = ref.decode_packed(payload, params[0, 0], params[0, 1],
                                 bits=bits)
    size = 1
    for d in shape:
        size *= d
    return out3.reshape(-1)[:size].reshape(shape).astype(dtype)
