"""jit'd wrappers for the quantization kernels, with backend dispatch.

Handles arbitrary shapes (pad + reshape to C=512 lanes), draws the
uniforms, computes global (lo, scale), picks BLOCK_R per kernel from the
actual resident operand dtypes, and dispatches between the two backends:

  backend='pallas'  the TPU kernels (interpret=True off-TPU)
  backend='jnp'     the pure-jnp reference (ref.py)
  backend='auto'    pallas on TPU, jnp elsewhere

Both backends consume the *same* (lo, scale) and the same uniform draws —
`jax.random.uniform` fills shapes in flat C-order, so the (pack, R, C)
segment view of encode and the (R*pack, C) view of qdq read identical
per-element uniforms. Consequence (asserted in tests/test_codec.py):

    decode(encode(x, key)) == quantize_dequantize(x, key)   bit-for-bit
    pallas(interpret) == jnp                                bit-for-bit

Wire layout: the padded flat array is split into pack = 8 // bits
contiguous segments of R rows x 512 lanes; element i of the flat input
lives at segment i // (R*512), bit-field (i // (R*512)) * bits of
payload byte i % (R*512). Padding: inputs are zero-padded to a multiple
of pack * 512 elements; payload bytes = ceil(n / (pack*512)) * 512.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.obs import flight as obs_flight
from repro.kernels.quant import kernel, ref

LANES = 512
VMEM_BUDGET = 8 * 1024 * 1024   # conservative half of ~16MB usable

# Fused flat-buffer tier: elements per quantization bucket (4Mi elements =
# 16 MiB fp32 per bucket -> a 100M-param gradient is ~25-31 (lo, scale)
# rows instead of one per pytree leaf). Canonical definition;
# repro.core.compression re-exports it.
DEFAULT_BUCKET_ELEMS = 1 << 22


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _use_pallas(backend: str) -> bool:
    if backend == "auto":
        return jax.default_backend() == "tpu"
    if backend not in ("pallas", "jnp"):
        raise ValueError(f"unknown backend '{backend}'")
    return backend == "pallas"


def _block_r(c: int, bytes_per_out_row_elem: int) -> int:
    """Rows per grid step such that all resident tiles fit VMEM_BUDGET.

    `bytes_per_out_row_elem` sums, over every operand tile resident during
    one grid step, the bytes that correspond to ONE element-column of one
    output row (per-kernel: qdq has 3 fp32 tiles = 12; packed encode has
    pack fp32 x-segments + pack fp32 u-segments + 1 uint8 out = 8*pack+1;
    decode has 1 uint8 in + 1 fp32 out = 5).
    """
    rows = VMEM_BUDGET // (bytes_per_out_row_elem * c)
    rows = max(8, min(1024, rows))
    return int(rows) & ~7 or 8   # multiple of 8 sublanes


def _to_2d(x: jnp.ndarray, multiple: int = 1) -> jnp.ndarray:
    """Flatten + zero-pad to (R, LANES) with R a multiple of `multiple`."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % (LANES * multiple)
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, LANES)


def _params_for(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    lo, scale = ref.quant_params(x, bits)
    return jnp.stack([lo, scale]).reshape(1, 2)


@partial(jax.jit, static_argnames=("bits", "backend"))
@obs_flight.kernel_annotation("quant.qdq")
def quantize_dequantize(x: jnp.ndarray, key: jax.Array, *, bits: int = 8,
                        backend: str = "auto") -> jnp.ndarray:
    """Fused Q(x) with stochastic rounding; same statistics as
    repro.core.compression.randomized_quantize."""
    params = _params_for(x, bits)
    # pad to the same multiple as the packed wire layout so qdq and
    # decode(encode(.)) consume identical uniform draws (threefry bit
    # generation is not prefix-stable across different totals)
    x2d = _to_2d(x, multiple=8 // bits)
    u = jax.random.uniform(key, x2d.shape, jnp.float32)
    if _use_pallas(backend):
        out = kernel.qdq(x2d, u, params, bits=bits,
                         block_r=_block_r(x2d.shape[1], 3 * 4),
                         interpret=_interpret())
    else:
        # direct qdq: skips the encode -> uint8 -> decode round trip (a
        # lossless detour — bit-identical, see ref.qdq) so XLA fuses the
        # whole rounding chain into one elementwise pass
        out = ref.qdq(x2d, u, params[0, 0], params[0, 1], bits=bits)
    return out.reshape(-1)[: x.size].reshape(x.shape).astype(x.dtype)


@partial(jax.jit, static_argnames=("bits", "backend"))
@obs_flight.kernel_annotation("quant.encode")
def encode(x: jnp.ndarray, key: jax.Array, *, bits: int = 8,
           backend: str = "auto"):
    """Returns (payload uint8 (R, 512), params (1, 2)).

    The payload is the packed wire array: payload.size bytes carry
    8 // bits codes per byte. Wire bytes = payload.nbytes + params.nbytes.
    """
    pack = 8 // bits
    params = _params_for(x, bits)
    x3 = _to_2d(x, multiple=pack).reshape(pack, -1, LANES)
    u = jax.random.uniform(key, x3.shape, jnp.float32)
    if _use_pallas(backend):
        payload = kernel.encode_packed(
            x3, u, params, bits=bits,
            block_r=_block_r(x3.shape[2], 8 * pack + 1),
            interpret=_interpret())
    else:
        payload = ref.encode_packed(x3, u, params[0, 0], params[0, 1],
                                    bits=bits)
    return payload, params


@partial(jax.jit, static_argnames=("bits", "shape", "dtype", "backend"))
@obs_flight.kernel_annotation("quant.decode")
def decode(payload: jnp.ndarray, params: jnp.ndarray, *, shape: tuple,
           bits: int = 8, dtype=jnp.float32, backend: str = "auto"):
    """Unpack + dequantize a wire payload back to `shape`."""
    if _use_pallas(backend):
        out3 = kernel.decode_packed(
            payload, params, bits=bits, out_dtype=jnp.float32,
            block_r=_block_r(payload.shape[1], 1 + 4),
            interpret=_interpret())
    else:
        out3 = ref.decode_packed(payload, params[0, 0], params[0, 1],
                                 bits=bits)
    size = 1
    for d in shape:
        size *= d
    return out3.reshape(-1)[:size].reshape(shape).astype(dtype)


# ---------------------------------------------------------------------------
# Fused flat-buffer tier: the whole gradient pytree as ONE buffer, segmented
# into size-capped buckets with an (n_buckets, 2) params array. Wire layout:
# bucket b owns the contiguous element range [b*cap, (b+1)*cap) of the flat
# buffer and is segment-packed *within itself* (the per-leaf layout, applied
# per bucket). Full buckets contribute Rb = cap // (pack*512) payload rows
# each; the (possibly short) LAST bucket is padded only to the pack*512
# granule and gets its own, smaller segment view of Rt = ceil(t / (pack*512))
# rows — trimming rows of a cap-sized view would drop real elements, because
# segment packing interleaves the whole bucket range into every row. So the
# whole-tree message pays at most ONE pad granule (the tail's) plus one
# 8-byte params row per bucket — vs one granule + one row per leaf on the
# per-leaf paths. Kernel cost is O(1) in the leaf count: one bucketed call
# for the full buckets + one per-leaf-style call for the tail.
# ---------------------------------------------------------------------------


def _align_up(x: int, m: int) -> int:
    return -(-x // m) * m


def edge_pad(flat: jnp.ndarray, padded_len: int) -> jnp.ndarray:
    """Zero-copy-pipeline edge pad: write `flat` and a broadcast of its
    last element into one preallocated buffer via dynamic_update_slice
    (``jnp.pad(mode='edge')`` lowers through concatenate — the copy tax
    this tier exists to avoid). Repeating the last REAL element keeps the
    pad out of every bucket's (lo, hi)."""
    n = flat.shape[0]
    if padded_len == n:
        return flat
    out = jnp.zeros((padded_len,), flat.dtype)
    out = lax.dynamic_update_slice(out, flat, (0,))
    tail = jnp.broadcast_to(flat[-1], (padded_len - n,))
    return lax.dynamic_update_slice(out, tail, (n,))


def _stack2(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(B,), (B,) -> (B, 2) without a concatenate/stack op (single-buffer
    writes, same contract as the payload assembly below)."""
    out = jnp.zeros((a.shape[0], 2), jnp.float32)
    out = lax.dynamic_update_slice(out, a.astype(jnp.float32)[:, None],
                                   (0, 0))
    return lax.dynamic_update_slice(out, b.astype(jnp.float32)[:, None],
                                    (0, 1))


def bucket_params(x2: jnp.ndarray, *, bits: int,
                  backend: str) -> jnp.ndarray:
    """Per-bucket (n_buckets, 2) [lo, scale] rows in ONE read of the
    buffer: min and max come out of the same reduction pass (the Pallas
    ``minmax_bucketed`` kernel on the pallas backend, a variadic
    ``lax.reduce`` on the jnp reference) instead of the separate min pass
    + max pass. The stats pass cannot fuse further into the encode kernel
    itself — stochastic rounding needs the bucket-global (lo, scale)
    before any element can be coded — so the flat pipeline's floor is two
    reads: one fused stats pass + one encode pass."""
    levels = (1 << bits) - 1
    if _use_pallas(backend):
        nb, cap = x2.shape
        mm = kernel.minmax_bucketed(
            x2.reshape(nb, cap // LANES, LANES),
            block_r=_block_r(LANES, 4), interpret=_interpret())
        lo, hi = mm[:, 0], mm[:, 1]
    else:
        lo, hi = ref.minmax_bucketed(x2)
    scale = jnp.where(hi > lo, (hi - lo) / levels, 1.0)
    return _stack2(lo, scale)


def partition_geometry(total: int, n_parts: int, *, bits: int,
                       bucket_elems: int = DEFAULT_BUCKET_ELEMS):
    """Equal, granule-aligned N-way partition view of a flat buffer (the
    ring AllReduce's reduce-scatter/all-gather unit).

    Returns (part_elems, nb_p, rows_p): each of the n_parts partitions
    owns part_elems contiguous elements of the (edge-padded to
    n_parts * part_elems) flat buffer — granule-aligned, so every
    partition segment-packs independently — and has its own bucket rows:
    nb_p (lo, scale) params rows and rows_p payload rows. Per-partition
    wire bytes = rows_p * LANES + nb_p * 8; a full partitioned exchange
    ships 2(N-1) of these per worker = 2*M*(N-1)/N + at most one pad
    granule per partition.
    """
    if n_parts <= 0:
        raise ValueError(f"need n_parts >= 1, got {n_parts}")
    pack = 8 // bits
    granule = pack * LANES
    part_elems = _align_up(max(1, -(-total // n_parts)), granule)
    _, _, nb_p, _, rows_p = flat_geometry(part_elems, bits=bits,
                                          bucket_elems=bucket_elems)
    return part_elems, nb_p, rows_p


def flat_geometry(total: int, *, bits: int,
                  bucket_elems: int = DEFAULT_BUCKET_ELEMS):
    """Static bucket geometry for a flat buffer of `total` elements.

    Returns (pack, cap, n_buckets, rows_per_bucket, rows_kept):
      cap             elements per full bucket (granule-aligned cap on
                      `bucket_elems`, shrunk for small buffers);
      rows_per_bucket payload rows each full bucket contributes;
      rows_kept       total payload rows on the wire — Rb per full bucket
                      plus the tail bucket's granule-aligned Rt.
    """
    if total <= 0:
        raise ValueError(f"empty flat buffer (total={total})")
    pack = 8 // bits
    granule = pack * LANES                      # elements per payload row
    cap = _align_up(min(bucket_elems, total), granule)
    n_buckets = -(-total // cap)
    rows_b = cap // granule
    tail = total - (n_buckets - 1) * cap        # in (0, cap]
    rows_kept = (n_buckets - 1) * rows_b + -(-tail // granule)
    return pack, cap, n_buckets, rows_b, rows_kept


def bucket_key(key, b):
    """Bucket b's uniform-draw key: fold_in(key, b). The SINGLE source of
    per-bucket randomness for every fused path — the vectorized
    encode_flat/qdq_flat (vmapped draw, bit-identical to per-key draws
    because threefry is counter-based) AND the cache-blocked from-tree
    encode draw the exact same bits per bucket."""
    return jax.random.fold_in(key, b)


def _bucket_views(flat: jnp.ndarray, key, *, bits: int, bucket_elems: int,
                  backend: str):
    """Split a flat buffer into head/tail segment views + per-bucket params.

    The buffer is edge-padded ONCE (single-buffer writes, no concatenate)
    to n_buckets * cap; every view below — the (nb, cap) stats view, the
    head's (B-1, pack, Rb, C) segments, the tail's (pack, Rt, C) segments
    — is a slice/reshape of that one padded buffer, so nothing else is
    materialized. Per-bucket [lo, scale] come from ``bucket_params``
    (min+max fused into one reduction read). Edge padding repeats the
    last REAL element, so the pad never perturbs the tail bucket's
    (lo, hi). Uniforms are drawn PER BUCKET under ``bucket_key(key, b)``
    (head buckets via one vmapped draw), so qdq_flat, encode_flat, and
    the cache-blocked from-tree encode all consume identical per-element
    randomness (bit-identical results)."""
    pack, cap, nb, rows_b, _ = flat_geometry(flat.size, bits=bits,
                                             bucket_elems=bucket_elems)
    granule = pack * LANES
    flat = flat.reshape(-1).astype(jnp.float32)
    total = flat.shape[0]
    head_elems = (nb - 1) * cap
    t = total - head_elems
    rt = -(-t // granule)
    padded = edge_pad(flat, nb * cap)
    params = bucket_params(padded.reshape(nb, cap), bits=bits,
                           backend=backend)
    x4 = u4 = None
    if nb > 1:
        x4 = padded[:head_elems].reshape(nb - 1, pack, rows_b, LANES)
        if key is not None:
            hkeys = jax.vmap(lambda b: bucket_key(key, b))(
                jnp.arange(nb - 1))
            u4 = jax.vmap(
                lambda k: jax.random.uniform(k, (pack, rows_b, LANES),
                                             jnp.float32))(hkeys)
    x3 = padded[head_elems:head_elems + rt * granule].reshape(pack, rt,
                                                              LANES)
    u3 = (None if key is None else
          jax.random.uniform(bucket_key(key, nb - 1), x3.shape,
                             jnp.float32))
    return x4, u4, x3, u3, params, (pack, nb, rows_b, rt, t)


def _write_head_tail(head, tail, out_shape, dtype):
    """Assemble the fused result by writing head + tail into ONE
    preallocated output (dynamic_update_slice) instead of concatenating —
    the copy that made the PR-2 flat path a measured compute regression.
    head is None in the single-bucket regime (the tail IS the result)."""
    if head is None:
        return tail.astype(dtype)
    out = jnp.zeros(out_shape, dtype)
    out = lax.dynamic_update_slice(out, head.astype(dtype),
                                   (0,) * len(out_shape))
    off = (head.shape[0],) + (0,) * (len(out_shape) - 1)
    return lax.dynamic_update_slice(out, tail.astype(dtype), off)


@obs_flight.kernel_annotation("quant.qdq_flat")
def _qdq_flat_impl(flat: jnp.ndarray, key: jax.Array, *, bits: int = 8,
                   bucket_elems: int = DEFAULT_BUCKET_ELEMS,
                   backend: str = "auto") -> jnp.ndarray:
    """Fused per-bucket Q(x) over a flat buffer (whole pytree, one pass).

    Bit-identical to decode_flat(encode_flat(flat, key)) — same uniform
    draws, same per-bucket params, same rounding."""
    x4, u4, x3, u3, params, (pack, nb, _, rt, t) = _bucket_views(
        flat, key, bits=bits, bucket_elems=bucket_elems, backend=backend)
    head = None
    if _use_pallas(backend):
        if nb > 1:
            head = kernel.qdq_bucketed(
                x4, u4, params[:nb - 1], bits=bits,
                block_r=_block_r(LANES, 12 * pack),
                interpret=_interpret()).reshape(-1)
        tl = kernel.qdq(x3.reshape(pack * rt, LANES),
                        u3.reshape(pack * rt, LANES), params[nb - 1:nb],
                        bits=bits, block_r=_block_r(LANES, 3 * 4),
                        interpret=_interpret())
    else:
        if nb > 1:
            head = ref.qdq_bucketed(x4, u4, params[:nb - 1, 0],
                                    params[:nb - 1, 1],
                                    bits=bits).reshape(-1)
        lo, scale = params[nb - 1, 0], params[nb - 1, 1]
        tl = ref.decode(ref.encode(x3, u3, lo, scale, bits=bits), lo, scale)
    return _write_head_tail(head, tl.reshape(-1)[:t], (flat.size,),
                            flat.dtype)


qdq_flat = jax.jit(_qdq_flat_impl,
                   static_argnames=("bits", "bucket_elems", "backend"))

# Donating variant: the flat buffer's storage is handed to XLA for reuse
# as the (same shape/dtype) output. Safe ONLY when the caller's buffer is
# dead after the call — e.g. a hop's decode+add temporary, or a freshly
# flattened gradient; a no-op hint under an outer trace and on backends
# without donation (CPU), real HBM savings at top level on TPU.
qdq_flat_donated = jax.jit(_qdq_flat_impl,
                           static_argnames=("bits", "bucket_elems",
                                            "backend"),
                           donate_argnums=(0,))


def encode_flat_blocked(leaves, offsets, total: int, key, *, bits: int = 8,
                        bucket_elems: int = DEFAULT_BUCKET_ELEMS):
    """Cache-blocked whole-tree encode: the zero-copy pipeline's hot path.

    Instead of materializing the full flat buffer (flatten) and then
    streaming it again for stats + uniforms + encode — several DRAM
    round trips over the whole gradient — each bucket is assembled from
    its (statically known) leaf fragments into ONE bucket-sized hot
    buffer, and its (lo, scale), uniform draw, quantization, and packing
    all happen while that block is cache-resident. Leaves are read once,
    payload rows are written once; the only working buffer is one bucket.

    Bit-identical to ``encode_flat(flatten(tree))``: stats are exact
    min/max of the same elements, every bucket draws under
    ``bucket_key(key, b)``, and the math is the same jnp reference. (The
    Pallas tier keeps the full-buffer views — on TPU the bucketed grid
    is already the blocking.)

    ``leaves``/``offsets``/``total`` are the FlatLayout pieces (passed
    raw to keep this module independent of repro.core).
    """
    pack, cap, nb, rows_b, rows_kept = flat_geometry(
        total, bits=bits, bucket_elems=bucket_elems)
    granule = pack * LANES
    levels = (1 << bits) - 1
    flats = [leaf.reshape(-1).astype(jnp.float32) for leaf in leaves]
    sizes = [f.shape[0] for f in flats]
    payload = jnp.zeros((rows_kept, LANES), jnp.uint8)
    params = jnp.zeros((nb, 2), jnp.float32)
    row_off = 0
    for b in range(nb):
        start = b * cap
        belems = min(cap, total - start)
        buf = jnp.zeros((belems,), jnp.float32)
        for off, sz, fl in zip(offsets, sizes, flats):
            lo_e, hi_e = max(off, start), min(off + sz, start + belems)
            if lo_e < hi_e:
                buf = lax.dynamic_update_slice(
                    buf, fl[lo_e - off:hi_e - off], (lo_e - start,))
        lo = jnp.min(buf)
        hi = jnp.max(buf)
        scale = jnp.where(hi > lo, (hi - lo) / levels, 1.0)
        rb = -(-belems // granule)
        if rb * granule != belems:
            buf = edge_pad(buf, rb * granule)
        x3 = buf.reshape(pack, rb, LANES)
        u = jax.random.uniform(bucket_key(key, b), x3.shape, jnp.float32)
        rows = ref.encode_packed(x3, u, lo, scale, bits=bits)
        payload = lax.dynamic_update_slice(payload, rows, (row_off, 0))
        params = lax.dynamic_update_slice(params, lo.reshape(1, 1), (b, 0))
        params = lax.dynamic_update_slice(params, scale.reshape(1, 1),
                                          (b, 1))
        row_off += rb
    return payload, params


@partial(jax.jit, static_argnames=("bits", "bucket_elems", "backend"))
@obs_flight.kernel_annotation("quant.encode_flat")
def encode_flat(flat: jnp.ndarray, key: jax.Array, *, bits: int = 8,
                bucket_elems: int = DEFAULT_BUCKET_ELEMS,
                backend: str = "auto"):
    """Bucketed encode of a flat fp32 buffer.

    Returns (payload uint8 (rows_kept, 512), params fp32 (n_buckets, 2)).
    Wire bytes = payload.nbytes + params.nbytes: the ONE message the
    fused exchanges ship per hop. Head and tail payload rows are written
    into one preallocated output (no concatenate — asserted via jaxpr in
    tests/test_flat_codec.py)."""
    x4, u4, x3, u3, params, (pack, nb, rows_b, rt, t) = _bucket_views(
        flat, key, bits=bits, bucket_elems=bucket_elems, backend=backend)
    head = None
    if _use_pallas(backend):
        if nb > 1:
            head = kernel.encode_packed_bucketed(
                x4, u4, params[:nb - 1], bits=bits,
                block_r=_block_r(LANES, 8 * pack + 1),
                interpret=_interpret()).reshape(-1, LANES)
        tl = kernel.encode_packed(
            x3, u3, params[nb - 1:nb], bits=bits,
            block_r=_block_r(LANES, 8 * pack + 1), interpret=_interpret())
    else:
        if nb > 1:
            head = ref.encode_packed_bucketed(
                x4, u4, params[:nb - 1, 0], params[:nb - 1, 1],
                bits=bits).reshape(-1, LANES)
        tl = ref.encode_packed(x3, u3, params[nb - 1, 0],
                               params[nb - 1, 1], bits=bits)
    rows_kept = (nb - 1) * rows_b + rt
    payload = _write_head_tail(head, tl, (rows_kept, LANES), jnp.uint8)
    return payload, params


def encode_partitioned_blocked(leaves, offsets, total: int, key, *,
                               n_parts: int, bits: int = 8,
                               bucket_elems: int = DEFAULT_BUCKET_ELEMS):
    """Cache-blocked partitioned whole-tree encode (the jnp tier of
    ``tree_encode_partitioned``).

    The vmapped flatten-then-encode pipeline materializes the full flat
    buffer and — worse — turns every per-partition dynamic_update_slice
    (edge_pad, head/tail assembly) into a full-buffer scatter under vmap,
    which is why the partitioned encode used to cost ~3x the flat encode.
    Here each partition's buckets are assembled straight from their
    (statically known) leaf fragments and statted/drawn/packed while
    cache-hot, exactly like ``encode_flat_blocked`` — leaves are read
    once, payload rows written once, no full-size temporary exists.

    Bit-identical to the vmapped ``_encode_partitions`` reference:
    partition p draws under fold_in(key, p), bucket b within it under
    ``bucket_key(fold_in(key, p), b)``, and positions past the real
    `total` repeat the LAST REAL element (edge_pad semantics), so they
    never perturb a bucket's (lo, hi). Partition sizes are granule-
    aligned, so no intra-bucket padding exists.

    Returns (payload (n_parts, rows_p, 512) uint8,
             params (n_parts, nb_p, 2) fp32).
    """
    part_elems, nb_p, rows_p = partition_geometry(
        total, n_parts, bits=bits, bucket_elems=bucket_elems)
    pack, cap, nb, _, _ = flat_geometry(part_elems, bits=bits,
                                        bucket_elems=bucket_elems)
    assert nb == nb_p, (nb, nb_p)
    granule = pack * LANES
    levels = (1 << bits) - 1
    flats = [leaf.reshape(-1).astype(jnp.float32) for leaf in leaves]
    sizes = [f.shape[0] for f in flats]
    last = flats[-1][-1]
    payload = jnp.zeros((n_parts, rows_p, LANES), jnp.uint8)
    params = jnp.zeros((n_parts, nb_p, 2), jnp.float32)
    for p in range(n_parts):
        pkey = bucket_key(key, p)   # fold_in(key, p): the partition key
        row_off = 0
        for b in range(nb):
            start = p * part_elems + b * cap
            belems = min(cap, part_elems - b * cap)
            buf = jnp.zeros((belems,), jnp.float32)
            for off, sz, fl in zip(offsets, sizes, flats):
                lo_e, hi_e = max(off, start), min(off + sz, start + belems)
                if lo_e < hi_e:
                    buf = lax.dynamic_update_slice(
                        buf, fl[lo_e - off:hi_e - off], (lo_e - start,))
            if start + belems > total:
                idx = jnp.arange(belems)
                buf = jnp.where(start + idx < total, buf, last)
            lo = jnp.min(buf)
            hi = jnp.max(buf)
            scale = jnp.where(hi > lo, (hi - lo) / levels, 1.0)
            rb = belems // granule
            x3 = buf.reshape(pack, rb, LANES)
            u = jax.random.uniform(bucket_key(pkey, b), x3.shape,
                                   jnp.float32)
            rows = ref.encode_packed(x3, u, lo, scale, bits=bits)
            payload = lax.dynamic_update_slice(
                payload, rows.reshape(1, rb, LANES), (p, row_off, 0))
            params = lax.dynamic_update_slice(
                params, jnp.stack([lo, scale]).reshape(1, 1, 2), (p, b, 0))
            row_off += rb
    return payload, params


@partial(jax.jit, static_argnames=("bits", "total", "bucket_elems",
                                   "backend"))
@obs_flight.kernel_annotation("quant.decode_flat")
def decode_flat(payload: jnp.ndarray, params: jnp.ndarray, *, total: int,
                bits: int = 8, bucket_elems: int = DEFAULT_BUCKET_ELEMS,
                backend: str = "auto") -> jnp.ndarray:
    """Unpack + dequantize a bucketed wire payload back to (total,) fp32.

    Head and tail land in one preallocated output (single-buffer writes,
    no concatenate), mirroring encode_flat."""
    pack, cap, nb, rows_b, rows_kept = flat_geometry(
        total, bits=bits, bucket_elems=bucket_elems)
    head_rows = (nb - 1) * rows_b
    t = total - (nb - 1) * cap
    head = None
    if _use_pallas(backend):
        if nb > 1:
            head = kernel.decode_packed_bucketed(
                payload[:head_rows].reshape(nb - 1, rows_b, LANES),
                params[:nb - 1], bits=bits, out_dtype=jnp.float32,
                block_r=_block_r(LANES, 1 + 4),
                interpret=_interpret()).reshape(-1)
        tl = kernel.decode_packed(
            payload[head_rows:], params[nb - 1:nb], bits=bits,
            out_dtype=jnp.float32, block_r=_block_r(LANES, 1 + 4),
            interpret=_interpret())
    else:
        if nb > 1:
            head = ref.decode_packed_bucketed(
                payload[:head_rows].reshape(nb - 1, rows_b, LANES),
                params[:nb - 1, 0], params[:nb - 1, 1],
                bits=bits).reshape(-1)
        tl = ref.decode_packed(payload[head_rows:], params[nb - 1, 0],
                               params[nb - 1, 1], bits=bits)
    return _write_head_tail(head, tl.reshape(-1)[:t], (total,),
                            jnp.float32)


# ---------------------------------------------------------------------------
# Fused ring hop: decode + add + re-encode as ONE dispatch. The partitioned
# ring AllReduce's reduce-scatter hop is exactly this op over one partition.
# ---------------------------------------------------------------------------


def _dae_ref(payload, params, x4, u4, *, bits: int):
    """jnp reference for the fused hop: the literal decode -> add ->
    minmax -> encode composition on the (B, pack, Rb, C) bucket view."""
    levels = (1 << bits) - 1
    dec = ref.decode_packed_bucketed(payload, params[:, 0], params[:, 1],
                                     bits=bits)
    summed = dec + x4
    lo, hi = ref.minmax_bucketed(summed.reshape(summed.shape[0], -1))
    scale = jnp.where(hi > lo, (hi - lo) / levels, 1.0)
    out = ref.encode_packed_bucketed(summed, u4, lo, scale, bits=bits)
    return out, _stack2(lo, scale)


@partial(jax.jit, static_argnames=("bits", "bucket_elems", "backend"))
@obs_flight.kernel_annotation("quant.decode_add_encode_flat")
def decode_add_encode_flat(payload: jnp.ndarray, params: jnp.ndarray,
                           local: jnp.ndarray, key: jax.Array, *,
                           bits: int = 8,
                           bucket_elems: int = DEFAULT_BUCKET_ELEMS,
                           backend: str = "auto"):
    """ONE fused ring hop over a flat message: decode the packed payload,
    add the `local` fp32 buffer, and re-encode under `key`, without ever
    materializing the decoded or summed fp32 buffer (Pallas backend: the
    two-phase ``decode_add_encode_bucketed`` kernel; jnp backend: the
    composition reference). Bit-identical to

        encode_flat(decode_flat(payload, params, total=local.size)
                    + local, key)

    on both backends. Granule-aligned buffers (every ring partition, by
    ``partition_geometry`` construction) take the fused path; other sizes
    fall back to the sequential composition, whose edge-pad handling the
    fused kernel does not reproduce.
    """
    total = local.size
    pack, cap, nb, rows_b, rows_kept = flat_geometry(
        total, bits=bits, bucket_elems=bucket_elems)
    granule = pack * LANES
    flat = local.reshape(-1).astype(jnp.float32)
    if total % granule:
        dec = decode_flat(payload, params, total=total, bits=bits,
                          bucket_elems=bucket_elems, backend=backend)
        return encode_flat(dec + flat, key, bits=bits,
                           bucket_elems=bucket_elems, backend=backend)
    head_rows = (nb - 1) * rows_b
    head_elems = (nb - 1) * cap
    rt = rows_kept - head_rows
    use_pallas = _use_pallas(backend)
    head = head_p = None
    if nb > 1:
        x4 = flat[:head_elems].reshape(nb - 1, pack, rows_b, LANES)
        hkeys = jax.vmap(lambda b: bucket_key(key, b))(jnp.arange(nb - 1))
        u4 = jax.vmap(
            lambda k: jax.random.uniform(k, (pack, rows_b, LANES),
                                         jnp.float32))(hkeys)
        pay4 = payload[:head_rows].reshape(nb - 1, rows_b, LANES)
        if use_pallas:
            head, head_p = kernel.decode_add_encode_bucketed(
                pay4, params[:nb - 1], x4, u4, bits=bits,
                block_r=_block_r(LANES, 8 * pack + 2),
                interpret=_interpret())
        else:
            head, head_p = _dae_ref(pay4, params[:nb - 1], x4, u4,
                                    bits=bits)
        head = head.reshape(-1, LANES)
    x3 = flat[head_elems:].reshape(1, pack, rt, LANES)
    u3 = jax.random.uniform(bucket_key(key, nb - 1),
                            (pack, rt, LANES),
                            jnp.float32).reshape(1, pack, rt, LANES)
    pay3 = payload[head_rows:].reshape(1, rt, LANES)
    if use_pallas:
        tl, tl_p = kernel.decode_add_encode_bucketed(
            pay3, params[nb - 1:nb], x3, u3, bits=bits,
            block_r=_block_r(LANES, 8 * pack + 2), interpret=_interpret())
    else:
        tl, tl_p = _dae_ref(pay3, params[nb - 1:nb], x3, u3, bits=bits)
    out_payload = _write_head_tail(head, tl.reshape(rt, LANES),
                                   (rows_kept, LANES), jnp.uint8)
    out_params = _write_head_tail(head_p, tl_p, (nb, 2), jnp.float32)
    return out_payload, out_params
