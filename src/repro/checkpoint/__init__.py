from repro.checkpoint.npz import (CheckpointCorruptionError,
                                  latest_checkpoint, load_state,
                                  save_state)

__all__ = ["CheckpointCorruptionError", "save_state", "load_state",
           "latest_checkpoint"]
