from repro.checkpoint.npz import latest_checkpoint, load_state, save_state

__all__ = ["save_state", "load_state", "latest_checkpoint"]
