"""Checkpointing: flattened-path npz (orbax is not installed offline).

Leaves are keyed by their slash-joined pytree path; restore rebuilds into a
caller-provided template (so dtypes/sharding decisions stay with the
trainer). On a real multi-host cluster each host would write its
addressable shards under `<dir>/shard-<process_index>.npz`; here (single
process) everything lands in one file. bf16 leaves are stored via a uint16
view (npz has no native bfloat16).

Writes are ATOMIC: the archive is written to a temporary file in the same
directory and published with ``os.replace``, so a crash mid-checkpoint
(the exact failure mode the cluster tier's FaultPlan injects) can never
leave a half-written ``step-*.npz`` — a reader sees the previous complete
checkpoint or the new one, nothing in between. ``load_state`` validates
the archive and raises ``ValueError`` on truncated/corrupt files instead
of deserializing garbage.

Integrity: ``save_state`` stores a CRC32 companion entry
(``__crc__<key>``) per array, and ``load_state`` verifies each checksum
against the raw stored bytes BEFORE any dtype/view conversion — a
silently bit-flipped leaf (disk rot, a bad donor in the decentralized
rejoin path) raises a ``ValueError`` naming the file and the array
instead of training on garbage. Archives written without checksums
(older checkpoints) still load.
"""
from __future__ import annotations

import os
import tempfile
import zipfile
import zlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

_BF16_PREFIX = "__bf16__"
_CRC_PREFIX = "__crc__"


class CheckpointCorruptionError(ValueError):
    """A stored array's bytes disagree with its CRC32 companion entry —
    the archive itself is well-formed zip, but a leaf was bit-flipped
    after the write (disk rot, a bad donor copy)."""


def _crc32(arr: np.ndarray) -> np.ndarray:
    """The array's CRC32 over its raw bytes, as a storable uint32."""
    return np.uint32(zlib.crc32(np.ascontiguousarray(arr).tobytes())
                     & 0xFFFFFFFF)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_state(state: PyTree, directory: str, *, step: int = 0) -> str:
    os.makedirs(directory, exist_ok=True)
    flat: dict[str, np.ndarray] = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(state):
        key = _path_str(path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:
            key = _BF16_PREFIX + key
            arr = arr.view(np.uint16)
        flat[key] = arr
        flat[_CRC_PREFIX + key] = _crc32(arr)
    fname = os.path.join(directory, f"step-{step:08d}.npz")
    # write-then-rename: the temp file lives in the target directory so
    # os.replace is an atomic same-filesystem rename
    fd, tmp = tempfile.mkstemp(dir=directory, prefix=".tmp-step-",
                               suffix=".npz")
    try:
        with os.fdopen(fd, "wb") as fh:
            np.savez(fh, **flat)
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, fname)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return fname


def latest_checkpoint(directory: str) -> str | None:
    if not os.path.isdir(directory):
        return None
    files = sorted(f for f in os.listdir(directory)
                   if f.startswith("step-") and f.endswith(".npz"))
    return os.path.join(directory, files[-1]) if files else None


def load_state(template: PyTree, fname: str) -> PyTree:
    by_key: dict[str, np.ndarray] = {}
    try:
        data = np.load(fname)
        crcs = {key[len(_CRC_PREFIX):]: int(data[key])
                for key in data.files if key.startswith(_CRC_PREFIX)}
        for key in data.files:
            if key.startswith(_CRC_PREFIX):
                continue
            # materialize every member here: a truncated zip member
            # surfaces while we still know which file to blame
            arr = data[key]
            # checksum the raw stored bytes before any view conversion;
            # archives without __crc__ entries (pre-integrity) still load
            if key in crcs and int(_crc32(arr)) != crcs[key]:
                raise CheckpointCorruptionError(
                    f"checksum mismatch in checkpoint {fname!r}: array "
                    f"{key!r} is corrupt (stored CRC32 {crcs[key]:#010x}"
                    f" != computed {int(_crc32(arr)):#010x})")
            if key.startswith(_BF16_PREFIX):
                by_key[key[len(_BF16_PREFIX):]] = arr.view(jnp.bfloat16)
            else:
                by_key[key] = arr
    except (FileNotFoundError, CheckpointCorruptionError):
        raise
    except (zipfile.BadZipFile, EOFError, OSError, ValueError) as e:
        raise ValueError(
            f"corrupt or truncated checkpoint {fname!r}: {e} — writes "
            "are atomic, so this file was damaged after the fact; "
            "restore from the previous step") from e

    def restore(path, leaf):
        key = _path_str(path)
        if key not in by_key:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = by_key[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                             f"template {leaf.shape}")
        return jnp.asarray(arr, dtype=leaf.dtype)

    return jax.tree_util.tree_map_with_path(restore, template)
