from repro.data.pipeline import (SyntheticLM, make_batch_shapes,
                                 synthetic_batch)

__all__ = ["SyntheticLM", "make_batch_shapes", "synthetic_batch"]
