"""Deterministic synthetic data pipeline.

Real corpora are not available offline, so the pipeline synthesizes a
*learnable* token stream: a fixed random bigram transition table (temperature-
controlled) — losses fall measurably within a few hundred steps, which the
end-to-end example uses as its progress signal. The pipeline is
sharding-aware: a batch is produced as one global array that the caller
device_puts with the mesh batch sharding; per-host slicing would follow the
same index math on a real multi-host cluster.

Also provides `make_batch_shapes` / `synthetic_batch`, the single source of
truth for what every (arch x input-shape) batch looks like — the launcher's
`input_specs()` builds its ShapeDtypeStructs from it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import InputShape, ModelConfig


@dataclasses.dataclass
class SyntheticLM:
    """Bigram-chain synthetic language model data."""

    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    order_temp: float = 1.0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # sparse-ish bigram preference: each token prefers ~8 successors
        self.n_succ = 8
        self.succ = rng.integers(0, self.vocab,
                                 size=(self.vocab, self.n_succ)).astype(np.int32)

    def batch_at(self, step: int, key: Optional[jax.Array] = None) -> dict:
        key = key if key is not None else jax.random.PRNGKey(self.seed)
        k = jax.random.fold_in(key, step)
        k1, k2, k3 = jax.random.split(k, 3)
        first = jax.random.randint(k1, (self.batch,), 0, self.vocab)
        choices = jax.random.randint(k2, (self.batch, self.seq_len),
                                     0, self.n_succ)
        noise = jax.random.bernoulli(k3, 0.05, (self.batch, self.seq_len))
        rand_tok = jax.random.randint(jax.random.fold_in(k3, 1),
                                      (self.batch, self.seq_len),
                                      0, self.vocab)
        succ = jnp.asarray(self.succ)

        def step_fn(tok, inputs):
            choice, nz, rt = inputs
            nxt = succ[tok, choice]
            nxt = jnp.where(nz, rt, nxt)
            return nxt, nxt

        _, seq = jax.lax.scan(
            step_fn, first,
            (choices.T, noise.T, rand_tok.T))
        seq = seq.T                                   # (B, S)
        tokens = seq[:, :-1]
        labels = seq[:, 1:]
        return {"tokens": tokens, "labels": labels}


def _embed_dtype(dtype):
    return dtype


def make_batch_shapes(cfg: ModelConfig, shape: InputShape, *,
                      dtype=jnp.bfloat16) -> dict:
    """jax.ShapeDtypeStruct pytree for one global batch (dry-run input_specs).

    train/prefill: full-sequence inputs (+labels for train).
    decode: one new token per sequence (the KV state is separate).
    """
    b, s = shape.global_batch, shape.seq_len
    sd = jax.ShapeDtypeStruct
    batch: dict[str, Any] = {}
    if shape.kind == "decode":
        if cfg.frontend == "token":
            batch["tokens"] = sd((b, 1), jnp.int32)
        else:
            batch["embeddings"] = sd((b, 1, cfg.d_model), dtype)
        return batch
    if cfg.frontend == "token":
        batch["tokens"] = sd((b, s), jnp.int32)
    else:
        batch["embeddings"] = sd((b, s, cfg.d_model), dtype)
        if cfg.rope_variant == "mrope":
            batch["positions3"] = sd((b, 3, s), jnp.int32)
    if cfg.is_encdec:
        # frame-embedding memory from the stub frontend (src len = s)
        batch["src_embeddings"] = sd((b, s, cfg.d_model), dtype)
    if shape.kind == "train":
        batch["labels"] = sd((b, s), jnp.int32)
    return batch


def synthetic_batch(cfg: ModelConfig, shape: InputShape, key: jax.Array, *,
                    dtype=jnp.bfloat16) -> dict:
    """Concrete random batch matching make_batch_shapes (smoke tests)."""
    shapes = make_batch_shapes(cfg, shape, dtype=dtype)
    out = {}
    for name, sd in shapes.items():
        k = jax.random.fold_in(key, hash(name) % (2**31))
        if sd.dtype == jnp.int32:
            hi = cfg.vocab if name in ("tokens", "labels") else shape.seq_len
            out[name] = jax.random.randint(k, sd.shape, 0, hi)
        else:
            out[name] = (jax.random.normal(k, sd.shape) * 0.02).astype(sd.dtype)
    return out
